"""AOT pipeline: lower every L2 entry point to HLO **text** artifacts.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and
NOT a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the Rust
``xla`` crate) rejects (``proto.id() <= INT_MAX``).  The HLO text parser
reassigns ids, so text round-trips cleanly.  See /opt/xla-example/README.md.

Usage (normally via ``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts [--configs a,b]

Produces ``<config>.<prim>.hlo.txt`` per primitive plus ``manifest.json``
describing shapes/param layout for the Rust runtime loader.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    MlpSpec,
    make_cnf_entry_points,
    make_entry_points,
    param_count,
)

# ---------------------------------------------------------------------------
# Experiment configs (DESIGN.md §6).  Batch sizes are CPU-scaled; the paper's
# V100 values are noted in DESIGN.md substitution table.
# ---------------------------------------------------------------------------

CONFIGS = {
    # quick: tiny everything — used by Rust integration tests and quickstart.
    "quick_d8": dict(kind="mlp", dims=(9, 16, 8), act="tanh", time_dep=True,
                     batch=4),
    # classification ODE block (paper: SqueezeNext on CIFAR10, 4 ODE blocks,
    # 199,800 params total; here 4 blocks x 50,296 = 201,184).
    "clf_d64": dict(kind="mlp", dims=(65, 168, 168, 64), act="relu",
                    time_dep=True, batch=128),
    # tanh variant for the Fig.2 activation ablation.
    "clf_d64_tanh": dict(kind="mlp", dims=(65, 168, 168, 64), act="tanh",
                         time_dep=True, batch=128),
    # CNF (FFJORD) surrogates of POWER / MINIBOONE / BSDS300 (d = 6/43/63).
    "cnf_power": dict(kind="cnf", dims=(7, 64, 64, 6), act="tanh",
                      time_dep=True, batch=512),
    "cnf_miniboone": dict(kind="cnf", dims=(44, 256, 256, 43), act="tanh",
                          time_dep=True, batch=256),
    "cnf_bsds300": dict(kind="cnf", dims=(64, 256, 256, 256, 63), act="tanh",
                        time_dep=True, batch=128),
    # stiff Robertson task: autonomous RHS, 5 GELU hidden layers (Kim et al.).
    "stiff_d3": dict(kind="mlp", dims=(3, 50, 50, 50, 50, 50, 3), act="gelu",
                     time_dep=False, batch=1),
}

# Primitives that consume (u, theta, t, ...) — example args per suffix.
def _example_args(cfg, spec: MlpSpec):
    b = cfg["batch"]
    d = spec.state_dim
    p = param_count(spec.dims)
    f32 = jnp.float32
    u = jax.ShapeDtypeStruct((b, d), f32)
    th = jax.ShapeDtypeStruct((p,), f32)
    t = jax.ShapeDtypeStruct((1,), f32)
    v = jax.ShapeDtypeStruct((b, d), f32)
    if cfg["kind"] == "mlp":
        return {
            "f": (u, th, t),
            "vjp_u": (u, th, t, v),
            "vjp_both": (u, th, t, v),
            "jvp": (u, th, t, v),
        }
    else:  # cnf
        eps = jax.ShapeDtypeStruct((b, d), f32)
        vl = jax.ShapeDtypeStruct((b, 1), f32)
        return {
            "faug": (u, th, t, eps),
            "vjp_aug": (u, th, t, eps, v, vl),
        }


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(name: str, cfg: dict, out_dir: str) -> dict:
    """Lower all primitives of one config; return its manifest entry."""
    spec = MlpSpec(dims=tuple(cfg["dims"]), act=cfg["act"],
                   time_dep=cfg["time_dep"])
    entries = (make_entry_points(spec) if cfg["kind"] == "mlp"
               else make_cnf_entry_points(spec))
    examples = _example_args(cfg, spec)
    arts, shapes = {}, {}
    for suffix, fn in entries.items():
        args = examples[suffix]
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.{suffix}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        arts[suffix] = fname
        shapes[suffix] = [list(a.shape) for a in args]
        print(f"  {fname}: {len(text)} chars, args {shapes[suffix]}")
    return {
        "kind": cfg["kind"],
        "dims": list(cfg["dims"]),
        "act": cfg["act"],
        "time_dep": cfg["time_dep"],
        "batch": cfg["batch"],
        "state_dim": spec.state_dim,
        "param_count": param_count(spec.dims),
        "artifacts": arts,
        "arg_shapes": shapes,
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=None,
                    help="comma-separated subset (default: all)")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for k, v in CONFIGS.items():
            print(f"{k}: {v}")
        return 0

    names = list(CONFIGS) if args.configs is None else args.configs.split(",")
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "configs": {}}
    for name in names:
        if name not in CONFIGS:
            print(f"unknown config {name!r}", file=sys.stderr)
            return 1
        print(f"[aot] lowering {name} ...")
        manifest["configs"][name] = lower_config(name, CONFIGS[name], args.out_dir)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote manifest with {len(manifest['configs'])} configs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
