"""L2 — the paper's compute graph in JAX, calling the L1 Pallas kernels.

The high-level-AD primitive of PNODE is the neural-ODE right-hand side

    f(u, theta, t)  with  u in R^{BxD},  theta in R^{P} (flat),  t in R^{1}

together with the derivative actions the discrete adjoint and the implicit
solvers need:

  * ``f``            — forward evaluation (one NFE),
  * ``vjp_u``        — v^T df/du           (transposed Jacobian-vector product),
  * ``vjp_both``     — (v^T df/du, v^T df/dtheta) fused in one executable so
                       the forward pass inside the VJP is shared,
  * ``jvp``          — (df/du) w           (matrix action for Newton-GMRES).

Everything is lowered once by ``aot.py`` into HLO text artifacts; the Rust
coordinator (L3) loads them through PJRT and owns the time loop, the adjoint
sweep, checkpointing, and the optimizer.  Python never runs at train time.

AD note: this jax version cannot reverse-differentiate *through* a
``pallas_call``, so the VJP/JVP of the MLP are hand-rolled at the layer level
(manual backprop), with the Pallas GEMM kernel used for every matmul in both
the forward and the backward graph.  This mirrors the paper's own layering:
the high-level adjoint composes manually-derived local derivatives.  The
pure-jnp reference path (``use_pallas=False``) uses jax.vjp/jax.jvp and is
the oracle the manual derivatives are tested against.  CNF augmented
dynamics need second-order AD (gradient of a Hutchinson JVP), so CNF configs
lower through the reference path (documented in DESIGN.md §2).

Parameter layout (MUST match rust/src/nn/init.rs): for each layer l with
weight W_l in R^{din x dout} (row-major) followed by bias b_l in R^{dout},
concatenated over layers into a single flat f32 vector.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import dense as dense_kernel
from .kernels import ref as kernel_ref


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MlpSpec:
    """Architecture of the RHS MLP.

    dims: layer widths [d_in, h1, ..., d_out]. If ``time_dep`` the network
    input is concat([u, t]) so d_in == D + 1, else d_in == D.
    """

    dims: Tuple[int, ...]
    act: str = "tanh"
    out_act: str = "identity"
    time_dep: bool = True
    use_pallas: bool = True

    @property
    def state_dim(self) -> int:
        return self.dims[-1]

    @property
    def in_dim(self) -> int:
        return self.dims[0]


def param_count(dims: Sequence[int]) -> int:
    return sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))


def unflatten_params(theta, dims: Sequence[int]):
    """Slice the flat parameter vector into [(W, b), ...] per the layout."""
    params = []
    off = 0
    for i in range(len(dims) - 1):
        din, dout = dims[i], dims[i + 1]
        w = theta[off:off + din * dout].reshape(din, dout)
        off += din * dout
        b = theta[off:off + dout]
        off += dout
        params.append((w, b))
    return params


def flatten_params(params) -> jnp.ndarray:
    return jnp.concatenate([jnp.concatenate([w.reshape(-1), b]) for w, b in params])


def init_params(key, dims: Sequence[int], scale: float = 1.0) -> jnp.ndarray:
    """Kaiming-uniform init, mirrored by rust/src/nn/init.rs for cross-checks."""
    parts = []
    for i in range(len(dims) - 1):
        din, dout = dims[i], dims[i + 1]
        key, k1, k2 = jax.random.split(key, 3)
        bound = scale * (1.0 / din) ** 0.5
        parts.append(jax.random.uniform(k1, (din * dout,), minval=-bound, maxval=bound))
        parts.append(jax.random.uniform(k2, (dout,), minval=-bound, maxval=bound))
    return jnp.concatenate(parts).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Activations and their derivatives (from the pre-activation)
# ---------------------------------------------------------------------------

def act_apply(x, act: str):
    return kernel_ref.apply_act_ref(x, act)


def act_grad(pre, act: str):
    """d act / d pre, evaluated elementwise at the pre-activation."""
    if act == "identity":
        return jnp.ones_like(pre)
    if act == "relu":
        return (pre > 0).astype(pre.dtype)
    if act == "tanh":
        y = jnp.tanh(pre)
        return 1.0 - y * y
    if act == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi).astype(pre.dtype)
        inner = c * (pre + 0.044715 * pre ** 3)
        th = jnp.tanh(inner)
        sech2 = 1.0 - th * th
        dinner = c * (1.0 + 3.0 * 0.044715 * pre * pre)
        return 0.5 * (1.0 + th) + 0.5 * pre * sech2 * dinner
    if act == "sigmoid":
        y = jax.nn.sigmoid(pre)
        return y * (1.0 - y)
    raise ValueError(f"unknown activation {act!r}")


# ---------------------------------------------------------------------------
# GEMM dispatch: Pallas kernel on the production path, jnp on the ref path
# ---------------------------------------------------------------------------

def _matmul(a, b, use_pallas: bool):
    """a @ b through the Pallas kernel (identity epilogue, zero bias)."""
    if use_pallas:
        zero_bias = jnp.zeros((b.shape[1],), dtype=a.dtype)
        return dense_kernel.dense(a, b, zero_bias, act="identity")
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def _dense_fused(x, w, b, act: str, use_pallas: bool):
    if use_pallas:
        return dense_kernel.dense(x, w, b, act=act)
    return kernel_ref.dense_ref(x, w, b, act=act)


# ---------------------------------------------------------------------------
# MLP forward / manual VJP / manual JVP
# ---------------------------------------------------------------------------

def _layer_acts(spec: MlpSpec):
    n = len(spec.dims) - 1
    return [spec.act if i < n - 1 else spec.out_act for i in range(n)]


def mlp_apply(spec: MlpSpec, theta, x):
    """Apply the MLP to ``x`` [B, d_in]; fused dense kernels, no caches."""
    params = unflatten_params(theta, spec.dims)
    h = x
    for (w, b), a in zip(params, _layer_acts(spec)):
        h = _dense_fused(h, w, b, a, spec.use_pallas)
    return h


def _mlp_forward_cached(spec: MlpSpec, theta, x):
    """Forward keeping per-layer inputs and pre-activations (for manual AD).

    Pre-activations come from the Pallas GEMM; the activation is applied
    outside the kernel here (XLA fuses it), because the backward needs
    ``pre`` explicitly.
    """
    params = unflatten_params(theta, spec.dims)
    h = x
    xs, pres = [], []
    for (w, b), a in zip(params, _layer_acts(spec)):
        xs.append(h)
        pre = _matmul(h, w, spec.use_pallas) + b
        pres.append(pre)
        h = act_apply(pre, a)
    return h, xs, pres


def mlp_vjp(spec: MlpSpec, theta, x, v, *, wrt_theta: bool = True):
    """Manual reverse pass: returns (gx, gtheta_flat or None).

    Standard layer-level backprop:
        gpre = g * act'(pre);  gx = gpre @ W^T;  gW = x^T @ gpre;
        gb = sum_rows(gpre)
    with every matmul dispatched to the Pallas kernel.
    """
    params = unflatten_params(theta, spec.dims)
    _, xs, pres = _mlp_forward_cached(spec, theta, x)
    acts = _layer_acts(spec)
    g = v
    gparams = [None] * len(params)
    for i in range(len(params) - 1, -1, -1):
        w, _ = params[i]
        gpre = g * act_grad(pres[i], acts[i])
        if wrt_theta:
            gw = _matmul(xs[i].T, gpre, spec.use_pallas)
            gb = jnp.sum(gpre, axis=0)
            gparams[i] = (gw, gb)
        g = _matmul(gpre, w.T, spec.use_pallas)
    gtheta = flatten_params(gparams) if wrt_theta else None
    return g, gtheta


def mlp_jvp(spec: MlpSpec, theta, x, dx):
    """Manual forward-mode tangent wrt the input only: returns dy."""
    params = unflatten_params(theta, spec.dims)
    _, xs, pres = _mlp_forward_cached(spec, theta, x)
    acts = _layer_acts(spec)
    d = dx
    for i, (w, _) in enumerate(params):
        dpre = _matmul(d, w, spec.use_pallas)
        d = dpre * act_grad(pres[i], acts[i])
    return d


# ---------------------------------------------------------------------------
# RHS f(u, theta, t) and its derivative actions
# ---------------------------------------------------------------------------

def _augment_time(spec: MlpSpec, u, t):
    if spec.time_dep:
        tcol = jnp.broadcast_to(t.reshape(1, 1), (u.shape[0], 1)).astype(u.dtype)
        return jnp.concatenate([u, tcol], axis=1)
    return u


def f_rhs(spec: MlpSpec, u, theta, t):
    """The neural-ODE RHS: u [B, D], theta [P], t [1] -> du/dt [B, D]."""
    return mlp_apply(spec, theta, _augment_time(spec, u, t))


def f_vjp_u(spec: MlpSpec, u, theta, t, v):
    """v^T df/du — the core primitive of the discrete adjoint (and GMRES^T)."""
    if spec.use_pallas:
        gx, _ = mlp_vjp(spec, theta, _augment_time(spec, u, t), v,
                        wrt_theta=False)
        return gx[:, :spec.state_dim] if spec.time_dep else gx
    _, pull = jax.vjp(lambda uu: f_rhs(spec, uu, theta, t), u)
    return pull(v)[0]


def f_vjp_both(spec: MlpSpec, u, theta, t, v):
    """(v^T df/du, v^T df/dtheta) with one shared forward."""
    if spec.use_pallas:
        gx, gth = mlp_vjp(spec, theta, _augment_time(spec, u, t), v,
                          wrt_theta=True)
        gu = gx[:, :spec.state_dim] if spec.time_dep else gx
        return gu, gth
    _, pull = jax.vjp(lambda uu, th: f_rhs(spec, uu, th, t), u, theta)
    return pull(v)


def f_jvp(spec: MlpSpec, u, theta, t, w):
    """(df/du) w — matrix-free Newton/GMRES action for implicit steps."""
    if spec.use_pallas:
        if spec.time_dep:
            zcol = jnp.zeros((u.shape[0], 1), dtype=u.dtype)
            dx = jnp.concatenate([w, zcol], axis=1)
        else:
            dx = w
        return mlp_jvp(spec, theta, _augment_time(spec, u, t), dx)
    _, tangent = jax.jvp(lambda uu: f_rhs(spec, uu, theta, t), (u,), (w,))
    return tangent


# ---------------------------------------------------------------------------
# CNF (FFJORD) augmented dynamics — reference path (needs 2nd-order AD)
# ---------------------------------------------------------------------------
#
# d/dt [x, logp] = [f(x, theta, t), -tr(df/dx)]
# with the trace estimated by Hutchinson:  tr(J) ~= eps^T J eps,
# eps a fixed Rademacher sample per iteration (drawn by the Rust side).

def _ref_spec(spec: MlpSpec) -> MlpSpec:
    return MlpSpec(spec.dims, spec.act, spec.out_act, spec.time_dep,
                   use_pallas=False)


def f_aug(spec: MlpSpec, x, theta, t, eps):
    """Augmented CNF dynamics.  Returns (dx [B, D], dlogp [B, 1])."""
    rspec = _ref_spec(spec)

    def fx(xx):
        return f_rhs(rspec, xx, theta, t)

    dx, jvp_eps = jax.jvp(fx, (x,), (eps,))
    # eps^T J eps summed over feature dim -> per-sample trace estimate.
    tr = jnp.sum(eps * jvp_eps, axis=1, keepdims=True)
    return dx, -tr


def f_aug_vjp(spec: MlpSpec, x, theta, t, eps, vx, vlogp):
    """VJP of the augmented dynamics wrt (x, theta), fused.

    vx [B, D], vlogp [B, 1] are the cotangents of (dx, dlogp).
    Returns (gx [B, D], gtheta [P]).
    """
    _, pull = jax.vjp(lambda xx, th: f_aug(spec, xx, th, t, eps), x, theta)
    gx, gth = pull((vx, vlogp))
    return gx, gth


# ---------------------------------------------------------------------------
# Entry points lowered by aot.py (one jitted callable per artifact)
# ---------------------------------------------------------------------------

def make_entry_points(spec: MlpSpec):
    """Return {artifact_suffix: callable} for one MLP config.

    All callables return tuples (lowered with return_tuple=True) so the Rust
    side can uniformly unwrap tuple outputs.
    """
    return {
        "f": lambda u, th, t: (f_rhs(spec, u, th, t),),
        "vjp_u": lambda u, th, t, v: (f_vjp_u(spec, u, th, t, v),),
        "vjp_both": lambda u, th, t, v: f_vjp_both(spec, u, th, t, v),
        "jvp": lambda u, th, t, w: (f_jvp(spec, u, th, t, w),),
    }


def make_cnf_entry_points(spec: MlpSpec):
    return {
        "faug": lambda x, th, t, e: f_aug(spec, x, th, t, e),
        "vjp_aug": lambda x, th, t, e, vx, vl: f_aug_vjp(spec, x, th, t, e, vx, vl),
    }
