"""L1 — fused dense layer as a Pallas kernel.

The hot spot of a neural ODE is the RHS MLP evaluated N_t * N_s times per
forward pass (and its VJP in every reverse step).  On the paper's V100 this
is cuBLAS GEMM + separate bias/activation kernels; here we re-think it for a
TPU-style memory hierarchy:

  * the GEMM is tiled into (bm, bn, bk) blocks sized for the MXU systolic
    array (128x128 native tile, capped to the actual problem shape),
  * partial products accumulate in an f32 VMEM scratch accumulator,
  * bias add + activation are fused into the epilogue of the last k-step so
    the pre-activation never round-trips to HBM,
  * BlockSpec index maps express the HBM->VMEM schedule that CUDA code
    expresses with threadblock tiling.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO ops.  On a real TPU the same
kernel compiles with interpret=False; DESIGN.md §8 estimates the VMEM
footprint and MXU utilisation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# Activation epilogues fused into the kernel. Keep in sync with ref.py and
# the Rust-side `nn/activations.rs`.
ACTIVATIONS = ("identity", "relu", "tanh", "gelu", "sigmoid")


def _apply_act(x, act: str):
    if act == "identity":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "tanh":
        return jnp.tanh(x)
    if act == "gelu":
        # tanh-approximation GELU (matches Rust impl and the paper's usage
        # of GELU for the stiff task).
        c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
    if act == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation {act!r}")


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, act: str, nk: int):
    """One (bm, bn) output tile; grid axis 2 walks the k blocks."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU-shaped partial product, accumulated in f32 scratch.
    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k_step == nk - 1)
    def _epilogue():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_act(y, act).astype(o_ref.dtype)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pick_block(dim: int, target: int) -> int:
    """Largest block <= target for the M/N axes (partial blocks are cropped)."""
    return min(dim, target)


def _pick_block_k(dim: int, target: int) -> int:
    """K-axis block: MUST divide the dimension.

    The k axis is a reduction: a partial trailing block would fold padded
    (undefined) values into the accumulator, so we take the largest divisor
    of ``dim`` not exceeding ``target``.  If the best divisor is tiny (prime
    widths), fall back to the whole axis — a single resident slab is still
    well within VMEM for the MLP widths used here (<= 512).
    """
    if dim <= target:
        return dim
    best = 1
    for cand in range(1, target + 1):
        if dim % cand == 0:
            best = cand
    return best if best >= 16 else dim


@functools.partial(
    jax.jit, static_argnames=("act", "bm", "bn", "bk", "interpret")
)
def dense(x, w, b, *, act: str = "identity", bm: int = 128, bn: int = 128,
          bk: int = 128, interpret: bool = True):
    """Fused ``act(x @ w + b)`` as a tiled Pallas kernel.

    Args:
      x: ``[M, K]`` input activations.
      w: ``[K, N]`` weights.
      b: ``[N]`` bias.
      act: epilogue activation name (see ``ACTIVATIONS``).
      bm/bn/bk: tile sizes (capped to the problem shape).
      interpret: must stay True for CPU PJRT execution.

    Returns:
      ``[M, N]`` output, same dtype as ``x``.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    bm_ = _pick_block(m, bm)
    bn_ = _pick_block(n, bn)
    bk_ = _pick_block_k(k, bk)
    nk = _ceil_div(k, bk_)

    grid = (_ceil_div(m, bm_), _ceil_div(n, bn_), nk)

    return pl.pallas_call(
        functools.partial(_dense_kernel, act=act, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk_, bn_), lambda i, j, s: (s, j)),
            pl.BlockSpec((bn_,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        # f32 accumulator tile held in VMEM across the k loop.
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(x, w, b)


def vmem_footprint_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency of one grid step (DESIGN.md §8)."""
    x_tile = bm * bk * dtype_bytes
    w_tile = bk * bn * dtype_bytes
    o_tile = bm * bn * dtype_bytes
    acc = bm * bn * 4  # f32 accumulator
    bias = bn * dtype_bytes
    return x_tile + w_tile + o_tile + acc + bias
