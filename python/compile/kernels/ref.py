"""Pure-jnp correctness oracle for the Pallas kernels.

Everything here is deliberately the most boring possible jnp code; the
pytest suite (``python/tests/test_kernel.py``) sweeps shapes/dtypes with
hypothesis and asserts allclose between ``kernels.dense.dense`` and
``dense_ref``, and between the full Pallas-backed MLP and ``mlp_ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_act_ref(x, act: str):
    if act == "identity":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "tanh":
        return jnp.tanh(x)
    if act == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
    if act == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation {act!r}")


def dense_ref(x, w, b, *, act: str = "identity"):
    """Reference ``act(x @ w + b)``."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    return apply_act_ref(y, act).astype(x.dtype)


def mlp_ref(params, x, *, act: str, out_act: str = "identity"):
    """Reference MLP given a list of (w, b) pairs."""
    h = x
    for i, (w, b) in enumerate(params):
        a = act if i < len(params) - 1 else out_act
        h = dense_ref(h, w, b, act=a)
    return h
