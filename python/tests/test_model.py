"""L2 correctness: RHS, VJP/JVP primitives, CNF augmented dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (
    MlpSpec,
    f_aug,
    f_aug_vjp,
    f_jvp,
    f_rhs,
    f_vjp_both,
    f_vjp_u,
    flatten_params,
    init_params,
    param_count,
    unflatten_params,
)

jax.config.update("jax_platform_name", "cpu")

SPEC = MlpSpec(dims=(5, 8, 4), act="tanh", time_dep=True)
SPEC_AUTON = MlpSpec(dims=(3, 10, 3), act="gelu", time_dep=False)


def _ref(spec):
    """Pure-jnp twin of a spec — the jax-AD oracle path."""
    return MlpSpec(spec.dims, spec.act, spec.out_act, spec.time_dep,
                   use_pallas=False)


def _setup(spec, b=3, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    theta = init_params(k1, spec.dims)
    u = jax.random.normal(k2, (b, spec.state_dim), dtype=jnp.float32)
    t = jnp.array([0.3], dtype=jnp.float32)
    return u, theta, t


def test_param_count_and_roundtrip():
    dims = (65, 168, 168, 64)
    assert param_count(dims) == 50296  # paper budget: 4 blocks = 201,184
    key = jax.random.PRNGKey(0)
    theta = init_params(key, dims)
    assert theta.shape == (50296,)
    back = flatten_params(unflatten_params(theta, dims))
    np.testing.assert_array_equal(theta, back)


@pytest.mark.parametrize("spec,b", [(SPEC, 3), (SPEC_AUTON, 1)])
def test_rhs_shapes(spec, b):
    u, theta, t = _setup(spec, b)
    out = f_rhs(spec, u, theta, t)
    assert out.shape == (b, spec.state_dim)
    assert out.dtype == jnp.float32


def test_pallas_and_ref_paths_agree():
    spec_p = MlpSpec(dims=(5, 8, 4), act="tanh", time_dep=True, use_pallas=True)
    spec_r = MlpSpec(dims=(5, 8, 4), act="tanh", time_dep=True, use_pallas=False)
    u, theta, t = _setup(spec_p)
    np.testing.assert_allclose(
        f_rhs(spec_p, u, theta, t), f_rhs(spec_r, u, theta, t),
        rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_vjp_u_matches_grad(seed):
    u, theta, t = _setup(SPEC, seed=seed % 7)
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, u.shape, dtype=jnp.float32)
    got = f_vjp_u(SPEC, u, theta, t, v)  # manual backprop + Pallas GEMMs
    want = jax.grad(lambda uu: jnp.vdot(f_rhs(_ref(SPEC), uu, theta, t), v))(u)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_vjp_both_matches_separate(seed):
    u, theta, t = _setup(SPEC, seed=seed % 5)
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, u.shape, dtype=jnp.float32)
    gu, gth = f_vjp_both(SPEC, u, theta, t, v)
    want_u = f_vjp_u(SPEC, u, theta, t, v)
    want_th = jax.grad(lambda th: jnp.vdot(f_rhs(_ref(SPEC), u, th, t), v))(theta)
    np.testing.assert_allclose(gu, want_u, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gth, want_th, rtol=1e-5, atol=1e-6)


def test_jvp_matches_jax_jvp_and_fd():
    u, theta, t = _setup(SPEC)
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, u.shape, dtype=jnp.float32)
    got = f_jvp(SPEC, u, theta, t, w)  # manual tangent + Pallas GEMMs
    _, want = jax.jvp(lambda uu: f_rhs(_ref(SPEC), uu, theta, t), (u,), (w,))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    h = 1e-3
    fd = (f_rhs(SPEC, u + h * w, theta, t) - f_rhs(SPEC, u - h * w, theta, t)) / (2 * h)
    np.testing.assert_allclose(got, fd, rtol=1e-2, atol=1e-3)


def test_jvp_vjp_duality():
    """<v, J w> == <J^T v, w> to machine precision."""
    u, theta, t = _setup(SPEC)
    key = jax.random.PRNGKey(4)
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, u.shape, dtype=jnp.float32)
    v = jax.random.normal(k2, u.shape, dtype=jnp.float32)
    lhs = jnp.vdot(v, f_jvp(SPEC, u, theta, t, w))
    rhs = jnp.vdot(f_vjp_u(SPEC, u, theta, t, v), w)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5)


# ---------------------------------------------------------------------------
# CNF augmented dynamics
# ---------------------------------------------------------------------------

CNF_SPEC = MlpSpec(dims=(5, 16, 4), act="tanh", time_dep=True)


def test_aug_dx_equals_plain_rhs():
    u, theta, t = _setup(CNF_SPEC, b=4)
    key = jax.random.PRNGKey(5)
    eps = jnp.sign(jax.random.normal(key, u.shape)).astype(jnp.float32)
    dx, _ = f_aug(CNF_SPEC, u, theta, t, eps)
    np.testing.assert_allclose(dx, f_rhs(CNF_SPEC, u, theta, t),
                               rtol=1e-5, atol=1e-6)


def test_hutchinson_unbiased_for_exact_trace():
    """E_eps[eps^T J eps] == tr(J); with D=4 average over many Rademacher
    draws converges; also check the exact identity for a full +/-1 basis."""
    u, theta, t = _setup(CNF_SPEC, b=2, seed=9)
    jac = jax.jacfwd(lambda uu: f_rhs(_ref(CNF_SPEC), uu, theta, t))(u)
    # jac: [B, D, B, D]; per-sample trace of the diagonal block.
    d = u.shape[1]
    exact = jnp.stack([jnp.trace(jac[i, :, i, :]) for i in range(u.shape[0])])

    key = jax.random.PRNGKey(10)
    n_draws = 4096
    eps = jnp.sign(jax.random.normal(key, (n_draws,) + u.shape)).astype(jnp.float32)

    @jax.jit
    def estimate(all_eps):
        def one(e):
            _, dlp = f_aug(CNF_SPEC, u, theta, t, e)
            return -dlp[:, 0]
        return jnp.mean(jax.vmap(one)(all_eps), axis=0)

    est = estimate(eps)
    np.testing.assert_allclose(est, exact, rtol=0.15, atol=0.05)


def test_aug_vjp_matches_grad():
    u, theta, t = _setup(CNF_SPEC, b=3, seed=11)
    key = jax.random.PRNGKey(12)
    k1, k2, k3 = jax.random.split(key, 3)
    eps = jnp.sign(jax.random.normal(k1, u.shape)).astype(jnp.float32)
    vx = jax.random.normal(k2, u.shape, dtype=jnp.float32)
    vl = jax.random.normal(k3, (u.shape[0], 1), dtype=jnp.float32)

    gx, gth = f_aug_vjp(CNF_SPEC, u, theta, t, eps, vx, vl)

    def scalar(uu, th):
        dx, dlp = f_aug(CNF_SPEC, uu, th, t, eps)
        return jnp.vdot(dx, vx) + jnp.vdot(dlp, vl)

    want_x = jax.grad(scalar, argnums=0)(u, theta)
    want_th = jax.grad(scalar, argnums=1)(u, theta)
    np.testing.assert_allclose(gx, want_x, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gth, want_th, rtol=1e-4, atol=1e-5)
