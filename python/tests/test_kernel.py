"""L1 correctness: Pallas dense kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/activations; assert_allclose against ref.py.
This is the core correctness signal for the kernel that every artifact's HLO
embeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dense import ACTIVATIONS, dense, vmem_footprint_bytes
from compile.kernels.ref import dense_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, dtype=jnp.float32)
    return x.astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    act=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref_f32(m, k, n, act, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = _rand(k1, (m, k), jnp.float32)
    w = _rand(k2, (k, n), jnp.float32)
    b = _rand(k3, (n,), jnp.float32)
    got = dense(x, w, b, act=act)
    want = dense_ref(x, w, b, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 32, 128]),
    k=st.sampled_from([8, 64, 256]),
    n=st.sampled_from([8, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref_bf16(m, k, n, seed):
    """bf16 inputs with f32 accumulation — the MXU-native path."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = _rand(k1, (m, k), jnp.bfloat16)
    w = _rand(k2, (k, n), jnp.bfloat16)
    b = _rand(k3, (n,), jnp.bfloat16)
    got = dense(x, w, b, act="tanh").astype(jnp.float32)
    want = dense_ref(x, w, b, act="tanh").astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("shape", [(1, 1, 1), (2, 3, 5), (128, 168, 64),
                                   (256, 65, 168), (7, 129, 33)])
def test_dense_odd_shapes(shape):
    """Non-divisible shapes exercise the partial-block path."""
    m, k, n = shape
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    x = _rand(k1, (m, k), jnp.float32)
    w = _rand(k2, (k, n), jnp.float32)
    b = _rand(k3, (n,), jnp.float32)
    np.testing.assert_allclose(
        dense(x, w, b, act="gelu"), dense_ref(x, w, b, act="gelu"),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 32, 64), (128, 128, 128)])
def test_dense_block_size_invariance(blocks):
    """Result must not depend on the tiling."""
    bm, bn, bk = blocks
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    x = _rand(k1, (64, 64), jnp.float32)
    w = _rand(k2, (64, 64), jnp.float32)
    b = _rand(k3, (64,), jnp.float32)
    got = dense(x, w, b, act="relu", bm=bm, bn=bn, bk=bk)
    want = dense_ref(x, w, b, act="relu")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dense_used_as_plain_matmul():
    """Zero bias + identity epilogue turns the kernel into the GEMM used by
    the manual backward pass (model.mlp_vjp)."""
    key = jax.random.PRNGKey(2)
    k1, k2 = jax.random.split(key)
    a = _rand(k1, (13, 21), jnp.float32)
    c = _rand(k2, (21, 34), jnp.float32)
    z = jnp.zeros((34,), dtype=jnp.float32)
    np.testing.assert_allclose(dense(a, c, z, act="identity"), a @ c,
                               rtol=1e-5, atol=1e-5)


def test_vmem_footprint_within_tpu_budget():
    """The default tile must fit comfortably in a 16 MiB VMEM."""
    assert vmem_footprint_bytes(128, 128, 128) < 16 * 1024 * 1024 // 4
