"""AOT pipeline smoke tests: lowering, manifest integrity, HLO text format."""

import json
import os

import pytest

from compile.aot import CONFIGS, lower_config
from compile.model import param_count


def test_all_configs_well_formed():
    for name, cfg in CONFIGS.items():
        dims = cfg["dims"]
        d = dims[-1]
        expected_in = d + 1 if cfg["time_dep"] else d
        assert dims[0] == expected_in, f"{name}: in dim {dims[0]} != {expected_in}"
        assert cfg["kind"] in ("mlp", "cnf")
        assert cfg["batch"] >= 1


def test_classification_parameter_budget_matches_paper():
    """Paper: 4 ODE blocks, 199,800 trainable params total. Ours: 201,184."""
    per_block = param_count(CONFIGS["clf_d64"]["dims"])
    total = 4 * per_block
    assert abs(total - 199_800) / 199_800 < 0.02


def test_lower_quick_config(tmp_path):
    entry = lower_config("quick_d8", CONFIGS["quick_d8"], str(tmp_path))
    # all four primitives emitted
    assert set(entry["artifacts"]) == {"f", "vjp_u", "vjp_both", "jvp"}
    for suffix, fname in entry["artifacts"].items():
        path = tmp_path / fname
        assert path.exists()
        text = path.read_text()
        assert text.startswith("HloModule"), f"{suffix} not HLO text"
        # 64-bit-id proto pitfall: text must be parseable => ids reassigned
        assert "ENTRY" in text
    assert entry["param_count"] == param_count((9, 16, 8))
    assert entry["arg_shapes"]["f"] == [[4, 8], [entry["param_count"]], [1]]


def test_lower_cnf_config(tmp_path):
    cfg = dict(CONFIGS["cnf_power"])
    cfg["batch"] = 8  # shrink for test speed
    entry = lower_config("cnf_tiny", cfg, str(tmp_path))
    assert set(entry["artifacts"]) == {"faug", "vjp_aug"}
    shapes = entry["arg_shapes"]["vjp_aug"]
    assert shapes == [[8, 6], [entry["param_count"]], [1], [8, 6], [8, 6], [8, 1]]


def test_manifest_written(tmp_path):
    import subprocess, sys
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--configs", "quick_d8"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert "quick_d8" in manifest["configs"]
