"""Make `python/` importable (`compile.*` namespace packages) when pytest
runs from the repo root (`python -m pytest python/tests -q`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
