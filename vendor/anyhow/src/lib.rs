//! Offline stand-in for the `anyhow` crate (the registry is unavailable in
//! this build environment).  Implements the subset the workspace uses:
//! [`Error`], [`Result`], [`anyhow!`], [`bail!`], [`ensure!`], and the
//! [`Context`] extension trait for `Result` and `Option`.
//!
//! The error is a single formatted message plus an optional chain of
//! context strings (most recent first), matching how `anyhow` renders
//! `{e:#}`.

use std::fmt;

/// A type-erased error: a message with layered context.
pub struct Error {
    /// context frames, outermost (most recently attached) first
    frames: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { frames: vec![message.to_string()] }
    }

    /// Attach an outer context frame (what `Context::context` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The outermost message (what `Display` shows).
    pub fn root_message(&self) -> &str {
        self.frames.first().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain like anyhow: "outer: inner"
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow's Debug prints the message then the cause chain
        write!(f, "{}", self.frames.join("\n\nCaused by:\n    "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

/// `.context(...)` / `.with_context(...)` on `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error { frames: vec![context.to_string(), e.to_string()] })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error { frames: vec![f().to_string(), e.to_string()] })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn macros_work() {
        fn g(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            ensure!(x != 3);
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(g(5).unwrap(), 5);
        assert!(g(-1).unwrap_err().to_string().contains("positive"));
        assert!(g(3).unwrap_err().to_string().contains("condition failed"));
        assert!(g(101).unwrap_err().to_string().contains("too big"));
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(v.context("empty").unwrap_err().to_string(), "empty");
        assert_eq!(Some(4u8).with_context(|| "x").unwrap(), 4);
    }
}
