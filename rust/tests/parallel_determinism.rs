//! End-to-end determinism contract of the data-parallel execution
//! engine: gradients are bitwise identical across `workers ∈ {1, 2, 4}`
//! for ERK and θ-schemes, under `All` and `Binomial` placements, on
//! static and adaptive grids (the adaptive grid is generated once and
//! shared by all shards), and with the shard fleet's tiered stores
//! leasing from ONE global hot-tier budget (spilling, never OOM-ing).

use pnode::adjoint::driver::ThetaDriver;
use pnode::checkpoint::CheckpointPolicy;
use pnode::exec::{pool, reduce, shard_ranges, ExecConfig};
use pnode::methods::{BlockSpec, GradientMethod, MethodReport, ParallelAdjoint};
use pnode::nn::Act;
use pnode::ode::grid::TimeGrid;
use pnode::ode::implicit::ThetaScheme;
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::tableau::Scheme;
use pnode::util::rng::Rng;

const B: usize = 24;
const D: usize = 6;
const SHARD_ROWS: usize = 8;

fn mk_rhs(seed: u64) -> ModuleRhs {
    let dims = vec![D + 1, 16, D];
    let mut rng = Rng::new(seed);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    ModuleRhs::mlp(dims, Act::Tanh, true, B, theta)
}

fn vecs(seed: u64, n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut u0 = vec![0.0f32; n];
    rng.fill_normal(&mut u0);
    for x in u0.iter_mut() {
        *x *= 0.4;
    }
    let mut w = vec![0.0f32; n];
    rng.fill_normal(&mut w);
    (u0, w)
}

fn erk_grad(
    policy: CheckpointPolicy,
    grid: TimeGrid,
    workers: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, MethodReport) {
    let rhs = mk_rhs(7);
    let (u0, w) = vecs(8, rhs.state_len());
    let spec = BlockSpec { scheme: Scheme::Dopri5, t0: 0.0, tf: 1.0, grid };
    let mut m = ParallelAdjoint::pnode(policy, ExecConfig { workers, shard_rows: SHARD_ROWS });
    let uf = m.forward(&rhs, &spec, &u0);
    let mut lam = w;
    let mut g = vec![0.0f32; rhs.param_len()];
    m.backward(&rhs, &spec, &mut lam, &mut g);
    (uf, lam, g, m.report())
}

#[test]
fn erk_gradients_bitwise_identical_across_worker_counts() {
    for policy in [CheckpointPolicy::All, CheckpointPolicy::Binomial { n_checkpoints: 3 }] {
        let (uf1, l1, g1, r1) = erk_grad(policy.clone(), TimeGrid::Uniform { nt: 12 }, 1);
        assert_eq!(r1.exec.shards, 3, "{}: 24 rows / 8 per shard", policy.name());
        for workers in [2usize, 4] {
            let (uf, l, g, r) =
                erk_grad(policy.clone(), TimeGrid::Uniform { nt: 12 }, workers);
            let tag = policy.name();
            assert_eq!(uf, uf1, "{tag}: u(t_F) bitwise, workers={workers}");
            assert_eq!(l, l1, "{tag}: λ bitwise, workers={workers}");
            assert_eq!(g, g1, "{tag}: θ̄ bitwise, workers={workers}");
            assert_eq!(r.exec.workers, workers.min(3) as u64, "reports the ran parallelism");
            assert_eq!(r.exec.shards, 3, "sharding is worker-count independent");
            assert_eq!(r.nfe_forward, r1.nfe_forward);
            assert_eq!(r.recompute_steps, r1.recompute_steps);
        }
    }
}

#[test]
fn time_conditioned_module_gradients_bitwise_across_worker_counts() {
    // the acceptance contract of the module refactor: a *time-conditioned*
    // architecture (FFJORD concatsquash — gates and shifts are functions
    // of t) shards exactly like the dense MLP, so gradients stay bitwise
    // identical for workers = 1, 2, N
    use pnode::api::ArchSpec;
    let arch = ArchSpec::ConcatSquashMlp { hidden: vec![12], act: Act::Tanh };
    let mut rng = Rng::new(51);
    let theta = arch.init(&mut rng, D);
    let rhs = ModuleRhs::from_arch(&arch, D, B, theta);
    let (u0, w) = vecs(52, rhs.state_len());

    let grad = |workers: usize| -> (Vec<f32>, Vec<f32>, Vec<f32>, MethodReport) {
        let spec = BlockSpec {
            scheme: Scheme::Dopri5,
            t0: 0.0,
            tf: 1.0,
            grid: TimeGrid::Uniform { nt: 10 },
        };
        let mut m = ParallelAdjoint::pnode(
            CheckpointPolicy::All,
            ExecConfig { workers, shard_rows: SHARD_ROWS },
        );
        let uf = m.forward(&rhs, &spec, &u0);
        let mut lam = w.clone();
        let mut g = vec![0.0f32; rhs.param_len()];
        m.backward(&rhs, &spec, &mut lam, &mut g);
        (uf, lam, g, m.report())
    };
    let (uf1, l1, g1, r1) = grad(1);
    assert_eq!(r1.exec.shards, 3);
    for workers in [2usize, 4] {
        let (uf, l, g, _r) = grad(workers);
        assert_eq!(uf, uf1, "concatsquash u(t_F) bitwise, workers={workers}");
        assert_eq!(l, l1, "concatsquash λ bitwise, workers={workers}");
        assert_eq!(g, g1, "concatsquash θ̄ bitwise, workers={workers}");
    }
}

#[test]
fn adaptive_grid_is_generated_once_and_shared_by_all_shards() {
    let grid = TimeGrid::Adaptive { atol: 1e-5, rtol: 1e-5, h0: Some(0.25) };
    for policy in [CheckpointPolicy::All, CheckpointPolicy::Binomial { n_checkpoints: 3 }] {
        let (uf1, l1, g1, r1) = erk_grad(policy.clone(), grid.clone(), 1);
        assert!(r1.n_accepted > 1, "controller must accept multiple steps: {r1:?}");
        for workers in [2usize, 4] {
            let (uf, l, g, r) = erk_grad(policy.clone(), grid.clone(), workers);
            let tag = policy.name();
            assert_eq!(uf, uf1, "{tag}: shared grid ⇒ bitwise u(t_F), workers={workers}");
            assert_eq!(l, l1, "{tag}: λ bitwise, workers={workers}");
            assert_eq!(g, g1, "{tag}: θ̄ bitwise, workers={workers}");
            assert_eq!(r.n_accepted, r1.n_accepted, "one accepted grid for the whole batch");
            assert_eq!(r.n_rejected, r1.n_rejected, "pre-pass rejections are grid-level");
        }
    }
}

#[test]
fn default_exec_config_matches_explicit_workers() {
    // PNODE_WORKERS (the CI matrix knob) only sets the DEFAULT worker
    // count; any value must reproduce the explicit workers=1 bits.  The
    // default shard_rows (16) differs from this file's helper (8), so the
    // reference run uses the same decomposition explicitly.
    let rhs = mk_rhs(7);
    let (u0, w) = vecs(8, rhs.state_len());
    let spec = BlockSpec {
        scheme: Scheme::Dopri5,
        t0: 0.0,
        tf: 1.0,
        grid: TimeGrid::Uniform { nt: 10 },
    };
    let run = |m: &mut ParallelAdjoint| {
        m.forward(&rhs, &spec, &u0);
        let mut lam = w.clone();
        let mut g = vec![0.0f32; rhs.param_len()];
        m.backward(&rhs, &spec, &mut lam, &mut g);
        (lam, g)
    };
    let mut md = ParallelAdjoint::pnode(CheckpointPolicy::All, ExecConfig::default());
    let mut m1 = ParallelAdjoint::pnode(
        CheckpointPolicy::All,
        ExecConfig { workers: 1, shard_rows: ExecConfig::default().shard_rows },
    );
    let (ld, gd) = run(&mut md);
    let (l1, g1) = run(&mut m1);
    assert_eq!(ld, l1, "default worker count reproduces workers=1 bitwise");
    assert_eq!(gd, g1);
}

#[test]
fn theta_scheme_shard_fleet_is_bitwise_across_worker_counts() {
    let rows = 12usize;
    let d = 4usize;
    let dims = vec![d, 12, d];
    let mut rng = Rng::new(31);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    let rhs = ModuleRhs::mlp(dims, Act::Gelu, false, rows, theta);
    let (u0, w) = vecs(32, rhs.state_len());
    let ts = vec![0.0, 0.1, 0.3, 0.6, 1.0];

    for policy in
        [CheckpointPolicy::SolutionOnly, CheckpointPolicy::Binomial { n_checkpoints: 2 }]
    {
        let shards = shard_ranges(rows, 4);
        assert_eq!(shards.len(), 3);
        let fleet = |workers: usize| -> (Vec<f32>, Vec<f32>) {
            let jobs: Vec<_> = shards
                .iter()
                .map(|r| {
                    let srhs = rhs.make_shard(r.len()).expect("ModuleRhs shards");
                    let su0 = u0[r.start * d..r.end * d].to_vec();
                    let sw = w[r.start * d..r.end * d].to_vec();
                    let ts = ts.clone();
                    let policy = policy.clone();
                    move || {
                        let mut run =
                            ThetaDriver::theta(ThetaScheme::crank_nicolson(), policy, &ts);
                        run.forward(srhs.as_ref(), &su0);
                        let mut lam = sw;
                        let mut g = vec![0.0f32; srhs.param_len()];
                        run.backward(srhs.as_ref(), &mut lam, &mut g);
                        (lam, g)
                    }
                })
                .collect();
            let done = pool::run_once_jobs(workers, jobs);
            let mut lam_full = Vec::new();
            let mut parts = Vec::new();
            for (lam, g) in done {
                lam_full.extend_from_slice(&lam);
                parts.push(g);
            }
            let mut g_full = vec![0.0f32; rhs.param_len()];
            reduce::tree_sum_into(&mut g_full, parts);
            (lam_full, g_full)
        };
        let (l1, g1) = fleet(1);
        for workers in [2usize, 4] {
            let (l, g) = fleet(workers);
            let tag = policy.name();
            assert_eq!(l, l1, "{tag}: θ-scheme λ bitwise, workers={workers}");
            assert_eq!(g, g1, "{tag}: θ-scheme θ̄ bitwise, workers={workers}");
        }
    }
}

#[test]
fn shard_fleet_shares_one_hot_tier_budget_and_spills_instead_of_oom() {
    let dir = std::env::temp_dir()
        .join(format!("pnode-par-fleet-{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let _ = std::fs::remove_dir_all(&dir);
    let budget: u64 = 8 << 10; // far below the fleet's ~48 KiB demand
    let tiered = CheckpointPolicy::Tiered {
        budget_bytes: budget,
        dir: dir.clone(),
        compress_f16: false,
        inner: Box::new(CheckpointPolicy::All),
    };
    let grid = TimeGrid::Uniform { nt: 16 };
    let (_, l_mem, g_mem, _) = erk_grad(CheckpointPolicy::All, grid.clone(), 4);

    let (_, l1, g1, r1) = erk_grad(tiered.clone(), grid.clone(), 1);
    for workers in [2usize, 4] {
        let (_, l, g, r) = erk_grad(tiered.clone(), grid.clone(), workers);
        assert_eq!(l, l1, "tiered fleet λ bitwise, workers={workers}");
        assert_eq!(g, g1, "tiered fleet θ̄ bitwise, workers={workers}");
        assert!(r.tier.spills > 0, "over-budget fleet must spill: {:?}", r.tier);
        assert_eq!(r.exec.lease_pool_bytes, budget);
        assert!(
            r.exec.peak_leased_bytes <= budget,
            "fleet hot tier stays inside the ONE global budget: {:?}",
            r.exec
        );
        assert_eq!(r.exec.over_grant_bytes, 0, "no mandatory-floor overdraw: {:?}", r.exec);
    }
    assert!(r1.tier.spills > 0);
    assert_eq!(l1, l_mem, "spilling changes placement, never values");
    assert_eq!(g1, g_mem);
    let _ = std::fs::remove_dir_all(&dir);
}
