//! Serving determinism contract (DESIGN.md §15): results scattered out of
//! the coalescing session pool must be bitwise identical to isolated
//! single-request runs — for every pool size, every coalescing width,
//! every gradient method's session, and whichever GEMM kernel path the
//! process runs (CI drives this file across the `PNODE_KERNEL` matrix).

use pnode::api::{RunSpec, Session, SolverBuilder};
use pnode::nn::Act;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::{ModuleRhs, Scheme, TimeGrid};
use pnode::serve::{ServeConfig, ServePool, Ticket};
use pnode::util::rng::Rng;

const D: usize = 6;
const K: usize = 10;

fn theta(seed: u64) -> Vec<f32> {
    // concat-time MLP over D state channels: input is [u, t]
    let dims = vec![D + 1, 12, D];
    let mut rng = Rng::new(seed);
    pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0)
}

fn rhs_at(rows: usize, seed: u64) -> ModuleRhs {
    ModuleRhs::mlp(vec![D + 1, 12, D], Act::Tanh, true, rows, theta(seed))
}

fn requests(seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..K)
        .map(|_| {
            let mut u0 = vec![0.0f32; D];
            rng.fill_normal(&mut u0);
            u0
        })
        .collect()
}

/// Serve all K requests through a pool of `sessions` workers coalescing
/// `max_batch` rows, and return the scattered results in request order.
fn serve_all(spec: &RunSpec, sessions: usize, max_batch: usize, seed: u64) -> Vec<Vec<f32>> {
    let cfg = ServeConfig { sessions, max_batch, ..Default::default() };
    let pool = ServePool::new(spec, D, cfg, move |rows| {
        Box::new(rhs_at(rows, seed)) as Box<dyn OdeRhs + Send>
    })
    .expect("serve pool");
    let tickets: Vec<Ticket> = requests(seed + 1)
        .into_iter()
        .map(|u0| pool.submit(u0).expect("submit"))
        .collect();
    let out = tickets.into_iter().map(Ticket::wait).collect();
    let report = pool.shutdown();
    assert_eq!(report.requests, K as u64);
    // each worker that dispatched >= 1 sweep allocates its workspace
    // exactly once; how many of the `sessions` workers got work is a
    // scheduling detail
    assert!(
        report.forward_allocs >= 1 && report.forward_allocs <= sessions as u64,
        "workspace allocations must stay within one-per-worker: {report:?}"
    );
    out
}

#[test]
fn coalesced_batches_match_isolated_forwards_across_pool_sizes() {
    let spec = SolverBuilder::new().scheme(Scheme::Rk4).uniform(5).build().unwrap();

    // ground truth: each request alone through the classic engine forward
    let seed = 1700;
    let rhs1 = rhs_at(1, seed);
    let mut isolated = Session::new(spec.clone()).unwrap();
    let reference: Vec<Vec<f32>> =
        requests(seed + 1).iter().map(|u0| isolated.forward(&rhs1, u0)).collect();

    for sessions in [1usize, 2, 4] {
        let served = serve_all(&spec, sessions, 4, seed);
        for (i, (got, want)) in served.iter().zip(&reference).enumerate() {
            assert_eq!(
                got, want,
                "request {i} through a {sessions}-session pool must be bitwise = isolated"
            );
        }
    }
}

#[test]
fn coalescing_width_never_changes_bits() {
    let spec = SolverBuilder::new().scheme(Scheme::Bosh3).uniform(7).build().unwrap();
    let seed = 1800;
    let narrow = serve_all(&spec, 2, 1, seed);
    let wide = serve_all(&spec, 2, 8, seed);
    assert_eq!(narrow, wide, "max_batch is a latency knob, never a bits knob");
}

#[test]
fn forward_into_matches_forward_across_methods_and_grids() {
    let seed = 1900;
    let rhs = rhs_at(3, seed);
    let mut rng = Rng::new(seed + 1);
    let mut u0 = vec![0.0f32; 3 * D];
    rng.fill_normal(&mut u0);

    for method in ["pnode", "pnode:binomial:2", "cont", "naive"] {
        for (scheme, grid) in [
            (Scheme::Rk4, TimeGrid::Uniform { nt: 6 }),
            (Scheme::Dopri5, TimeGrid::adaptive(1e-5)),
        ] {
            let spec = SolverBuilder::new()
                .method_str(method)
                .scheme(scheme)
                .grid(grid)
                .build()
                .unwrap_or_else(|e| panic!("{method}: {e}"));
            let mut s = Session::new(spec).unwrap();
            let want = s.forward(&rhs, &u0);
            let mut got = vec![0.0f32; u0.len()];
            s.forward_into(&rhs, &u0, &mut got);
            assert_eq!(
                want, got,
                "forward_into must be bitwise = forward ({method}, {})",
                scheme.name()
            );
        }
    }
}

#[test]
fn pool_rejects_nonstatic_grids() {
    let spec = SolverBuilder::new()
        .scheme(Scheme::Dopri5)
        .grid(TimeGrid::adaptive(1e-6))
        .build()
        .unwrap();
    let e = ServePool::new(&spec, D, ServeConfig::default(), |rows| {
        Box::new(rhs_at(rows, 1)) as Box<dyn OdeRhs + Send>
    })
    .unwrap_err();
    assert!(
        e.contains("static grid") && e.contains("bitwise"),
        "rejection must explain the determinism rationale: {e}"
    );
}

#[test]
fn steady_state_pool_serving_keeps_allocations_flat() {
    let spec = SolverBuilder::new().uniform(4).build().unwrap();
    let cfg = ServeConfig { sessions: 1, max_batch: K, ..Default::default() };
    let pool = ServePool::new(&spec, D, cfg, |rows| {
        Box::new(rhs_at(rows, 77)) as Box<dyn OdeRhs + Send>
    })
    .expect("serve pool");
    for _wave in 0..5 {
        let tickets: Vec<Ticket> = requests(78)
            .into_iter()
            .map(|u0| pool.submit(u0).expect("submit"))
            .collect();
        for t in tickets {
            let _ = t.wait();
        }
    }
    let report = pool.shutdown();
    assert_eq!(report.requests, 5 * K as u64);
    assert_eq!(report.forward_allocs, 1, "one warm-up allocation, then zero: {report:?}");
}
