//! The facade's serving-path contract: a long-lived `Session` reused
//! across gradients is (a) bitwise identical to fresh per-call sessions,
//! (b) allocation-stable (one workspace allocation for any N calls), and
//! (c) still budget-safe — a parallel tiered fleet's concurrent hot
//! footprint stays within the arbiter pool across reuse.

use pnode::api::{Session, SolverBuilder};
use pnode::exec::ExecConfig;
use pnode::nn::Act;
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::util::rng::Rng;

const B: usize = 24;
const D: usize = 6;

fn mk_rhs(seed: u64) -> ModuleRhs {
    let dims = vec![D + 1, 16, D];
    let mut rng = Rng::new(seed);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    ModuleRhs::mlp(dims, Act::Tanh, true, B, theta)
}

fn probe_vectors(seed: u64, n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut u0 = vec![0.0f32; n];
    rng.fill_normal(&mut u0);
    for x in u0.iter_mut() {
        *x *= 0.4;
    }
    let mut w = vec![0.0f32; n];
    rng.fill_normal(&mut w);
    (u0, w)
}

#[test]
fn reused_session_matches_fresh_sessions_bitwise() {
    let rhs = mk_rhs(21);
    let (u0, w) = probe_vectors(22, rhs.state_len());
    let spec = SolverBuilder::new()
        .method_str("pnode")
        .scheme_str("dopri5")
        .uniform(7)
        .build()
        .unwrap();

    const N: usize = 5;
    let mut reused = Session::new(spec.clone()).unwrap();
    let mut reused_grads = Vec::with_capacity(N);
    let mut reused_lams = Vec::with_capacity(N);
    for _ in 0..N {
        let _ = reused.grad(&rhs, &u0, &w);
        reused_grads.push(reused.grad_theta().to_vec());
        reused_lams.push(reused.lambda0().to_vec());
    }
    assert_eq!(reused.grads_run(), N as u64);
    assert_eq!(
        reused.workspace_allocs(),
        1,
        "N grads with stable shapes allocate the workspace exactly once"
    );

    for i in 0..N {
        let mut fresh = Session::new(spec.clone()).unwrap();
        let _ = fresh.grad(&rhs, &u0, &w);
        assert_eq!(reused_grads[i], fresh.grad_theta(), "θ̄ call {i} bitwise");
        assert_eq!(reused_lams[i], fresh.lambda0(), "λ call {i} bitwise");
    }
}

#[test]
fn parallel_session_reuse_is_bitwise_and_allocation_stable() {
    let rhs = mk_rhs(31);
    let (u0, w) = probe_vectors(32, rhs.state_len());
    let spec = SolverBuilder::new()
        .method_str("pnode")
        .scheme_str("rk4")
        .uniform(6)
        .parallel(ExecConfig { workers: 3, shard_rows: 8 })
        .build()
        .unwrap();

    let mut reused = Session::new(spec.clone()).unwrap();
    let mut grads = Vec::new();
    for _ in 0..3 {
        let out = reused.grad(&rhs, &u0, &w);
        assert_eq!(out.report.exec.shards, 3, "24 rows / 8 per shard");
        grads.push(reused.grad_theta().to_vec());
    }
    assert_eq!(reused.workspace_allocs(), 1);
    assert_eq!(grads[0], grads[1]);
    assert_eq!(grads[1], grads[2]);

    let mut fresh = Session::new(spec).unwrap();
    let _ = fresh.grad(&rhs, &u0, &w);
    assert_eq!(grads[0], fresh.grad_theta(), "reuse never changes bits");
}

#[test]
fn tiered_fleet_budget_holds_under_reuse() {
    // an over-subscribed shard fleet leasing from ONE arbiter pool: every
    // reused-gradient call must spill rather than exceed the budget
    let rhs = mk_rhs(41);
    let (u0, w) = probe_vectors(42, rhs.state_len());

    // reference: the same fleet, all-resident — measures the footprint
    // and pins the gradient bits the tiered fleet must reproduce (same
    // shard decomposition, same tree-reduction shape)
    let cfg = ExecConfig { workers: 4, shard_rows: 8 };
    let mut probe = SolverBuilder::new()
        .method_str("pnode")
        .scheme_str("rk4")
        .uniform(24)
        .parallel(cfg)
        .session()
        .unwrap();
    let footprint = probe.grad(&rhs, &u0, &w).report.ckpt_bytes;
    let budget = (footprint / 4).max(1);

    let dir = std::env::temp_dir().join(format!("pnode-session-reuse-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = SolverBuilder::new()
        .method_str(&format!(
            "pnode:tiered:{budget}:{}",
            dir.to_string_lossy()
        ))
        .scheme_str("rk4")
        .uniform(24)
        .parallel(cfg)
        .build()
        .unwrap();

    let mut session = Session::new(spec).unwrap();
    for call in 0..3 {
        let out = session.grad(&rhs, &u0, &w);
        let exec = out.report.exec;
        assert_eq!(exec.lease_pool_bytes, budget, "call {call}");
        assert!(
            exec.peak_leased_bytes <= budget,
            "call {call}: fleet hot tier exceeded the budget: {} > {budget}",
            exec.peak_leased_bytes
        );
        assert_eq!(exec.over_grant_bytes, 0, "call {call}: {exec:?}");
        assert!(out.report.tier.spills > 0, "call {call}: quarter budget must spill");
        // spilling must never change the gradient (f32 cold tier)
        assert_eq!(session.grad_theta(), probe.grad_theta(), "call {call}: θ̄ bitwise");
        assert_eq!(session.lambda0(), probe.lambda0(), "call {call}: λ bitwise");
    }
    assert_eq!(session.workspace_allocs(), 1, "reuse holds under tiering too");
    let _ = std::fs::remove_dir_all(&dir);
}
