//! Deep finite-difference gradient checks across schemes, policies, and
//! both implicit methods — the "reverse-accurate to machine precision"
//! claim, exercised harder than the unit tests do.

use pnode::checkpoint::CheckpointPolicy;
use pnode::methods::{BlockSpec, GradientMethod, Pnode};
use pnode::nn::Act;
use pnode::ode::implicit::{integrate_implicit, ThetaScheme};
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::tableau::EXPLICIT_SCHEMES;
use pnode::testing::prop;
use pnode::util::rng::Rng;

fn mk_rhs(seed: u64) -> ModuleRhs {
    let dims = vec![4, 9, 3];
    let mut rng = Rng::new(seed);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.2);
    ModuleRhs::mlp(dims, Act::Tanh, true, 2, theta)
}

#[test]
fn fd_check_every_scheme_and_policy() {
    for &scheme in EXPLICIT_SCHEMES {
        for policy in [
            CheckpointPolicy::All,
            CheckpointPolicy::SolutionOnly,
            CheckpointPolicy::Binomial { n_checkpoints: 2 },
        ] {
            let mut rhs = mk_rhs(33);
            let mut rng = Rng::new(34);
            let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
            let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);
            // nt is a checked invariant of this uniform grid; keep the
            // literal local rather than the panicking BlockSpec::nt()
            let nt = 7;
            let spec = BlockSpec::new(scheme, nt);

            let mut m = Pnode::new(policy.clone());
            m.forward(&rhs, &spec, &u0);
            let mut lambda = w.clone();
            let mut g = vec![0.0f32; rhs.param_len()];
            m.backward(&rhs, &spec, &mut lambda, &mut g);

            let loss = |rhs: &dyn OdeRhs| {
                let uf = pnode::ode::erk::integrate_fixed(
                    scheme.tableau(),
                    rhs,
                    spec.t0,
                    spec.tf,
                    nt,
                    &u0,
                    |_, _, _, _, _, _| {},
                );
                pnode::tensor::dot(&w, &uf)
            };
            let h = 1e-2f32;
            let theta0 = rhs.params().to_vec();
            let p = theta0.len();
            for idx in [0usize, p / 4, p / 2, p - 1] {
                let mut tp = theta0.clone();
                tp[idx] += h;
                rhs.set_params(&tp);
                let lp = loss(&rhs);
                let mut tm = theta0.clone();
                tm[idx] -= h;
                rhs.set_params(&tm);
                let lm = loss(&rhs);
                rhs.set_params(&theta0);
                let fd = (lp - lm) / (2.0 * h as f64);
                assert!(
                    (fd - g[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{}/{}: dθ[{idx}] {} vs fd {fd}",
                    scheme.name(),
                    policy.name(),
                    g[idx]
                );
            }
        }
    }
}

#[test]
fn fd_check_implicit_multistep() {
    for scheme in [ThetaScheme::backward_euler(), ThetaScheme::crank_nicolson()] {
        let mut rhs = {
            let dims = vec![3, 12, 3];
            let mut rng = Rng::new(44);
            let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 0.8);
            ModuleRhs::mlp(dims, Act::Gelu, false, 1, theta)
        };
        let u0 = vec![0.4f32, -0.1, 0.3];
        let w = vec![1.0f32, 0.5, -0.3];
        let (t0, tf, nt) = (0.0, 1.0, 6);

        let ts: Vec<f64> =
            (0..=nt).map(|i| t0 + (tf - t0) * i as f64 / nt as f64).collect();
        let mut run = pnode::adjoint::driver::ThetaDriver::theta(
            scheme,
            CheckpointPolicy::SolutionOnly,
            &ts,
        );
        run.forward(&rhs, &u0);
        let mut lambda = w.clone();
        let mut g = vec![0.0f32; rhs.param_len()];
        run.backward(&rhs, &mut lambda, &mut g);

        let loss = |rhs: &dyn OdeRhs| {
            let uf = integrate_implicit(scheme, rhs, t0, tf, nt, &u0, |_, _, _, _, _| {});
            pnode::tensor::dot(&w, &uf)
        };
        let h = 1e-2f32;
        let theta0 = rhs.params().to_vec();
        for idx in [0usize, theta0.len() / 2, theta0.len() - 1] {
            let mut tp = theta0.clone();
            tp[idx] += h;
            rhs.set_params(&tp);
            let lp = loss(&rhs);
            let mut tm = theta0.clone();
            tm[idx] -= h;
            rhs.set_params(&tm);
            let lm = loss(&rhs);
            rhs.set_params(&theta0);
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - g[idx] as f64).abs() < 3e-2 * (1.0 + fd.abs()),
                "{}: dθ[{idx}] {} vs fd {fd}",
                scheme.name,
                g[idx]
            );
        }
    }
}

/// Adaptive-grid reverse accuracy: the PNODE gradient under
/// `TimeGrid::Adaptive` must match central finite differences of the *same
/// accepted discrete map* (the grid is frozen for the FD oracle), under
/// both the All and binomial:4 policies.
#[test]
fn fd_check_adaptive_grid_policies() {
    use pnode::adjoint::driver::ErkDriver;
    use pnode::ode::grid::TimeGrid;
    let tab = &pnode::ode::tableau::DOPRI5;
    for policy in [
        CheckpointPolicy::All,
        CheckpointPolicy::Binomial { n_checkpoints: 4 },
    ] {
        let mut rhs = mk_rhs(77);
        let mut rng = Rng::new(78);
        let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
        let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);
        let grid = TimeGrid::Adaptive { atol: 1e-6, rtol: 1e-6, h0: None };

        let mut run = ErkDriver::erk(tab, policy.clone(), 0.0, 1.0, grid);
        run.forward(&rhs, &u0);
        let frozen: Vec<(f64, f64)> = run.grid_steps().to_vec();
        assert!(frozen.len() > 1, "controller must accept multiple steps");
        let mut lambda = w.clone();
        let mut g = vec![0.0f32; rhs.param_len()];
        run.backward(&rhs, &mut lambda, &mut g);

        let loss = |rhs: &dyn OdeRhs, u0: &[f32]| {
            let uf =
                pnode::ode::erk::integrate_grid(tab, rhs, &frozen, u0, |_, _, _, _, _, _| {});
            pnode::tensor::dot(&w, &uf)
        };
        let h = 1e-3f32;
        for idx in 0..rhs.state_len().min(4) {
            let mut up = u0.clone();
            up[idx] += h;
            let mut um = u0.clone();
            um[idx] -= h;
            let fd = (loss(&rhs, &up) - loss(&rhs, &um)) / (2.0 * h as f64);
            assert!(
                (fd - lambda[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "{}: dL/du[{idx}] {} vs fd {fd}",
                policy.name(),
                lambda[idx]
            );
        }
        let h = 1e-2f32;
        let theta0 = rhs.params().to_vec();
        let p = theta0.len();
        for idx in [0usize, p / 2, p - 1] {
            let mut tp = theta0.clone();
            tp[idx] += h;
            rhs.set_params(&tp);
            let lp = loss(&rhs, &u0);
            let mut tm = theta0.clone();
            tm[idx] -= h;
            rhs.set_params(&tm);
            let lm = loss(&rhs, &u0);
            rhs.set_params(&theta0);
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - g[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "{}: dθ[{idx}] {} vs fd {fd}",
                policy.name(),
                g[idx]
            );
        }
    }
}

/// Explicit-nonuniform-grid reverse accuracy, through the Pnode method
/// surface (BlockSpec carries the grid).
#[test]
fn fd_check_explicit_nonuniform_grid() {
    use pnode::ode::grid::TimeGrid;
    let steps = vec![(0.0, 0.04), (0.04, 0.08), (0.12, 0.18), (0.3, 0.3), (0.6, 0.4)];
    for policy in [CheckpointPolicy::All, CheckpointPolicy::SolutionOnly] {
        let mut rhs = mk_rhs(88);
        let mut rng = Rng::new(89);
        let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
        let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);
        let spec = BlockSpec {
            scheme: pnode::ode::tableau::Scheme::Rk4,
            t0: 0.0,
            tf: 1.0,
            grid: TimeGrid::Explicit(steps.clone()),
        };

        let mut m = Pnode::new(policy.clone());
        m.forward(&rhs, &spec, &u0);
        let mut lambda = w.clone();
        let mut g = vec![0.0f32; rhs.param_len()];
        m.backward(&rhs, &spec, &mut lambda, &mut g);

        let loss = |rhs: &dyn OdeRhs| {
            let uf = pnode::ode::erk::integrate_grid(
                spec.scheme.tableau(),
                rhs,
                &steps,
                &u0,
                |_, _, _, _, _, _| {},
            );
            pnode::tensor::dot(&w, &uf)
        };
        let h = 1e-2f32;
        let theta0 = rhs.params().to_vec();
        let p = theta0.len();
        for idx in [0usize, p / 3, p - 1] {
            let mut tp = theta0.clone();
            tp[idx] += h;
            rhs.set_params(&tp);
            let lp = loss(&rhs);
            let mut tm = theta0.clone();
            tm[idx] -= h;
            rhs.set_params(&tm);
            let lm = loss(&rhs);
            rhs.set_params(&theta0);
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - g[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "{}: dθ[{idx}] {} vs fd {fd}",
                policy.name(),
                g[idx]
            );
        }
    }
}

/// Every spec-addressable architecture, end to end through the discrete
/// adjoint: PNODE θ-gradients over a `ModuleRhs` must match central finite
/// differences of the frozen forward map — dense, time-conditioned
/// (concat + concatsquash), residual, and augmented graphs alike.
#[test]
fn fd_check_every_architecture() {
    use pnode::api::ArchSpec;
    use pnode::ode::ModuleRhs;
    let archs = [
        ArchSpec::Mlp { hidden: vec![8], act: Act::Tanh },
        ArchSpec::ConcatMlp { hidden: vec![8], act: Act::Gelu },
        ArchSpec::ConcatSquashMlp { hidden: vec![8], act: Act::Tanh },
        ArchSpec::Residual(Box::new(ArchSpec::ConcatMlp { hidden: vec![6], act: Act::Tanh })),
        ArchSpec::Augment {
            extra: 2,
            inner: Box::new(ArchSpec::Mlp { hidden: vec![6], act: Act::Sigmoid }),
        },
    ];
    for arch in archs {
        let mut rng = Rng::new(91);
        let theta0 = arch.init(&mut rng, 3);
        let mut rhs = ModuleRhs::from_arch(&arch, 3, 2, theta0.clone());
        let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
        let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);
        let nt = 6;
        let spec = BlockSpec::new(pnode::ode::tableau::Scheme::Rk4, nt);

        let mut m = Pnode::new(CheckpointPolicy::All);
        m.forward(&rhs, &spec, &u0);
        let mut lambda = w.clone();
        let mut g = vec![0.0f32; rhs.param_len()];
        m.backward(&rhs, &spec, &mut lambda, &mut g);

        let loss = |rhs: &dyn OdeRhs| {
            let uf = pnode::ode::erk::integrate_fixed(
                spec.scheme.tableau(),
                rhs,
                spec.t0,
                spec.tf,
                nt,
                &u0,
                |_, _, _, _, _, _| {},
            );
            pnode::tensor::dot(&w, &uf)
        };
        let h = 1e-2f32;
        let p = theta0.len();
        for idx in [0usize, p / 3, p / 2, p - 1] {
            let mut tp = theta0.clone();
            tp[idx] += h;
            rhs.set_params(&tp);
            let lp = loss(&rhs);
            let mut tm = theta0.clone();
            tm[idx] -= h;
            rhs.set_params(&tm);
            let lm = loss(&rhs);
            rhs.set_params(&theta0);
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - g[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "{}: dθ[{idx}] {} vs fd {fd}",
                arch.name(),
                g[idx]
            );
        }
    }
}

/// The per-module derivative contract, exercised through the shared
/// property helpers: vjp/jvp duality, first-order FD, and the directional
/// second-order FD for every module kind.
#[test]
fn per_module_adjoint_consistency_and_fd() {
    use pnode::api::ArchSpec;
    use pnode::nn::module::{Activation, Augment, Linear, Module};
    let roster: Vec<(&str, Box<dyn Module>)> = vec![
        ("linear", Box::new(Linear::new(4, 3))),
        ("act-tanh", Box::new(Activation::new(Act::Tanh, 5))),
        ("act-gelu", Box::new(Activation::new(Act::Gelu, 4))),
        ("augment", Box::new(Augment::new(3, 2))),
        ("mlp-seq", ArchSpec::Mlp { hidden: vec![7, 5], act: Act::Tanh }.build(4)),
        ("concat-time", ArchSpec::ConcatMlp { hidden: vec![6], act: Act::Gelu }.build(3)),
        (
            "concatsquash",
            ArchSpec::ConcatSquashMlp { hidden: vec![6], act: Act::Tanh }.build(3),
        ),
        (
            "residual",
            ArchSpec::Residual(Box::new(ArchSpec::Mlp { hidden: vec![6], act: Act::Sigmoid }))
                .build(4),
        ),
    ];
    for (name, m) in roster {
        prop::check(&format!("gradcheck-module-{name}"), 211, 4, |rng| {
            let mut theta = prop::vec_normal(rng, m.param_len());
            for v in theta.iter_mut() {
                *v *= 0.5;
            }
            let t = rng.uniform(0.0, 1.0);
            prop::module_duality(m.as_ref(), 2, t, &theta, rng)?;
            prop::module_fd(m.as_ref(), 2, t, &theta, rng)?;
            prop::module_sovjp_fd(m.as_ref(), 2, t, &theta, rng)
        });
    }
}

/// The stiff task's analytic RHS is outside the module system and must be
/// byte-for-byte unaffected by it: golden values pinned exactly.
#[test]
fn robertson_analytic_rhs_is_bitwise_pinned() {
    use pnode::ode::rhs::RobertsonRhs;
    let rhs = RobertsonRhs::default();
    let mut du = [0.0f32; 3];
    rhs.f(0.0, &[1.0, 0.0, 0.0], &mut du);
    assert_eq!(du, [-0.04, 0.04, 0.0]);
    let u = [0.5f32, 2e-5, 0.25];
    rhs.f(0.0, &u, &mut du);
    // the exact f32 roundings of the f64 arithmetic, pinned bit-for-bit
    let want = [
        ((-0.04 * 0.5f64) + 1e4 * (2e-5f32 as f64) * 0.25) as f32,
        ((0.04 * 0.5f64) - 3e7 * (2e-5f32 as f64) * (2e-5f32 as f64)
            - 1e4 * (2e-5f32 as f64) * 0.25) as f32,
        (3e7 * (2e-5f32 as f64) * (2e-5f32 as f64)) as f32,
    ];
    assert_eq!(du, want);
    let mut vj = [0.0f32; 3];
    rhs.vjp_u(0.0, &u, &[1.0, 0.0, 0.0], &mut vj);
    assert_eq!(vj[0], -0.04f64 as f32);
}

/// Property: for random seeds, discrete-adjoint λ equals the FD directional
/// derivative along a random direction.
#[test]
fn fd_directional_derivative_property() {
    prop::check("fd-directional", 55, 6, |rng| {
        let rhs = mk_rhs(rng.next_u64());
        let n = rhs.state_len();
        let u0 = prop::vec_uniform(rng, n, 0.5);
        let w = prop::vec_uniform(rng, n, 1.0);
        let dir = prop::vec_normal(rng, n);
        let nt = 5;
        let spec = BlockSpec::new(pnode::ode::tableau::Scheme::Midpoint, nt);

        let mut m = Pnode::new(CheckpointPolicy::All);
        m.forward(&rhs, &spec, &u0);
        let mut lambda = w.clone();
        let mut g = vec![0.0f32; rhs.param_len()];
        m.backward(&rhs, &spec, &mut lambda, &mut g);
        let analytic = pnode::tensor::dot(&lambda, &dir);

        let loss = |u0: &[f32]| {
            let uf = pnode::ode::erk::integrate_fixed(
                spec.scheme.tableau(),
                &rhs,
                spec.t0,
                spec.tf,
                nt,
                u0,
                |_, _, _, _, _, _| {},
            );
            pnode::tensor::dot(&w, &uf)
        };
        let h = 1e-3f32;
        let mut up = u0.clone();
        let mut um = u0.clone();
        for i in 0..n {
            up[i] += h * dir[i];
            um[i] -= h * dir[i];
        }
        let fd = (loss(&up) - loss(&um)) / (2.0 * h as f64);
        if (fd - analytic).abs() > 2e-2 * (1.0 + fd.abs()) {
            return Err(format!("directional: analytic {analytic} vs fd {fd}"));
        }
        Ok(())
    });
}
