//! `RunSpec` JSON round-trip and builder-validation tests (the facade's
//! serialization contract): serialize → parse → identical spec across
//! every grid and policy variant, and degenerate specs rejected at build
//! time with the underlying message.

use pnode::api::{ArchSpec, MethodSpec, RunSpec, SolverBuilder, METHOD_NAMES};
use pnode::checkpoint::CheckpointPolicy;
use pnode::exec::ExecConfig;
use pnode::nn::Act;
use pnode::ode::grid::TimeGrid;
use pnode::ode::tableau::Scheme;

fn roundtrip(spec: &RunSpec) {
    // pretty and compact text both re-parse to the identical spec
    let pretty = spec.to_json().to_string_pretty();
    let back = RunSpec::parse_json(&pretty)
        .unwrap_or_else(|e| panic!("pretty re-parse failed: {e}\n{pretty}"));
    assert_eq!(&back, spec, "pretty round-trip\n{pretty}");
    let compact = spec.to_json().to_string_compact();
    let back = RunSpec::parse_json(&compact)
        .unwrap_or_else(|e| panic!("compact re-parse failed: {e}\n{compact}"));
    assert_eq!(&back, spec, "compact round-trip\n{compact}");
}

#[test]
fn every_method_name_roundtrips() {
    for name in METHOD_NAMES {
        let spec = SolverBuilder::new().method_str(name).build().unwrap();
        assert_eq!(spec.method.name(), *name);
        roundtrip(&spec);
    }
}

#[test]
fn tiered_policy_and_adaptive_grid_roundtrip() {
    // tiered policy (composed with binomial placement) over an adaptive
    // grid with explicit h0, plus a nonunit span — the maximal variant
    let spec = SolverBuilder::new()
        .method_str("pnode:tiered:8m+f16:/tmp/pnode-spec-spill:binomial:4")
        .scheme(Scheme::Dopri5)
        .span(0.25, 2.5)
        .grid(TimeGrid::Adaptive { atol: 1e-6, rtol: 1e-8, h0: Some(0.125) })
        .build()
        .unwrap();
    match spec.method.pnode_policy().unwrap() {
        CheckpointPolicy::Tiered { budget_bytes, compress_f16, inner, .. } => {
            assert_eq!(*budget_bytes, 8 << 20);
            assert!(compress_f16);
            assert_eq!(**inner, CheckpointPolicy::Binomial { n_checkpoints: 4 });
        }
        p => panic!("wrong policy {p:?}"),
    }
    roundtrip(&spec);

    // adaptive without h0 serializes without the key and still round-trips
    let spec = SolverBuilder::new()
        .scheme(Scheme::Bosh3)
        .adaptive(1e-5)
        .build()
        .unwrap();
    roundtrip(&spec);
}

#[test]
fn explicit_grids_and_exec_roundtrip() {
    // nonuniform explicit steps survive exactly (f64 shortest-round-trip
    // printing), with and without the execution engine
    let steps = vec![(0.0, 0.05), (0.05, 0.1), (0.15000000000000002, 0.85)];
    let spec = SolverBuilder::new()
        .method_str("pnode2")
        .grid(TimeGrid::Explicit(steps))
        .parallel(ExecConfig { workers: 3, shard_rows: 8 })
        .build()
        .unwrap();
    roundtrip(&spec);

    let spec = SolverBuilder::new()
        .method_str("aca")
        .uniform(12)
        .workers(2)
        .build()
        .unwrap();
    assert_eq!(spec.exec.map(|c| c.workers), Some(2));
    roundtrip(&spec);
}

#[test]
fn implicit_scheme_specs_roundtrip() {
    let ts = [0.0, 0.1, 0.3, 0.7, 1.5];
    let spec = SolverBuilder::new()
        .policy(CheckpointPolicy::SolutionOnly)
        .scheme(Scheme::CrankNicolson)
        .span(0.0, 1.5)
        .grid(TimeGrid::from_times(&ts))
        .build()
        .unwrap();
    roundtrip(&spec);
}

#[test]
fn arch_specs_roundtrip_end_to_end() {
    // the acceptance matrix: at minimum concatsquash (time-conditioned)
    // and augmented architectures survive serialize → parse → identical,
    // via both the typed setter and the CLI grammar
    let squash = ArchSpec::ConcatSquashMlp { hidden: vec![64, 64], act: Act::Tanh };
    let spec = SolverBuilder::new()
        .scheme(Scheme::Dopri5)
        .uniform(10)
        .arch(squash.clone())
        .build()
        .unwrap();
    assert_eq!(spec.arch, Some(squash));
    roundtrip(&spec);

    let augmented = ArchSpec::Augment {
        extra: 4,
        inner: Box::new(ArchSpec::ConcatMlp { hidden: vec![32], act: Act::Relu }),
    };
    let spec = SolverBuilder::new()
        .method_str("pnode:binomial:3")
        .uniform(6)
        .arch(augmented.clone())
        .build()
        .unwrap();
    assert_eq!(spec.arch.as_ref().map(|a| a.augment_extra()), Some(4));
    roundtrip(&spec);

    // the whole roster, through the string grammar and with exec composed
    for arch in [
        "mlp:16,16:tanh",
        "concat:32:gelu",
        "concatsquash:64:tanh",
        "residual:mlp:24:sigmoid",
        "augment:2:concatsquash:16:tanh",
    ] {
        let spec = SolverBuilder::new()
            .arch_str(arch)
            .workers(2)
            .uniform(4)
            .build()
            .unwrap_or_else(|e| panic!("{arch}: {e}"));
        assert_eq!(spec.arch.as_ref().map(|a| a.name()), Some(arch.to_string()));
        roundtrip(&spec);
    }

    // arch-less specs keep serializing with an explicit null (legacy docs
    // without the key also parse)
    let spec = SolverBuilder::new().build().unwrap();
    assert_eq!(spec.arch, None);
    roundtrip(&spec);
    let spec = RunSpec::parse_json(
        r#"{"method": "pnode", "scheme": "rk4", "grid": {"kind": "uniform", "nt": 4}}"#,
    )
    .unwrap();
    assert_eq!(spec.arch, None);
}

#[test]
fn bad_arch_documents_are_rejected_with_context() {
    let e = SolverBuilder::new().arch_str("mlp:16,0:tanh").build().unwrap_err();
    assert!(e.contains("nonzero"), "{e}");
    let e = SolverBuilder::new().arch_str("augment:0:mlp:4:tanh").build().unwrap_err();
    assert!(e.contains("extra"), "{e}");
    let e = RunSpec::parse_json(
        r#"{"method": "pnode", "scheme": "rk4",
            "grid": {"kind": "uniform", "nt": 4},
            "arch": {"kind": "warp_core"}}"#,
    )
    .unwrap_err();
    assert!(e.contains("warp_core"), "{e}");
    let e = RunSpec::parse_json(
        r#"{"method": "pnode", "scheme": "rk4",
            "grid": {"kind": "uniform", "nt": 4},
            "arch": {"kind": "concatsquash_mlp", "hidden": [16]}}"#,
    )
    .unwrap_err();
    assert!(e.contains("act"), "{e}");
}

#[test]
fn builder_rejects_degenerate_specs_with_messages() {
    // the satellite contract: the *underlying* message survives, never a
    // bare "unknown method"
    let e = SolverBuilder::new().method_str("pnode:binomial:0").build().unwrap_err();
    assert!(e.contains("binomial:0") && e.contains("at least one"), "{e}");
    let e = SolverBuilder::new().method_str("pnode:tiered:0:/tmp/x").build().unwrap_err();
    assert!(e.contains("zero"), "{e}");
    let e = SolverBuilder::new().workers(0).build().unwrap_err();
    assert!(e.contains("workers"), "{e}");
    let e = SolverBuilder::new().shard_rows(0).build().unwrap_err();
    assert!(e.contains("shard_rows"), "{e}");
    let e = SolverBuilder::new().uniform(0).build().unwrap_err();
    assert!(e.contains("nt >= 1"), "{e}");
    let e = SolverBuilder::new().grid(TimeGrid::Explicit(vec![])).build().unwrap_err();
    assert!(e.contains("at least one step"), "{e}");
    let e = SolverBuilder::new()
        .grid(TimeGrid::Explicit(vec![(0.9, 0.1), (0.0, 0.5)]))
        .build()
        .unwrap_err();
    assert!(e.contains("strictly increasing"), "{e}");
    let e = SolverBuilder::new()
        .grid(TimeGrid::Adaptive { atol: -1.0, rtol: 1e-6, h0: None })
        .scheme(Scheme::Dopri5)
        .build()
        .unwrap_err();
    assert!(e.contains("positive"), "{e}");
    // adaptive grid on schemes without an embedded pair
    for scheme in [Scheme::Euler, Scheme::Rk4, Scheme::CrankNicolson] {
        let e = SolverBuilder::new()
            .scheme(scheme)
            .adaptive(1e-6)
            .build()
            .unwrap_err();
        assert!(e.contains("embedded"), "{}: {e}", scheme.name());
    }
    // implicit θ-schemes: pnode family only, single-engine only
    let e = SolverBuilder::new()
        .method_str("cont")
        .scheme(Scheme::BackwardEuler)
        .build()
        .unwrap_err();
    assert!(e.contains("implicit"), "{e}");
    let e = SolverBuilder::new()
        .scheme(Scheme::CrankNicolson)
        .workers(2)
        .build()
        .unwrap_err();
    assert!(e.contains("explicit schemes only"), "{e}");
}

#[test]
fn parse_json_rejects_bad_documents_with_context() {
    let e = RunSpec::parse_json("{").unwrap_err();
    assert!(e.contains("parse error"), "{e}");
    let e = RunSpec::parse_json(r#"{"scheme": "rk4"}"#).unwrap_err();
    assert!(e.contains("method"), "{e}");
    let e = RunSpec::parse_json(
        r#"{"method": "pnode", "scheme": "rk4", "grid": {"kind": "warped"}}"#,
    )
    .unwrap_err();
    assert!(e.contains("warped"), "{e}");
    // degenerate content fails validation even when well-formed JSON
    let e = RunSpec::parse_json(
        r#"{"method": "pnode", "scheme": "rk4", "grid": {"kind": "uniform", "nt": 0}}"#,
    )
    .unwrap_err();
    assert!(e.contains("nt >= 1"), "{e}");
    // unknown sibling keys (e.g. the CLI's "task" block) are ignored
    let spec = RunSpec::parse_json(
        r#"{"method": "pnode", "scheme": "rk4",
            "grid": {"kind": "uniform", "nt": 4},
            "task": {"kind": "classification"}}"#,
    )
    .unwrap();
    assert_eq!(spec.grid, TimeGrid::Uniform { nt: 4 });
    assert_eq!(spec.method, MethodSpec::Pnode { policy: CheckpointPolicy::All });
}

#[test]
fn checked_in_exemplar_specs_parse_and_roundtrip() {
    for path in [
        "examples/specs/clf_small.json",
        "examples/specs/tiered_adaptive.json",
        "examples/specs/cnf_concatsquash.json",
        "examples/specs/clf_augmented.json",
    ] {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("{path}: {e} (run tests from the repo root)"));
        let spec = RunSpec::parse_json(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
        roundtrip(&spec);
    }
    // the two new exemplars carry the architectures the module system adds
    let squash = RunSpec::parse_json(
        &std::fs::read_to_string("examples/specs/cnf_concatsquash.json").unwrap(),
    )
    .unwrap();
    assert!(
        matches!(squash.arch, Some(ArchSpec::ConcatSquashMlp { .. })),
        "{:?}",
        squash.arch
    );
    let aug = RunSpec::parse_json(
        &std::fs::read_to_string("examples/specs/clf_augmented.json").unwrap(),
    )
    .unwrap();
    assert!(aug.arch.as_ref().map(|a| a.augment_extra()).unwrap_or(0) > 0, "{:?}", aug.arch);
}
