//! XLA integration tests: run only when `artifacts/manifest.json` exists
//! (`make artifacts`).  Validates the AOT path end-to-end: Pallas/JAX HLO
//! artifacts, PJRT execution, cross-checks against the pure-Rust mirror,
//! and a full PNODE gradient through the XLA RHS.

use pnode::methods::{BlockSpec, GradientMethod, Pnode};
use pnode::checkpoint::CheckpointPolicy;
use pnode::nn::Act;
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::rhs_xla::{XlaCnfRhs, XlaRhs};
use pnode::ode::tableau::Scheme;
use pnode::runtime::{Client, Manifest, ModelArtifacts};
use pnode::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    Manifest::load_default().ok()
}

fn quick_pair(seed: u64) -> Option<(XlaRhs, ModuleRhs)> {
    let m = manifest()?;
    let client = Client::cpu().ok()?;
    let arts = ModelArtifacts::load(&client, &m, "quick_d8").ok()?;
    let entry = arts.entry.clone();
    let mut rng = Rng::new(seed);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &entry.dims, 1.0);
    let xla = XlaRhs::new(arts, theta.clone()).ok()?;
    let rust = ModuleRhs::mlp(
        entry.dims.clone(),
        Act::parse(&entry.act).unwrap(),
        entry.time_dep,
        entry.batch,
        theta,
    );
    Some((xla, rust))
}

macro_rules! need_artifacts {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn xla_primitives_match_rust_mirror() {
    let (xla, rust) = need_artifacts!(quick_pair(1));
    let n = xla.state_len();
    let mut rng = Rng::new(2);
    let mut u = vec![0.0f32; n];
    rng.fill_normal(&mut u);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v);

    for t in [0.0f64, 0.37, 1.0] {
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        xla.f(t, &u, &mut a);
        rust.f(t, &u, &mut b);
        pnode::testing::assert_allclose(&a, &b, 1e-4, 1e-6, "f");

        xla.vjp_u(t, &u, &v, &mut a);
        rust.vjp_u(t, &u, &v, &mut b);
        pnode::testing::assert_allclose(&a, &b, 1e-4, 1e-6, "vjp_u");

        xla.jvp(t, &u, &v, &mut a);
        rust.jvp(t, &u, &v, &mut b);
        pnode::testing::assert_allclose(&a, &b, 1e-4, 1e-6, "jvp");
    }

    let mut ga = vec![0.0f32; n];
    let mut gb = vec![0.0f32; n];
    let mut ta = vec![0.0f32; xla.param_len()];
    let mut tb = vec![0.0f32; rust.param_len()];
    xla.vjp_both(0.5, &u, &v, &mut ga, &mut ta);
    rust.vjp_both(0.5, &u, &v, &mut gb, &mut tb);
    pnode::testing::assert_allclose(&ga, &gb, 1e-4, 1e-6, "vjp_both u");
    pnode::testing::assert_allclose(&ta, &tb, 1e-4, 1e-6, "vjp_both theta");
}

#[test]
fn pnode_gradient_through_xla_matches_rust() {
    let (xla, rust) = need_artifacts!(quick_pair(3));
    let n = xla.state_len();
    let mut rng = Rng::new(4);
    let mut u0 = vec![0.0f32; n];
    rng.fill_normal(&mut u0);
    let mut w = vec![0.0f32; n];
    rng.fill_normal(&mut w);
    let spec = BlockSpec::new(Scheme::Bosh3, 5);

    let grad = |rhs: &dyn OdeRhs| -> (Vec<f32>, Vec<f32>) {
        let mut m = Pnode::new(CheckpointPolicy::All);
        m.forward(rhs, &spec, &u0);
        let mut l = w.clone();
        let mut g = vec![0.0f32; rhs.param_len()];
        m.backward(rhs, &spec, &mut l, &mut g);
        (l, g)
    };
    let (lx, gx) = grad(&xla);
    let (lr, gr) = grad(&rust);
    pnode::testing::assert_allclose(&lx, &lr, 1e-3, 1e-5, "lambda xla vs rust");
    pnode::testing::assert_allclose(&gx, &gr, 1e-3, 1e-5, "gtheta xla vs rust");
}

#[test]
fn xla_implicit_step_runs_through_jvp_artifact() {
    let (xla, _) = need_artifacts!(quick_pair(5));
    use pnode::ode::implicit::{integrate_implicit, ThetaScheme};
    let n = xla.state_len();
    let mut rng = Rng::new(6);
    let mut u0 = vec![0.0f32; n];
    rng.fill_normal(&mut u0);
    let uf = integrate_implicit(
        ThetaScheme::crank_nicolson(),
        &xla,
        0.0,
        0.5,
        5,
        &u0,
        |_, _, _, _, _| {},
    );
    assert!(uf.iter().all(|x| x.is_finite()));
    assert!(xla.nfe().forward > 0, "Newton-GMRES must call f/jvp");
}

#[test]
fn cnf_artifacts_execute_and_conserve_shape() {
    let m = need_artifacts!(manifest());
    let client = need_artifacts!(Client::cpu().ok());
    let arts = need_artifacts!(ModelArtifacts::load(&client, &m, "cnf_power").ok());
    let entry = arts.entry.clone();
    let mut rng = Rng::new(7);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &entry.dims, 1.0);
    let mut rhs = need_artifacts!(XlaCnfRhs::new(arts, theta).ok());
    let (b, d) = (rhs.batch(), rhs.dim());
    let mut eps = vec![0.0f32; b * d];
    rng.fill_rademacher(&mut eps);
    rhs.set_eps(&eps);

    let mut z = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut z[..b * d]);
    let mut out = vec![0.0f32; rhs.state_len()];
    rhs.f(0.2, &z, &mut out);
    assert!(out.iter().all(|x| x.is_finite()));
    // dlogp part populated
    assert!(out[b * d..].iter().any(|&x| x != 0.0));

    // vjp duality spot check on the x-part:
    // <vx, dx> vs <gx, x> is not an identity; instead check vjp shape+finite
    let mut v = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut v);
    let mut gu = vec![0.0f32; rhs.state_len()];
    let mut gt = vec![0.0f32; rhs.param_len()];
    rhs.vjp_both(0.2, &z, &v, &mut gu, &mut gt);
    assert!(gu.iter().all(|x| x.is_finite()));
    assert!(gt.iter().any(|&x| x != 0.0));
}
