//! The static-analysis gate (DESIGN.md §14): fixture checks for every
//! `pnode-lint` rule, then the self-run — the shipped tree and its JSON
//! artifacts must be lint-clean.  CI additionally runs the `pnode-lint`
//! binary, which is a thin wrapper over the same library entry points.

use std::path::PathBuf;

use pnode::analysis::{lint_source, lint_tree, validate_artifacts, Finding};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Lint one fixture the way `pnode-lint --rs` does: under a virtual
/// `methods/` path, so every path-scoped rule (determinism included)
/// applies.
fn fixture(name: &str) -> Vec<Finding> {
    let path = repo_root().join("rust/tests/lint_fixtures").join(name);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading fixture {name}: {e}"));
    lint_source(&format!("methods/{name}"), &src)
}

fn rule_lines(fs: &[Finding]) -> Vec<(&'static str, usize)> {
    fs.iter().map(|f| (f.rule, f.line)).collect()
}

#[test]
fn bad_fixtures_are_flagged_with_the_right_rule_and_line() {
    assert_eq!(
        rule_lines(&fixture("bad_determinism.rs")),
        vec![("determinism", 1), ("determinism", 3)]
    );
    assert_eq!(rule_lines(&fixture("bad_unsafe.rs")), vec![("unsafe-safety", 2)]);
    assert_eq!(rule_lines(&fixture("bad_ordering.rs")), vec![("ordering", 6)]);
    assert_eq!(rule_lines(&fixture("bad_panic.rs")), vec![("panic", 2)]);
    // a waiver without a reason is itself a finding and waives nothing
    assert_eq!(rule_lines(&fixture("bad_waiver.rs")), vec![("waiver", 1), ("panic", 3)]);
}

#[test]
fn waived_fixtures_pass() {
    let names =
        ["waived_determinism.rs", "waived_unsafe.rs", "waived_ordering.rs", "waived_panic.rs"];
    for name in names {
        let fs = fixture(name);
        assert!(fs.is_empty(), "{name} should be clean, got: {fs:?}");
    }
}

#[test]
fn shipped_tree_is_lint_clean() {
    let fs = lint_tree(&repo_root().join("rust/src")).expect("walking rust/src");
    assert!(
        fs.is_empty(),
        "pnode-lint findings in the shipped tree:\n{}",
        fs.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn shipped_json_artifacts_parse() {
    let fs = validate_artifacts(&repo_root()).expect("walking artifacts");
    assert!(fs.is_empty(), "malformed JSON artifacts: {fs:?}");
}
