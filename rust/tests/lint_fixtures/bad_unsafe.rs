fn head(xs: &[f32]) -> f32 {
    unsafe { *xs.as_ptr() }
}
