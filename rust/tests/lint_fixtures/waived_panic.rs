fn parse_step(s: &str) -> usize {
    // lint:allow(panic): fixture — the input is a compile-time constant
    s.parse().unwrap()
}
