use std::sync::atomic::{AtomicUsize, Ordering};

static N: AtomicUsize = AtomicUsize::new(0);

fn bump() -> usize {
    N.fetch_add(1, Ordering::Relaxed)
}
