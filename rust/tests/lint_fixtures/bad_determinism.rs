use std::collections::HashMap;

fn which_step(seen: &HashMap<u64, usize>, step: u64) -> Option<usize> {
    seen.get(&step).copied()
}
