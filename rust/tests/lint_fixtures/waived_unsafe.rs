fn head(xs: &[f32]) -> f32 {
    // SAFETY: the caller guarantees xs is non-empty
    unsafe { *xs.as_ptr() }
}
