// lint:allow(determinism): fixture — a keyed scratch map, never iterated
use std::collections::HashMap;

// lint:allow(determinism): fixture — insertion only, order never observed
fn probe(seen: &mut HashMap<u64, usize>) {
    seen.insert(7, 1);
}
