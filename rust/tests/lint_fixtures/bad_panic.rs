fn parse_step(s: &str) -> usize {
    s.parse().unwrap()
}
