use std::sync::atomic::{AtomicUsize, Ordering};

static N: AtomicUsize = AtomicUsize::new(0);

fn bump() -> usize {
    // Relaxed: a statistics counter; no data is published through it
    N.fetch_add(1, Ordering::Relaxed)
}
