//! Method-equivalence and Table-2 shape tests at a scale closer to the
//! paper's benchmarks (larger nets, more steps) than the unit tests.
//! Every engine is resolved through the facade.

use pnode::api::{Session, SolverBuilder};
use pnode::methods::{MemModel, MethodReport};
use pnode::nn::Act;
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::tableau::Scheme;
use pnode::testing::prop;
use pnode::util::rng::Rng;

fn big_rhs(seed: u64) -> ModuleRhs {
    let dims = vec![17, 32, 32, 16];
    let mut rng = Rng::new(seed);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    ModuleRhs::mlp(dims, Act::Tanh, true, 8, theta)
}

fn session_of(method: &str, scheme: Scheme, nt: usize) -> Session {
    SolverBuilder::new()
        .method_str(method)
        .scheme(scheme)
        .uniform(nt)
        .session()
        .unwrap_or_else(|e| panic!("{method}: {e}"))
}

#[test]
fn gradients_identical_at_scale() {
    let rhs = big_rhs(61);
    let mut rng = Rng::new(62);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);
    let nt = 11;

    let mut reference = session_of("pnode", Scheme::Dopri5, nt);
    let _ = reference.grad(&rhs, &u0, &w);

    for name in ["naive", "anode", "aca", "pnode2", "pnode:binomial:4"] {
        let mut session = session_of(name, Scheme::Dopri5, nt);
        let _ = session.grad(&rhs, &u0, &w);
        assert!(
            pnode::testing::rel_l2(session.lambda0(), reference.lambda0()) < 1e-5,
            "{name}: lambda deviates"
        );
        assert!(
            pnode::testing::rel_l2(session.grad_theta(), reference.grad_theta()) < 1e-5,
            "{name}: grad deviates"
        );
    }
}

#[test]
fn table2_shape_at_benchmark_scale() {
    // clf_d64 instantiation of the memory model, sized off the real
    // module graph (summed per-module activation bytes): orderings and
    // crossovers the paper reports in Fig. 3 must hold.
    let theta = vec![0.0f32; pnode::nn::param_count(&[65, 168, 168, 64])];
    let clf = ModuleRhs::mlp(vec![65, 168, 168, 64], Act::Relu, true, 128, theta);
    let act_bytes = clf.activation_bytes_per_eval();
    assert_eq!(
        act_bytes,
        128 * ((65 + 168) + (168 + 168) + (168 + 64)) * 4,
        "per-module accounting equals the closed form on clf_d64"
    );
    for nt in [2u64, 5, 11, 20] {
        let m = MemModel::for_rhs(&clf, 6, nt, 4);
        assert!(m.node_naive() > m.anode(), "nt={nt}");
        assert!(m.anode() > m.aca(), "nt={nt}");
        assert!(m.aca() > m.node_cont(), "nt={nt}");
        assert!(m.pnode() < m.anode(), "nt={nt}: pnode must beat anode");
        assert!(m.pnode2() < m.aca() + act_bytes, "nt={nt}");
        // PNODE has the slowest growth among reverse-accurate methods
        if nt >= 5 {
            let m2 = MemModel { nt: nt * 2, ..m };
            let growth = |f: &dyn Fn(&MemModel) -> u64| f(&m2) - f(&m);
            let g_naive = growth(&|x| x.node_naive());
            let g_anode = growth(&|x| x.anode());
            let g_pnode = growth(&|x| x.pnode());
            assert!(g_pnode < g_anode && g_anode < g_naive, "nt={nt}");
        }
    }
}

#[test]
fn recompute_overhead_ordering() {
    // ACA does ~2x the recompute of ANODE's 1x; PNODE-All none.  (nt is a
    // local invariant of this uniform-grid test — the spec's grid is
    // static by construction, so no planned_nt() indirection is needed.)
    let rhs = big_rhs(71);
    let mut rng = Rng::new(72);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);
    let nt = 12usize;

    let report_of = |name: &str| -> MethodReport {
        let mut session = session_of(name, Scheme::Rk4, nt);
        session.grad(&rhs, &u0, &w).report
    };
    let pnode = report_of("pnode");
    let pnode2 = report_of("pnode2");
    let anode = report_of("anode");
    let aca = report_of("aca");
    assert_eq!(pnode.recompute_steps, 0);
    assert_eq!(pnode2.recompute_steps, (nt - 1) as u64);
    assert_eq!(anode.recompute_steps, nt as u64);
    assert_eq!(aca.recompute_steps, 2 * nt as u64);
    // NFE-B ordering: aca > anode ≈ pnode > naive(0)
    assert!(aca.nfe_backward > anode.nfe_backward);
    assert_eq!(report_of("naive").nfe_backward, 0);
}

#[test]
fn wallclock_shape_pnode_not_slower_than_aca() {
    // timing smoke test (coarse: assert PNODE-All <= 1.5x ACA)
    let rhs = big_rhs(81);
    let mut rng = Rng::new(82);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);

    let time_of = |name: &str| {
        let mut session = session_of(name, Scheme::Dopri5, 10);
        let t = std::time::Instant::now();
        for _ in 0..3 {
            let _ = session.grad(&rhs, &u0, &w);
        }
        t.elapsed().as_secs_f64()
    };
    let _warm = time_of("pnode");
    let t_pnode = time_of("pnode");
    let t_aca = time_of("aca");
    assert!(
        t_pnode <= t_aca * 1.5,
        "pnode {t_pnode:.4}s should not be slower than aca {t_aca:.4}s"
    );
}
