//! Cross-module integration tests (XLA-free: the pure-Rust MLP mirror
//! drives the full integrator → adjoint → checkpoint → optimizer stack).

use pnode::checkpoint::CheckpointPolicy;
use pnode::data::spiral::SpiralDataset;
use pnode::methods::{method_by_name, BlockSpec, GradientMethod, Pnode};
use pnode::nn::{Act, Adam, Optimizer};
use pnode::ode::rhs::{MlpRhs, OdeRhs};
use pnode::ode::tableau::{Scheme, EXPLICIT_SCHEMES};
use pnode::tasks::ClassificationTask;
use pnode::testing::prop;
use pnode::util::rng::Rng;

fn mk_rhs(dims: &[usize], batch: usize, seed: u64) -> MlpRhs {
    let mut rng = Rng::new(seed);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, dims, 1.0);
    MlpRhs::new(dims.to_vec(), Act::Tanh, true, batch, theta)
}

/// Every (scheme × method) combination produces a gradient that agrees
/// with PNODE-All for reverse-accurate methods.
#[test]
fn all_schemes_times_all_methods_agree() {
    let rhs = mk_rhs(&[5, 8, 4], 2, 1);
    let mut rng = Rng::new(2);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);

    for &scheme in EXPLICIT_SCHEMES {
        let spec = BlockSpec::new(scheme, 6);
        let mut reference = Pnode::new(CheckpointPolicy::All);
        reference.forward(&rhs, &spec, &u0);
        let mut l_ref = w.clone();
        let mut g_ref = vec![0.0f32; rhs.param_len()];
        reference.backward(&rhs, &spec, &mut l_ref, &mut g_ref);

        for name in ["naive", "anode", "aca", "pnode2", "pnode:binomial:3"] {
            let mut m = method_by_name(name).unwrap();
            m.forward(&rhs, &spec, &u0);
            let mut l = w.clone();
            let mut g = vec![0.0f32; rhs.param_len()];
            m.backward(&rhs, &spec, &mut l, &mut g);
            pnode::testing::assert_allclose(
                &l,
                &l_ref,
                1e-4,
                1e-6,
                &format!("{} lambda ({})", name, scheme.name()),
            );
            pnode::testing::assert_allclose(
                &g,
                &g_ref,
                1e-4,
                1e-6,
                &format!("{} gtheta ({})", name, scheme.name()),
            );
        }
    }
}

/// Continuous-adjoint discrepancy shrinks as O(h) accumulated (Prop. 1).
#[test]
fn prop1_continuous_adjoint_discrepancy_order() {
    let rhs = mk_rhs(&[4, 10, 3], 1, 7);
    let mut rng = Rng::new(8);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.4);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);

    let gap = |nt: usize| -> f64 {
        let spec = BlockSpec::new(Scheme::Euler, nt);
        let mut pnode = Pnode::new(CheckpointPolicy::All);
        pnode.forward(&rhs, &spec, &u0);
        let mut l_d = w.clone();
        let mut g_d = vec![0.0f32; rhs.param_len()];
        pnode.backward(&rhs, &spec, &mut l_d, &mut g_d);

        let mut cont = method_by_name("cont").unwrap();
        cont.forward(&rhs, &spec, &u0);
        let mut l_c = w.clone();
        let mut g_c = vec![0.0f32; rhs.param_len()];
        cont.backward(&rhs, &spec, &mut l_c, &mut g_c);
        pnode::testing::rel_l2(&l_c, &l_d)
    };
    let g1 = gap(8);
    let g2 = gap(32);
    assert!(g1 > 1e-7, "coarse-step gap should be visible: {g1:.2e}");
    assert!(g2 < g1 * 0.5, "gap must shrink with h: {g1:.2e} -> {g2:.2e}");
}

/// Recompute counts across the full binomial budget range are monotone and
/// hit the paper's endpoints (0 at full memory, N_t−1 at solution-only).
#[test]
fn checkpoint_budget_tradeoff_curve() {
    let rhs = mk_rhs(&[4, 6, 3], 2, 11);
    let mut rng = Rng::new(12);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);
    let nt = 16;
    let spec = BlockSpec::new(Scheme::Rk4, nt);

    let mut prev_recompute = u64::MAX;
    let mut prev_bytes = 0u64;
    for nc in [1usize, 2, 4, 8, 15] {
        let mut m = Pnode::new(CheckpointPolicy::Binomial { n_checkpoints: nc });
        m.forward(&rhs, &spec, &u0);
        let mut l = w.clone();
        let mut g = vec![0.0f32; rhs.param_len()];
        m.backward(&rhs, &spec, &mut l, &mut g);
        let r = m.report();
        assert!(
            r.recompute_steps <= prev_recompute,
            "recompute not monotone at nc={nc}"
        );
        assert!(r.ckpt_bytes >= prev_bytes, "memory not monotone at nc={nc}");
        prev_recompute = r.recompute_steps;
        prev_bytes = r.ckpt_bytes;
        if nc >= nt - 1 {
            assert_eq!(r.recompute_steps, 0);
        }
    }
}

/// End-to-end: a 2-block classifier trains to >90% train accuracy on an
/// easy spiral with every reverse-accurate method.
#[test]
fn classification_trains_with_each_method() {
    const D: usize = 8;
    const B: usize = 32;
    for name in ["pnode", "pnode2", "aca"] {
        let mut rng = Rng::new(100);
        let dims = vec![D + 1, 24, D];
        let p = pnode::nn::param_count(&dims);
        let dims_i = dims.clone();
        let name_owned = name.to_string();
        let mut task = ClassificationTask::new(
            &mut rng,
            2,
            BlockSpec::new(Scheme::Bosh3, 3),
            p,
            D,
            2,
            move |r| pnode::nn::init::kaiming_uniform(r, &dims_i, 1.0),
            move || method_by_name(&name_owned).unwrap(),
        );
        let mut rhs = MlpRhs::new(dims, Act::Tanh, true, B, task.block_theta(0).to_vec());
        let ds = SpiralDataset::generate(&mut rng, 100, 2, D);
        let (train, _) = ds.split(1.0);
        let mut opt = Adam::new(task.theta.len(), 1e-2);
        let mut x = vec![0.0f32; B * D];
        let mut y = vec![0usize; B];
        let mut acc = 0.0;
        for it in 0..60 {
            train.fill_batch(it * B, B, &mut x, &mut y);
            let res = task.grad_step(&mut rhs, B, &x, &y, 0.1);
            acc = res.accuracy;
            let g = res.grad;
            task.apply_grad(&mut opt as &mut dyn Optimizer, &g);
        }
        assert!(acc > 0.85, "{name}: final train acc {acc}");
    }
}

/// The tiered storage backend, addressed through the method-factory string
/// form, spills past its RAM budget and still reproduces the in-memory
/// gradients bit-for-bit (uncompressed cold tier).
#[test]
fn tiered_method_spec_spills_and_matches_in_memory() {
    let rhs = mk_rhs(&[5, 8, 4], 2, 31);
    let mut rng = Rng::new(32);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);
    let spec = BlockSpec::new(Scheme::Dopri5, 24);

    let mut reference = Pnode::new(CheckpointPolicy::All);
    reference.forward(&rhs, &spec, &u0);
    let mut l_ref = w.clone();
    let mut g_ref = vec![0.0f32; rhs.param_len()];
    reference.backward(&rhs, &spec, &mut l_ref, &mut g_ref);

    let dir = std::env::temp_dir().join(format!("pnode-int-tiered-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let name = format!("pnode:tiered:2k:{}", dir.to_string_lossy());
    let mut m = method_by_name(&name).expect("tiered method spec parses");
    m.forward(&rhs, &spec, &u0);
    let mut l = w.clone();
    let mut g = vec![0.0f32; rhs.param_len()];
    m.backward(&rhs, &spec, &mut l, &mut g);
    let r = m.report();

    assert_eq!(l, l_ref, "tiered λ is bitwise identical");
    assert_eq!(g, g_ref, "tiered θ̄ is bitwise identical");
    assert!(r.tier.spills > 0, "2 KiB budget must spill: {:?}", r.tier);
    assert!(r.tier.cold_bytes_written > 0);
    assert!(r.tier.prefetch_hits > 0, "backward sweep prefetches: {:?}", r.tier);
    assert!(
        r.ckpt_bytes < reference.report().ckpt_bytes,
        "hot-tier peak ({}) must undercut the all-resident peak ({})",
        r.ckpt_bytes,
        reference.report().ckpt_bytes
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// NFE counters propagate through the whole stack consistently.
#[test]
fn nfe_accounting_is_consistent() {
    let rhs = mk_rhs(&[5, 8, 4], 2, 21);
    let spec = BlockSpec::new(Scheme::Dopri5, 10);
    let mut rng = Rng::new(22);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);

    let mut m = Pnode::new(CheckpointPolicy::All);
    m.forward(&rhs, &spec, &u0);
    let mut l = w.clone();
    let mut g = vec![0.0f32; rhs.param_len()];
    m.backward(&rhs, &spec, &mut l, &mut g);
    let r = m.report();
    // FSAL: 7 + 6*(nt-1) forward evals
    assert_eq!(r.nfe_forward, 7 + 6 * 9);
    // backward: ≤ s vjps per step (zero-cotangent stages are skipped)
    assert!(r.nfe_backward <= 7 * 10);
    assert!(r.nfe_backward >= 6 * 10);
}
