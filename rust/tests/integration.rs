//! Cross-module integration tests (XLA-free: the pure-Rust MLP mirror
//! drives the full integrator → adjoint → checkpoint → optimizer stack),
//! with every gradient run constructed through the facade
//! (`SolverBuilder` → `RunSpec` → `Session`).

use pnode::api::SolverBuilder;
use pnode::checkpoint::CheckpointPolicy;
use pnode::data::spiral::SpiralDataset;
use pnode::nn::{Act, Adam, Optimizer};
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::tableau::{Scheme, EXPLICIT_SCHEMES};
use pnode::tasks::ClassificationTask;
use pnode::testing::prop;
use pnode::util::rng::Rng;

fn mk_rhs(dims: &[usize], batch: usize, seed: u64) -> ModuleRhs {
    let mut rng = Rng::new(seed);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, dims, 1.0);
    ModuleRhs::mlp(dims.to_vec(), Act::Tanh, true, batch, theta)
}

/// One session-driven gradient; returns (λ₀, θ̄).
fn grad_of(
    method: &str,
    scheme: Scheme,
    nt: usize,
    rhs: &dyn OdeRhs,
    u0: &[f32],
    w: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut session = SolverBuilder::new()
        .method_str(method)
        .scheme(scheme)
        .uniform(nt)
        .session()
        .unwrap_or_else(|e| panic!("{method}: {e}"));
    let _ = session.grad(rhs, u0, w);
    (session.lambda0().to_vec(), session.grad_theta().to_vec())
}

/// Every (scheme × method) combination produces a gradient that agrees
/// with PNODE-All for reverse-accurate methods.
#[test]
fn all_schemes_times_all_methods_agree() {
    let rhs = mk_rhs(&[5, 8, 4], 2, 1);
    let mut rng = Rng::new(2);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);

    for &scheme in EXPLICIT_SCHEMES {
        let (l_ref, g_ref) = grad_of("pnode", scheme, 6, &rhs, &u0, &w);
        for name in ["naive", "anode", "aca", "pnode2", "pnode:binomial:3"] {
            let (l, g) = grad_of(name, scheme, 6, &rhs, &u0, &w);
            pnode::testing::assert_allclose(
                &l,
                &l_ref,
                1e-4,
                1e-6,
                &format!("{} lambda ({})", name, scheme.name()),
            );
            pnode::testing::assert_allclose(
                &g,
                &g_ref,
                1e-4,
                1e-6,
                &format!("{} gtheta ({})", name, scheme.name()),
            );
        }
    }
}

/// Continuous-adjoint discrepancy shrinks as O(h) accumulated (Prop. 1).
#[test]
fn prop1_continuous_adjoint_discrepancy_order() {
    let rhs = mk_rhs(&[4, 10, 3], 1, 7);
    let mut rng = Rng::new(8);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.4);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);

    let gap = |nt: usize| -> f64 {
        let (l_d, _) = grad_of("pnode", Scheme::Euler, nt, &rhs, &u0, &w);
        let (l_c, _) = grad_of("cont", Scheme::Euler, nt, &rhs, &u0, &w);
        pnode::testing::rel_l2(&l_c, &l_d)
    };
    let g1 = gap(8);
    let g2 = gap(32);
    assert!(g1 > 1e-7, "coarse-step gap should be visible: {g1:.2e}");
    assert!(g2 < g1 * 0.5, "gap must shrink with h: {g1:.2e} -> {g2:.2e}");
}

/// Recompute counts across the full binomial budget range are monotone and
/// hit the paper's endpoints (0 at full memory, N_t−1 at solution-only).
#[test]
fn checkpoint_budget_tradeoff_curve() {
    let rhs = mk_rhs(&[4, 6, 3], 2, 11);
    let mut rng = Rng::new(12);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);
    let nt = 16;

    let mut prev_recompute = u64::MAX;
    let mut prev_bytes = 0u64;
    for nc in [1usize, 2, 4, 8, 15] {
        let mut session = SolverBuilder::new()
            .policy(CheckpointPolicy::Binomial { n_checkpoints: nc })
            .scheme(Scheme::Rk4)
            .uniform(nt)
            .session()
            .unwrap();
        let r = session.grad(&rhs, &u0, &w).report;
        assert!(
            r.recompute_steps <= prev_recompute,
            "recompute not monotone at nc={nc}"
        );
        assert!(r.ckpt_bytes >= prev_bytes, "memory not monotone at nc={nc}");
        prev_recompute = r.recompute_steps;
        prev_bytes = r.ckpt_bytes;
        if nc >= nt - 1 {
            assert_eq!(r.recompute_steps, 0);
        }
    }
}

/// End-to-end: a 2-block classifier trains to >85% train accuracy on an
/// easy spiral with every reverse-accurate method.
#[test]
fn classification_trains_with_each_method() {
    const D: usize = 8;
    const B: usize = 32;
    for name in ["pnode", "pnode2", "aca"] {
        let mut rng = Rng::new(100);
        let dims = vec![D + 1, 24, D];
        let p = pnode::nn::param_count(&dims);
        let dims_i = dims.clone();
        let spec = SolverBuilder::new()
            .method_str(name)
            .scheme(Scheme::Bosh3)
            .uniform(3)
            .build()
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut task = ClassificationTask::new(&mut rng, 2, &spec, p, D, 2, move |r| {
            pnode::nn::init::kaiming_uniform(r, &dims_i, 1.0)
        });
        let mut rhs = ModuleRhs::mlp(dims, Act::Tanh, true, B, task.block_theta(0).to_vec());
        let ds = SpiralDataset::generate(&mut rng, 100, 2, D);
        let (train, _) = ds.split(1.0);
        let mut opt = Adam::new(task.theta.len(), 1e-2);
        let mut x = vec![0.0f32; B * D];
        let mut y = vec![0usize; B];
        let mut acc = 0.0;
        for it in 0..60 {
            train.fill_batch(it * B, B, &mut x, &mut y);
            let res = task.grad_step(&mut rhs, B, &x, &y, 0.1);
            acc = res.accuracy;
            let g = res.grad;
            task.apply_grad(&mut opt as &mut dyn Optimizer, &g);
        }
        assert!(acc > 0.85, "{name}: final train acc {acc}");
    }
}

/// The tiered storage backend, addressed through the facade's method
/// string form, spills past its RAM budget and still reproduces the
/// in-memory gradients bit-for-bit (uncompressed cold tier).
#[test]
fn tiered_method_spec_spills_and_matches_in_memory() {
    let rhs = mk_rhs(&[5, 8, 4], 2, 31);
    let mut rng = Rng::new(32);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);

    let mut reference = SolverBuilder::new()
        .method_str("pnode")
        .scheme(Scheme::Dopri5)
        .uniform(24)
        .session()
        .unwrap();
    let ref_bytes = reference.grad(&rhs, &u0, &w).report.ckpt_bytes;

    let dir = std::env::temp_dir().join(format!("pnode-int-tiered-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let name = format!("pnode:tiered:2k:{}", dir.to_string_lossy());
    let mut session = SolverBuilder::new()
        .method_str(&name)
        .scheme(Scheme::Dopri5)
        .uniform(24)
        .session()
        .expect("tiered method spec parses");
    let r = session.grad(&rhs, &u0, &w).report;

    assert_eq!(session.lambda0(), reference.lambda0(), "tiered λ is bitwise identical");
    assert_eq!(session.grad_theta(), reference.grad_theta(), "tiered θ̄ is bitwise identical");
    assert!(r.tier.spills > 0, "2 KiB budget must spill: {:?}", r.tier);
    assert!(r.tier.cold_bytes_written > 0);
    assert!(r.tier.prefetch_hits > 0, "backward sweep prefetches: {:?}", r.tier);
    assert!(
        r.ckpt_bytes < ref_bytes,
        "hot-tier peak ({}) must undercut the all-resident peak ({ref_bytes})",
        r.ckpt_bytes
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// NFE counters propagate through the whole stack consistently.
#[test]
fn nfe_accounting_is_consistent() {
    let rhs = mk_rhs(&[5, 8, 4], 2, 21);
    let mut rng = Rng::new(22);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);

    let mut session = SolverBuilder::new()
        .method_str("pnode")
        .scheme(Scheme::Dopri5)
        .uniform(10)
        .session()
        .unwrap();
    let r = session.grad(&rhs, &u0, &w).report;
    // FSAL: 7 + 6*(nt-1) forward evals
    assert_eq!(r.nfe_forward, 7 + 6 * 9);
    // backward: ≤ s vjps per step (zero-cotangent stages are skipped)
    assert!(r.nfe_backward <= 7 * 10);
    assert!(r.nfe_backward >= 6 * 10);
}
