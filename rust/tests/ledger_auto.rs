//! ISSUE-8 integration surface: the persistent run ledger, the
//! telemetry-calibrated [`CostModel`], and `auto:<budget>` resolution.
//!
//! Covers the satellite-4 checklist end to end: lossless/order-stable
//! ledger round-trips, a fit that reproduces synthetic constants,
//! deterministic resolution, gradients of an auto-resolved session
//! bitwise identical to the same concrete policy run directly, and
//! degenerate auto specs rejected at `validate()` with precise messages.
//!
//! No test here mutates process env (`PNODE_LEDGER_DIR` etc.) — the lib
//! test harness runs threads in parallel and `set_var` would race; the
//! ledger tests pass explicit temp dirs instead.

use pnode::api::{MethodSpec, Session, SolverBuilder};
use pnode::checkpoint::CheckpointPolicy;
use pnode::coordinator::ExperimentRow;
use pnode::methods::ResolvedPolicy;
use pnode::obs::calibrate::ResolveCtx;
use pnode::obs::{CostModel, Ledger, RunRecord};
use pnode::ode::rhs::OdeRhs;
use pnode::ode::tableau::Scheme;
use pnode::ode::ModuleRhs;
use pnode::util::json;
use pnode::util::rng::Rng;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("pnode-la-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn approx(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * b.abs().max(1.0)
}

// ---------------------------------------------------------------- ledger

fn sample_record(i: usize) -> RunRecord {
    RunRecord {
        build: format!("main-g{i:09}"),
        spec: json::parse(&format!(
            "{{\"version\":1,\"method\":\"pnode:binomial:{}\",\"scheme\":\"dopri5\"}}",
            i + 1
        ))
        .unwrap(),
        row: json::parse(&format!("{{\"n_accepted\":{},\"time_secs\":0.25}}", 10 + i)).unwrap(),
        metrics: json::parse("{\"counters\":{\"gemm.mul_adds\":4096},\"spans\":{}}").unwrap(),
        memcheck: (i % 2 == 1)
            .then(|| json::parse("{\"predicted_bytes\":64,\"observed_bytes\":64}").unwrap()),
    }
}

#[test]
fn ledger_roundtrip_is_lossless_and_order_stable() {
    let dir = tmp_dir("roundtrip");
    let ledger = Ledger::open(&dir).unwrap();
    let recs: Vec<RunRecord> = (0..5).map(sample_record).collect();
    for r in &recs {
        ledger.append(r).unwrap();
    }
    // lossless: every field (including nested Json docs and the optional
    // memcheck) reads back equal; stable: in append order
    assert_eq!(ledger.read_all().unwrap(), recs);
    // appending through a fresh handle preserves the prefix
    Ledger::open(&dir).unwrap().append(&sample_record(5)).unwrap();
    let all = ledger.read_all().unwrap();
    assert_eq!(all.len(), 6);
    assert_eq!(all[..5], recs[..]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ledger_lines_are_independent_json_objects() {
    let dir = tmp_dir("lines");
    let ledger = Ledger::open(&dir).unwrap();
    for i in 0..3 {
        ledger.append(&sample_record(i)).unwrap();
    }
    let text = std::fs::read_to_string(ledger.path()).unwrap();
    for line in text.lines() {
        let doc = json::parse(line).unwrap();
        for key in ["build", "spec", "row", "metrics"] {
            assert!(doc.get(key).is_some(), "line missing {key}: {line}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------ cost model

/// A synthetic record with exactly known constants: 10 forward span calls
/// totalling `fwd_total` secs, 40960 checkpoint bytes stored in 1 ms and
/// restored in 2 ms under `solution_only` at nt = 11 (10 slots).
fn synth_record(fwd_total: f64) -> RunRecord {
    let metrics = format!(
        "{{\"counters\":{{}},\"spans\":{{\
          \"forward\":{{\"count\":10,\"total_secs\":{fwd_total}}},\
          \"store\":{{\"count\":10,\"total_secs\":0.001}},\
          \"restore\":{{\"count\":10,\"total_secs\":0.002}}}}}}"
    );
    RunRecord {
        build: "synth-g0".into(),
        spec: json::parse("{\"method\":\"pnode:solution_only\",\"scheme\":\"rk4\"}").unwrap(),
        row: json::parse("{\"measured_ckpt_bytes\":40960,\"n_accepted\":11}").unwrap(),
        metrics: json::parse(&metrics).unwrap(),
        memcheck: None,
    }
}

#[test]
fn fit_reproduces_synthetic_constants() {
    let records: Vec<RunRecord> = [1.0, 2.0, 4.0].iter().map(|t| synth_record(*t)).collect();
    let m = CostModel::fit(&records);
    // per-call forward medians over {0.1, 0.2, 0.4} → upper median 0.2
    assert!(approx(m.phase_secs[0], 0.2), "{:?}", m.phase_secs);
    // bandwidths are bytes/total-secs of the matching span
    assert!(approx(m.store_bytes_per_sec, 40960.0 / 0.001), "{}", m.store_bytes_per_sec);
    assert!(approx(m.restore_bytes_per_sec, 40960.0 / 0.002), "{}", m.restore_bytes_per_sec);
    // 40960 bytes over solution_only's 10 slots at nt = 11
    assert!(approx(m.vec_bytes, 4096.0), "{}", m.vec_bytes);
    assert!(approx(m.typical_nt, 11.0), "{}", m.typical_nt);
    // no tier spans → spill terms keep their documented priors
    let p = CostModel::priors();
    assert_eq!(m.spill_bytes_per_sec, p.spill_bytes_per_sec);
    assert_eq!(m.prefetch_bytes_per_sec, p.prefetch_bytes_per_sec);
    assert_eq!(m.samples, 3);
}

#[test]
fn cold_ledger_fit_is_exactly_the_priors() {
    assert_eq!(CostModel::fit(&[]), CostModel::priors());
}

#[test]
fn resolution_is_deterministic_and_budget_coherent() {
    let m = CostModel::priors();
    let ctx = ResolveCtx { nt: 12, n_stages: 7 };
    assert_eq!(m.resolve(1_572_864, &ctx).unwrap(), m.resolve(1_572_864, &ctx).unwrap());
    // a generous budget admits everything and All (zero recompute,
    // cheapest predicted time) wins
    assert_eq!(m.resolve(1 << 30, &ctx).unwrap(), CheckpointPolicy::All);
    // every candidate's fits flag agrees with its own predicted peak
    for c in m.candidates(1_572_864, &ctx) {
        assert_eq!(c.fits, c.pred_peak_hot_bytes <= 1_572_864, "{c:?}");
    }
}

// --------------------------------------------- auto sessions end to end

fn mk_rhs(seed: u64) -> ModuleRhs {
    let dims = vec![5, 9, 4];
    let mut rng = Rng::new(seed);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    ModuleRhs::mlp(dims, pnode::nn::Act::Tanh, true, 2, theta)
}

#[test]
fn auto_gradients_are_bitwise_identical_to_the_resolved_policy() {
    let rhs = mk_rhs(801);
    let mut rng = Rng::new(802);
    let mut u0 = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut u0);
    let w = vec![1.0f32; rhs.state_len()];

    let auto_spec = SolverBuilder::new()
        .policy_str("auto:1m")
        .scheme(Scheme::Dopri5)
        .uniform(10)
        .build()
        .unwrap();
    let mut auto = Session::new(auto_spec.clone()).unwrap();
    let out = auto.grad(&rhs, &u0, &w);
    assert_eq!(out.report.auto.budget_bytes, 1 << 20);
    assert_ne!(out.report.auto.resolved, ResolvedPolicy::NotAuto);

    // whatever the ledger-calibrated winner is, running it directly must
    // produce the exact same bits (resolution is observation-only)
    let resolved = auto.resolved_policy().expect("auto specs record a resolution").clone();
    let direct_spec = SolverBuilder::new()
        .policy(resolved.clone())
        .scheme(Scheme::Dopri5)
        .uniform(10)
        .build()
        .unwrap();
    assert_eq!(direct_spec.method, auto.resolved_spec().method);
    let mut direct = Session::new(direct_spec).unwrap();
    let direct_out = direct.grad(&rhs, &u0, &w);
    assert_eq!(out.u_f, direct_out.u_f);
    assert_eq!(auto.grad_theta(), direct.grad_theta(), "grad_theta must match bitwise");
    assert_eq!(auto.lambda0(), direct.lambda0(), "lambda0 must match bitwise");

    // the rows built from these reports carry requested vs resolved
    let row = ExperimentRow::from_spec_report("t", "d", &auto_spec, &out.report, 0.1, 0);
    assert_eq!(row.policy_requested.as_deref(), Some("auto:1m"));
    assert_eq!(row.policy_resolved.as_deref(), Some(resolved.name().as_str()));
    let j = row.to_json().to_string_compact();
    assert!(j.contains("\"policy_requested\":\"auto:1m\""), "{j}");
    let direct_row =
        ExperimentRow::from_spec_report("t", "d", direct.spec(), &direct_out.report, 0.1, 0);
    assert_eq!(direct_row.policy_requested, None, "concrete runs have no auto columns");
}

#[test]
fn auto_specs_roundtrip_through_strings_and_json() {
    let m = MethodSpec::parse("pnode:auto:8m").unwrap();
    assert_eq!(m.name(), "pnode:auto:8m");
    assert_eq!(MethodSpec::parse(&m.name()).unwrap(), m);
    assert_eq!(
        m.pnode_policy(),
        Some(&CheckpointPolicy::Auto { budget_bytes: 8 << 20 })
    );

    let spec = SolverBuilder::new()
        .policy_str("auto:8m")
        .scheme(Scheme::Rk4)
        .uniform(6)
        .build()
        .unwrap();
    let doc = spec.to_json();
    let back = pnode::api::RunSpec::from_json(&doc).unwrap();
    assert_eq!(back.method, spec.method);
    assert_eq!(back.to_json(), doc, "auto specs round-trip losslessly through JSON");
}

#[test]
fn degenerate_auto_specs_are_rejected_with_precise_messages() {
    // zero budget, through the builder (parse + validate funnel)
    let e = SolverBuilder::new().policy_str("auto:0").uniform(4).build().unwrap_err();
    assert!(e.contains("auto:0") && e.contains("nonzero"), "{e}");
    // zero budget, programmatic construction caught at spec validate
    let mut spec = SolverBuilder::new().uniform(4).build().unwrap();
    spec.method = MethodSpec::Pnode { policy: CheckpointPolicy::Auto { budget_bytes: 0 } };
    let e = spec.validate().unwrap_err();
    assert!(e.contains("auto:0"), "{e}");
    assert!(Session::new(spec).is_err(), "invalid specs never open sessions");
    // auto nested inside tiered: must name the fix, not fold into the dir
    let e = MethodSpec::parse("pnode:tiered:8m:/tmp/x:auto:4k").unwrap_err();
    assert!(e.contains("auto") && e.contains("concrete"), "{e}");
}
