//! Cross-kernel-path equivalence contract (DESIGN.md §12).
//!
//! The packed SIMD GEMM is NOT bitwise-equal to the legacy scalar loop
//! (different accumulation order), so scalar-vs-SIMD comparisons here are
//! at tolerance.  Everything *within* one path is exact: the Portable and
//! Avx2 paths are bitwise-identical to each other (both FMA end to end),
//! and the fused Linear+Activation plan is bitwise-equal to the
//! per-module composition on the same path.  `PNODE_KERNEL` itself is a
//! process-wide one-shot, so CI exercises the env values by running this
//! whole suite once per setting; in-process we pin the `_with` entries.

use pnode::nn::module::{Activation, ArchSpec, Linear, Module, Sequential};
use pnode::nn::Act;
use pnode::tensor::gemm::{
    self, kernel_path, sgemm_at_with, sgemm_bt_with, sgemm_with, KernelPath,
};
use pnode::util::rng::Rng;

/// Paper hot shape: B=128 rows through the 168-wide hidden layers.
const M: usize = 128;
const K: usize = 168;
const N: usize = 168;

fn filled(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v);
    for x in v.iter_mut() {
        *x *= 0.3;
    }
    v
}

fn assert_close(tag: &str, got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len(), "{tag}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let scale = 1.0f32.max(w.abs());
        assert!(
            (g - w).abs() <= tol * scale,
            "{tag}[{i}]: {g} vs {w} (tol {tol})"
        );
    }
}

#[test]
fn all_gemm_variants_agree_across_paths_at_paper_shape() {
    let mut rng = Rng::new(401);
    let a = filled(&mut rng, M * K);
    let b = filled(&mut rng, K * N);
    let at_a = filled(&mut rng, K * M);
    let bt_b = filled(&mut rng, N * K);

    let mut paths = vec![KernelPath::Scalar, KernelPath::Portable];
    let detected = gemm::detect();
    if detected != KernelPath::Portable {
        paths.push(detected);
    }

    let run = |p: KernelPath| {
        let mut c1 = vec![0.1f32; M * N];
        sgemm_with(p, M, K, N, &a, &b, &mut c1, 0.0);
        let mut c2 = vec![0.0f32; M * N];
        sgemm_at_with(p, M, K, N, &at_a, &b, &mut c2, 0.0);
        let mut c3 = vec![0.0f32; M * N];
        sgemm_bt_with(p, M, N, K, &a, &bt_b, &mut c3, 0.0);
        (c1, c2, c3)
    };
    let (s1, s2, s3) = run(KernelPath::Scalar);
    for p in &paths[1..] {
        let (c1, c2, c3) = run(*p);
        // k=168 dot products; 1e-4 relative absorbs the reassociation
        assert_close(&format!("sgemm {}", p.name()), &c1, &s1, 1e-4);
        assert_close(&format!("sgemm_at {}", p.name()), &c2, &s2, 1e-4);
        assert_close(&format!("sgemm_bt {}", p.name()), &c3, &s3, 1e-4);
    }
}

#[test]
fn portable_and_detected_simd_are_bitwise_identical() {
    // both paths compute every element as the same sequence of fused
    // multiply-adds, so their bits agree on every CPU
    let detected = gemm::detect();
    if detected == KernelPath::Portable {
        return; // nothing stronger to compare against on this host
    }
    let mut rng = Rng::new(402);
    let a = filled(&mut rng, M * K);
    let b = filled(&mut rng, K * N);
    let mut cp = vec![0.0f32; M * N];
    let mut cd = vec![0.0f32; M * N];
    sgemm_with(KernelPath::Portable, M, K, N, &a, &b, &mut cp, 0.0);
    sgemm_with(detected, M, K, N, &a, &b, &mut cd, 0.0);
    assert_eq!(cp, cd, "portable vs {} must be bitwise", detected.name());
}

#[test]
fn dispatched_path_is_one_of_the_known_kernels() {
    let p = kernel_path();
    assert!(
        matches!(p, KernelPath::Scalar | KernelPath::Portable | KernelPath::Avx2),
        "unknown path {p:?}"
    );
    // dispatch note is a no-op without obs enabled — must not panic
    gemm::note_dispatch();
}

fn mlp_stack() -> (Sequential, Vec<usize>) {
    let dims = vec![65usize, 48, 48, 64];
    let seq = Sequential::new(vec![
        Box::new(Linear::new(dims[0], dims[1])) as Box<dyn Module>,
        Box::new(Activation::new(Act::Tanh, dims[1])),
        Box::new(Linear::new(dims[1], dims[2])),
        Box::new(Activation::new(Act::Tanh, dims[2])),
        Box::new(Linear::new(dims[2], dims[3])),
    ]);
    (seq, dims)
}

#[test]
fn fused_plan_is_bitwise_equal_to_per_module_composition() {
    // the fusion contract: on ONE kernel path, evaluating Linear and
    // Activation as a single fused step produces the very same bits as
    // running the two modules back to back (same GEMM, same single bias
    // add, same elementwise order)
    let (seq, dims) = mlp_stack();
    assert_eq!(seq.n_fused_steps(), 2, "both Linear+Act pairs fuse");
    let bsz = 9usize;
    let mut rng = Rng::new(403);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    let x = filled(&mut rng, bsz * dims[0]);
    let v = filled(&mut rng, bsz * dims[3]);

    // fused
    let mut y = vec![0.0f32; bsz * dims[3]];
    let mut cache = vec![0.0f32; seq.cache_len(bsz)];
    seq.forward(bsz, 0.37, &theta, &x, &mut y, &mut cache);
    let mut gx = vec![0.0f32; bsz * dims[0]];
    let mut gt = vec![0.0f32; seq.param_len()];
    seq.vjp(bsz, 0.37, &theta, &v, &mut gx, Some(&mut gt), &cache);
    let mut dy = vec![0.0f32; bsz * dims[3]];
    seq.jvp(bsz, 0.37, &theta, &x, &mut dy, &cache);

    // per-module reference chain
    let children: Vec<Box<dyn Module>> = vec![
        Box::new(Linear::new(dims[0], dims[1])),
        Box::new(Activation::new(Act::Tanh, dims[1])),
        Box::new(Linear::new(dims[1], dims[2])),
        Box::new(Activation::new(Act::Tanh, dims[2])),
        Box::new(Linear::new(dims[2], dims[3])),
    ];
    let wmax = dims.iter().copied().max().unwrap();
    let mut offs = vec![0usize];
    let mut coffs = vec![0usize];
    for c in &children {
        offs.push(offs.last().unwrap() + c.param_len());
        coffs.push(coffs.last().unwrap() + c.cache_len(bsz));
    }
    let mut rcache = vec![0.0f32; *coffs.last().unwrap()];
    let mut cur = vec![0.0f32; bsz * wmax];
    let mut nxt = vec![0.0f32; bsz * wmax];
    cur[..bsz * dims[0]].copy_from_slice(&x);
    let mut width = dims[0];
    for (i, c) in children.iter().enumerate() {
        let th = &theta[offs[i]..offs[i + 1]];
        let cc = &mut rcache[coffs[i]..coffs[i + 1]];
        c.forward(bsz, 0.37, th, &cur[..bsz * width], &mut nxt[..bsz * c.out_dim()], cc);
        width = c.out_dim();
        std::mem::swap(&mut cur, &mut nxt);
    }
    assert_eq!(&cur[..bsz * dims[3]], &y[..], "fused forward is bitwise");

    let mut rgt = vec![0.0f32; seq.param_len()];
    let mut vcur = vec![0.0f32; bsz * wmax];
    let mut vnxt = vec![0.0f32; bsz * wmax];
    vcur[..bsz * dims[3]].copy_from_slice(&v);
    for (i, c) in children.iter().enumerate().rev() {
        let th = &theta[offs[i]..offs[i + 1]];
        let cc = &rcache[coffs[i]..coffs[i + 1]];
        let gslice = &mut rgt[offs[i]..offs[i + 1]];
        c.vjp(
            bsz,
            0.37,
            th,
            &vcur[..bsz * c.out_dim()],
            &mut vnxt[..bsz * c.in_dim()],
            Some(gslice),
            cc,
        );
        std::mem::swap(&mut vcur, &mut vnxt);
    }
    assert_eq!(&vcur[..bsz * dims[0]], &gx[..], "fused vjp gx is bitwise");
    assert_eq!(rgt, gt, "fused vjp gθ is bitwise");

    let mut dcur = vec![0.0f32; bsz * wmax];
    let mut dnxt = vec![0.0f32; bsz * wmax];
    dcur[..bsz * dims[0]].copy_from_slice(&x);
    for (i, c) in children.iter().enumerate() {
        let th = &theta[offs[i]..offs[i + 1]];
        let cc = &rcache[coffs[i]..coffs[i + 1]];
        c.jvp(bsz, 0.37, th, &dcur[..bsz * c.in_dim()], &mut dnxt[..bsz * c.out_dim()], cc);
        std::mem::swap(&mut dcur, &mut dnxt);
    }
    assert_eq!(&dcur[..bsz * dims[3]], &dy[..], "fused jvp is bitwise");
}

#[test]
fn concat_time_fused_entry_matches_manual_augmentation() {
    // ConcatTime over a fusable Sequential takes the b_eff = b + t·W[d,:]
    // shortcut; versus materialising [x | t] that reassociates one add,
    // so the comparison is at tolerance (DESIGN.md §12)
    let d = 6usize;
    let bsz = 5usize;
    let t = 0.61f64;
    let arch = ArchSpec::ConcatMlp { hidden: vec![11, 9], act: Act::Gelu };
    let m = arch.build(d);
    let mut rng = Rng::new(404);
    let theta = {
        let dims = vec![d + 1, 11, 9, d];
        pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0)
    };
    assert_eq!(theta.len(), m.param_len(), "layout matches ConcatMlp");
    let x = filled(&mut rng, bsz * d);
    let v = filled(&mut rng, bsz * d);

    let mut y = vec![0.0f32; bsz * d];
    let mut cache = vec![0.0f32; m.cache_len(bsz)];
    m.forward(bsz, t, &theta, &x, &mut y, &mut cache);
    let mut gx = vec![0.0f32; bsz * d];
    let mut gt = vec![0.0f32; m.param_len()];
    m.vjp(bsz, t, &theta, &v, &mut gx, Some(&mut gt), &cache);
    let mut dy = vec![0.0f32; bsz * d];
    m.jvp(bsz, t, &theta, &x, &mut dy, &cache);

    // reference: the same inner stack fed an explicitly augmented input
    let inner = Sequential::new(vec![
        Box::new(Linear::new(d + 1, 11)) as Box<dyn Module>,
        Box::new(Activation::new(Act::Gelu, 11)),
        Box::new(Linear::new(11, 9)),
        Box::new(Activation::new(Act::Gelu, 9)),
        Box::new(Linear::new(9, d)),
    ]);
    let mut xt = vec![0.0f32; bsz * (d + 1)];
    for r in 0..bsz {
        xt[r * (d + 1)..r * (d + 1) + d].copy_from_slice(&x[r * d..(r + 1) * d]);
        xt[r * (d + 1) + d] = t as f32;
    }
    let mut ry = vec![0.0f32; bsz * d];
    let mut rcache = vec![0.0f32; inner.cache_len(bsz)];
    inner.forward(bsz, t, &theta, &xt, &mut ry, &mut rcache);
    assert_close("concat-time forward", &y, &ry, 1e-5);

    let mut rgpad = vec![0.0f32; bsz * (d + 1)];
    let mut rgt = vec![0.0f32; inner.param_len()];
    inner.vjp(bsz, t, &theta, &v, &mut rgpad, Some(&mut rgt), &rcache);
    let mut rgx = vec![0.0f32; bsz * d];
    for r in 0..bsz {
        rgx[r * d..(r + 1) * d].copy_from_slice(&rgpad[r * (d + 1)..r * (d + 1) + d]);
    }
    assert_close("concat-time vjp gx", &gx, &rgx, 1e-5);
    assert_close("concat-time vjp gθ", &gt, &rgt, 1e-5);

    let mut dpad = vec![0.0f32; bsz * (d + 1)];
    for r in 0..bsz {
        dpad[r * (d + 1)..r * (d + 1) + d].copy_from_slice(&x[r * d..(r + 1) * d]);
    }
    let mut rdy = vec![0.0f32; bsz * d];
    inner.jvp(bsz, t, &theta, &dpad, &mut rdy, &rcache);
    assert_close("concat-time jvp", &dy, &rdy, 1e-5);
}

#[test]
fn gemm_bits_are_independent_of_worker_count_on_every_path() {
    // the end-to-end determinism pin lives in parallel_determinism.rs;
    // this is the kernel-level version across explicit paths
    let mut rng = Rng::new(405);
    let m = 256usize;
    let (k, n) = (96usize, 96usize);
    let a = filled(&mut rng, m * k);
    let b = filled(&mut rng, k * n);
    let mut paths = vec![KernelPath::Scalar, KernelPath::Portable];
    let detected = gemm::detect();
    if detected != KernelPath::Portable {
        paths.push(detected);
    }
    for p in paths {
        let mut base = vec![0.0f32; m * n];
        gemm::set_gemm_workers(1);
        sgemm_with(p, m, k, n, &a, &b, &mut base, 0.0);
        for workers in [2usize, 3, 4] {
            let mut c = vec![0.0f32; m * n];
            gemm::set_gemm_workers(workers);
            sgemm_with(p, m, k, n, &a, &b, &mut c, 0.0);
            assert_eq!(c, base, "{}: workers={workers} changed bits", p.name());
        }
    }
    gemm::set_gemm_workers(1); // restore the process default
}
