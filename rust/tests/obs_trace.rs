//! End-to-end contract of the observability subsystem (DESIGN.md §11):
//! a disabled sink records nothing, recording never changes gradient
//! bits, the Chrome-trace export parses back well-formed through the
//! in-tree JSON parser, and the merged `(tid, seq)` stream is identical
//! across runs and worker counts.
//!
//! The obs sink is process-global and `cargo test` shares one process
//! per binary, so EVERY test here holds [`pnode::obs::test_guard`] for
//! its whole body and leaves the sink disabled + reset on exit.

use pnode::api::{Session, SolverBuilder};
use pnode::nn::Act;
use pnode::obs::{self, EventKind};
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::util::rng::Rng;

const B: usize = 24;
const D: usize = 6;
const SHARD_ROWS: usize = 8;

fn mk_rhs(seed: u64) -> ModuleRhs {
    let dims = vec![D + 1, 16, D];
    let mut rng = Rng::new(seed);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    ModuleRhs::mlp(dims, Act::Tanh, true, B, theta)
}

fn vecs(seed: u64, n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut u0 = vec![0.0f32; n];
    rng.fill_normal(&mut u0);
    for x in u0.iter_mut() {
        *x *= 0.4;
    }
    let mut w = vec![0.0f32; n];
    rng.fill_normal(&mut w);
    (u0, w)
}

/// One full gradient through the facade; returns `(u_f, λ0, θ̄)`.
fn run_grad(spec: &pnode::api::RunSpec) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let rhs = mk_rhs(7);
    let (u0, w) = vecs(8, rhs.state_len());
    let mut s = Session::new(spec.clone()).expect("valid spec");
    let out = s.grad(&rhs, &u0, &w);
    (out.u_f, s.lambda0().to_vec(), s.grad_theta().to_vec())
}

/// The acceptance configuration: tiered (over-budget, so it spills and
/// leases) with a binomial inner placement, on the parallel engine.
fn tiered_binomial_spec(dir: &str, workers: usize) -> pnode::api::RunSpec {
    SolverBuilder::new()
        .scheme_str("dopri5")
        .policy_str(&format!("tiered:8k:{dir}:binomial:4"))
        .uniform(12)
        .workers(workers)
        .shard_rows(SHARD_ROWS)
        .build()
        .expect("valid tiered+binomial spec")
}

fn tmp_dir(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("pnode-obs-{tag}-{}", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

#[test]
fn obs_off_records_nothing_and_gradients_are_bitwise_identical_on_off() {
    let _g = obs::test_guard();
    obs::disable();
    obs::reset();

    let dir = tmp_dir("bitwise");
    let _ = std::fs::remove_dir_all(&dir);
    let spec = tiered_binomial_spec(&dir, 2);

    let off = run_grad(&spec);
    assert!(obs::take().is_empty(), "obs off => zero events recorded");

    obs::enable();
    let on = run_grad(&spec);
    let events = obs::take();
    obs::disable();
    assert!(!events.is_empty(), "obs on => the run is traced");

    assert_eq!(off.0, on.0, "u(t_F) bitwise identical obs on/off");
    assert_eq!(off.1, on.1, "λ0 bitwise identical obs on/off");
    assert_eq!(off.2, on.2, "θ̄ bitwise identical obs on/off");

    obs::reset();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spec_obs_block_switches_the_sink_on() {
    let _g = obs::test_guard();
    obs::disable();
    obs::reset();

    let spec = SolverBuilder::new().uniform(3).observe(true).build().unwrap();
    let _s = Session::new(spec).unwrap();
    assert!(obs::enabled(), "opening a session on an obs spec enables the sink");

    obs::disable();
    obs::reset();
}

#[test]
fn chrome_trace_parses_back_and_is_well_formed() {
    let _g = obs::test_guard();
    obs::disable();
    obs::reset();

    let dir = tmp_dir("trace");
    let _ = std::fs::remove_dir_all(&dir);
    let spec = tiered_binomial_spec(&dir, 2);

    obs::enable();
    let _ = run_grad(&spec);
    let events = obs::take();
    obs::disable();

    // every adjoint phase shows up, plus pool / lease / session spans
    let names: std::collections::BTreeSet<&str> = events.iter().map(|e| e.name).collect();
    for phase in obs::PHASES {
        assert!(names.contains(phase), "missing {phase:?} span in {names:?}");
    }
    assert!(names.contains("session.grad"), "{names:?}");
    assert!(names.contains("pool.job"), "{names:?}");
    assert!(names.contains("lease.ask"), "arbiter lease spans: {names:?}");
    assert!(
        names.iter().any(|n| n.starts_with("tier.")),
        "tiered-store events: {names:?}"
    );

    // spans balance per tid, Ends pair with the innermost Begin
    let mut stacks: std::collections::BTreeMap<u32, Vec<&str>> = Default::default();
    for e in &events {
        match e.kind {
            EventKind::Begin => stacks.entry(e.tid).or_default().push(e.name),
            EventKind::End => {
                let top = stacks.get_mut(&e.tid).and_then(|s| s.pop());
                assert_eq!(
                    top,
                    Some(e.name),
                    "End must match the innermost open Begin on tid {}",
                    e.tid
                );
            }
            _ => {}
        }
    }
    for (tid, s) in &stacks {
        assert!(s.is_empty(), "unbalanced spans on tid {tid}: {s:?}");
    }

    // export parses back through the in-tree parser, one traceEvent per
    // recorded event, Chrome/Perfetto-shaped
    let text = obs::chrome_trace(&events).to_string_compact();
    let doc = pnode::util::json::parse(&text).expect("chrome trace is valid JSON");
    assert_eq!(doc.get("displayTimeUnit").and_then(|v| v.as_str()), Some("ms"));
    let tes = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("traceEvents array");
    assert_eq!(tes.len(), events.len(), "one trace event per recorded event");
    for te in tes {
        let ph = te.get("ph").and_then(|v| v.as_str()).expect("ph");
        assert!(["B", "E", "C", "i"].contains(&ph), "unknown ph {ph:?}");
        assert_eq!(te.get("pid").and_then(|v| v.as_usize()), Some(1));
        assert!(te.get("tid").and_then(|v| v.as_usize()).is_some());
        assert!(te.get("name").and_then(|v| v.as_str()).is_some());
        assert!(te.get("ts").and_then(|v| v.as_f64()).is_some());
    }

    // the metrics fold sees the same phases
    let m = obs::Metrics::from_events(&events);
    assert!(m.span_count("forward") > 0);
    assert!(m.span_total_secs("forward") >= 0.0);

    obs::reset();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn merged_trace_is_deterministic_across_runs_and_worker_counts() {
    let _g = obs::test_guard();
    obs::disable();
    obs::reset();

    // NON-tiered on purpose: lease contention under the budget arbiter is
    // timing-dependent (grants depend on what is concurrently leased), so
    // the determinism contract covers every event source except it.
    let spec_at = |workers: usize| {
        SolverBuilder::new()
            .scheme_str("dopri5")
            .policy_str("binomial:3")
            .uniform(12)
            .workers(workers)
            .shard_rows(SHARD_ROWS)
            .build()
            .unwrap()
    };

    obs::enable();
    let _ = run_grad(&spec_at(1));
    let a = obs::take();
    let _ = run_grad(&spec_at(1));
    let b = obs::take();
    let _ = run_grad(&spec_at(3));
    let c = obs::take();
    obs::disable();

    assert!(!a.is_empty());
    let key = |ev: &[obs::Event]| -> Vec<(u32, u64, &str, EventKind)> {
        ev.iter().map(|e| (e.tid, e.seq, e.name, e.kind.clone())).collect()
    };
    assert_eq!(key(&a), key(&b), "identical runs merge to identical streams");
    assert_eq!(
        key(&a),
        key(&c),
        "worker count changes wall clock, never the merged stream"
    );

    obs::reset();
}

#[test]
fn gemm_mul_adds_total_is_worker_count_invariant_and_attributed_to_pool_tids() {
    let _g = obs::test_guard();
    obs::disable();
    obs::reset();

    // the counter is emitted per logical obs tid (main = 0, pool job
    // i+1), never inside the kernel's internal row-block threads — so
    // the summed total is exact work, independent of PNODE_WORKERS and
    // of the GEMM thread pool
    let spec_at = |workers: usize| {
        SolverBuilder::new()
            .scheme_str("dopri5")
            .policy_str("binomial:3")
            .uniform(12)
            .workers(workers)
            .shard_rows(SHARD_ROWS)
            .build()
            .unwrap()
    };

    obs::enable();
    let _ = run_grad(&spec_at(1));
    let serial = obs::take();
    let _ = run_grad(&spec_at(3));
    let pooled = obs::take();
    obs::disable();

    let total = |ev: &[obs::Event]| pnode::obs::Metrics::from_events(ev).counter("gemm.mul_adds");
    assert!(total(&serial) > 0.0, "the gradient multiplies matrices");
    assert_eq!(
        total(&serial),
        total(&pooled),
        "summed mul-adds are exact work, invariant to sharding"
    );
    // under the pool, shard-local GEMMs attribute to their job's logical
    // tid — the counter must not collapse onto the coordinator thread
    let pool_attributed = pooled.iter().any(|e| {
        e.tid > 0 && e.name == "gemm.mul_adds" && matches!(e.kind, EventKind::Counter(_))
    });
    assert!(pool_attributed, "pool workers emit their own mul-add counts");

    obs::reset();
}
