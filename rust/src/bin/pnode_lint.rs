//! `pnode-lint` — the crate's static-analysis gate (DESIGN.md §14).
//!
//! ```text
//! pnode-lint [REPO_ROOT]          lint rust/src + validate JSON artifacts
//! pnode-lint --rs FILE...         lint individual .rs files (fixture aid)
//! ```
//!
//! Exit status 0 when clean, 1 on any finding, 2 on I/O errors.  Each
//! finding prints as `rule: file:line: message`.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use pnode::analysis::{lint_source, lint_tree, validate_artifacts, Finding};

fn fail(msg: impl std::fmt::Display) -> ExitCode {
    eprintln!("pnode-lint: {msg}");
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut findings: Vec<Finding> = Vec::new();

    if args.first().map(String::as_str) == Some("--rs") {
        if args.len() < 2 {
            return fail("--rs needs at least one file");
        }
        for path in &args[1..] {
            let src = match std::fs::read_to_string(path) {
                Ok(s) => s,
                Err(e) => return fail(format!("{path}: {e}")),
            };
            // ad-hoc files are linted under a virtual `methods/` path so
            // every path-scoped rule (determinism included) applies
            let name = Path::new(path)
                .file_name()
                .map(|n| n.to_string_lossy().to_string())
                .unwrap_or_else(|| path.clone());
            findings.extend(lint_source(&format!("methods/{name}"), &src));
        }
    } else {
        if args.len() > 1 {
            return fail("usage: pnode-lint [REPO_ROOT] | pnode-lint --rs FILE...");
        }
        let root = PathBuf::from(args.first().map(String::as_str).unwrap_or("."));
        let src_root = root.join("rust/src");
        if !src_root.is_dir() {
            let msg = format!("{} is not a directory (run from the repo root)", src_root.display());
            return fail(msg);
        }
        match lint_tree(&src_root) {
            Ok(fs) => findings.extend(fs.into_iter().map(|mut f| {
                f.file = format!("rust/src/{}", f.file);
                f
            })),
            Err(e) => return fail(e),
        }
        match validate_artifacts(&root) {
            Ok(fs) => findings.extend(fs),
            Err(e) => return fail(e),
        }
    }

    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!("pnode-lint: clean");
        ExitCode::SUCCESS
    } else {
        println!("pnode-lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}
