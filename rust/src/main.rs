//! `pnode` — CLI entrypoint of the PNODE-RS framework.
//!
//! Subcommands:
//!   info                      — artifact/platform info
//!   gradcheck                 — XLA-vs-Rust cross-check on quick_d8
//!   train-clf [--method ...]  — classification training (spiral surrogate);
//!                               `--grid adaptive:1e-6` switches the ODE
//!                               blocks to PI-controlled Dopri5 stepping;
//!                               `--workers N` runs gradients on the
//!                               data-parallel execution engine (default:
//!                               PNODE_WORKERS or available parallelism —
//!                               bitwise identical for any N)
//!   train-stiff [--scheme cn] — stiff Robertson training
//!   bench <table2|prop2>      — analytic tables (full benches live in
//!                               `cargo bench` targets)

use anyhow::Result;

use pnode::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(),
        Some("gradcheck") => cmd_gradcheck(),
        Some("train-clf") => cmd_train_clf(&args),
        Some("train-stiff") => cmd_train_stiff(&args),
        Some("bench") => cmd_bench(&args),
        _ => {
            eprintln!(
                "usage: pnode <info|gradcheck|train-clf|train-stiff|bench> [options]\n\
                 see README.md for details"
            );
            Ok(())
        }
    }
}

fn cmd_info() -> Result<()> {
    let client = pnode::runtime::Client::cpu()?;
    println!("platform: {} ({} devices)", client.platform_name(), client.device_count());
    match pnode::runtime::Manifest::load_default() {
        Ok(m) => {
            println!("artifacts: {} configs in {:?}", m.configs.len(), m.dir);
            for (name, cfg) in &m.configs {
                println!(
                    "  {name}: kind={} dims={:?} act={} batch={} params={}",
                    cfg.kind, cfg.dims, cfg.act, cfg.batch, cfg.param_count
                );
            }
        }
        Err(e) => println!("artifacts: not available ({e})"),
    }
    Ok(())
}

fn cmd_gradcheck() -> Result<()> {
    use pnode::nn::Act;
    use pnode::ode::rhs::OdeRhs;
    use pnode::util::rng::Rng;

    let client = pnode::runtime::Client::cpu()?;
    let manifest = pnode::runtime::Manifest::load_default()?;
    let arts = pnode::runtime::ModelArtifacts::load(&client, &manifest, "quick_d8")?;
    let entry = arts.entry.clone();
    let mut rng = Rng::new(7);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &entry.dims, 1.0);

    let xla = pnode::ode::XlaRhs::new(arts, theta.clone())?;
    let rust = pnode::ode::MlpRhs::new(
        entry.dims.clone(),
        Act::parse(&entry.act).unwrap(),
        entry.time_dep,
        entry.batch,
        theta,
    );

    let n = xla.state_len();
    let mut u = vec![0.0f32; n];
    rng.fill_normal(&mut u);
    let v = {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v);
        v
    };

    let mut fx = vec![0.0f32; n];
    let mut fr = vec![0.0f32; n];
    xla.f(0.3, &u, &mut fx);
    rust.f(0.3, &u, &mut fr);
    let f_err = pnode::testing::rel_l2(&fx, &fr);

    let mut gx = vec![0.0f32; n];
    let mut gr = vec![0.0f32; n];
    let mut gtx = vec![0.0f32; xla.param_len()];
    let mut gtr = vec![0.0f32; rust.param_len()];
    xla.vjp_both(0.3, &u, &v, &mut gx, &mut gtx);
    rust.vjp_both(0.3, &u, &v, &mut gr, &mut gtr);
    let vjp_err = pnode::testing::rel_l2(&gx, &gr);
    let vjpt_err = pnode::testing::rel_l2(&gtx, &gtr);

    println!("f      rel-l2: {f_err:.3e}");
    println!("vjp_u  rel-l2: {vjp_err:.3e}");
    println!("vjp_th rel-l2: {vjpt_err:.3e}");
    anyhow::ensure!(f_err < 1e-4 && vjp_err < 1e-4 && vjpt_err < 1e-4, "cross-check FAILED");
    println!("gradcheck OK: XLA artifacts match the pure-Rust mirror");
    Ok(())
}

fn cmd_train_clf(args: &Args) -> Result<()> {
    use pnode::data::spiral::SpiralDataset;
    use pnode::exec::ExecConfig;
    use pnode::methods::{method_by_name, parallel_method_by_name, BlockSpec};
    use pnode::nn::{Act, Optimizer};
    use pnode::ode::rhs::OdeRhs;
    use pnode::ode::tableau::Scheme;
    use pnode::tasks::ClassificationTask;
    use pnode::util::rng::Rng;

    let method_name = args.get_or("method", "pnode").to_string();
    let scheme = Scheme::parse(args.get_or("scheme", "dopri5")).expect("unknown scheme");
    let nt = args.get_usize("nt", 4);
    // --grid uniform | uniform:<nt> | adaptive:<atol>[:<rtol>[:<h0>]]
    let grid = pnode::ode::grid::TimeGrid::parse(args.get_or("grid", "uniform"), nt)
        .unwrap_or_else(|e| panic!("--grid: {e}"));
    let steps = args.get_usize("steps", 100);
    let n_blocks = args.get_usize("blocks", 4);
    let seed = args.get_u64("seed", 42);
    let use_xla = !args.flag("no-xla");
    // --workers: data-parallel execution engine size.  Purely a wall-clock
    // knob — sharding and reduction order are worker-count independent,
    // so gradients (and the whole training trajectory) are bitwise
    // identical for any N.
    let workers = args.get_usize("workers", pnode::exec::default_workers());
    let shard_rows = args.get_usize("shard-rows", pnode::exec::DEFAULT_SHARD_ROWS);
    let exec_cfg = ExecConfig { workers, shard_rows };
    pnode::tensor::gemm::set_gemm_workers(workers);
    // validate the method spec up front (the factory below asserts)
    method_by_name(&method_name).unwrap_or_else(|| panic!("unknown method {method_name:?}"));

    let mut rng = Rng::new(seed);
    const D: usize = 64;
    const B: usize = 128;
    let dims = vec![D + 1, 168, 168, D];
    let per_block = pnode::nn::param_count(&dims);
    let dims_init = dims.clone();

    let grid_name = grid.name();
    let mut task = ClassificationTask::new(
        &mut rng,
        n_blocks,
        BlockSpec { scheme, t0: 0.0, tf: 1.0, grid },
        per_block,
        D,
        10,
        move |r| pnode::nn::init::kaiming_uniform(r, &dims_init, 1.0),
        || parallel_method_by_name(&method_name, exec_cfg).expect("method validated above"),
    );
    println!(
        "classification: {} blocks x {} params = {} total (paper: 199,800), grid {}, \
         engine {} workers x {}-row shards (XLA RHS is not shardable: falls back to 1)",
        n_blocks,
        per_block,
        per_block * n_blocks,
        grid_name,
        workers,
        shard_rows
    );

    let mut rhs: Box<dyn OdeRhs> = if use_xla {
        let client = pnode::runtime::Client::cpu()?;
        let manifest = pnode::runtime::Manifest::load_default()?;
        let cfg = args.get_or("config", "clf_d64");
        let arts = pnode::runtime::ModelArtifacts::load(&client, &manifest, cfg)?;
        Box::new(pnode::ode::XlaRhs::new(arts, task.block_theta(0).to_vec())?)
    } else {
        Box::new(pnode::ode::MlpRhs::new(
            dims,
            Act::Relu,
            true,
            B,
            task.block_theta(0).to_vec(),
        ))
    };

    let ds = SpiralDataset::generate(&mut rng, 600, 10, D);
    let (train, test) = ds.split(0.9);
    let mut opt = pnode::nn::Adam::new(task.theta.len(), args.get_f64("lr", 1e-3));
    let mut log = pnode::train::TrainLog::new();
    let mut x = vec![0.0f32; B * D];
    let mut y = vec![0usize; B];

    for step in 0..steps {
        train.fill_batch(step * B, B, &mut x, &mut y);
        let res = task.grad_step(rhs.as_mut(), B, &x, &y, 0.05);
        let gn = pnode::train::grad_norm(&res.grad);
        task.apply_grad(&mut opt as &mut dyn Optimizer, &res.grad);
        log.push(
            step,
            res.loss,
            Some(res.accuracy),
            gn,
            res.report.nfe_forward,
            res.report.nfe_backward,
        );
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {step:4}  loss {:.4}  acc {:.3}  |g| {:.2e}  nfe {}/{}  steps {}+{}rej  \
                 {:.0} samp/s",
                res.loss,
                res.accuracy,
                gn,
                res.report.nfe_forward,
                res.report.nfe_backward,
                res.report.n_accepted,
                res.report.n_rejected,
                res.report.exec.samples_per_sec
            );
        }
    }
    let mut xt = vec![0.0f32; B * D];
    let mut yt = vec![0usize; B];
    test.fill_batch(0, B, &mut xt, &mut yt);
    let (tl, ta) = task.evaluate(rhs.as_mut(), B, &xt, &yt);
    println!("test: loss {tl:.4} acc {ta:.3}");
    if let Some(out) = args.get("log-out") {
        std::fs::write(out, log.to_csv())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_train_stiff(args: &Args) -> Result<()> {
    use pnode::data::robertson::RobertsonData;
    use pnode::nn::{Act, Optimizer};
    use pnode::ode::implicit::ThetaScheme;
    use pnode::ode::rhs::OdeRhs;
    use pnode::tasks::StiffTask;
    use pnode::util::rng::Rng;

    let epochs = args.get_usize("epochs", 300);
    let scheme = args.get_or("scheme", "cn").to_string();
    let scaled = !args.flag("raw");
    let use_xla = !args.flag("no-xla");
    let seed = args.get_u64("seed", 3);

    let data = RobertsonData::generate(40, 8, scaled);
    let task = StiffTask::new(data, args.get_usize("substeps", 2));

    // small init: the untrained field must stay bounded over [1e-5, 100]
    let dims = vec![3, 50, 50, 50, 50, 50, 3];
    let mut rng = Rng::new(seed);
    let theta0 = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 0.1);
    let mut rhs: Box<dyn OdeRhs> = if use_xla {
        let client = pnode::runtime::Client::cpu()?;
        let manifest = pnode::runtime::Manifest::load_default()?;
        let arts = pnode::runtime::ModelArtifacts::load(&client, &manifest, "stiff_d3")?;
        Box::new(pnode::ode::XlaRhs::new(arts, theta0.clone())?)
    } else {
        Box::new(pnode::ode::MlpRhs::new(dims, Act::Gelu, false, 1, theta0.clone()))
    };

    let mut opt = pnode::nn::AdamW::new(rhs.param_len(), args.get_f64("lr", 5e-3), 1e-4);
    let mut theta = theta0;
    let mut stats = pnode::train::GradStats::default();
    for epoch in 0..epochs {
        let step = if scheme == "dopri5" {
            task.grad_explicit_adaptive(rhs.as_ref(), 1e-6)
        } else {
            let s = if scheme == "beuler" {
                ThetaScheme::backward_euler()
            } else {
                ThetaScheme::crank_nicolson()
            };
            task.grad_implicit(rhs.as_ref(), s)
        };
        let gn = pnode::train::grad_norm(&step.grad);
        stats.observe(gn, 1e6);
        let mut grad = step.grad;
        pnode::train::clip_grad_norm(&mut grad, 100.0);
        opt.step(&mut theta, &grad);
        rhs.set_params(&theta);
        if epoch % 20 == 0 || epoch + 1 == epochs {
            println!(
                "epoch {epoch:4}  MAE {:.5}  |g| {:.2e}  nfe {}/{}  steps {}+{}rej{}",
                step.loss,
                gn,
                step.nfe_forward,
                step.nfe_backward,
                step.n_accepted,
                step.n_rejected,
                if stats.exploded { "  [EXPLODED]" } else { "" }
            );
        }
    }
    println!("max |g| over run: {:.3e}  exploded: {}", stats.max_norm, stats.exploded);
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("prop2") => {
            let mut t = pnode::bench::Table::new(
                "Prop. 2 — minimal recomputations p̃(N_t, N_c) [formula | DP-optimal]",
                &["N_t", "N_c=1", "N_c=2", "N_c=3", "N_c=5", "N_c=8"],
            );
            let mut planner = pnode::checkpoint::BinomialPlanner::new();
            for nt in [5usize, 10, 20, 40, 80] {
                let mut cells = vec![nt.to_string()];
                for nc in [1usize, 2, 3, 5, 8] {
                    let f = pnode::checkpoint::prop2_extra_steps(nt, nc).unwrap();
                    let d = planner.optimal_cost(nt, nc);
                    cells.push(format!("{f} | {d}"));
                }
                t.row(cells);
            }
            t.print();
        }
        Some("table2") => {
            let mm = pnode::methods::MemModel {
                act_bytes: 128 * (65 + 168 + 168 + 168 + 168 + 64) * 4,
                state_bytes: 128 * 64 * 4,
                param_bytes: 50_296 * 4,
                n_stages: 6,
                nt: 10,
                nb: 4,
            };
            let mut t = pnode::bench::Table::new(
                "Table 2 — modeled memory (clf_d64, Dopri5, N_t=10, N_b=4)",
                &["method", "model GB", "reverse-accurate", "implicit"],
            );
            for (name, ra, imp) in [
                ("cont", "x", "x"),
                ("naive", "yes", "x"),
                ("anode", "yes", "x"),
                ("aca", "yes", "x"),
                ("pnode", "yes", "yes"),
                ("pnode2", "yes", "yes"),
            ] {
                let bytes = mm.by_method(name).unwrap();
                t.row(vec![
                    name.into(),
                    format!("{:.3}", pnode::methods::MemModel::gb(bytes)),
                    ra.into(),
                    imp.into(),
                ]);
            }
            t.print();
        }
        _ => eprintln!("usage: pnode bench <prop2|table2>  (full sweeps: cargo bench)"),
    }
    Ok(())
}
