//! `pnode` — CLI entrypoint of the PNODE-RS framework.
//!
//! Subcommands:
//!   run --spec <file.json>    — execute a serialized RunSpec (the typed
//!                               facade artifact; see DESIGN.md §9 and
//!                               examples/specs/); an optional "task"
//!                               block in the same file picks what the
//!                               spec drives (gradient | classification |
//!                               cnf), and the spec's "arch" block picks
//!                               the dynamics architecture (DESIGN.md §10).
//!                               `--trace out.trace.json` records the run
//!                               and writes a Chrome trace-event file
//!                               (load it in Perfetto / chrome://tracing);
//!                               `--metrics` prints the folded metrics
//!                               (human table; `--metrics json` emits one
//!                               machine-readable JSON object as the last
//!                               stdout line — DESIGN.md §11/§13).
//!                               Observed runs append one record to the
//!                               persistent ledger (`.pnode/ledger/`)
//!   serve --spec <file.json>  — fixed-duration inference load loop on the
//!                               forward-only session pool (DESIGN.md §15):
//!                               the optional "serve" block in the spec file
//!                               sizes the fleet/batching/clients; `--json`
//!                               emits the final ServeReport as the last
//!                               stdout line; observed runs (`--metrics` or
//!                               an "obs" block) append a serve-mode ledger
//!                               record that `pnode report` renders with
//!                               requests/sec + latency columns
//!   report                    — per-phase wall times of the last ledger
//!                               run vs. the ledger baseline medians,
//!                               with regression flags (DESIGN.md §13);
//!                               `--ledger <dir>`, `--threshold <frac>`
//!   advise --spec <file.json> — enumerate the auto-policy candidates for
//!                               the spec with predicted bytes/secs and
//!                               print the winner, without running
//!                               (`--budget <bytes>` for non-auto specs)
//!   info                      — artifact/platform info
//!   gradcheck                 — XLA-vs-Rust cross-check on quick_d8
//!   train-clf [--method ...]  — classification training (spiral surrogate);
//!                               `--arch concatsquash:64:tanh` or any other
//!                               ArchSpec picks the block dynamics, and
//!                               `--augment K` wraps it in ANODE zero
//!                               channels (needs --no-xla);
//!                               `--grid adaptive:1e-6` switches the ODE
//!                               blocks to PI-controlled Dopri5 stepping;
//!                               `--workers N` runs gradients on the
//!                               data-parallel execution engine (default:
//!                               PNODE_WORKERS or available parallelism —
//!                               bitwise identical for any N)
//!   train-stiff [--scheme cn] — stiff Robertson training
//!   bench <table2|prop2>      — analytic tables (full benches live in
//!                               `cargo bench` targets)
//!
//! Every gradient run is constructed through the `SolverBuilder` →
//! `RunSpec` → `Session` facade; invalid configurations fail up front
//! with the underlying parse/validation message.

use anyhow::Result;

use pnode::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("report") => cmd_report(&args),
        Some("advise") => cmd_advise(&args),
        Some("info") => cmd_info(),
        Some("gradcheck") => cmd_gradcheck(),
        Some("train-clf") => cmd_train_clf(&args),
        Some("train-stiff") => cmd_train_stiff(&args),
        Some("bench") => cmd_bench(&args),
        _ => {
            eprintln!(
                "usage: pnode <run|serve|report|advise|info|gradcheck|train-clf|train-stiff|bench> \
                 [options]\n\
                 see README.md for details"
            );
            Ok(())
        }
    }
}

/// Execute a serialized `RunSpec`.  The file is the spec document itself
/// (see `RunSpec::to_json`); an optional extra `"task"` object selects
/// the workload:
///
/// ```text
/// "task": {"kind": "gradient", "dim": 16, "hidden": 32, "batch": 8, "seed": 7}
/// "task": {"kind": "classification", "steps": 20, "blocks": 2, "dim": 16,
///          "hidden": 32, "classes": 4, "batch": 64, "seed": 7, "lr": 3e-3}
/// "task": {"kind": "cnf", "steps": 10, "blocks": 1, "dim": 3, "hidden": 16,
///          "batch": 32, "seed": 7, "lr": 2e-2}
/// ```
///
/// The spec's own `"arch"` block (an `ArchSpec`) picks the dynamics
/// architecture; without one each task falls back to its legacy default
/// (`concat` MLP for gradient/classification, `concatsquash` for cnf).
fn cmd_run(args: &Args) -> Result<()> {
    use pnode::api::RunSpec;
    use pnode::util::json;

    let path = args
        .get("spec")
        .ok_or_else(|| anyhow::anyhow!("run needs --spec <file.json> (see examples/specs/)"))?;
    let text = std::fs::read_to_string(path)?;
    let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let spec = RunSpec::from_json(&doc).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    println!("spec ({path}):\n{}", spec.to_json().to_string_pretty());

    // --trace / --metrics (or an "obs" block in the spec itself) switch
    // on the process-global recording sink before the run starts
    let trace_path = args.get("trace").map(|s| s.to_string());
    // `--metrics` prints the human table; `--metrics json` emits the
    // fold as one compact JSON object, guaranteed to be the last stdout
    // line (so `... | tail -n 1` is machine-readable)
    let metrics_json = match (args.get("metrics"), args.flag("metrics")) {
        (Some("json"), _) => Some(true),
        (Some("human"), _) | (None, true) => Some(false),
        (Some(m), _) => {
            return Err(anyhow::anyhow!("--metrics takes human | json (got {m:?})"))
        }
        (None, false) => None,
    };
    if trace_path.is_some() || metrics_json.is_some() || spec.obs.map_or(false, |o| o.enabled) {
        pnode::obs::enable();
    }

    // the "task" block is fully ours, so hold it to the spec's standard:
    // unknown keys are typos, and present-but-mistyped values are errors,
    // never silent defaults — the saved row must reproduce the document
    let task = doc.get("task");
    if let Some(t) = task {
        const KNOWN: &[&str] = &[
            "kind", "steps", "blocks", "dim", "hidden", "classes", "batch", "seed", "lr",
        ];
        let obj = t
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("{path}: \"task\" must be an object"))?;
        for (k, _) in obj {
            anyhow::ensure!(
                KNOWN.contains(&k.as_str()),
                "{path}: unknown task key {k:?} (known: {KNOWN:?})"
            );
        }
    }
    let get_usize = |key: &str, default: usize| -> Result<usize> {
        match task.and_then(|t| t.get(key)) {
            None => Ok(default),
            Some(v) => v.as_usize().ok_or_else(|| {
                anyhow::anyhow!("{path}: task field {key:?} must be a number (got {v:?})")
            }),
        }
    };
    let get_f64 = |key: &str, default: f64| -> Result<f64> {
        match task.and_then(|t| t.get(key)) {
            None => Ok(default),
            Some(v) => v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("{path}: task field {key:?} must be a number (got {v:?})")
            }),
        }
    };
    let kind = match task.and_then(|t| t.get("kind")) {
        None => "gradient",
        Some(k) => k.as_str().ok_or_else(|| {
            anyhow::anyhow!("{path}: task field \"kind\" must be a string (got {k:?})")
        })?,
    };
    let (events, row) = match kind {
        "gradient" => run_spec_gradient(
            &spec,
            get_usize("dim", 16)?,
            get_usize("hidden", 32)?,
            get_usize("batch", 8)?,
            get_usize("seed", 7)? as u64,
        )?,
        "classification" => run_spec_classification(
            &spec,
            get_usize("steps", 20)?,
            get_usize("blocks", 2)?,
            get_usize("dim", 16)?,
            get_usize("hidden", 32)?,
            get_usize("classes", 4)?,
            get_usize("batch", 64)?,
            get_usize("seed", 7)? as u64,
            get_f64("lr", 3e-3)?,
        )?,
        "cnf" => run_spec_cnf(
            &spec,
            get_usize("steps", 10)?,
            get_usize("blocks", 1)?,
            get_usize("dim", 3)?,
            get_usize("hidden", 16)?,
            get_usize("batch", 32)?,
            get_usize("seed", 7)? as u64,
            get_f64("lr", 2e-2)?,
        )?,
        k => {
            return Err(anyhow::anyhow!(
                "{path}: unknown task kind {k:?} (want gradient | classification | cnf)"
            ))
        }
    };

    // solver warnings land in the trace, not on stderr: surface them here
    for e in events.iter().filter(|e| e.name.starts_with("warn.")) {
        match &e.detail {
            Some(d) => println!("warn [{}]: {d}", e.name),
            None => println!("warn [{}]", e.name),
        }
    }
    if let Some(tp) = &trace_path {
        let trace = pnode::obs::chrome_trace(&events);
        std::fs::write(tp, trace.to_string_compact())?;
        println!("chrome trace ({} events) written to {tp}", events.len());
    }
    // every observed run lands in the persistent ledger: `pnode report`
    // folds over it, and the auto-policy cost model calibrates from it
    if !events.is_empty() {
        if let Some(row) = &row {
            let metrics = pnode::obs::Metrics::from_events(&events);
            let rec = pnode::obs::RunRecord {
                build: pnode::obs::build_tag(),
                spec: spec.to_json(),
                row: row.to_json(),
                metrics: metrics.to_json(),
                memcheck: (row.mem_pred_ckpt_bytes > 0 || row.mem_obs_ckpt_bytes > 0).then(
                    || pnode::obs::memcheck(row.mem_pred_ckpt_bytes, row.mem_obs_ckpt_bytes),
                ),
            };
            match pnode::obs::Ledger::open_default() {
                Ok(ledger) => match ledger.append(&rec) {
                    Ok(()) => println!(
                        "ledger: run (build {}) appended to {:?}",
                        rec.build,
                        ledger.path()
                    ),
                    Err(e) => println!("warn [ledger]: {e}"),
                },
                Err(e) => println!("warn [ledger]: {e}"),
            }
        }
    }
    if let Some(as_json) = metrics_json {
        let m = pnode::obs::Metrics::from_events(&events);
        if as_json {
            println!("{}", m.to_json().to_string_compact());
        } else {
            println!("metrics:\n{}", m.to_json().to_string_pretty());
        }
    }
    Ok(())
}

/// Fixed-duration inference load loop on the serve pool (DESIGN.md §15).
/// The spec file is a plain `RunSpec` document plus an optional `"serve"`
/// object:
///
/// ```text
/// "serve": {"sessions": 2, "max_batch": 16, "max_delay_ms": 2,
///           "duration_secs": 2, "clients": 32, "dim": 16, "hidden": 32,
///           "seed": 7, "pool_mb": 0}
/// ```
///
/// `clients` closed-loop producers each keep one request in flight; the
/// pool coalesces across them.  `--duration <secs>` overrides the file;
/// `--json` prints the final `ServeReport` as the last stdout line.
fn cmd_serve(args: &Args) -> Result<()> {
    use pnode::api::RunSpec;
    use pnode::ode::rhs::OdeRhs;
    use pnode::serve::{ServeConfig, ServePool};
    use pnode::util::json;
    use pnode::util::rng::Rng;

    let path = args.get("spec").ok_or_else(|| {
        anyhow::anyhow!("serve needs --spec <file.json> (see examples/specs/serve_clf.json)")
    })?;
    let text = std::fs::read_to_string(path)?;
    let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let spec = RunSpec::from_json(&doc).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let as_json = args.flag("json");
    if !as_json {
        println!("spec ({path}):\n{}", spec.to_json().to_string_pretty());
    }

    // the "serve" block is held to the same standard as `run`'s "task"
    // block: unknown keys are typos, mistyped values are errors
    let serve = doc.get("serve");
    if let Some(s) = serve {
        const KNOWN: &[&str] = &[
            "sessions", "max_batch", "max_delay_ms", "duration_secs", "clients", "dim", "hidden",
            "seed", "pool_mb",
        ];
        let obj = s
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("{path}: \"serve\" must be an object"))?;
        for (k, _) in obj {
            anyhow::ensure!(
                KNOWN.contains(&k.as_str()),
                "{path}: unknown serve key {k:?} (known: {KNOWN:?})"
            );
        }
    }
    let get_usize = |key: &str, default: usize| -> Result<usize> {
        match serve.and_then(|s| s.get(key)) {
            None => Ok(default),
            Some(v) => v.as_usize().ok_or_else(|| {
                anyhow::anyhow!("{path}: serve field {key:?} must be a number (got {v:?})")
            }),
        }
    };
    let get_f64 = |key: &str, default: f64| -> Result<f64> {
        match serve.and_then(|s| s.get(key)) {
            None => Ok(default),
            Some(v) => v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("{path}: serve field {key:?} must be a number (got {v:?})")
            }),
        }
    };
    let sessions = get_usize("sessions", 2)?;
    let max_batch = get_usize("max_batch", 16)?;
    let max_delay_ms = get_f64("max_delay_ms", 2.0)?;
    let duration_secs = args.get_f64("duration", get_f64("duration_secs", 2.0)?);
    let clients = get_usize("clients", sessions * max_batch)?;
    let dim = get_usize("dim", 16)?;
    let hidden = get_usize("hidden", 32)?;
    let seed = get_usize("seed", 7)? as u64;
    let pool_mb = get_f64("pool_mb", 0.0)?;
    anyhow::ensure!(clients >= 1, "{path}: serve needs clients >= 1");
    anyhow::ensure!(
        duration_secs.is_finite() && duration_secs > 0.0,
        "{path}: serve needs a positive duration (got {duration_secs})"
    );

    if args.get("metrics").is_some()
        || args.flag("metrics")
        || spec.obs.map_or(false, |o| o.enabled)
    {
        pnode::obs::enable();
    }

    let arch = spec.arch.clone().unwrap_or(pnode::api::ArchSpec::ConcatMlp {
        hidden: vec![hidden],
        act: pnode::nn::Act::Relu,
    });
    let mut rng = Rng::new(seed);
    let theta = arch.init(&mut rng, dim);
    let cfg = ServeConfig {
        sessions,
        max_batch,
        max_delay_secs: max_delay_ms * 1e-3,
        session_bytes: 0,
        pool_bytes: (pool_mb * (1u64 << 20) as f64) as u64,
    };
    let arch_rhs = arch.clone();
    let theta_rhs = theta.clone();
    let pool = ServePool::new(&spec, dim, cfg, move |rows| {
        Box::new(pnode::ode::ModuleRhs::from_arch(&arch_rhs, dim, rows, theta_rhs.clone()))
            as Box<dyn OdeRhs + Send>
    })
    .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    if !as_json {
        println!(
            "serving: arch {} dim {dim} | {sessions} session(s) x batch {max_batch} \
             (deadline {max_delay_ms} ms), {clients} closed-loop client(s), {duration_secs:.1}s",
            arch.name()
        );
    }

    let sw = pnode::obs::stopwatch();
    let served: u64 = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|cid| {
                let pool = &pool;
                let sw = &sw;
                scope.spawn(move || {
                    let mut rng = Rng::new(seed ^ (0x9e3779b97f4a7c15 + cid as u64));
                    let mut u0 = vec![0.0f32; dim];
                    let mut n = 0u64;
                    while sw.elapsed_secs() < duration_secs {
                        rng.fill_normal(&mut u0);
                        match pool.submit(u0.clone()) {
                            Ok(t) => {
                                let _ = t.wait();
                                n += 1;
                            }
                            Err(_) => break,
                        }
                    }
                    n
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or(0)).sum()
    });
    let wall = sw.elapsed_secs();
    let report = pool.shutdown();
    anyhow::ensure!(
        report.requests == served,
        "serve accounting drift: pool served {} vs clients counted {served}",
        report.requests
    );

    let row =
        pnode::coordinator::ExperimentRow::from_serve_report("serve", "load_loop", &spec, &report, wall);
    let events = take_obs_events();
    if !events.is_empty() {
        let metrics = pnode::obs::Metrics::from_events(&events);
        let rec = pnode::obs::RunRecord {
            build: pnode::obs::build_tag(),
            spec: spec.to_json(),
            row: row.to_json(),
            metrics: metrics.to_json(),
            memcheck: None,
        };
        match pnode::obs::Ledger::open_default() {
            Ok(ledger) => match ledger.append(&rec) {
                Ok(()) => {
                    if !as_json {
                        println!(
                            "ledger: serve run (build {}) appended to {:?}",
                            rec.build,
                            ledger.path()
                        );
                    }
                }
                Err(e) => eprintln!("warn [ledger]: {e}"),
            },
            Err(e) => eprintln!("warn [ledger]: {e}"),
        }
    }
    if as_json {
        println!("{}", report.to_json().to_string_compact());
    } else {
        println!(
            "served {} request(s) in {wall:.2}s: {:.1} req/s, p50 {:.3} ms, p99 {:.3} ms, \
             {:.1} rows/sweep over {} sweep(s), lease waits {}",
            report.requests,
            report.requests_per_sec,
            report.p50_secs * 1e3,
            report.p99_secs * 1e3,
            report.mean_batch_rows,
            report.batches,
            report.exec.lease_waits
        );
    }
    Ok(())
}

/// Per-phase wall times of the last ledger run vs. the baseline medians
/// over earlier runs of the same method+scheme, with regression flags
/// (DESIGN.md §13).  Warn-only: drift prints `REGRESSED` but the command
/// still exits 0, so CI gates stay a deliberate choice.
fn cmd_report(args: &Args) -> Result<()> {
    use pnode::obs::calibrate::REGRESSION_THRESHOLD;
    use pnode::obs::Ledger;
    use pnode::util::json::Json;

    let ledger = match args.get("ledger") {
        Some(dir) => Ledger::open(dir),
        None => Ledger::open_default(),
    }
    .map_err(|e| anyhow::anyhow!(e))?;
    let records = ledger.read_all().map_err(|e| anyhow::anyhow!(e))?;
    let Some(last) = records.last() else {
        println!(
            "ledger {:?} is empty — run `pnode run --spec <file.json>` with an \
             \"obs\" block (or --metrics) first",
            ledger.path()
        );
        return Ok(());
    };
    let threshold = args.get_f64("threshold", REGRESSION_THRESHOLD);
    let ident = |r: &pnode::obs::RunRecord| -> (String, String) {
        let s = |key: &str| {
            r.spec
                .get(key)
                .and_then(Json::as_str)
                .unwrap_or("?")
                .to_string()
        };
        (s("method"), s("scheme"))
    };
    let (method, scheme) = ident(last);
    println!(
        "last run: build {}  method {}  scheme {}  ({} ledger record(s))",
        last.build,
        method,
        scheme,
        records.len()
    );
    let row_str = |rec: &pnode::obs::RunRecord, key: &str| -> Option<String> {
        rec.row.get(key).and_then(Json::as_str).map(str::to_string)
    };
    if let (Some(req), Some(res)) =
        (row_str(last, "policy_requested"), row_str(last, "policy_resolved"))
    {
        println!("policy: {req} -> {res}");
    }
    if let Some(mc) = &last.memcheck {
        println!("memcheck: {}", mc.to_string_compact());
    }

    // serve-mode records (from `pnode serve`) carry throughput/latency
    // columns instead of adjoint phases: render those against the
    // comparable earlier serve runs and stop — the phase table below
    // would be empty for a forward-only run
    let row_f64 = |rec: &pnode::obs::RunRecord, key: &str| -> Option<f64> {
        rec.row.get(key).and_then(Json::as_f64)
    };
    if let Some(rps) = row_f64(last, "requests_per_sec") {
        let prior_serve: Vec<&pnode::obs::RunRecord> = records[..records.len() - 1]
            .iter()
            .filter(|r| ident(r) == (method.clone(), scheme.clone()))
            .filter(|r| row_f64(r, "requests_per_sec").is_some())
            .collect();
        let median = |mut v: Vec<f64>| -> Option<f64> {
            v.sort_by(|a, b| a.partial_cmp(b).expect("serve metrics are finite"));
            (!v.is_empty()).then(|| v[v.len() / 2])
        };
        let mut table = pnode::bench::Table::new(
            "serve throughput/latency vs ledger baseline",
            &["metric", "last", "baseline", "delta", "flag"],
        );
        let mut regressions = 0usize;
        // throughput regresses downward, latency regresses upward
        for (key, label, scale, higher_better, last_v) in [
            ("requests_per_sec", "requests/sec", 1.0, true, Some(rps)),
            ("latency_p50_secs", "p50 (ms)", 1e3, false, row_f64(last, "latency_p50_secs")),
            ("latency_p99_secs", "p99 (ms)", 1e3, false, row_f64(last, "latency_p99_secs")),
        ] {
            let Some(l) = last_v else { continue };
            let base = median(prior_serve.iter().filter_map(|r| row_f64(r, key)).collect());
            let (base_cell, delta_cell, flag) = match base {
                None => ("-".to_string(), "-".to_string(), ""),
                Some(b) if b > 0.0 => {
                    let delta = (l - b) / b;
                    let regressed =
                        if higher_better { delta < -threshold } else { delta > threshold };
                    let flag = if regressed {
                        regressions += 1;
                        "REGRESSED"
                    } else {
                        ""
                    };
                    (format!("{:.3}", b * scale), format!("{:+.1}%", delta * 100.0), flag)
                }
                Some(b) => (format!("{:.3}", b * scale), "-".to_string(), ""),
            };
            table.row(vec![
                label.to_string(),
                format!("{:.3}", l * scale),
                base_cell,
                delta_cell,
                flag.to_string(),
            ]);
        }
        table.print();
        println!(
            "baseline: median over {} comparable earlier serve run(s); regression threshold \
             {:.0}%{}",
            prior_serve.len(),
            threshold * 100.0,
            if regressions > 0 {
                format!("; {regressions} metric(s) REGRESSED")
            } else {
                String::new()
            }
        );
        return Ok(());
    }

    // baseline: per-phase medians over the *earlier* runs with the same
    // method+scheme identity (the comparable population)
    let prior: Vec<&pnode::obs::RunRecord> = records[..records.len() - 1]
        .iter()
        .filter(|r| ident(r) == (method.clone(), scheme.clone()))
        .collect();
    let span_secs = |rec: &pnode::obs::RunRecord, phase: &str| -> Option<f64> {
        rec.metrics
            .get("spans")?
            .get(phase)?
            .get("total_secs")?
            .as_f64()
    };
    let mut table = pnode::bench::Table::new(
        "per-phase wall time vs ledger baseline",
        &["phase", "last (s)", "baseline (s)", "delta", "flag"],
    );
    let mut regressions = 0usize;
    for phase in pnode::obs::PHASES {
        let last_secs = span_secs(last, phase);
        let mut samples: Vec<f64> = prior.iter().filter_map(|r| span_secs(r, phase)).collect();
        samples.sort_by(|a, b| a.partial_cmp(b).expect("span seconds are finite"));
        let baseline = (!samples.is_empty()).then(|| samples[samples.len() / 2]);
        let (Some(l), base) = (last_secs, baseline) else {
            continue;
        };
        let (base_cell, delta_cell, flag) = match base {
            None => ("-".to_string(), "-".to_string(), ""),
            Some(b) if b > 0.0 => {
                let delta = (l - b) / b;
                let flag = if delta > threshold {
                    regressions += 1;
                    "REGRESSED"
                } else {
                    ""
                };
                (format!("{b:.6}"), format!("{:+.1}%", delta * 100.0), flag)
            }
            Some(b) => (format!("{b:.6}"), "-".to_string(), ""),
        };
        table.row(vec![
            phase.to_string(),
            format!("{l:.6}"),
            base_cell,
            delta_cell,
            flag.to_string(),
        ]);
    }
    table.print();
    println!(
        "baseline: median over {} comparable earlier run(s); regression threshold +{:.0}%{}",
        prior.len(),
        threshold * 100.0,
        if regressions > 0 {
            format!("; {regressions} phase(s) REGRESSED")
        } else {
            String::new()
        }
    );
    Ok(())
}

/// Enumerate the auto-policy candidates for a spec with predicted peak
/// hot bytes and wall seconds, and print the winner — without running
/// the spec (DESIGN.md §13).
fn cmd_advise(args: &Args) -> Result<()> {
    use pnode::api::RunSpec;
    use pnode::checkpoint::{CheckpointPolicy, MemoryBudget};
    use pnode::obs::calibrate::{CostModel, ResolveCtx};
    use pnode::util::json;

    let path = args
        .get("spec")
        .ok_or_else(|| anyhow::anyhow!("advise needs --spec <file.json> (see examples/specs/)"))?;
    let text = std::fs::read_to_string(path)?;
    let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let spec = RunSpec::from_json(&doc).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let budget = match spec.method.pnode_policy() {
        Some(CheckpointPolicy::Auto { budget_bytes }) => *budget_bytes,
        _ => match args.get("budget") {
            Some(b) => MemoryBudget::parse(b).map_err(|e| anyhow::anyhow!(e))?.bytes,
            None => {
                return Err(anyhow::anyhow!(
                    "{path}: method {:?} has no auto budget — use a `pnode:auto:<bytes>` \
                     policy or pass --budget <bytes>",
                    spec.method.name()
                ))
            }
        },
    };
    let model = CostModel::from_default_ledger();
    println!(
        "cost model: {} ledger sample(s){}",
        model.samples,
        if model.samples == 0 { " — documented priors (DESIGN.md §13)" } else { "" }
    );
    let ctx = ResolveCtx::for_spec(&spec, &model);
    println!(
        "resolve ctx: nt {}  n_stages {}  budget {}",
        ctx.nt,
        ctx.n_stages,
        pnode::util::human_bytes(budget)
    );
    let winner = model
        .resolve(budget, &ctx)
        .map_err(|e| anyhow::anyhow!(e))?;
    let mut table = pnode::bench::Table::new(
        "auto-policy candidates",
        &["policy", "pred peak hot", "pred secs", "fits", "pick"],
    );
    for c in model.candidates(budget, &ctx) {
        table.row(vec![
            c.policy.name(),
            pnode::util::human_bytes(c.pred_peak_hot_bytes),
            format!("{:.6}", c.pred_secs),
            if c.fits { "yes" } else { "OVER BUDGET" }.to_string(),
            if c.policy == winner { "<== winner" } else { "" }.to_string(),
        ]);
    }
    table.print();
    println!("advise: {} (budget {})", winner.name(), pnode::util::human_bytes(budget));
    Ok(())
}

/// Drain the obs sink when recording is on (the per-task tail call; an
/// un-observed run returns no events without touching the sink).
fn take_obs_events() -> Vec<pnode::obs::Event> {
    if pnode::obs::enabled() {
        pnode::obs::take()
    } else {
        Vec::new()
    }
}

/// One gradient of L = Σ u(T) on a synthetic MLP RHS — the zero-to-aha
/// path for a spec file: run it, print the report, persist the row.
/// Observed runs fold their metrics into the saved row (per-phase wall
/// times, predicted-vs-observed checkpoint memory) and return the raw
/// events for the caller's trace export.
fn run_spec_gradient(
    spec: &pnode::api::RunSpec,
    dim: usize,
    hidden: usize,
    batch: usize,
    seed: u64,
) -> Result<(Vec<pnode::obs::Event>, Option<pnode::coordinator::ExperimentRow>)> {
    use pnode::api::ArchSpec;
    use pnode::nn::Act;
    use pnode::ode::ModuleRhs;
    use pnode::ode::rhs::OdeRhs;
    use pnode::util::rng::Rng;

    if let Some(cfg) = spec.exec {
        pnode::tensor::gemm::set_gemm_workers(cfg.workers);
    }
    let arch = spec
        .arch
        .clone()
        .unwrap_or(ArchSpec::ConcatMlp { hidden: vec![hidden], act: Act::Tanh });
    println!("arch: {}", arch.name());
    let mut rng = Rng::new(seed);
    let theta = arch.init(&mut rng, dim);
    let rhs = ModuleRhs::from_arch(&arch, dim, batch, theta);
    let mut u0 = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut u0);
    let lambda = vec![1.0f32; rhs.state_len()];

    let mut session = pnode::api::Session::new(spec.clone())
        .map_err(|e| anyhow::anyhow!("invalid spec: {e}"))?;
    if let Some(policy) = session.resolved_policy() {
        println!("auto policy resolved to {}", policy.name());
    }
    let mut runner = pnode::coordinator::Runner::new("run_spec");
    let row = runner.run_spec_job("synthetic_mlp", spec, 0, || {
        let out = session.grad(&rhs, &u0, &lambda);
        out.report
    });
    println!(
        "gradient: NFE {}/{}  steps {}+{}rej  ckpt {}  spills {}  workers {}  {:.3}s",
        row.nfe_forward,
        row.nfe_backward,
        row.n_accepted,
        row.n_rejected,
        pnode::util::human_bytes(row.measured_ckpt_bytes),
        row.spill_count,
        row.workers,
        row.time_secs
    );
    let n_accepted = row.n_accepted;
    println!("|dL/dθ| = {:.4}", pnode::tensor::nrm2(session.grad_theta()));

    let events = take_obs_events();
    if !events.is_empty() {
        // validate the paper's Table-2 memory model against this run:
        // predict the checkpoint-storage term from the executed step
        // count, compare against the live peak the obs gauges saw
        let metrics = pnode::obs::Metrics::from_events(&events);
        let n_stages = if spec.scheme.is_implicit() {
            1
        } else {
            spec.scheme.tableau().s as u64
        };
        let mm = pnode::methods::MemModel::for_rhs(&rhs, n_stages, n_accepted, 1);
        // predict with the *resolved* method so an auto spec is checked
        // against the policy that actually ran
        let predicted = mm.ckpt_bytes_for(&session.resolved_spec().method);
        let row = runner.rows.last_mut().expect("row just pushed");
        row.attach_obs(&metrics, predicted);
        println!(
            "memcheck: {}",
            pnode::obs::memcheck(row.mem_pred_ckpt_bytes, row.mem_obs_ckpt_bytes)
                .to_string_compact()
        );
        for (phase, secs) in &row.phase_secs {
            println!("  phase {phase:10} {secs:.6}s");
        }
    }
    let path = runner.save()?;
    println!("row (with embedded run_spec) saved to {path:?}");
    let row = runner.rows.pop();
    Ok((events, row))
}

/// Spiral-classification training driven entirely by the spec (the CI
/// smoke workload; pure-Rust RHS, no artifacts needed).
#[allow(clippy::too_many_arguments)]
fn run_spec_classification(
    spec: &pnode::api::RunSpec,
    steps: usize,
    blocks: usize,
    dim: usize,
    hidden: usize,
    classes: usize,
    batch: usize,
    seed: u64,
    lr: f64,
) -> Result<(Vec<pnode::obs::Event>, Option<pnode::coordinator::ExperimentRow>)> {
    use pnode::api::ArchSpec;
    use pnode::data::spiral::SpiralDataset;
    use pnode::nn::{Act, Optimizer};
    use pnode::ode::ModuleRhs;
    use pnode::tasks::ClassificationTask;
    use pnode::util::rng::Rng;

    if let Some(cfg) = spec.exec {
        pnode::tensor::gemm::set_gemm_workers(cfg.workers);
    }
    let mut rng = Rng::new(seed);
    let arch = spec
        .arch
        .clone()
        .unwrap_or(ArchSpec::ConcatMlp { hidden: vec![hidden], act: Act::Relu });
    let extra = arch.augment_extra();
    println!("arch: {} (augment +{extra})", arch.name());
    let per_block = arch.param_count(dim);
    let arch_init = arch.clone();
    let init = move |r: &mut Rng| arch_init.init(r, dim);
    let mut task = if extra > 0 {
        ClassificationTask::augmented(&mut rng, blocks, spec, per_block, dim, extra, classes, init)
    } else {
        ClassificationTask::new(&mut rng, blocks, spec, per_block, dim, classes, init)
    };
    let mut rhs = ModuleRhs::from_arch(&arch, dim, batch, task.block_theta(0).to_vec());
    let ds = SpiralDataset::generate(&mut rng, batch * 5, classes, dim);
    let (train, test) = ds.split(0.9);
    let mut opt = pnode::nn::Adam::new(task.theta.len(), lr);
    let mut x = vec![0.0f32; batch * dim];
    let mut y = vec![0usize; batch];
    let mut last_report = None;
    let train_t = std::time::Instant::now();
    for step in 0..steps {
        train.fill_batch(step * batch, batch, &mut x, &mut y);
        let res = task.grad_step(&mut rhs, batch, &x, &y, 0.05);
        last_report = Some(res.report);
        task.apply_grad(&mut opt as &mut dyn Optimizer, &res.grad);
        if step % 5 == 0 || step + 1 == steps {
            println!(
                "step {step:3}  loss {:.4}  acc {:.3}  nfe {}/{}  {:.0} samp/s",
                res.loss,
                res.accuracy,
                res.report.nfe_forward,
                res.report.nfe_backward,
                res.report.exec.samples_per_sec
            );
        }
    }
    let mut xt = vec![0.0f32; batch * dim];
    let mut yt = vec![0usize; batch];
    test.fill_batch(0, batch, &mut xt, &mut yt);
    let (tl, ta) = task.evaluate(&mut rhs, batch, &xt, &yt);
    println!("test: loss {tl:.4} acc {ta:.3}");
    anyhow::ensure!(tl.is_finite(), "training diverged");
    let events = take_obs_events();
    let row = last_report.map(|rep| {
        let mut row = pnode::coordinator::ExperimentRow::from_spec_report(
            "run_spec",
            "spiral_clf",
            spec,
            &rep,
            train_t.elapsed().as_secs_f64(),
            0,
        );
        if !events.is_empty() {
            let metrics = pnode::obs::Metrics::from_events(&events);
            let n_stages = if spec.scheme.is_implicit() {
                1
            } else {
                spec.scheme.tableau().s as u64
            };
            let mm = pnode::methods::MemModel::for_rhs(
                &rhs,
                n_stages,
                rep.n_accepted.max(1),
                blocks as u64,
            );
            row.attach_obs(&metrics, mm.ckpt_bytes_for(&spec.method));
        }
        row
    });
    Ok((events, row))
}

/// Concatsquash CNF density estimation driven by the spec: Hutchinson
/// trace dynamics with the exact second-order adjoint (the §5.2 workload,
/// XLA-free).
#[allow(clippy::too_many_arguments)]
fn run_spec_cnf(
    spec: &pnode::api::RunSpec,
    steps: usize,
    flows: usize,
    dim: usize,
    hidden: usize,
    batch: usize,
    seed: u64,
    lr: f64,
) -> Result<(Vec<pnode::obs::Event>, Option<pnode::coordinator::ExperimentRow>)> {
    use pnode::api::ArchSpec;
    use pnode::nn::{Act, Optimizer};
    use pnode::tasks::cnf::{CnfTask, HutchinsonCnfRhs};
    use pnode::util::rng::Rng;

    if let Some(cfg) = spec.exec {
        pnode::tensor::gemm::set_gemm_workers(cfg.workers);
    }
    let arch = spec
        .arch
        .clone()
        .unwrap_or(ArchSpec::ConcatSquashMlp { hidden: vec![hidden], act: Act::Tanh });
    anyhow::ensure!(
        arch.augment_extra() == 0,
        "cnf tasks take a non-augmented arch (got {})",
        arch.name()
    );
    println!("arch: {}", arch.name());
    let mut rng = Rng::new(seed);
    let per_flow = arch.param_count(dim);
    let arch_init = arch.clone();
    let mut task = CnfTask::new(&mut rng, flows, spec, batch, dim, per_flow, move |r| {
        arch_init.init(r, dim)
    });
    let mut rhs =
        HutchinsonCnfRhs::new(&arch, batch, dim, task.theta[..per_flow].to_vec(), &mut rng);
    // over-dispersed normal data: the flow should contract it toward the base
    let mut x = vec![0.0f32; batch * dim];
    rng.fill_normal(&mut x);
    for v in x.iter_mut() {
        *v *= 2.0;
    }
    let mut opt = pnode::nn::Adam::new(task.theta.len(), lr);
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    let mut last_report = None;
    let train_t = std::time::Instant::now();
    for step in 0..steps {
        let res = task.grad_step(&mut rhs, &x);
        last_report = Some(res.report);
        if step == 0 {
            first = res.nll;
        }
        last = res.nll;
        opt.step(&mut task.theta, &res.grad);
        if step % 5 == 0 || step + 1 == steps {
            println!(
                "step {step:3}  nll {:.4}  nfe {}/{}  ckpt {}",
                res.nll,
                res.report.nfe_forward,
                res.report.nfe_backward,
                pnode::util::human_bytes(res.report.ckpt_bytes)
            );
        }
    }
    anyhow::ensure!(last.is_finite(), "CNF training diverged");
    println!("nll {first:.4} -> {last:.4}");
    let events = take_obs_events();
    let row = last_report.map(|rep| {
        let mut row = pnode::coordinator::ExperimentRow::from_spec_report(
            "run_spec",
            "cnf",
            spec,
            &rep,
            train_t.elapsed().as_secs_f64(),
            0,
        );
        if !events.is_empty() {
            row.attach_obs(&pnode::obs::Metrics::from_events(&events), 0);
        }
        row
    });
    Ok((events, row))
}

fn cmd_info() -> Result<()> {
    let client = pnode::runtime::Client::cpu()?;
    println!("platform: {} ({} devices)", client.platform_name(), client.device_count());
    match pnode::runtime::Manifest::load_default() {
        Ok(m) => {
            println!("artifacts: {} configs in {:?}", m.configs.len(), m.dir);
            for (name, cfg) in &m.configs {
                println!(
                    "  {name}: kind={} dims={:?} act={} batch={} params={}",
                    cfg.kind, cfg.dims, cfg.act, cfg.batch, cfg.param_count
                );
            }
        }
        Err(e) => println!("artifacts: not available ({e})"),
    }
    Ok(())
}

fn cmd_gradcheck() -> Result<()> {
    use pnode::nn::Act;
    use pnode::ode::rhs::OdeRhs;
    use pnode::util::rng::Rng;

    let client = pnode::runtime::Client::cpu()?;
    let manifest = pnode::runtime::Manifest::load_default()?;
    let arts = pnode::runtime::ModelArtifacts::load(&client, &manifest, "quick_d8")?;
    let entry = arts.entry.clone();
    let mut rng = Rng::new(7);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &entry.dims, 1.0);

    let xla = pnode::ode::XlaRhs::new(arts, theta.clone())?;
    let rust = pnode::ode::ModuleRhs::mlp(
        entry.dims.clone(),
        Act::parse(&entry.act).unwrap(),
        entry.time_dep,
        entry.batch,
        theta,
    );

    let n = xla.state_len();
    let mut u = vec![0.0f32; n];
    rng.fill_normal(&mut u);
    let v = {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v);
        v
    };

    let mut fx = vec![0.0f32; n];
    let mut fr = vec![0.0f32; n];
    xla.f(0.3, &u, &mut fx);
    rust.f(0.3, &u, &mut fr);
    let f_err = pnode::testing::rel_l2(&fx, &fr);

    let mut gx = vec![0.0f32; n];
    let mut gr = vec![0.0f32; n];
    let mut gtx = vec![0.0f32; xla.param_len()];
    let mut gtr = vec![0.0f32; rust.param_len()];
    xla.vjp_both(0.3, &u, &v, &mut gx, &mut gtx);
    rust.vjp_both(0.3, &u, &v, &mut gr, &mut gtr);
    let vjp_err = pnode::testing::rel_l2(&gx, &gr);
    let vjpt_err = pnode::testing::rel_l2(&gtx, &gtr);

    println!("f      rel-l2: {f_err:.3e}");
    println!("vjp_u  rel-l2: {vjp_err:.3e}");
    println!("vjp_th rel-l2: {vjpt_err:.3e}");
    anyhow::ensure!(f_err < 1e-4 && vjp_err < 1e-4 && vjpt_err < 1e-4, "cross-check FAILED");
    println!("gradcheck OK: XLA artifacts match the pure-Rust mirror");
    Ok(())
}

fn cmd_train_clf(args: &Args) -> Result<()> {
    use pnode::api::SolverBuilder;
    use pnode::data::spiral::SpiralDataset;
    use pnode::nn::Optimizer;
    use pnode::ode::rhs::OdeRhs;
    use pnode::tasks::ClassificationTask;
    use pnode::util::rng::Rng;

    let nt = args.get_usize("nt", 4);
    let steps = args.get_usize("steps", 100);
    let n_blocks = args.get_usize("blocks", 4);
    let seed = args.get_u64("seed", 42);
    let use_xla = !args.flag("no-xla");
    // --arch picks the block dynamics (ArchSpec grammar); --augment K is
    // shorthand for wrapping it in ANODE zero channels
    let augment = args.get_usize("augment", 0);
    // --workers: data-parallel execution engine size.  Purely a wall-clock
    // knob — sharding and reduction order are worker-count independent,
    // so gradients (and the whole training trajectory) are bitwise
    // identical for any N.
    let workers = args.get_usize("workers", pnode::exec::default_workers());
    let shard_rows = args.get_usize("shard-rows", pnode::exec::DEFAULT_SHARD_ROWS);
    pnode::tensor::gemm::set_gemm_workers(workers);

    // the whole gradient configuration is ONE validated, typed spec; any
    // parse error (method, scheme, grid) or degenerate combination comes
    // back with the underlying message
    let mut builder = SolverBuilder::new()
        .method_str(args.get_or("method", "pnode"))
        .scheme_str(args.get_or("scheme", "dopri5"))
        .grid_str(args.get_or("grid", "uniform"), nt)
        .workers(workers)
        .shard_rows(shard_rows)
        .arch_str(args.get_or("arch", "concat:168,168:relu"));
    if augment > 0 {
        // wrap whatever arch was picked in ANODE zero channels
        builder = builder.arch_str(&format!(
            "augment:{augment}:{}",
            args.get_or("arch", "concat:168,168:relu")
        ));
    }
    let spec = builder
        .build()
        .map_err(|e| anyhow::anyhow!("invalid solver configuration: {e}"))?;
    let arch = spec.arch.clone().expect("train-clf always declares an arch");
    let extra = arch.augment_extra();
    // the AOT artifacts are compiled for the default concat-MLP layout
    // only: ANY custom architecture needs the pure-Rust module path
    anyhow::ensure!(
        !use_xla || (args.get("arch").is_none() && extra == 0),
        "custom architectures have no XLA artifacts: add --no-xla"
    );

    let mut rng = Rng::new(seed);
    const D: usize = 64;
    const B: usize = 128;
    let per_block = arch.param_count(D);

    let grid_name = spec.grid.name();
    let arch_init = arch.clone();
    let init = move |r: &mut Rng| arch_init.init(r, D);
    let mut task = if extra > 0 {
        ClassificationTask::augmented(&mut rng, n_blocks, &spec, per_block, D, extra, 10, init)
    } else {
        ClassificationTask::new(&mut rng, n_blocks, &spec, per_block, D, 10, init)
    };
    println!(
        "classification: arch {} | {} blocks x {} params = {} total (paper: 199,800), grid {}, \
         engine {} workers x {}-row shards (XLA RHS is not shardable: falls back to 1)",
        arch.name(),
        n_blocks,
        per_block,
        per_block * n_blocks,
        grid_name,
        workers,
        shard_rows
    );

    let mut rhs: Box<dyn OdeRhs> = if use_xla {
        let client = pnode::runtime::Client::cpu()?;
        let manifest = pnode::runtime::Manifest::load_default()?;
        let cfg = args.get_or("config", "clf_d64");
        let arts = pnode::runtime::ModelArtifacts::load(&client, &manifest, cfg)?;
        Box::new(pnode::ode::XlaRhs::new(arts, task.block_theta(0).to_vec())?)
    } else {
        Box::new(pnode::ode::ModuleRhs::from_arch(
            &arch,
            D,
            B,
            task.block_theta(0).to_vec(),
        ))
    };

    let ds = SpiralDataset::generate(&mut rng, 600, 10, D);
    let (train, test) = ds.split(0.9);
    let mut opt = pnode::nn::Adam::new(task.theta.len(), args.get_f64("lr", 1e-3));
    let mut log = pnode::train::TrainLog::new();
    let mut x = vec![0.0f32; B * D];
    let mut y = vec![0usize; B];

    for step in 0..steps {
        train.fill_batch(step * B, B, &mut x, &mut y);
        let res = task.grad_step(rhs.as_mut(), B, &x, &y, 0.05);
        let gn = pnode::train::grad_norm(&res.grad);
        task.apply_grad(&mut opt as &mut dyn Optimizer, &res.grad);
        log.push(
            step,
            res.loss,
            Some(res.accuracy),
            gn,
            res.report.nfe_forward,
            res.report.nfe_backward,
        );
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {step:4}  loss {:.4}  acc {:.3}  |g| {:.2e}  nfe {}/{}  steps {}+{}rej  \
                 {:.0} samp/s",
                res.loss,
                res.accuracy,
                gn,
                res.report.nfe_forward,
                res.report.nfe_backward,
                res.report.n_accepted,
                res.report.n_rejected,
                res.report.exec.samples_per_sec
            );
        }
    }
    let mut xt = vec![0.0f32; B * D];
    let mut yt = vec![0usize; B];
    test.fill_batch(0, B, &mut xt, &mut yt);
    let (tl, ta) = task.evaluate(rhs.as_mut(), B, &xt, &yt);
    println!("test: loss {tl:.4} acc {ta:.3}");
    if let Some(out) = args.get("log-out") {
        std::fs::write(out, log.to_csv())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_train_stiff(args: &Args) -> Result<()> {
    use pnode::data::robertson::RobertsonData;
    use pnode::nn::{Act, Optimizer};
    use pnode::ode::rhs::OdeRhs;
    use pnode::ode::tableau::Scheme;
    use pnode::tasks::StiffTask;
    use pnode::util::rng::Rng;

    let epochs = args.get_usize("epochs", 300);
    let scheme_name = args.get_or("scheme", "cn").to_string();
    let scheme = Scheme::parse(&scheme_name)
        .ok_or_else(|| anyhow::anyhow!("unknown scheme {scheme_name:?}"))?;
    // the explicit baseline is specifically adaptive Dopri5 (Fig. 5);
    // don't silently substitute it for other explicit scheme names
    anyhow::ensure!(
        scheme.is_implicit() || scheme == Scheme::Dopri5,
        "train-stiff supports cn | beuler (implicit θ-adjoint) or dopri5 \
         (the adaptive explicit baseline), got {scheme_name:?}"
    );
    let scaled = !args.flag("raw");
    let use_xla = !args.flag("no-xla");
    let seed = args.get_u64("seed", 3);

    let data = RobertsonData::generate(40, 8, scaled);
    let task = StiffTask::new(data, args.get_usize("substeps", 2));

    // small init: the untrained field must stay bounded over [1e-5, 100]
    let dims = vec![3, 50, 50, 50, 50, 50, 3];
    let mut rng = Rng::new(seed);
    let theta0 = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 0.1);
    let mut rhs: Box<dyn OdeRhs> = if use_xla {
        let client = pnode::runtime::Client::cpu()?;
        let manifest = pnode::runtime::Manifest::load_default()?;
        let arts = pnode::runtime::ModelArtifacts::load(&client, &manifest, "stiff_d3")?;
        Box::new(pnode::ode::XlaRhs::new(arts, theta0.clone())?)
    } else {
        Box::new(pnode::ode::ModuleRhs::mlp(dims, Act::Gelu, false, 1, theta0.clone()))
    };

    let mut opt = pnode::nn::AdamW::new(rhs.param_len(), args.get_f64("lr", 5e-3), 1e-4);
    let mut theta = theta0;
    let mut stats = pnode::train::GradStats::default();
    for epoch in 0..epochs {
        let step = if scheme.is_implicit() {
            task.grad_implicit(rhs.as_ref(), scheme)
        } else {
            task.grad_explicit_adaptive(rhs.as_ref(), 1e-6)
        };
        let gn = pnode::train::grad_norm(&step.grad);
        stats.observe(gn, 1e6);
        let mut grad = step.grad;
        pnode::train::clip_grad_norm(&mut grad, 100.0);
        opt.step(&mut theta, &grad);
        rhs.set_params(&theta);
        if epoch % 20 == 0 || epoch + 1 == epochs {
            println!(
                "epoch {epoch:4}  MAE {:.5}  |g| {:.2e}  nfe {}/{}  steps {}+{}rej{}",
                step.loss,
                gn,
                step.nfe_forward,
                step.nfe_backward,
                step.n_accepted,
                step.n_rejected,
                if stats.exploded { "  [EXPLODED]" } else { "" }
            );
        }
    }
    println!("max |g| over run: {:.3e}  exploded: {}", stats.max_norm, stats.exploded);
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("prop2") => {
            let mut t = pnode::bench::Table::new(
                "Prop. 2 — minimal recomputations p̃(N_t, N_c) [formula | DP-optimal]",
                &["N_t", "N_c=1", "N_c=2", "N_c=3", "N_c=5", "N_c=8"],
            );
            let mut planner = pnode::checkpoint::BinomialPlanner::new();
            for nt in [5usize, 10, 20, 40, 80] {
                let mut cells = vec![nt.to_string()];
                for nc in [1usize, 2, 3, 5, 8] {
                    let f = pnode::checkpoint::prop2_extra_steps(nt, nc).unwrap();
                    let d = planner.optimal_cost(nt, nc);
                    cells.push(format!("{f} | {d}"));
                }
                t.row(cells);
            }
            t.print();
        }
        Some("table2") => {
            // size the model off the real module graph: summed per-module
            // activation bytes of the clf_d64 architecture at B = 128
            // (Σ_l B·(d_l + d_{l+1}) = 128·801 floats — the same total the
            // old hand-maintained constant encoded)
            let arch = pnode::api::ArchSpec::ConcatMlp {
                hidden: vec![168, 168],
                act: pnode::nn::Act::Relu,
            };
            let theta = vec![0.0f32; arch.param_count(64)];
            let rhs = pnode::ode::ModuleRhs::from_arch(&arch, 64, 128, theta);
            let mm = pnode::methods::MemModel::for_rhs(&rhs, 6, 10, 4);
            let mut t = pnode::bench::Table::new(
                "Table 2 — modeled memory (clf_d64, Dopri5, N_t=10, N_b=4)",
                &["method", "model GB", "reverse-accurate", "implicit"],
            );
            for (name, ra, imp) in [
                ("cont", "x", "x"),
                ("naive", "yes", "x"),
                ("anode", "yes", "x"),
                ("aca", "yes", "x"),
                ("pnode", "yes", "yes"),
                ("pnode2", "yes", "yes"),
            ] {
                let bytes = mm.by_method(name).unwrap();
                t.row(vec![
                    name.into(),
                    format!("{:.3}", pnode::methods::MemModel::gb(bytes)),
                    ra.into(),
                    imp.into(),
                ]);
            }
            t.print();
        }
        _ => eprintln!("usage: pnode bench <prop2|table2>  (full sweeps: cargo bench)"),
    }
    Ok(())
}
