//! SGEMM kernel family for the pure-Rust hot path.  Since PR 1 made the
//! `xla` feature off-by-default, every forward, VJP, and second-order
//! adjoint in the crate bottoms out here — this IS the production kernel,
//! not a cross-check curiosity.
//!
//! Architecture (DESIGN.md §12):
//!
//! * **Panel packing.** `b` is repacked once per call into zero-padded
//!   panels of [`LANES`] contiguous columns (`panel[p * LANES + j]`), so
//!   the microkernel streams unit-stride, aligned-width rows regardless
//!   of `n` or transposition.
//! * **Register-blocked microkernel.** [`MR`] output rows × [`LANES`]
//!   output columns accumulate in registers over the full `k` extent —
//!   one accumulator per (row, lane), the `p` loop strictly sequential,
//!   no horizontal reduction.  Every multiply-add is a *fused*
//!   multiply-add: `_mm256_fmadd_ps` on the AVX2 path, `f32::mul_add`
//!   (correctly rounded everywhere) on the portable path, so the two
//!   vector paths are bitwise identical on every CPU.
//! * **One-time dispatch.** [`kernel_path`] picks scalar / portable /
//!   AVX2 once per process: `PNODE_KERNEL=scalar` forces the legacy
//!   loop, `PNODE_KERNEL=portable` forces the lane-emulation path
//!   (debug aid), anything else runs CPU detection.
//! * **`beta` folded into the writeback.** The vector paths never
//!   pre-sweep `c`: each output element is produced exactly once, and the
//!   first (only) panel write applies `beta` — `c = acc` when `beta == 0`
//!   (old contents never read, NaN-safe), `c += acc` when `beta == 1`.
//! * **Row-block parallelism** ([`set_gemm_workers`]) layers on top
//!   unchanged: workers own disjoint `c` row blocks and each output
//!   element's arithmetic is independent of how rows are grouped into
//!   tiles, so results are bitwise identical for any worker count.
//! * **Fused epilogues.** [`sgemm_epi`] / [`sgemm_epi2`] run a per-row
//!   closure (bias add, activation, gating) while the freshly written row
//!   is still cache-hot — the building block for the fused module kernels
//!   in `nn/module/`.  Epilogues must not re-enter this module: the
//!   thread-local pack buffer is borrowed for the whole call.
//!
//! Determinism contract: every path is bitwise reproducible across runs
//! and worker counts, and the portable and AVX2 paths are bitwise
//! identical to *each other* — but the vector paths are NOT bitwise equal
//! to the scalar loop (different accumulation order + fused rounding).
//! Oracle comparisons therefore pin the scalar path exactly and hold the
//! vector paths to a tolerance; see DESIGN.md §12.
//!
//! The legacy scalar loop keeps its two value-preserving quirks: the
//! zero-skip fast path (`a` entries that are exactly 0 skip their `b`
//! row, guarded by a lazy `b`-finiteness scan so `0·NaN` / `0·Inf` still
//! poison) and the serial ikj order.  The vector paths drop the skip —
//! fused multiplies make `0·NaN = NaN` propagation automatic, and the
//! `-0.0 + 0·x` sign preservation of the skip is a scalar-only artifact
//! (pinned as such in the tests below).

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Worker threads [`sgemm`] may use for large outputs (process-wide; set
/// from `--workers` / `PNODE_WORKERS`).  1 disables parallelism.
static GEMM_WORKERS: AtomicUsize = AtomicUsize::new(1);

pub fn set_gemm_workers(n: usize) {
    // Relaxed: a process-wide tuning knob written once at startup; a
    // stale read changes thread count, never data — each GEMM publishes
    // its results through the scoped-pool join, not through this atomic
    GEMM_WORKERS.store(n.max(1), Ordering::Relaxed);
}

pub fn gemm_workers() -> usize {
    // Relaxed: pairs with the Relaxed store above (see set_gemm_workers)
    GEMM_WORKERS.load(Ordering::Relaxed)
}

/// Row-blocking only pays above this many output rows...
const PAR_MIN_ROWS: usize = 64;
/// ...and this many multiply-adds (thread spawn is a few tens of µs).
const PAR_MIN_MULADDS: u64 = 1 << 21;

/// Virtual vector width of the packed kernel, in f32 lanes.  Fixed — not
/// CPU-dependent — so packing layout and reduction order (and therefore
/// output bits) never vary across machines.
const LANES: usize = 8;
/// Output rows per register tile.
const MR: usize = 4;

// ---------------------------------------------------------------------------
// dispatch

/// Which kernel implementation this process runs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum KernelPath {
    /// legacy serial ikj loop with the zero-skip fast path
    Scalar,
    /// packed kernel, lane loop emulated with `f32::mul_add`
    Portable,
    /// packed kernel, AVX2 + FMA intrinsics (bitwise equal to Portable)
    Avx2,
}

impl KernelPath {
    pub fn name(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Portable => "portable",
            KernelPath::Avx2 => "avx2",
        }
    }
}

/// CPU-feature detection result (what an unset/`simd` `PNODE_KERNEL`
/// resolves to) — exposed so tests and benches can pin the strongest
/// path available on the host without touching the one-shot dispatch.
pub fn detect() -> KernelPath {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return KernelPath::Avx2;
        }
    }
    KernelPath::Portable
}

/// The process-wide kernel path, decided once on first use:
/// `PNODE_KERNEL=scalar` forces the legacy loop, `PNODE_KERNEL=portable`
/// forces lane emulation (debug aid — slow without hardware FMA), any
/// other value (including the documented `simd` and unset) runs CPU
/// detection.
pub fn kernel_path() -> KernelPath {
    static PATH: OnceLock<KernelPath> = OnceLock::new();
    *PATH.get_or_init(|| match std::env::var("PNODE_KERNEL").as_deref() {
        Ok("scalar") => KernelPath::Scalar,
        Ok("portable") => KernelPath::Portable,
        _ => detect(),
    })
}

/// Record which kernel path the process dispatched to: one instant event
/// (`kernel.dispatch`, detail = path name).  Called from `Session`
/// construction — not from the first GEMM — so the event lands at a
/// deterministic `(tid, seq)` position in every traced run.
pub fn note_dispatch() {
    if crate::obs::enabled() {
        crate::obs::warn("kernel.dispatch", || kernel_path().name().to_string());
    }
}

/// `gemm.mul_adds` counter: the full `m·k·n` product, stamped under the
/// caller's *logical* obs tid — the main thread (tid 0) or, inside the
/// execution pool, the `job_ctx` tid of the enclosing job — so parallel
/// shards contribute under their own deterministic `(tid, seq)` keys and
/// the metrics fold's per-name sum covers every worker.  The kernel's own
/// row-block scoped threads stay silent on purpose: counting there would
/// split the product by `gemm_workers()`, making the event multiset
/// depend on the worker count and breaking the trace-identical-across-
/// worker-counts contract (`tests/obs_trace.rs`); the entry-point count
/// is already the whole product regardless of the split.
#[inline]
fn obs_gemm(m: usize, k: usize, n: usize) {
    if crate::obs::enabled() {
        crate::obs::counter("gemm.mul_adds", (m as f64) * (k as f64) * (n as f64));
    }
}

// ---------------------------------------------------------------------------
// legacy scalar path (PNODE_KERNEL=scalar) — arithmetic preserved verbatim

/// Lazily computed "is `b` entirely finite" — the zero-skip gate.  The
/// scan costs O(k·n), so it only runs if a zero in `a` is actually
/// encountered; GEMMs whose `a` has no exact zeros pay nothing.
#[derive(Clone, Copy, Default)]
struct BFinite(Option<bool>);

impl BFinite {
    #[inline]
    fn check(&mut self, b: &[f32]) -> bool {
        *self.0.get_or_insert_with(|| b.iter().all(|x| x.is_finite()))
    }
}

/// The serial ikj kernel over output rows `[i0, i0 + rows)` of c.
fn sgemm_rows(i0: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    if n == 0 {
        return;
    }
    let mut b_finite = BFinite::default();
    let rows = c.len() / n;
    for r in 0..rows {
        let i = i0 + r;
        let crow = &mut c[r * n..(r + 1) * n];
        for p in 0..k {
            let aval = a[i * k + p];
            if aval == 0.0 && b_finite.check(b) {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += aval * brow[j];
            }
        }
    }
}

fn scale_c(c: &mut [f32], beta: f32) {
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for x in c.iter_mut() {
            *x *= beta;
        }
    }
}

fn scalar_sgemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32], beta: f32) {
    scale_c(c, beta);
    let workers = gemm_workers();
    if workers > 1 && m >= PAR_MIN_ROWS && (m as u64) * (k as u64) * (n as u64) >= PAR_MIN_MULADDS
    {
        // row-blocked: disjoint c row blocks, identical per-row arithmetic
        let rows_per = m.div_ceil(workers);
        std::thread::scope(|s| {
            for (bi, cblock) in c.chunks_mut(rows_per * n).enumerate() {
                s.spawn(move || sgemm_rows(bi * rows_per, k, n, a, b, cblock));
            }
        });
        return;
    }
    sgemm_rows(0, k, n, a, b, c);
}

fn scalar_sgemm_at(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32], beta: f32) {
    scale_c(c, beta);
    let mut b_finite = BFinite::default();
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let aval = arow[i];
            if aval == 0.0 && b_finite.check(b) {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aval * brow[j];
            }
        }
    }
}

fn scalar_sgemm_bt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32], beta: f32) {
    scale_c(c, beta);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            crow[j] += acc;
        }
    }
}

// ---------------------------------------------------------------------------
// packed vector path (Portable / Avx2)

/// Strided read-only view of the `a` operand: `at(i, p) = A[i, p]` for
/// the logical [m, k] matrix, covering both the natural layout
/// (`row_stride = k, p_stride = 1`) and the transposed-storage layout of
/// [`sgemm_at`] (`row_stride = 1, p_stride = m`).
#[derive(Clone, Copy)]
struct AView<'a> {
    a: &'a [f32],
    row_stride: usize,
    p_stride: usize,
}

impl AView<'_> {
    #[inline(always)]
    fn at(&self, i: usize, p: usize) -> f32 {
        self.a[i * self.row_stride + p * self.p_stride]
    }
}

thread_local! {
    /// Per-thread pack buffer, reused across calls.  Borrowed for the
    /// whole duration of a packed GEMM — epilogues must not re-enter.
    static PACK: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Pack `b` into `n.div_ceil(LANES)` panels of `k` rows × `LANES`
/// contiguous columns, zero-padding the last panel's missing columns.
/// `transposed` reads `b` as [n, k] row-major (the [`sgemm_bt`] layout).
fn pack_b(k: usize, n: usize, b: &[f32], transposed: bool, out: &mut Vec<f32>) {
    let panels = n.div_ceil(LANES);
    out.clear();
    out.resize(panels * k * LANES, 0.0);
    for jp in 0..panels {
        let j0 = jp * LANES;
        let jw = LANES.min(n - j0);
        let panel = &mut out[jp * k * LANES..(jp + 1) * k * LANES];
        if transposed {
            for (dj, bcol) in b.chunks_exact(k).skip(j0).take(jw).enumerate() {
                for (p, bv) in bcol.iter().enumerate() {
                    panel[p * LANES + dj] = *bv;
                }
            }
        } else {
            for (p, brow) in b.chunks_exact(n).enumerate() {
                panel[p * LANES..p * LANES + jw].copy_from_slice(&brow[j0..j0 + jw]);
            }
        }
    }
}

/// Portable microkernel: `mr` rows × LANES lanes over the full `k`
/// extent, one accumulator per (row, lane), `f32::mul_add` per element.
/// Lane-for-lane this is the same arithmetic as [`mk_avx2`] (fused
/// multiply-adds are correctly rounded), so the two are bitwise equal.
fn mk_portable(
    av: AView,
    i0: usize,
    mr: usize,
    k: usize,
    panel: &[f32],
    acc: &mut [[f32; LANES]; MR],
) {
    for p in 0..k {
        let brow = &panel[p * LANES..(p + 1) * LANES];
        for (r, accr) in acc.iter_mut().enumerate().take(mr) {
            let aval = av.at(i0 + r, p);
            for (al, bl) in accr.iter_mut().zip(brow) {
                *al = aval.mul_add(*bl, *al);
            }
        }
    }
}

/// AVX2 + FMA microkernel.
///
/// SAFETY: callers dispatch this only after runtime detection of both
/// features; `a` indices are in range by the tiling invariants of
/// [`do_tile`], the panel slice holds `k * LANES` floats by construction.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn mk_avx2(
    av: AView,
    i0: usize,
    mr: usize,
    k: usize,
    panel: &[f32],
    acc: &mut [[f32; LANES]; MR],
) {
    use std::arch::x86_64::*;
    let (rs, ps) = (av.row_stride, av.p_stride);
    let mut vacc = [_mm256_setzero_ps(); MR];
    if mr == MR {
        // full tile: constant trip count, unrolled by the compiler
        for p in 0..k {
            let bv = _mm256_loadu_ps(panel.as_ptr().add(p * LANES));
            for (r, va) in vacc.iter_mut().enumerate() {
                let aval = _mm256_set1_ps(*av.a.get_unchecked((i0 + r) * rs + p * ps));
                *va = _mm256_fmadd_ps(aval, bv, *va);
            }
        }
    } else {
        for p in 0..k {
            let bv = _mm256_loadu_ps(panel.as_ptr().add(p * LANES));
            for (r, va) in vacc.iter_mut().enumerate().take(mr) {
                let aval = _mm256_set1_ps(*av.a.get_unchecked((i0 + r) * rs + p * ps));
                *va = _mm256_fmadd_ps(aval, bv, *va);
            }
        }
    }
    for (accr, va) in acc.iter_mut().zip(vacc).take(mr) {
        _mm256_storeu_ps(accr.as_mut_ptr(), va);
    }
}

/// Off x86-64 the Avx2 variant is never selected; keep the symbol so the
/// dispatch match compiles everywhere.
///
/// SAFETY: trivially safe — delegates to the safe portable kernel; the
/// signature stays `unsafe fn` only to match the x86-64 variant.
#[cfg(not(target_arch = "x86_64"))]
unsafe fn mk_avx2(
    av: AView,
    i0: usize,
    mr: usize,
    k: usize,
    panel: &[f32],
    acc: &mut [[f32; LANES]; MR],
) {
    mk_portable(av, i0, mr, k, panel, acc)
}

/// One register tile: rows `[i_abs, i_abs + mr)` × all packed panels,
/// with `beta` folded into the (single) writeback of each output element.
#[allow(clippy::too_many_arguments)]
fn do_tile(
    path: KernelPath,
    av: AView,
    i_abs: usize,
    mr: usize,
    k: usize,
    n: usize,
    bp: &[f32],
    crows: &mut [f32],
    beta: f32,
) {
    let panels = n.div_ceil(LANES);
    for jp in 0..panels {
        let panel = &bp[jp * k * LANES..(jp + 1) * k * LANES];
        let mut acc = [[0.0f32; LANES]; MR];
        match path {
            // SAFETY: Avx2 is only ever selected by detect() after a
            // runtime avx2+fma check, and do_tile's tiling invariants
            // keep every index the microkernel touches in range
            KernelPath::Avx2 => unsafe { mk_avx2(av, i_abs, mr, k, panel, &mut acc) },
            _ => mk_portable(av, i_abs, mr, k, panel, &mut acc),
        }
        let j0 = jp * LANES;
        let jw = LANES.min(n - j0);
        for (r, accr) in acc.iter().enumerate().take(mr) {
            let crow = &mut crows[r * n + j0..r * n + j0 + jw];
            if beta == 0.0 {
                // old contents never read: NaN/garbage in c cannot leak
                crow.copy_from_slice(&accr[..jw]);
            } else if beta == 1.0 {
                for (cj, aj) in crow.iter_mut().zip(accr) {
                    *cj += *aj;
                }
            } else {
                for (cj, aj) in crow.iter_mut().zip(accr) {
                    *cj = beta * *cj + *aj;
                }
            }
        }
    }
}

fn no_epi(_i: usize, _row: &mut [f32]) {}

/// Packed kernel over output rows `[i0, i0 + rows)` (one worker's row
/// block), then the per-row epilogue while each row is still hot.  Tile
/// grouping never changes bits: each output element has its own
/// accumulator and a fixed sequential `p` order.
#[allow(clippy::too_many_arguments)]
fn simd_rows<F: Fn(usize, &mut [f32])>(
    path: KernelPath,
    av: AView,
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
    bp: &[f32],
    cblock: &mut [f32],
    beta: f32,
    epi: &F,
) {
    let mut it = 0;
    while it < rows {
        let mr = MR.min(rows - it);
        let crows = &mut cblock[it * n..(it + mr) * n];
        do_tile(path, av, i0 + it, mr, k, n, bp, crows, beta);
        for r in 0..mr {
            epi(i0 + it + r, &mut crows[r * n..(r + 1) * n]);
        }
        it += mr;
    }
}

/// As [`simd_rows`], with a second [rows, n] buffer `y` driven by the
/// epilogue (`epi(abs_row, zrow, yrow)`); `z` gets the raw GEMM result.
#[allow(clippy::too_many_arguments)]
fn simd_rows2<F: Fn(usize, &mut [f32], &mut [f32])>(
    path: KernelPath,
    av: AView,
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
    bp: &[f32],
    zblock: &mut [f32],
    yblock: &mut [f32],
    epi: &F,
) {
    let mut it = 0;
    while it < rows {
        let mr = MR.min(rows - it);
        let zrows = &mut zblock[it * n..(it + mr) * n];
        do_tile(path, av, i0 + it, mr, k, n, bp, zrows, 0.0);
        for r in 0..mr {
            epi(
                i0 + it + r,
                &mut zrows[r * n..(r + 1) * n],
                &mut yblock[(it + r) * n..(it + r + 1) * n],
            );
        }
        it += mr;
    }
}

fn par_worthwhile(m: usize, k: usize, n: usize) -> bool {
    gemm_workers() > 1
        && m >= PAR_MIN_ROWS
        && (m as u64) * (k as u64) * (n as u64) >= PAR_MIN_MULADDS
}

// ---------------------------------------------------------------------------
// public entry points

/// c[m,n] (+)= a[m,k] @ b[k,n];  row-major, `beta` scales existing c.
pub fn sgemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32], beta: f32) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    obs_gemm(m, k, n);
    sgemm_with(kernel_path(), m, k, n, a, b, c, beta);
}

/// [`sgemm`] on an explicit kernel path — exposed so tests and benches
/// can exercise every path in one process despite the one-time dispatch.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_with(
    path: KernelPath,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    beta: f32,
) {
    if path == KernelPath::Scalar {
        scalar_sgemm(m, k, n, a, b, c, beta);
        return;
    }
    PACK.with(|p| {
        let mut pk = p.borrow_mut();
        pack_b(k, n, b, false, &mut pk);
        let av = AView { a, row_stride: k, p_stride: 1 };
        let bp: &[f32] = &pk;
        if par_worthwhile(m, k, n) {
            let rows_per = m.div_ceil(gemm_workers());
            std::thread::scope(|s| {
                for (bi, cblock) in c.chunks_mut(rows_per * n).enumerate() {
                    s.spawn(move || {
                        let rows = cblock.len() / n;
                        simd_rows(path, av, bi * rows_per, rows, k, n, bp, cblock, beta, &no_epi);
                    });
                }
            });
            return;
        }
        simd_rows(path, av, 0, m, k, n, bp, c, beta, &no_epi);
    });
}

/// c[m,n] (+)= a^T[m,k] @ b[k,n] where a is stored [k,m] row-major.
pub fn sgemm_at(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32], // [k, m]
    b: &[f32], // [k, n]
    c: &mut [f32],
    beta: f32,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    obs_gemm(m, k, n);
    sgemm_at_with(kernel_path(), m, k, n, a, b, c, beta);
}

/// [`sgemm_at`] on an explicit kernel path.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_at_with(
    path: KernelPath,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    beta: f32,
) {
    if path == KernelPath::Scalar {
        scalar_sgemm_at(m, k, n, a, b, c, beta);
        return;
    }
    PACK.with(|p| {
        let mut pk = p.borrow_mut();
        pack_b(k, n, b, false, &mut pk);
        let av = AView { a, row_stride: 1, p_stride: m };
        simd_rows(path, av, 0, m, k, n, &pk, c, beta, &no_epi);
    });
}

/// c[m,n] (+)= a[m,k] @ b^T[k,n] where b is stored [n,k] row-major.
pub fn sgemm_bt(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32], // [m, k]
    b: &[f32], // [n, k]
    c: &mut [f32],
    beta: f32,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    obs_gemm(m, k, n);
    sgemm_bt_with(kernel_path(), m, k, n, a, b, c, beta);
}

/// [`sgemm_bt`] on an explicit kernel path.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_bt_with(
    path: KernelPath,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    beta: f32,
) {
    if path == KernelPath::Scalar {
        scalar_sgemm_bt(m, k, n, a, b, c, beta);
        return;
    }
    PACK.with(|p| {
        let mut pk = p.borrow_mut();
        pack_b(k, n, b, true, &mut pk);
        let av = AView { a, row_stride: k, p_stride: 1 };
        simd_rows(path, av, 0, m, k, n, &pk, c, beta, &no_epi);
    });
}

/// c[m,n] = a[m,k] @ b[k,n], then `epi(i, row_i)` on each completed row
/// while it is still cache-hot (bias adds, activations, masking...).
/// The epilogue runs once per row, on the worker that produced the row;
/// it must be `Sync` and must not call back into this module.
pub fn sgemm_epi<F: Fn(usize, &mut [f32]) + Sync>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    epi: &F,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    obs_gemm(m, k, n);
    let path = kernel_path();
    if path == KernelPath::Scalar {
        scalar_sgemm(m, k, n, a, b, c, 0.0);
        for (i, crow) in c.chunks_mut(n).enumerate() {
            epi(i, crow);
        }
        return;
    }
    PACK.with(|p| {
        let mut pk = p.borrow_mut();
        pack_b(k, n, b, false, &mut pk);
        let av = AView { a, row_stride: k, p_stride: 1 };
        let bp: &[f32] = &pk;
        if par_worthwhile(m, k, n) {
            let rows_per = m.div_ceil(gemm_workers());
            std::thread::scope(|s| {
                for (bi, cblock) in c.chunks_mut(rows_per * n).enumerate() {
                    s.spawn(move || {
                        let rows = cblock.len() / n;
                        simd_rows(path, av, bi * rows_per, rows, k, n, bp, cblock, 0.0, epi);
                    });
                }
            });
            return;
        }
        simd_rows(path, av, 0, m, k, n, bp, c, 0.0, epi);
    });
}

/// z[m,n] = a[m,k] @ b[k,n], then `epi(i, z_row_i, y_row_i)` per row —
/// the two-output variant for kernels that keep the pre-activation (z)
/// and emit the activated value (y) in one pass.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_epi2<F: Fn(usize, &mut [f32], &mut [f32]) + Sync>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    z: &mut [f32],
    y: &mut [f32],
    epi: &F,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(z.len(), m * n);
    debug_assert_eq!(y.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    obs_gemm(m, k, n);
    let path = kernel_path();
    if path == KernelPath::Scalar {
        scalar_sgemm(m, k, n, a, b, z, 0.0);
        for (i, (zrow, yrow)) in z.chunks_mut(n).zip(y.chunks_mut(n)).enumerate() {
            epi(i, zrow, yrow);
        }
        return;
    }
    PACK.with(|p| {
        let mut pk = p.borrow_mut();
        pack_b(k, n, b, false, &mut pk);
        let av = AView { a, row_stride: k, p_stride: 1 };
        let bp: &[f32] = &pk;
        if par_worthwhile(m, k, n) {
            let rows_per = m.div_ceil(gemm_workers());
            std::thread::scope(|s| {
                let zc = z.chunks_mut(rows_per * n);
                let yc = y.chunks_mut(rows_per * n);
                for (bi, (zblock, yblock)) in zc.zip(yc).enumerate() {
                    s.spawn(move || {
                        let rows = zblock.len() / n;
                        simd_rows2(path, av, bi * rows_per, rows, k, n, bp, zblock, yblock, epi);
                    });
                }
            });
            return;
        }
        simd_rows2(path, av, 0, m, k, n, bp, z, y, epi);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every path testable on this machine: scalar and portable always,
    /// AVX2 when the CPU has it.
    fn paths() -> Vec<KernelPath> {
        let mut v = vec![KernelPath::Scalar, KernelPath::Portable];
        if detect() == KernelPath::Avx2 {
            v.push(KernelPath::Avx2);
        }
        v
    }

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    /// Small integers (−6..7): all products and partial sums are exactly
    /// representable, so EVERY path must match the oracle bit-for-bit
    /// regardless of accumulation order.
    fn fill(seed: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| ((i * 31 + seed * 17) % 13) as f32 - 6.0).collect()
    }

    /// Non-integer values: reassociation changes bits, so comparisons
    /// against the oracle use a relative tolerance.
    fn fill_f(seed: usize, len: usize) -> Vec<f32> {
        (0..len)
            .map(|i| (((i * 31 + seed * 17) % 97) as f32) * 0.217 - 10.0)
            .collect()
    }

    fn assert_close(got: &[f32], want: &[f32], tag: &str) {
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let tol = 1e-4 * w.abs().max(1.0);
            assert!((g - w).abs() <= tol, "{tag}[{i}]: got {g}, want {w}");
        }
    }

    /// Kernel-edge shapes: 1, LANES−1, LANES, LANES+1, odd primes, and a
    /// multi-tile/multi-panel case — exercises remainder tiles and panel
    /// padding in every dimension.
    const EDGES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 7, 9),
        (3, 4, 5),
        (7, 8, 8),
        (8, 8, 8),
        (9, 9, 9),
        (13, 21, 7),
        (17, 5, 23),
        (5, 16, 1),
        (2, 0, 3),
        (31, 13, 19),
    ];

    #[test]
    fn sgemm_matches_naive_exactly_on_integer_data_all_paths() {
        for path in paths() {
            for &(m, k, n) in EDGES {
                let a = fill(1, m * k);
                let b = fill(2, k * n);
                let mut c = vec![f32::NAN; m * n]; // beta=0 must overwrite, never read
                sgemm_with(path, m, k, n, &a, &b, &mut c, 0.0);
                assert_eq!(c, naive(m, k, n, &a, &b), "{path:?} ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn sgemm_matches_naive_within_tolerance_on_float_data_all_paths() {
        for path in paths() {
            for &(m, k, n) in EDGES {
                let a = fill_f(1, m * k);
                let b = fill_f(2, k * n);
                let mut c = vec![0.0; m * n];
                sgemm_with(path, m, k, n, &a, &b, &mut c, 0.0);
                assert_close(&c, &naive(m, k, n, &a, &b), &format!("{path:?} ({m},{k},{n})"));
            }
        }
    }

    #[test]
    fn portable_and_avx2_are_bitwise_identical() {
        if detect() != KernelPath::Avx2 {
            return; // no AVX2 on this machine; contract vacuously holds
        }
        for &(m, k, n) in EDGES {
            let a = fill_f(3, m * k);
            let b = fill_f(4, k * n);
            let mut cp = vec![0.0; m * n];
            let mut cv = vec![0.0; m * n];
            sgemm_with(KernelPath::Portable, m, k, n, &a, &b, &mut cp, 0.0);
            sgemm_with(KernelPath::Avx2, m, k, n, &a, &b, &mut cv, 0.0);
            assert_eq!(cp, cv, "({m},{k},{n}): fused-madd lanes must agree exactly");

            let mut tp = vec![0.0; m * n];
            let mut tv = vec![0.0; m * n];
            let at: Vec<f32> = {
                let mut t = vec![0.0; k * m];
                for i in 0..m {
                    for p in 0..k {
                        t[p * m + i] = a[i * k + p];
                    }
                }
                t
            };
            sgemm_at_with(KernelPath::Portable, m, k, n, &at, &b, &mut tp, 0.0);
            sgemm_at_with(KernelPath::Avx2, m, k, n, &at, &b, &mut tv, 0.0);
            assert_eq!(tp, tv, "sgemm_at ({m},{k},{n})");
        }
    }

    #[test]
    fn sgemm_beta_accumulates_on_all_paths() {
        for path in paths() {
            // beta = 1: accumulate into existing c
            let a = fill(1, 4);
            let b = fill(2, 4);
            let mut c = vec![1.0; 4];
            sgemm_with(path, 2, 2, 2, &a, &b, &mut c, 1.0);
            let mut want = naive(2, 2, 2, &a, &b);
            for w in want.iter_mut() {
                *w += 1.0;
            }
            assert_eq!(c, want, "{path:?} beta=1");

            // general beta: c = beta·c + a@b  (integer data stays exact)
            let mut c2 = vec![2.0; 4];
            sgemm_with(path, 2, 2, 2, &a, &b, &mut c2, 3.0);
            let mut want2 = naive(2, 2, 2, &a, &b);
            for w in want2.iter_mut() {
                *w += 6.0;
            }
            assert_eq!(c2, want2, "{path:?} beta=3");
        }
    }

    #[test]
    fn zero_times_nonfinite_poisons_on_all_paths() {
        // 0·NaN / 0·Inf must poison the output on every path: the scalar
        // loop via the guarded zero-skip, the vector paths via fused
        // multiply-adds that never skip.
        for path in paths() {
            for poison in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
                // c[0,0] = 0·poison + 1·3, c[0,1] = 0·2 + 1·4
                let a = vec![0.0f32, 1.0];
                let b = vec![poison, 2.0, 3.0, 4.0];
                let mut c = vec![0.0f32; 2];
                sgemm_with(path, 1, 2, 2, &a, &b, &mut c, 0.0);
                assert!(c[0].is_nan(), "{path:?}: 0·{poison} must poison, got {}", c[0]);
                assert_eq!(c[1], 4.0, "{path:?}: finite columns are unaffected");

                // a^T variant: same contraction, a stored [k=2, m=1]
                let at = vec![0.0f32, 1.0];
                let mut c2 = vec![0.0f32; 2];
                sgemm_at_with(path, 1, 2, 2, &at, &b, &mut c2, 0.0);
                assert!(c2[0].is_nan(), "{path:?}: sgemm_at 0·{poison} must poison");
                assert_eq!(c2[1], 4.0);
            }
        }
    }

    #[test]
    fn scalar_zero_skip_preserves_negative_zero() {
        // The skip still fires on finite inputs: -0.0 + 0·x keeps its
        // sign only when skipped, which pins the fast path as actually
        // taken.  Scalar-path-only: the vector paths compute
        // -0.0 + 0·5 = +0.0 (no skip), which is the documented behavior.
        let a = vec![0.0f32];
        let b = vec![5.0f32];
        let mut c = vec![-0.0f32];
        sgemm_with(KernelPath::Scalar, 1, 1, 1, &a, &b, &mut c, 1.0);
        assert!(c[0] == 0.0 && c[0].is_sign_negative(), "skip taken for finite b");
    }

    #[test]
    fn parallel_rows_are_bitwise_identical_to_serial_on_all_paths() {
        // above both thresholds: 256 rows, 256·96·96 ≈ 2.4M mul-adds
        let (m, k, n) = (256, 96, 96);
        let a = fill_f(5, m * k);
        let b = fill_f(6, k * n);
        for path in paths() {
            let mut serial = vec![0.0f32; m * n];
            sgemm_with(path, m, k, n, &a, &b, &mut serial, 0.0);
            for workers in [2usize, 3, 4] {
                set_gemm_workers(workers);
                let mut par = vec![0.5f32; m * n];
                sgemm_with(path, m, k, n, &a, &b, &mut par, 0.0);
                set_gemm_workers(1);
                assert_eq!(par, serial, "{path:?} workers={workers}: blocks must not change bits");
            }
        }
    }

    #[test]
    fn transposed_variants_match_on_all_paths() {
        for path in paths() {
            for &(m, k, n) in &[(5, 7, 3), (9, 8, 17), (1, 13, 8)] {
                let a = fill(3, m * k);
                let b = fill(4, k * n);
                let want = naive(m, k, n, &a, &b);

                // a stored transposed [k,m]
                let mut at = vec![0.0; k * m];
                for i in 0..m {
                    for p in 0..k {
                        at[p * m + i] = a[i * k + p];
                    }
                }
                let mut c = vec![0.0; m * n];
                sgemm_at_with(path, m, k, n, &at, &b, &mut c, 0.0);
                assert_eq!(c, want, "{path:?} sgemm_at ({m},{k},{n})");

                // b stored transposed [n,k]
                let mut bt = vec![0.0; n * k];
                for p in 0..k {
                    for j in 0..n {
                        bt[j * k + p] = b[p * n + j];
                    }
                }
                let mut c2 = vec![0.0; m * n];
                sgemm_bt_with(path, m, k, n, &a, &bt, &mut c2, 0.0);
                assert_eq!(c2, want, "{path:?} sgemm_bt ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn packing_pads_remainder_panels_with_zeros() {
        // n = 11 → two panels; the second covers columns 8..11 + 5 pad
        // lanes that must be exactly zero (they feed real FMAs).
        let (k, n) = (3, 11);
        let b = fill(7, k * n);
        let mut out = vec![f32::NAN; 1]; // stale contents must be cleared
        pack_b(k, n, &b, false, &mut out);
        assert_eq!(out.len(), 2 * k * LANES);
        for p in 0..k {
            for j in 0..LANES {
                assert_eq!(out[p * LANES + j], b[p * n + j], "panel 0 ({p},{j})");
            }
            for dj in 0..LANES {
                let j = LANES + dj;
                let want = if j < n { b[p * n + j] } else { 0.0 };
                assert_eq!(out[(k + p) * LANES + dj], want, "panel 1 ({p},{dj})");
            }
        }
    }

    #[test]
    fn epilogue_runs_once_per_row_with_correct_product() {
        let (m, k, n) = (6, 5, 11);
        let a = fill(1, m * k);
        let b = fill(2, k * n);
        let bias = fill(3, n);
        let want = {
            let mut w = naive(m, k, n, &a, &b);
            for row in w.chunks_mut(n) {
                for (x, bj) in row.iter_mut().zip(&bias) {
                    *x += *bj;
                }
            }
            w
        };
        let mut c = vec![0.0; m * n];
        sgemm_epi(m, k, n, &a, &b, &mut c, &|_, row| {
            for (x, bj) in row.iter_mut().zip(&bias) {
                *x += *bj;
            }
        });
        assert_close(&c, &want, "sgemm_epi");
    }

    #[test]
    fn epilogue2_fills_both_buffers() {
        let (m, k, n) = (7, 4, 9);
        let a = fill(4, m * k);
        let b = fill(5, k * n);
        let z_want = naive(m, k, n, &a, &b);
        let mut z = vec![0.0; m * n];
        let mut y = vec![0.0; m * n];
        sgemm_epi2(m, k, n, &a, &b, &mut z, &mut y, &|_, zrow, yrow| {
            for (zj, yj) in zrow.iter().zip(yrow.iter_mut()) {
                *yj = 2.0 * *zj;
            }
        });
        assert_close(&z, &z_want, "epi2 z");
        let y_want: Vec<f32> = z_want.iter().map(|v| 2.0 * v).collect();
        assert_close(&y, &y_want, "epi2 y");
    }

    #[test]
    fn mul_adds_counter_is_recorded_at_gemm_entry() {
        let _g = crate::obs::test_guard();
        crate::obs::disable();
        crate::obs::reset();
        crate::obs::enable();
        let (m, k, n) = (3, 4, 5);
        let a = fill(1, m * k);
        let b = fill(2, k * n);
        let mut c = vec![0.0; m * n];
        sgemm(m, k, n, &a, &b, &mut c, 0.0);
        crate::obs::disable();
        let events = crate::obs::take();
        let total: f64 = events
            .iter()
            .filter(|e| e.name == "gemm.mul_adds")
            .map(|e| match e.kind {
                crate::obs::EventKind::Counter(v) => v,
                _ => 0.0,
            })
            .sum();
        assert_eq!(total, (m * k * n) as f64);
        crate::obs::reset();
    }

    #[test]
    fn note_dispatch_emits_the_path_name() {
        let _g = crate::obs::test_guard();
        crate::obs::disable();
        crate::obs::reset();
        crate::obs::enable();
        note_dispatch();
        crate::obs::disable();
        let events = crate::obs::take();
        let ev = events.iter().find(|e| e.name == "kernel.dispatch").expect("dispatch event");
        assert_eq!(ev.detail.as_deref(), Some(kernel_path().name()));
        crate::obs::reset();
    }

    #[test]
    fn degenerate_shapes_are_no_ops() {
        let mut c: Vec<f32> = vec![];
        sgemm(0, 3, 4, &[], &fill(1, 12), &mut c, 0.0);
        sgemm(3, 4, 0, &fill(1, 12), &[], &mut c, 0.0);
        // k = 0: the product is empty, so c = beta·c
        for path in paths() {
            let mut cc = vec![7.0f32; 6];
            sgemm_with(path, 2, 0, 3, &[], &[], &mut cc, 0.0);
            assert_eq!(cc, vec![0.0; 6], "{path:?} k=0 beta=0");
        }
    }
}
