//! Small blocked SGEMM for the pure-Rust MLP (cross-check path and
//! XLA-free tests).  The production hot path runs GEMMs inside the AOT HLO;
//! this one only needs to be correct and reasonably fast.
//!
//! Two performance features, both value-preserving:
//!
//! * a zero-skip fast path (`a` entries that are exactly 0 skip their `b`
//!   row), guarded so it only fires when `b` is entirely finite —
//!   `0 * NaN = NaN` and `0 * Inf = NaN` must poison the output, not be
//!   silently dropped.  The finiteness scan runs lazily on the first
//!   zero encountered, so zero-free GEMMs pay nothing for the guard;
//! * row-blocked parallelism for large outputs ([`set_gemm_workers`]):
//!   each worker computes a disjoint block of `c` rows with the *same*
//!   per-row arithmetic as the serial loop, so the result is bitwise
//!   identical for any worker count.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker threads [`sgemm`] may use for large outputs (process-wide; set
/// from `--workers` / `PNODE_WORKERS`).  1 disables parallelism.
static GEMM_WORKERS: AtomicUsize = AtomicUsize::new(1);

pub fn set_gemm_workers(n: usize) {
    GEMM_WORKERS.store(n.max(1), Ordering::Relaxed);
}

pub fn gemm_workers() -> usize {
    GEMM_WORKERS.load(Ordering::Relaxed)
}

/// Row-blocking only pays above this many output rows...
const PAR_MIN_ROWS: usize = 64;
/// ...and this many multiply-adds (thread spawn is a few tens of µs).
const PAR_MIN_MULADDS: u64 = 1 << 21;

/// Lazily computed "is `b` entirely finite" — the zero-skip gate.  The
/// scan costs O(k·n), so it only runs if a zero in `a` is actually
/// encountered; GEMMs whose `a` has no exact zeros pay nothing.
#[derive(Clone, Copy, Default)]
struct BFinite(Option<bool>);

impl BFinite {
    #[inline]
    fn check(&mut self, b: &[f32]) -> bool {
        *self.0.get_or_insert_with(|| b.iter().all(|x| x.is_finite()))
    }
}

/// The serial ikj kernel over output rows `[i0, i0 + rows)` of c.
fn sgemm_rows(i0: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    if n == 0 {
        return;
    }
    let mut b_finite = BFinite::default();
    let rows = c.len() / n;
    for r in 0..rows {
        let i = i0 + r;
        let crow = &mut c[r * n..(r + 1) * n];
        for p in 0..k {
            let aval = a[i * k + p];
            if aval == 0.0 && b_finite.check(b) {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += aval * brow[j];
            }
        }
    }
}

/// c[m,n] (+)= a[m,k] @ b[k,n];  row-major, `beta` scales existing c.
pub fn sgemm(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    beta: f32,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for x in c.iter_mut() {
            *x *= beta;
        }
    }
    let workers = gemm_workers();
    if workers > 1 && m >= PAR_MIN_ROWS && (m as u64) * (k as u64) * (n as u64) >= PAR_MIN_MULADDS
    {
        // row-blocked: disjoint c row blocks, identical per-row arithmetic
        let rows_per = m.div_ceil(workers);
        std::thread::scope(|s| {
            for (bi, cblock) in c.chunks_mut(rows_per * n).enumerate() {
                s.spawn(move || sgemm_rows(bi * rows_per, k, n, a, b, cblock));
            }
        });
        return;
    }
    sgemm_rows(0, k, n, a, b, c);
}

/// c[m,n] (+)= a^T[m,k] @ b[k,n] where a is stored [k,m] row-major.
pub fn sgemm_at(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32], // [k, m]
    b: &[f32], // [k, n]
    c: &mut [f32],
    beta: f32,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for x in c.iter_mut() {
            *x *= beta;
        }
    }
    let mut b_finite = BFinite::default();
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let aval = arow[i];
            if aval == 0.0 && b_finite.check(b) {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aval * brow[j];
            }
        }
    }
}

/// c[m,n] (+)= a[m,k] @ b^T[k,n] where b is stored [n,k] row-major.
pub fn sgemm_bt(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32], // [m, k]
    b: &[f32], // [n, k]
    c: &mut [f32],
    beta: f32,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for x in c.iter_mut() {
            *x *= beta;
        }
    }
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            crow[j] += acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn fill(seed: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| ((i * 31 + seed * 17) % 13) as f32 - 6.0).collect()
    }

    #[test]
    fn sgemm_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (8, 8, 8), (13, 21, 7)] {
            let a = fill(1, m * k);
            let b = fill(2, k * n);
            let mut c = vec![0.0; m * n];
            sgemm(m, k, n, &a, &b, &mut c, 0.0);
            assert_eq!(c, naive(m, k, n, &a, &b), "({m},{k},{n})");
        }
    }

    #[test]
    fn sgemm_beta_accumulates() {
        let a = fill(1, 4);
        let b = fill(2, 4);
        let mut c = vec![1.0; 4];
        sgemm(2, 2, 2, &a, &b, &mut c, 1.0);
        let mut want = naive(2, 2, 2, &a, &b);
        for w in want.iter_mut() {
            *w += 1.0;
        }
        assert_eq!(c, want);
    }

    #[test]
    fn zero_skip_does_not_swallow_non_finite_b() {
        // regression: `a` entries that are exactly 0 used to skip their
        // `b` row unconditionally, silently dropping 0·NaN / 0·Inf
        for poison in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            // c[0,0] = 0·poison + 1·3, c[0,1] = 0·2 + 1·4
            let a = vec![0.0f32, 1.0];
            let b = vec![poison, 2.0, 3.0, 4.0];
            let mut c = vec![0.0f32; 2];
            sgemm(1, 2, 2, &a, &b, &mut c, 0.0);
            assert!(c[0].is_nan(), "0·{poison} must poison the output, got {}", c[0]);
            assert_eq!(c[1], 4.0, "finite columns are unaffected");

            // a^T variant: same contraction, a stored [k=2, m=1]
            let at = vec![0.0f32, 1.0];
            let mut c2 = vec![0.0f32; 2];
            sgemm_at(1, 2, 2, &at, &b, &mut c2, 0.0);
            assert!(c2[0].is_nan(), "sgemm_at 0·{poison} must poison");
            assert_eq!(c2[1], 4.0);
        }
        // the skip still fires on finite inputs: -0.0 + 0·x keeps its sign
        // only when skipped, which pins the fast path as actually taken
        let a = vec![0.0f32];
        let b = vec![5.0f32];
        let mut c = vec![-0.0f32];
        sgemm(1, 1, 1, &a, &b, &mut c, 1.0);
        assert!(c[0] == 0.0 && c[0].is_sign_negative(), "skip taken for finite b");
    }

    #[test]
    fn parallel_rows_are_bitwise_identical_to_serial() {
        // above both thresholds: 256 rows, 256·96·96 ≈ 2.4M mul-adds
        let (m, k, n) = (256, 96, 96);
        let a = fill(5, m * k);
        let b = fill(6, k * n);
        let mut serial = vec![0.0f32; m * n];
        sgemm(m, k, n, &a, &b, &mut serial, 0.0);
        for workers in [2usize, 3, 4] {
            set_gemm_workers(workers);
            let mut par = vec![0.5f32; m * n];
            sgemm(m, k, n, &a, &b, &mut par, 0.0);
            set_gemm_workers(1);
            assert_eq!(par, serial, "workers={workers}: row blocks must not change bits");
        }
    }

    #[test]
    fn transposed_variants_match() {
        let (m, k, n) = (5, 7, 3);
        let a = fill(3, m * k);
        let b = fill(4, k * n);
        let want = naive(m, k, n, &a, &b);

        // a stored transposed [k,m]
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let mut c = vec![0.0; m * n];
        sgemm_at(m, k, n, &at, &b, &mut c, 0.0);
        assert_eq!(c, want);

        // b stored transposed [n,k]
        let mut bt = vec![0.0; n * k];
        for p in 0..k {
            for j in 0..n {
                bt[j * k + p] = b[p * n + j];
            }
        }
        let mut c2 = vec![0.0; m * n];
        sgemm_bt(m, k, n, &a, &bt, &mut c2, 0.0);
        assert_eq!(c2, want);
    }
}
