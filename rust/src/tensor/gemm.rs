//! Small blocked SGEMM for the pure-Rust MLP (cross-check path and
//! XLA-free tests).  The production hot path runs GEMMs inside the AOT HLO;
//! this one only needs to be correct and reasonably fast.

/// c[m,n] (+)= a[m,k] @ b[k,n];  row-major, `beta` scales existing c.
pub fn sgemm(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    beta: f32,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for x in c.iter_mut() {
            *x *= beta;
        }
    }
    // ikj loop order: unit-stride inner loop over b and c rows.
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let aval = a[i * k + p];
            if aval == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += aval * brow[j];
            }
        }
    }
}

/// c[m,n] (+)= a^T[m,k] @ b[k,n] where a is stored [k,m] row-major.
pub fn sgemm_at(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32], // [k, m]
    b: &[f32], // [k, n]
    c: &mut [f32],
    beta: f32,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for x in c.iter_mut() {
            *x *= beta;
        }
    }
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let aval = arow[i];
            if aval == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aval * brow[j];
            }
        }
    }
}

/// c[m,n] (+)= a[m,k] @ b^T[k,n] where b is stored [n,k] row-major.
pub fn sgemm_bt(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32], // [m, k]
    b: &[f32], // [n, k]
    c: &mut [f32],
    beta: f32,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for x in c.iter_mut() {
            *x *= beta;
        }
    }
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            crow[j] += acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn fill(seed: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| ((i * 31 + seed * 17) % 13) as f32 - 6.0).collect()
    }

    #[test]
    fn sgemm_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (8, 8, 8), (13, 21, 7)] {
            let a = fill(1, m * k);
            let b = fill(2, k * n);
            let mut c = vec![0.0; m * n];
            sgemm(m, k, n, &a, &b, &mut c, 0.0);
            assert_eq!(c, naive(m, k, n, &a, &b), "({m},{k},{n})");
        }
    }

    #[test]
    fn sgemm_beta_accumulates() {
        let a = fill(1, 4);
        let b = fill(2, 4);
        let mut c = vec![1.0; 4];
        sgemm(2, 2, 2, &a, &b, &mut c, 1.0);
        let mut want = naive(2, 2, 2, &a, &b);
        for w in want.iter_mut() {
            *w += 1.0;
        }
        assert_eq!(c, want);
    }

    #[test]
    fn transposed_variants_match() {
        let (m, k, n) = (5, 7, 3);
        let a = fill(3, m * k);
        let b = fill(4, k * n);
        let want = naive(m, k, n, &a, &b);

        // a stored transposed [k,m]
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let mut c = vec![0.0; m * n];
        sgemm_at(m, k, n, &at, &b, &mut c, 0.0);
        assert_eq!(c, want);

        // b stored transposed [n,k]
        let mut bt = vec![0.0; n * k];
        for p in 0..k {
            for j in 0..n {
                bt[j * k + p] = b[p * n + j];
            }
        }
        let mut c2 = vec![0.0; m * n];
        sgemm_bt(m, k, n, &a, &bt, &mut c2, 0.0);
        assert_eq!(c2, want);
    }
}
