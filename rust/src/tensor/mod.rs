//! Flat f32 vector/matrix kernels used by the L3 hot loop.
//!
//! ODE states, adjoint variables, and parameter vectors are flat `Vec<f32>`;
//! the vector helpers below are written to autovectorise and allocate
//! nothing, and [`gemm`] is the production matrix kernel the whole crate
//! bottoms out in (the optional `xla` feature, off by default, is the
//! only path that runs GEMMs elsewhere).

pub mod gemm;

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// y = x
#[inline]
pub fn copy(x: &[f32], y: &mut [f32]) {
    y.copy_from_slice(x);
}

/// x *= a
#[inline]
pub fn scal(a: f32, x: &mut [f32]) {
    for xi in x {
        *xi *= a;
    }
}

/// out = x + a*y  (no aliasing)
#[inline]
pub fn waxpy(out: &mut [f32], x: &[f32], a: f32, y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for i in 0..out.len() {
        out[i] = x[i] + a * y[i];
    }
}

/// <x, y>
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    // f64 accumulation: GMRES orthogonalisation is sensitive to this.
    x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
}

/// ||x||_2
#[inline]
pub fn nrm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// ||x||_inf
#[inline]
pub fn nrm_inf(x: &[f32]) -> f64 {
    x.iter().fold(0.0f64, |m, &v| m.max(v.abs() as f64))
}

/// x = 0
#[inline]
pub fn zero(x: &mut [f32]) {
    x.fill(0.0);
}

/// Weighted RMS norm used by adaptive step-size control:
/// sqrt(mean_i (x_i / (atol + rtol*|ref_i|))^2)
pub fn wrms_norm(x: &[f32], reference: &[f32], atol: f64, rtol: f64) -> f64 {
    debug_assert_eq!(x.len(), reference.len());
    let mut acc = 0.0f64;
    for i in 0..x.len() {
        let w = atol + rtol * (reference[i].abs() as f64);
        let r = x[i] as f64 / w;
        acc += r * r;
    }
    (acc / x.len() as f64).sqrt()
}

/// Max |x - y| (test helper and convergence checks).
pub fn max_abs_diff(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .fold(0.0f64, |m, (a, b)| m.max((a - b).abs() as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }

    #[test]
    fn waxpy_no_alias() {
        let x = [1.0, 2.0];
        let y = [10.0, 20.0];
        let mut out = [0.0; 2];
        waxpy(&mut out, &x, 0.5, &y);
        assert_eq!(out, [6.0, 12.0]);
    }

    #[test]
    fn norms() {
        let x = [3.0, -4.0];
        assert!((nrm2(&x) - 5.0).abs() < 1e-12);
        assert_eq!(nrm_inf(&x), 4.0);
        assert!((dot(&x, &x) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn wrms() {
        let e = [0.1, 0.1];
        let r = [1.0, 1.0];
        // w = 0.1 + 0.1*1 = 0.2, ratio = 0.5 each -> rms 0.5
        let n = wrms_norm(&e, &r, 0.1, 0.1);
        assert!((n - 0.5).abs() < 1e-6);
    }
}
