//! Persistent run ledger: an append-only JSONL store under
//! `.pnode/ledger/` (DESIGN.md §13).
//!
//! Every observed `pnode run` appends one [`RunRecord`] — the serialized
//! [`crate::api::RunSpec`], the run's `ExperimentRow`, the metrics fold,
//! the live memcheck, and a git-describe-style [`build_tag`] — as one
//! compact JSON object per line.  The format is the durability layer the
//! rest of the PR builds on: `pnode report` folds per-phase wall times
//! over it, and [`crate::obs::calibrate::CostModel`] fits its time
//! constants from it to resolve `auto:<budget>` policies.
//!
//! JSONL was chosen over one growing array because appends are O(record)
//! (open in append mode, write one line), a torn final line from a
//! crashed run corrupts nothing before it, and external tooling can
//! stream it line-by-line.  Round-trips go through `util/json`, so a
//! record read back equals the record written (asserted in
//! `tests/ledger_auto.rs`).

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// File name of the JSONL store inside the ledger dir.
pub const LEDGER_FILE: &str = "runs.jsonl";

/// Env var overriding the default ledger dir (benches isolate their
/// ledgers with it; unset means `.pnode/ledger` under the CWD).
pub const LEDGER_DIR_ENV: &str = "PNODE_LEDGER_DIR";

/// One persisted run.  The spec/row/metrics payloads are kept as [`Json`]
/// rather than re-typed structs: the ledger is a durability format, and
/// holding the documents verbatim keeps the round-trip lossless even as
/// the row grows columns in later PRs.
#[derive(Clone, Debug, PartialEq)]
pub struct RunRecord {
    /// git-describe-style build tag (see [`build_tag`])
    pub build: String,
    /// the `RunSpec::to_json` document that produced the run
    pub spec: Json,
    /// the run's `ExperimentRow::to_json` document
    pub row: Json,
    /// the metrics fold (`crate::obs::Metrics::to_json`) — the same
    /// serializer `pnode run --metrics json` emits
    pub metrics: Json,
    /// predicted-vs-observed checkpoint bytes (`crate::obs::memcheck`);
    /// absent when the run had no memory model
    pub memcheck: Option<Json>,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("build", Json::str(self.build.clone())),
            ("spec", self.spec.clone()),
            ("row", self.row.clone()),
            ("metrics", self.metrics.clone()),
        ];
        if let Some(mc) = &self.memcheck {
            kv.push(("memcheck", mc.clone()));
        }
        Json::obj(kv)
    }

    pub fn from_json(v: &Json) -> Result<RunRecord, String> {
        let req = |key: &str| {
            v.get(key)
                .cloned()
                .ok_or_else(|| format!("ledger record is missing {key:?}"))
        };
        Ok(RunRecord {
            build: req("build")?
                .as_str()
                .ok_or("ledger record \"build\" must be a string")?
                .to_string(),
            spec: req("spec")?,
            row: req("row")?,
            metrics: req("metrics")?,
            memcheck: v.get("memcheck").cloned(),
        })
    }
}

/// Handle on one ledger directory.  `open` creates the directory;
/// records live in `<dir>/runs.jsonl`.
#[derive(Clone, Debug)]
pub struct Ledger {
    dir: PathBuf,
}

impl Ledger {
    /// Open (creating if needed) the ledger at `dir`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Ledger, String> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .map_err(|e| format!("cannot create ledger dir {}: {e}", dir.display()))?;
        Ok(Ledger { dir })
    }

    /// The process-default ledger dir: `$PNODE_LEDGER_DIR`, else
    /// `.pnode/ledger` under the CWD.
    pub fn default_dir() -> PathBuf {
        match std::env::var(LEDGER_DIR_ENV) {
            Ok(d) if !d.is_empty() => PathBuf::from(d),
            _ => PathBuf::from(".pnode/ledger"),
        }
    }

    /// Open the process-default ledger (see [`Ledger::default_dir`]).
    pub fn open_default() -> Result<Ledger, String> {
        Ledger::open(Ledger::default_dir())
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of the JSONL file (which may not exist yet — an empty ledger
    /// has the dir but no file).
    pub fn path(&self) -> PathBuf {
        self.dir.join(LEDGER_FILE)
    }

    /// Append one record as a single compact JSON line.
    pub fn append(&self, rec: &RunRecord) -> Result<(), String> {
        let path = self.path();
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| format!("cannot open ledger {}: {e}", path.display()))?;
        writeln!(f, "{}", rec.to_json().to_string_compact())
            .map_err(|e| format!("cannot append to ledger {}: {e}", path.display()))
    }

    /// Read every record in append order.  A missing file is an empty
    /// ledger; a malformed line is an error naming its line number.
    pub fn read_all(&self) -> Result<Vec<RunRecord>, String> {
        let path = self.path();
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(format!("cannot read ledger {}: {e}", path.display())),
        };
        let mut out = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let doc = json::parse(line)
                .map_err(|e| format!("{}:{}: bad JSON: {e:?}", path.display(), i + 1))?;
            out.push(
                RunRecord::from_json(&doc)
                    .map_err(|e| format!("{}:{}: {e}", path.display(), i + 1))?,
            );
        }
        Ok(out)
    }
}

/// Git-describe-style build tag without shelling out: `$PNODE_BUILD_TAG`
/// if set, else `<branch>-g<short-hash>` read from `.git/HEAD` (following
/// the ref through loose and packed refs), else `"untagged"`.  Ledger
/// records and `BENCH_micro.json` entries key on it so perf history stays
/// attributable across PRs.
pub fn build_tag() -> String {
    if let Ok(tag) = std::env::var("PNODE_BUILD_TAG") {
        if !tag.is_empty() {
            return tag;
        }
    }
    git_head_tag().unwrap_or_else(|| "untagged".to_string())
}

fn git_head_tag() -> Option<String> {
    let head = std::fs::read_to_string(".git/HEAD").ok()?;
    let head = head.trim();
    if let Some(r) = head.strip_prefix("ref: ") {
        let branch = r.rsplit('/').next().filter(|b| !b.is_empty())?;
        let hash = std::fs::read_to_string(Path::new(".git").join(r))
            .ok()
            .map(|s| s.trim().to_string())
            .filter(|h| !h.is_empty())
            .or_else(|| packed_ref(r))?;
        Some(format!("{branch}-g{}", &hash[..hash.len().min(12)]))
    } else if !head.is_empty() {
        Some(format!("detached-g{}", &head[..head.len().min(12)]))
    } else {
        None
    }
}

fn packed_ref(r: &str) -> Option<String> {
    let packed = std::fs::read_to_string(".git/packed-refs").ok()?;
    for line in packed.lines() {
        if line.starts_with('#') || line.starts_with('^') {
            continue;
        }
        if let Some((hash, name)) = line.split_once(' ') {
            if name.trim() == r && !hash.is_empty() {
                return Some(hash.to_string());
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pnode-ledger-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn rec(i: usize) -> RunRecord {
        RunRecord {
            build: format!("main-g{i:012}"),
            spec: json::parse(&format!("{{\"version\":1,\"method\":\"pnode\",\"nt\":{i}}}"))
                .unwrap(),
            row: json::parse(&format!("{{\"time_secs\":{}.5,\"n\":{i}}}", i + 1)).unwrap(),
            metrics: json::parse("{\"counters\":{\"gemm.mul_adds\":64},\"spans\":{}}").unwrap(),
            memcheck: (i % 2 == 0)
                .then(|| json::parse("{\"predicted_bytes\":10,\"observed_bytes\":9}").unwrap()),
        }
    }

    #[test]
    fn append_read_roundtrip_preserves_order_and_content() {
        let dir = tmp_dir("roundtrip");
        let ledger = Ledger::open(&dir).unwrap();
        assert_eq!(ledger.read_all().unwrap(), vec![], "empty ledger reads as no records");
        let recs: Vec<RunRecord> = (0..3).map(rec).collect();
        for r in &recs {
            ledger.append(r).unwrap();
        }
        assert_eq!(ledger.read_all().unwrap(), recs);
        // a reopened handle sees the same records and keeps appending
        let again = Ledger::open(&dir).unwrap();
        again.append(&rec(3)).unwrap();
        let all = again.read_all().unwrap();
        assert_eq!(all.len(), 4);
        assert_eq!(all[..3], recs[..]);
        assert_eq!(all[3], rec(3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn records_are_one_compact_line_each() {
        let dir = tmp_dir("lines");
        let ledger = Ledger::open(&dir).unwrap();
        ledger.append(&rec(0)).unwrap();
        ledger.append(&rec(1)).unwrap();
        let text = std::fs::read_to_string(ledger.path()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let doc = json::parse(line).unwrap();
            assert!(doc.get("build").is_some() && doc.get("metrics").is_some());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_lines_error_with_line_number() {
        let dir = tmp_dir("malformed");
        let ledger = Ledger::open(&dir).unwrap();
        ledger.append(&rec(0)).unwrap();
        let mut f = std::fs::OpenOptions::new().append(true).open(ledger.path()).unwrap();
        writeln!(f, "{{\"build\":42}}").unwrap();
        drop(f);
        let e = ledger.read_all().unwrap_err();
        assert!(e.contains(":2:"), "{e}");
    }

    #[test]
    fn build_tag_is_nonempty() {
        assert!(!build_tag().is_empty());
    }
}
