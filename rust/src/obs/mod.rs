//! Crate-wide observability: tracing spans + derived metrics, zero deps.
//!
//! Design (DESIGN.md §11): a process-global sink gated by ONE relaxed
//! atomic load when disabled — instrumentation anywhere in the crate is a
//! single branch until someone calls [`enable`].  When enabled, events go
//! to per-thread buffers (lock-free append; the only lock is taken once
//! per thread at flush time) and are merged deterministically at
//! [`take`], keyed by `(logical tid, per-thread sequence)`.  Logical tids
//! are assigned by the caller — the main thread is 0, the execution pool
//! stamps each *job* (not each OS thread) with `job index + 1` via
//! [`job_ctx`] — so the merged event order is identical across runs and
//! across worker counts, even though wall-clock timestamps are not.
//!
//! Everything downstream is a pure fold over the merged stream:
//! [`metrics::Metrics`] derives counters, gauge extrema, span wall-times
//! and log-bucket latency histograms, and per-phase peak bytes;
//! [`export::chrome_trace`] renders Chrome trace-event JSON loadable in
//! Perfetto / `chrome://tracing`.
//!
//! Recording is observation-only: no instrumented code path branches on
//! recorded data, so gradients are bitwise identical with the sink on or
//! off (asserted in `tests/obs_trace.rs`).

pub mod calibrate;
pub mod export;
pub mod ledger;
pub mod metrics;
pub mod trace;

pub use calibrate::CostModel;
pub use export::{chrome_trace, memcheck};
pub use ledger::{build_tag, Ledger, RunRecord};
pub use metrics::{Hist, Metrics};
pub use trace::{
    counter, disable, enable, enabled, gauge, instant, job_ctx, reset, span, stopwatch, take,
    test_guard, warn, Event, EventKind, JobCtx, SpanGuard, Stopwatch,
};

/// Span names of the adjoint phases whose wall-time and peak-bytes are
/// surfaced as `ExperimentRow` columns; byte gauges are attributed to the
/// innermost enclosing span with one of these names.
pub const PHASES: &[&str] = &["forward", "store", "restore", "recompute", "vjp"];
