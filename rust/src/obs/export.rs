//! Exporters: Chrome trace-event JSON (loadable in Perfetto or
//! `chrome://tracing`) and the predicted-vs-observed memory-model check.

use crate::obs::trace::{Event, EventKind};
use crate::util::json::Json;

fn micros(ts_nanos: u64) -> f64 {
    ts_nanos as f64 / 1000.0
}

/// Render the merged event stream as Chrome trace-event JSON:
/// `{"traceEvents": [...], "displayTimeUnit": "ms"}`.  Spans become
/// `"B"`/`"E"` duration events, counters and gauges `"C"` counter
/// events, instants `"i"` with any detail under `args`.  `tid` is the
/// logical obs tid (0 = main, `job + 1` per pool job), so the track
/// layout matches the deterministic merge order.
pub fn chrome_trace(events: &[Event]) -> Json {
    let mut out: Vec<Json> = Vec::with_capacity(events.len());
    for e in events {
        let mut kv: Vec<(&str, Json)> = vec![
            ("name", Json::str(e.name)),
            ("ph", Json::str(phase_of(&e.kind))),
            ("ts", Json::num(micros(e.ts_nanos))),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(e.tid as f64)),
        ];
        match &e.kind {
            EventKind::Counter(v) | EventKind::Gauge(v) => {
                kv.push(("args", Json::obj(vec![("value", Json::num(*v))])));
            }
            EventKind::Instant => {
                kv.push(("s", Json::str("t")));
                if let Some(d) = &e.detail {
                    kv.push(("args", Json::obj(vec![("detail", Json::str(d.clone()))])));
                }
            }
            EventKind::Begin | EventKind::End => {}
        }
        out.push(Json::obj(kv));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

fn phase_of(kind: &EventKind) -> &'static str {
    match kind {
        EventKind::Begin => "B",
        EventKind::End => "E",
        EventKind::Counter(_) | EventKind::Gauge(_) => "C",
        EventKind::Instant => "i",
    }
}

/// Predicted-vs-observed peak-bytes comparison — the paper's Table 2
/// checked against live gauges on every run.  `ratio = observed /
/// predicted` (0 when the model predicts zero bytes).
pub fn memcheck(predicted_bytes: u64, observed_bytes: u64) -> Json {
    let ratio = if predicted_bytes == 0 {
        0.0
    } else {
        observed_bytes as f64 / predicted_bytes as f64
    };
    Json::obj(vec![
        ("predicted_bytes", Json::num(predicted_bytes as f64)),
        ("observed_bytes", Json::num(observed_bytes as f64)),
        ("ratio", Json::num(ratio)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn ev(name: &'static str, kind: EventKind, tid: u32, seq: u64, ts: u64) -> Event {
        Event { name, kind, tid, seq, ts_nanos: ts, detail: None }
    }

    #[test]
    fn chrome_trace_round_trips_through_the_parser() {
        let events = vec![
            ev("forward", EventKind::Begin, 0, 0, 1000),
            ev("ckpt.hot_bytes", EventKind::Gauge(64.0), 0, 1, 1500),
            Event {
                name: "warn.theta_stall",
                kind: EventKind::Instant,
                tid: 0,
                seq: 2,
                ts_nanos: 1600,
                detail: Some("t = 0.5".into()),
            },
            ev("forward", EventKind::End, 0, 3, 2000),
        ];
        let text = chrome_trace(&events).to_string_pretty();
        let back = parse(&text).expect("exporter emits valid JSON");
        let arr = back.get("traceEvents").and_then(|t| t.as_arr()).unwrap();
        assert_eq!(arr.len(), 4);
        for e in arr {
            assert!(e.get("name").and_then(|n| n.as_str()).is_some());
            assert!(e.get("ph").and_then(|p| p.as_str()).is_some());
            assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
            assert!(e.get("pid").is_some() && e.get("tid").is_some());
        }
        assert_eq!(arr[0].get("ph").unwrap().as_str(), Some("B"));
        assert_eq!(arr[1].get("ph").unwrap().as_str(), Some("C"));
        assert_eq!(arr[2].get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(
            arr[2].get("args").unwrap().get("detail").unwrap().as_str(),
            Some("t = 0.5")
        );
        assert_eq!(arr[3].get("ph").unwrap().as_str(), Some("E"));
        // ts is microseconds
        assert_eq!(arr[0].get("ts").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn memcheck_ratio() {
        let j = memcheck(1000, 900);
        assert_eq!(j.get("predicted_bytes").unwrap().as_f64(), Some(1000.0));
        assert_eq!(j.get("observed_bytes").unwrap().as_f64(), Some(900.0));
        assert!((j.get("ratio").unwrap().as_f64().unwrap() - 0.9).abs() < 1e-12);
        assert_eq!(memcheck(0, 5).get("ratio").unwrap().as_f64(), Some(0.0));
    }
}
