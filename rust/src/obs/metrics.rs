//! Metrics derived by folding the merged event stream: counters sum,
//! gauges keep last/max, spans accumulate count + total wall-time + a
//! fixed log-bucket latency histogram, and byte gauges are attributed to
//! the innermost enclosing adjoint phase to give per-phase peaks.
//!
//! Keeping derivation out of the hot path means recording stays a plain
//! buffer append; everything here is replayable from a saved trace.

use crate::obs::trace::{Event, EventKind};
use crate::obs::PHASES;
use crate::util::json::Json;

/// Fixed-size base-2 log-bucket histogram of durations in nanoseconds:
/// bucket `i` holds samples in `[2^i, 2^{i+1})` ns (bucket 0 also takes
/// 0-ns samples).  64 buckets cover every representable duration.
#[derive(Clone, Debug)]
pub struct Hist {
    counts: [u64; 64],
    n: u64,
    sum_nanos: u64,
    max_nanos: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist { counts: [0; 64], n: 0, sum_nanos: 0, max_nanos: 0 }
    }
}

impl Hist {
    fn bucket(nanos: u64) -> usize {
        (63 - nanos.max(1).leading_zeros()) as usize
    }

    pub fn record_nanos(&mut self, nanos: u64) {
        self.counts[Self::bucket(nanos)] += 1;
        self.n += 1;
        self.sum_nanos += nanos;
        self.max_nanos = self.max_nanos.max(nanos);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn total_secs(&self) -> f64 {
        self.sum_nanos as f64 * 1e-9
    }

    pub fn max_secs(&self) -> f64 {
        self.max_nanos as f64 * 1e-9
    }

    pub fn mean_secs(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.total_secs() / self.n as f64
        }
    }

    /// Quantile estimate from the buckets: the upper edge of the bucket
    /// where the cumulative count crosses `q * n`.  Log-bucket accuracy:
    /// within a factor of 2.
    pub fn quantile_secs(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.n as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return (1u64 << (i + 1).min(63)) as f64 * 1e-9;
            }
        }
        self.max_secs()
    }

    /// Nonzero buckets as `[{"le_nanos", "count"}, ...]` (upper edges).
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                Json::obj(vec![
                    ("le_nanos", Json::num((1u128 << (i + 1)).min(u64::MAX as u128) as f64)),
                    ("count", Json::num(c as f64)),
                ])
            })
            .collect();
        Json::Arr(buckets)
    }
}

/// Last and max sample of a gauge.
#[derive(Clone, Copy, Debug, Default)]
pub struct GaugeStat {
    pub last: f64,
    pub max: f64,
}

/// Aggregate of all spans sharing one name.
#[derive(Clone, Debug, Default)]
pub struct SpanStat {
    pub count: u64,
    pub hist: Hist,
}

impl SpanStat {
    pub fn total_secs(&self) -> f64 {
        self.hist.total_secs()
    }
}

/// The flat metrics view of one run.  All maps are name-sorted vectors
/// so JSON output is deterministic.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub counters: Vec<(String, f64)>,
    pub gauges: Vec<(String, GaugeStat)>,
    pub spans: Vec<(String, SpanStat)>,
    /// peak value of `*bytes*` gauges per innermost enclosing phase span
    /// (see [`crate::obs::PHASES`])
    pub phase_peak_bytes: Vec<(String, u64)>,
}

fn upsert<T: Default>(v: &mut Vec<(String, T)>, name: &str) -> usize {
    match v.binary_search_by(|(k, _)| k.as_str().cmp(name)) {
        Ok(i) => i,
        Err(i) => {
            v.insert(i, (name.to_string(), T::default()));
            i
        }
    }
}

fn get<'a, T>(v: &'a [(String, T)], name: &str) -> Option<&'a T> {
    v.binary_search_by(|(k, _)| k.as_str().cmp(name)).ok().map(|i| &v[i].1)
}

impl Metrics {
    /// Fold a `(tid, seq)`-merged event stream (the output of
    /// [`crate::obs::take`]) into metrics.
    pub fn from_events(events: &[Event]) -> Metrics {
        let mut m = Metrics::default();
        // per-tid span stacks: (name, begin ts)
        let mut stacks: Vec<(u32, Vec<(&'static str, u64)>)> = Vec::new();
        for e in events {
            let si = match stacks.iter().position(|(t, _)| *t == e.tid) {
                Some(i) => i,
                None => {
                    stacks.push((e.tid, Vec::new()));
                    stacks.len() - 1
                }
            };
            let stack = &mut stacks[si].1;
            match &e.kind {
                EventKind::Begin => stack.push((e.name, e.ts_nanos)),
                EventKind::End => {
                    // pop to the matching Begin; unmatched Ends are dropped
                    if let Some(pos) = stack.iter().rposition(|(n, _)| *n == e.name) {
                        let (_, t0) = stack.remove(pos);
                        let i = upsert::<SpanStat>(&mut m.spans, e.name);
                        let s = &mut m.spans[i].1;
                        s.count += 1;
                        s.hist.record_nanos(e.ts_nanos.saturating_sub(t0));
                    }
                }
                EventKind::Counter(v) => {
                    let i = upsert::<f64>(&mut m.counters, e.name);
                    m.counters[i].1 += v;
                }
                EventKind::Gauge(v) => {
                    let i = upsert::<GaugeStat>(&mut m.gauges, e.name);
                    let g = &mut m.gauges[i].1;
                    g.last = *v;
                    g.max = g.max.max(*v);
                    if e.name.contains("bytes") {
                        if let Some(phase) =
                            stack.iter().rev().map(|(n, _)| *n).find(|n| PHASES.contains(n))
                        {
                            let i = upsert::<u64>(&mut m.phase_peak_bytes, phase);
                            let p = &mut m.phase_peak_bytes[i].1;
                            *p = (*p).max(*v as u64);
                        }
                    }
                }
                EventKind::Instant => {
                    let i = upsert::<f64>(&mut m.counters, e.name);
                    m.counters[i].1 += 1.0;
                }
            }
        }
        m
    }

    pub fn counter(&self, name: &str) -> f64 {
        get(&self.counters, name).copied().unwrap_or(0.0)
    }

    pub fn gauge(&self, name: &str) -> GaugeStat {
        get(&self.gauges, name).copied().unwrap_or_default()
    }

    /// Total wall-time of all spans with this name, in seconds.
    pub fn span_total_secs(&self, name: &str) -> f64 {
        get(&self.spans, name).map(|s| s.total_secs()).unwrap_or(0.0)
    }

    pub fn span_count(&self, name: &str) -> u64 {
        get(&self.spans, name).map(|s| s.count).unwrap_or(0)
    }

    /// Peak bytes observed while the named phase span was innermost.
    pub fn phase_peak(&self, phase: &str) -> u64 {
        get(&self.phase_peak_bytes, phase).copied().unwrap_or(0)
    }

    /// The flat metrics JSON merged into `ExperimentRow` / printed by
    /// `pnode run --metrics`.
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters.iter().map(|(k, v)| (k.clone(), Json::num(*v))).collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|(k, g)| {
                    (
                        k.clone(),
                        Json::obj(vec![("last", Json::num(g.last)), ("max", Json::num(g.max))]),
                    )
                })
                .collect(),
        );
        let spans = Json::Obj(
            self.spans
                .iter()
                .map(|(k, s)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::num(s.count as f64)),
                            ("total_secs", Json::num(s.total_secs())),
                            ("mean_secs", Json::num(s.hist.mean_secs())),
                            ("p50_secs", Json::num(s.hist.quantile_secs(0.5))),
                            ("p99_secs", Json::num(s.hist.quantile_secs(0.99))),
                            ("max_secs", Json::num(s.hist.max_secs())),
                            ("hist", s.hist.to_json()),
                        ]),
                    )
                })
                .collect(),
        );
        let phases = Json::Obj(
            self.phase_peak_bytes
                .iter()
                .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("spans", spans),
            ("phase_peak_bytes", phases),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &'static str, kind: EventKind, tid: u32, seq: u64, ts: u64) -> Event {
        Event { name, kind, tid, seq, ts_nanos: ts, detail: None }
    }

    #[test]
    fn hist_buckets_and_quantiles() {
        let mut h = Hist::default();
        for ns in [1u64, 2, 3, 1000, 1_000_000] {
            h.record_nanos(ns);
        }
        assert_eq!(h.count(), 5);
        assert!(h.max_secs() >= 1e-3 - 1e-12);
        assert!(h.quantile_secs(0.5) > 0.0);
        assert!(h.quantile_secs(1.0) >= h.quantile_secs(0.5));
        // bucket edges: 1 -> bucket 0, 2..3 -> bucket 1
        assert_eq!(Hist::bucket(0), 0);
        assert_eq!(Hist::bucket(1), 0);
        assert_eq!(Hist::bucket(2), 1);
        assert_eq!(Hist::bucket(3), 1);
        assert_eq!(Hist::bucket(4), 2);
    }

    #[test]
    fn fold_derives_counters_spans_and_phase_peaks() {
        let events = vec![
            ev("forward", EventKind::Begin, 0, 0, 100),
            ev("store", EventKind::Begin, 0, 1, 150),
            ev("ckpt.hot_bytes", EventKind::Gauge(4096.0), 0, 2, 160),
            ev("store", EventKind::End, 0, 3, 200),
            ev("ckpt.hot_bytes", EventKind::Gauge(1024.0), 0, 4, 210),
            ev("nfe", EventKind::Counter(3.0), 0, 5, 220),
            ev("nfe", EventKind::Counter(2.0), 0, 6, 230),
            ev("warn.stall", EventKind::Instant, 0, 7, 240),
            ev("forward", EventKind::End, 0, 8, 300),
        ];
        let m = Metrics::from_events(&events);
        assert_eq!(m.counter("nfe"), 5.0);
        assert_eq!(m.counter("warn.stall"), 1.0);
        assert_eq!(m.span_count("store"), 1);
        assert!((m.span_total_secs("store") - 50e-9).abs() < 1e-15);
        assert!((m.span_total_secs("forward") - 200e-9).abs() < 1e-15);
        // 4096 sampled inside store (innermost phase), 1024 inside forward
        assert_eq!(m.phase_peak("store"), 4096);
        assert_eq!(m.phase_peak("forward"), 1024);
        let g = m.gauge("ckpt.hot_bytes");
        assert_eq!(g.max, 4096.0);
        assert_eq!(g.last, 1024.0);
    }

    #[test]
    fn metrics_json_is_deterministically_ordered() {
        let events = vec![
            ev("b.count", EventKind::Counter(1.0), 0, 0, 0),
            ev("a.count", EventKind::Counter(1.0), 0, 1, 1),
        ];
        let m = Metrics::from_events(&events);
        let s = m.to_json().to_string_compact();
        let a = s.find("a.count").unwrap();
        let b = s.find("b.count").unwrap();
        assert!(a < b, "name-sorted output: {s}");
    }
}
