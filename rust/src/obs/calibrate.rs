//! Telemetry-calibrated cost model + `auto:<budget>` policy resolution
//! (DESIGN.md §13).
//!
//! [`CostModel`] extends the paper's Table-2 memory model
//! ([`crate::methods::MemModel`]) into *time*: per-phase seconds per call,
//! checkpoint store/restore bandwidth, and tier spill/prefetch bandwidth.
//! Each constant is fit as the median over persisted
//! [`crate::obs::ledger`] records (robust to one slow outlier run) and
//! falls back to a documented prior when the ledger is cold, so
//! `auto:<budget>` resolves deterministically on a fresh checkout and
//! sharpens as real telemetry accumulates.
//!
//! Resolution enumerates a fixed candidate list — `All`, `SolutionOnly`,
//! `Binomial(k)` over a doubling k grid, and `tiered:{budget}[+f16]`
//! around an `All` placement — predicts peak hot-tier bytes and wall
//! seconds for each, rejects candidates whose predicted peak exceeds the
//! budget, and picks the cheapest survivor (first wins ties, so the
//! outcome is deterministic given a fixed ledger).

use crate::api::spec::RunSpec;
use crate::checkpoint::{prop2_extra_steps, CheckpointPolicy};
use crate::obs::ledger::{Ledger, RunRecord};
use crate::obs::PHASES;
use crate::util::json::Json;

/// Spill directory used by auto-resolved tiered candidates.  Fixed (not
/// configurable per spec) so the resolution is fully described by
/// `{budget, f16}` and [`crate::methods::AutoNote`] can stay `Copy`.
pub const AUTO_SPILL_DIR: &str = ".pnode/spill";

/// Default wall-time regression threshold of `pnode report`: a phase
/// whose last-run time exceeds the ledger baseline median by more than
/// this fraction is flagged `REGRESSED`.
pub const REGRESSION_THRESHOLD: f64 = 0.25;

/// Time-and-memory cost model.  All terms are per *one* gradient
/// (forward + adjoint sweep); see DESIGN.md §13 for the prediction
/// formula and the priors' provenance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// seconds per span call of each adjoint phase, in [`PHASES`] order
    /// (`forward`, `store`, `restore`, `recompute`, `vjp`)
    pub phase_secs: [f64; 5],
    /// checkpoint store bandwidth (bytes/sec through the hot tier)
    pub store_bytes_per_sec: f64,
    /// checkpoint restore bandwidth (bytes/sec)
    pub restore_bytes_per_sec: f64,
    /// tier spill-to-disk bandwidth (bytes/sec)
    pub spill_bytes_per_sec: f64,
    /// tier prefetch-from-disk bandwidth (bytes/sec)
    pub prefetch_bytes_per_sec: f64,
    /// bytes of one stored checkpoint vector (solution or stage slot)
    pub vec_bytes: f64,
    /// executed steps of a typical run (stands in for `nt` when the
    /// spec's grid is adaptive)
    pub typical_nt: f64,
    /// ledger records the fit consumed (0 ⇒ pure priors)
    pub samples: usize,
}

impl CostModel {
    /// Documented priors for a cold ledger: microsecond-scale phase steps
    /// sized for the crate's default MLP benchmarks, RAM-copy store
    /// bandwidth well above disk spill bandwidth (so recomputation is
    /// preferred over spilling until telemetry says otherwise), and one
    /// 32 KiB checkpoint vector (a 128x64 f32 state).
    pub fn priors() -> CostModel {
        CostModel {
            phase_secs: [50e-6, 5e-6, 5e-6, 50e-6, 100e-6],
            store_bytes_per_sec: 4e9,
            restore_bytes_per_sec: 4e9,
            spill_bytes_per_sec: 1e9,
            prefetch_bytes_per_sec: 2e9,
            vec_bytes: 32_768.0,
            typical_nt: 16.0,
            samples: 0,
        }
    }

    /// Fit the model over ledger records: each term is the median of the
    /// per-record estimates that could be derived (a record without tier
    /// spans simply contributes nothing to the spill terms), and terms
    /// with no estimates keep their prior.
    pub fn fit(records: &[RunRecord]) -> CostModel {
        let mut m = CostModel::priors();
        let mut phase: [Vec<f64>; 5] = Default::default();
        let mut store = Vec::new();
        let mut restore = Vec::new();
        let mut spill = Vec::new();
        let mut prefetch = Vec::new();
        let mut vecb = Vec::new();
        let mut nts = Vec::new();
        for r in records {
            for (i, name) in PHASES.iter().enumerate() {
                if let Some(per_call) = span_per_call_secs(&r.metrics, name) {
                    phase[i].push(per_call);
                }
            }
            let row_f64 = |key: &str| r.row.get(key).and_then(Json::as_f64).filter(|x| *x > 0.0);
            let ckpt_bytes = row_f64("measured_ckpt_bytes");
            if let (Some(b), Some(t)) = (ckpt_bytes, span_total_secs(&r.metrics, "store")) {
                store.push(b / t);
            }
            if let (Some(b), Some(t)) = (ckpt_bytes, span_total_secs(&r.metrics, "restore")) {
                restore.push(b / t);
            }
            let cold = row_f64("ckpt_cold_bytes");
            if let (Some(b), Some(t)) = (cold, span_total_secs(&r.metrics, "tier.spill")) {
                spill.push(b / t);
            }
            if let (Some(b), Some(t)) = (cold, span_total_secs(&r.metrics, "tier.prefetch_wait"))
            {
                prefetch.push(b / t);
            }
            // per-vector bytes: measured checkpoint residency over the
            // stored-vector count the record's own spec implies
            if let (Some(b), Some(nt), Some(spec)) =
                (ckpt_bytes, row_f64("n_accepted"), record_policy(r))
            {
                let n_stages = record_n_stages(r);
                let v = stored_vectors(&spec, nt as u64, n_stages);
                if v > 0 {
                    vecb.push(b / v as f64);
                }
            }
            if let Some(nt) = row_f64("n_accepted") {
                nts.push(nt);
            }
        }
        for (i, samples) in phase.iter_mut().enumerate() {
            if let Some(x) = median(samples) {
                m.phase_secs[i] = x;
            }
        }
        if let Some(x) = median(&mut store) {
            m.store_bytes_per_sec = x;
        }
        if let Some(x) = median(&mut restore) {
            m.restore_bytes_per_sec = x;
        }
        if let Some(x) = median(&mut spill) {
            m.spill_bytes_per_sec = x;
        }
        if let Some(x) = median(&mut prefetch) {
            m.prefetch_bytes_per_sec = x;
        }
        if let Some(x) = median(&mut vecb) {
            m.vec_bytes = x;
        }
        if let Some(x) = median(&mut nts) {
            m.typical_nt = x;
        }
        m.samples = records.len();
        m
    }

    /// Fit against the process-default ledger; an unreadable or cold
    /// ledger yields the priors.
    pub fn from_default_ledger() -> CostModel {
        Ledger::open_default()
            .and_then(|l| l.read_all())
            .map(|recs| CostModel::fit(&recs))
            .unwrap_or_else(|_| CostModel::priors())
    }

    /// Predicted peak hot-tier (RAM-resident) checkpoint bytes.  Tiered
    /// candidates are capped at their own hot budget — the overflow is
    /// exactly what the tier spills.
    pub fn predict_peak_hot_bytes(&self, policy: &CheckpointPolicy, ctx: &ResolveCtx) -> u64 {
        let stored = stored_vectors(policy, ctx.nt, ctx.n_stages) as f64 * self.vec_bytes;
        let stored = stored.round() as u64;
        match policy {
            CheckpointPolicy::Tiered { budget_bytes, .. } => stored.min(*budget_bytes),
            _ => stored,
        }
    }

    /// Predicted wall seconds of one gradient:
    ///
    /// ```text
    /// nt·t_fwd + nt·t_vjp + R·t_rec            (integration + recompute)
    /// + C·t_store + C·t_restore                (per-checkpoint-step span)
    /// + V/store_bps + V/restore_bps            (checkpoint byte traffic)
    /// + spilled/spill_bps + cold/prefetch_bps  (tiered overflow only)
    /// ```
    ///
    /// with `R` from Prop. 2 for binomial placements, `V` the stored
    /// bytes, `spilled = max(0, V - budget)`, and `cold` the spilled
    /// payload after optional f16 halving.
    pub fn predict_secs(&self, policy: &CheckpointPolicy, ctx: &ResolveCtx) -> f64 {
        let nt = ctx.nt as f64;
        let stored_bytes = stored_vectors(policy, ctx.nt, ctx.n_stages) as f64 * self.vec_bytes;
        let ckpt_steps = stored_steps(policy, ctx.nt) as f64;
        let recompute = recompute_steps(policy, ctx.nt) as f64;
        let [t_fwd, t_store, t_restore, t_rec, t_vjp] = self.phase_secs;
        let mut secs = nt * t_fwd
            + nt * t_vjp
            + recompute * t_rec
            + ckpt_steps * (t_store + t_restore)
            + stored_bytes / self.store_bytes_per_sec
            + stored_bytes / self.restore_bytes_per_sec;
        if let CheckpointPolicy::Tiered { budget_bytes, compress_f16, .. } = policy {
            let spilled = (stored_bytes - *budget_bytes as f64).max(0.0);
            let cold = if *compress_f16 { spilled / 2.0 } else { spilled };
            secs += spilled / self.spill_bytes_per_sec + cold / self.prefetch_bytes_per_sec;
        }
        secs
    }

    /// The fixed candidate list for `auto:<budget>`, in enumeration
    /// order, each with its predictions and budget verdict.
    pub fn candidates(&self, budget_bytes: u64, ctx: &ResolveCtx) -> Vec<Candidate> {
        let mut policies = vec![CheckpointPolicy::All, CheckpointPolicy::SolutionOnly];
        let slots = ctx.nt.saturating_sub(1).max(1) as usize;
        let mut k = 1usize;
        while k < slots {
            policies.push(CheckpointPolicy::Binomial { n_checkpoints: k });
            k *= 2;
        }
        for compress_f16 in [false, true] {
            policies.push(CheckpointPolicy::Tiered {
                budget_bytes,
                dir: AUTO_SPILL_DIR.into(),
                compress_f16,
                inner: Box::new(CheckpointPolicy::All),
            });
        }
        policies
            .into_iter()
            .map(|policy| {
                let peak = self.predict_peak_hot_bytes(&policy, ctx);
                Candidate {
                    pred_peak_hot_bytes: peak,
                    pred_secs: self.predict_secs(&policy, ctx),
                    fits: peak <= budget_bytes,
                    policy,
                }
            })
            .collect()
    }

    /// Resolve `auto:<budget>` to the cheapest fitting candidate.
    /// Deterministic: strict `<` on predicted seconds keeps the earliest
    /// enumerated candidate on ties, and the inputs (ledger fit + fixed
    /// candidate list) carry no run-to-run nondeterminism.
    pub fn resolve(&self, budget_bytes: u64, ctx: &ResolveCtx) -> Result<CheckpointPolicy, String> {
        let cands = self.candidates(budget_bytes, ctx);
        let mut best: Option<&Candidate> = None;
        for c in cands.iter().filter(|c| c.fits) {
            if best.map_or(true, |b| c.pred_secs < b.pred_secs) {
                best = Some(c);
            }
        }
        best.map(|c| c.policy.clone()).ok_or_else(|| {
            format!(
                "auto policy: no candidate fits under budget {budget_bytes} bytes \
                 (smallest predicted peak was {} bytes); raise the budget",
                cands.iter().map(|c| c.pred_peak_hot_bytes).min().unwrap_or(0)
            )
        })
    }
}

/// The problem sizes known at resolution time (Session/registry build).
#[derive(Clone, Copy, Debug)]
pub struct ResolveCtx {
    /// planned step count (the calibrated `typical_nt` for adaptive grids)
    pub nt: u64,
    /// stage derivatives stored per step by stage-keeping placements
    pub n_stages: u64,
}

impl ResolveCtx {
    pub fn for_spec(spec: &RunSpec, model: &CostModel) -> ResolveCtx {
        let nt = spec
            .grid
            .planned_nt()
            .map(|n| n as u64)
            .unwrap_or_else(|| model.typical_nt.round().max(1.0) as u64);
        let n_stages =
            if spec.scheme.is_implicit() { 1 } else { spec.scheme.tableau().s as u64 };
        ResolveCtx { nt, n_stages }
    }
}

/// One enumerated auto-policy candidate with its predictions.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub policy: CheckpointPolicy,
    pub pred_peak_hot_bytes: u64,
    pub pred_secs: f64,
    /// predicted peak hot bytes ≤ the auto budget
    pub fits: bool,
}

/// Resolve a spec whose pnode policy is `auto:<budget>` against the
/// default ledger.  Returns `None` for concrete policies, otherwise
/// `(resolved spec, budget bytes, winning policy)`.  Deterministic given
/// a fixed ledger: same records → same fit → same winner.
pub fn resolve_spec(spec: &RunSpec) -> Result<Option<(RunSpec, u64, CheckpointPolicy)>, String> {
    let budget = match spec.method.pnode_policy() {
        Some(CheckpointPolicy::Auto { budget_bytes }) => *budget_bytes,
        _ => return Ok(None),
    };
    let model = CostModel::from_default_ledger();
    let ctx = ResolveCtx::for_spec(spec, &model);
    let policy = model.resolve(budget, &ctx)?;
    let mut resolved = spec.clone();
    resolved.method = crate::api::spec::MethodSpec::Pnode { policy: policy.clone() };
    resolved.validate()?;
    Ok(Some((resolved, budget, policy)))
}

/// Checkpoint vectors (solution or stage slots) the placement stores over
/// `nt` steps — the same counting `MemModel::ckpt_bytes_for` uses.
pub fn stored_vectors(policy: &CheckpointPolicy, nt: u64, n_stages: u64) -> u64 {
    let slots = nt.saturating_sub(1);
    match policy {
        CheckpointPolicy::All => slots * (n_stages + 1),
        CheckpointPolicy::SolutionOnly => slots,
        CheckpointPolicy::Binomial { n_checkpoints } => {
            (*n_checkpoints as u64).min(slots) * (n_stages + 1)
        }
        CheckpointPolicy::Tiered { inner, .. } => stored_vectors(inner, nt, n_stages),
        CheckpointPolicy::Auto { .. } => 0,
    }
}

/// Steps at which the placement stores a checkpoint (each costs one
/// store span going forward and one restore span coming back).
fn stored_steps(policy: &CheckpointPolicy, nt: u64) -> u64 {
    let slots = nt.saturating_sub(1);
    match policy.placement() {
        CheckpointPolicy::All | CheckpointPolicy::SolutionOnly => slots,
        CheckpointPolicy::Binomial { n_checkpoints } => (*n_checkpoints as u64).min(slots),
        _ => 0,
    }
}

/// Recomputed forward steps of the adjoint sweep: 0 for `All`, `nt - 1`
/// for `SolutionOnly`, Prop. 2 for binomial placements (pessimistic
/// `nt²` when the closed form declines to answer).
fn recompute_steps(policy: &CheckpointPolicy, nt: u64) -> u64 {
    match policy.placement() {
        CheckpointPolicy::All => 0,
        CheckpointPolicy::SolutionOnly => nt.saturating_sub(1),
        CheckpointPolicy::Binomial { n_checkpoints } => {
            prop2_extra_steps(nt as usize, *n_checkpoints).unwrap_or(nt.saturating_mul(nt))
        }
        _ => 0,
    }
}

fn span_total_secs(metrics: &Json, name: &str) -> Option<f64> {
    metrics
        .get("spans")?
        .get(name)?
        .get("total_secs")?
        .as_f64()
        .filter(|t| *t > 0.0)
}

fn span_per_call_secs(metrics: &Json, name: &str) -> Option<f64> {
    let span = metrics.get("spans")?.get(name)?;
    let count = span.get("count")?.as_f64().filter(|c| *c > 0.0)?;
    let total = span.get("total_secs")?.as_f64().filter(|t| *t > 0.0)?;
    Some(total / count)
}

/// The concrete checkpoint policy a ledger record ran under (its resolved
/// policy when the run was auto, else the method string's own policy).
fn record_policy(r: &RunRecord) -> Option<CheckpointPolicy> {
    if let Some(name) = r.row.get("policy_resolved").and_then(Json::as_str) {
        if let Ok(p) = CheckpointPolicy::parse(name) {
            return Some(p);
        }
    }
    let method = r.spec.get("method")?.as_str()?;
    let spec = crate::api::spec::MethodSpec::parse(method).ok()?;
    match spec.pnode_policy()? {
        CheckpointPolicy::Auto { .. } => None,
        p => Some(p.clone()),
    }
}

fn record_n_stages(r: &RunRecord) -> u64 {
    use crate::ode::tableau::Scheme;
    r.spec
        .get("scheme")
        .and_then(Json::as_str)
        .and_then(Scheme::parse)
        .map(|s| if s.is_implicit() { 1 } else { s.tableau().s as u64 })
        .unwrap_or(1)
}

/// Upper median: deterministic, robust to a minority of outliers, and
/// never interpolates (a fitted constant is always one actually-observed
/// estimate).
fn median(xs: &mut Vec<f64>) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    // lint:allow(panic): cost estimates are sums and products of finite calibrated terms, so the comparison never sees NaN
    xs.sort_by(|a, b| a.partial_cmp(b).expect("cost estimates are finite"));
    Some(xs[xs.len() / 2])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ResolveCtx {
        ResolveCtx { nt: 12, n_stages: 7 }
    }

    #[test]
    fn stored_vector_counts_match_the_memory_model() {
        // nt=12, s=7: Table-2 counting
        assert_eq!(stored_vectors(&CheckpointPolicy::All, 12, 7), 11 * 8);
        assert_eq!(stored_vectors(&CheckpointPolicy::SolutionOnly, 12, 7), 11);
        assert_eq!(
            stored_vectors(&CheckpointPolicy::Binomial { n_checkpoints: 4 }, 12, 7),
            4 * 8
        );
        let tiered = CheckpointPolicy::parse("tiered:1m:/tmp/x:binomial:4").unwrap();
        assert_eq!(stored_vectors(&tiered, 12, 7), 4 * 8);
    }

    #[test]
    fn priors_prefer_recomputation_over_spilling() {
        // with a cold ledger and a budget that excludes All, binomial
        // recomputation (~µs per step) must beat tiered disk traffic
        // (~ms per MiB), so auto never picks the spill path by default
        let m = CostModel::priors();
        let budget = 1_572_864; // 1.5 MiB: All at nt=12/s=7 needs ~2.75 MiB
        let win = m.resolve(budget, &ctx()).unwrap();
        assert_eq!(win, CheckpointPolicy::Binomial { n_checkpoints: 4 }, "{win:?}");
        let cands = m.candidates(budget, &ctx());
        for c in &cands {
            assert_eq!(c.fits, c.pred_peak_hot_bytes <= budget, "{c:?}");
            assert!(c.pred_secs.is_finite() && c.pred_secs > 0.0, "{c:?}");
        }
        assert!(
            !cands.iter().find(|c| c.policy == CheckpointPolicy::All).unwrap().fits,
            "All must be over this budget"
        );
    }

    #[test]
    fn generous_budget_resolves_to_all() {
        let m = CostModel::priors();
        let win = m.resolve(1 << 30, &ctx()).unwrap();
        assert_eq!(win, CheckpointPolicy::All);
    }

    #[test]
    fn tiny_budget_falls_back_to_tiered_spill() {
        // 1 byte fits no in-RAM placement, but tiered's hot peak is
        // capped by its own budget — so the spill path still fits and wins
        let m = CostModel::priors();
        let win = m.resolve(1, &ctx()).unwrap();
        assert!(matches!(win, CheckpointPolicy::Tiered { .. }), "{win:?}");
    }

    #[test]
    fn resolution_is_deterministic() {
        let m = CostModel::priors();
        let a = m.resolve(1_572_864, &ctx()).unwrap();
        let b = m.resolve(1_572_864, &ctx()).unwrap();
        assert_eq!(a, b);
    }
}
