//! The event recorder: global on/off sink, per-thread buffers, spans,
//! and the deterministic `(tid, seq)` merge.
//!
//! Hot-path contract: every public recording function begins with a
//! single `Relaxed` load of the enable flag and returns immediately when
//! it is clear — no clock read, no thread-local access, no allocation.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// What one [`Event`] records.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// span opened (paired with the next same-tid `End` of the same name)
    Begin,
    /// span closed
    End,
    /// additive counter increment
    Counter(f64),
    /// sampled level (occupancy, bytes resident, ...)
    Gauge(f64),
    /// point event — warnings, marks
    Instant,
}

/// One recorded observation.  `(tid, seq)` is the deterministic merge
/// key; `ts_nanos` (monotonic, from the first `enable`) is for humans
/// and duration math only and is NOT stable across runs.
#[derive(Clone, Debug)]
pub struct Event {
    pub name: &'static str,
    pub kind: EventKind,
    /// logical thread id: 0 = main, `job + 1` inside a pool job
    pub tid: u32,
    /// per-tid sequence number, dense from 0
    pub seq: u64,
    pub ts_nanos: u64,
    /// free-form payload (warnings); `None` on the hot path
    pub detail: Option<String>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn flushed() -> &'static Mutex<Vec<Event>> {
    static FLUSHED: OnceLock<Mutex<Vec<Event>>> = OnceLock::new();
    FLUSHED.get_or_init(|| Mutex::new(Vec::new()))
}

fn lock_flushed() -> MutexGuard<'static, Vec<Event>> {
    match flushed().lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Next sequence number per logical job tid, persisted across pool
/// invocations within one stream.  A forward pool and a backward pool
/// both run job 0 (tid 1); without continuation their events would
/// collide at `(1, 0)` and merge in flush order, which is thread-timing
/// dependent.  Touched once per job entry/exit, never per event.
fn job_seqs() -> &'static Mutex<Vec<(u32, u64)>> {
    static SEQS: OnceLock<Mutex<Vec<(u32, u64)>>> = OnceLock::new();
    SEQS.get_or_init(|| Mutex::new(Vec::new()))
}

fn lock_job_seqs() -> MutexGuard<'static, Vec<(u32, u64)>> {
    match job_seqs().lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Per-thread buffer.  Appends are lock-free; the contents reach the
/// global pool either at [`take`] (current thread) or when the thread
/// exits (the `Drop` impl runs from the TLS destructor on join).
struct ThreadBuf {
    tid: u32,
    seq: u64,
    events: Vec<Event>,
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        if !self.events.is_empty() {
            lock_flushed().append(&mut self.events);
        }
    }
}

thread_local! {
    static BUF: RefCell<ThreadBuf> =
        RefCell::new(ThreadBuf { tid: 0, seq: 0, events: Vec::new() });
}

/// Is the sink recording?  One relaxed atomic load — the entire cost of
/// every instrumentation point while observability is off.
#[inline(always)]
pub fn enabled() -> bool {
    // Relaxed: a stale read merely drops or records one extra event; the
    // epoch the events need is published by the SeqCst store in enable()
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the global sink on (idempotent).  Pins the timestamp epoch on
/// first use.
pub fn enable() {
    epoch();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn the global sink off.  Already-buffered events stay until
/// [`take`] or [`reset`].
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Monotonic stopwatch for coarse phase timing (scaling reports,
/// calibration).  Lives in `obs` so clock reads stay out of the gradient
/// modules: the determinism lint bans `Instant` from `methods/` et al.,
/// keeping every nondeterministic input to a run inside the
/// observability layer.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    started: Instant,
}

impl Stopwatch {
    /// Seconds since [`stopwatch`] was called.
    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}

/// Start a [`Stopwatch`] now.
pub fn stopwatch() -> Stopwatch {
    Stopwatch { started: Instant::now() }
}

/// Drop every buffered event (current thread's buffer + the flushed
/// pool) and rewind the current thread's sequence counter.
pub fn reset() {
    BUF.with(|b| {
        let mut b = b.borrow_mut();
        b.events.clear();
        b.seq = 0;
    });
    lock_flushed().clear();
    lock_job_seqs().clear();
}

#[inline]
fn record(name: &'static str, kind: EventKind, detail: Option<String>) {
    let ts_nanos = epoch().elapsed().as_nanos() as u64;
    BUF.with(|b| {
        let mut b = b.borrow_mut();
        let (tid, seq) = (b.tid, b.seq);
        b.seq += 1;
        b.events.push(Event { name, kind, tid, seq, ts_nanos, detail });
    });
}

/// Add `value` to the named counter.
#[inline]
pub fn counter(name: &'static str, value: f64) {
    if !enabled() {
        return;
    }
    record(name, EventKind::Counter(value), None);
}

/// Sample the named gauge at `value`.
#[inline]
pub fn gauge(name: &'static str, value: f64) {
    if !enabled() {
        return;
    }
    record(name, EventKind::Gauge(value), None);
}

/// Record a point event with no payload.
#[inline]
pub fn instant(name: &'static str) {
    if !enabled() {
        return;
    }
    record(name, EventKind::Instant, None);
}

/// Record a warning-class point event.  The payload closure only runs
/// when the sink is enabled, so formatting costs nothing when off —
/// this is the replacement for ad-hoc `eprintln!` diagnostics.
#[inline]
pub fn warn(name: &'static str, detail: impl FnOnce() -> String) {
    if !enabled() {
        return;
    }
    record(name, EventKind::Instant, Some(detail()));
}

/// RAII span: `Begin` now, `End` on drop.  A guard created while the
/// sink was off records nothing on drop (balance is per-guard).
pub struct SpanGuard {
    name: &'static str,
    active: bool,
}

/// Open a span.  Nest freely; the metrics fold pairs `Begin`/`End` with
/// a per-tid stack.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { name, active: false };
    }
    record(name, EventKind::Begin, None);
    SpanGuard { name, active: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.active {
            // record unconditionally: a Begin must get its End even if
            // the sink was disabled mid-span, or nesting checks break
            record(self.name, EventKind::End, None);
        }
    }
}

/// RAII logical-thread context for pool jobs: swaps the current thread's
/// `(tid, seq)` to `(tid, 0)` and restores the saved pair on drop.
/// Because jobs are deterministic work units, keying events by job index
/// instead of OS thread makes the merged stream identical across runs
/// and worker counts.
pub struct JobCtx {
    saved_tid: u32,
    saved_seq: u64,
    active: bool,
}

/// Enter job context `tid` (the pool passes `job index + 1`; 0 is the
/// main thread and must not be claimed by jobs).  The tid's sequence
/// counter continues where a previous job context for the same tid left
/// off, so multi-phase pool runs (forward pool, then backward pool) keep
/// the merge key `(tid, seq)` collision-free.
pub fn job_ctx(tid: u32) -> JobCtx {
    if !enabled() {
        return JobCtx { saved_tid: 0, saved_seq: 0, active: false };
    }
    let start = lock_job_seqs()
        .iter()
        .find(|(t, _)| *t == tid)
        .map(|(_, s)| *s)
        .unwrap_or(0);
    BUF.with(|b| {
        let mut b = b.borrow_mut();
        let ctx = JobCtx { saved_tid: b.tid, saved_seq: b.seq, active: true };
        b.tid = tid;
        b.seq = start;
        ctx
    })
}

impl Drop for JobCtx {
    fn drop(&mut self) {
        if self.active {
            BUF.with(|b| {
                let mut b = b.borrow_mut();
                let (tid, seq) = (b.tid, b.seq);
                let mut seqs = lock_job_seqs();
                match seqs.iter_mut().find(|(t, _)| *t == tid) {
                    Some(e) => e.1 = seq,
                    None => seqs.push((tid, seq)),
                }
                drop(seqs);
                b.tid = self.saved_tid;
                b.seq = self.saved_seq;
            });
        }
    }
}

/// Flush the current thread's buffer and drain the global pool, merged
/// into the deterministic order: ascending `(tid, seq)`.  Worker-thread
/// buffers were flushed by their TLS destructors when the scoped pool
/// joined, so after a run completes this is the full stream.
pub fn take() -> Vec<Event> {
    BUF.with(|b| {
        let mut b = b.borrow_mut();
        if !b.events.is_empty() {
            let mut ev = std::mem::take(&mut b.events);
            lock_flushed().append(&mut ev);
        }
        b.seq = 0;
    });
    lock_job_seqs().clear();
    let mut all = std::mem::take(&mut *lock_flushed());
    all.sort_by(|a, b| (a.tid, a.seq).cmp(&(b.tid, b.seq)));
    all
}

/// Serialize tests that touch the global sink.  `cargo test` runs tests
/// of one binary concurrently in one process; any test calling
/// [`enable`] must hold this guard for its whole body.
pub fn test_guard() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These unit tests exercise only mechanics that are safe under the
    // shared process-global sink; end-to-end enable/disable runs live in
    // tests/obs_trace.rs where every test holds `test_guard`.

    #[test]
    fn disabled_sink_records_nothing_and_guards_are_inert() {
        let _g = test_guard();
        disable();
        reset();
        {
            let _s = span("never");
            counter("never.count", 1.0);
            gauge("never.gauge", 2.0);
            instant("never.mark");
            warn("never.warn", || panic!("payload must not be formatted"));
        }
        assert!(take().is_empty(), "obs off => zero events recorded");
    }

    #[test]
    fn merge_orders_by_tid_then_seq_and_job_ctx_restores() {
        let _g = test_guard();
        reset();
        enable();
        counter("main.a", 1.0);
        {
            let _ctx = job_ctx(2);
            counter("job2.a", 1.0);
            counter("job2.b", 1.0);
        }
        {
            let _ctx = job_ctx(1);
            counter("job1.a", 1.0);
        }
        counter("main.b", 1.0);
        disable();
        let ev = take();
        let keys: Vec<(u32, u64, &str)> = ev.iter().map(|e| (e.tid, e.seq, e.name)).collect();
        assert_eq!(
            keys,
            vec![
                (0, 0, "main.a"),
                (0, 1, "main.b"),
                (1, 0, "job1.a"),
                (2, 0, "job2.a"),
                (2, 1, "job2.b"),
            ]
        );
        reset();
    }

    #[test]
    fn job_seqs_continue_across_pool_phases() {
        let _g = test_guard();
        reset();
        enable();
        {
            let _c = job_ctx(1);
            counter("fwd", 1.0);
        }
        {
            let _c = job_ctx(1);
            counter("bwd", 1.0);
        }
        disable();
        let ev = take();
        let keys: Vec<(u32, u64, &str)> = ev.iter().map(|e| (e.tid, e.seq, e.name)).collect();
        assert_eq!(
            keys,
            vec![(1, 0, "fwd"), (1, 1, "bwd")],
            "a re-entered tid never collides with its earlier events"
        );
        reset();
    }

    #[test]
    fn span_guards_balance_and_nest() {
        let _g = test_guard();
        reset();
        enable();
        {
            let _outer = span("outer");
            let _inner = span("inner");
        }
        disable();
        let ev = take();
        let shape: Vec<(&str, EventKind)> =
            ev.iter().map(|e| (e.name, e.kind.clone())).collect();
        assert_eq!(
            shape,
            vec![
                ("outer", EventKind::Begin),
                ("inner", EventKind::Begin),
                ("inner", EventKind::End),
                ("outer", EventKind::End),
            ]
        );
        reset();
    }
}
