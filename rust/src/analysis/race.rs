//! Deterministic happens-before checker for the exec/lease protocol
//! (`debug-sync` feature only; compiled out of release builds).
//!
//! Model: classic vector clocks over *logical* sync objects.  Each
//! participating thread owns a clock slot; each synchronization object
//! (a pool run's claim counter, a job result slot, an arbiter's state
//! lock) carries the clock of its last release.  Instrumented operations
//! in [`crate::exec::pool`] and [`crate::exec::arbiter`]:
//!
//! * **pool job claim** (`fetch_add` on the index counter) — an RMW:
//!   acquire the counter object's clock, tick, release back.
//! * **pool job complete** — stamp the job slot with the worker's clock.
//! * **pool scope join** — the caller joins every participant's clock
//!   (mirrors `std::thread::scope`'s join edge).
//! * **pool collect** — reading job `i`'s result slot asserts the
//!   writer's clock is ≤ the reader's (the write happened-before).
//! * **lease ask / settle** (writes under the arbiter mutex) — acquire
//!   the pool object, tick, stamp the byte-counter writer clock, release.
//! * **arbiter stats** (reads under the same mutex) — acquire, then
//!   assert the last byte-counter write is ≤ the reader's clock: every
//!   hot-tier byte-count read is ordered after the write that produced
//!   it, so `over_grant_bytes == 0` in a test is a real protocol
//!   property, not a stale-read artifact.
//!
//! What it can catch: a missing join edge in the protocol as modeled —
//! e.g. reading a result slot without the scope join, or reading arbiter
//! counters through a path that skips the mutex (instrumented as a
//! [`read_unsynced`]).  What it cannot catch: races in code that is not
//! instrumented, and orderings the OS never schedules during the run —
//! it checks the executions it sees, not all executions (DESIGN.md §14).
//!
//! Violations are recorded, not panicked, so a test can assert
//! `violations() == 0` (or probe the checker's own semantics by
//! provoking one) without poisoning unrelated state.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard, OnceLock};

type Clock = Vec<u64>;

fn join(into: &mut Clock, other: &Clock) {
    if into.len() < other.len() {
        into.resize(other.len(), 0);
    }
    for (a, b) in into.iter_mut().zip(other) {
        *a = (*a).max(*b);
    }
}

/// `a ≤ b` pointwise (missing entries are 0).
fn leq(a: &Clock, b: &Clock) -> bool {
    a.iter().enumerate().all(|(i, &x)| x <= b.get(i).copied().unwrap_or(0))
}

#[derive(Default)]
struct RunState {
    /// job index -> clock of the worker that completed it
    slots: BTreeMap<usize, Clock>,
    /// thread slots that claimed at least one job of this run
    participants: Vec<usize>,
    n_jobs: usize,
    collected: usize,
}

#[derive(Default)]
struct State {
    /// per-thread vector clocks, indexed by thread slot
    clocks: Vec<Clock>,
    /// last-release clock per sync object id
    objects: BTreeMap<u64, Clock>,
    /// last byte-counter write clock per arbiter id
    writers: BTreeMap<u64, Clock>,
    runs: BTreeMap<u64, RunState>,
    violations: Vec<String>,
    next_id: u64,
    next_slot: usize,
}

fn state() -> &'static Mutex<State> {
    static STATE: OnceLock<Mutex<State>> = OnceLock::new();
    STATE.get_or_init(|| Mutex::new(State::default()))
}

fn lock() -> MutexGuard<'static, State> {
    match state().lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

thread_local! {
    static SLOT: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}

/// This thread's clock slot, allocated on first use.
fn me(st: &mut State) -> usize {
    SLOT.with(|s| match s.get() {
        Some(slot) => slot,
        None => {
            let slot = st.next_slot;
            st.next_slot += 1;
            s.set(Some(slot));
            slot
        }
    })
}

fn clock_of(st: &mut State, slot: usize) -> &mut Clock {
    if st.clocks.len() <= slot {
        st.clocks.resize_with(slot + 1, Clock::new);
    }
    &mut st.clocks[slot]
}

fn tick(st: &mut State, slot: usize) {
    let c = clock_of(st, slot);
    if c.len() <= slot {
        c.resize(slot + 1, 0);
    }
    c[slot] += 1;
}

/// Acquire `obj`'s clock into the thread, tick, release back — models an
/// RMW or a mutex acquire+release in one step.
fn sync_through(st: &mut State, slot: usize, obj: u64) {
    let oc = st.objects.get(&obj).cloned().unwrap_or_default();
    join(clock_of(st, slot), &oc);
    tick(st, slot);
    let tc = clock_of(st, slot).clone();
    st.objects.insert(obj, tc);
}

/// Allocate a fresh sync-object id (one per arbiter, one per pool run).
pub fn new_object_id() -> u64 {
    let mut st = lock();
    st.next_id += 1;
    st.next_id
}

/// Begin a pool run of `n_jobs` jobs; the id doubles as the claim
/// counter's sync-object id.
pub fn pool_run_begin(n_jobs: usize) -> u64 {
    let mut st = lock();
    st.next_id += 1;
    let id = st.next_id;
    st.runs.insert(id, RunState { n_jobs, ..RunState::default() });
    // the spawning thread's clock is the baseline every worker inherits
    // through its first counter RMW
    let slot = me(&mut st);
    tick(&mut st, slot);
    let tc = clock_of(&mut st, slot).clone();
    st.objects.insert(id, tc);
    id
}

/// A worker claimed job `_i` via the atomic index counter (an RMW: full
/// acquire+release edge through the counter object).
pub fn pool_claim(run: u64, _i: usize) {
    let mut st = lock();
    let slot = me(&mut st);
    sync_through(&mut st, slot, run);
    if let Some(r) = st.runs.get_mut(&run) {
        if !r.participants.contains(&slot) {
            r.participants.push(slot);
        }
    }
}

/// A worker finished job `i`: stamp the result slot with its clock.
pub fn pool_complete(run: u64, i: usize) {
    let mut st = lock();
    let slot = me(&mut st);
    tick(&mut st, slot);
    let tc = clock_of(&mut st, slot).clone();
    if let Some(r) = st.runs.get_mut(&run) {
        r.slots.insert(i, tc);
    }
}

/// The spawning thread passed the scope join: it now happens-after every
/// participant (mirrors `std::thread::scope`).
pub fn pool_scope_join(run: u64) {
    let mut st = lock();
    let slot = me(&mut st);
    let parts = st.runs.get(&run).map(|r| r.participants.clone()).unwrap_or_default();
    for p in parts {
        let pc = clock_of(&mut st, p).clone();
        join(clock_of(&mut st, slot), &pc);
    }
}

/// The caller reads job `i`'s result slot; the completing write must be
/// ordered before this read.
pub fn pool_collect(run: u64, i: usize) {
    let mut st = lock();
    let slot = me(&mut st);
    let reader = clock_of(&mut st, slot).clone();
    let Some(r) = st.runs.get_mut(&run) else { return };
    let ok = r.slots.get(&i).map(|w| leq(w, &reader)).unwrap_or(false);
    r.collected += 1;
    let done = r.collected >= r.n_jobs;
    if done {
        st.runs.remove(&run);
    }
    if !ok {
        st.violations.push(format!(
            "pool run {run}: result slot {i} read without a happens-before edge from its writer"
        ));
    }
}

/// A lease `ask`/`settle` mutated the arbiter's byte counters while
/// holding its mutex: acquire+release the pool object and stamp the
/// writer clock the next [`stats_read`] must be ordered after.
pub fn lease_write(arbiter: u64) {
    let mut st = lock();
    let slot = me(&mut st);
    sync_through(&mut st, slot, arbiter);
    let tc = clock_of(&mut st, slot).clone();
    st.writers.insert(arbiter, tc);
}

/// `BudgetArbiter::stats` read the byte counters while holding the
/// mutex: the acquire must bring the last write into the reader's past.
pub fn stats_read(arbiter: u64) {
    let mut st = lock();
    let slot = me(&mut st);
    sync_through(&mut st, slot, arbiter);
    let reader = clock_of(&mut st, slot).clone();
    if let Some(w) = st.writers.get(&arbiter) {
        if !leq(w, &reader) {
            st.violations.push(format!(
                "arbiter {arbiter}: byte-counter read not ordered after the last lease write"
            ));
        }
    }
}

/// An *unsynchronized* byte-counter read — exists so tests can prove the
/// checker detects the edge it guards (no production path calls this).
pub fn read_unsynced(arbiter: u64) {
    let mut st = lock();
    let slot = me(&mut st);
    let reader = clock_of(&mut st, slot).clone();
    if let Some(w) = st.writers.get(&arbiter) {
        if !leq(w, &reader) {
            st.violations.push(format!(
                "arbiter {arbiter}: byte-counter read not ordered after the last lease write"
            ));
        }
    }
}

/// Number of happens-before violations recorded so far.
pub fn violations() -> usize {
    lock().violations.len()
}

/// Drain and return the recorded violation reports.
pub fn take_violations() -> Vec<String> {
    std::mem::take(&mut lock().violations)
}

/// Serialize tests that assert on the process-global checker state.
pub fn test_guard() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_protocol_is_race_free_for_any_worker_count() {
        let _g = test_guard();
        let base = violations();
        for workers in [1usize, 2, 4] {
            let out = crate::exec::pool::run_indexed(workers, 16, |i| i * 3);
            assert_eq!(out, (0..16).map(|i| i * 3).collect::<Vec<_>>(), "workers={workers}");
        }
        assert_eq!(violations(), base, "instrumented pool runs must record no violations");
    }

    #[test]
    fn contended_arbiter_byte_counts_are_ordered_not_racy() {
        let _g = test_guard();
        let base = violations();
        let arb = crate::exec::arbiter::BudgetArbiter::new(10_000);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let arb = arb.clone();
                s.spawn(move || {
                    let mut l = arb.lease();
                    for want in [400u64, 2600, 900] {
                        l.ask(want);
                        let st = arb.stats();
                        assert!(st.leased <= 10_000);
                        l.settle(want.min(l.held()));
                    }
                });
            }
        });
        let st = arb.stats();
        assert_eq!(st.leased, 0);
        assert_eq!(
            st.over_grant_bytes, 0,
            "no floors used — and with zero violations this is a real protocol property, \
             not a stale read: {st:?}"
        );
        assert_eq!(violations(), base, "{:?}", take_violations());
    }

    #[test]
    fn checker_detects_an_unsynchronized_read() {
        let _g = test_guard();
        let id = new_object_id();
        let base = violations();
        let done = std::sync::atomic::AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| {
                lease_write(id);
                // release-store publishes "written" to the spinning reader;
                // deliberately NOT a checker-visible edge
                done.store(true, std::sync::atomic::Ordering::Release);
            });
            // acquire-load pairs with the release-store above so the real
            // program is ordered — but the *checker* was not told, which
            // is exactly the stale-read shape it must flag
            while !done.load(std::sync::atomic::Ordering::Acquire) {
                std::hint::spin_loop();
            }
            read_unsynced(id);
        });
        assert_eq!(violations(), base + 1, "the unsynchronized read must be flagged");
        let reports = take_violations();
        assert!(reports.iter().any(|r| r.contains("not ordered after")), "{reports:?}");
    }

    #[test]
    fn synced_reads_after_writes_pass() {
        let _g = test_guard();
        let base = violations();
        let id = new_object_id();
        lease_write(id);
        stats_read(id); // same thread: trivially ordered
        std::thread::scope(|s| {
            s.spawn(|| stats_read(id)); // cross-thread through the object clock
        });
        assert_eq!(violations(), base, "{:?}", take_violations());
    }
}
