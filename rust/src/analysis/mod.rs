//! Crate-tailored static analysis (DESIGN.md §14).
//!
//! Two halves:
//!
//! * **`pnode-lint`** ([`lexer`] + [`lints`]): a comment/string-aware
//!   token scanner and rule registry enforcing the invariants the test
//!   matrix cannot — no hash/time tokens in gradient modules, `SAFETY:`
//!   comments on `unsafe`, justified weak atomic orderings, and a
//!   panic-free library surface — with an inline waiver grammar
//!   (`// lint:allow(<rule>): <reason>`).  CI runs the binary over
//!   `rust/src` as a hard gate; it also validates the checked-in JSON
//!   artifacts parse via [`crate::util::json`].
//! * **[`race`]** (`debug-sync` feature): a deterministic vector-clock
//!   happens-before checker stamped into the exec pool's job
//!   claim/complete protocol and the budget arbiter's lease ask/settle
//!   path, asserting byte-count reads are ordered after their writes.

pub mod lexer;
pub mod lints;
#[cfg(feature = "debug-sync")]
pub mod race;

pub use lints::{lint_source, lint_tree, validate_artifacts, Finding, RULE_IDS};
