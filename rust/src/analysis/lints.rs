//! The `pnode-lint` rule registry: crate-specific invariants over the
//! [`crate::analysis::lexer`] scan, with an inline waiver grammar.
//!
//! Rules (DESIGN.md §14):
//!
//! * `determinism` — no `HashMap`/`HashSet`/`Instant`/`SystemTime`
//!   tokens in the numeric/gradient modules (`ode/`, `adjoint/`, `nn/`,
//!   `tensor/`, `linalg/`, `methods/`, `serve/`, `exec/reduce.rs`).
//!   Hashing and
//!   wall-clock time belong to `obs/` and the CLI; a stray `Instant` in a
//!   gradient path is how bitwise reproducibility quietly dies.
//! * `unsafe-safety` — every `unsafe` token must be immediately preceded
//!   by a comment containing `SAFETY:` (attribute lines and blank lines
//!   between the comment and the token are allowed).
//! * `ordering` — every `Ordering::Relaxed`/`Acquire`/`Release`/`AcqRel`
//!   use must carry a comment (same line, or the line directly above)
//!   naming the happens-before edge it relies on.  `SeqCst` is exempt:
//!   it is the maximal ordering, so there is no weaker edge to justify.
//! * `panic` — `.unwrap()`/`.expect()`/`panic!`/`unreachable!` outside
//!   `#[cfg(test)]` regions, `main.rs`, `bin/`, `bench/`, and `testing/`
//!   requires a waiver.
//!
//! Waiver grammar: `// lint:allow(<rule>): <reason>` on the finding's
//! line or the line directly above — in a *plain* comment (doc comments
//! only document the grammar, they never waive).  A waiver without a
//! reason, or naming an unknown rule, is itself reported (rule id
//! `waiver`) and cannot be waived.
//!
//! All rules skip `#[cfg(test)]` regions — test code may hash, time,
//! and assert freely; the invariants protect the library surface.

use std::path::{Path, PathBuf};

use crate::analysis::lexer::{ident_positions, scan, test_region_lines, Scan};
use crate::util::json;

/// Rule identifiers accepted by `lint:allow(...)`.
pub const RULE_IDS: &[&str] = &["determinism", "unsafe-safety", "ordering", "panic"];

/// Modules where the `determinism` rule applies (path prefixes relative
/// to `rust/src`), plus exact files.
const DET_MODULES: &[&str] =
    &["ode/", "adjoint/", "nn/", "tensor/", "linalg/", "methods/", "serve/"];
const DET_FILES: &[&str] = &["exec/reduce.rs"];
/// Identifiers the `determinism` rule bans in those modules.
const DET_IDENTS: &[&str] = &["HashMap", "HashSet", "Instant", "SystemTime"];
/// Path prefixes exempt from the `panic` rule (CLI, benches, test kit).
const PANIC_EXEMPT: &[&str] = &["main.rs", "bin/", "bench/", "testing/"];

/// One lint finding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// rule id (`determinism`, `unsafe-safety`, `ordering`, `panic`,
    /// `waiver`, or `artifact` for JSON artifact failures)
    pub rule: &'static str,
    /// path as given to the linter (relative to `rust/src` for tree runs)
    pub file: String,
    /// 1-based line number
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}:{}: {}", self.rule, self.file, self.line, self.message)
    }
}

/// A parsed-and-valid waiver: `(1-based line, rule id)`.
struct Waiver {
    line: usize,
    rule: String,
}

/// Parse `lint:allow(...)` waivers out of the per-line comment text.
/// Malformed waivers are appended to `findings` under the `waiver` rule.
fn collect_waivers(rel: &str, sc: &Scan, findings: &mut Vec<Finding>) -> Vec<Waiver> {
    let mut waivers = Vec::new();
    for (ln0, comment) in sc.comments.iter().enumerate() {
        // waivers live in plain `//` comments; doc comments only *describe*
        // the grammar (this module, README excerpts) and never waive
        let t = comment.trim_start();
        if t.starts_with("///") || t.starts_with("//!") {
            continue;
        }
        let Some(at) = comment.find("lint:allow") else { continue };
        let line = ln0 + 1;
        let mut push_bad = |message: String| {
            findings.push(Finding { rule: "waiver", file: rel.to_string(), line, message });
        };
        let rest = &comment[at + "lint:allow".len()..];
        let Some(body) = rest.strip_prefix('(') else {
            push_bad("malformed waiver (want `lint:allow(<rule>): <reason>`)".to_string());
            continue;
        };
        let rule: String =
            body.chars().take_while(|c| c.is_alphanumeric() || *c == '_' || *c == '-').collect();
        let after_rule = &body[rule.len()..];
        let Some(tail) = after_rule.strip_prefix(')') else {
            push_bad("malformed waiver (want `lint:allow(<rule>): <reason>`)".to_string());
            continue;
        };
        if !RULE_IDS.contains(&rule.as_str()) {
            push_bad(format!("waiver names unknown rule {rule:?}"));
            continue;
        }
        let reason = tail.strip_prefix(':').map(str::trim).unwrap_or("");
        if !tail.starts_with(':') || reason.is_empty() {
            push_bad(format!("waiver for {rule:?} has no reason"));
            continue;
        }
        waivers.push(Waiver { line, rule });
    }
    waivers
}

/// Scan one line of code text for `Ordering::<weak>` uses; returns the
/// matched variant names.
fn ordering_uses(line: &str) -> Vec<&'static str> {
    const WEAK: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel"];
    let chars: Vec<char> = line.chars().collect();
    let mut out = Vec::new();
    for pos in ident_positions(line, "Ordering") {
        let mut j = pos + "Ordering".len();
        while chars.get(j) == Some(&' ') {
            j += 1;
        }
        if chars.get(j) != Some(&':') || chars.get(j + 1) != Some(&':') {
            continue;
        }
        j += 2;
        while chars.get(j) == Some(&' ') {
            j += 1;
        }
        let ident: String = chars[j.min(chars.len())..]
            .iter()
            .take_while(|c| c.is_alphanumeric() || **c == '_')
            .collect();
        if let Some(v) = WEAK.iter().find(|v| **v == ident) {
            out.push(*v);
        }
    }
    out
}

/// `.unwrap(` / `.expect(` call sites on a code line (method-call form
/// only, so a local `fn expect` definition or `unwrap_or` never match).
fn panic_calls(line: &str) -> Vec<&'static str> {
    let chars: Vec<char> = line.chars().collect();
    let mut out = Vec::new();
    for name in ["unwrap", "expect"] {
        for pos in ident_positions(line, name) {
            let before_dot = chars[..pos].iter().rev().find(|c| !c.is_whitespace());
            let mut j = pos + name.len();
            while chars.get(j).map(|c| c.is_whitespace()).unwrap_or(false) {
                j += 1;
            }
            if before_dot == Some(&'.') && chars.get(j) == Some(&'(') {
                out.push(if name == "unwrap" { "unwrap" } else { "expect" });
            }
        }
    }
    for name in ["panic", "unreachable"] {
        for pos in ident_positions(line, name) {
            let mut j = pos + name.len();
            while chars.get(j).map(|c| c.is_whitespace()).unwrap_or(false) {
                j += 1;
            }
            if chars.get(j) == Some(&'!') {
                out.push(if name == "panic" { "panic" } else { "unreachable" });
            }
        }
    }
    out
}

/// Lint one file's source text as if it lived at `rel` under `rust/src`.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let sc = scan(src);
    let tests = test_region_lines(&sc);
    let mut findings = Vec::new();
    let waivers = collect_waivers(rel, &sc, &mut findings);
    let waived = |rule: &str, line: usize| {
        waivers.iter().any(|w| w.rule == rule && (w.line == line || w.line + 1 == line))
    };
    let has_comment = |ln0: usize| !sc.comments[ln0].trim().is_empty();

    let det_applies =
        DET_MODULES.iter().any(|m| rel.starts_with(m)) || DET_FILES.contains(&rel);
    let panic_applies = !PANIC_EXEMPT.iter().any(|m| rel.starts_with(m));

    for (ln0, code) in sc.code.iter().enumerate() {
        let line = ln0 + 1;
        if tests[ln0] {
            continue; // all rules protect the library surface, not tests
        }
        if det_applies {
            for ident in DET_IDENTS {
                for _ in ident_positions(code, ident) {
                    if !waived("determinism", line) {
                        findings.push(Finding {
                            rule: "determinism",
                            file: rel.to_string(),
                            line,
                            message: format!(
                                "`{ident}` in deterministic module (hash/time belong to obs/ and the CLI)"
                            ),
                        });
                    }
                }
            }
        }
        for _ in ident_positions(code, "unsafe") {
            // accept SAFETY: on the same line or in the comment block
            // directly above (attributes and blank lines may intervene)
            let mut ok = sc.comments[ln0].contains("SAFETY:");
            let mut k = ln0;
            while !ok && k > 0 {
                k -= 1;
                let ck = sc.code[k].trim();
                if ck.starts_with("#[") || (ck.is_empty() && !has_comment(k)) {
                    continue; // attribute or blank line: keep walking
                }
                if ck.is_empty() && has_comment(k) {
                    if sc.comments[k].contains("SAFETY:") {
                        ok = true;
                    } else {
                        continue; // walk up the contiguous comment block
                    }
                } else {
                    break; // hit real code: no SAFETY comment adjacent
                }
            }
            if !ok && !waived("unsafe-safety", line) {
                findings.push(Finding {
                    rule: "unsafe-safety",
                    file: rel.to_string(),
                    line,
                    message: "`unsafe` without an immediately preceding `// SAFETY:` comment"
                        .to_string(),
                });
            }
        }
        for variant in ordering_uses(code) {
            let justified = has_comment(ln0)
                || (ln0 > 0 && sc.code[ln0 - 1].trim().is_empty() && has_comment(ln0 - 1));
            if !justified && !waived("ordering", line) {
                findings.push(Finding {
                    rule: "ordering",
                    file: rel.to_string(),
                    line,
                    message: format!(
                        "`Ordering::{variant}` without a justification comment naming its happens-before edge"
                    ),
                });
            }
        }
        if panic_applies {
            for call in panic_calls(code) {
                if !waived("panic", line) {
                    let what = match call {
                        "unwrap" | "expect" => format!("`.{call}()`"),
                        other => format!("`{other}!`"),
                    };
                    findings.push(Finding {
                        rule: "panic",
                        file: rel.to_string(),
                        line,
                        message: format!("{what} on the library surface needs a waiver"),
                    });
                }
            }
        }
    }
    findings
}

/// Recursively collect `.rs` files under `root`, sorted for a
/// deterministic report order.
fn rs_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> =
            std::fs::read_dir(&dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `src_root` (normally `rust/src`).
pub fn lint_tree(src_root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for path in rs_files(src_root)? {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        let src = std::fs::read_to_string(&path)?;
        findings.extend(lint_source(&rel, &src));
    }
    Ok(findings)
}

/// Validate the checked-in JSON artifacts under `repo_root` parse
/// cleanly with [`crate::util::json`]: `BENCH_*.json` at the root,
/// `examples/specs/*.json`, and `ci/metrics_baseline.json`.  A malformed
/// artifact must fail CI here, before a bench run silently masks it.
pub fn validate_artifacts(repo_root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut root_entries: Vec<PathBuf> =
        std::fs::read_dir(repo_root)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    root_entries.sort();
    for p in root_entries {
        let name = p.file_name().map(|n| n.to_string_lossy().to_string()).unwrap_or_default();
        if name.starts_with("BENCH_") && name.ends_with(".json") {
            paths.push(p);
        }
    }
    let specs = repo_root.join("examples/specs");
    if specs.is_dir() {
        let mut spec_files: Vec<PathBuf> =
            std::fs::read_dir(&specs)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
        spec_files.sort();
        paths.extend(
            spec_files
                .into_iter()
                .filter(|p| p.extension().map(|e| e == "json").unwrap_or(false)),
        );
    }
    let baseline = repo_root.join("ci/metrics_baseline.json");
    if baseline.exists() {
        paths.push(baseline);
    }
    let mut findings = Vec::new();
    for p in paths {
        let rel = p
            .strip_prefix(repo_root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        let text = std::fs::read_to_string(&p)?;
        if let Err(e) = json::parse(&text) {
            findings.push(Finding { rule: "artifact", file: rel, line: 1, message: e.to_string() });
        }
    }
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn determinism_flags_banned_idents_only_in_listed_modules() {
        let src = "use std::collections::HashMap;\nfn f() { let t = Instant::now(); }\n";
        let in_methods = lint_source("methods/x.rs", src);
        assert_eq!(rules_of(&in_methods), vec!["determinism", "determinism"]);
        assert_eq!(in_methods[0].line, 1);
        assert_eq!(in_methods[1].line, 2);
        assert!(lint_source("obs/x.rs", src).is_empty(), "obs/ may hash and time");
        // substrings must not match: Instantiate != Instant
        let doc = "fn f() { let instantiate_all = 1; let _ = instantiate_all; }\n";
        assert!(lint_source("ode/x.rs", doc).is_empty());
    }

    #[test]
    fn unsafe_requires_adjacent_safety_comment() {
        let bad = "fn f() { unsafe { core() } }\n";
        assert_eq!(rules_of(&lint_source("tensor/x.rs", bad)), vec!["unsafe-safety"]);
        let good = "// SAFETY: bounds checked by the caller\nfn f() { unsafe { core() } }\n";
        assert!(lint_source("tensor/x.rs", good).is_empty());
        let through_attr =
            "// SAFETY: dispatched only after feature detection\n#[target_feature(enable = \"avx2\")]\nunsafe fn g() {}\n";
        assert!(lint_source("tensor/x.rs", through_attr).is_empty(), "attributes may intervene");
        let same_line = "unsafe { core() } // SAFETY: single-threaded here\n";
        assert!(lint_source("tensor/x.rs", same_line).is_empty());
    }

    #[test]
    fn ordering_requires_comment_and_seqcst_is_exempt() {
        let bad = "fn f() { X.load(Ordering::Relaxed); }\n";
        let fs = lint_source("exec/x.rs", bad);
        assert_eq!(rules_of(&fs), vec!["ordering"]);
        let good = "fn f() { X.load(Ordering::Relaxed); // counter only, no edge needed\n}\n";
        assert!(lint_source("exec/x.rs", good).is_empty());
        let above = "// release-store in enable() is the edge\nfn f() {\n    // pairs with it\n    X.load(Ordering::Acquire);\n}\n";
        assert!(lint_source("exec/x.rs", above).is_empty());
        let seqcst = "fn f() { X.store(true, Ordering::SeqCst); }\n";
        assert!(lint_source("exec/x.rs", seqcst).is_empty(), "SeqCst needs no justification");
    }

    #[test]
    fn panic_rule_exempts_tests_and_cli_paths() {
        let src = "fn f() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); panic!(); }\n}\n";
        let fs = lint_source("util/x.rs", src);
        assert_eq!(rules_of(&fs), vec!["panic"]);
        assert_eq!(fs[0].line, 1);
        assert!(lint_source("main.rs", src).is_empty());
        assert!(lint_source("bin/pnode_lint.rs", src).is_empty());
        assert!(lint_source("bench/harness.rs", src).is_empty());
        assert!(lint_source("testing/prop.rs", src).is_empty());
    }

    #[test]
    fn waivers_suppress_same_or_next_line_and_need_reasons() {
        let waived =
            "// lint:allow(panic): poisoned lock is unrecoverable\nfn f() { x.unwrap(); }\n";
        assert!(lint_source("util/x.rs", waived).is_empty());
        let trailing = "fn f() { x.unwrap() } // lint:allow(panic): infallible by construction\n";
        assert!(lint_source("util/x.rs", trailing).is_empty());
        let no_reason = "// lint:allow(panic):\nfn f() { x.unwrap(); }\n";
        assert_eq!(rules_of(&lint_source("util/x.rs", no_reason)), vec!["waiver", "panic"]);
        let unknown = "// lint:allow(speed): because\nfn f() { x.unwrap(); }\n";
        assert_eq!(rules_of(&lint_source("util/x.rs", unknown)), vec!["waiver", "panic"]);
        let wrong_rule = "// lint:allow(ordering): not the right rule\nfn f() { x.unwrap(); }\n";
        assert_eq!(rules_of(&lint_source("util/x.rs", wrong_rule)), vec!["panic"]);
        // doc comments describe the grammar without waiving (or tripping
        // the malformed-waiver check)
        let doc = "/// Waivers look like `lint:allow(<rule>): <reason>`.\nfn f() { x.unwrap(); }\n";
        assert_eq!(rules_of(&lint_source("util/x.rs", doc)), vec!["panic"]);
        let doc_waiver =
            "//! lint:allow(panic): doc comments never waive\nfn f() { x.unwrap(); }\n";
        assert_eq!(rules_of(&lint_source("util/x.rs", doc_waiver)), vec!["panic"]);
    }

    #[test]
    fn tokens_inside_strings_and_comments_never_fire() {
        let src = "// HashMap unsafe .unwrap() Ordering::Relaxed panic!\nfn f() { let s = \"Instant::now() unsafe panic!\"; let _ = s; }\n";
        assert!(lint_source("methods/x.rs", src).is_empty());
    }
}
