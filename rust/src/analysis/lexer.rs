//! Comment/string-aware Rust source scanner for `pnode-lint`.
//!
//! This is not a full Rust lexer — it is the minimal state machine the
//! lint rules need: it splits a source file into per-line **code text**
//! (comments removed, string/char *contents* blanked to spaces so tokens
//! inside literals can never match a rule) and per-line **comment text**
//! (so rules can require `// SAFETY:` / justification comments and find
//! `lint:allow` waivers).  Handled: line comments, nested block comments,
//! string / byte-string / raw-string literals (any `#` count), char
//! literals incl. escapes, and the lifetime-vs-char-literal ambiguity.
//!
//! On top of the split, [`test_region_lines`] marks every line covered by
//! a `#[cfg(test)]`-gated item (attribute line through the matching close
//! brace) so rules can exempt test code.

/// One scanned file: `code[i]` and `comments[i]` partition line `i`
/// (0-based) of the source.
pub struct Scan {
    /// source line with comments stripped and literal contents blanked
    pub code: Vec<String>,
    /// comment text on the line (`//`, `///`, `/* .. */` bodies); empty
    /// when the line has no comment
    pub comments: Vec<String>,
}

#[derive(PartialEq)]
enum State {
    Normal,
    LineComment,
    /// nested block comment at the given depth
    BlockComment(u32),
    /// inside `"…"` / `b"…"`
    Str,
    /// inside `r"…"` / `r#"…"#` / `br#"…"#` with this many hashes
    RawStr(usize),
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Does a raw-string literal (`r"`, `r#"`, `br##"` …) start at `i`?
/// Returns the hash count and the length of the opener when it does.
fn raw_str_open(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    if j >= chars.len() || chars[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while j < chars.len() && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < chars.len() && chars[j] == '"' {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// Is the `'` at `i` a char literal (as opposed to a lifetime)?  A char
/// literal is `'\…'`, `'x'`, or `'ident'` with a closing quote right
/// after the identifier; a lifetime (`'a`, `'static`) has none.
fn is_char_literal(chars: &[char], i: usize) -> bool {
    match chars.get(i + 1) {
        Some('\\') => true,
        Some(&c) => {
            if chars.get(i + 2) == Some(&'\'') {
                return true; // 'x'
            }
            if !is_ident(c) {
                return false;
            }
            let mut j = i + 2;
            while j < chars.len() && is_ident(chars[j]) {
                j += 1;
            }
            chars.get(j) == Some(&'\'') // 'abc' (only valid as a typo, but lex it)
        }
        None => false,
    }
}

/// Scan `src` into per-line code and comment text (see module docs).
pub fn scan(src: &str) -> Scan {
    let chars: Vec<char> = src.chars().collect();
    let n_lines = src.split('\n').count();
    let mut code: Vec<String> = vec![String::new(); n_lines];
    let mut comments: Vec<String> = vec![String::new(); n_lines];
    let mut li = 0usize;
    let mut state = State::Normal;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if state == State::LineComment {
                state = State::Normal;
            }
            li += 1;
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    comments[li].push_str("//");
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment(1);
                    comments[li].push_str("/*");
                    i += 2;
                } else if c == '"' {
                    code[li].push('"');
                    state = State::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b')
                    && (i == 0 || !is_ident(chars[i - 1]))
                    && raw_str_open(&chars, i).is_some()
                {
                    let (hashes, len) = raw_str_open(&chars, i).expect("checked above"); // lint:allow(panic): guarded by the is_some() arm condition
                    for k in 0..len {
                        code[li].push(chars[i + k]);
                    }
                    state = State::RawStr(hashes);
                    i += len;
                } else if c == 'b'
                    && chars.get(i + 1) == Some(&'"')
                    && (i == 0 || !is_ident(chars[i - 1]))
                {
                    code[li].push_str("b\"");
                    state = State::Str;
                    i += 2;
                } else if c == '\'' {
                    if is_char_literal(&chars, i) {
                        // consume to the closing quote, emit a blank literal
                        let mut j = i + 1;
                        if chars.get(j) == Some(&'\\') {
                            j += 2;
                            while j < chars.len() && chars[j] != '\'' {
                                j += 1;
                            }
                        } else {
                            j += 1;
                            while j < chars.len() && chars[j] != '\'' {
                                j += 1;
                            }
                        }
                        code[li].push_str("' '");
                        i = j + 1;
                    } else {
                        code[li].push('\''); // lifetime tick
                        i += 1;
                    }
                } else {
                    code[li].push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comments[li].push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment(depth + 1);
                    comments[li].push_str("/*");
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    comments[li].push_str("*/");
                    state = if depth == 1 { State::Normal } else { State::BlockComment(depth - 1) };
                    i += 2;
                } else {
                    comments[li].push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    code[li].push_str("  ");
                    i += 2;
                } else if c == '"' {
                    code[li].push('"');
                    state = State::Normal;
                    i += 1;
                } else {
                    code[li].push(' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && chars[i + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes
                {
                    code[li].push('"');
                    for _ in 0..hashes {
                        code[li].push('#');
                    }
                    i += 1 + hashes;
                    state = State::Normal;
                } else {
                    code[li].push(' ');
                    i += 1;
                }
            }
        }
    }
    Scan { code, comments }
}

/// Per-line flags: `true` when the line is covered by a `#[cfg(test)]`
/// item — from the attribute line through the matching close brace of the
/// item body.  Detection is literal (`#[cfg(test)]`), which is the only
/// spelling this crate uses.
pub fn test_region_lines(scan: &Scan) -> Vec<bool> {
    let joined = scan.code.join("\n");
    let bytes: Vec<char> = joined.chars().collect();
    let mut covered = vec![false; scan.code.len()];
    let needle: Vec<char> = "#[cfg(test)]".chars().collect();
    let mut i = 0usize;
    while i + needle.len() <= bytes.len() {
        if bytes[i..i + needle.len()] != needle[..] {
            i += 1;
            continue;
        }
        let attr_start = i;
        let mut j = i + needle.len();
        // opening brace of the following item
        while j < bytes.len() && bytes[j] != '{' {
            j += 1;
        }
        let mut depth = 0i32;
        while j < bytes.len() {
            match bytes[j] {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        let line_of =
            |pos: usize| bytes[..pos.min(bytes.len())].iter().filter(|&&c| c == '\n').count();
        let (a, b) = (line_of(attr_start), line_of(j));
        for flag in covered.iter_mut().take(b + 1).skip(a) {
            *flag = true;
        }
        i += needle.len();
    }
    covered
}

/// Column positions where `ident` occurs as a whole identifier token in
/// `line` (code text — call only on [`Scan::code`] lines).
pub fn ident_positions(line: &str, ident: &str) -> Vec<usize> {
    let chars: Vec<char> = line.chars().collect();
    let needle: Vec<char> = ident.chars().collect();
    let mut out = Vec::new();
    if needle.is_empty() || chars.len() < needle.len() {
        return out;
    }
    for start in 0..=chars.len() - needle.len() {
        if chars[start..start + needle.len()] != needle[..] {
            continue;
        }
        let before_ok = start == 0 || !is_ident(chars[start - 1]);
        let after = chars.get(start + needle.len());
        let after_ok = after.map(|&c| !is_ident(c)).unwrap_or(true);
        if before_ok && after_ok {
            out.push(start);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_separated_from_code() {
        let src = "let x = 1; // HashMap in a comment\nlet s = \"Instant::now\";\n";
        let sc = scan(src);
        assert!(sc.code[0].contains("let x = 1;"));
        assert!(!sc.code[0].contains("HashMap"));
        assert!(sc.comments[0].contains("HashMap"));
        assert!(!sc.code[1].contains("Instant"), "{:?}", sc.code[1]);
        assert!(sc.code[1].contains('"'), "delimiters stay");
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let src = "a /* one /* two */ still */ b\n/* open\nunsafe here\n*/ c\n";
        let sc = scan(src);
        assert!(sc.code[0].contains('a') && sc.code[0].contains('b'));
        assert!(sc.comments[0].contains("two"));
        assert!(sc.code[2].is_empty(), "{:?}", sc.code[2]);
        assert!(sc.comments[2].contains("unsafe"));
        assert!(sc.code[3].contains('c'));
    }

    #[test]
    fn raw_strings_and_char_literals_blank_their_contents() {
        let src = "let r = r#\"panic! { \" } \"#; let c = '{'; let lt: &'static str = \"x\";\n";
        let sc = scan(&src);
        assert!(!sc.code[0].contains("panic"));
        assert!(
            !sc.code[0].contains('{'),
            "brace inside literals must not count: {:?}",
            sc.code[0]
        );
        assert!(sc.code[0].contains("'static"), "lifetimes survive: {:?}", sc.code[0]);
    }

    #[test]
    fn escaped_quote_char_literal_does_not_derail_the_scanner() {
        let src = "let q = '\\''; let after = unsafe_token;\n";
        let sc = scan(src);
        assert!(sc.code[0].contains("after"), "{:?}", sc.code[0]);
    }

    #[test]
    fn cfg_test_region_covers_the_item_body() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn more() {}\n";
        let sc = scan(src);
        let cov = test_region_lines(&sc);
        assert_eq!(cov, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn ident_positions_require_token_boundaries() {
        assert_eq!(ident_positions("Instantiate(Instant)", "Instant"), vec![12]);
        assert_eq!(ident_positions("x.unwrap()", "unwrap"), vec![2]);
        assert!(ident_positions("my_unwrap()", "unwrap").is_empty());
    }
}
