//! The unified solver facade (DESIGN.md §9): one typed, serializable
//! entry point for every gradient run.
//!
//! ```text
//! SolverBuilder ──build()──▶ RunSpec ──Session::new──▶ Session::grad(rhs, u0, λ_F)
//!      (fluent, validated)   (JSON ⇄)   (registry-resolved engine,
//!                                        reusable workspaces, pool/arbiter)
//! ```
//!
//! * [`RunSpec`] / [`MethodSpec`] — the typed description of a run
//!   (method family × checkpoint policy × scheme × span × grid ×
//!   execution engine), serializable to/from JSON so a run is a
//!   reviewable artifact (`pnode run --spec spec.json`, and every
//!   [`crate::coordinator::ExperimentRow`] embeds the spec that produced
//!   it).
//! * [`SolverBuilder`] — fluent construction with build-time validation
//!   of every degenerate combination.
//! * [`MethodRegistry`] — engine factories keyed by method family; the
//!   data-parallel wrapper and the shared checkpoint-memory arbiter
//!   compose here, behind the spec's `exec` field.
//! * [`Session`] — the long-lived handle that owns the engine and the
//!   reusable gradient workspaces (the serving hot path).

pub mod builder;
pub mod registry;
pub mod session;
pub mod spec;

pub use builder::SolverBuilder;
pub use registry::MethodRegistry;
pub use session::{GradReport, Session};
pub use spec::{MethodSpec, ObsSpec, RunSpec, METHOD_NAMES};

// the architecture half of a spec document lives in the nn layer; re-export
// it here so facade users address runs and dynamics from one import
pub use crate::nn::module::ArchSpec;
