//! `MethodSpec` / `RunSpec` — the typed, serializable description of one
//! gradient run (DESIGN.md §9).
//!
//! A [`RunSpec`] pins everything that determines a gradient computation:
//! the method family and its checkpoint policy ([`MethodSpec`]), the
//! integration scheme, the time span and [`TimeGrid`], and the optional
//! data-parallel [`ExecConfig`].  It serializes to/from JSON via
//! [`crate::util::json`], so a run is a reviewable artifact: the CLI's
//! `pnode run --spec spec.json` consumes the same document that
//! [`crate::coordinator::ExperimentRow`] embeds in every result row.
//!
//! Specs are *validated*, not trusted: [`RunSpec::validate`] rejects every
//! degenerate combination (zero step counts, `binomial:0`, zero tier
//! budgets, implicit schemes under baselines or adaptive grids,
//! `workers = 0`) with a message naming the offending part — the checks
//! that previously lived scattered across parse functions and task code.

use crate::checkpoint::CheckpointPolicy;
use crate::exec::{ExecConfig, DEFAULT_SHARD_ROWS};
use crate::methods::BlockSpec;
use crate::nn::module::ArchSpec;
use crate::ode::grid::TimeGrid;
use crate::ode::tableau::Scheme;
use crate::util::json::Json;

/// All method names in the paper's table order (the bench-matrix axis).
pub static METHOD_NAMES: &[&str] = &["naive", "cont", "anode", "aca", "pnode", "pnode2"];

/// The gradient method family of a run: PNODE (the paper's discrete
/// adjoint, parameterized by its [`CheckpointPolicy`]) or one of the four
/// baselines it is compared against.
#[derive(Clone, Debug, PartialEq)]
pub enum MethodSpec {
    /// High-level discrete adjoint with checkpointing (the paper's
    /// method).  `All` is "PNODE", `SolutionOnly` is "PNODE2"; with an
    /// implicit [`Scheme`] this runs the θ-method adjoint.
    Pnode { policy: CheckpointPolicy },
    /// Continuous adjoint baseline (not reverse-accurate).
    NodeCont,
    /// Full-tape baseline.
    NodeNaive,
    /// Block-checkpointing baseline.
    Anode,
    /// Adaptive checkpoint adjoint baseline.
    Aca,
}

impl MethodSpec {
    /// Parse a method spec.  Grammar:
    ///
    /// ```text
    /// naive | cont | anode | aca | pnode | pnode2
    /// pnode:<checkpoint-policy>     (see CheckpointPolicy::parse)
    /// ```
    ///
    /// Unlike the old `method_by_name` string dispatch, errors carry the
    /// underlying message (e.g. *why* `pnode:binomial:0` is degenerate).
    pub fn parse(s: &str) -> Result<MethodSpec, String> {
        match s {
            "pnode" => Ok(MethodSpec::Pnode { policy: CheckpointPolicy::All }),
            "pnode2" => Ok(MethodSpec::Pnode { policy: CheckpointPolicy::SolutionOnly }),
            "cont" | "node_cont" => Ok(MethodSpec::NodeCont),
            "naive" | "node_naive" => Ok(MethodSpec::NodeNaive),
            "anode" => Ok(MethodSpec::Anode),
            "aca" => Ok(MethodSpec::Aca),
            _ => {
                if let Some(rest) = s.strip_prefix("pnode:") {
                    let policy = CheckpointPolicy::parse(rest)?;
                    return Ok(MethodSpec::Pnode { policy });
                }
                Err(format!(
                    "unknown method {s:?} (want naive | cont | anode | aca | pnode | pnode2 | \
                     pnode:<policy>)"
                ))
            }
        }
    }

    /// Canonical name; `parse(name())` round-trips.
    pub fn name(&self) -> String {
        match self {
            MethodSpec::Pnode { policy: CheckpointPolicy::All } => "pnode".into(),
            MethodSpec::Pnode { policy: CheckpointPolicy::SolutionOnly } => "pnode2".into(),
            MethodSpec::Pnode { policy } => format!("pnode:{}", policy.name()),
            MethodSpec::NodeCont => "cont".into(),
            MethodSpec::NodeNaive => "naive".into(),
            MethodSpec::Anode => "anode".into(),
            MethodSpec::Aca => "aca".into(),
        }
    }

    /// Registry key: the method family, independent of policy details.
    pub fn family(&self) -> &'static str {
        match self {
            MethodSpec::Pnode { .. } => "pnode",
            MethodSpec::NodeCont => "cont",
            MethodSpec::NodeNaive => "naive",
            MethodSpec::Anode => "anode",
            MethodSpec::Aca => "aca",
        }
    }

    /// Whether gradients are exact to machine precision wrt the discrete
    /// forward map (everything except the continuous adjoint).
    pub fn reverse_accurate(&self) -> bool {
        !matches!(self, MethodSpec::NodeCont)
    }

    /// The PNODE checkpoint policy, if this is the PNODE family.
    pub fn pnode_policy(&self) -> Option<&CheckpointPolicy> {
        match self {
            MethodSpec::Pnode { policy } => Some(policy),
            _ => None,
        }
    }

    /// Reject degenerate policies that the string parser already refuses
    /// but programmatic construction can still produce (one source of
    /// truth: [`CheckpointPolicy::validate`]).
    pub fn validate(&self) -> Result<(), String> {
        match self {
            MethodSpec::Pnode { policy } => policy.validate(),
            _ => Ok(()),
        }
    }
}

/// Observability controls for a run (DESIGN.md §11).  Off by default;
/// when enabled, [`crate::api::Session`] switches on the process-global
/// [`crate::obs`] sink so the run records phase spans, tier/arbiter
/// events, and solver counters.  Recording is observation-only — it
/// never feeds back into computed values — so gradients are bitwise
/// identical with obs on or off.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ObsSpec {
    /// record trace events and metrics for runs opened on this spec
    pub enabled: bool,
}

/// One typed description of a gradient run: method × scheme × span ×
/// grid × execution engine.  Build via [`crate::api::SolverBuilder`] (which
/// validates), serialize via [`RunSpec::to_json`], execute via
/// [`crate::api::Session`].
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    pub method: MethodSpec,
    pub scheme: Scheme,
    pub t0: f64,
    pub tf: f64,
    pub grid: TimeGrid,
    /// data-parallel execution engine; `None` runs the single in-thread
    /// engine (no worker pool, no batch sharding)
    pub exec: Option<ExecConfig>,
    /// dynamics architecture ([`ArchSpec`]); `None` when the caller
    /// supplies its own `OdeRhs` (analytic RHSs, XLA artifacts)
    pub arch: Option<ArchSpec>,
    /// observability controls ([`ObsSpec`]); `None` records nothing
    pub obs: Option<ObsSpec>,
}

impl RunSpec {
    /// The integration window this spec describes.
    pub fn block_spec(&self) -> BlockSpec {
        BlockSpec { scheme: self.scheme, t0: self.t0, tf: self.tf, grid: self.grid.clone() }
    }

    /// Build the dynamics this spec declares: the declared [`ArchSpec`]
    /// instantiated over `batch` rows of `data_dim`-channel samples with
    /// parameters `theta`.  Errors when the spec carries no `"arch"`.
    pub fn make_rhs(
        &self,
        data_dim: usize,
        batch: usize,
        theta: Vec<f32>,
    ) -> Result<crate::ode::ModuleRhs, String> {
        let arch = self
            .arch
            .as_ref()
            .ok_or("spec declares no \"arch\": supply an architecture (or pass your own OdeRhs)")?;
        if theta.len() != arch.param_count(data_dim) {
            return Err(format!(
                "arch {} wants {} parameters at data_dim {data_dim} (got {})",
                arch.name(),
                arch.param_count(data_dim),
                theta.len()
            ));
        }
        Ok(crate::ode::ModuleRhs::from_arch(arch, data_dim, batch, theta))
    }

    /// Draw an initial parameter vector for the declared [`ArchSpec`].
    pub fn init_theta(
        &self,
        rng: &mut crate::util::rng::Rng,
        data_dim: usize,
    ) -> Result<Vec<f32>, String> {
        let arch = self
            .arch
            .as_ref()
            .ok_or("spec declares no \"arch\": supply an architecture (or pass your own OdeRhs)")?;
        Ok(arch.init(rng, data_dim))
    }

    /// Construct a gradient engine for this spec from the global
    /// [`crate::api::MethodRegistry`].
    pub fn make_engine(&self) -> Result<Box<dyn crate::methods::GradientMethod>, String> {
        crate::api::registry::global().make(self)
    }

    /// Open a long-lived [`crate::api::Session`] on this spec.
    pub fn session(self) -> Result<crate::api::Session, String> {
        crate::api::Session::new(self)
    }

    /// Reject every degenerate combination with a message naming the
    /// offending part (the single chokepoint behind the builder, the JSON
    /// loader, and `Session::new`).
    pub fn validate(&self) -> Result<(), String> {
        self.method.validate()?;
        if let Some(arch) = &self.arch {
            arch.validate()?;
        }
        if !(self.t0.is_finite() && self.tf.is_finite() && self.tf > self.t0) {
            return Err(format!(
                "integration span must be finite with t0 < tf (got [{}, {}])",
                self.t0, self.tf
            ));
        }
        match &self.grid {
            TimeGrid::Uniform { nt } => {
                if *nt == 0 {
                    return Err("uniform grid needs nt >= 1".into());
                }
            }
            TimeGrid::Explicit(steps) => {
                if steps.is_empty() {
                    return Err("explicit grid needs at least one step".into());
                }
                if steps.iter().any(|(t, h)| !t.is_finite() || !h.is_finite() || *h <= 0.0) {
                    return Err("explicit grid steps must have finite t and h > 0".into());
                }
                if let Some(w) = steps.windows(2).find(|w| w[1].0 <= w[0].0) {
                    return Err(format!(
                        "explicit grid times must be strictly increasing \
                         (step at t = {} follows t = {})",
                        w[1].0, w[0].0
                    ));
                }
            }
            TimeGrid::Adaptive { atol, rtol, h0 } => {
                let pos = |v: f64| v.is_finite() && v > 0.0;
                let h0_ok = match h0 {
                    Some(h) => pos(*h),
                    None => true,
                };
                if !pos(*atol) || !pos(*rtol) || !h0_ok {
                    return Err(
                        "adaptive grid tolerances and h0 must be positive and finite".into()
                    );
                }
            }
        }
        if self.scheme.is_implicit() {
            if !matches!(self.method, MethodSpec::Pnode { .. }) {
                return Err(format!(
                    "{} is an implicit θ-scheme: only the pnode family runs the implicit \
                     discrete adjoint (got method {:?})",
                    self.scheme.name(),
                    self.method.name()
                ));
            }
            if !self.grid.is_static() {
                return Err(format!(
                    "implicit θ-schemes have no embedded error estimate: run {} on a \
                     static (uniform or explicit) grid",
                    self.scheme.name()
                ));
            }
            if self.exec.is_some() {
                return Err(
                    "the data-parallel execution engine supports explicit schemes only \
                     (drop exec, or use an explicit scheme)"
                        .into(),
                );
            }
        } else if matches!(self.grid, TimeGrid::Adaptive { .. })
            && self.scheme.tableau().b_err.is_none()
        {
            return Err(format!(
                "{} carries no embedded error estimate: adaptive grids need an \
                 embedded explicit pair (bosh3 or dopri5)",
                self.scheme.name()
            ));
        }
        if let Some(cfg) = &self.exec {
            if cfg.workers == 0 {
                return Err(
                    "exec.workers must be >= 1 (omit exec for the single-engine path)".into()
                );
            }
            if cfg.shard_rows == 0 {
                return Err("exec.shard_rows must be >= 1".into());
            }
        }
        Ok(())
    }

    // ---------------- JSON ----------------

    /// Serialize to the reviewable spec document.  Unknown keys on the
    /// way in are ignored, so the same file can carry side-channel
    /// sections (the CLI's optional `"task"` block).
    pub fn to_json(&self) -> Json {
        let exec = match &self.exec {
            None => Json::Null,
            Some(cfg) => Json::obj(vec![
                ("workers", Json::num(cfg.workers as f64)),
                ("shard_rows", Json::num(cfg.shard_rows as f64)),
            ]),
        };
        let arch = match &self.arch {
            None => Json::Null,
            Some(a) => a.to_json(),
        };
        let obs = match &self.obs {
            None => Json::Null,
            Some(o) => Json::obj(vec![("enabled", Json::Bool(o.enabled))]),
        };
        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("method", Json::str(self.method.name())),
            ("scheme", Json::str(self.scheme.name())),
            ("t0", Json::num(self.t0)),
            ("tf", Json::num(self.tf)),
            ("grid", grid_to_json(&self.grid)),
            ("exec", exec),
            ("arch", arch),
            ("obs", obs),
        ])
    }

    /// Parse and validate a spec document (the inverse of
    /// [`RunSpec::to_json`]; see the format there).
    pub fn from_json(v: &Json) -> Result<RunSpec, String> {
        if let Some(ver) = v.get("version") {
            if ver.as_usize() != Some(1) {
                return Err(format!("unsupported spec version {ver:?} (want 1)"));
            }
        }
        let method_name = v
            .get("method")
            .and_then(|m| m.as_str())
            .ok_or("spec is missing the \"method\" string")?;
        let method = MethodSpec::parse(method_name)?;
        let scheme_name = v
            .get("scheme")
            .and_then(|s| s.as_str())
            .ok_or("spec is missing the \"scheme\" string")?;
        let scheme = Scheme::parse(scheme_name)
            .ok_or_else(|| format!("unknown scheme {scheme_name:?}"))?;
        // absent span keys take the [0, 1] defaults, but a key that is
        // present and not a number is an error, never a silent default
        let span_field = |key: &str, default: f64| -> Result<f64, String> {
            match v.get(key) {
                None => Ok(default),
                Some(x) => x
                    .as_f64()
                    .ok_or_else(|| format!("spec field {key:?} must be a number (got {x:?})")),
            }
        };
        let t0 = span_field("t0", 0.0)?;
        let tf = span_field("tf", 1.0)?;
        let grid = match v.get("grid") {
            Some(g) => grid_from_json(g)?,
            None => return Err("spec is missing the \"grid\" object".into()),
        };
        let exec = match v.get("exec") {
            None | Some(Json::Null) => None,
            Some(e) => {
                let workers = e
                    .get("workers")
                    .and_then(|w| w.as_usize())
                    .ok_or("exec needs a \"workers\" count")?;
                // absent takes the default; present-but-not-a-number is
                // an error, never a silent default (same rule as t0/tf)
                let shard_rows = match e.get("shard_rows") {
                    None => DEFAULT_SHARD_ROWS,
                    Some(r) => r.as_usize().ok_or_else(|| {
                        format!("exec field \"shard_rows\" must be a number (got {r:?})")
                    })?,
                };
                Some(ExecConfig { workers, shard_rows })
            }
        };
        let arch = match v.get("arch") {
            None | Some(Json::Null) => None,
            Some(a) => Some(ArchSpec::from_json(a)?),
        };
        let obs = match v.get("obs") {
            None | Some(Json::Null) => None,
            Some(o) => {
                // a present obs block with no "enabled" key means on (the
                // block's presence is the signal); present-but-not-a-bool
                // is an error, never a silent default
                let enabled = match o.get("enabled") {
                    None => true,
                    Some(b) => b.as_bool().ok_or_else(|| {
                        format!("obs field \"enabled\" must be a bool (got {b:?})")
                    })?,
                };
                Some(ObsSpec { enabled })
            }
        };
        let spec = RunSpec { method, scheme, t0, tf, grid, exec, arch, obs };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a spec from JSON text (file contents).
    pub fn parse_json(text: &str) -> Result<RunSpec, String> {
        let v = crate::util::json::parse(text).map_err(|e| e.to_string())?;
        RunSpec::from_json(&v)
    }
}

fn grid_to_json(grid: &TimeGrid) -> Json {
    match grid {
        TimeGrid::Uniform { nt } => Json::obj(vec![
            ("kind", Json::str("uniform")),
            ("nt", Json::num(*nt as f64)),
        ]),
        TimeGrid::Explicit(steps) => Json::obj(vec![
            ("kind", Json::str("explicit")),
            (
                "steps",
                Json::Arr(
                    steps
                        .iter()
                        .map(|(t, h)| Json::Arr(vec![Json::num(*t), Json::num(*h)]))
                        .collect(),
                ),
            ),
        ]),
        TimeGrid::Adaptive { atol, rtol, h0 } => {
            let mut kv = vec![
                ("kind", Json::str("adaptive")),
                ("atol", Json::num(*atol)),
                ("rtol", Json::num(*rtol)),
            ];
            if let Some(h0) = h0 {
                kv.push(("h0", Json::num(*h0)));
            }
            Json::obj(kv)
        }
    }
}

fn grid_from_json(g: &Json) -> Result<TimeGrid, String> {
    let kind = g
        .get("kind")
        .and_then(|k| k.as_str())
        .ok_or("grid needs a \"kind\" string (uniform | explicit | adaptive)")?;
    match kind {
        "uniform" => {
            let nt = g
                .get("nt")
                .and_then(|n| n.as_usize())
                .ok_or("uniform grid needs an \"nt\" count")?;
            Ok(TimeGrid::Uniform { nt })
        }
        "explicit" => {
            let steps = g
                .get("steps")
                .and_then(|s| s.as_arr())
                .ok_or("explicit grid needs a \"steps\" array of [t, h] pairs")?;
            let mut out = Vec::with_capacity(steps.len());
            for s in steps {
                let pair = s.as_arr().filter(|p| p.len() == 2);
                let (t, h) = match pair {
                    Some(p) => (p[0].as_f64(), p[1].as_f64()),
                    None => (None, None),
                };
                match (t, h) {
                    (Some(t), Some(h)) => out.push((t, h)),
                    _ => return Err(format!("bad explicit grid step {s:?} (want [t, h])")),
                }
            }
            Ok(TimeGrid::Explicit(out))
        }
        "adaptive" => {
            let atol = g
                .get("atol")
                .and_then(|x| x.as_f64())
                .ok_or("adaptive grid needs \"atol\"")?;
            let rtol = g.get("rtol").and_then(|x| x.as_f64()).unwrap_or(atol);
            let h0 = g.get("h0").and_then(|x| x.as_f64());
            Ok(TimeGrid::Adaptive { atol, rtol, h0 })
        }
        k => Err(format!("unknown grid kind {k:?} (want uniform | explicit | adaptive)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_spec_parse_roundtrip_and_errors() {
        for name in METHOD_NAMES {
            let m = MethodSpec::parse(name).unwrap();
            assert_eq!(m.name(), *name, "canonical name round-trips");
            assert_eq!(MethodSpec::parse(&m.name()), Ok(m));
        }
        let m = MethodSpec::parse("pnode:binomial:4").unwrap();
        assert_eq!(
            m.pnode_policy(),
            Some(&CheckpointPolicy::Binomial { n_checkpoints: 4 })
        );
        assert_eq!(m.family(), "pnode");
        assert!(!MethodSpec::NodeCont.reverse_accurate());
        assert!(m.reverse_accurate());

        // the underlying policy-parse message survives (the old
        // method_by_name swallowed it via ok()?)
        let e = MethodSpec::parse("pnode:binomial:0").unwrap_err();
        assert!(e.contains("binomial:0") && e.contains("at least one"), "{e}");
        let e = MethodSpec::parse("pnode:tiered:8m").unwrap_err();
        assert!(e.contains("spill dir"), "{e}");
        let e = MethodSpec::parse("nope").unwrap_err();
        assert!(e.contains("nope"), "{e}");
    }

    #[test]
    fn obs_block_round_trips_and_defaults_off() {
        let spec = crate::api::SolverBuilder::new().uniform(4).build().unwrap();
        assert!(spec.obs.is_none(), "off by default");
        assert_eq!(RunSpec::from_json(&spec.to_json()).unwrap(), spec);

        let spec = crate::api::SolverBuilder::new()
            .uniform(4)
            .observe(true)
            .build()
            .unwrap();
        let back = RunSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.obs, Some(ObsSpec { enabled: true }));
        assert_eq!(back, spec, "lossless round-trip");

        // a bare obs block means on; a non-bool "enabled" is an error
        let base = r#"{"method":"pnode","scheme":"rk4","grid":{"kind":"uniform","nt":4}"#;
        let v = crate::util::json::parse(&format!("{base},\"obs\":{{}}}}")).unwrap();
        assert_eq!(
            RunSpec::from_json(&v).unwrap().obs,
            Some(ObsSpec { enabled: true })
        );
        let v =
            crate::util::json::parse(&format!("{base},\"obs\":{{\"enabled\":1}}}}")).unwrap();
        assert!(RunSpec::from_json(&v).unwrap_err().contains("enabled"));
    }

    #[test]
    fn programmatic_degenerate_policies_are_rejected() {
        let bad = MethodSpec::Pnode {
            policy: CheckpointPolicy::Binomial { n_checkpoints: 0 },
        };
        assert!(bad.validate().unwrap_err().contains("binomial"));
        let bad = MethodSpec::Pnode {
            policy: CheckpointPolicy::Tiered {
                budget_bytes: 0,
                dir: "/tmp/x".into(),
                compress_f16: false,
                inner: Box::new(CheckpointPolicy::All),
            },
        };
        assert!(bad.validate().unwrap_err().contains("nonzero"));
    }
}
