//! `SolverBuilder` — the fluent constructor for [`RunSpec`]s.
//!
//! Every knob has a typed setter and (where a CLI-facing string grammar
//! exists) a `_str` twin that defers parse errors to [`SolverBuilder::build`],
//! so call chains stay fluent and the first error — parse or validation —
//! comes back as one `Result` with the underlying message intact.

use crate::api::session::Session;
use crate::api::spec::{MethodSpec, ObsSpec, RunSpec};
use crate::checkpoint::CheckpointPolicy;
use crate::exec::{default_workers, ExecConfig, DEFAULT_SHARD_ROWS};
use crate::nn::module::ArchSpec;
use crate::ode::grid::TimeGrid;
use crate::ode::tableau::Scheme;

/// Builds a validated [`RunSpec`].  Defaults: `pnode` (checkpoint
/// everything), RK4, 8 uniform steps over `[0, 1]`, single-engine
/// execution.
pub struct SolverBuilder {
    method: MethodSpec,
    scheme: Scheme,
    t0: f64,
    tf: f64,
    grid: TimeGrid,
    exec: Option<ExecConfig>,
    arch: Option<ArchSpec>,
    obs: Option<ObsSpec>,
    /// first deferred `_str` parse error; reported by `build`
    err: Option<String>,
}

impl Default for SolverBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SolverBuilder {
    pub fn new() -> Self {
        SolverBuilder {
            method: MethodSpec::Pnode { policy: CheckpointPolicy::All },
            scheme: Scheme::Rk4,
            t0: 0.0,
            tf: 1.0,
            grid: TimeGrid::Uniform { nt: 8 },
            exec: None,
            arch: None,
            obs: None,
            err: None,
        }
    }

    /// Start from an existing spec (tweak-and-rebuild).
    pub fn from_spec(spec: RunSpec) -> Self {
        SolverBuilder {
            method: spec.method,
            scheme: spec.scheme,
            t0: spec.t0,
            tf: spec.tf,
            grid: spec.grid,
            exec: spec.exec,
            arch: spec.arch,
            obs: spec.obs,
            err: None,
        }
    }

    fn fail(mut self, e: String) -> Self {
        if self.err.is_none() {
            self.err = Some(e);
        }
        self
    }

    // ---------------- method ----------------

    pub fn method(mut self, method: MethodSpec) -> Self {
        self.method = method;
        self
    }

    /// Method from the CLI grammar (`pnode`, `pnode2`, `pnode:<policy>`,
    /// `cont`, `naive`, `anode`, `aca`).
    pub fn method_str(self, s: &str) -> Self {
        match MethodSpec::parse(s) {
            Ok(m) => self.method(m),
            Err(e) => self.fail(e),
        }
    }

    /// Shorthand: the PNODE family with this checkpoint policy.
    pub fn policy(self, policy: CheckpointPolicy) -> Self {
        self.method(MethodSpec::Pnode { policy })
    }

    /// Shorthand: the PNODE family with a parsed checkpoint policy.
    pub fn policy_str(self, s: &str) -> Self {
        match CheckpointPolicy::parse(s) {
            Ok(p) => self.policy(p),
            Err(e) => self.fail(e),
        }
    }

    // ---------------- scheme / span / grid ----------------

    pub fn scheme(mut self, scheme: Scheme) -> Self {
        self.scheme = scheme;
        self
    }

    pub fn scheme_str(self, s: &str) -> Self {
        match Scheme::parse(s) {
            Some(sc) => self.scheme(sc),
            None => {
                let e = format!("unknown scheme {s:?}");
                self.fail(e)
            }
        }
    }

    /// Integration window `[t0, tf]`.
    pub fn span(mut self, t0: f64, tf: f64) -> Self {
        self.t0 = t0;
        self.tf = tf;
        self
    }

    pub fn grid(mut self, grid: TimeGrid) -> Self {
        self.grid = grid;
        self
    }

    /// Grid from the CLI grammar (`uniform`, `uniform:<nt>`,
    /// `adaptive:<atol>[:<rtol>[:<h0>]]`); `default_nt` fills the bare
    /// `uniform` form.
    pub fn grid_str(self, s: &str, default_nt: usize) -> Self {
        match TimeGrid::parse(s, default_nt) {
            Ok(g) => self.grid(g),
            Err(e) => self.fail(e),
        }
    }

    /// `nt` equal steps.
    pub fn uniform(self, nt: usize) -> Self {
        self.grid(TimeGrid::Uniform { nt })
    }

    /// PI-controlled adaptation with `atol = rtol = tol`.
    pub fn adaptive(self, tol: f64) -> Self {
        self.grid(TimeGrid::adaptive(tol))
    }

    // ---------------- architecture ----------------

    /// Declare the dynamics architecture the run executes
    /// (serialized with the spec; tasks build their RHS from it).
    pub fn arch(mut self, arch: ArchSpec) -> Self {
        self.arch = Some(arch);
        self
    }

    /// Architecture from the CLI grammar (`mlp:…`, `concat:…`,
    /// `concatsquash:…`, `residual:…`, `augment:…` — see
    /// [`ArchSpec::parse`]).
    pub fn arch_str(self, s: &str) -> Self {
        match ArchSpec::parse(s) {
            Ok(a) => self.arch(a),
            Err(e) => self.fail(e),
        }
    }

    // ---------------- execution ----------------

    /// Run on the data-parallel execution engine with this config.
    pub fn parallel(mut self, cfg: ExecConfig) -> Self {
        self.exec = Some(cfg);
        self
    }

    /// Data-parallel with `workers` threads (keeps any configured shard
    /// size, else the default).
    pub fn workers(mut self, workers: usize) -> Self {
        let shard_rows = self.exec.map(|c| c.shard_rows).unwrap_or(DEFAULT_SHARD_ROWS);
        self.exec = Some(ExecConfig { workers, shard_rows });
        self
    }

    /// Rows per shard of the data-parallel engine (the determinism knob).
    pub fn shard_rows(mut self, shard_rows: usize) -> Self {
        let workers = self.exec.map(|c| c.workers).unwrap_or_else(default_workers);
        self.exec = Some(ExecConfig { workers, shard_rows });
        self
    }

    /// Back to the single in-thread engine.
    pub fn single(mut self) -> Self {
        self.exec = None;
        self
    }

    // ---------------- observability ----------------

    /// Record trace events and metrics for runs on this spec
    /// (DESIGN.md §11).  Opening a [`Session`] on the built spec switches
    /// on the process-global obs sink; recording never changes gradients.
    pub fn observe(mut self, enabled: bool) -> Self {
        self.obs = Some(ObsSpec { enabled });
        self
    }

    // ---------------- terminal ----------------

    /// Validate and produce the spec: the first deferred parse error or
    /// degenerate-combination violation comes back here.
    pub fn build(self) -> Result<RunSpec, String> {
        if let Some(e) = self.err {
            return Err(e);
        }
        let spec = RunSpec {
            method: self.method,
            scheme: self.scheme,
            t0: self.t0,
            tf: self.tf,
            grid: self.grid,
            exec: self.exec,
            arch: self.arch,
            obs: self.obs,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Build and open a [`Session`] in one call.
    pub fn session(self) -> Result<Session, String> {
        Session::new(self.build()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_build_and_setters_stick() {
        let spec = SolverBuilder::new().build().unwrap();
        assert_eq!(spec.method.name(), "pnode");
        assert_eq!(spec.scheme, Scheme::Rk4);
        assert_eq!(spec.grid, TimeGrid::Uniform { nt: 8 });
        assert!(spec.exec.is_none());

        let spec = SolverBuilder::new()
            .method_str("pnode:binomial:3")
            .scheme_str("dopri5")
            .span(0.0, 2.0)
            .adaptive(1e-6)
            .workers(4)
            .shard_rows(8)
            .build()
            .unwrap();
        assert_eq!(spec.method.name(), "pnode:binomial:3");
        assert_eq!(spec.scheme, Scheme::Dopri5);
        assert_eq!(spec.tf, 2.0);
        assert_eq!(spec.exec, Some(ExecConfig { workers: 4, shard_rows: 8 }));
        assert_eq!(SolverBuilder::from_spec(spec.clone()).build(), Ok(spec));
    }

    #[test]
    fn first_error_wins_and_carries_the_message() {
        let e = SolverBuilder::new()
            .method_str("pnode:binomial:0")
            .scheme_str("nope")
            .build()
            .unwrap_err();
        assert!(e.contains("binomial:0"), "deferred parse error first: {e}");

        let e = SolverBuilder::new().scheme_str("nope").build().unwrap_err();
        assert!(e.contains("nope"), "{e}");
        let e = SolverBuilder::new().grid_str("uniform:0", 8).build().unwrap_err();
        assert!(e.contains("nt >= 1"), "{e}");
    }

    #[test]
    fn degenerate_combinations_are_rejected_at_build() {
        // workers = 0
        let e = SolverBuilder::new().workers(0).build().unwrap_err();
        assert!(e.contains("workers"), "{e}");
        // adaptive grid on a scheme without an embedded pair
        let e = SolverBuilder::new()
            .scheme(Scheme::Rk4)
            .adaptive(1e-6)
            .build()
            .unwrap_err();
        assert!(e.contains("embedded"), "{e}");
        // implicit scheme under a baseline method
        let e = SolverBuilder::new()
            .method_str("aca")
            .scheme(Scheme::CrankNicolson)
            .build()
            .unwrap_err();
        assert!(e.contains("implicit"), "{e}");
        // inverted span
        let e = SolverBuilder::new().span(1.0, 0.0).build().unwrap_err();
        assert!(e.contains("t0 < tf"), "{e}");
        // zero tier budget (programmatic; the string parser also rejects)
        let e = SolverBuilder::new()
            .policy(CheckpointPolicy::Tiered {
                budget_bytes: 0,
                dir: "/tmp/x".into(),
                compress_f16: false,
                inner: Box::new(CheckpointPolicy::All),
            })
            .build()
            .unwrap_err();
        assert!(e.contains("nonzero"), "{e}");
    }
}
