//! `Session` — the long-lived execution handle behind the facade.
//!
//! A session is built once from a validated [`RunSpec`] and then reused
//! across gradient calls: it owns the gradient engine the registry
//! resolved (including, for parallel tiered specs, the engine's shared
//! [`crate::exec::BudgetArbiter`] and checkpoint backends) plus the λ and
//! θ̄ workspaces of the [`Session::grad`] hot path.  Repeated `grad` calls
//! with stable shapes reuse those buffers and the engine instead of
//! re-allocating per step — observable through
//! [`Session::workspace_allocs`], which the serving-path tests pin to 1.
//!
//! Two call styles:
//!
//! * [`Session::grad`] — one-shot `(u0, λ_F) → (u_F, report)` with the
//!   gradients left in the session workspace ([`Session::lambda0`],
//!   [`Session::grad_theta`]): the serving hot path.
//! * [`Session::forward`] / [`Session::backward`] — split halves for
//!   callers that chain blocks or inject λ jumps between them (the tasks
//!   layer: one session per ODE block / observation segment).

use crate::api::registry::{global, MethodRegistry};
use crate::api::spec::RunSpec;
use crate::checkpoint::CheckpointPolicy;
use crate::methods::{AutoNote, BlockSpec, GradientMethod, MethodReport};
use crate::obs;
use crate::ode::forward::{forward_over_into, ForwardWorkspace};
use crate::ode::rhs::OdeRhs;

/// Outcome of one [`Session::grad`] call.  `u_f` is owned; the gradient
/// buffers live in the session's reusable workspace — read them via
/// [`Session::lambda0`] / [`Session::grad_theta`] (or copy out) before
/// the next call overwrites them.
pub struct GradReport {
    /// final state `u(t_F)`
    pub u_f: Vec<f32>,
    /// resource accounting of this forward+backward
    pub report: MethodReport,
}

pub struct Session {
    spec: RunSpec,
    /// the spec the engine actually runs: `auto:<budget>` replaced by the
    /// cost model's winning concrete policy (identical to `spec` otherwise)
    resolved_spec: RunSpec,
    /// requested-vs-resolved note stamped onto every report this session
    /// emits (the default note for concrete specs)
    auto: AutoNote,
    block: BlockSpec,
    engine: Box<dyn GradientMethod>,
    /// reusable λ workspace: seeded with ∂L/∂u_F, left holding ∂L/∂u_0
    lambda: Vec<f32>,
    /// reusable θ̄ accumulation workspace
    grad: Vec<f32>,
    /// reusable forward-only workspace ([`Session::forward_into`])
    fwd: ForwardWorkspace,
    workspace_allocs: u64,
    /// times the forward-only workspace was (re)allocated
    forward_allocs: u64,
    grads_run: u64,
    forwards_run: u64,
}

impl Session {
    /// Validate the spec and resolve its engine from the global registry.
    pub fn new(spec: RunSpec) -> Result<Session, String> {
        Session::with_registry(spec, global())
    }

    /// Like [`Session::new`] against a custom registry.
    pub fn with_registry(spec: RunSpec, registry: &MethodRegistry) -> Result<Session, String> {
        spec.validate()?;
        // the sink is process-global: a spec that asks for observability
        // switches it on for the process; sessions never switch it off
        // (another live session may want it)
        if spec.obs.map_or(false, |o| o.enabled) {
            obs::enable();
        }
        // one instant event naming the resolved GEMM kernel path; emitted
        // here (not lazily at first GEMM) so its (tid, seq) slot in the
        // trace is deterministic across runs and worker counts
        crate::tensor::gemm::note_dispatch();
        // resolve `auto:<budget>` once up front so this session can
        // report both the requested and the winning policy; the registry
        // would resolve identically on its own (same ledger, same model),
        // but then the choice would be invisible to reports
        let (resolved_spec, auto) = match crate::obs::calibrate::resolve_spec(&spec)? {
            Some((resolved, budget, policy)) => {
                (resolved, AutoNote::for_resolution(budget, &policy))
            }
            None => (spec.clone(), AutoNote::default()),
        };
        let engine = registry.make(&resolved_spec)?;
        let block = spec.block_spec();
        Ok(Session {
            spec,
            resolved_spec,
            auto,
            block,
            engine,
            lambda: Vec::new(),
            grad: Vec::new(),
            fwd: ForwardWorkspace::new(),
            workspace_allocs: 0,
            forward_allocs: 0,
            grads_run: 0,
            forwards_run: 0,
        })
    }

    pub fn spec(&self) -> &RunSpec {
        &self.spec
    }

    /// The spec the engine actually runs: for `auto:<budget>` specs the
    /// method carries the resolved concrete policy; otherwise identical
    /// to [`Session::spec`].
    pub fn resolved_spec(&self) -> &RunSpec {
        &self.resolved_spec
    }

    /// The concrete checkpoint policy an `auto:<budget>` spec resolved
    /// to; `None` when the spec named a concrete policy itself.
    pub fn resolved_policy(&self) -> Option<&CheckpointPolicy> {
        if self.auto.is_auto() {
            self.resolved_spec.method.pnode_policy()
        } else {
            None
        }
    }

    pub fn block_spec(&self) -> &BlockSpec {
        &self.block
    }

    /// Build the dynamics the spec declares over `batch` rows
    /// (see [`RunSpec::make_rhs`]; errors when the spec has no `"arch"`).
    pub fn make_rhs(
        &self,
        data_dim: usize,
        batch: usize,
        theta: Vec<f32>,
    ) -> Result<crate::ode::ModuleRhs, String> {
        self.spec.make_rhs(data_dim, batch, theta)
    }

    /// Integrate forward through the gradient engine; must precede
    /// [`Session::backward`].
    ///
    /// **Deprecated for inference**: this path allocates a fresh
    /// `Vec<f32>` per call and pays the engine's checkpoint store work.
    /// Call it only when a backward pass follows (it records the forward
    /// trajectory); for forward-only evaluation use
    /// [`Session::forward_into`], which is allocation-free at steady
    /// state and bitwise identical.
    pub fn forward(&mut self, rhs: &dyn OdeRhs, u0: &[f32]) -> Vec<f32> {
        let _sp = obs::span("session.forward");
        self.engine.forward(rhs, &self.block, u0)
    }

    /// Forward-only inference into a caller buffer — the serving fast
    /// path.  Skips the engine and with it every checkpoint
    /// store/restore (the `CheckpointPolicy::None`-equivalent internal
    /// mode; an `auto:<budget>` policy trivially resolves to it here
    /// because no backward pass is requested), integrating directly on
    /// the session-owned [`ForwardWorkspace`].  Bitwise identical to
    /// [`Session::forward`] for every method family and grid kind
    /// (checkpoint sinks never change values; see
    /// `crate::ode::forward`), and allocation-free once the state shape
    /// is warm — observable through [`Session::forward_allocs`].
    ///
    /// Records nothing: a [`Session::backward`] call must be preceded by
    /// [`Session::forward`], not by this.  Implicit θ-schemes fall back
    /// to the engine path (serving stiff implicit models is off the hot
    /// path and allocates; the fallback still counts a forward alloc).
    pub fn forward_into(&mut self, rhs: &dyn OdeRhs, u0: &[f32], out: &mut [f32]) {
        let _sp = obs::span("session.forward_into");
        assert_eq!(out.len(), u0.len(), "forward_into: out must match u0's length");
        if self.block.scheme.is_implicit() {
            let u_f = self.engine.forward(rhs, &self.block, u0);
            out.copy_from_slice(&u_f);
            self.forward_allocs += 1;
            self.forwards_run += 1;
            return;
        }
        let tab = self.block.scheme.tableau();
        if self.fwd.ensure(tab.s, u0.len()) {
            self.forward_allocs += 1;
        }
        forward_over_into(tab, rhs, self.block.t0, self.block.tf, &self.block.grid, u0, &mut self.fwd, out);
        self.forwards_run += 1;
    }

    /// Propagate `lambda` (∂L/∂u_F → ∂L/∂u_0) through the latest forward
    /// pass, accumulating into `grad_theta` (caller-owned buffers — the
    /// blocks/λ-jumps call style).
    pub fn backward(&mut self, rhs: &dyn OdeRhs, lambda: &mut [f32], grad_theta: &mut [f32]) {
        let _sp = obs::span("session.backward");
        self.engine.backward(rhs, &self.block, lambda, grad_theta);
    }

    /// One full gradient on the reusable workspace: forward from `u0`,
    /// backward from `lambda_f = ∂L/∂u_F`.  Afterwards
    /// [`Session::lambda0`] holds ∂L/∂u_0 and [`Session::grad_theta`]
    /// holds ∂L/∂θ.
    pub fn grad(&mut self, rhs: &dyn OdeRhs, u0: &[f32], lambda_f: &[f32]) -> GradReport {
        let _sp = obs::span("session.grad");
        let param_len = rhs.param_len();
        if self.lambda.len() != lambda_f.len() || self.grad.len() != param_len {
            self.lambda = vec![0.0; lambda_f.len()];
            self.grad = vec![0.0; param_len];
            self.workspace_allocs += 1;
        }
        self.lambda.copy_from_slice(lambda_f);
        self.grad.fill(0.0);
        let u_f = self.engine.forward(rhs, &self.block, u0);
        self.engine
            .backward(rhs, &self.block, &mut self.lambda, &mut self.grad);
        self.grads_run += 1;
        let mut report = self.engine.report();
        report.auto = self.auto;
        GradReport { u_f, report }
    }

    /// ∂L/∂u_0 of the latest [`Session::grad`] call.
    pub fn lambda0(&self) -> &[f32] {
        &self.lambda
    }

    /// ∂L/∂θ of the latest [`Session::grad`] call.
    pub fn grad_theta(&self) -> &[f32] {
        &self.grad
    }

    /// Accounting of the latest forward+backward (either call style).
    pub fn report(&self) -> MethodReport {
        let mut report = self.engine.report();
        report.auto = self.auto;
        report
    }

    /// How many times the `grad` workspace was (re)allocated.  Stable
    /// shapes keep this at 1 across any number of calls — the serving
    /// hot-path invariant.
    pub fn workspace_allocs(&self) -> u64 {
        self.workspace_allocs
    }

    /// Completed [`Session::grad`] calls.
    pub fn grads_run(&self) -> u64 {
        self.grads_run
    }

    /// How many times the forward-only workspace was (re)allocated.
    /// Stable state shapes keep this at 1 across any number of
    /// [`Session::forward_into`] calls — the serve path's steady-state
    /// zero-allocation invariant.
    pub fn forward_allocs(&self) -> u64 {
        self.forward_allocs
    }

    /// Completed [`Session::forward_into`] calls.
    pub fn forwards_run(&self) -> u64 {
        self.forwards_run
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SolverBuilder;
    use crate::nn::Act;
    use crate::ode::ModuleRhs;
    use crate::util::rng::Rng;

    fn mk_rhs(seed: u64) -> ModuleRhs {
        let dims = vec![5, 9, 4];
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
        ModuleRhs::mlp(dims, Act::Tanh, true, 2, theta)
    }

    #[test]
    fn grad_matches_split_forward_backward_bitwise() {
        let rhs = mk_rhs(601);
        let mut rng = Rng::new(602);
        let mut u0 = vec![0.0f32; rhs.state_len()];
        rng.fill_normal(&mut u0);
        let w = vec![1.0f32; rhs.state_len()];

        let spec = SolverBuilder::new().uniform(6).build().unwrap();
        let mut one = Session::new(spec.clone()).unwrap();
        let out = one.grad(&rhs, &u0, &w);

        let mut two = Session::new(spec).unwrap();
        let uf = two.forward(&rhs, &u0);
        let mut lam = w.clone();
        let mut g = vec![0.0f32; rhs.param_len()];
        two.backward(&rhs, &mut lam, &mut g);

        assert_eq!(out.u_f, uf);
        assert_eq!(one.lambda0(), &lam[..]);
        assert_eq!(one.grad_theta(), &g[..]);
        assert_eq!(one.grads_run(), 1);
        assert!(out.report.nfe_forward > 0);
    }

    #[test]
    fn workspaces_allocate_once_across_repeated_grads() {
        let rhs = mk_rhs(611);
        let mut rng = Rng::new(612);
        let mut u0 = vec![0.0f32; rhs.state_len()];
        rng.fill_normal(&mut u0);
        let w = vec![1.0f32; rhs.state_len()];

        let mut s = SolverBuilder::new().uniform(5).session().unwrap();
        for _ in 0..4 {
            let _ = s.grad(&rhs, &u0, &w);
        }
        assert_eq!(s.workspace_allocs(), 1, "stable shapes never re-allocate");
        assert_eq!(s.grads_run(), 4);
    }

    #[test]
    fn forward_into_matches_engine_forward_bitwise_and_never_reallocates() {
        let rhs = mk_rhs(621);
        let mut rng = Rng::new(622);
        let mut u0 = vec![0.0f32; rhs.state_len()];
        rng.fill_normal(&mut u0);

        for spec in [
            SolverBuilder::new().uniform(6).build().unwrap(),
            SolverBuilder::new()
                .scheme(crate::ode::Scheme::Dopri5)
                .grid(crate::ode::TimeGrid::adaptive(1e-6))
                .build()
                .unwrap(),
        ] {
            let mut s = Session::new(spec).unwrap();
            let reference = s.forward(&rhs, &u0);
            let mut out = vec![0.0f32; u0.len()];
            for _ in 0..3 {
                s.forward_into(&rhs, &u0, &mut out);
                assert_eq!(reference, out, "forward_into must be bitwise = forward");
            }
            assert_eq!(s.forward_allocs(), 1, "stable shapes never re-allocate");
            assert_eq!(s.forwards_run(), 3);
            assert_eq!(s.workspace_allocs(), 0, "the grad workspace is untouched");
        }
    }

    #[test]
    fn invalid_specs_never_open_a_session() {
        let spec = SolverBuilder::new().build().unwrap();
        let mut bad = spec.clone();
        bad.exec = Some(crate::exec::ExecConfig { workers: 0, shard_rows: 4 });
        assert!(Session::new(bad).is_err(), "post-build mutation is re-validated");
    }
}
