//! `MethodRegistry` — gradient-engine factories keyed by method family.
//!
//! The registry is the single place where a validated [`RunSpec`] becomes
//! a concrete [`GradientMethod`]: the five paper methods register here,
//! and the data-parallel wrapper composes on top of any of them when the
//! spec carries an [`crate::exec::ExecConfig`].  Tasks, benches, the CLI,
//! and the examples never name engine types — they go through
//! [`crate::api::Session`] (or [`RunSpec::make_engine`]), which resolves
//! against the [`global`] registry.
//!
//! Fleet memory: a parallel `pnode` spec with a `Tiered` policy routes
//! through [`ParallelAdjoint::pnode`], which lifts the policy's budget
//! into ONE shared [`crate::exec::BudgetArbiter`] pool for the whole
//! shard fleet — the special arbiter constructors are crate-internal
//! plumbing behind this one entry point.

use std::sync::{Arc, OnceLock};

use crate::api::spec::{MethodSpec, RunSpec};
use crate::methods::theta::ImplicitAdjoint;
use crate::methods::{Aca, Anode, GradientMethod, NodeCont, NodeNaive, ParallelAdjoint, Pnode};

/// An engine factory: a validated spec in, a fresh gradient engine out.
pub type EngineFn = dyn Fn(&RunSpec) -> Box<dyn GradientMethod> + Send + Sync;

pub struct MethodRegistry {
    entries: Vec<(String, Arc<EngineFn>)>,
    /// index of the built-in `pnode` factory: only *its* parallel form
    /// takes the `ParallelAdjoint::pnode` arbiter-sharing shortcut — a
    /// custom `pnode` registration shadows the built-in on every path,
    /// including parallel specs (which then get the generic wrapper)
    builtin_pnode: Option<usize>,
}

impl MethodRegistry {
    /// A registry with no entries (extension/test baseline).
    pub fn empty() -> Self {
        MethodRegistry { entries: Vec::new(), builtin_pnode: None }
    }

    /// The five paper methods.  `pnode` dispatches on the spec's scheme:
    /// explicit RK runs [`Pnode`], implicit θ-schemes run
    /// [`ImplicitAdjoint`].
    pub fn with_builtins() -> Self {
        let mut r = MethodRegistry::empty();
        r.register("pnode", |spec: &RunSpec| {
            let policy = spec
                .method
                .pnode_policy()
                .cloned()
                .unwrap_or(crate::checkpoint::CheckpointPolicy::All);
            if spec.scheme.is_implicit() {
                Box::new(ImplicitAdjoint::new(policy))
            } else {
                Box::new(Pnode::new(policy))
            }
        });
        r.builtin_pnode = Some(r.entries.len() - 1);
        r.register("cont", |_spec: &RunSpec| Box::new(NodeCont::new()));
        r.register("naive", |_spec: &RunSpec| Box::new(NodeNaive::new()));
        r.register("anode", |_spec: &RunSpec| Box::new(Anode::new()));
        r.register("aca", |_spec: &RunSpec| Box::new(Aca::new()));
        r
    }

    /// Register a factory for `family` (later registrations shadow
    /// earlier ones, so built-ins can be overridden).
    pub fn register<F>(&mut self, family: &str, f: F)
    where
        F: Fn(&RunSpec) -> Box<dyn GradientMethod> + Send + Sync + 'static,
    {
        self.entries.push((family.to_string(), Arc::new(f)));
    }

    /// Registered family keys, registration order.
    pub fn families(&self) -> Vec<&str> {
        self.entries.iter().map(|(k, _)| k.as_str()).collect()
    }

    /// Construct the engine a spec describes: the family's factory, with
    /// the data-parallel wrapper composed on top when `spec.exec` is set.
    pub fn make(&self, spec: &RunSpec) -> Result<Box<dyn GradientMethod>, String> {
        // `auto:<budget>` resolves to its concrete winner here — the one
        // chokepoint every engine construction funnels through, so tasks
        // and benches that bypass `Session` still get a runnable policy.
        // (`Session` resolves earlier itself, to record requested vs.
        // resolved in its reports; it then hands `make` a concrete spec.)
        if matches!(
            spec.method.pnode_policy(),
            Some(crate::checkpoint::CheckpointPolicy::Auto { .. })
        ) {
            let (resolved, _, _) = crate::obs::calibrate::resolve_spec(spec)?
                .ok_or_else(|| "auto policy did not resolve to a concrete spec".to_string())?;
            return self.make(&resolved);
        }
        let family = spec.method.family();
        let idx = self
            .entries
            .iter()
            .rposition(|(k, _)| k == family)
            .ok_or_else(|| {
                format!(
                    "no engine registered for method family {family:?} (registered: {:?})",
                    self.families()
                )
            })?;
        let f = Arc::clone(&self.entries[idx].1);
        match spec.exec {
            None => Ok(f(spec)),
            Some(cfg) => {
                if Some(idx) == self.builtin_pnode {
                    if let MethodSpec::Pnode { policy } = &spec.method {
                        // fleet mode: a Tiered policy's budget becomes one
                        // global arbiter pool shared by every shard's store
                        return Ok(Box::new(ParallelAdjoint::pnode(policy.clone(), cfg)));
                    }
                }
                let mut single = spec.clone();
                single.exec = None;
                Ok(Box::new(ParallelAdjoint::new(
                    Box::new(move || f(&single)),
                    cfg,
                )))
            }
        }
    }
}

static GLOBAL: OnceLock<MethodRegistry> = OnceLock::new();

/// The process-wide registry with the built-in factories.
pub fn global() -> &'static MethodRegistry {
    GLOBAL.get_or_init(MethodRegistry::with_builtins)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::spec::METHOD_NAMES;
    use crate::api::SolverBuilder;
    use crate::exec::ExecConfig;
    use crate::ode::tableau::Scheme;

    #[test]
    fn builtins_cover_every_paper_method() {
        for name in METHOD_NAMES {
            let spec = SolverBuilder::new().method_str(name).build().unwrap();
            let engine = global().make(&spec).unwrap();
            assert_eq!(
                engine.reverse_accurate(),
                spec.method.reverse_accurate(),
                "{name}"
            );
        }
        let spec = SolverBuilder::new()
            .method_str("pnode:binomial:4")
            .build()
            .unwrap();
        assert!(global().make(&spec).is_ok());
    }

    #[test]
    fn parallel_specs_wrap_every_family() {
        for name in METHOD_NAMES {
            let spec = SolverBuilder::new()
                .method_str(name)
                .parallel(ExecConfig { workers: 2, shard_rows: 4 })
                .build()
                .unwrap();
            let engine = global().make(&spec).unwrap();
            assert_eq!(engine.name(), "parallel", "{name}");
        }
    }

    #[test]
    fn implicit_schemes_dispatch_to_the_theta_engine() {
        let spec = SolverBuilder::new()
            .method_str("pnode2")
            .scheme(Scheme::CrankNicolson)
            .uniform(4)
            .build()
            .unwrap();
        let engine = global().make(&spec).unwrap();
        assert_eq!(engine.name(), "pnode-implicit");
    }

    #[test]
    fn arch_specs_resolve_engines_and_dynamics() {
        // a spec that declares its architecture still resolves engines by
        // method family, and the same document builds shardable dynamics
        use crate::nn::Act;
        use crate::nn::module::ArchSpec;
        use crate::ode::rhs::OdeRhs;
        let spec = SolverBuilder::new()
            .arch(ArchSpec::ConcatSquashMlp { hidden: vec![6], act: Act::Tanh })
            .uniform(4)
            .build()
            .unwrap();
        let engine = global().make(&spec).unwrap();
        assert!(engine.reverse_accurate());
        let mut rng = crate::util::rng::Rng::new(5);
        let theta = spec.init_theta(&mut rng, 3).unwrap();
        let rhs = spec.make_rhs(3, 4, theta).unwrap();
        assert_eq!(rhs.state_len(), 12);
        assert!(
            rhs.make_shard(2).is_some(),
            "arch-built dynamics must shard for the parallel wrapper"
        );
        // no arch → make_rhs is a clear error, not a panic
        let bare = SolverBuilder::new().build().unwrap();
        assert!(bare.make_rhs(3, 4, Vec::new()).unwrap_err().contains("arch"));
    }

    #[test]
    fn unknown_family_is_reported_and_registration_shadows() {
        let mut r = MethodRegistry::empty();
        let spec = SolverBuilder::new().build().unwrap();
        let e = r.make(&spec).unwrap_err();
        assert!(e.contains("pnode"), "{e}");
        r.register("pnode", |_s| Box::new(NodeNaive::new()));
        assert_eq!(r.make(&spec).unwrap().name(), "naive", "custom factory wins");
    }

    #[test]
    fn custom_pnode_factory_shadows_on_the_parallel_path_too() {
        // a custom "pnode" registration must win even when exec is set:
        // the arbiter-sharing shortcut is reserved for the built-in
        // NodeCont is the one non-reverse-accurate engine: if the
        // built-in shortcut ran instead of the custom factory, the
        // wrapper's probe would report reverse_accurate = true
        let mut r = MethodRegistry::with_builtins();
        r.register("pnode", |_s| Box::new(NodeCont::new()));
        let spec = SolverBuilder::new()
            .parallel(ExecConfig { workers: 2, shard_rows: 4 })
            .build()
            .unwrap();
        let engine = r.make(&spec).unwrap();
        assert_eq!(engine.name(), "parallel", "wrapped generically");
        assert!(!engine.reverse_accurate(), "probe ran the custom factory");
        // single-engine path shadows as before
        let single = SolverBuilder::new().build().unwrap();
        assert_eq!(r.make(&single).unwrap().name(), "cont");
    }
}
