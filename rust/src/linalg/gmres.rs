//! Restarted GMRES(m) with modified Gram–Schmidt and Givens rotations.
//!
//! Matrix-free: the operator is a closure `w -> A w`.  The implicit time
//! steps use it twice — forward with the JVP action
//! `w - hγ (∂f/∂u) w`, and in the adjoint with the *transposed* action
//! `w - hγ (∂f/∂u)ᵀ w` (paper eq. 13) — which is exactly why the framework
//! only ever needs Jacobian-vector products, never the matrix.

use crate::tensor;

#[derive(Clone, Debug)]
pub struct GmresOptions {
    /// restart length
    pub m: usize,
    /// relative tolerance on ||r|| / ||b||
    pub rtol: f64,
    /// absolute tolerance on ||r||
    pub atol: f64,
    pub max_restarts: usize,
}

impl Default for GmresOptions {
    fn default() -> Self {
        // f32 state vectors: tighter tolerances than ~1e-6 relative are not
        // reliably reachable (the paper's PETSc solves run f64)
        GmresOptions { m: 30, rtol: 1e-6, atol: 1e-9, max_restarts: 20 }
    }
}

#[derive(Clone, Debug)]
pub struct GmresResult {
    pub converged: bool,
    /// operator applications
    pub iters: usize,
    pub residual: f64,
}

/// Solve `A x = b`, overwriting `x` (initial guess in, solution out).
pub fn gmres<F>(mut apply: F, b: &[f32], x: &mut [f32], opts: &GmresOptions) -> GmresResult
where
    F: FnMut(&[f32], &mut [f32]),
{
    let n = b.len();
    let bnorm = tensor::nrm2(b).max(1e-300);
    let tol = (opts.rtol * bnorm).max(opts.atol);
    let m = opts.m.min(n.max(1));

    let mut iters = 0usize;
    let mut r = vec![0.0f32; n];
    let mut w = vec![0.0f32; n];
    // Krylov basis (m+1 vectors)
    let mut v: Vec<Vec<f32>> = (0..=m).map(|_| vec![0.0f32; n]).collect();
    // Hessenberg (column-major per iteration), Givens cos/sin, rhs g
    let mut hcol = vec![0.0f64; m + 1];
    let mut hmat = vec![0.0f64; (m + 1) * m];
    let mut cs = vec![0.0f64; m];
    let mut sn = vec![0.0f64; m];
    let mut g = vec![0.0f64; m + 1];

    for _restart in 0..=opts.max_restarts {
        // r = b - A x
        apply(x, &mut r);
        iters += 1;
        for i in 0..n {
            r[i] = b[i] - r[i];
        }
        let rnorm = tensor::nrm2(&r);
        if rnorm <= tol {
            return GmresResult { converged: true, iters, residual: rnorm };
        }

        // v0 = r / ||r||
        for i in 0..n {
            v[0][i] = (r[i] as f64 / rnorm) as f32;
        }
        g.iter_mut().for_each(|x| *x = 0.0);
        g[0] = rnorm;

        let mut k_used = 0;
        for k in 0..m {
            // w = A v_k
            apply(&v[k], &mut w);
            iters += 1;
            // modified Gram–Schmidt
            for j in 0..=k {
                let hjk = tensor::dot(&w, &v[j]);
                hcol[j] = hjk;
                tensor::axpy(-(hjk as f32), &v[j], &mut w);
            }
            let hk1 = tensor::nrm2(&w);
            hcol[k + 1] = hk1;
            if hk1 > 1e-300 {
                for i in 0..n {
                    v[k + 1][i] = (w[i] as f64 / hk1) as f32;
                }
            }
            // apply existing Givens rotations to the new column
            for j in 0..k {
                let t = cs[j] * hcol[j] + sn[j] * hcol[j + 1];
                hcol[j + 1] = -sn[j] * hcol[j] + cs[j] * hcol[j + 1];
                hcol[j] = t;
            }
            // new rotation to zero hcol[k+1]
            let denom = (hcol[k] * hcol[k] + hcol[k + 1] * hcol[k + 1]).sqrt();
            if denom > 1e-300 {
                cs[k] = hcol[k] / denom;
                sn[k] = hcol[k + 1] / denom;
            } else {
                cs[k] = 1.0;
                sn[k] = 0.0;
            }
            hcol[k] = cs[k] * hcol[k] + sn[k] * hcol[k + 1];
            hcol[k + 1] = 0.0;
            g[k + 1] = -sn[k] * g[k];
            g[k] = cs[k] * g[k];
            // store column
            for j in 0..=k + 1 {
                hmat[j * m + k] = hcol[j];
            }
            k_used = k + 1;
            if g[k + 1].abs() <= tol || hk1 <= 1e-300 {
                break;
            }
        }

        // back-substitute y from the k_used×k_used triangular system
        let mut y = vec![0.0f64; k_used];
        for j in (0..k_used).rev() {
            let mut acc = g[j];
            for l in j + 1..k_used {
                acc -= hmat[j * m + l] * y[l];
            }
            y[j] = acc / hmat[j * m + j];
        }
        // x += V y
        for j in 0..k_used {
            tensor::axpy(y[j] as f32, &v[j], x);
        }

        // convergence check for this cycle
        apply(x, &mut r);
        iters += 1;
        for i in 0..n {
            r[i] = b[i] - r[i];
        }
        let rnorm = tensor::nrm2(&r);
        if rnorm <= tol {
            return GmresResult { converged: true, iters, residual: rnorm };
        }
    }

    apply(x, &mut r);
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    GmresResult { converged: false, iters, residual: tensor::nrm2(&r) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn dense_apply(a: &[f32], n: usize) -> impl FnMut(&[f32], &mut [f32]) + '_ {
        move |x: &[f32], y: &mut [f32]| {
            for i in 0..n {
                let mut acc = 0.0f32;
                for j in 0..n {
                    acc += a[i * n + j] * x[j];
                }
                y[i] = acc;
            }
        }
    }

    #[test]
    fn solves_identity() {
        let n = 5;
        let b = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        let mut x = vec![0.0f32; n];
        let res = gmres(|v, out| out.copy_from_slice(v), &b, &mut x, &GmresOptions::default());
        assert!(res.converged);
        crate::testing::assert_allclose(&x, &b, 1e-6, 1e-7, "identity solve");
    }

    #[test]
    fn solves_random_spd_systems() {
        prop::check("gmres-spd", 13, 10, |rng| {
            let n = prop::size_in(rng, 2, 20);
            // A = M Mᵀ + n I (well-conditioned SPD)
            let m = prop::vec_normal(rng, n * n);
            let mut a = vec![0.0f32; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for k in 0..n {
                        acc += m[i * n + k] * m[j * n + k];
                    }
                    a[i * n + j] = acc + if i == j { n as f32 } else { 0.0 };
                }
            }
            let xtrue = prop::vec_normal(rng, n);
            let mut b = vec![0.0f32; n];
            dense_apply(&a, n)(&xtrue, &mut b);
            let mut x = vec![0.0f32; n];
            let res = gmres(dense_apply(&a, n), &b, &mut x, &GmresOptions::default());
            if !res.converged {
                return Err(format!("no convergence, res {:.2e}", res.residual));
            }
            let err = crate::testing::rel_l2(&x, &xtrue);
            if err > 1e-4 {
                return Err(format!("solution error {err:.2e}"));
            }
            Ok(())
        });
    }

    #[test]
    fn restarted_solve_nontrivial() {
        // force restarts with small m on a shifted random matrix
        let n = 40;
        let mut rng = Rng::new(21);
        let mut a = prop::vec_normal(&mut rng, n * n);
        for x in a.iter_mut() {
            *x *= 0.1;
        }
        for i in 0..n {
            a[i * n + i] += 2.0; // diagonally dominant-ish
        }
        let xtrue = prop::vec_normal(&mut rng, n);
        let mut b = vec![0.0f32; n];
        dense_apply(&a, n)(&xtrue, &mut b);
        let mut x = vec![0.0f32; n];
        let opts = GmresOptions { m: 5, ..Default::default() };
        let res = gmres(dense_apply(&a, n), &b, &mut x, &opts);
        assert!(res.converged, "residual {:.2e}", res.residual);
        assert!(crate::testing::rel_l2(&x, &xtrue) < 1e-4);
    }

    #[test]
    fn warm_start_counts_fewer_iters() {
        let n = 30;
        let mut rng = Rng::new(5);
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0 + 0.1 * rng.f32();
        }
        let b = prop::vec_normal(&mut rng, n);
        let mut cold = vec![0.0f32; n];
        let rc = gmres(dense_apply(&a, n), &b, &mut cold, &GmresOptions::default());
        let mut warm = cold.clone();
        let rw = gmres(dense_apply(&a, n), &b, &mut warm, &GmresOptions::default());
        assert!(rw.iters <= rc.iters);
        assert!(rw.converged);
    }
}
