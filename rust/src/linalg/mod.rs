//! Matrix-free iterative linear algebra: restarted GMRES and a
//! Jacobian-free Newton–Krylov solver (the paper's PETSc SNES/KSP role).

pub mod gmres;
pub mod newton;

pub use gmres::{gmres, GmresOptions, GmresResult};
pub use newton::{newton_solve, NewtonOptions, NewtonResult};
