//! Jacobian-free Newton–Krylov: Newton's method where each linear solve is
//! matrix-free GMRES over the JVP action (PETSc SNES + matrix-free KSP in
//! the paper's implementation).

use crate::linalg::gmres::{gmres, GmresOptions, GmresResult};
use crate::tensor;

#[derive(Clone, Debug)]
pub struct NewtonOptions {
    pub atol: f64,
    pub rtol: f64,
    pub max_iters: usize,
    pub gmres: GmresOptions,
}

impl Default for NewtonOptions {
    fn default() -> Self {
        // f32 residuals: see GmresOptions::default on tolerance choice
        NewtonOptions {
            atol: 1e-7,
            rtol: 1e-6,
            max_iters: 25,
            gmres: GmresOptions::default(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct NewtonResult {
    pub converged: bool,
    pub iters: usize,
    pub residual_norm: f64,
    /// cumulative GMRES operator applications
    pub linear_iters: usize,
}

/// Solve R(x) = 0 in place.
///
/// * `residual(x, out)` — evaluates R(x).
/// * `jacobian_apply(x, w, out)` — evaluates (∂R/∂x)(x) · w.
pub fn newton_solve<R, J>(
    mut residual: R,
    mut jacobian_apply: J,
    x: &mut [f32],
    opts: &NewtonOptions,
) -> NewtonResult
where
    R: FnMut(&[f32], &mut [f32]),
    J: FnMut(&[f32], &[f32], &mut [f32]),
{
    let n = x.len();
    let mut r = vec![0.0f32; n];
    let mut dx = vec![0.0f32; n];
    let mut neg_r = vec![0.0f32; n];
    let mut linear_iters = 0usize;

    residual(x, &mut r);
    let r0 = tensor::nrm2(&r).max(1e-300);
    let tol = (opts.rtol * r0).max(opts.atol);

    for it in 0..opts.max_iters {
        let rn = tensor::nrm2(&r);
        if rn <= tol {
            return NewtonResult {
                converged: true,
                iters: it,
                residual_norm: rn,
                linear_iters,
            };
        }
        for i in 0..n {
            neg_r[i] = -r[i];
        }
        tensor::zero(&mut dx);
        let x_frozen = x.to_vec();
        let lin: GmresResult = gmres(
            |w, out| jacobian_apply(&x_frozen, w, out),
            &neg_r,
            &mut dx,
            &opts.gmres,
        );
        linear_iters += lin.iters;
        // damped update with simple backtracking if the step increases ||R||
        let mut lambda = 1.0f32;
        let mut accepted = false;
        for _ in 0..6 {
            let mut xt = x_frozen.clone();
            tensor::axpy(lambda, &dx, &mut xt);
            residual(&xt, &mut r);
            if tensor::nrm2(&r) < rn || lambda < 1e-3 {
                x.copy_from_slice(&xt);
                accepted = true;
                break;
            }
            lambda *= 0.5;
        }
        if !accepted {
            // take the tiny step anyway; next iteration re-evaluates
            tensor::axpy(lambda, &dx, x);
            residual(x, &mut r);
        }
    }

    let rn = tensor::nrm2(&r);
    NewtonResult {
        converged: rn <= tol,
        iters: opts.max_iters,
        residual_norm: rn,
        linear_iters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_scalar_nonlinear() {
        // R(x) = x^3 - 8, root x = 2
        let mut x = vec![5.0f32];
        let res = newton_solve(
            |x, out| out[0] = x[0] * x[0] * x[0] - 8.0,
            |x, w, out| out[0] = 3.0 * x[0] * x[0] * w[0],
            &mut x,
            &NewtonOptions::default(),
        );
        assert!(res.converged, "{res:?}");
        assert!((x[0] - 2.0).abs() < 1e-5, "{}", x[0]);
    }

    #[test]
    fn solves_2d_system() {
        // R = [x^2 + y^2 - 4, x - y]  => x = y = sqrt(2)
        let mut x = vec![3.0f32, 1.0];
        let res = newton_solve(
            |v, out| {
                out[0] = v[0] * v[0] + v[1] * v[1] - 4.0;
                out[1] = v[0] - v[1];
            },
            |v, w, out| {
                out[0] = 2.0 * v[0] * w[0] + 2.0 * v[1] * w[1];
                out[1] = w[0] - w[1];
            },
            &mut x,
            &NewtonOptions::default(),
        );
        assert!(res.converged);
        let s = 2.0f32.sqrt();
        assert!((x[0] - s).abs() < 1e-5 && (x[1] - s).abs() < 1e-5, "{x:?}");
    }

    #[test]
    fn quadratic_convergence_iteration_count() {
        // well-scaled problem should converge in <= 8 Newton iterations
        let mut x = vec![0.5f32];
        let res = newton_solve(
            |x, out| out[0] = x[0].exp() - 3.0,
            |x, w, out| out[0] = x[0].exp() * w[0],
            &mut x,
            &NewtonOptions::default(),
        );
        assert!(res.converged);
        assert!(res.iters <= 8, "iters {}", res.iters);
        assert!((x[0] - 3.0f32.ln()).abs() < 1e-5);
    }
}
