//! Classification with a chain of neural-ODE blocks + linear readout —
//! the SqueezeNext-on-CIFAR10 surrogate (paper §5.1; substitution noted in
//! DESIGN.md §2).  `n_blocks` ODE blocks share one architecture but own
//! separate parameter slices (paper: 4 blocks, 199,800 params total; ours:
//! 4 × 50,296 = 201,184 with the `clf_d64` artifact config).
//!
//! Gradient execution goes through the facade: the task holds one
//! [`Session`] per block (each owns its engine and forward state between
//! the forward chain and the reverse λ sweep), all opened from one
//! [`RunSpec`] — the task never names concrete method types.

use crate::api::{RunSpec, Session};
use crate::methods::MethodReport;
use crate::nn::module::{Augment, Module};
use crate::nn::readout::Readout;
use crate::ode::rhs::OdeRhs;
use crate::util::rng::Rng;

pub struct ClassificationTask {
    pub n_blocks: usize,
    /// concatenated per-block parameters
    pub theta: Vec<f32>,
    pub readout: Readout,
    /// per-block facade sessions (each holds its forward state)
    sessions: Vec<Session>,
    /// ANODE lift (Gholami et al., 2019): data rows are zero-padded into
    /// the augmented ODE state before the first block
    lift: Option<Augment>,
    /// ping-pong state buffers for the allocation-free [`Self::infer`]
    /// path (reused across calls; sized on first use)
    infer_u: Vec<f32>,
    infer_v: Vec<f32>,
}

/// Outcome of one training step.
pub struct StepResult {
    pub loss: f64,
    pub accuracy: f64,
    pub grad: Vec<f32>,
    pub report: MethodReport,
}

impl ClassificationTask {
    /// Open one session per block on `spec` (each block needs an
    /// independent engine instance).  Panics on an invalid spec — build
    /// it with [`crate::api::SolverBuilder`], which validates.
    pub fn new(
        rng: &mut Rng,
        n_blocks: usize,
        spec: &RunSpec,
        per_block_params: usize,
        state_dim: usize,
        n_classes: usize,
        init: impl Fn(&mut Rng) -> Vec<f32>,
    ) -> Self {
        assert!(n_blocks > 0, "classification task needs at least one ODE block");
        let mut theta = Vec::with_capacity(n_blocks * per_block_params);
        for _ in 0..n_blocks {
            let t = init(rng);
            assert_eq!(t.len(), per_block_params);
            theta.extend_from_slice(&t);
        }
        let readout = Readout::new(rng, state_dim, n_classes);
        let sessions = (0..n_blocks)
            .map(|_| {
                Session::new(spec.clone())
                    // lint:allow(panic): the task builds its spec from validated presets; a failure is a harness bug surfaced at startup
                    .unwrap_or_else(|e| panic!("classification task: invalid RunSpec: {e}"))
            })
            .collect();
        ClassificationTask {
            n_blocks,
            theta,
            readout,
            sessions,
            lift: None,
            infer_u: Vec::new(),
            infer_v: Vec::new(),
        }
    }

    /// The ANODE variant: ODE blocks run over `data_dim + extra` channels,
    /// data rows are lifted with zero channels before the first block, and
    /// the readout sees the full augmented state.
    #[allow(clippy::too_many_arguments)]
    pub fn augmented(
        rng: &mut Rng,
        n_blocks: usize,
        spec: &RunSpec,
        per_block_params: usize,
        data_dim: usize,
        extra: usize,
        n_classes: usize,
        init: impl Fn(&mut Rng) -> Vec<f32>,
    ) -> Self {
        let mut task = ClassificationTask::new(
            rng,
            n_blocks,
            spec,
            per_block_params,
            data_dim + extra,
            n_classes,
            init,
        );
        task.lift = Some(Augment::new(data_dim, extra));
        task
    }

    /// Zero channels of the ANODE lift (0 for the plain task).
    pub fn augment_extra(&self) -> usize {
        self.lift.as_ref().map(|l| l.extra()).unwrap_or(0)
    }

    /// Lift a data batch into the ODE state (identity unless augmented).
    fn lifted(&self, x: &[f32]) -> Vec<f32> {
        match &self.lift {
            None => x.to_vec(),
            Some(l) => {
                let rows = x.len() / l.in_dim();
                let mut out = vec![0.0f32; rows * l.out_dim()];
                let mut cache: [f32; 0] = [];
                l.forward(rows, 0.0, &[], x, &mut out, &mut cache);
                out
            }
        }
    }

    /// The spec every block runs.
    pub fn spec(&self) -> &RunSpec {
        self.sessions[0].spec()
    }

    pub fn per_block(&self) -> usize {
        self.theta.len() / self.n_blocks
    }

    pub fn block_theta(&self, b: usize) -> &[f32] {
        let p = self.per_block();
        &self.theta[b * p..(b + 1) * p]
    }

    /// Forward through all blocks; returns the final features.
    /// `x` is the *data* batch — the ANODE variant lifts it into the
    /// augmented state first.
    ///
    /// This path feeds [`Self::grad_step`]: each session records its
    /// forward state for the reverse λ sweep.  For inference-only calls
    /// prefer [`Self::infer`], which produces bitwise-identical features
    /// through the allocation-free [`Session::forward_into`] path.
    pub fn forward(&mut self, rhs: &mut dyn OdeRhs, x: &[f32]) -> Vec<f32> {
        let mut u = self.lifted(x);
        for b in 0..self.n_blocks {
            rhs.set_params(self.block_theta(b));
            u = self.sessions[b].forward(rhs, &u);
        }
        u
    }

    /// Inference forward through all blocks via the allocation-free
    /// [`Session::forward_into`] path (no checkpoint writes, workspaces
    /// and ping-pong buffers reused across calls).  Bitwise identical to
    /// [`Self::forward`]; the returned slice lives until the next call.
    pub fn infer(&mut self, rhs: &mut dyn OdeRhs, x: &[f32]) -> &[f32] {
        let n = match &self.lift {
            None => x.len(),
            Some(l) => (x.len() / l.in_dim()) * l.out_dim(),
        };
        self.infer_u.resize(n, 0.0);
        self.infer_v.resize(n, 0.0);
        match &self.lift {
            None => self.infer_u.copy_from_slice(x),
            Some(l) => {
                let rows = x.len() / l.in_dim();
                self.infer_u.fill(0.0);
                let mut cache: [f32; 0] = [];
                l.forward(rows, 0.0, &[], x, &mut self.infer_u, &mut cache);
            }
        }
        for b in 0..self.n_blocks {
            rhs.set_params(self.block_theta(b));
            self.sessions[b].forward_into(&*rhs, &self.infer_u, &mut self.infer_v);
            std::mem::swap(&mut self.infer_u, &mut self.infer_v);
        }
        &self.infer_u
    }

    /// Inference-only loss/accuracy (no tapes, no gradients, no
    /// steady-state allocation).
    pub fn evaluate(
        &mut self,
        rhs: &mut dyn OdeRhs,
        bsz: usize,
        x: &[f32],
        y: &[usize],
    ) -> (f64, f64) {
        self.infer(rhs, x);
        let g = self.readout.loss_and_grads(bsz, &self.infer_u, y);
        (g.loss, g.accuracy)
    }

    /// One full forward + loss + backward; returns gradients wrt all block
    /// parameters (concatenated, same layout as `theta`).  Readout grads
    /// are applied internally with `readout_lr`.
    pub fn grad_step(
        &mut self,
        rhs: &mut dyn OdeRhs,
        bsz: usize,
        x: &[f32],
        y: &[usize],
        readout_lr: f32,
    ) -> StepResult {
        let u_final = self.forward(rhs, x);
        let ro = self.readout.loss_and_grads(bsz, &u_final, y);

        let p = self.per_block();
        let mut grad = vec![0.0f32; self.theta.len()];
        let mut lambda = ro.du.clone();
        let mut report = MethodReport::default();
        for b in (0..self.n_blocks).rev() {
            rhs.set_params(self.block_theta(b));
            self.sessions[b].backward(rhs, &mut lambda, &mut grad[b * p..(b + 1) * p]);
            let r = self.sessions[b].report();
            report.nfe_forward += r.nfe_forward;
            report.nfe_backward += r.nfe_backward;
            report.recompute_steps += r.recompute_steps;
            report.ckpt_bytes += r.ckpt_bytes;
            // graph memory is a high-water mark, not a sum: blocks backprop
            // one at a time
            report.graph_bytes = report.graph_bytes.max(r.graph_bytes);
            report.merge_grid(&r);
            // seed from the first block's stats so blocks_merged counts
            // real blocks, not the default accumulator
            if b + 1 == self.n_blocks {
                report.exec = r.exec;
            } else {
                report.exec.merge(&r.exec);
            }
        }
        self.readout.apply_grads(readout_lr, &ro);
        StepResult { loss: ro.loss, accuracy: ro.accuracy, grad, report }
    }

    /// Apply an optimizer update to the block parameters.
    pub fn apply_grad(&mut self, opt: &mut dyn crate::nn::Optimizer, grad: &[f32]) {
        opt.step(&mut self.theta, grad);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SolverBuilder;
    use crate::data::spiral::SpiralDataset;
    use crate::nn::module::ArchSpec;
    use crate::nn::{Act, Adam, Optimizer};
    use crate::ode::ModuleRhs;

    const D: usize = 8;
    const B: usize = 16;

    fn mk_task(rng: &mut Rng, n_blocks: usize) -> (ClassificationTask, ModuleRhs) {
        let arch = ArchSpec::ConcatMlp { hidden: vec![16], act: Act::Tanh };
        let p = arch.param_count(D);
        let spec = SolverBuilder::new()
            .scheme_str("rk4")
            .uniform(4)
            .arch(arch.clone())
            .build()
            .expect("valid spec");
        let arch_init = arch.clone();
        let task = ClassificationTask::new(rng, n_blocks, &spec, p, D, 3, move |r| {
            arch_init.init(r, D)
        });
        let rhs = spec.make_rhs(D, B, task.block_theta(0).to_vec()).unwrap();
        (task, rhs)
    }

    #[test]
    fn multi_block_training_reduces_loss() {
        let mut rng = Rng::new(201);
        let (mut task, mut rhs) = mk_task(&mut rng, 2);
        let ds = SpiralDataset::generate(&mut rng, 40, 3, D);
        let (train, _) = ds.split(1.0);
        let mut opt = Adam::new(task.theta.len(), 5e-3);
        let mut x = vec![0.0f32; B * D];
        let mut y = vec![0usize; B];

        let mut first = None;
        let mut last = 0.0;
        for it in 0..30 {
            train.fill_batch(it * B, B, &mut x, &mut y);
            let res = task.grad_step(&mut rhs, B, &x, &y, 0.05);
            if first.is_none() {
                first = Some(res.loss);
            }
            last = res.loss;
            let g = res.grad;
            task.apply_grad(&mut opt as &mut dyn Optimizer, &g);
        }
        assert!(
            last < first.unwrap() * 0.9,
            "loss should drop: {first:?} -> {last}"
        );
    }

    #[test]
    fn infer_matches_forward_bitwise_without_reallocation() {
        let mut rng = Rng::new(241);
        let (mut task, mut rhs) = mk_task(&mut rng, 2);
        let mut x = vec![0.0f32; B * D];
        rng.fill_normal(&mut x);
        let y: Vec<usize> = (0..B).map(|_| rng.below(3)).collect();

        let via_forward = task.forward(&mut rhs, &x);
        let (loss_fwd, acc_fwd) = {
            let g = task.readout.loss_and_grads(B, &via_forward, &y);
            (g.loss, g.accuracy)
        };
        for _ in 0..3 {
            let via_infer = task.infer(&mut rhs, &x).to_vec();
            assert_eq!(via_infer, via_forward, "infer must be bitwise = forward");
        }
        let (loss_inf, acc_inf) = task.evaluate(&mut rhs, B, &x, &y);
        assert_eq!(loss_inf, loss_fwd);
        assert_eq!(acc_inf, acc_fwd);
        // one warm-up workspace allocation per block session, then flat
        let allocs: u64 = task.sessions.iter().map(|s| s.forward_allocs()).sum();
        assert_eq!(allocs, task.n_blocks as u64, "steady-state inference allocates nothing");
    }

    #[test]
    fn block_gradients_match_finite_differences() {
        let mut rng = Rng::new(211);
        let (mut task, mut rhs) = mk_task(&mut rng, 2);
        let mut x = vec![0.0f32; B * D];
        rng.fill_normal(&mut x);
        let y: Vec<usize> = (0..B).map(|_| rng.below(3)).collect();

        let res = task.grad_step(&mut rhs, B, &x, &y, 0.0);
        // FD on a few entries of each block's θ (readout frozen: lr=0)
        let h = 1e-2f32;
        let loss_at = |task: &mut ClassificationTask, rhs: &mut ModuleRhs| -> f64 {
            let u = task.forward(rhs, &x);
            task.readout.loss_and_grads(B, &u, &y).loss
        };
        for &idx in &[0usize, 7, task.theta.len() - 1] {
            let orig = task.theta[idx];
            task.theta[idx] = orig + h;
            let lp = loss_at(&mut task, &mut rhs);
            task.theta[idx] = orig - h;
            let lm = loss_at(&mut task, &mut rhs);
            task.theta[idx] = orig;
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - res.grad[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "grad[{idx}] {} vs fd {fd}",
                res.grad[idx]
            );
        }
    }

    #[test]
    fn augmented_task_gradients_match_finite_differences() {
        // ANODE workload: blocks integrate D+EXTRA channels, data is
        // lifted with zeros, readout reads the augmented state
        const EXTRA: usize = 3;
        let mut rng = Rng::new(221);
        let arch = ArchSpec::Augment {
            extra: EXTRA,
            inner: Box::new(ArchSpec::ConcatMlp { hidden: vec![16], act: Act::Tanh }),
        };
        let p = arch.param_count(D);
        let spec = SolverBuilder::new()
            .scheme_str("rk4")
            .uniform(4)
            .arch(arch.clone())
            .build()
            .expect("valid spec");
        let arch_init = arch.clone();
        let mut task = ClassificationTask::augmented(&mut rng, 2, &spec, p, D, EXTRA, 3, move |r| {
            arch_init.init(r, D)
        });
        assert_eq!(task.augment_extra(), EXTRA);
        let mut rhs = spec.make_rhs(D, B, task.block_theta(0).to_vec()).unwrap();
        assert_eq!(rhs.state_dim(), D + EXTRA);

        let mut x = vec![0.0f32; B * D];
        rng.fill_normal(&mut x);
        let y: Vec<usize> = (0..B).map(|_| rng.below(3)).collect();
        let res = task.grad_step(&mut rhs, B, &x, &y, 0.0);
        assert!(res.loss.is_finite());

        let h = 1e-2f32;
        let loss_at = |task: &mut ClassificationTask, rhs: &mut ModuleRhs| -> f64 {
            let u = task.forward(rhs, &x);
            task.readout.loss_and_grads(B, &u, &y).loss
        };
        for &idx in &[0usize, 11, task.theta.len() - 1] {
            let orig = task.theta[idx];
            task.theta[idx] = orig + h;
            let lp = loss_at(&mut task, &mut rhs);
            task.theta[idx] = orig - h;
            let lm = loss_at(&mut task, &mut rhs);
            task.theta[idx] = orig;
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - res.grad[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "grad[{idx}] {} vs fd {fd}",
                res.grad[idx]
            );
        }
    }
}
