//! Learning stiff dynamics (paper §5.3): train a neural ODE on Robertson's
//! chemistry with the Crank–Nicolson discrete adjoint (enabled uniquely by
//! PNODE) and compare against adaptive Dopri5, whose gradients explode
//! (Fig. 5).  Loss = MAE over 40 log-spaced observations (eq. 15), with
//! min–max feature scaling (eq. 16).

use crate::adjoint::driver::ImplicitAdjointRun;
use crate::data::robertson::RobertsonData;
use crate::linalg::gmres::GmresOptions;
use crate::ode::adaptive::{integrate_adaptive, AdaptiveController};
use crate::adjoint::discrete_erk::AdjointErkWorkspace;
use crate::ode::implicit::ThetaScheme;
use crate::ode::rhs::OdeRhs;
use crate::ode::tableau;

pub struct StiffTask {
    pub data: RobertsonData,
    /// internal sub-steps between consecutive observations
    pub substeps: usize,
}

pub struct StiffStep {
    pub loss: f64,
    pub grad: Vec<f32>,
    pub nfe_forward: u64,
    pub nfe_backward: u64,
    /// predictions at the observation times [n_obs, 3]
    pub pred: Vec<f32>,
}

impl StiffTask {
    pub fn new(data: RobertsonData, substeps: usize) -> Self {
        StiffTask { data, substeps }
    }

    /// Full integration grid: obs times densified by `substeps`.
    fn grid(&self) -> (Vec<f64>, Vec<usize>) {
        let mut grid = Vec::new();
        let mut obs_idx = Vec::new(); // grid index of each observation
        grid.push(self.data.ts[0]);
        obs_idx.push(0usize);
        for w in self.data.ts.windows(2) {
            let (a, b) = (w[0], w[1]);
            for s in 1..=self.substeps {
                grid.push(a + (b - a) * s as f64 / self.substeps as f64);
            }
            obs_idx.push(grid.len() - 1);
        }
        (grid, obs_idx)
    }

    /// MAE loss and its per-observation gradients.
    fn mae(&self, preds: &[Vec<f32>]) -> (f64, Vec<Vec<f32>>) {
        let n = preds.len();
        let mut loss = 0.0f64;
        let mut grads = Vec::with_capacity(n);
        let denom = (n * 3) as f64;
        for (i, p) in preds.iter().enumerate() {
            let obs = self.data.obs(i);
            let mut g = vec![0.0f32; 3];
            for c in 0..3 {
                let d = p[c] as f64 - obs[c] as f64;
                loss += d.abs() / denom;
                g[c] = (d.signum() / denom) as f32;
            }
            grads.push(g);
        }
        (loss, grads)
    }

    /// Gradient via the Crank–Nicolson (or BE) discrete adjoint with
    /// observation-time λ jumps.
    pub fn grad_implicit(&self, rhs: &dyn OdeRhs, scheme: ThetaScheme) -> StiffStep {
        rhs.reset_nfe();
        let (grid, obs_idx) = self.grid();
        let mut run = ImplicitAdjointRun::new(scheme, grid);
        run.gmres_opts = GmresOptions { rtol: 1e-8, ..Default::default() };
        let u0 = self.data.u0();
        run.forward(rhs, &u0);
        let nfe_f = rhs.nfe().forward;

        // predictions at observation indices (obs 0 is the initial state)
        let preds: Vec<Vec<f32>> = obs_idx.iter().map(|&gi| run.state(gi).to_vec()).collect();
        let (loss, obs_grads) = self.mae(&preds);
        let mut pred_flat = Vec::with_capacity(preds.len() * 3);
        for p in &preds {
            pred_flat.extend_from_slice(p);
        }

        // backward with λ jumps at each observation
        let mut lambda = vec![0.0f32; 3];
        let mut grad = vec![0.0f32; rhs.param_len()];
        for seg in (0..obs_idx.len() - 1).rev() {
            // jump for the observation at the segment's right edge
            let right_obs = seg + 1;
            for c in 0..3 {
                lambda[c] += obs_grads[right_obs][c];
            }
            run.backward_range(rhs, obs_idx[seg], obs_idx[right_obs], &mut lambda, &mut grad);
        }
        // (gradient wrt u0 is discarded: u0 is data)
        let nfe = rhs.nfe();
        StiffStep {
            loss,
            grad,
            nfe_forward: nfe_f,
            nfe_backward: nfe.backward + (nfe.forward - nfe_f),
            pred: pred_flat,
        }
    }

    /// Gradient via adaptive Dopri5 + discrete adjoint per segment (the
    /// explicit baseline of Fig. 5 / Table 8).
    pub fn grad_explicit_adaptive(&self, rhs: &dyn OdeRhs, tol: f64) -> StiffStep {
        rhs.reset_nfe();
        let tab = &tableau::DOPRI5;
        let ctrl = AdaptiveController::new(tol, tol);
        let u0 = self.data.u0();
        let n_obs = self.data.n_obs();

        // forward per segment, recording all accepted steps (policy All)
        let mut seg_steps: Vec<Vec<(f64, f64, Vec<f32>, Vec<Vec<f32>>)>> = Vec::new();
        let mut preds = vec![u0.clone()];
        let mut u = u0.clone();
        for w in self.data.ts.windows(2) {
            let mut steps = Vec::new();
            let res = integrate_adaptive(
                tab,
                rhs,
                w[0],
                w[1],
                (w[1] - w[0]) / 4.0,
                &ctrl,
                &u,
                |_, t, h, u_n, ks, _| {
                    steps.push((t, h, u_n.to_vec(), ks.to_vec()));
                },
            );
            u = res.final_state.clone();
            preds.push(u.clone());
            seg_steps.push(steps);
        }
        let nfe_f = rhs.nfe().forward;
        let (loss, obs_grads) = self.mae(&preds);
        let mut pred_flat = Vec::with_capacity(preds.len() * 3);
        for p in &preds {
            pred_flat.extend_from_slice(p);
        }

        // discrete adjoint over accepted steps, with λ jumps at observations
        let mut lambda = vec![0.0f32; 3];
        let mut grad = vec![0.0f32; rhs.param_len()];
        let mut aws = AdjointErkWorkspace::new(tab.s, 3);
        for seg in (0..n_obs - 1).rev() {
            for c in 0..3 {
                lambda[c] += obs_grads[seg + 1][c];
            }
            for (t, h, u_n, ks) in seg_steps[seg].iter().rev() {
                crate::adjoint::discrete_erk::adjoint_erk_step(
                    tab, rhs, *t, *h, u_n, ks, &mut lambda, &mut grad, &mut aws,
                );
            }
        }
        let nfe = rhs.nfe();
        StiffStep {
            loss,
            grad,
            nfe_forward: nfe_f,
            nfe_backward: nfe.backward + (nfe.forward - nfe_f),
            pred: pred_flat,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Act;
    use crate::ode::rhs::MlpRhs;
    use crate::util::rng::Rng;

    fn mk_rhs(seed: u64) -> MlpRhs {
        // small net for tests (paper uses 5×50 GELU); init small so the
        // untrained vector field does not blow up over the long [1e-5, 100]
        // horizon (the paper's min–max scaling serves the same purpose)
        let dims = vec![3, 16, 16, 3];
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 0.05);
        MlpRhs::new(dims, Act::Gelu, false, 1, theta)
    }

    fn small_task() -> StiffTask {
        StiffTask::new(RobertsonData::generate(10, 4, true), 2)
    }

    #[test]
    fn implicit_gradient_matches_finite_differences() {
        let mut rhs = mk_rhs(401);
        let task = small_task();
        let step = task.grad_implicit(&rhs, ThetaScheme::crank_nicolson());
        assert!(step.loss.is_finite());

        let h = 1e-3f32;
        let theta0 = rhs.params().to_vec();
        for &idx in &[0usize, 50, theta0.len() - 1] {
            let mut tp = theta0.clone();
            tp[idx] += h;
            rhs.set_params(&tp);
            let lp = task.grad_implicit(&rhs, ThetaScheme::crank_nicolson()).loss;
            let mut tm = theta0.clone();
            tm[idx] -= h;
            rhs.set_params(&tm);
            let lm = task.grad_implicit(&rhs, ThetaScheme::crank_nicolson()).loss;
            rhs.set_params(&theta0);
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - step.grad[idx] as f64).abs() < 3e-2 * (1.0 + fd.abs()),
                "grad[{idx}] {} vs fd {fd}",
                step.grad[idx]
            );
        }
    }

    #[test]
    fn training_with_cn_reduces_mae() {
        let mut rhs = mk_rhs(411);
        let task = small_task();
        let mut opt = crate::nn::AdamW::new(rhs.param_len(), 5e-3, 1e-4);
        use crate::nn::Optimizer;
        let first = task.grad_implicit(&rhs, ThetaScheme::crank_nicolson()).loss;
        let mut theta = rhs.params().to_vec();
        let mut last = first;
        for _ in 0..60 {
            let step = task.grad_implicit(&rhs, ThetaScheme::crank_nicolson());
            last = step.loss;
            opt.step(&mut theta, &step.grad);
            rhs.set_params(&theta);
        }
        assert!(last < first * 0.8, "MAE {first} -> {last}");
    }

    #[test]
    fn explicit_adaptive_path_runs() {
        let rhs = mk_rhs(421);
        let task = small_task();
        let step = task.grad_explicit_adaptive(&rhs, 1e-5);
        assert!(step.loss.is_finite());
        assert!(step.nfe_forward > 0);
        assert_eq!(step.grad.len(), rhs.param_len());
    }
}
