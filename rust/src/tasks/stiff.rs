//! Learning stiff dynamics (paper §5.3): train a neural ODE on Robertson's
//! chemistry with the Crank–Nicolson discrete adjoint (enabled uniquely by
//! PNODE) and compare against adaptive Dopri5, whose gradients explode
//! (Fig. 5).  Loss = MAE over 40 log-spaced observations (eq. 15), with
//! min–max feature scaling (eq. 16).
//!
//! Both gradient paths run through the facade: one [`Session`] per
//! observation segment (implicit path: a θ-scheme over the densified
//! explicit segment grid; explicit path: a per-segment adaptive Dopri5
//! spec).  Forward chains the segments; backward walks them in reverse
//! with the λ jumps added at each observation — the task never names a
//! driver or engine type.

use crate::api::{Session, SolverBuilder};
use crate::checkpoint::CheckpointPolicy;
use crate::data::robertson::RobertsonData;
use crate::ode::grid::TimeGrid;
use crate::ode::rhs::OdeRhs;
use crate::ode::tableau::Scheme;

pub struct StiffTask {
    pub data: RobertsonData,
    /// internal sub-steps between consecutive observations
    pub substeps: usize,
}

pub struct StiffStep {
    pub loss: f64,
    pub grad: Vec<f32>,
    pub nfe_forward: u64,
    pub nfe_backward: u64,
    /// executed (accepted) steps of the forward pass
    pub n_accepted: u64,
    /// rejected adaptive trials (0 for the implicit fixed-grid path)
    pub n_rejected: u64,
    /// predictions at the observation times [n_obs, 3]
    pub pred: Vec<f32>,
}

impl StiffTask {
    pub fn new(data: RobertsonData, substeps: usize) -> Self {
        StiffTask { data, substeps }
    }

    /// MAE loss and its per-observation gradients.
    fn mae(&self, preds: &[Vec<f32>]) -> (f64, Vec<Vec<f32>>) {
        let n = preds.len();
        let mut loss = 0.0f64;
        let mut grads = Vec::with_capacity(n);
        let denom = (n * 3) as f64;
        for (i, p) in preds.iter().enumerate() {
            let obs = self.data.obs(i);
            let mut g = vec![0.0f32; 3];
            for c in 0..3 {
                let d = p[c] as f64 - obs[c] as f64;
                loss += d.abs() / denom;
                g[c] = (d.signum() / denom) as f32;
            }
            grads.push(g);
        }
        (loss, grads)
    }

    /// Run the segment sessions: forward chained over all observation
    /// windows, then backward in reverse with the λ jump for each
    /// observation added at its segment's right edge; the gradient wrt
    /// `u_0` is discarded (u0 is data).
    fn grad_over_segments(
        &self,
        rhs: &dyn OdeRhs,
        mut sessions: Vec<Session>,
    ) -> StiffStep {
        let u0 = self.data.u0();
        let mut preds = vec![u0.clone()];
        let mut u = u0;
        for s in sessions.iter_mut() {
            u = s.forward(rhs, &u);
            preds.push(u.clone());
        }
        let (loss, obs_grads) = self.mae(&preds);
        let mut pred_flat = Vec::with_capacity(preds.len() * 3);
        for p in &preds {
            pred_flat.extend_from_slice(p);
        }

        let mut lambda = vec![0.0f32; 3];
        let mut grad = vec![0.0f32; rhs.param_len()];
        for seg in (0..sessions.len()).rev() {
            let right_obs = seg + 1;
            for c in 0..3 {
                lambda[c] += obs_grads[right_obs][c];
            }
            sessions[seg].backward(rhs, &mut lambda, &mut grad);
        }

        let (mut nfe_f, mut nfe_b) = (0u64, 0u64);
        let (mut n_accepted, mut n_rejected) = (0u64, 0u64);
        for s in &sessions {
            let r = s.report();
            nfe_f += r.nfe_forward;
            nfe_b += r.nfe_backward;
            n_accepted += r.n_accepted;
            n_rejected += r.n_rejected;
        }
        StiffStep {
            loss,
            grad,
            nfe_forward: nfe_f,
            nfe_backward: nfe_b,
            n_accepted,
            n_rejected,
            pred: pred_flat,
        }
    }

    /// Gradient via the implicit θ-scheme discrete adjoint
    /// (`Scheme::CrankNicolson` or `Scheme::BackwardEuler`) with
    /// observation-time λ jumps.
    pub fn grad_implicit(&self, rhs: &dyn OdeRhs, scheme: Scheme) -> StiffStep {
        assert!(
            scheme.is_implicit(),
            "grad_implicit needs an implicit θ-scheme (cn | beuler), got {}",
            scheme.name()
        );
        rhs.reset_nfe();
        let sessions: Vec<Session> = self
            .data
            .ts
            .windows(2)
            .map(|w| {
                // densify the observation window by `substeps`
                let ts: Vec<f64> = (0..=self.substeps)
                    .map(|s| w[0] + (w[1] - w[0]) * s as f64 / self.substeps as f64)
                    .collect();
                SolverBuilder::new()
                    .policy(CheckpointPolicy::SolutionOnly)
                    .scheme(scheme)
                    .span(w[0], w[1])
                    .grid(TimeGrid::from_times(&ts))
                    .session()
                    // lint:allow(panic): segment specs come from validated presets; a failure is a harness bug surfaced at startup
                    .expect("valid stiff segment spec")
            })
            .collect();
        self.grad_over_segments(rhs, sessions)
    }

    /// Gradient via adaptive Dopri5 + checkpointed discrete adjoint per
    /// segment (the explicit baseline of Fig. 5 / Table 8).  Each segment
    /// runs the PI controller, records its accepted grid, and adjoints it
    /// through the same facade as every other PNODE configuration.
    pub fn grad_explicit_adaptive(&self, rhs: &dyn OdeRhs, tol: f64) -> StiffStep {
        rhs.reset_nfe();
        let sessions: Vec<Session> = self
            .data
            .ts
            .windows(2)
            .map(|w| {
                SolverBuilder::new()
                    .policy(CheckpointPolicy::All)
                    .scheme(Scheme::Dopri5)
                    .span(w[0], w[1])
                    .grid(TimeGrid::Adaptive {
                        atol: tol,
                        rtol: tol,
                        h0: Some((w[1] - w[0]) / 4.0),
                    })
                    .session()
                    // lint:allow(panic): segment specs come from validated presets; a failure is a harness bug surfaced at startup
                    .expect("valid stiff segment spec")
            })
            .collect();
        self.grad_over_segments(rhs, sessions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Act;
    use crate::ode::ModuleRhs;
    use crate::util::rng::Rng;

    fn mk_rhs(seed: u64) -> ModuleRhs {
        // small net for tests (paper uses 5×50 GELU); init small so the
        // untrained vector field does not blow up over the long [1e-5, 100]
        // horizon (the paper's min–max scaling serves the same purpose)
        let dims = vec![3, 16, 16, 3];
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 0.05);
        ModuleRhs::mlp(dims, Act::Gelu, false, 1, theta)
    }

    fn small_task() -> StiffTask {
        StiffTask::new(RobertsonData::generate(10, 4, true), 2)
    }

    #[test]
    fn implicit_gradient_matches_finite_differences() {
        let mut rhs = mk_rhs(401);
        let task = small_task();
        let step = task.grad_implicit(&rhs, Scheme::CrankNicolson);
        assert!(step.loss.is_finite());
        assert!(step.n_accepted > 0 && step.n_rejected == 0);

        let h = 1e-3f32;
        let theta0 = rhs.params().to_vec();
        for &idx in &[0usize, 50, theta0.len() - 1] {
            let mut tp = theta0.clone();
            tp[idx] += h;
            rhs.set_params(&tp);
            let lp = task.grad_implicit(&rhs, Scheme::CrankNicolson).loss;
            let mut tm = theta0.clone();
            tm[idx] -= h;
            rhs.set_params(&tm);
            let lm = task.grad_implicit(&rhs, Scheme::CrankNicolson).loss;
            rhs.set_params(&theta0);
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - step.grad[idx] as f64).abs() < 3e-2 * (1.0 + fd.abs()),
                "grad[{idx}] {} vs fd {fd}",
                step.grad[idx]
            );
        }
    }

    #[test]
    fn training_with_cn_reduces_mae() {
        let mut rhs = mk_rhs(411);
        let task = small_task();
        let mut opt = crate::nn::AdamW::new(rhs.param_len(), 5e-3, 1e-4);
        use crate::nn::Optimizer;
        let first = task.grad_implicit(&rhs, Scheme::CrankNicolson).loss;
        let mut theta = rhs.params().to_vec();
        let mut last = first;
        for _ in 0..60 {
            let step = task.grad_implicit(&rhs, Scheme::CrankNicolson);
            last = step.loss;
            opt.step(&mut theta, &step.grad);
            rhs.set_params(&theta);
        }
        assert!(last < first * 0.8, "MAE {first} -> {last}");
    }

    #[test]
    fn explicit_adaptive_path_runs() {
        let rhs = mk_rhs(421);
        let task = small_task();
        let step = task.grad_explicit_adaptive(&rhs, 1e-5);
        assert!(step.loss.is_finite());
        assert!(step.nfe_forward > 0);
        assert!(step.n_accepted > 0, "accepted grid recorded");
        assert_eq!(step.grad.len(), rhs.param_len());
    }

    #[test]
    fn explicit_adaptive_gradient_matches_finite_differences() {
        // reverse accuracy wrt the accepted discrete map survives the λ
        // jumps: FD over the *same task loss* (the grid re-adapts under
        // perturbation, so compare with a tolerance, not bitwise)
        let mut rhs = mk_rhs(431);
        let task = small_task();
        let step = task.grad_explicit_adaptive(&rhs, 1e-6);
        assert!(step.loss.is_finite());

        let h = 1e-3f32;
        let theta0 = rhs.params().to_vec();
        for &idx in &[0usize, theta0.len() / 2] {
            let mut tp = theta0.clone();
            tp[idx] += h;
            rhs.set_params(&tp);
            let lp = task.grad_explicit_adaptive(&rhs, 1e-6).loss;
            let mut tm = theta0.clone();
            tm[idx] -= h;
            rhs.set_params(&tm);
            let lm = task.grad_explicit_adaptive(&rhs, 1e-6).loss;
            rhs.set_params(&theta0);
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - step.grad[idx] as f64).abs() < 5e-2 * (1.0 + fd.abs()),
                "grad[{idx}] {} vs fd {fd}",
                step.grad[idx]
            );
        }
    }

    #[test]
    #[should_panic(expected = "θ-scheme")]
    fn explicit_scheme_is_rejected_by_the_implicit_path() {
        let rhs = mk_rhs(441);
        let task = small_task();
        let _ = task.grad_implicit(&rhs, Scheme::Dopri5);
    }
}
