//! The paper's three experiment families, built on the generic method
//! layer: image-classification surrogate (§5.1, Figs. 2–3), FFJORD
//! continuous normalizing flows (§5.2, Tables 3–7), and stiff Robertson
//! dynamics with implicit integration (§5.3, Figs. 4–5, Table 8).

pub mod classification;
pub mod cnf;
pub mod stiff;

pub use classification::ClassificationTask;
pub use cnf::{CnfTask, HutchinsonCnfRhs, LinearCnfRhs};
pub use stiff::StiffTask;
