//! FFJORD continuous normalizing flows for density estimation (paper
//! §5.2, Tables 3–7).
//!
//! State layout per flow: `[x (B·D) | logp (B)]`.  Dynamics are the
//! Hutchinson-augmented RHS: [`HutchinsonCnfRhs`] drives any
//! time-conditioned module architecture (FFJORD concatsquash stacks are
//! the default — `ArchSpec::ConcatSquashMlp`), with the trace-estimate
//! adjoint computed *exactly* through the module system's directional
//! second-order pass (`Module::sovjp`); [`LinearCnfRhs`] keeps a
//! closed-form oracle, and the `cnf_*` artifacts cover the XLA path.
//! The NLL under a standard-normal base is
//!     L = −mean_b [ log N(z_b(T)) + Δlogp_b(T) ]
//! whose gradient seeds the adjoint: ∂L/∂z = z/B, ∂L/∂Δlogp = −1/B.

use std::cell::RefCell;

use crate::api::{RunSpec, Session};
use crate::methods::MethodReport;
use crate::nn::module::{ArchSpec, Module};
use crate::ode::rhs::{Nfe, NfeCounter, OdeRhs};
use crate::util::rng::Rng;

const LOG_2PI: f64 = 1.8378770664093453;

pub struct CnfTask {
    pub n_flows: usize,
    pub batch: usize,
    pub dim: usize,
    /// concatenated per-flow parameters
    pub theta: Vec<f32>,
    /// per-flow facade sessions (each holds its forward state)
    sessions: Vec<Session>,
}

pub struct CnfStep {
    pub nll: f64,
    pub grad: Vec<f32>,
    pub report: MethodReport,
}

impl CnfTask {
    /// Open one session per flow on `spec`.  Panics on an invalid spec —
    /// build it with [`crate::api::SolverBuilder`], which validates.
    pub fn new(
        rng: &mut Rng,
        n_flows: usize,
        spec: &RunSpec,
        batch: usize,
        dim: usize,
        per_flow_params: usize,
        init: impl Fn(&mut Rng) -> Vec<f32>,
    ) -> Self {
        assert!(n_flows > 0, "cnf task needs at least one flow");
        let mut theta = Vec::with_capacity(n_flows * per_flow_params);
        for _ in 0..n_flows {
            let t = init(rng);
            assert_eq!(t.len(), per_flow_params);
            theta.extend_from_slice(&t);
        }
        let sessions = (0..n_flows)
            .map(|_| {
                Session::new(spec.clone())
                    // lint:allow(panic): the task builds its spec from validated presets; a failure is a harness bug surfaced at startup
                    .unwrap_or_else(|e| panic!("cnf task: invalid RunSpec: {e}"))
            })
            .collect();
        CnfTask { n_flows, batch, dim, theta, sessions }
    }

    /// The spec every flow runs.
    pub fn spec(&self) -> &RunSpec {
        self.sessions[0].spec()
    }

    pub fn per_flow(&self) -> usize {
        self.theta.len() / self.n_flows
    }

    /// NLL of the final augmented state.
    pub fn nll(&self, z: &[f32]) -> f64 {
        let (b, d) = (self.batch, self.dim);
        let (x, logp) = z.split_at(b * d);
        let mut total = 0.0f64;
        for r in 0..b {
            let mut logn = -0.5 * d as f64 * LOG_2PI;
            for c in 0..d {
                let v = x[r * d + c] as f64;
                logn -= 0.5 * v * v;
            }
            total += logn + logp[r] as f64;
        }
        -total / b as f64
    }

    /// ∂NLL/∂z at the final state.
    fn nll_grad(&self, z: &[f32]) -> Vec<f32> {
        let (b, d) = (self.batch, self.dim);
        let mut g = vec![0.0f32; z.len()];
        let inv_b = 1.0 / b as f32;
        for i in 0..b * d {
            g[i] = z[i] * inv_b; // −∂logN/∂x = x
        }
        for r in 0..b {
            g[b * d + r] = -inv_b;
        }
        g
    }

    /// One gradient computation on a batch `x` [B, D].
    pub fn grad_step(&mut self, rhs: &mut dyn OdeRhs, x: &[f32]) -> CnfStep {
        let (b, d) = (self.batch, self.dim);
        let p = self.per_flow();
        // z0 = [x, 0]
        let mut z = vec![0.0f32; b * d + b];
        z[..b * d].copy_from_slice(x);
        for f in 0..self.n_flows {
            rhs.set_params(&self.theta[f * p..(f + 1) * p]);
            z = self.sessions[f].forward(rhs, &z);
        }
        let nll = self.nll(&z);
        let mut lambda = self.nll_grad(&z);
        let mut grad = vec![0.0f32; self.theta.len()];
        let mut report = MethodReport::default();
        for f in (0..self.n_flows).rev() {
            rhs.set_params(&self.theta[f * p..(f + 1) * p]);
            self.sessions[f].backward(rhs, &mut lambda, &mut grad[f * p..(f + 1) * p]);
            let r = self.sessions[f].report();
            report.nfe_forward += r.nfe_forward;
            report.nfe_backward += r.nfe_backward;
            report.recompute_steps += r.recompute_steps;
            report.ckpt_bytes += r.ckpt_bytes;
            report.graph_bytes = report.graph_bytes.max(r.graph_bytes);
            report.merge_grid(&r);
        }
        CnfStep { nll, grad, report }
    }
}

// ---------------------------------------------------------------------------
// HutchinsonCnfRhs: module-driven CNF dynamics with an exact trace adjoint
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, Default)]
struct CnfScratch {
    /// module forward-cache arena
    cache: Vec<f32>,
    /// staging for f(x) when only the cache is wanted
    fx: Vec<f32>,
    /// J·ε tangent image
    jw: Vec<f32>,
    /// second-order cotangent −v_logp ⊗ ε
    u2: Vec<f32>,
    /// second-order input gradient
    gx2: Vec<f32>,
}

/// FFJORD dynamics over a module graph with a fixed Rademacher probe:
///
/// ```text
/// dx/dt    = f(x, θ, t)                       (the module)
/// dlogp/dt = −εᵀ (∂f/∂x) ε                    (Hutchinson estimate)
/// ```
///
/// The adjoint of the trace term needs `∇_{x,θ} ⟨−v_logp ε, J(x) ε⟩` — a
/// directional second-order quantity — which [`Module::sovjp`] provides
/// exactly, so every gradient method stays reverse-accurate on CNF
/// workloads for arbitrary module architectures (concatsquash stacks,
/// residual wrappers, …), not just the closed-form linear oracle.
pub struct HutchinsonCnfRhs {
    pub batch: usize,
    pub dim: usize,
    module: Box<dyn Module>,
    arch: ArchSpec,
    theta: Vec<f32>,
    /// fixed Rademacher probe rows ε_r (one per sample)
    pub eps: Vec<f32>,
    nfe: NfeCounter,
    scratch: RefCell<CnfScratch>,
}

impl HutchinsonCnfRhs {
    /// Build `arch` at `dim` over `batch` rows; `rng` draws the probe.
    /// The arch must not be augmented (CNF states carry their own logp
    /// channel instead).
    pub fn new(arch: &ArchSpec, batch: usize, dim: usize, theta: Vec<f32>, rng: &mut Rng) -> Self {
        assert_eq!(
            arch.augment_extra(),
            0,
            "CNF dynamics take a non-augmented arch (state carries logp already)"
        );
        let module = arch.build(dim);
        assert_eq!(module.in_dim(), dim);
        assert_eq!(module.out_dim(), dim);
        assert_eq!(theta.len(), module.param_len(), "theta mismatch for {}", arch.name());
        let mut eps = vec![0.0f32; batch * dim];
        rng.fill_rademacher(&mut eps);
        HutchinsonCnfRhs {
            batch,
            dim,
            module,
            arch: arch.clone(),
            theta,
            eps,
            nfe: NfeCounter::default(),
            scratch: RefCell::default(),
        }
    }

    /// The architecture driving `dx/dt`.
    pub fn arch(&self) -> &ArchSpec {
        &self.arch
    }

    fn ensure_scratch(&self) {
        let (b, d) = (self.batch, self.dim);
        let mut s = self.scratch.borrow_mut();
        let cl = self.module.cache_len(b);
        if s.cache.len() < cl {
            s.cache.resize(cl, 0.0);
        }
        if s.fx.len() < b * d {
            s.fx.resize(b * d, 0.0);
            s.jw.resize(b * d, 0.0);
            s.u2.resize(b * d, 0.0);
            s.gx2.resize(b * d, 0.0);
        }
    }

    fn vjp_impl(&self, t: f64, z: &[f32], v: &[f32], out: &mut [f32], mut gt: Option<&mut [f32]>) {
        self.nfe.hit_backward();
        self.ensure_scratch();
        let (b, d) = (self.batch, self.dim);
        let x = &z[..b * d];
        let (vx, vlogp) = v.split_at(b * d);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        // first-order part: out_x = Jᵀ v_x (+ gθ), with a forward pass to
        // populate the cache
        self.module.forward(b, t, &self.theta, x, &mut s.fx[..b * d], &mut s.cache);
        self.module.vjp(b, t, &self.theta, vx, &mut out[..b * d], gt.as_deref_mut(), &s.cache);
        // trace part: ∇⟨−v_logp ε, J ε⟩ through the second-order pass
        for r in 0..b {
            for i in 0..d {
                s.u2[r * d + i] = -vlogp[r] * self.eps[r * d + i];
            }
        }
        self.module.sovjp(
            b,
            t,
            &self.theta,
            x,
            &self.eps,
            &s.u2[..b * d],
            &mut s.gx2[..b * d],
            gt,
            &mut s.cache,
        );
        for i in 0..b * d {
            out[i] += s.gx2[i];
        }
        // f is independent of logp
        for r in 0..b {
            out[b * d + r] = 0.0;
        }
    }
}

impl OdeRhs for HutchinsonCnfRhs {
    fn state_len(&self) -> usize {
        self.batch * self.dim + self.batch
    }

    fn param_len(&self) -> usize {
        self.theta.len()
    }

    fn params(&self) -> &[f32] {
        &self.theta
    }

    fn set_params(&mut self, theta: &[f32]) {
        assert_eq!(theta.len(), self.theta.len());
        self.theta.copy_from_slice(theta);
    }

    fn f(&self, t: f64, z: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        self.ensure_scratch();
        let (b, d) = (self.batch, self.dim);
        let x = &z[..b * d];
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        self.module.forward(b, t, &self.theta, x, &mut out[..b * d], &mut s.cache);
        // dlogp_r = −ε_rᵀ (J ε)_r via one tangent pass
        self.module.jvp(b, t, &self.theta, &self.eps, &mut s.jw[..b * d], &s.cache);
        for r in 0..b {
            let mut tr = 0.0f32;
            for i in 0..d {
                tr += self.eps[r * d + i] * s.jw[r * d + i];
            }
            out[b * d + r] = -tr;
        }
    }

    fn vjp_u(&self, t: f64, z: &[f32], v: &[f32], out: &mut [f32]) {
        self.vjp_impl(t, z, v, out, None);
    }

    fn vjp_both(&self, t: f64, z: &[f32], v: &[f32], out_u: &mut [f32], grad_theta: &mut [f32]) {
        self.vjp_impl(t, z, v, out_u, Some(grad_theta));
    }

    fn jvp(&self, _t: f64, _u: &[f32], _w: &[f32], _out: &mut [f32]) {
        unimplemented!("CNF uses explicit schemes only")
    }

    fn nfe(&self) -> Nfe {
        self.nfe.get()
    }

    fn reset_nfe(&self) {
        self.nfe.reset();
    }

    fn activation_bytes_per_eval(&self) -> u64 {
        // per-module accounting of the x-dynamics (the logp channel adds
        // one tangent image, counted with the widest module boundary)
        self.module.activation_bytes(self.batch)
            + (self.batch * self.dim * 4) as u64
    }
}

// ---------------------------------------------------------------------------
// LinearCnfRhs: analytic CNF dynamics for XLA-free tests
// ---------------------------------------------------------------------------

/// dx/dt = A x with Hutchinson trace estimate −εᵀAε (exact derivatives).
/// θ = vec(A).  Gradients of the augmented system are closed-form, making
/// the full CNF pipeline testable without artifacts.
pub struct LinearCnfRhs {
    pub batch: usize,
    pub dim: usize,
    a: Vec<f32>,
    pub eps: Vec<f32>,
    nfe: NfeCounter,
}

impl LinearCnfRhs {
    pub fn new(batch: usize, dim: usize, a: Vec<f32>, rng: &mut Rng) -> Self {
        assert_eq!(a.len(), dim * dim);
        let mut eps = vec![0.0f32; batch * dim];
        rng.fill_rademacher(&mut eps);
        LinearCnfRhs { batch, dim, a, eps, nfe: NfeCounter::default() }
    }
}

impl OdeRhs for LinearCnfRhs {
    fn state_len(&self) -> usize {
        self.batch * self.dim + self.batch
    }

    fn param_len(&self) -> usize {
        self.dim * self.dim
    }

    fn params(&self) -> &[f32] {
        &self.a
    }

    fn set_params(&mut self, theta: &[f32]) {
        self.a.copy_from_slice(theta);
    }

    fn f(&self, _t: f64, z: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        let (b, d) = (self.batch, self.dim);
        let (x, _) = z.split_at(b * d);
        for r in 0..b {
            for i in 0..d {
                let mut acc = 0.0f32;
                for j in 0..d {
                    acc += self.a[i * d + j] * x[r * d + j];
                }
                out[r * d + i] = acc;
            }
            // dlogp = -ε_rᵀ A ε_r
            let e = &self.eps[r * d..(r + 1) * d];
            let mut tr = 0.0f32;
            for i in 0..d {
                for j in 0..d {
                    tr += e[i] * self.a[i * d + j] * e[j];
                }
            }
            out[b * d + r] = -tr;
        }
    }

    fn vjp_u(&self, _t: f64, _z: &[f32], v: &[f32], out: &mut [f32]) {
        self.nfe.hit_backward();
        let (b, d) = (self.batch, self.dim);
        let (vx, _vlogp) = v.split_at(b * d);
        // gx = Aᵀ vx ; dlogp independent of x and of logp
        for r in 0..b {
            for j in 0..d {
                let mut acc = 0.0f32;
                for i in 0..d {
                    acc += self.a[i * d + j] * vx[r * d + i];
                }
                out[r * d + j] = acc;
            }
            out[b * d + r] = 0.0;
        }
    }

    fn vjp_both(&self, t: f64, z: &[f32], v: &[f32], out_u: &mut [f32], grad_theta: &mut [f32]) {
        self.vjp_u(t, z, v, out_u);
        let (b, d) = (self.batch, self.dim);
        let (x, _) = z.split_at(b * d);
        let (vx, vlogp) = v.split_at(b * d);
        // dL/dA_ij += Σ_r vx[r,i] x[r,j] − vlogp[r] ε_i ε_j
        for r in 0..b {
            let e = &self.eps[r * d..(r + 1) * d];
            for i in 0..d {
                for j in 0..d {
                    grad_theta[i * d + j] +=
                        vx[r * d + i] * x[r * d + j] - vlogp[r] * e[i] * e[j];
                }
            }
        }
    }

    fn jvp(&self, _t: f64, _u: &[f32], _w: &[f32], _out: &mut [f32]) {
        unimplemented!("CNF uses explicit schemes only")
    }

    fn nfe(&self) -> Nfe {
        self.nfe.get()
    }

    fn reset_nfe(&self) {
        self.nfe.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SolverBuilder;

    const B: usize = 8;
    const D: usize = 3;

    fn mk() -> (CnfTask, LinearCnfRhs, Vec<f32>) {
        let mut rng = Rng::new(301);
        // contraction toward 0 => flow maps data toward the base density
        let a = vec![
            -0.5, 0.1, 0.0, //
            0.0, -0.4, 0.05, //
            0.1, 0.0, -0.6,
        ];
        let spec = SolverBuilder::new()
            .scheme_str("rk4")
            .uniform(8)
            .build()
            .expect("valid spec");
        let task = CnfTask::new(&mut rng, 1, &spec, B, D, D * D, |_r| a.clone());
        let rhs = LinearCnfRhs::new(B, D, a.clone(), &mut rng);
        let mut x = vec![0.0f32; B * D];
        rng.fill_normal(&mut x);
        for v in x.iter_mut() {
            *v *= 2.0; // over-dispersed data
        }
        (task, rhs, x)
    }

    #[test]
    fn hutchinson_trace_is_exact_in_expectation_for_rademacher() {
        // for fixed eps, εᵀAε deviates from tr(A); over the diagonal it's exact
        let mut rng = Rng::new(303);
        let a = vec![1.0f32, 0.0, 0.0, 2.0];
        let rhs = LinearCnfRhs::new(4, 2, a, &mut rng);
        let z = vec![0.0f32; 4 * 2 + 4];
        let mut out = vec![0.0f32; 12];
        rhs.f(0.0, &z, &mut out);
        // diagonal A: εᵀAε = Σ a_ii ε_i² = tr(A) exactly for Rademacher ε
        for r in 0..4 {
            assert!((out[8 + r] + 3.0).abs() < 1e-5, "{}", out[8 + r]);
        }
    }

    #[test]
    fn nll_gradient_matches_finite_differences() {
        let (mut task, mut rhs, x) = mk();
        let res = task.grad_step(&mut rhs, &x);
        assert!(res.nll.is_finite());

        let h = 1e-3f32;
        let mut probe = crate::api::Session::new(task.spec().clone()).unwrap();
        for &idx in &[0usize, 4, 8] {
            let orig = task.theta[idx];
            task.theta[idx] = orig + h;
            let mut z = vec![0.0f32; B * D + B];
            z[..B * D].copy_from_slice(&x);
            rhs.set_params(&task.theta);
            let mut zf = vec![0.0f32; B * D + B];
            probe.forward_into(&rhs, &z, &mut zf);
            let lp = task.nll(&zf);
            task.theta[idx] = orig - h;
            rhs.set_params(&task.theta);
            let mut zf = vec![0.0f32; B * D + B];
            probe.forward_into(&rhs, &z, &mut zf);
            let lm = task.nll(&zf);
            task.theta[idx] = orig;
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - res.grad[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "grad[{idx}] {} vs fd {fd}",
                res.grad[idx]
            );
        }
    }

    fn mk_squash() -> (CnfTask, HutchinsonCnfRhs, Vec<f32>) {
        let mut rng = Rng::new(311);
        let arch = ArchSpec::ConcatSquashMlp { hidden: vec![8], act: crate::nn::Act::Tanh };
        let p = arch.param_count(D);
        let spec = SolverBuilder::new()
            .scheme_str("rk4")
            .uniform(6)
            .arch(arch.clone())
            .build()
            .expect("valid spec");
        let arch_init = arch.clone();
        let task = CnfTask::new(&mut rng, 1, &spec, B, D, p, move |r| arch_init.init(r, D));
        let rhs = HutchinsonCnfRhs::new(&arch, B, D, task.theta.clone(), &mut rng);
        let mut x = vec![0.0f32; B * D];
        rng.fill_normal(&mut x);
        for v in x.iter_mut() {
            *v *= 2.0;
        }
        (task, rhs, x)
    }

    #[test]
    fn concatsquash_nll_gradient_matches_finite_differences() {
        // the exact-trace-adjoint path (Module::sovjp) under the full
        // discrete adjoint: FD of the frozen forward map must agree
        let (mut task, mut rhs, x) = mk_squash();
        let res = task.grad_step(&mut rhs, &x);
        assert!(res.nll.is_finite());

        let h = 1e-3f32;
        let mut probe = crate::api::Session::new(task.spec().clone()).unwrap();
        let p = task.theta.len();
        // probe W, b, the gate hypernet, and the shift hypernet regions
        for &idx in &[0usize, 7, p / 2, p - 1] {
            let orig = task.theta[idx];
            task.theta[idx] = orig + h;
            let mut z = vec![0.0f32; B * D + B];
            z[..B * D].copy_from_slice(&x);
            rhs.set_params(&task.theta);
            let mut zf = vec![0.0f32; B * D + B];
            probe.forward_into(&rhs, &z, &mut zf);
            let lp = task.nll(&zf);
            task.theta[idx] = orig - h;
            rhs.set_params(&task.theta);
            let mut zf = vec![0.0f32; B * D + B];
            probe.forward_into(&rhs, &z, &mut zf);
            let lm = task.nll(&zf);
            task.theta[idx] = orig;
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - res.grad[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "grad[{idx}] {} vs fd {fd}",
                res.grad[idx]
            );
        }
    }

    #[test]
    fn training_concatsquash_cnf_reduces_nll() {
        let (mut task, mut rhs, x) = mk_squash();
        let mut opt = crate::nn::Adam::new(task.theta.len(), 2e-2);
        use crate::nn::Optimizer;
        let first = task.grad_step(&mut rhs, &x).nll;
        let mut last = first;
        for _ in 0..40 {
            let res = task.grad_step(&mut rhs, &x);
            last = res.nll;
            opt.step(&mut task.theta, &res.grad);
        }
        assert!(last < first - 0.02, "NLL {first} -> {last}");
    }

    #[test]
    fn training_linear_cnf_reduces_nll() {
        let (mut task, mut rhs, x) = mk();
        let mut opt = crate::nn::Adam::new(task.theta.len(), 2e-2);
        use crate::nn::Optimizer;
        let first = task.grad_step(&mut rhs, &x).nll;
        let mut last = first;
        for _ in 0..40 {
            let res = task.grad_step(&mut rhs, &x);
            last = res.nll;
            opt.step(&mut task.theta, &res.grad);
        }
        assert!(last < first - 0.05, "NLL {first} -> {last}");
    }
}
