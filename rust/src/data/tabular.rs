//! Gaussian-mixture tabular datasets — surrogates of POWER (d=6),
//! MINIBOONE (d=43), BSDS300 (d=63) for the CNF experiments (Tables 3–7).
//! The CNF columns the paper reports (NFE, time/iter, memory) depend on
//! dimensionality, batch size, and N_t, not on the particular density, so a
//! seeded mixture of anisotropic Gaussians preserves the benchmark while
//! keeping the repo self-contained (DESIGN.md §2).

use crate::util::rng::Rng;

pub struct TabularDataset {
    pub dim: usize,
    pub n: usize,
    /// [n, dim] row-major, standardized to zero mean / unit variance
    pub x: Vec<f32>,
}

/// Named presets mirroring the paper's datasets.
pub fn preset(name: &str) -> Option<(usize, usize)> {
    // (dim, default sample count)
    Some(match name {
        "power" => (6, 8192),
        "miniboone" => (43, 4096),
        "bsds300" => (63, 4096),
        _ => return None,
    })
}

impl TabularDataset {
    /// `k`-component mixture with random means/scales and correlations.
    pub fn generate(rng: &mut Rng, dim: usize, n: usize, k: usize) -> Self {
        // component parameters
        let mut means = vec![0.0f32; k * dim];
        rng.fill_uniform(&mut means, -3.0, 3.0);
        let mut scales = vec![0.0f32; k * dim];
        rng.fill_uniform(&mut scales, 0.2, 1.2);
        // shared random rotation (correlates features)
        let mut rot = vec![0.0f32; dim * dim];
        rng.fill_normal(&mut rot);
        for v in rot.iter_mut() {
            *v /= (dim as f32).sqrt();
        }

        let mut x = vec![0.0f32; n * dim];
        let mut z = vec![0.0f32; dim];
        for row in 0..n {
            let c = rng.below(k);
            for d in 0..dim {
                z[d] = means[c * dim + d] + scales[c * dim + d] * rng.normal() as f32;
            }
            // x_row = rot @ z (mixing)
            for i in 0..dim {
                let mut acc = 0.0f32;
                for j in 0..dim {
                    acc += rot[i * dim + j] * z[j];
                }
                x[row * dim + i] = acc;
            }
        }
        // standardize per feature
        for d in 0..dim {
            let mut mean = 0.0f64;
            for row in 0..n {
                mean += x[row * dim + d] as f64;
            }
            mean /= n as f64;
            let mut var = 0.0f64;
            for row in 0..n {
                var += (x[row * dim + d] as f64 - mean).powi(2);
            }
            let std = (var / n as f64).sqrt().max(1e-8);
            for row in 0..n {
                x[row * dim + d] = ((x[row * dim + d] as f64 - mean) / std) as f32;
            }
        }
        TabularDataset { dim, n, x }
    }

    pub fn from_preset(rng: &mut Rng, name: &str) -> Option<Self> {
        let (dim, n) = preset(name)?;
        Some(Self::generate(rng, dim, n, 8))
    }

    /// Fill a batch (wrapping) starting at `offset`.
    pub fn fill_batch(&self, offset: usize, bsz: usize, out: &mut [f32]) {
        for b in 0..bsz {
            let idx = (offset + b) % self.n;
            out[b * self.dim..(b + 1) * self.dim]
                .copy_from_slice(&self.x[idx * self.dim..(idx + 1) * self.dim]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_dimensions() {
        assert_eq!(preset("power").unwrap().0, 6);
        assert_eq!(preset("miniboone").unwrap().0, 43);
        assert_eq!(preset("bsds300").unwrap().0, 63);
        assert!(preset("mnist").is_none());
    }

    #[test]
    fn standardized_moments() {
        let mut rng = Rng::new(9);
        let ds = TabularDataset::generate(&mut rng, 5, 4000, 4);
        for d in 0..5 {
            let mut mean = 0.0f64;
            let mut var = 0.0f64;
            for row in 0..ds.n {
                mean += ds.x[row * 5 + d] as f64;
            }
            mean /= ds.n as f64;
            for row in 0..ds.n {
                var += (ds.x[row * 5 + d] as f64 - mean).powi(2);
            }
            var /= ds.n as f64;
            assert!(mean.abs() < 1e-5, "feature {d} mean {mean}");
            assert!((var - 1.0).abs() < 1e-4, "feature {d} var {var}");
        }
    }

    #[test]
    fn mixture_is_multimodal() {
        // crude: histogram of the first feature should not look unimodal —
        // check that variance of per-quartile means is substantial
        let mut rng = Rng::new(10);
        let ds = TabularDataset::generate(&mut rng, 3, 3000, 6);
        let mut f0: Vec<f32> = (0..ds.n).map(|r| ds.x[r * 3]).collect();
        f0.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = ds.n / 4;
        let spread = f0[3 * q] - f0[q];
        assert!(spread > 0.5, "spread {spread}");
    }

    #[test]
    fn batches_wrap() {
        let mut rng = Rng::new(11);
        let ds = TabularDataset::generate(&mut rng, 4, 10, 2);
        let mut out = vec![0.0f32; 12 * 4];
        ds.fill_batch(5, 12, &mut out);
        // row 5 of the batch == dataset row 0 == batch row... offset 5 + 5 = 10 % 10 = 0
        assert_eq!(&out[5 * 4..6 * 4], &ds.x[0..4]);
    }
}
