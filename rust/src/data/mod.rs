//! Synthetic datasets standing in for the paper's benchmarks
//! (substitution table in DESIGN.md §2): spiral classification for
//! CIFAR-10/SqueezeNext, seeded Gaussian mixtures for the POWER /
//! MINIBOONE / BSDS300 tabular CNF datasets, and the true Robertson
//! chemistry for the stiff-dynamics task.

pub mod robertson;
pub mod spiral;
pub mod tabular;

pub use robertson::RobertsonData;
pub use spiral::SpiralDataset;
pub use tabular::TabularDataset;

/// Min–max feature scaling to [0, 1] (paper eq. 16).  Returns (min, max)
/// per feature for later inverse mapping.
pub fn min_max_scale(data: &mut [f32], n_features: usize) -> (Vec<f32>, Vec<f32>) {
    let rows = data.len() / n_features;
    let mut mins = vec![f32::INFINITY; n_features];
    let mut maxs = vec![f32::NEG_INFINITY; n_features];
    for r in 0..rows {
        for c in 0..n_features {
            let v = data[r * n_features + c];
            mins[c] = mins[c].min(v);
            maxs[c] = maxs[c].max(v);
        }
    }
    for r in 0..rows {
        for c in 0..n_features {
            let span = (maxs[c] - mins[c]).max(1e-12);
            data[r * n_features + c] = (data[r * n_features + c] - mins[c]) / span;
        }
    }
    (mins, maxs)
}

#[cfg(test)]
mod tests {
    #[test]
    fn min_max_scales_to_unit_interval() {
        let mut d = vec![1.0f32, 10.0, 3.0, 20.0, 2.0, 15.0];
        let (mins, maxs) = super::min_max_scale(&mut d, 2);
        assert_eq!(mins, vec![1.0, 10.0]);
        assert_eq!(maxs, vec![3.0, 20.0]);
        for &x in &d {
            assert!((0.0..=1.0).contains(&x));
        }
        assert_eq!(d[0], 0.0);
        assert_eq!(d[2], 1.0);
    }
}
