//! Ground-truth data for the stiff task (§5.3): solve Robertson's
//! equations with a tightly-converged implicit integrator on a dense
//! internal grid, then sample 40 points log-spaced over [1e-5, 100]
//! (paper's setup), optionally min–max scaled (paper eq. 16).

use crate::ode::implicit::{integrate_implicit_grid, ThetaScheme};
use crate::ode::rhs::RobertsonRhs;

pub struct RobertsonData {
    /// observation times (log-spaced)
    pub ts: Vec<f64>,
    /// [n_obs, 3] concentrations at the observation times
    pub u: Vec<f32>,
    /// per-species (min, max) used for scaling (None if unscaled)
    pub scale: Option<(Vec<f32>, Vec<f32>)>,
}

/// `n` log-spaced points in [a, b].
pub fn logspace(a: f64, b: f64, n: usize) -> Vec<f64> {
    let (la, lb) = (a.ln(), b.ln());
    (0..n)
        .map(|i| (la + (lb - la) * i as f64 / (n - 1) as f64).exp())
        .collect()
}

impl RobertsonData {
    /// Generate the paper's dataset: u0 = [1,0,0], 40 log-spaced samples
    /// over [1e-5, 100].  `substeps` dense implicit sub-steps between
    /// consecutive observations control the reference accuracy.
    ///
    /// The reference integrator is backward Euler (L-stable — Robertson's
    /// extreme stiffness makes Crank–Nicolson's marginal A-stability
    /// oscillate on coarse grids) over a geometrically refined sub-grid.
    pub fn generate(n_obs: usize, substeps: usize, scaled: bool) -> Self {
        let ts = logspace(1e-5, 100.0, n_obs);
        // dense grid: start at t=0, densify between observations
        let mut grid = vec![0.0f64];
        let mut prev = 0.0f64;
        for &t in &ts {
            for s in 1..=substeps {
                grid.push(prev + (t - prev) * s as f64 / substeps as f64);
            }
            prev = t;
        }
        let rhs = RobertsonRhs::default();
        let mut u = Vec::with_capacity(n_obs * 3);
        let mut next_obs = 0usize;
        // integrate and capture at observation times
        let grid_ref = &grid;
        let ts_ref = &ts;
        integrate_implicit_grid(
            ThetaScheme::backward_euler(),
            &rhs,
            grid_ref,
            &[1.0, 0.0, 0.0],
            |step, _t, _h, _u_prev, u_next| {
                let t_next = grid_ref[step + 1];
                while next_obs < ts_ref.len()
                    && (t_next - ts_ref[next_obs]).abs() < 1e-12 * ts_ref[next_obs].max(1.0)
                {
                    u.extend_from_slice(u_next);
                    next_obs += 1;
                }
            },
        );
        assert_eq!(u.len(), n_obs * 3, "missed observation times");

        let mut data = RobertsonData { ts, u, scale: None };
        if scaled {
            data.apply_min_max();
        }
        data
    }

    /// Min–max scale each species to [0, 1] (paper §5.3.1).
    pub fn apply_min_max(&mut self) {
        let (mins, maxs) = crate::data::min_max_scale(&mut self.u, 3);
        self.scale = Some((mins, maxs));
    }

    pub fn n_obs(&self) -> usize {
        self.ts.len()
    }

    pub fn obs(&self, i: usize) -> &[f32] {
        &self.u[i * 3..(i + 1) * 3]
    }

    /// Initial condition in the (possibly scaled) data space.
    pub fn u0(&self) -> Vec<f32> {
        // the trajectory starts from the first observation
        self.obs(0).to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logspace_endpoints_and_monotone() {
        let ts = logspace(1e-5, 100.0, 40);
        assert_eq!(ts.len(), 40);
        assert!((ts[0] - 1e-5).abs() < 1e-12);
        assert!((ts[39] - 100.0).abs() < 1e-9);
        for w in ts.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn robertson_physics_sanity() {
        let data = RobertsonData::generate(40, 12, false);
        assert_eq!(data.n_obs(), 40);
        // u1 decays from 1, u3 grows from 0, mass conserved
        let first = data.obs(0);
        let last = data.obs(39);
        assert!(first[0] > 0.99, "{first:?}");
        assert!(last[0] < first[0]);
        assert!(last[2] > 0.1);
        for i in 0..40 {
            let o = data.obs(i);
            let mass = o[0] as f64 + o[1] as f64 + o[2] as f64;
            assert!((mass - 1.0).abs() < 1e-3, "obs {i}: mass {mass}");
            // u2 stays tiny (the fast species): the famous 5-orders gap
            assert!(o[1] < 1e-3);
        }
    }

    #[test]
    fn scaling_normalizes_species() {
        let data = RobertsonData::generate(40, 8, true);
        assert!(data.scale.is_some());
        let mut max2 = 0.0f32;
        for i in 0..data.n_obs() {
            max2 = max2.max(data.obs(i)[1]);
        }
        // after min-max, even the tiny species spans up to 1
        assert!((max2 - 1.0).abs() < 1e-6, "max of species 2 = {max2}");
    }
}
