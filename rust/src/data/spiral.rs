//! Interleaved-spirals classification data, lifted to D dimensions with a
//! fixed random projection — the CIFAR-10 surrogate (DESIGN.md §2): it
//! exercises exactly what Fig. 2/3 measure (gradient fidelity and
//! memory/time scaling of the ODE-block classifier), with a decision
//! boundary hard enough that gradient errors visibly hurt accuracy.

use crate::util::rng::Rng;

pub struct SpiralDataset {
    pub n_classes: usize,
    pub dim: usize,
    /// [n, dim] row-major features
    pub x: Vec<f32>,
    pub y: Vec<usize>,
}

impl SpiralDataset {
    /// `n_per_class` points per class, lifted from 2-D spirals to `dim`
    /// with a random orthogonal-ish projection + small noise.
    pub fn generate(rng: &mut Rng, n_per_class: usize, n_classes: usize, dim: usize) -> Self {
        assert!(dim >= 2);
        // random projection 2 -> dim (fixed by the rng seed)
        let mut proj = vec![0.0f32; 2 * dim];
        rng.fill_normal(&mut proj);
        for v in proj.iter_mut() {
            *v /= (dim as f32).sqrt();
        }

        let n = n_per_class * n_classes;
        let mut x = vec![0.0f32; n * dim];
        let mut y = vec![0usize; n];
        for c in 0..n_classes {
            for i in 0..n_per_class {
                let idx = c * n_per_class + i;
                let t = i as f32 / n_per_class as f32; // 0..1 along the arm
                let r = 0.2 + 2.0 * t;
                let phi = 2.0 * std::f32::consts::PI
                    * (c as f32 / n_classes as f32 + 0.75 * t)
                    + rng.normal_f32(0.0, 0.03);
                let (px, py) = (r * phi.cos(), r * phi.sin());
                for d in 0..dim {
                    x[idx * dim + d] = px * proj[d] + py * proj[dim + d]
                        + rng.normal_f32(0.0, 0.01);
                }
                y[idx] = c;
            }
        }
        // shuffle jointly
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut xs = vec![0.0f32; n * dim];
        let mut ys = vec![0usize; n];
        for (new, &old) in order.iter().enumerate() {
            xs[new * dim..(new + 1) * dim].copy_from_slice(&x[old * dim..(old + 1) * dim]);
            ys[new] = y[old];
        }
        SpiralDataset { n_classes, dim, x: xs, y: ys }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Split into (train, test) at `frac`.
    pub fn split(&self, frac: f64) -> (SpiralView<'_>, SpiralView<'_>) {
        let cut = (self.len() as f64 * frac) as usize;
        (
            SpiralView { data: self, start: 0, end: cut },
            SpiralView { data: self, start: cut, end: self.len() },
        )
    }
}

/// Borrowed contiguous slice of the dataset.
pub struct SpiralView<'a> {
    data: &'a SpiralDataset,
    start: usize,
    end: usize,
}

impl<'a> SpiralView<'a> {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fill a fixed-size batch (wrapping around) starting at `offset`.
    pub fn fill_batch(&self, offset: usize, bsz: usize, x: &mut [f32], y: &mut [usize]) {
        let dim = self.data.dim;
        for b in 0..bsz {
            let idx = self.start + (offset + b) % self.len();
            x[b * dim..(b + 1) * dim]
                .copy_from_slice(&self.data.x[idx * dim..(idx + 1) * dim]);
            y[b] = self.data.y[idx];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_balanced_shuffled_classes() {
        let mut rng = Rng::new(5);
        let ds = SpiralDataset::generate(&mut rng, 50, 4, 8);
        assert_eq!(ds.len(), 200);
        let mut counts = [0usize; 4];
        for &c in &ds.y {
            counts[c] += 1;
        }
        assert_eq!(counts, [50; 4]);
        // shuffled: the first 50 labels are not all class 0
        assert!(ds.y[..50].iter().any(|&c| c != ds.y[0]));
    }

    #[test]
    fn features_are_bounded_and_nontrivial() {
        let mut rng = Rng::new(6);
        let ds = SpiralDataset::generate(&mut rng, 30, 2, 16);
        let norm: f64 = ds.x.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        assert!(norm > 1.0);
        assert!(ds.x.iter().all(|v| v.abs() < 10.0));
    }

    #[test]
    fn batch_filling_wraps() {
        let mut rng = Rng::new(7);
        let ds = SpiralDataset::generate(&mut rng, 10, 2, 4);
        let (train, test) = ds.split(0.8);
        assert_eq!(train.len(), 16);
        assert_eq!(test.len(), 4);
        let mut x = vec![0.0f32; 8 * 4];
        let mut y = vec![0usize; 8];
        test.fill_batch(0, 8, &mut x, &mut y); // 8 > 4: wraps
        assert_eq!(&y[0..4], &y[4..8]);
    }
}
