//! Deterministic pseudo-random numbers: xoshiro256++ seeded via SplitMix64.
//!
//! The offline registry has no `rand` crate; this is the standard
//! xoshiro256++ generator (Blackman & Vigna) plus the distributions the
//! framework needs (uniform, normal via Box–Muller, Rademacher) and a
//! Fisher–Yates shuffle.  Everything is reproducible from a `u64` seed; the
//! same seeds drive dataset synthesis, parameter init, and Hutchinson
//! samples, so experiments are exactly repeatable.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller output
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for parallel workers / subsystems).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for practical purposes (n << 2^64).
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean/std as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// +1 / -1 with equal probability (Hutchinson probes).
    #[inline]
    pub fn rademacher(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for x in out {
            *x = self.normal() as f32;
        }
    }

    /// Fill a slice with Rademacher +/-1.
    pub fn fill_rademacher(&mut self, out: &mut [f32]) {
        for x in out {
            *x = self.rademacher();
        }
    }

    /// Fill with uniform values in [lo, hi).
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for x in out {
            *x = self.uniform(lo as f64, hi as f64) as f32;
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
    }

    #[test]
    fn rademacher_is_pm_one_and_balanced() {
        let mut r = Rng::new(3);
        let mut pos = 0;
        for _ in 0..10_000 {
            let x = r.rademacher();
            assert!(x == 1.0 || x == -1.0);
            if x > 0.0 {
                pos += 1;
            }
        }
        assert!((pos as f64 / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn forked_streams_diverge() {
        let mut base = Rng::new(1);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
