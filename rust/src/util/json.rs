//! Minimal JSON reader/writer (the offline registry has no serde).
//!
//! Supports the full JSON grammar minus exotic escapes (\u surrogate pairs
//! are decoded), preserves object key order, and offers typed accessors
//! tailored to what the manifest/config/results files need.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the missing key name (manifest loading).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<usize> (shape lists).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // ---------------- builders ----------------

    pub fn obj(kv: Vec<(&str, Json)>) -> Json {
        Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    pub fn from_map(m: &BTreeMap<String, f64>) -> Json {
        Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
    }

    // ---------------- serialisation ----------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    item.write(out, indent, false); // arrays stay compact
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !kv.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------- parsing ----------------

pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // high surrogate: expect \uXXXX low surrogate
                                self.pos += 1;
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                self.pos -= 1; // hex4 advances from pos+1
                                let lo = self.hex4()?;
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let rest = &self.b[self.pos..];
                    let step = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..step.min(rest.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos += step;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let start = self.pos + 1;
        if start + 4 > self.b.len() {
            return Err(self.err("bad \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[start..start + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [1.5, true, null, "x\ny"], "c": {"d": -2e3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2000.0));
        // serialize -> parse -> equal
        let back = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back);
        let back2 = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, back2);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version":1,"configs":{"quick_d8":{"dims":[9,16,8],
            "param_count":296,"artifacts":{"f":"quick_d8.f.hlo.txt"},
            "arg_shapes":{"f":[[4,8],[296],[1]]}}}}"#;
        let v = parse(src).unwrap();
        let cfg = v.get("configs").unwrap().get("quick_d8").unwrap();
        assert_eq!(cfg.get("dims").unwrap().as_usize_vec(), Some(vec![9, 16, 8]));
        let shapes = cfg.get("arg_shapes").unwrap().get("f").unwrap();
        assert_eq!(shapes.as_arr().unwrap()[0].as_usize_vec(), Some(vec![4, 8]));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\"b\\cA\n""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\cA\n"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""héllo λ θ""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo λ θ"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(vec![]));
    }
}
