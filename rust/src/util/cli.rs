//! Tiny CLI argument parser (no clap offline).
//!
//! Grammar: `prog [subcommand] [--key value | --flag] [positional...]`.
//! Values never start with `--`; `--flag` followed by another option or end
//! of argv is a boolean flag.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        // first bare token is the subcommand
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                    continue;
                }
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        // lint:allow(panic): peek() just returned Some, so next() yields that element
                        out.options.insert(name.to_string(), it.next().unwrap());
                    }
                    _ => out.flags.push(name.to_string()),
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            // lint:allow(panic): CLI argument errors abort with a pointed message by design
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            // lint:allow(panic): CLI argument errors abort with a pointed message by design
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            // lint:allow(panic): CLI argument errors abort with a pointed message by design
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {s:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --nt 10 --scheme dopri5 file.json --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_usize("nt", 0), 10);
        assert_eq!(a.get("scheme"), Some("dopri5"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["file.json"]);
    }

    #[test]
    fn key_equals_value() {
        let a = parse("bench --table=3 --out=/tmp/x");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get("table"), Some("3"));
        assert_eq!(a.get("out"), Some("/tmp/x"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_f64("lr", 0.005), 0.005);
        assert_eq!(a.get_u64("seed", 42), 42);
    }
}
