//! Small self-contained substrates replacing crates unavailable in the
//! offline registry (DESIGN.md §2): JSON, RNG, CLI parsing, statistics.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;

/// Human-readable byte size (GiB/MiB/KiB).
pub fn human_bytes(bytes: u64) -> String {
    const G: f64 = (1u64 << 30) as f64;
    const M: f64 = (1u64 << 20) as f64;
    const K: f64 = (1u64 << 10) as f64;
    let b = bytes as f64;
    if b >= G {
        format!("{:.3} GiB", b / G)
    } else if b >= M {
        format!("{:.2} MiB", b / M)
    } else if b >= K {
        format!("{:.1} KiB", b / K)
    } else {
        format!("{bytes} B")
    }
}

/// Human-readable duration.
pub fn human_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 << 20), "3.00 MiB");
        assert_eq!(human_bytes(5 << 30), "5.000 GiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(human_secs(2.5), "2.500 s");
        assert_eq!(human_secs(0.002), "2.000 ms");
        assert_eq!(human_secs(0.000002), "2.0 us");
    }
}
