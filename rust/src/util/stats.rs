//! Streaming statistics and summaries for benchmarks and training metrics.

/// Welford online mean/variance plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Stream {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stream {
    pub fn new() -> Self {
        Stream { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile (nearest-rank on a copy; fine for bench-sized samples).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    // lint:allow(panic): callers pass finite samples (seconds, byte counts); the comparison never sees NaN
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Stream::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic dataset is 32/7
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }
}
