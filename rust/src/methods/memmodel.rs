//! Table-2 memory model: translate each method's structural memory
//! (AD-graph depth × activation size + checkpoint storage) into the bytes a
//! V100-class accelerator would hold, so the benches can print "GPU Mem
//! (GB)" columns comparable to the paper's (DESIGN.md §2, §9).

/// Constant allocator overhead the paper attributes to the CUDA runtime
/// (§5.1: "the CUDA runtime allocates ∼0.4 GB").
pub const CUDA_RUNTIME_BYTES: u64 = 429_496_730; // 0.4 GiB

/// Problem-size inputs of the model.
#[derive(Clone, Copy, Debug)]
pub struct MemModel {
    /// bytes of intermediate activations of one f evaluation (batch incl.)
    pub act_bytes: u64,
    /// bytes of one state vector (B × D × 4)
    pub state_bytes: u64,
    /// parameter + optimizer-state bytes (θ, grads, Adam moments)
    pub param_bytes: u64,
    /// number of stages of the scheme
    pub n_stages: u64,
    /// time steps per block
    pub nt: u64,
    /// number of ODE blocks
    pub nb: u64,
}

impl MemModel {
    /// Problem-size inputs measured off a live RHS: `act_bytes` is the
    /// *summed per-module* accounting
    /// ([`crate::ode::rhs::OdeRhs::activation_bytes_per_eval`], which a
    /// module graph reports as the sum of its children's scratch plans),
    /// state/param bytes follow from the RHS dimensions.  This is how the
    /// Table-2/Fig-3 benches and `pnode bench table2` size the model now —
    /// no hand-maintained closed forms per architecture.
    pub fn for_rhs(rhs: &dyn crate::ode::rhs::OdeRhs, n_stages: u64, nt: u64, nb: u64) -> MemModel {
        MemModel {
            act_bytes: rhs.activation_bytes_per_eval(),
            state_bytes: (rhs.state_len() * 4) as u64,
            param_bytes: (rhs.param_len() * 4) as u64,
            n_stages,
            nt,
            nb,
        }
    }

    /// Fixed cost every method pays: runtime + params/optimizer + one batch.
    fn base(&self) -> u64 {
        CUDA_RUNTIME_BYTES + 4 * self.param_bytes + 2 * self.state_bytes
    }

    /// NODE-naive: graph over all blocks/steps/stages; no checkpoints.
    pub fn node_naive(&self) -> u64 {
        self.base() + self.nb * self.nt * self.n_stages * self.act_bytes
    }

    /// NODE-cont: one f-eval graph; no storage (reconstructs backward).
    pub fn node_cont(&self) -> u64 {
        self.base() + self.act_bytes
    }

    /// ANODE: block-input checkpoints + one block's full tape at a time.
    pub fn anode(&self) -> u64 {
        self.base() + self.nb * self.state_bytes + self.nt * self.n_stages * self.act_bytes
    }

    /// ACA: per-step solution checkpoints + a one-step local graph.
    pub fn aca(&self) -> u64 {
        self.base() + self.nb * self.nt * self.state_bytes + self.n_stages * self.act_bytes
    }

    /// PNODE (checkpoint all): (N_t−1)(N_s+1) vectors + one f-eval graph.
    pub fn pnode(&self) -> u64 {
        self.base()
            + self.nb * (self.nt.saturating_sub(1)) * (self.n_stages + 1) * self.state_bytes
            + self.act_bytes
    }

    /// PNODE2 (solutions only): N_t−1 vectors + one f-eval graph.
    pub fn pnode2(&self) -> u64 {
        self.base() + self.nb * (self.nt.saturating_sub(1)) * self.state_bytes + self.act_bytes
    }

    /// PNODE with a binomial budget of `nc` checkpoints per block.
    pub fn pnode_binomial(&self, nc: u64) -> u64 {
        self.base()
            + self.nb * nc.min(self.nt.saturating_sub(1)) * (self.n_stages + 1) * self.state_bytes
            + self.act_bytes
    }

    /// The checkpoint-storage term of the prediction alone — the part of
    /// Table 2 this process actually allocates (no CUDA constant, no
    /// AD-graph activations) — so observed runs can validate the model
    /// against live peak checkpoint bytes (DESIGN.md §11).  Tiered
    /// policies predict their inner placement: the tier split changes
    /// *where* checkpoints live, never how many bytes exist.
    pub fn ckpt_bytes_for(&self, method: &crate::api::MethodSpec) -> u64 {
        use crate::api::MethodSpec as M;
        use crate::checkpoint::CheckpointPolicy as P;
        fn policy_bytes(m: &MemModel, p: &P) -> u64 {
            let slots = m.nt.saturating_sub(1);
            match p {
                P::All => m.nb * slots * (m.n_stages + 1) * m.state_bytes,
                P::SolutionOnly => m.nb * slots * m.state_bytes,
                P::Binomial { n_checkpoints } => {
                    m.nb * (*n_checkpoints as u64).min(slots) * (m.n_stages + 1) * m.state_bytes
                }
                P::Tiered { inner, .. } => policy_bytes(m, inner),
                // unresolved auto: bounded by its own budget and by the
                // checkpoint-everything placement it may pick (callers
                // that want the exact figure resolve the policy first)
                P::Auto { budget_bytes } => {
                    (*budget_bytes).min(m.nb * slots * (m.n_stages + 1) * m.state_bytes)
                }
            }
        }
        match method {
            M::Pnode { policy } => policy_bytes(self, policy),
            M::Anode => self.nb * self.state_bytes,
            M::Aca => self.nb * self.nt * self.state_bytes,
            M::NodeNaive | M::NodeCont => 0,
        }
    }

    pub fn by_method(&self, name: &str) -> Option<u64> {
        Some(match name {
            "naive" | "node_naive" => self.node_naive(),
            "cont" | "node_cont" => self.node_cont(),
            "anode" => self.anode(),
            "aca" => self.aca(),
            "pnode" => self.pnode(),
            "pnode2" => self.pnode2(),
            _ => return None,
        })
    }

    pub fn gb(bytes: u64) -> f64 {
        bytes as f64 / (1u64 << 30) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> MemModel {
        MemModel {
            act_bytes: 50 << 20, // 50 MiB per eval
            state_bytes: 2 << 20,
            param_bytes: 800 << 10,
            n_stages: 6,
            nt: 11,
            nb: 4,
        }
    }

    #[test]
    fn ordering_matches_paper_figure3() {
        let m = model();
        // naive largest; pnode smallest among reverse-accurate; cont smallest
        assert!(m.node_naive() > m.anode());
        assert!(m.anode() > m.pnode());
        assert!(m.pnode() > m.pnode2());
        assert!(m.node_cont() < m.pnode2());
        assert!(m.aca() < m.anode());
    }

    #[test]
    fn pnode_memory_grows_slowest_with_nt() {
        let grow = |f: &dyn Fn(&MemModel) -> u64| {
            let mut m = model();
            m.nt = 2;
            let lo = f(&m);
            m.nt = 32;
            let hi = f(&m);
            (hi - lo) as f64
        };
        let naive_growth = grow(&|m| m.node_naive());
        let anode_growth = grow(&|m| m.anode());
        let pnode_growth = grow(&|m| m.pnode());
        assert!(pnode_growth < anode_growth);
        assert!(anode_growth < naive_growth);
        // cont is flat in N_t
        assert_eq!(grow(&|m| m.node_cont()), 0.0);
    }

    #[test]
    fn binomial_interpolates() {
        let m = model();
        let full = m.pnode();
        let tight = m.pnode_binomial(2);
        assert!(tight < full);
        assert!(tight > m.node_cont());
        assert_eq!(m.pnode_binomial(1000), full, "budget caps at N_t-1");
    }

    #[test]
    fn ckpt_term_is_the_model_minus_base_and_graph() {
        use crate::api::MethodSpec;
        let m = model();
        let base_graph = |total: u64, graph: u64| total - graph;
        let pnode = MethodSpec::parse("pnode").unwrap();
        assert_eq!(
            m.ckpt_bytes_for(&pnode) + m.act_bytes,
            base_graph(m.pnode(), m.base()),
            "pnode: storage term + one f-eval graph"
        );
        let pnode2 = MethodSpec::parse("pnode2").unwrap();
        assert_eq!(
            m.ckpt_bytes_for(&pnode2) + m.act_bytes,
            base_graph(m.pnode2(), m.base())
        );
        let bino = MethodSpec::parse("pnode:binomial:2").unwrap();
        assert_eq!(
            m.ckpt_bytes_for(&bino) + m.act_bytes,
            base_graph(m.pnode_binomial(2), m.base())
        );
        assert_eq!(m.ckpt_bytes_for(&MethodSpec::Aca), m.nb * m.nt * m.state_bytes);
        assert_eq!(m.ckpt_bytes_for(&MethodSpec::NodeCont), 0);
        // tiered predicts its inner placement
        let tiered = MethodSpec::parse("pnode:tiered:1m:/tmp/x").unwrap();
        assert_eq!(m.ckpt_bytes_for(&tiered), m.ckpt_bytes_for(&pnode));
    }

    #[test]
    fn by_method_covers_table() {
        let m = model();
        for name in crate::api::METHOD_NAMES {
            assert!(m.by_method(name).is_some(), "{name}");
        }
    }

    #[test]
    fn per_module_accounting_reproduces_the_mlp_closed_form() {
        // Table-2 regression: the summed per-module activation bytes of a
        // module-graph RHS must equal the legacy Mlp closed form
        // Σ_l B·(d_l + d_{l+1})·4 on the same dims, so memory numbers
        // derived from `for_rhs` don't drift from the historical tables.
        use crate::nn::Act;
        use crate::ode::rhs::OdeRhs;
        use crate::ode::ModuleRhs;
        for (dims, time_dep) in [
            (vec![9usize, 16, 8], true),
            (vec![65, 168, 168, 64], true),
            (vec![3, 50, 50, 3], false),
        ] {
            for bsz in [1usize, 4, 128] {
                let theta = vec![0.0f32; crate::nn::param_count(&dims)];
                let rhs = ModuleRhs::mlp(dims.clone(), Act::Relu, time_dep, bsz, theta);
                let closed: u64 = dims
                    .windows(2)
                    .map(|w| (bsz * (w[0] + w[1]) * 4) as u64)
                    .sum();
                assert_eq!(
                    rhs.activation_bytes_per_eval(),
                    closed,
                    "{dims:?} at B={bsz}"
                );
                let mm = MemModel::for_rhs(&rhs, 6, 10, 4);
                assert_eq!(mm.act_bytes, closed);
                assert_eq!(mm.state_bytes, (rhs.state_len() * 4) as u64);
                assert_eq!(mm.param_bytes, (rhs.param_len() * 4) as u64);
            }
        }
    }
}
