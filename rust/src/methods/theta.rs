//! `ImplicitAdjoint` — the implicit θ-scheme face of the PNODE discrete
//! adjoint, behind the same [`GradientMethod`] interface as the explicit
//! methods so the facade registry can serve `RunSpec`s with
//! `Scheme::BackwardEuler` / `Scheme::CrankNicolson` uniformly.
//!
//! Forward steps are Newton–GMRES solves; the adjoint solves the
//! transposed linearized step operator per step (solution-recording —
//! there are no stages to store), all through the unified
//! [`crate::adjoint::driver::AdjointDriver`].  Grids must be static
//! (uniform or explicit): θ-methods carry no embedded error estimate,
//! which [`crate::api::RunSpec::validate`] enforces at build time.

use crate::adjoint::driver::{AdjointDriver, ThetaDriver};
use crate::adjoint::scheme::ThetaStep;
use crate::checkpoint::CheckpointPolicy;
use crate::linalg::gmres::GmresOptions;
use crate::methods::{BlockSpec, GradientMethod, MethodReport};
use crate::ode::implicit::ThetaScheme;
use crate::ode::rhs::OdeRhs;
use crate::ode::tableau::Scheme;

pub struct ImplicitAdjoint {
    pub policy: CheckpointPolicy,
    /// rtol of the transposed adjoint GMRES solves (tight by default: the
    /// stiff task's λ jumps compound per-step solve error)
    pub gmres_rtol: f64,
    run: Option<ThetaDriver>,
    report: MethodReport,
}

impl ImplicitAdjoint {
    pub fn new(policy: CheckpointPolicy) -> Self {
        ImplicitAdjoint { policy, gmres_rtol: 1e-8, run: None, report: MethodReport::default() }
    }
}

fn theta_of(scheme: Scheme) -> ThetaScheme {
    match scheme {
        Scheme::BackwardEuler => ThetaScheme::backward_euler(),
        Scheme::CrankNicolson => ThetaScheme::crank_nicolson(),
        // lint:allow(panic): constructor-time configuration check: pairing an explicit scheme with the implicit driver is a caller bug
        s => panic!("ImplicitAdjoint drives θ-schemes; {} is explicit (use Pnode)", s.name()),
    }
}

impl GradientMethod for ImplicitAdjoint {
    fn name(&self) -> &'static str {
        "pnode-implicit"
    }

    fn reverse_accurate(&self) -> bool {
        true
    }

    fn forward(&mut self, rhs: &dyn OdeRhs, spec: &BlockSpec, u0: &[f32]) -> Vec<f32> {
        rhs.reset_nfe();
        let mut run = AdjointDriver::new(
            ThetaStep::new(theta_of(spec.scheme)),
            self.policy.clone(),
            spec.t0,
            spec.tf,
            spec.grid.clone(),
        );
        run.scheme.gmres_opts = GmresOptions { rtol: self.gmres_rtol, ..Default::default() };
        let uf = run.forward(rhs, u0);
        self.report = MethodReport {
            nfe_forward: rhs.nfe().forward,
            ..MethodReport::default()
        };
        self.report.note_grid(run.grid_steps(), run.n_rejected());
        self.run = Some(run);
        uf
    }

    fn backward(
        &mut self,
        rhs: &dyn OdeRhs,
        _spec: &BlockSpec,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
    ) {
        // lint:allow(panic): the GradientMethod contract runs forward before backward
        let run = self.run.as_mut().expect("forward before backward");
        rhs.reset_nfe();
        run.backward(rhs, lambda, grad_theta);
        let nfe = rhs.nfe();
        // NFE-B: transposed products + any re-run Newton solves
        self.report.nfe_backward = nfe.backward + nfe.forward;
        self.report.recompute_steps = run.recompute_steps;
        self.report.ckpt_bytes = run.peak_checkpoint_bytes();
        self.report.tier = run.tier_stats();
        self.report.graph_bytes = rhs.activation_bytes_per_eval();
    }

    fn report(&self) -> MethodReport {
        self.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Act;
    use crate::ode::grid::TimeGrid;
    use crate::ode::ModuleRhs;
    use crate::util::rng::Rng;

    fn mk_rhs(seed: u64) -> ModuleRhs {
        let dims = vec![3, 10, 3];
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 0.8);
        ModuleRhs::mlp(dims, Act::Gelu, false, 1, theta)
    }

    #[test]
    fn matches_theta_driver_bitwise() {
        // the method face is plumbing, not math: same driver, same bits
        let rhs = mk_rhs(501);
        let u0 = vec![0.4f32, -0.1, 0.3];
        let w = vec![1.0f32, 0.5, -0.3];
        let ts: Vec<f64> = (0..=6).map(|i| i as f64 / 6.0).collect();

        let mut direct = ThetaDriver::theta(
            ThetaScheme::crank_nicolson(),
            CheckpointPolicy::SolutionOnly,
            &ts,
        );
        direct.scheme.gmres_opts = GmresOptions { rtol: 1e-8, ..Default::default() };
        direct.forward(&rhs, &u0);
        let mut l_ref = w.clone();
        let mut g_ref = vec![0.0f32; rhs.param_len()];
        direct.backward(&rhs, &mut l_ref, &mut g_ref);

        let spec = BlockSpec {
            scheme: Scheme::CrankNicolson,
            t0: 0.0,
            tf: 1.0,
            grid: TimeGrid::from_times(&ts),
        };
        let mut m = ImplicitAdjoint::new(CheckpointPolicy::SolutionOnly);
        let uf = m.forward(&rhs, &spec, &u0);
        let mut l = w.clone();
        let mut g = vec![0.0f32; rhs.param_len()];
        m.backward(&rhs, &spec, &mut l, &mut g);

        assert_eq!(uf, direct.final_state().to_vec());
        assert_eq!(l, l_ref, "λ bitwise vs the bare driver");
        assert_eq!(g, g_ref, "θ̄ bitwise vs the bare driver");
        let r = m.report();
        assert!(r.nfe_forward > 0 && r.nfe_backward > 0);
        assert_eq!(r.n_accepted, 6);
        assert_eq!(r.recompute_steps, 0, "SolutionOnly θ sweep re-runs nothing");
    }

    #[test]
    fn uniform_grid_matches_explicit_times() {
        let rhs = mk_rhs(511);
        let u0 = vec![0.2f32, 0.1, -0.3];
        let w = vec![1.0f32, 1.0, 1.0];
        // power-of-two step count: the uniform and windowed-difference
        // grids are then the same floats, so the runs are the same bits
        let nt = 4usize;
        let ts: Vec<f64> = (0..=nt).map(|i| i as f64 / nt as f64).collect();

        let grad = |grid: TimeGrid| {
            let spec =
                BlockSpec { scheme: Scheme::BackwardEuler, t0: 0.0, tf: 1.0, grid };
            let mut m = ImplicitAdjoint::new(CheckpointPolicy::SolutionOnly);
            m.forward(&rhs, &spec, &u0);
            let mut l = w.clone();
            let mut g = vec![0.0f32; rhs.param_len()];
            m.backward(&rhs, &spec, &mut l, &mut g);
            (l, g)
        };
        let (l_u, g_u) = grad(TimeGrid::Uniform { nt });
        let (l_e, g_e) = grad(TimeGrid::from_times(&ts));
        assert_eq!(l_u, l_e, "uniform and equivalent explicit grids are the same map");
        assert_eq!(g_u, g_e);
    }
}
