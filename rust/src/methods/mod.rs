//! The five neural-ODE gradient methods the paper compares (Table 2):
//! PNODE (ours, discrete adjoint + checkpoint policies), NODE-cont
//! (continuous adjoint), NODE-naive (full tape), ANODE (block
//! checkpointing), and ACA (adaptive checkpoint adjoint).  All expose the
//! same [`GradientMethod`] interface so tasks and benches are generic.

pub mod baselines;
pub mod memmodel;
pub mod parallel;
pub mod pnode;

pub use baselines::{Aca, Anode, NodeCont, NodeNaive};
pub use memmodel::MemModel;
pub use parallel::ParallelAdjoint;
pub use pnode::Pnode;

use crate::checkpoint::{CheckpointPolicy, TierStats};
use crate::exec::{ExecConfig, ExecStats};
use crate::ode::grid::TimeGrid;
use crate::ode::rhs::OdeRhs;
use crate::ode::tableau::Scheme;

/// Integration window of one ODE block: scheme + `[t0, tf]` + the time
/// grid (uniform, explicit nonuniform, or adaptive — see [`TimeGrid`]).
#[derive(Clone, Debug)]
pub struct BlockSpec {
    pub scheme: Scheme,
    pub t0: f64,
    pub tf: f64,
    pub grid: TimeGrid,
}

impl BlockSpec {
    /// Uniform grid with `nt` steps over `[0, 1]`.
    pub fn new(scheme: Scheme, nt: usize) -> Self {
        BlockSpec { scheme, t0: 0.0, tf: 1.0, grid: TimeGrid::Uniform { nt } }
    }

    /// Adaptive grid with `atol = rtol = tol` over `[0, 1]`.
    pub fn adaptive(scheme: Scheme, tol: f64) -> Self {
        BlockSpec { scheme, t0: 0.0, tf: 1.0, grid: TimeGrid::adaptive(tol) }
    }

    /// Planned step count.  Panics for adaptive grids (the count is only
    /// known once a forward pass has run — see `MethodReport::n_accepted`).
    pub fn nt(&self) -> usize {
        self.grid
            .planned_nt()
            .expect("adaptive grids have no planned step count; read MethodReport::n_accepted")
    }
}

/// Resource accounting for one forward+backward gradient computation.
#[derive(Clone, Copy, Debug, Default)]
pub struct MethodReport {
    /// function evaluations in the forward pass
    pub nfe_forward: u64,
    /// function evaluations in the backward pass (recomputes + transposed
    /// products, per each method's own accounting — matches the paper's
    /// NFE-B column semantics)
    pub nfe_backward: u64,
    /// re-executed forward steps (PNODE checkpointing overhead)
    pub recompute_steps: u64,
    /// measured peak checkpoint bytes resident in RAM
    pub ckpt_bytes: u64,
    /// modeled AD-graph residency (tape emulation, Table-2 semantics)
    pub graph_bytes: u64,
    /// executed (accepted) steps of the forward pass
    pub n_accepted: u64,
    /// rejected adaptive trials (0 for static grids); these cost forward
    /// NFE but contribute zero backward NFE and zero checkpoint bytes
    pub n_rejected: u64,
    /// smallest executed step size
    pub h_min: f64,
    /// largest executed step size
    pub h_max: f64,
    /// storage-tier counters (hot/cold bytes, spills, prefetch hits);
    /// zeros beyond the hot fields for purely in-memory checkpointing
    pub tier: TierStats,
    /// data-parallel execution counters (workers, shards, throughput,
    /// arbiter lease contention); zeros for single-threaded methods
    pub exec: ExecStats,
}

impl MethodReport {
    pub fn total_model_bytes(&self) -> u64 {
        self.ckpt_bytes + self.graph_bytes
    }

    /// Record the executed grid (accepted steps + rejected trial count).
    pub fn note_grid(&mut self, steps: &[(f64, f64)], n_rejected: usize) {
        self.n_accepted = steps.len() as u64;
        self.n_rejected = n_rejected as u64;
        self.h_min = if steps.is_empty() {
            0.0
        } else {
            steps.iter().map(|s| s.1).fold(f64::INFINITY, f64::min)
        };
        self.h_max = steps.iter().map(|s| s.1).fold(0.0, f64::max);
    }

    /// Fold another block's grid stats into this aggregate (multi-block
    /// tasks): step counts accumulate, step-size extremes widen.  `h_min
    /// == 0.0` is the "no steps recorded" sentinel on both sides.
    pub fn merge_grid(&mut self, other: &MethodReport) {
        self.n_accepted += other.n_accepted;
        self.n_rejected += other.n_rejected;
        self.h_max = self.h_max.max(other.h_max);
        self.h_min = if self.h_min == 0.0 {
            other.h_min
        } else if other.h_min == 0.0 {
            self.h_min
        } else {
            self.h_min.min(other.h_min)
        };
    }
}

/// A gradient engine for one ODE block.
///
/// `Send` so engines (with their checkpoint state between `forward` and
/// `backward`) can move across the execution engine's worker threads.
pub trait GradientMethod: Send {
    fn name(&self) -> &'static str;

    /// Whether gradients are exact to machine precision wrt the discrete map.
    fn reverse_accurate(&self) -> bool;

    /// Integrate forward; must be called before `backward`.
    fn forward(&mut self, rhs: &dyn OdeRhs, spec: &BlockSpec, u0: &[f32]) -> Vec<f32>;

    /// Propagate `lambda` (∂L/∂u_F → ∂L/∂u_0), accumulate `grad_theta`.
    fn backward(&mut self, rhs: &dyn OdeRhs, spec: &BlockSpec, lambda: &mut [f32], grad_theta: &mut [f32]);

    /// Accounting of the latest forward+backward (call after backward).
    fn report(&self) -> MethodReport;
}

/// Construct a method by name (CLI / bench matrix).
pub fn method_by_name(name: &str) -> Option<Box<dyn GradientMethod>> {
    Some(match name {
        "pnode" => Box::new(Pnode::new(CheckpointPolicy::All)),
        "pnode2" => Box::new(Pnode::new(CheckpointPolicy::SolutionOnly)),
        "node_cont" | "cont" => Box::new(NodeCont::new()),
        "node_naive" | "naive" => Box::new(NodeNaive::new()),
        "anode" => Box::new(Anode::new()),
        "aca" => Box::new(Aca::new()),
        _ => {
            if let Some(rest) = name.strip_prefix("pnode:") {
                let policy = CheckpointPolicy::parse(rest).ok()?;
                return Some(Box::new(Pnode::new(policy)));
            }
            return None;
        }
    })
}

/// The PNODE checkpoint policy a method name denotes, if any (`pnode`,
/// `pnode2`, `pnode:<policy>`).
pub fn pnode_policy_of_name(name: &str) -> Option<CheckpointPolicy> {
    match name {
        "pnode" => Some(CheckpointPolicy::All),
        "pnode2" => Some(CheckpointPolicy::SolutionOnly),
        _ => CheckpointPolicy::parse(name.strip_prefix("pnode:")?).ok(),
    }
}

/// Data-parallel wrapper over [`method_by_name`]: the named method runs
/// one instance per batch shard on the `cfg` worker pool (falling back to
/// a single instance for non-shardable RHSs).  `pnode:tiered:*` specs get
/// their budget lifted into a shared [`crate::exec::BudgetArbiter`], so
/// the whole shard fleet draws from ONE global hot-tier pool.
pub fn parallel_method_by_name(name: &str, cfg: ExecConfig) -> Option<Box<dyn GradientMethod>> {
    if let Some(policy) = pnode_policy_of_name(name) {
        return Some(Box::new(ParallelAdjoint::pnode(policy, cfg)));
    }
    method_by_name(name)?; // validate before capturing the name
    let name = name.to_string();
    Some(Box::new(ParallelAdjoint::new(
        Box::new(move || method_by_name(&name).expect("name validated above")),
        cfg,
    )))
}

/// All method names in the paper's table order.
pub static METHOD_NAMES: &[&str] = &["naive", "cont", "anode", "aca", "pnode", "pnode2"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_factory_knows_all_names() {
        for name in METHOD_NAMES {
            assert!(method_by_name(name).is_some(), "{name}");
        }
        assert!(method_by_name("pnode:binomial:4").is_some());
        assert!(method_by_name("pnode:tiered:8m:/tmp/pnode-spill").is_some());
        assert!(method_by_name("pnode:tiered:8m:/tmp/pnode-spill:binomial:4").is_some());
        assert!(method_by_name("pnode:binomial:0").is_none(), "degenerate policy rejected");
        assert!(method_by_name("nope").is_none());
    }

    #[test]
    fn parallel_factory_wraps_every_name() {
        let cfg = ExecConfig { workers: 2, shard_rows: 4 };
        for name in METHOD_NAMES {
            assert!(parallel_method_by_name(name, cfg).is_some(), "{name}");
        }
        assert!(parallel_method_by_name("pnode:binomial:4", cfg).is_some());
        assert!(parallel_method_by_name("nope", cfg).is_none());
        assert_eq!(pnode_policy_of_name("pnode"), Some(CheckpointPolicy::All));
        assert_eq!(pnode_policy_of_name("pnode2"), Some(CheckpointPolicy::SolutionOnly));
        assert_eq!(
            pnode_policy_of_name("pnode:binomial:3"),
            Some(CheckpointPolicy::Binomial { n_checkpoints: 3 })
        );
        assert_eq!(pnode_policy_of_name("cont"), None);
        assert_eq!(pnode_policy_of_name("pnode:bogus"), None);
    }
}
