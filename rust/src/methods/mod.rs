//! The five neural-ODE gradient methods the paper compares (Table 2):
//! PNODE (ours, discrete adjoint + checkpoint policies; explicit RK via
//! [`Pnode`], implicit θ-schemes via [`ImplicitAdjoint`]), NODE-cont
//! (continuous adjoint), NODE-naive (full tape), ANODE (block
//! checkpointing), and ACA (adaptive checkpoint adjoint).  All expose the
//! same [`GradientMethod`] interface so tasks and benches are generic.
//!
//! Construction goes through the facade: a [`crate::api::RunSpec`] names
//! a method as a typed [`crate::api::MethodSpec`], and the
//! [`crate::api::MethodRegistry`] resolves it to an engine (composing
//! [`ParallelAdjoint`] on top when the spec carries an `ExecConfig`).
//! The old `method_by_name` string dispatch is gone.

pub mod baselines;
pub mod memmodel;
pub mod parallel;
pub mod pnode;
pub mod theta;

pub use baselines::{Aca, Anode, NodeCont, NodeNaive};
pub use memmodel::MemModel;
pub use parallel::ParallelAdjoint;
pub use pnode::Pnode;
pub use theta::ImplicitAdjoint;

use crate::checkpoint::{CheckpointPolicy, TierStats};
use crate::exec::ExecStats;
use crate::ode::grid::TimeGrid;
use crate::ode::rhs::OdeRhs;
use crate::ode::tableau::Scheme;

/// Integration window of one ODE block: scheme + `[t0, tf]` + the time
/// grid (uniform, explicit nonuniform, or adaptive — see [`TimeGrid`]).
#[derive(Clone, Debug)]
pub struct BlockSpec {
    pub scheme: Scheme,
    pub t0: f64,
    pub tf: f64,
    pub grid: TimeGrid,
}

impl BlockSpec {
    /// Uniform grid with `nt` steps over `[0, 1]`.
    pub fn new(scheme: Scheme, nt: usize) -> Self {
        BlockSpec { scheme, t0: 0.0, tf: 1.0, grid: TimeGrid::Uniform { nt } }
    }

    /// Adaptive grid with `atol = rtol = tol` over `[0, 1]`.
    pub fn adaptive(scheme: Scheme, tol: f64) -> Self {
        BlockSpec { scheme, t0: 0.0, tf: 1.0, grid: TimeGrid::adaptive(tol) }
    }

    /// Planned step count.  Panics for adaptive grids (the count is only
    /// known once a forward pass has run — see `MethodReport::n_accepted`).
    pub fn nt(&self) -> usize {
        self.grid
            .planned_nt()
            // lint:allow(panic): documented contract: planned step counts exist only for non-adaptive grids, and the message redirects adaptive callers
            .expect("adaptive grids have no planned step count; read MethodReport::n_accepted")
    }
}

/// Resource accounting for one forward+backward gradient computation.
#[derive(Clone, Copy, Debug, Default)]
pub struct MethodReport {
    /// function evaluations in the forward pass
    pub nfe_forward: u64,
    /// function evaluations in the backward pass (recomputes + transposed
    /// products, per each method's own accounting — matches the paper's
    /// NFE-B column semantics)
    pub nfe_backward: u64,
    /// re-executed forward steps (PNODE checkpointing overhead)
    pub recompute_steps: u64,
    /// measured peak checkpoint bytes resident in RAM
    pub ckpt_bytes: u64,
    /// modeled AD-graph residency (tape emulation, Table-2 semantics)
    pub graph_bytes: u64,
    /// executed (accepted) steps of the forward pass
    pub n_accepted: u64,
    /// rejected adaptive trials (0 for static grids); these cost forward
    /// NFE but contribute zero backward NFE and zero checkpoint bytes
    pub n_rejected: u64,
    /// smallest executed step size
    pub h_min: f64,
    /// largest executed step size
    pub h_max: f64,
    /// storage-tier counters (hot/cold bytes, spills, prefetch hits);
    /// zeros beyond the hot fields for purely in-memory checkpointing
    pub tier: TierStats,
    /// data-parallel execution counters (workers, shards, throughput,
    /// arbiter lease contention); zeros for single-threaded methods
    pub exec: ExecStats,
    /// how an `auto:<budget>` policy resolved (the default note for
    /// concretely-specified policies); stamped by the `Session` facade
    pub auto: AutoNote,
}

/// Resolution note stamped by the facade when a spec's checkpoint policy
/// was `auto:<budget>`: which concrete candidate the calibrated cost
/// model picked.  Kept `Copy` like the report that carries it — the
/// candidate space is small enough to encode without strings, and the
/// full policy strings are reconstructed by [`AutoNote::requested_name`]
/// / [`AutoNote::resolved_name`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AutoNote {
    /// requested auto budget in bytes (0 ⇒ the policy was concrete and
    /// nothing was resolved)
    pub budget_bytes: u64,
    /// the winning candidate
    pub resolved: ResolvedPolicy,
}

/// The concrete candidate an `auto:<budget>` policy resolved to.  Tiered
/// candidates always use the fixed auto spill dir
/// (`crate::obs::calibrate::AUTO_SPILL_DIR`) and an `All` inner placement,
/// so the variant only needs the compression flag.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ResolvedPolicy {
    /// the spec named a concrete policy; nothing was resolved
    #[default]
    NotAuto,
    All,
    SolutionOnly,
    Binomial { k: u32 },
    Tiered { f16: bool },
}

impl AutoNote {
    /// Encode a resolution the cost model produced.  Panics on an
    /// unresolvable shape (the resolver only emits the candidate set
    /// below) or a zero budget (rejected at `validate`).
    pub fn for_resolution(budget_bytes: u64, policy: &CheckpointPolicy) -> AutoNote {
        assert!(budget_bytes > 0, "auto budgets are nonzero by validation");
        let resolved = match policy {
            CheckpointPolicy::All => ResolvedPolicy::All,
            CheckpointPolicy::SolutionOnly => ResolvedPolicy::SolutionOnly,
            CheckpointPolicy::Binomial { n_checkpoints } => {
                ResolvedPolicy::Binomial { k: *n_checkpoints as u32 }
            }
            CheckpointPolicy::Tiered { compress_f16, .. } => {
                ResolvedPolicy::Tiered { f16: *compress_f16 }
            }
            CheckpointPolicy::Auto { .. } => {
                // lint:allow(panic): resolve_spec replaces Auto with its concrete winner before any engine construction reaches this match
                panic!("auto cannot resolve to itself")
            }
        };
        AutoNote { budget_bytes, resolved }
    }

    /// Whether this report came from an `auto:<budget>` spec.
    pub fn is_auto(&self) -> bool {
        self.budget_bytes != 0
    }

    /// The requested policy string (`auto:<budget>`); `None` for
    /// concrete specs.
    pub fn requested_name(&self) -> Option<String> {
        self.is_auto()
            .then(|| CheckpointPolicy::Auto { budget_bytes: self.budget_bytes }.name())
    }

    /// The resolved policy string, reconstructed to match
    /// `CheckpointPolicy::name()` of the winning candidate exactly;
    /// `None` for concrete specs.
    pub fn resolved_name(&self) -> Option<String> {
        let p = match self.resolved {
            ResolvedPolicy::NotAuto => return None,
            ResolvedPolicy::All => CheckpointPolicy::All,
            ResolvedPolicy::SolutionOnly => CheckpointPolicy::SolutionOnly,
            ResolvedPolicy::Binomial { k } => {
                CheckpointPolicy::Binomial { n_checkpoints: k as usize }
            }
            ResolvedPolicy::Tiered { f16 } => CheckpointPolicy::Tiered {
                budget_bytes: self.budget_bytes,
                dir: crate::obs::calibrate::AUTO_SPILL_DIR.into(),
                compress_f16: f16,
                inner: Box::new(CheckpointPolicy::All),
            },
        };
        Some(p.name())
    }
}

impl MethodReport {
    pub fn total_model_bytes(&self) -> u64 {
        self.ckpt_bytes + self.graph_bytes
    }

    /// Record the executed grid (accepted steps + rejected trial count).
    pub fn note_grid(&mut self, steps: &[(f64, f64)], n_rejected: usize) {
        self.n_accepted = steps.len() as u64;
        self.n_rejected = n_rejected as u64;
        self.h_min = if steps.is_empty() {
            0.0
        } else {
            steps.iter().map(|s| s.1).fold(f64::INFINITY, f64::min)
        };
        self.h_max = steps.iter().map(|s| s.1).fold(0.0, f64::max);
    }

    /// Fold another block's grid stats into this aggregate (multi-block
    /// tasks): step counts accumulate, step-size extremes widen.  `h_min
    /// == 0.0` is the "no steps recorded" sentinel on both sides.
    pub fn merge_grid(&mut self, other: &MethodReport) {
        self.n_accepted += other.n_accepted;
        self.n_rejected += other.n_rejected;
        self.h_max = self.h_max.max(other.h_max);
        self.h_min = if self.h_min == 0.0 {
            other.h_min
        } else if other.h_min == 0.0 {
            self.h_min
        } else {
            self.h_min.min(other.h_min)
        };
    }
}

/// A gradient engine for one ODE block.
///
/// `Send` so engines (with their checkpoint state between `forward` and
/// `backward`) can move across the execution engine's worker threads.
pub trait GradientMethod: Send {
    fn name(&self) -> &'static str;

    /// Whether gradients are exact to machine precision wrt the discrete map.
    fn reverse_accurate(&self) -> bool;

    /// Integrate forward; must be called before `backward`.
    fn forward(&mut self, rhs: &dyn OdeRhs, spec: &BlockSpec, u0: &[f32]) -> Vec<f32>;

    /// Propagate `lambda` (∂L/∂u_F → ∂L/∂u_0), accumulate `grad_theta`.
    fn backward(&mut self, rhs: &dyn OdeRhs, spec: &BlockSpec, lambda: &mut [f32], grad_theta: &mut [f32]);

    /// Accounting of the latest forward+backward (call after backward).
    fn report(&self) -> MethodReport;
}

