//! `ParallelAdjoint` — the data-parallel adjoint execution engine's
//! [`GradientMethod`] face (DESIGN.md §8).
//!
//! The wrapper shards a minibatch into worker-count-*independent* row
//! shards ([`crate::exec::shard_ranges`]), runs one independent inner
//! gradient engine per shard on the worker pool (each shard owns its RHS
//! clone and its checkpoint backend), and combines results
//! deterministically: final states and λ are pure row concatenations,
//! and the per-shard θ̄ contributions are summed through a fixed-shape
//! tree ([`crate::exec::reduce`]).  Consequence: gradients are **bitwise
//! identical for `workers = 1, 2, N`** — the worker count is purely a
//! wall-clock knob.
//!
//! Adaptive grids: the PI controller's error norm couples batch rows, so
//! per-shard adaptation would give every shard (and therefore every
//! `shard_rows` choice) its own grid.  Instead the forward pass generates
//! the accepted grid ONCE on the full batch and every shard replays it as
//! a frozen explicit grid — one extra forward integration, charged to
//! `nfe_forward`, in exchange for a single shared time discretization.
//!
//! Memory: with a `Tiered` policy (see [`ParallelAdjoint::pnode`]) the
//! policy's budget becomes one global pool behind a
//! [`crate::exec::BudgetArbiter`]; the shard fleet's stores lease their
//! hot-tier bytes from it and degrade by spilling — never by exceeding
//! the budget.  Arbiter counters flow out through `MethodReport::exec`.
//!
//! Determinism caveat: the bitwise-across-workers guarantee requires
//! value-preserving storage.  Exact (f32) spills qualify; `+f16` spills
//! are lossy, and under the shared pool *which* records spill depends on
//! timing-dependent lease grants — so tiered`+f16` fleets are
//! approximate (as f16 already is vs. in-memory), not bitwise across
//! worker counts.

use std::ops::Range;
use std::sync::Arc;

use crate::checkpoint::{CheckpointPolicy, TierStats};
use crate::exec::arbiter::{ArbiterStats, BudgetArbiter};
use crate::exec::{pool, reduce, shard_ranges, ExecConfig, ExecStats};
use crate::methods::{BlockSpec, GradientMethod, MethodReport, Pnode};
use crate::obs;
use crate::ode::grid::{integrate_erk_over, TimeGrid};
use crate::ode::rhs::OdeRhs;

/// Factory for per-shard inner gradient engines (one independent
/// instance per shard per forward pass).
pub type MethodFactory = Box<dyn Fn() -> Box<dyn GradientMethod> + Send + Sync>;

/// One shard's engine state, retained between `forward` and `backward`.
struct Shard {
    rows: Range<usize>,
    rhs: Box<dyn OdeRhs + Send>,
    method: Box<dyn GradientMethod>,
}

pub struct ParallelAdjoint {
    make: MethodFactory,
    pub cfg: ExecConfig,
    arbiter: Option<Arc<BudgetArbiter>>,
    /// arbiter snapshot at forward start, for per-gradient deltas
    arb_base: ArbiterStats,
    shards: Vec<Shard>,
    /// the spec shards actually ran (adaptive grids frozen to explicit)
    shard_spec: Option<BlockSpec>,
    /// single-engine path for non-shardable RHSs
    fallback: Option<Box<dyn GradientMethod>>,
    inner_reverse_accurate: bool,
    batch_rows: usize,
    row_len: usize,
    /// forward NFE + rejected trials of the grid-generation pre-pass
    pre_nfe: u64,
    pre_rejected: usize,
    fwd_secs: f64,
    report: MethodReport,
}

impl ParallelAdjoint {
    pub fn new(make: MethodFactory, cfg: ExecConfig) -> Self {
        let inner_reverse_accurate = make().reverse_accurate();
        ParallelAdjoint {
            make,
            cfg,
            arbiter: None,
            arb_base: ArbiterStats::default(),
            shards: Vec::new(),
            shard_spec: None,
            fallback: None,
            inner_reverse_accurate,
            batch_rows: 0,
            row_len: 0,
            pre_nfe: 0,
            pre_rejected: 0,
            fwd_secs: 0.0,
            report: MethodReport::default(),
        }
    }

    /// Report this arbiter's counters through `MethodReport::exec` (set
    /// automatically by [`ParallelAdjoint::pnode`] for tiered policies).
    pub(crate) fn with_arbiter(mut self, arbiter: Arc<BudgetArbiter>) -> Self {
        self.arbiter = Some(arbiter);
        self
    }

    /// Data-parallel PNODE with the given checkpoint policy.  A `Tiered`
    /// policy's `budget_bytes` becomes ONE global hot-tier pool shared by
    /// every shard's store through a [`BudgetArbiter`] — the fleet-level
    /// memory/compute trade-off.
    pub fn pnode(policy: CheckpointPolicy, cfg: ExecConfig) -> Self {
        match &policy {
            CheckpointPolicy::Tiered { budget_bytes, .. } => {
                let arbiter = BudgetArbiter::new(*budget_bytes);
                let arb = arbiter.clone();
                ParallelAdjoint::new(
                    Box::new(move || Box::new(Pnode::with_arbiter(policy.clone(), arb.clone()))),
                    cfg,
                )
                .with_arbiter(arbiter)
            }
            _ => ParallelAdjoint::new(Box::new(move || Box::new(Pnode::new(policy.clone()))), cfg),
        }
    }

    /// The arbiter's live counters, when a shared pool governs this engine.
    pub fn arbiter_stats(&self) -> Option<ArbiterStats> {
        self.arbiter.as_ref().map(|a| a.stats())
    }
}

/// Sum tier counters across shards (traffic totals; note the summed
/// per-store `peak_hot_bytes` is an upper bound on the fleet's concurrent
/// footprint — the arbiter's `peak_leased_bytes` is the concurrent truth).
fn combine_tier(acc: &mut TierStats, t: &TierStats) {
    acc.hot_bytes += t.hot_bytes;
    acc.peak_hot_bytes += t.peak_hot_bytes;
    acc.cold_bytes_written += t.cold_bytes_written;
    acc.cold_bytes_live += t.cold_bytes_live;
    acc.spills += t.spills;
    acc.hot_hits += t.hot_hits;
    acc.prefetch_hits += t.prefetch_hits;
    acc.cold_reads += t.cold_reads;
    acc.compressed_elems += t.compressed_elems;
    acc.compress_max_abs_err = acc.compress_max_abs_err.max(t.compress_max_abs_err);
}

impl GradientMethod for ParallelAdjoint {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn reverse_accurate(&self) -> bool {
        self.inner_reverse_accurate
    }

    fn forward(&mut self, rhs: &dyn OdeRhs, spec: &BlockSpec, u0: &[f32]) -> Vec<f32> {
        let started = obs::stopwatch();
        self.shards.clear();
        self.shard_spec = None;
        self.fallback = None;
        self.pre_nfe = 0;
        self.pre_rejected = 0;
        self.report = MethodReport::default();
        if let Some(arb) = &self.arbiter {
            self.arb_base = arb.stats();
        }

        let rows = rhs.batch_rows();
        let ranges = shard_ranges(rows, self.cfg.shard_rows);
        // the probe doubles as shard 0's RHS below — never a wasted clone
        let mut probe = if ranges.len() > 1 { rhs.make_shard(ranges[0].len()) } else { None };
        if probe.is_none() {
            let mut m = (self.make)();
            let uf = m.forward(rhs, spec, u0);
            self.fallback = Some(m);
            self.batch_rows = rows;
            self.fwd_secs = started.elapsed_secs();
            return uf;
        }
        self.batch_rows = rows;
        self.row_len = rhs.state_len() / rows;
        // fair-share the global pool across the fleet: every shard's
        // store coexists from its forward until its backward, whatever
        // the worker count, so the partition is over shards, not workers
        if let Some(arb) = &self.arbiter {
            arb.set_parties(ranges.len());
        }

        // Adaptive grids: one grid-generation pass on the full batch; all
        // shards replay the frozen accepted grid (see module docs).
        let grid = match &spec.grid {
            TimeGrid::Adaptive { .. } => {
                rhs.reset_nfe();
                let run = integrate_erk_over(
                    spec.scheme.tableau(),
                    rhs,
                    spec.t0,
                    spec.tf,
                    &spec.grid,
                    u0,
                    |_, _, _, _, _, _| {},
                );
                self.pre_nfe = rhs.nfe().forward;
                self.pre_rejected = run.n_rejected;
                TimeGrid::Explicit(run.steps)
            }
            g => g.clone(),
        };
        let shard_spec = BlockSpec { scheme: spec.scheme, t0: spec.t0, tf: spec.tf, grid };

        let rl = self.row_len;
        let jobs: Vec<_> = ranges
            .iter()
            .map(|r| {
                let r = r.clone();
                let srhs = probe
                    .take()
                    // lint:allow(panic): make_shard succeeded on shard 0's probe, and every shard asks for the same row layout
                    .unwrap_or_else(|| rhs.make_shard(r.len()).expect("shardability probed"));
                let mut method = (self.make)();
                let sub_u0 = u0[r.start * rl..r.end * rl].to_vec();
                let sspec = shard_spec.clone();
                move || {
                    let uf = method.forward(srhs.as_ref(), &sspec, &sub_u0);
                    (r, srhs, method, uf)
                }
            })
            .collect();
        let done = pool::run_once_jobs(self.cfg.workers, jobs);

        let mut uf_full = vec![0.0f32; rows * rl];
        for (r, srhs, method, uf) in done {
            uf_full[r.start * rl..r.end * rl].copy_from_slice(&uf);
            self.shards.push(Shard { rows: r, rhs: srhs, method });
        }
        self.shard_spec = Some(shard_spec);
        self.fwd_secs = started.elapsed_secs();
        uf_full
    }

    fn backward(
        &mut self,
        rhs: &dyn OdeRhs,
        spec: &BlockSpec,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
    ) {
        let started = obs::stopwatch();
        if let Some(m) = &mut self.fallback {
            m.backward(rhs, spec, lambda, grad_theta);
            self.report = m.report();
            let total = self.fwd_secs + started.elapsed_secs();
            let mut exec = ExecStats {
                workers: 1,
                shards: 1,
                samples_per_sec: if total > 0.0 { self.batch_rows as f64 / total } else { 0.0 },
                ..ExecStats::default()
            };
            // a tiered fallback still leased from the pool: report it, so
            // the budget invariant stays checkable on non-sharded runs
            if let Some(arb) = &self.arbiter {
                let st = arb.stats();
                exec.lease_pool_bytes = st.total;
                exec.peak_leased_bytes = st.peak_leased;
                exec.lease_waits = st.lease_waits - self.arb_base.lease_waits;
                exec.lease_denied_bytes = st.denied_bytes - self.arb_base.denied_bytes;
                exec.over_grant_bytes = st.over_grant_bytes;
            }
            self.report.exec = exec;
            return;
        }

        let rl = self.row_len;
        let p = grad_theta.len();
        // shards carry the parameters of their own forward pass; re-sync
        // to the caller's RHS so multi-block training (set_params between
        // blocks) stays correct
        let theta = rhs.params().to_vec();
        // lint:allow(panic): the GradientMethod contract runs forward before backward
        let sspec = self.shard_spec.clone().expect("forward before backward");
        let shards = std::mem::take(&mut self.shards);
        let n_shards = shards.len();
        let jobs: Vec<_> = shards
            .into_iter()
            .map(|mut sh| {
                let mut lam = lambda[sh.rows.start * rl..sh.rows.end * rl].to_vec();
                let sspec = sspec.clone();
                let theta = theta.clone();
                move || {
                    sh.rhs.set_params(&theta);
                    let mut g = vec![0.0f32; p];
                    sh.method.backward(sh.rhs.as_ref(), &sspec, &mut lam, &mut g);
                    let rep = sh.method.report();
                    (sh.rows, lam, g, rep)
                }
            })
            .collect();
        let done = pool::run_once_jobs(self.cfg.workers, jobs);

        // λ rows are shard-local: scatter back in place.  θ̄ contributions
        // sum through the fixed-shape tree (shard order), then into the
        // caller's accumulator.
        let mut parts = Vec::with_capacity(n_shards);
        let mut agg = MethodReport::default();
        for (r, lam, g, rep) in done {
            lambda[r.start * rl..r.end * rl].copy_from_slice(&lam);
            parts.push(g);
            // NFE / recompute counts are per-trajectory (grid-determined
            // and equal across shards): keep the max so the columns stay
            // comparable with unsharded runs.  Byte and tier counters are
            // fleet totals: sum.
            agg.nfe_forward = agg.nfe_forward.max(rep.nfe_forward);
            agg.nfe_backward = agg.nfe_backward.max(rep.nfe_backward);
            agg.recompute_steps = agg.recompute_steps.max(rep.recompute_steps);
            agg.ckpt_bytes += rep.ckpt_bytes;
            agg.graph_bytes = agg.graph_bytes.max(rep.graph_bytes);
            combine_tier(&mut agg.tier, &rep.tier);
            if agg.n_accepted == 0 {
                agg.n_accepted = rep.n_accepted;
                agg.h_min = rep.h_min;
                agg.h_max = rep.h_max;
            }
        }
        reduce::tree_sum_into(grad_theta, parts);

        agg.nfe_forward += self.pre_nfe;
        agg.n_rejected = self.pre_rejected as u64;
        let total = self.fwd_secs + started.elapsed_secs();
        let mut exec = ExecStats {
            // the pool clamps concurrency to the job count: report the
            // parallelism that actually ran, not the configured ceiling
            workers: self.cfg.workers.min(n_shards) as u64,
            shards: n_shards as u64,
            samples_per_sec: if total > 0.0 { self.batch_rows as f64 / total } else { 0.0 },
            ..ExecStats::default()
        };
        if let Some(arb) = &self.arbiter {
            let st = arb.stats();
            exec.lease_pool_bytes = st.total;
            exec.peak_leased_bytes = st.peak_leased;
            exec.lease_waits = st.lease_waits - self.arb_base.lease_waits;
            exec.lease_denied_bytes = st.denied_bytes - self.arb_base.denied_bytes;
            exec.over_grant_bytes = st.over_grant_bytes;
        }
        agg.exec = exec;
        self.report = agg;
    }

    fn report(&self) -> MethodReport {
        self.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Act;
    use crate::ode::ModuleRhs;
    use crate::ode::rhs::LinearRhs;
    use crate::ode::tableau::Scheme;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    const B: usize = 20;
    const D: usize = 6;

    fn mk_rhs(seed: u64, batch: usize) -> ModuleRhs {
        let dims = vec![D + 1, 14, D];
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
        ModuleRhs::mlp(dims, Act::Tanh, true, batch, theta)
    }

    fn grad(
        method: &mut dyn GradientMethod,
        rhs: &ModuleRhs,
        spec: &BlockSpec,
        u0: &[f32],
        w: &[f32],
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, MethodReport) {
        let uf = method.forward(rhs, spec, u0);
        let mut lam = w.to_vec();
        let mut g = vec![0.0f32; rhs.param_len()];
        method.backward(rhs, spec, &mut lam, &mut g);
        (uf, lam, g, method.report())
    }

    #[test]
    fn sharded_gradient_matches_unsharded_rows_and_sums() {
        // λ rows must equal the unsharded run's bitwise (row-independent
        // paths); θ̄ differs only by summation shape, so compare to the
        // tree-sum of per-shard analytic runs — and to the unsharded θ̄
        // within rounding
        let rhs = mk_rhs(3, B);
        let mut rng = Rng::new(4);
        let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
        let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);
        let spec = BlockSpec::new(Scheme::Rk4, 6);

        let mut single = Pnode::new(CheckpointPolicy::All);
        let (uf_s, lam_s, g_s, _) = grad(&mut single, &rhs, &spec, &u0, &w);

        let cfg = ExecConfig { workers: 3, shard_rows: 8 };
        let mut par = ParallelAdjoint::pnode(CheckpointPolicy::All, cfg);
        let (uf_p, lam_p, g_p, rep) = grad(&mut par, &rhs, &spec, &u0, &w);

        assert_eq!(uf_p, uf_s, "final states are row concatenations");
        assert_eq!(lam_p, lam_s, "λ rows are shard-local");
        crate::testing::assert_allclose(&g_p, &g_s, 1e-4, 1e-5, "θ̄ reduction shape");
        assert_eq!(rep.exec.shards, 3, "20 rows / 8 per shard");
        assert_eq!(rep.exec.workers, 3);
        assert!(rep.exec.samples_per_sec > 0.0);
        assert_eq!(rep.nfe_forward, 6 * 4, "per-trajectory NFE semantics");
    }

    #[test]
    fn non_shardable_rhs_falls_back_to_the_inner_method() {
        let rhs = LinearRhs::new(3, vec![-0.4, 0.1, 0.0, 0.0, -0.2, 0.05, 0.0, 0.0, -0.1]);
        let u0 = vec![1.0f32, 0.5, -0.5];
        let w = vec![1.0f32, 1.0, 1.0];
        let spec = BlockSpec::new(Scheme::Rk4, 5);

        let run = |method: &mut dyn GradientMethod| {
            let uf = method.forward(&rhs, &spec, &u0);
            let mut lam = w.clone();
            let mut g = vec![0.0f32; rhs.param_len()];
            method.backward(&rhs, &spec, &mut lam, &mut g);
            (uf, lam, g, method.report())
        };
        let mut single = Pnode::new(CheckpointPolicy::All);
        let (uf_s, lam_s, g_s, _) = run(&mut single);
        let mut par =
            ParallelAdjoint::pnode(CheckpointPolicy::All, ExecConfig { workers: 4, shard_rows: 2 });
        let (uf_p, lam_p, g_p, rep) = run(&mut par);
        assert_eq!(uf_p, uf_s);
        assert_eq!(lam_p, lam_s, "fallback is the plain method, bitwise");
        assert_eq!(g_p, g_s);
        assert_eq!(rep.exec.shards, 1);
        assert_eq!(rep.exec.workers, 1);
    }

    #[test]
    fn multi_block_param_resync_uses_the_callers_rhs() {
        // backward must push the caller's CURRENT params into the shard
        // RHSs (multi-block training mutates them between blocks)
        let mut rng = Rng::new(12);
        let u0 = prop::vec_uniform(&mut rng, B * D, 0.5);
        let w = prop::vec_uniform(&mut rng, B * D, 1.0);
        let spec = BlockSpec::new(Scheme::Rk4, 4);
        let cfg = ExecConfig { workers: 2, shard_rows: 8 };

        // reference: forward and backward both under θ_b
        let mut rhs_b = mk_rhs(13, B);
        let theta_b = rhs_b.params().to_vec();
        let mut reference = ParallelAdjoint::pnode(CheckpointPolicy::All, cfg);
        let (_, lam_ref, g_ref, _) = grad(&mut reference, &rhs_b, &spec, &u0, &w);

        // same engine, forward under θ_b, backward handed an RHS carrying
        // θ_b again (emulating the task's set_params choreography)
        let mut par = ParallelAdjoint::pnode(CheckpointPolicy::All, cfg);
        par.forward(&rhs_b, &spec, &u0);
        rhs_b.set_params(&theta_b);
        let mut lam = w.clone();
        let mut g = vec![0.0f32; rhs_b.param_len()];
        par.backward(&rhs_b, &spec, &mut lam, &mut g);
        assert_eq!(lam, lam_ref);
        assert_eq!(g, g_ref);
    }
}
