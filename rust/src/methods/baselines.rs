//! The four baseline gradient methods the paper compares against (§4).
//! Each is a faithful re-implementation of the method's *compute and
//! memory pattern*; graph memory (what PyTorch tapes would hold) is
//! accounted analytically via `activation_bytes_per_eval`, since our
//! backward passes run VJPs through the AOT artifacts rather than a real
//! autograd tape.
//!
//! All baselines run over the spec's [`TimeGrid`].  For adaptive grids
//! the forward pass generates the grid with the PI controller (rejected
//! trials burn forward NFE); recompute-based backward passes (ANODE,
//! ACA) replay the *frozen accepted grid*, which preserves reverse
//! accuracy and the method's memory pattern without re-running the
//! step-size search.

use crate::adjoint::continuous::{continuous_adjoint_erk, continuous_adjoint_erk_grid};
use crate::adjoint::discrete_erk::{adjoint_erk_step, AdjointErkWorkspace};
use crate::methods::{BlockSpec, GradientMethod, MethodReport};
use crate::ode::erk::{erk_step, integrate_grid, ErkWorkspace};
use crate::ode::grid::{integrate_erk_over, TimeGrid};
use crate::ode::rhs::OdeRhs;

// ---------------------------------------------------------------------------
// NODE-cont: the vanilla neural ODE (continuous adjoint, not reverse-accurate)
// ---------------------------------------------------------------------------

pub struct NodeCont {
    u_final: Vec<f32>,
    steps: Vec<(f64, f64)>,
    report: MethodReport,
}

impl NodeCont {
    pub fn new() -> Self {
        NodeCont { u_final: Vec::new(), steps: Vec::new(), report: MethodReport::default() }
    }
}

impl Default for NodeCont {
    fn default() -> Self {
        Self::new()
    }
}

impl GradientMethod for NodeCont {
    fn name(&self) -> &'static str {
        "node_cont"
    }

    fn reverse_accurate(&self) -> bool {
        false
    }

    fn forward(&mut self, rhs: &dyn OdeRhs, spec: &BlockSpec, u0: &[f32]) -> Vec<f32> {
        rhs.reset_nfe();
        let tab = spec.scheme.tableau();
        let run = integrate_erk_over(
            tab, rhs, spec.t0, spec.tf, &spec.grid, u0, |_, _, _, _, _, _| {},
        );
        self.u_final = run.final_state;
        self.steps = run.steps;
        self.report = MethodReport { nfe_forward: rhs.nfe().forward, ..Default::default() };
        self.report.note_grid(&self.steps, run.n_rejected);
        self.u_final.clone()
    }

    fn backward(
        &mut self,
        rhs: &dyn OdeRhs,
        spec: &BlockSpec,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
    ) {
        rhs.reset_nfe();
        let tab = spec.scheme.tableau();
        match &spec.grid {
            // the uniform branch keeps the legacy backward time points
            // bit-for-bit (t = tf − k·h vs the grid variant's t_n + h_n,
            // which differ in rounding); nonuniform/adaptive grids retrace
            // the recorded steps in reverse
            TimeGrid::Uniform { nt } => continuous_adjoint_erk(
                tab, rhs, spec.t0, spec.tf, *nt, &self.u_final, lambda, grad_theta,
            ),
            _ => continuous_adjoint_erk_grid(
                tab, rhs, &self.steps, &self.u_final, lambda, grad_theta,
            ),
        }
        let nfe = rhs.nfe();
        self.report.nfe_backward = nfe.forward.max(nfe.backward);
        // no checkpoints; graph is one f eval deep
        self.report.ckpt_bytes = (self.u_final.len() * 4) as u64;
        self.report.graph_bytes = rhs.activation_bytes_per_eval();
    }

    fn report(&self) -> MethodReport {
        self.report
    }
}

// ---------------------------------------------------------------------------
// NODE-naive: backprop through the whole solve (deepest graph, no recompute)
// ---------------------------------------------------------------------------

pub struct NodeNaive {
    tape: Vec<(f64, f64, Vec<f32>, Vec<Vec<f32>>)>, // (t, h, u_n, ks) per step
    report: MethodReport,
}

impl NodeNaive {
    pub fn new() -> Self {
        NodeNaive { tape: Vec::new(), report: MethodReport::default() }
    }
}

impl Default for NodeNaive {
    fn default() -> Self {
        Self::new()
    }
}

impl GradientMethod for NodeNaive {
    fn name(&self) -> &'static str {
        "node_naive"
    }

    fn reverse_accurate(&self) -> bool {
        true
    }

    fn forward(&mut self, rhs: &dyn OdeRhs, spec: &BlockSpec, u0: &[f32]) -> Vec<f32> {
        rhs.reset_nfe();
        self.tape.clear();
        let tab = spec.scheme.tableau();
        let tape = &mut self.tape;
        let run = integrate_erk_over(
            tab, rhs, spec.t0, spec.tf, &spec.grid, u0,
            |_, t, h, u, ks, _| {
                tape.push((t, h, u.to_vec(), ks.to_vec()));
            },
        );
        // graph memory: every stage of every executed step keeps its
        // activations live
        self.report = MethodReport {
            nfe_forward: rhs.nfe().forward,
            graph_bytes: self.tape.len() as u64
                * tab.s as u64
                * rhs.activation_bytes_per_eval(),
            ..Default::default()
        };
        self.report.note_grid(&run.steps, run.n_rejected);
        run.final_state
    }

    fn backward(
        &mut self,
        rhs: &dyn OdeRhs,
        spec: &BlockSpec,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
    ) {
        rhs.reset_nfe();
        let tab = spec.scheme.tableau();
        let n = lambda.len();
        let mut aws = AdjointErkWorkspace::new(tab.s, n);
        for (t, h, u, ks) in self.tape.iter().rev() {
            adjoint_erk_step(tab, rhs, *t, *h, u, ks, lambda, grad_theta, &mut aws);
        }
        // paper semantics: backprop through the stored graph costs no f
        // re-evaluations -> NFE-B = 0
        self.report.nfe_backward = 0;
        self.report.ckpt_bytes = self
            .tape
            .iter()
            .map(|(_, _, u, ks)| ((u.len() + ks.iter().map(|k| k.len()).sum::<usize>()) * 4) as u64)
            .sum();
    }

    fn report(&self) -> MethodReport {
        self.report
    }
}

// ---------------------------------------------------------------------------
// ANODE: checkpoint block inputs; recompute the block forward with a full
// tape, then backprop (Gholaminejad et al. 2019)
// ---------------------------------------------------------------------------

pub struct Anode {
    u0: Vec<f32>,
    steps: Vec<(f64, f64)>,
    report: MethodReport,
}

impl Anode {
    pub fn new() -> Self {
        Anode { u0: Vec::new(), steps: Vec::new(), report: MethodReport::default() }
    }
}

impl Default for Anode {
    fn default() -> Self {
        Self::new()
    }
}

impl GradientMethod for Anode {
    fn name(&self) -> &'static str {
        "anode"
    }

    fn reverse_accurate(&self) -> bool {
        true
    }

    fn forward(&mut self, rhs: &dyn OdeRhs, spec: &BlockSpec, u0: &[f32]) -> Vec<f32> {
        rhs.reset_nfe();
        self.u0 = u0.to_vec(); // the only checkpoint: the block input
        let tab = spec.scheme.tableau();
        let run = integrate_erk_over(
            tab, rhs, spec.t0, spec.tf, &spec.grid, u0, |_, _, _, _, _, _| {},
        );
        self.steps = run.steps;
        self.report = MethodReport {
            nfe_forward: rhs.nfe().forward,
            ckpt_bytes: (u0.len() * 4) as u64,
            ..Default::default()
        };
        self.report.note_grid(&self.steps, run.n_rejected);
        run.final_state
    }

    fn backward(
        &mut self,
        rhs: &dyn OdeRhs,
        spec: &BlockSpec,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
    ) {
        rhs.reset_nfe();
        let tab = spec.scheme.tableau();
        let n = lambda.len();
        let nt = self.steps.len();
        // recompute the whole block over the frozen grid, storing the tape
        let mut tape: Vec<(f64, f64, Vec<f32>, Vec<Vec<f32>>)> = Vec::with_capacity(nt);
        integrate_grid(tab, rhs, &self.steps, &self.u0, |_, t, h, u, ks, _| {
            tape.push((t, h, u.to_vec(), ks.to_vec()));
        });
        let recompute_evals = rhs.nfe().forward;
        let mut aws = AdjointErkWorkspace::new(tab.s, n);
        for (t, h, u, ks) in tape.iter().rev() {
            adjoint_erk_step(tab, rhs, *t, *h, u, ks, lambda, grad_theta, &mut aws);
        }
        self.report.nfe_backward = recompute_evals; // the recompute is the cost
        self.report.recompute_steps = nt as u64;
        // tape lives during backward: graph = N_t * N_s activations
        self.report.graph_bytes =
            nt as u64 * tab.s as u64 * rhs.activation_bytes_per_eval();
        self.report.ckpt_bytes += tape
            .iter()
            .map(|(_, _, u, ks)| ((u.len() + ks.iter().map(|k| k.len()).sum::<usize>()) * 4) as u64)
            .sum::<u64>();
    }

    fn report(&self) -> MethodReport {
        self.report
    }
}

// ---------------------------------------------------------------------------
// ACA: adaptive checkpoint adjoint (Zhuang et al. 2020) — solution
// checkpoints from an extra forward pass, then per-step local graphs
// ---------------------------------------------------------------------------

pub struct Aca {
    u0: Vec<f32>,
    steps: Vec<(f64, f64)>,
    report: MethodReport,
}

impl Aca {
    pub fn new() -> Self {
        Aca { u0: Vec::new(), steps: Vec::new(), report: MethodReport::default() }
    }
}

impl Default for Aca {
    fn default() -> Self {
        Self::new()
    }
}

impl GradientMethod for Aca {
    fn name(&self) -> &'static str {
        "aca"
    }

    fn reverse_accurate(&self) -> bool {
        true
    }

    fn forward(&mut self, rhs: &dyn OdeRhs, spec: &BlockSpec, u0: &[f32]) -> Vec<f32> {
        rhs.reset_nfe();
        self.u0 = u0.to_vec();
        let tab = spec.scheme.tableau();
        let run = integrate_erk_over(
            tab, rhs, spec.t0, spec.tf, &spec.grid, u0, |_, _, _, _, _, _| {},
        );
        self.steps = run.steps;
        self.report = MethodReport { nfe_forward: rhs.nfe().forward, ..Default::default() };
        self.report.note_grid(&self.steps, run.n_rejected);
        run.final_state
    }

    fn backward(
        &mut self,
        rhs: &dyn OdeRhs,
        spec: &BlockSpec,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
    ) {
        rhs.reset_nfe();
        let tab = spec.scheme.tableau();
        let n = lambda.len();
        let nt = self.steps.len();
        // ACA's extra forward pass over the accepted grid: store the
        // solution at every step (this is exactly ACA's trick — the
        // step-size search is not repeated)
        let mut solutions: Vec<(f64, f64, Vec<f32>)> = Vec::with_capacity(nt);
        integrate_grid(tab, rhs, &self.steps, &self.u0, |_, t, h, u, _, _| {
            solutions.push((t, h, u.to_vec()));
        });
        // per-step: recompute the local graph (the step's stages), backprop it
        let mut aws = AdjointErkWorkspace::new(tab.s, n);
        let mut ews = ErkWorkspace::new(n);
        let mut ks: Vec<Vec<f32>> = (0..tab.s).map(|_| vec![0.0f32; n]).collect();
        let mut un = vec![0.0f32; n];
        for (t, h, u) in solutions.iter().rev() {
            erk_step(tab, rhs, *t, *h, u, &mut ks, &mut un, &mut ews, None);
            adjoint_erk_step(tab, rhs, *t, *h, u, &ks, lambda, grad_theta, &mut aws);
        }
        // NFE-B: extra forward + per-step recompute (≈ 2 N_t N_s, paper §4)
        self.report.nfe_backward = rhs.nfe().forward;
        self.report.recompute_steps = 2 * nt as u64;
        self.report.ckpt_bytes =
            solutions.iter().map(|(_, _, u)| (u.len() * 4) as u64).sum::<u64>();
        // local graph: one step's stages = N_s activations deep
        self.report.graph_bytes = tab.s as u64 * rhs.activation_bytes_per_eval();
    }

    fn report(&self) -> MethodReport {
        self.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::CheckpointPolicy;
    use crate::methods::pnode::Pnode;
    use crate::nn::Act;
    use crate::ode::ModuleRhs;
    use crate::ode::tableau::Scheme;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn mk_rhs(seed: u64) -> ModuleRhs {
        let dims = vec![4, 6, 3];
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
        ModuleRhs::mlp(dims, Act::Tanh, true, 2, theta)
    }

    fn grad_of(
        method: &mut dyn GradientMethod,
        rhs: &ModuleRhs,
        spec: &BlockSpec,
        u0: &[f32],
        w: &[f32],
    ) -> (Vec<f32>, Vec<f32>) {
        method.forward(rhs, spec, u0);
        let mut lambda = w.to_vec();
        let mut gtheta = vec![0.0f32; rhs.param_len()];
        method.backward(rhs, spec, &mut lambda, &mut gtheta);
        (lambda, gtheta)
    }

    #[test]
    fn reverse_accurate_methods_agree_exactly() {
        let rhs = mk_rhs(71);
        let spec = BlockSpec::new(Scheme::Bosh3, 6);
        let mut rng = Rng::new(72);
        let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
        let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);

        let mut pnode = Pnode::new(CheckpointPolicy::All);
        let (l_ref, g_ref) = grad_of(&mut pnode, &rhs, &spec, &u0, &w);

        for mut m in [
            Box::new(NodeNaive::new()) as Box<dyn GradientMethod>,
            Box::new(Anode::new()),
            Box::new(Aca::new()),
        ] {
            let (l, g) = grad_of(m.as_mut(), &rhs, &spec, &u0, &w);
            crate::testing::assert_allclose(&l, &l_ref, 1e-6, 1e-7, m.name());
            crate::testing::assert_allclose(&g, &g_ref, 1e-6, 1e-7, m.name());
            assert!(m.reverse_accurate());
        }
    }

    #[test]
    fn reverse_accurate_methods_agree_under_adaptive_grids() {
        // all reverse-accurate methods differentiate the same accepted
        // discrete map, so they agree on adaptive grids too
        let rhs = mk_rhs(171);
        let spec = BlockSpec::adaptive(Scheme::Dopri5, 1e-5);
        let mut rng = Rng::new(172);
        let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
        let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);

        let mut pnode = Pnode::new(CheckpointPolicy::All);
        let (l_ref, g_ref) = grad_of(&mut pnode, &rhs, &spec, &u0, &w);
        let r_ref = pnode.report();
        assert!(r_ref.n_accepted > 1, "{r_ref:?}");

        for mut m in [
            Box::new(NodeNaive::new()) as Box<dyn GradientMethod>,
            Box::new(Anode::new()),
            Box::new(Aca::new()),
        ] {
            let (l, g) = grad_of(m.as_mut(), &rhs, &spec, &u0, &w);
            crate::testing::assert_allclose(&l, &l_ref, 1e-6, 1e-7, m.name());
            crate::testing::assert_allclose(&g, &g_ref, 1e-6, 1e-7, m.name());
            let r = m.report();
            assert_eq!(r.n_accepted, r_ref.n_accepted, "{}: same accepted grid", m.name());
            assert_eq!(r.n_rejected, r_ref.n_rejected, "{}", m.name());
        }

        // the continuous adjoint retraces the accepted grid in reverse:
        // close, but not reverse-accurate
        let mut cont = NodeCont::new();
        let (l_cont, _) = grad_of(&mut cont, &rhs, &spec, &u0, &w);
        let err = crate::testing::rel_l2(&l_cont, &l_ref);
        assert!(err < 0.2, "continuous adjoint should be close: {err}");
    }

    #[test]
    fn continuous_adjoint_is_close_but_not_exact() {
        let rhs = mk_rhs(81);
        let spec = BlockSpec::new(Scheme::Euler, 10);
        let mut rng = Rng::new(82);
        let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
        let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);

        let mut pnode = Pnode::new(CheckpointPolicy::All);
        let (l_ref, _) = grad_of(&mut pnode, &rhs, &spec, &u0, &w);
        let mut cont = NodeCont::new();
        let (l_cont, _) = grad_of(&mut cont, &rhs, &spec, &u0, &w);
        assert!(!cont.reverse_accurate());

        let err = crate::testing::rel_l2(&l_cont, &l_ref);
        assert!(err < 0.2, "continuous adjoint should be close: {err}");
        assert!(err > 1e-7, "continuous adjoint should NOT be exact: {err}");
    }

    #[test]
    fn nfe_patterns_match_table2() {
        let rhs = mk_rhs(91);
        let nt = 10usize;
        let spec = BlockSpec::new(Scheme::Rk4, nt);
        let mut rng = Rng::new(92);
        let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
        let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);
        let s = 4u64;

        let check = |m: &mut dyn GradientMethod, f: u64, b: u64| {
            grad_of(m, &rhs, &spec, &u0, &w);
            let r = m.report();
            assert_eq!(r.nfe_forward, f, "{} NFE-F", m.name());
            assert_eq!(r.nfe_backward, b, "{} NFE-B", m.name());
        };
        let ntu = nt as u64;
        // PNODE: forward N_t*N_s, backward N_t*N_s transposed products
        check(&mut Pnode::new(CheckpointPolicy::All), ntu * s, ntu * s);
        // naive: no backward evals
        check(&mut NodeNaive::new(), ntu * s, 0);
        // ANODE: backward = full recompute
        check(&mut Anode::new(), ntu * s, ntu * s);
        // ACA: extra forward + per-step recompute = 2*N_t*N_s
        check(&mut Aca::new(), ntu * s, 2 * ntu * s);
        // cont: backward integrates the augmented system: N_t*N_s forward
        // evals (plus the same number of vjps)
        let mut cont = NodeCont::new();
        grad_of(&mut cont, &rhs, &spec, &u0, &w);
        assert_eq!(cont.report().nfe_backward, ntu * s);
    }

    #[test]
    fn memory_ordering_matches_table2() {
        // naive > anode > aca ≈ pnode2 ; pnode graph smallest
        let rhs = mk_rhs(101);
        let spec = BlockSpec::new(Scheme::Dopri5, 12);
        let mut rng = Rng::new(102);
        let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
        let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);

        let total = |m: &mut dyn GradientMethod| -> u64 {
            grad_of(m, &rhs, &spec, &u0, &w);
            m.report().total_model_bytes()
        };
        let naive = total(&mut NodeNaive::new());
        let anode = total(&mut Anode::new());
        let aca = total(&mut Aca::new());
        let pnode = total(&mut Pnode::new(CheckpointPolicy::All));
        let pnode2 = total(&mut Pnode::new(CheckpointPolicy::SolutionOnly));
        let cont = total(&mut NodeCont::new());

        // single block: naive ≈ anode (+ block-input checkpoint); with
        // N_b > 1 blocks naive grows N_b× faster (see memmodel tests)
        assert!(naive + 1024 >= anode, "naive {naive} << anode {anode}");
        assert!(anode > pnode, "anode {anode} <= pnode {pnode}");
        assert!(pnode > pnode2, "pnode {pnode} <= pnode2 {pnode2}");
        assert!(pnode2 < aca * 2, "pnode2 {pnode2} should be ~aca {aca}");
        assert!(cont < pnode, "cont {cont} should be smallest-ish vs {pnode}");
    }
}
