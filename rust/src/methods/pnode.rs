//! PNODE: high-level discrete adjoint with checkpointing (the paper's
//! contribution).  `CheckpointPolicy::All` is the paper's default "PNODE"
//! configuration; `SolutionOnly` is "PNODE2"; `Binomial{n}` exposes the
//! full memory/compute trade-off of Prop. 2.  Runs on any [`TimeGrid`]
//! the spec carries — including adaptive Dopri5, where gradients are
//! reverse-accurate with respect to the accepted discrete map.

use std::sync::Arc;

use crate::adjoint::driver::ErkDriver;
use crate::checkpoint::CheckpointPolicy;
use crate::exec::arbiter::BudgetArbiter;
use crate::methods::{BlockSpec, GradientMethod, MethodReport};
use crate::ode::rhs::OdeRhs;

pub struct Pnode {
    pub policy: CheckpointPolicy,
    /// fleet mode: a `Tiered` policy leases hot-tier bytes from this
    /// shared pool instead of owning its whole budget
    arbiter: Option<Arc<BudgetArbiter>>,
    run: Option<ErkDriver<'static>>,
    report: MethodReport,
}

impl Pnode {
    pub fn new(policy: CheckpointPolicy) -> Self {
        Pnode { policy, arbiter: None, run: None, report: MethodReport::default() }
    }

    /// PNODE whose tiered checkpoint store draws from the shared
    /// checkpoint-memory `arbiter` — fleet plumbing behind
    /// [`crate::methods::ParallelAdjoint::pnode`]; public callers reach it
    /// through a parallel tiered `crate::api::RunSpec`.
    pub(crate) fn with_arbiter(policy: CheckpointPolicy, arbiter: Arc<BudgetArbiter>) -> Self {
        Pnode { policy, arbiter: Some(arbiter), run: None, report: MethodReport::default() }
    }

    /// The executed (accepted) `(t_n, h_n)` grid of the latest forward
    /// pass — for adaptive specs, the grid the PI controller generated.
    pub fn grid_steps(&self) -> Option<&[(f64, f64)]> {
        self.run.as_ref().map(|r| r.grid_steps())
    }
}

impl GradientMethod for Pnode {
    fn name(&self) -> &'static str {
        match self.policy {
            CheckpointPolicy::All => "pnode",
            CheckpointPolicy::SolutionOnly => "pnode2",
            CheckpointPolicy::Binomial { .. } => "pnode-binomial",
            CheckpointPolicy::Tiered { .. } => "pnode-tiered",
        }
    }

    fn reverse_accurate(&self) -> bool {
        true
    }

    fn forward(&mut self, rhs: &dyn OdeRhs, spec: &BlockSpec, u0: &[f32]) -> Vec<f32> {
        rhs.reset_nfe();
        let tab = spec.scheme.tableau();
        let mut run = ErkDriver::erk_with_arbiter(
            tab,
            self.policy.clone(),
            spec.t0,
            spec.tf,
            spec.grid.clone(),
            self.arbiter.clone(),
        );
        let uf = run.forward(rhs, u0);
        self.report = MethodReport {
            nfe_forward: rhs.nfe().forward,
            ..MethodReport::default()
        };
        self.report.note_grid(run.grid_steps(), run.n_rejected());
        self.run = Some(run);
        uf
    }

    fn backward(
        &mut self,
        rhs: &dyn OdeRhs,
        _spec: &BlockSpec,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
    ) {
        // lint:allow(panic): the GradientMethod contract runs forward before backward
        let run = self.run.as_mut().expect("forward before backward");
        rhs.reset_nfe();
        run.backward(rhs, lambda, grad_theta);
        let nfe = rhs.nfe();
        // NFE-B: transposed products + stage recomputes (the paper counts
        // both as function evaluations in the backward pass)
        self.report.nfe_backward = nfe.backward + nfe.forward;
        self.report.recompute_steps = run.recompute_steps;
        self.report.ckpt_bytes = run.peak_checkpoint_bytes();
        self.report.tier = run.tier_stats();
        // the only graph ever built is one f evaluation deep: O(N_l)
        self.report.graph_bytes = rhs.activation_bytes_per_eval();
    }

    fn report(&self) -> MethodReport {
        self.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::grid::TimeGrid;
    use crate::ode::rhs::LinearRhs;
    use crate::ode::tableau::Scheme;

    /// The paper's §4 claim, asserted via MethodReport: rejected adaptive
    /// trials count toward forward NFE but contribute zero backward NFE
    /// and zero checkpoint bytes.
    #[test]
    fn rejected_steps_cost_forward_nfe_only() {
        // a stiff axis plus a generous trial step guarantees rejections
        let rhs = LinearRhs::new(2, vec![-40.0, 0.0, 0.0, -1.0]);
        let u0 = vec![1.0f32, 1.0];
        let w = vec![1.0f32, 1.0];

        let report_of = |spec: &BlockSpec| -> (MethodReport, Option<Vec<(f64, f64)>>) {
            let mut m = Pnode::new(CheckpointPolicy::All);
            m.forward(&rhs, spec, &u0);
            let mut l = w.clone();
            let mut g = vec![0.0f32; rhs.param_len()];
            m.backward(&rhs, spec, &mut l, &mut g);
            let steps = m.grid_steps().map(|s| s.to_vec());
            (m.report(), steps)
        };

        let ada_spec = BlockSpec {
            scheme: Scheme::Dopri5,
            t0: 0.0,
            tf: 1.0,
            grid: TimeGrid::Adaptive { atol: 1e-6, rtol: 1e-6, h0: Some(0.5) },
        };
        let (r_ada, steps) = report_of(&ada_spec);
        let steps = steps.expect("forward recorded the accepted grid");
        assert!(r_ada.n_rejected > 0, "expected rejected trials: {r_ada:?}");
        assert_eq!(r_ada.n_accepted as usize, steps.len());
        assert!(r_ada.h_min > 0.0 && r_ada.h_max >= r_ada.h_min, "{r_ada:?}");

        // the same accepted grid replayed as an explicit spec: identical
        // backward NFE and checkpoint bytes, strictly fewer forward NFE
        let ex_spec = BlockSpec {
            scheme: Scheme::Dopri5,
            t0: 0.0,
            tf: 1.0,
            grid: TimeGrid::Explicit(steps),
        };
        let (r_ex, _) = report_of(&ex_spec);
        assert_eq!(r_ex.n_rejected, 0);
        assert_eq!(r_ada.nfe_backward, r_ex.nfe_backward, "zero backward NFE from rejects");
        assert_eq!(r_ada.ckpt_bytes, r_ex.ckpt_bytes, "zero checkpoint bytes from rejects");
        assert!(
            r_ada.nfe_forward > r_ex.nfe_forward,
            "rejects cost forward NFE: {} vs {}",
            r_ada.nfe_forward,
            r_ex.nfe_forward
        );
    }
}
