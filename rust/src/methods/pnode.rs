//! PNODE: high-level discrete adjoint with checkpointing (the paper's
//! contribution).  `CheckpointPolicy::All` is the paper's default "PNODE"
//! configuration; `SolutionOnly` is "PNODE2"; `Binomial{n}` exposes the
//! full memory/compute trade-off of Prop. 2.

use crate::adjoint::driver::ErkAdjointRun;
use crate::checkpoint::CheckpointPolicy;
use crate::methods::{BlockSpec, GradientMethod, MethodReport};
use crate::ode::rhs::OdeRhs;

pub struct Pnode {
    pub policy: CheckpointPolicy,
    run: Option<ErkAdjointRun<'static>>,
    report: MethodReport,
}

impl Pnode {
    pub fn new(policy: CheckpointPolicy) -> Self {
        Pnode { policy, run: None, report: MethodReport::default() }
    }
}

impl GradientMethod for Pnode {
    fn name(&self) -> &'static str {
        match self.policy {
            CheckpointPolicy::All => "pnode",
            CheckpointPolicy::SolutionOnly => "pnode2",
            CheckpointPolicy::Binomial { .. } => "pnode-binomial",
            CheckpointPolicy::Tiered { .. } => "pnode-tiered",
        }
    }

    fn reverse_accurate(&self) -> bool {
        true
    }

    fn forward(&mut self, rhs: &dyn OdeRhs, spec: &BlockSpec, u0: &[f32]) -> Vec<f32> {
        rhs.reset_nfe();
        let tab = spec.scheme.tableau();
        let mut run = ErkAdjointRun::new(tab, self.policy.clone(), spec.t0, spec.tf, spec.nt);
        let uf = run.forward(rhs, u0);
        self.report = MethodReport {
            nfe_forward: rhs.nfe().forward,
            ..MethodReport::default()
        };
        self.run = Some(run);
        uf
    }

    fn backward(
        &mut self,
        rhs: &dyn OdeRhs,
        _spec: &BlockSpec,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
    ) {
        let run = self.run.as_mut().expect("forward before backward");
        rhs.reset_nfe();
        run.backward(rhs, lambda, grad_theta);
        let nfe = rhs.nfe();
        // NFE-B: transposed products + stage recomputes (the paper counts
        // both as function evaluations in the backward pass)
        self.report.nfe_backward = nfe.backward + nfe.forward;
        self.report.recompute_steps = run.recompute_steps;
        self.report.ckpt_bytes = run.peak_checkpoint_bytes();
        self.report.tier = run.tier_stats();
        // the only graph ever built is one f evaluation deep: O(N_l)
        self.report.graph_bytes = rhs.activation_bytes_per_eval();
    }

    fn report(&self) -> MethodReport {
        self.report
    }
}
