//! `ModuleRhs` — the neural ODE right-hand side over a composable module
//! graph (the successor of the old hard-wired `MlpRhs`).
//!
//! The RHS owns a [`Module`] graph built from an [`ArchSpec`] plus the
//! flat parameter vector, and implements the full [`OdeRhs`] contract:
//! time-conditioning stays *inside* the graph ([`ConcatTime`] /
//! [`ConcatSquash`] read `t` directly), so the state dimension equals the
//! module's in/out dimension and no augment/strip plumbing leaks out.
//!
//! Row sharding ([`OdeRhs::make_shard`]) rebuilds the same architecture
//! at the shard's row count from the stored spec: every provided module
//! is row-independent (per-sample loops + per-row GEMMs), so a shard
//! reproduces its rows of the full-batch run bitwise — the contract the
//! data-parallel execution engine (`crate::exec`) relies on.
//!
//! [`ArchSpec`]: crate::nn::module::ArchSpec
//! [`ConcatTime`]: crate::nn::module::ConcatTime
//! [`ConcatSquash`]: crate::nn::module::ConcatSquash

use std::cell::RefCell;

use crate::nn::Act;
use crate::nn::module::{ArchSpec, Module};
use crate::ode::rhs::{Nfe, NfeCounter, OdeRhs};

#[derive(Clone, Debug, Default)]
struct RhsScratch {
    /// module forward-cache arena
    cache: Vec<f32>,
    /// staging for forward outputs the caller does not want
    y: Vec<f32>,
}

/// Neural RHS backed by a module graph; construct via
/// [`ModuleRhs::from_arch`] (or the [`ModuleRhs::mlp`] shorthand for the
/// legacy flat-MLP layout).
pub struct ModuleRhs {
    module: Box<dyn Module>,
    /// the spec that built `module` — shards rebuild from it
    arch: ArchSpec,
    /// data channels per sample before any augmentation
    data_dim: usize,
    batch: usize,
    state_dim: usize,
    theta: Vec<f32>,
    nfe: NfeCounter,
    scratch: RefCell<RhsScratch>,
}

impl ModuleRhs {
    /// Instantiate `arch` at `data_dim` over `batch` rows with parameters
    /// `theta` (layout: the arch's flat layout, see [`ArchSpec::init`]).
    pub fn from_arch(arch: &ArchSpec, data_dim: usize, batch: usize, theta: Vec<f32>) -> Self {
        // lint:allow(panic): constructor-time validation of a caller-supplied architecture, surfaced at build
        arch.validate().unwrap_or_else(|e| panic!("invalid arch {:?}: {e}", arch.name()));
        assert!(batch > 0, "ModuleRhs needs at least one batch row");
        let module = arch.build(data_dim);
        let state_dim = arch.state_dim(data_dim);
        debug_assert_eq!(module.in_dim(), state_dim);
        debug_assert_eq!(module.out_dim(), state_dim);
        assert_eq!(
            theta.len(),
            module.param_len(),
            "theta length mismatch for arch {}",
            arch.name()
        );
        ModuleRhs {
            module,
            arch: arch.clone(),
            data_dim,
            batch,
            state_dim,
            theta,
            nfe: NfeCounter::default(),
            scratch: RefCell::default(),
        }
    }

    /// The legacy flat-MLP constructor: `dims` are the layer widths of
    /// the network *input included* (`[d(+1), hidden…, d]`), `time_dep`
    /// appends `t` as an input column — exactly the old `MlpRhs::new`
    /// signature, with the identical parameter layout, so existing θ
    /// vectors (and RNG init streams) carry over unchanged.
    pub fn mlp(dims: Vec<usize>, act: Act, time_dep: bool, batch: usize, theta: Vec<f32>) -> Self {
        assert!(dims.len() >= 2, "an MLP RHS needs at least [in, out] dims (got {dims:?})");
        // lint:allow(panic): dims.len() >= 2 asserted on the line above
        let state_dim = *dims.last().unwrap();
        let expect_in = if time_dep { state_dim + 1 } else { state_dim };
        assert_eq!(dims[0], expect_in, "in dim mismatch for time_dep={time_dep}");
        let hidden = dims[1..dims.len() - 1].to_vec();
        let arch = if time_dep {
            ArchSpec::ConcatMlp { hidden, act }
        } else {
            ArchSpec::Mlp { hidden, act }
        };
        ModuleRhs::from_arch(&arch, state_dim, batch, theta)
    }

    /// The architecture this RHS executes.
    pub fn arch(&self) -> &ArchSpec {
        &self.arch
    }

    /// State channels per sample (after any augmentation).
    pub fn state_dim(&self) -> usize {
        self.state_dim
    }

    /// Batch rows.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The underlying module graph.
    pub fn module(&self) -> &dyn Module {
        self.module.as_ref()
    }

    fn ensure_scratch(&self) {
        let mut s = self.scratch.borrow_mut();
        let cl = self.module.cache_len(self.batch);
        if s.cache.len() < cl {
            s.cache.resize(cl, 0.0);
        }
        let n = self.batch * self.state_dim;
        if s.y.len() < n {
            s.y.resize(n, 0.0);
        }
    }
}

impl OdeRhs for ModuleRhs {
    fn state_len(&self) -> usize {
        self.batch * self.state_dim
    }

    fn param_len(&self) -> usize {
        self.theta.len()
    }

    fn params(&self) -> &[f32] {
        &self.theta
    }

    fn set_params(&mut self, theta: &[f32]) {
        assert_eq!(theta.len(), self.theta.len());
        self.theta.copy_from_slice(theta);
    }

    fn f(&self, t: f64, u: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        self.ensure_scratch();
        let mut s = self.scratch.borrow_mut();
        self.module.forward(self.batch, t, &self.theta, u, out, &mut s.cache);
    }

    fn vjp_u(&self, t: f64, u: &[f32], v: &[f32], out: &mut [f32]) {
        self.nfe.hit_backward();
        self.ensure_scratch();
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        let n = self.state_len();
        self.module.forward(self.batch, t, &self.theta, u, &mut s.y[..n], &mut s.cache);
        self.module.vjp(self.batch, t, &self.theta, v, out, None, &s.cache);
    }

    fn vjp_both(&self, t: f64, u: &[f32], v: &[f32], out_u: &mut [f32], grad_theta: &mut [f32]) {
        self.nfe.hit_backward();
        self.ensure_scratch();
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        let n = self.state_len();
        self.module.forward(self.batch, t, &self.theta, u, &mut s.y[..n], &mut s.cache);
        self.module.vjp(self.batch, t, &self.theta, v, out_u, Some(grad_theta), &s.cache);
    }

    fn jvp(&self, t: f64, u: &[f32], w: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        self.ensure_scratch();
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        let n = self.state_len();
        self.module.forward(self.batch, t, &self.theta, u, &mut s.y[..n], &mut s.cache);
        self.module.jvp(self.batch, t, &self.theta, w, out, &s.cache);
    }

    fn nfe(&self) -> Nfe {
        self.nfe.get()
    }

    fn reset_nfe(&self) {
        self.nfe.reset();
    }

    fn activation_bytes_per_eval(&self) -> u64 {
        // summed per-module accounting (what Table 2 consumes)
        self.module.activation_bytes(self.batch)
    }

    fn batch_rows(&self) -> usize {
        self.batch
    }

    fn make_shard(&self, rows: usize) -> Option<Box<dyn OdeRhs + Send>> {
        if rows == 0 {
            return None;
        }
        // every provided module is row-independent (per-sample loops and
        // per-row GEMM arithmetic), so a shard reproduces its rows of the
        // full-batch run bitwise
        Some(Box::new(ModuleRhs::from_arch(
            &self.arch,
            self.data_dim,
            rows,
            self.theta.clone(),
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::rhs::LinearRhs;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn mk_mlp(seed: u64) -> ModuleRhs {
        let dims = vec![5, 8, 4];
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
        ModuleRhs::mlp(dims, Act::Tanh, true, 3, theta)
    }

    fn arch_roster() -> Vec<ArchSpec> {
        vec![
            ArchSpec::Mlp { hidden: vec![7], act: Act::Tanh },
            ArchSpec::ConcatMlp { hidden: vec![6], act: Act::Gelu },
            ArchSpec::ConcatSquashMlp { hidden: vec![6, 5], act: Act::Tanh },
            ArchSpec::Residual(Box::new(ArchSpec::ConcatMlp { hidden: vec![5], act: Act::Tanh })),
            ArchSpec::Augment {
                extra: 2,
                inner: Box::new(ArchSpec::Mlp { hidden: vec![6], act: Act::Sigmoid }),
            },
        ]
    }

    #[test]
    fn mlp_rhs_duality_and_nfe() {
        prop::check("module-rhs-duality", 11, 10, |rng| {
            let rhs = mk_mlp(rng.next_u64());
            let n = rhs.state_len();
            let u = prop::vec_normal(rng, n);
            let w = prop::vec_normal(rng, n);
            let v = prop::vec_normal(rng, n);
            let mut jw = vec![0.0f32; n];
            rhs.jvp(0.3, &u, &w, &mut jw);
            let mut jtv = vec![0.0f32; n];
            rhs.vjp_u(0.3, &u, &v, &mut jtv);
            let lhs = crate::tensor::dot(&v, &jw);
            let rhsv = crate::tensor::dot(&jtv, &w);
            if (lhs - rhsv).abs() > 1e-4 * (1.0 + lhs.abs()) {
                return Err(format!("duality broken: {lhs} vs {rhsv}"));
            }
            Ok(())
        });
        let rhs = mk_mlp(1);
        rhs.reset_nfe();
        let u = vec![0.1f32; rhs.state_len()];
        let mut out = vec![0.0f32; rhs.state_len()];
        rhs.f(0.0, &u, &mut out);
        rhs.f(0.1, &u, &mut out);
        rhs.vjp_u(0.0, &u, &out.clone(), &mut out);
        assert_eq!(rhs.nfe(), Nfe { forward: 2, backward: 1 });
    }

    #[test]
    fn every_arch_satisfies_rhs_duality() {
        for arch in arch_roster() {
            prop::check(&format!("arch-rhs-duality-{}", arch.name()), 17, 5, |rng| {
                let theta = {
                    let mut t = prop::vec_normal(rng, arch.param_count(3));
                    for v in t.iter_mut() {
                        *v *= 0.5;
                    }
                    t
                };
                let rhs = ModuleRhs::from_arch(&arch, 3, 2, theta);
                let n = rhs.state_len();
                let u = prop::vec_normal(rng, n);
                let w = prop::vec_normal(rng, n);
                let v = prop::vec_normal(rng, n);
                let mut jw = vec![0.0f32; n];
                rhs.jvp(0.4, &u, &w, &mut jw);
                let mut jtv = vec![0.0f32; n];
                rhs.vjp_u(0.4, &u, &v, &mut jtv);
                let lhs = crate::tensor::dot(&v, &jw);
                let rhsv = crate::tensor::dot(&jtv, &w);
                if (lhs - rhsv).abs() > 1e-4 * (1.0 + lhs.abs()) {
                    return Err(format!("duality broken: {lhs} vs {rhsv}"));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn shards_reproduce_full_batch_rows_bitwise() {
        let rhs = mk_mlp(21); // batch 3, state_dim 4
        let d = rhs.state_dim();
        let b = rhs.batch_rows();
        assert_eq!(b, 3);
        let mut rng = Rng::new(22);
        let u = prop::vec_normal(&mut rng, rhs.state_len());
        let v = prop::vec_normal(&mut rng, rhs.state_len());
        let mut full_f = vec![0.0f32; rhs.state_len()];
        rhs.f(0.4, &u, &mut full_f);
        let mut full_vjp = vec![0.0f32; rhs.state_len()];
        rhs.vjp_u(0.4, &u, &v, &mut full_vjp);

        // single-row shards
        let one = rhs.make_shard(1).expect("ModuleRhs is shardable");
        assert_eq!(one.batch_rows(), 1);
        assert_eq!(one.param_len(), rhs.param_len());
        for r in 0..b {
            let mut out = vec![0.0f32; d];
            one.f(0.4, &u[r * d..(r + 1) * d], &mut out);
            assert_eq!(out, &full_f[r * d..(r + 1) * d], "f row {r} bitwise");
            let mut gv = vec![0.0f32; d];
            one.vjp_u(0.4, &u[r * d..(r + 1) * d], &v[r * d..(r + 1) * d], &mut gv);
            assert_eq!(gv, &full_vjp[r * d..(r + 1) * d], "vjp row {r} bitwise");
        }
        // a two-row shard over rows 0..2
        let two = rhs.make_shard(2).expect("shardable");
        let mut out = vec![0.0f32; 2 * d];
        two.f(0.4, &u[..2 * d], &mut out);
        assert_eq!(out, &full_f[..2 * d], "two-row shard bitwise");
        assert!(rhs.make_shard(0).is_none());
        // non-batched RHSs opt out
        assert!(LinearRhs::new(2, vec![0.0; 4]).make_shard(1).is_none());
    }

    #[test]
    fn concatsquash_shards_are_bitwise_too() {
        // the time-conditioned architecture the CNF task runs must hold
        // the same shard contract as the dense MLP
        let arch = ArchSpec::ConcatSquashMlp { hidden: vec![6], act: Act::Tanh };
        let mut rng = Rng::new(31);
        let theta = arch.init(&mut rng, 3);
        let rhs = ModuleRhs::from_arch(&arch, 3, 4, theta);
        let d = rhs.state_dim();
        let u = prop::vec_normal(&mut rng, rhs.state_len());
        let mut full = vec![0.0f32; rhs.state_len()];
        rhs.f(0.7, &u, &mut full);
        let one = rhs.make_shard(1).unwrap();
        for r in 0..rhs.batch_rows() {
            let mut out = vec![0.0f32; d];
            one.f(0.7, &u[r * d..(r + 1) * d], &mut out);
            assert_eq!(out, &full[r * d..(r + 1) * d], "row {r}");
        }
    }

    #[test]
    fn time_dependence_is_real() {
        let rhs = mk_mlp(5);
        let u = vec![0.3f32; rhs.state_len()];
        let mut a = vec![0.0f32; rhs.state_len()];
        let mut b = vec![0.0f32; rhs.state_len()];
        rhs.f(0.0, &u, &mut a);
        rhs.f(0.9, &u, &mut b);
        assert!(crate::tensor::max_abs_diff(&a, &b) > 1e-6);
    }

    #[test]
    fn augmented_arch_integrates_over_the_lifted_state() {
        let arch = ArchSpec::Augment {
            extra: 2,
            inner: Box::new(ArchSpec::Mlp { hidden: vec![6], act: Act::Tanh }),
        };
        let mut rng = Rng::new(41);
        let theta = arch.init(&mut rng, 3);
        let rhs = ModuleRhs::from_arch(&arch, 3, 2, theta);
        assert_eq!(rhs.state_dim(), 5, "3 data + 2 zero channels");
        assert_eq!(rhs.state_len(), 10);
    }
}
