//! The high-level AD primitive of the whole framework: the ODE right-hand
//! side `f(u, θ, t)` together with its derivative actions.
//!
//! Everything above this trait (integrators, adjoints, checkpointing,
//! gradient methods) is generic over [`OdeRhs`]; implementations:
//!
//! * [`crate::ode::rhs_xla::XlaRhs`] — the production path, executing the
//!   AOT-compiled Pallas/JAX artifacts through PJRT,
//! * [`crate::ode::ModuleRhs`] — the pure-Rust composable-module mirror
//!   (XLA-free tests + cross-checks), built from an
//!   [`crate::nn::module::ArchSpec`],
//! * [`LinearRhs`] — analytic `du/dt = A u` with exact Jacobians,
//! * [`RobertsonRhs`] — the true stiff chemistry of Section 5.3, used to
//!   generate ground-truth data and to exercise the implicit solvers.

use std::cell::Cell;

/// Forward/backward function-evaluation counters (NFE-F / NFE-B in the
/// paper's tables).  Forward = `f` and `jvp`; backward = `vjp_*`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Nfe {
    pub forward: u64,
    pub backward: u64,
}

/// The neural-ODE right-hand side and its derivative actions.
///
/// State vectors are flat `[B*D]` f32 slices; parameters a flat `[P]`
/// vector owned by the implementation.
pub trait OdeRhs {
    /// Flat state length (batch × state dim).
    fn state_len(&self) -> usize;
    /// Parameter count.
    fn param_len(&self) -> usize;
    fn params(&self) -> &[f32];
    fn set_params(&mut self, theta: &[f32]);

    /// out = f(u, θ, t)
    fn f(&self, t: f64, u: &[f32], out: &mut [f32]);

    /// out = (∂f/∂u)ᵀ v
    fn vjp_u(&self, t: f64, u: &[f32], v: &[f32], out: &mut [f32]);

    /// out_u = (∂f/∂u)ᵀ v ; grad_theta += (∂f/∂θ)ᵀ v
    fn vjp_both(&self, t: f64, u: &[f32], v: &[f32], out_u: &mut [f32], grad_theta: &mut [f32]);

    /// out = (∂f/∂u) w
    fn jvp(&self, t: f64, u: &[f32], w: &[f32], out: &mut [f32]);

    fn nfe(&self) -> Nfe;
    fn reset_nfe(&self);

    /// Bytes of intermediate activations one `f` evaluation materialises
    /// (feeds the Table-2 memory model; 0 for analytic RHSs).
    fn activation_bytes_per_eval(&self) -> u64 {
        0
    }

    /// Independent batch rows in the state (`state_len() / batch_rows()`
    /// entries per row); 1 when the state is a single coupled system.
    fn batch_rows(&self) -> usize {
        1
    }

    /// Build an independent RHS of the same model over `rows` batch rows,
    /// carrying a copy of the current parameters — `None` when the RHS is
    /// not row-shardable.  Contract for `Some`: rows evolve independently
    /// under `f`/`vjp`/`jvp` with identical per-row arithmetic at any
    /// batch size, so integrating a shard reproduces the corresponding
    /// rows of the full-batch run bitwise.  This is the basis of the
    /// data-parallel execution engine (`crate::exec`).
    fn make_shard(&self, rows: usize) -> Option<Box<dyn OdeRhs + Send>> {
        let _ = rows;
        None
    }
}

/// Shared counter plumbing for implementations.
#[derive(Clone, Debug, Default)]
pub struct NfeCounter {
    forward: Cell<u64>,
    backward: Cell<u64>,
}

impl NfeCounter {
    pub fn hit_forward(&self) {
        self.forward.set(self.forward.get() + 1);
    }

    pub fn hit_backward(&self) {
        self.backward.set(self.backward.get() + 1);
    }

    pub fn get(&self) -> Nfe {
        Nfe { forward: self.forward.get(), backward: self.backward.get() }
    }

    pub fn reset(&self) {
        self.forward.set(0);
        self.backward.set(0);
    }
}

// ---------------------------------------------------------------------------
// LinearRhs: du/dt = A u (A trainable)
// ---------------------------------------------------------------------------

/// `du/dt = A u` with `θ = vec(A)` — exact Jacobians, ideal for gradient
/// checks: ∂f/∂u = A, (∂f/∂θ)ᵀv accumulates v uᵀ.
pub struct LinearRhs {
    pub d: usize,
    a: Vec<f32>, // [d, d] row-major
    nfe: NfeCounter,
}

impl LinearRhs {
    pub fn new(d: usize, a: Vec<f32>) -> Self {
        assert_eq!(a.len(), d * d);
        LinearRhs { d, a, nfe: NfeCounter::default() }
    }
}

impl OdeRhs for LinearRhs {
    fn state_len(&self) -> usize {
        self.d
    }

    fn param_len(&self) -> usize {
        self.d * self.d
    }

    fn params(&self) -> &[f32] {
        &self.a
    }

    fn set_params(&mut self, theta: &[f32]) {
        self.a.copy_from_slice(theta);
    }

    fn f(&self, _t: f64, u: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        for i in 0..self.d {
            let mut acc = 0.0f32;
            for j in 0..self.d {
                acc += self.a[i * self.d + j] * u[j];
            }
            out[i] = acc;
        }
    }

    fn vjp_u(&self, _t: f64, _u: &[f32], v: &[f32], out: &mut [f32]) {
        self.nfe.hit_backward();
        // Aᵀ v
        for j in 0..self.d {
            let mut acc = 0.0f32;
            for i in 0..self.d {
                acc += self.a[i * self.d + j] * v[i];
            }
            out[j] = acc;
        }
    }

    fn vjp_both(&self, t: f64, u: &[f32], v: &[f32], out_u: &mut [f32], grad_theta: &mut [f32]) {
        self.vjp_u(t, u, v, out_u);
        // ∂f_i/∂A_ij = u_j  =>  gA_ij += v_i u_j
        for i in 0..self.d {
            for j in 0..self.d {
                grad_theta[i * self.d + j] += v[i] * u[j];
            }
        }
    }

    fn jvp(&self, t: f64, _u: &[f32], w: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        // A w — same as f with w
        let saved = self.nfe.get();
        self.f(t, w, out);
        // f() already counted; undo double-count of this jvp
        self.nfe.forward.set(saved.forward);
    }

    fn nfe(&self) -> Nfe {
        self.nfe.get()
    }

    fn reset_nfe(&self) {
        self.nfe.reset();
    }
}

// ---------------------------------------------------------------------------
// RobertsonRhs: the true stiff chemistry (data generation / implicit tests)
// ---------------------------------------------------------------------------

/// Robertson's equations (paper eq. 14):
///   u1' = -k1 u1 + k3 u2 u3
///   u2' =  k1 u1 - k2 u2² - k3 u2 u3
///   u3' =  k2 u2²
/// Stiff with k1 = 0.04, k2 = 3e7, k3 = 1e4.  Not trainable (param_len 0).
pub struct RobertsonRhs {
    pub k1: f64,
    pub k2: f64,
    pub k3: f64,
    nfe: NfeCounter,
}

impl Default for RobertsonRhs {
    fn default() -> Self {
        RobertsonRhs { k1: 0.04, k2: 3e7, k3: 1e4, nfe: NfeCounter::default() }
    }
}

impl RobertsonRhs {
    /// 3×3 Jacobian at u.
    pub fn jacobian(&self, u: &[f32]) -> [[f64; 3]; 3] {
        let (k1, k2, k3) = (self.k1, self.k2, self.k3);
        let (u2, u3) = (u[1] as f64, u[2] as f64);
        [
            [-k1, k3 * u3, k3 * u2],
            [k1, -2.0 * k2 * u2 - k3 * u3, -k3 * u2],
            [0.0, 2.0 * k2 * u2, 0.0],
        ]
    }
}

impl OdeRhs for RobertsonRhs {
    fn state_len(&self) -> usize {
        3
    }

    fn param_len(&self) -> usize {
        0
    }

    fn params(&self) -> &[f32] {
        &[]
    }

    fn set_params(&mut self, _theta: &[f32]) {}

    fn f(&self, _t: f64, u: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        let (u1, u2, u3) = (u[0] as f64, u[1] as f64, u[2] as f64);
        out[0] = (-self.k1 * u1 + self.k3 * u2 * u3) as f32;
        out[1] = (self.k1 * u1 - self.k2 * u2 * u2 - self.k3 * u2 * u3) as f32;
        out[2] = (self.k2 * u2 * u2) as f32;
    }

    fn vjp_u(&self, _t: f64, u: &[f32], v: &[f32], out: &mut [f32]) {
        self.nfe.hit_backward();
        let j = self.jacobian(u);
        for col in 0..3 {
            out[col] =
                (j[0][col] * v[0] as f64 + j[1][col] * v[1] as f64 + j[2][col] * v[2] as f64)
                    as f32;
        }
    }

    fn vjp_both(&self, t: f64, u: &[f32], v: &[f32], out_u: &mut [f32], _gt: &mut [f32]) {
        self.vjp_u(t, u, v, out_u);
    }

    fn jvp(&self, _t: f64, u: &[f32], w: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        let j = self.jacobian(u);
        for row in 0..3 {
            out[row] =
                (j[row][0] * w[0] as f64 + j[row][1] * w[1] as f64 + j[row][2] * w[2] as f64)
                    as f32;
        }
    }

    fn nfe(&self) -> Nfe {
        self.nfe.get()
    }

    fn reset_nfe(&self) {
        self.nfe.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_rhs_exact() {
        let a = vec![0.0, 1.0, -1.0, 0.0]; // rotation generator
        let rhs = LinearRhs::new(2, a);
        let mut out = [0.0f32; 2];
        rhs.f(0.0, &[1.0, 0.0], &mut out);
        assert_eq!(out, [0.0, -1.0]);
        let mut vj = [0.0f32; 2];
        rhs.vjp_u(0.0, &[1.0, 0.0], &[1.0, 0.0], &mut vj);
        assert_eq!(vj, [0.0, 1.0]); // Aᵀ e1
    }

    #[test]
    fn robertson_mass_conservation() {
        // u1' + u2' + u3' = 0
        let rhs = RobertsonRhs::default();
        let u = [0.7f32, 1e-5, 0.3];
        let mut du = [0.0f32; 3];
        rhs.f(0.0, &u, &mut du);
        let s = du[0] as f64 + du[1] as f64 + du[2] as f64;
        assert!(s.abs() < 1e-4, "{s}");
    }

    #[test]
    fn robertson_jacobian_matches_fd() {
        let rhs = RobertsonRhs::default();
        let u = [0.9f32, 2e-5, 0.1];
        let j = rhs.jacobian(&u);
        let h = 1e-6f32;
        for col in 0..3 {
            let mut up = u;
            up[col] += h;
            let mut um = u;
            um[col] -= h;
            let mut fp = [0.0f32; 3];
            let mut fm = [0.0f32; 3];
            rhs.f(0.0, &up, &mut fp);
            rhs.f(0.0, &um, &mut fm);
            for row in 0..3 {
                let fd = (fp[row] as f64 - fm[row] as f64) / (2.0 * h as f64);
                let rel = (fd - j[row][col]).abs() / (1.0 + j[row][col].abs());
                assert!(rel < 2e-2, "J[{row}][{col}] {} vs fd {fd}", j[row][col]);
            }
        }
    }

}
