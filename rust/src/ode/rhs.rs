//! The high-level AD primitive of the whole framework: the ODE right-hand
//! side `f(u, θ, t)` together with its derivative actions.
//!
//! Everything above this trait (integrators, adjoints, checkpointing,
//! gradient methods) is generic over [`OdeRhs`]; implementations:
//!
//! * [`crate::ode::rhs_xla::XlaRhs`] — the production path, executing the
//!   AOT-compiled Pallas/JAX artifacts through PJRT,
//! * [`MlpRhs`] — the pure-Rust mirror (XLA-free tests + cross-checks),
//! * [`LinearRhs`] — analytic `du/dt = A u` with exact Jacobians,
//! * [`RobertsonRhs`] — the true stiff chemistry of Section 5.3, used to
//!   generate ground-truth data and to exercise the implicit solvers.

use std::cell::Cell;

use crate::nn::{Act, Mlp};

/// Forward/backward function-evaluation counters (NFE-F / NFE-B in the
/// paper's tables).  Forward = `f` and `jvp`; backward = `vjp_*`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Nfe {
    pub forward: u64,
    pub backward: u64,
}

/// The neural-ODE right-hand side and its derivative actions.
///
/// State vectors are flat `[B*D]` f32 slices; parameters a flat `[P]`
/// vector owned by the implementation.
pub trait OdeRhs {
    /// Flat state length (batch × state dim).
    fn state_len(&self) -> usize;
    /// Parameter count.
    fn param_len(&self) -> usize;
    fn params(&self) -> &[f32];
    fn set_params(&mut self, theta: &[f32]);

    /// out = f(u, θ, t)
    fn f(&self, t: f64, u: &[f32], out: &mut [f32]);

    /// out = (∂f/∂u)ᵀ v
    fn vjp_u(&self, t: f64, u: &[f32], v: &[f32], out: &mut [f32]);

    /// out_u = (∂f/∂u)ᵀ v ; grad_theta += (∂f/∂θ)ᵀ v
    fn vjp_both(&self, t: f64, u: &[f32], v: &[f32], out_u: &mut [f32], grad_theta: &mut [f32]);

    /// out = (∂f/∂u) w
    fn jvp(&self, t: f64, u: &[f32], w: &[f32], out: &mut [f32]);

    fn nfe(&self) -> Nfe;
    fn reset_nfe(&self);

    /// Bytes of intermediate activations one `f` evaluation materialises
    /// (feeds the Table-2 memory model; 0 for analytic RHSs).
    fn activation_bytes_per_eval(&self) -> u64 {
        0
    }

    /// Independent batch rows in the state (`state_len() / batch_rows()`
    /// entries per row); 1 when the state is a single coupled system.
    fn batch_rows(&self) -> usize {
        1
    }

    /// Build an independent RHS of the same model over `rows` batch rows,
    /// carrying a copy of the current parameters — `None` when the RHS is
    /// not row-shardable.  Contract for `Some`: rows evolve independently
    /// under `f`/`vjp`/`jvp` with identical per-row arithmetic at any
    /// batch size, so integrating a shard reproduces the corresponding
    /// rows of the full-batch run bitwise.  This is the basis of the
    /// data-parallel execution engine (`crate::exec`).
    fn make_shard(&self, rows: usize) -> Option<Box<dyn OdeRhs + Send>> {
        let _ = rows;
        None
    }
}

/// Shared counter plumbing for implementations.
#[derive(Clone, Debug, Default)]
pub struct NfeCounter {
    forward: Cell<u64>,
    backward: Cell<u64>,
}

impl NfeCounter {
    pub fn hit_forward(&self) {
        self.forward.set(self.forward.get() + 1);
    }

    pub fn hit_backward(&self) {
        self.backward.set(self.backward.get() + 1);
    }

    pub fn get(&self) -> Nfe {
        Nfe { forward: self.forward.get(), backward: self.backward.get() }
    }

    pub fn reset(&self) {
        self.forward.set(0);
        self.backward.set(0);
    }
}

// ---------------------------------------------------------------------------
// LinearRhs: du/dt = A u (A trainable)
// ---------------------------------------------------------------------------

/// `du/dt = A u` with `θ = vec(A)` — exact Jacobians, ideal for gradient
/// checks: ∂f/∂u = A, (∂f/∂θ)ᵀv accumulates v uᵀ.
pub struct LinearRhs {
    pub d: usize,
    a: Vec<f32>, // [d, d] row-major
    nfe: NfeCounter,
}

impl LinearRhs {
    pub fn new(d: usize, a: Vec<f32>) -> Self {
        assert_eq!(a.len(), d * d);
        LinearRhs { d, a, nfe: NfeCounter::default() }
    }
}

impl OdeRhs for LinearRhs {
    fn state_len(&self) -> usize {
        self.d
    }

    fn param_len(&self) -> usize {
        self.d * self.d
    }

    fn params(&self) -> &[f32] {
        &self.a
    }

    fn set_params(&mut self, theta: &[f32]) {
        self.a.copy_from_slice(theta);
    }

    fn f(&self, _t: f64, u: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        for i in 0..self.d {
            let mut acc = 0.0f32;
            for j in 0..self.d {
                acc += self.a[i * self.d + j] * u[j];
            }
            out[i] = acc;
        }
    }

    fn vjp_u(&self, _t: f64, _u: &[f32], v: &[f32], out: &mut [f32]) {
        self.nfe.hit_backward();
        // Aᵀ v
        for j in 0..self.d {
            let mut acc = 0.0f32;
            for i in 0..self.d {
                acc += self.a[i * self.d + j] * v[i];
            }
            out[j] = acc;
        }
    }

    fn vjp_both(&self, t: f64, u: &[f32], v: &[f32], out_u: &mut [f32], grad_theta: &mut [f32]) {
        self.vjp_u(t, u, v, out_u);
        // ∂f_i/∂A_ij = u_j  =>  gA_ij += v_i u_j
        for i in 0..self.d {
            for j in 0..self.d {
                grad_theta[i * self.d + j] += v[i] * u[j];
            }
        }
    }

    fn jvp(&self, t: f64, _u: &[f32], w: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        // A w — same as f with w
        let saved = self.nfe.get();
        self.f(t, w, out);
        // f() already counted; undo double-count of this jvp
        self.nfe.forward.set(saved.forward);
    }

    fn nfe(&self) -> Nfe {
        self.nfe.get()
    }

    fn reset_nfe(&self) {
        self.nfe.reset();
    }
}

// ---------------------------------------------------------------------------
// RobertsonRhs: the true stiff chemistry (data generation / implicit tests)
// ---------------------------------------------------------------------------

/// Robertson's equations (paper eq. 14):
///   u1' = -k1 u1 + k3 u2 u3
///   u2' =  k1 u1 - k2 u2² - k3 u2 u3
///   u3' =  k2 u2²
/// Stiff with k1 = 0.04, k2 = 3e7, k3 = 1e4.  Not trainable (param_len 0).
pub struct RobertsonRhs {
    pub k1: f64,
    pub k2: f64,
    pub k3: f64,
    nfe: NfeCounter,
}

impl Default for RobertsonRhs {
    fn default() -> Self {
        RobertsonRhs { k1: 0.04, k2: 3e7, k3: 1e4, nfe: NfeCounter::default() }
    }
}

impl RobertsonRhs {
    /// 3×3 Jacobian at u.
    pub fn jacobian(&self, u: &[f32]) -> [[f64; 3]; 3] {
        let (k1, k2, k3) = (self.k1, self.k2, self.k3);
        let (u2, u3) = (u[1] as f64, u[2] as f64);
        [
            [-k1, k3 * u3, k3 * u2],
            [k1, -2.0 * k2 * u2 - k3 * u3, -k3 * u2],
            [0.0, 2.0 * k2 * u2, 0.0],
        ]
    }
}

impl OdeRhs for RobertsonRhs {
    fn state_len(&self) -> usize {
        3
    }

    fn param_len(&self) -> usize {
        0
    }

    fn params(&self) -> &[f32] {
        &[]
    }

    fn set_params(&mut self, _theta: &[f32]) {}

    fn f(&self, _t: f64, u: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        let (u1, u2, u3) = (u[0] as f64, u[1] as f64, u[2] as f64);
        out[0] = (-self.k1 * u1 + self.k3 * u2 * u3) as f32;
        out[1] = (self.k1 * u1 - self.k2 * u2 * u2 - self.k3 * u2 * u3) as f32;
        out[2] = (self.k2 * u2 * u2) as f32;
    }

    fn vjp_u(&self, _t: f64, u: &[f32], v: &[f32], out: &mut [f32]) {
        self.nfe.hit_backward();
        let j = self.jacobian(u);
        for col in 0..3 {
            out[col] =
                (j[0][col] * v[0] as f64 + j[1][col] * v[1] as f64 + j[2][col] * v[2] as f64)
                    as f32;
        }
    }

    fn vjp_both(&self, t: f64, u: &[f32], v: &[f32], out_u: &mut [f32], _gt: &mut [f32]) {
        self.vjp_u(t, u, v, out_u);
    }

    fn jvp(&self, _t: f64, u: &[f32], w: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        let j = self.jacobian(u);
        for row in 0..3 {
            out[row] =
                (j[row][0] * w[0] as f64 + j[row][1] * w[1] as f64 + j[row][2] * w[2] as f64)
                    as f32;
        }
    }

    fn nfe(&self) -> Nfe {
        self.nfe.get()
    }

    fn reset_nfe(&self) {
        self.nfe.reset();
    }
}

// ---------------------------------------------------------------------------
// MlpRhs: pure-Rust neural RHS (mirror of the XLA artifacts)
// ---------------------------------------------------------------------------

/// Neural RHS backed by the pure-Rust [`Mlp`].
///
/// If `time_dep`, the MLP input is `concat([u, t])` per sample (matching
/// `model.py::_augment_time`); gradients wrt the appended `t` column are
/// dropped.
pub struct MlpRhs {
    mlp: Mlp,
    pub batch: usize,
    pub state_dim: usize,
    pub time_dep: bool,
    nfe: NfeCounter,
}

impl MlpRhs {
    pub fn new(dims: Vec<usize>, act: Act, time_dep: bool, batch: usize, theta: Vec<f32>) -> Self {
        let state_dim = *dims.last().unwrap();
        let expect_in = if time_dep { state_dim + 1 } else { state_dim };
        assert_eq!(dims[0], expect_in, "in dim mismatch for time_dep={time_dep}");
        MlpRhs {
            mlp: Mlp::new(dims, act, theta),
            batch,
            state_dim,
            time_dep,
            nfe: NfeCounter::default(),
        }
    }

    fn augment(&self, t: f64, u: &[f32]) -> Vec<f32> {
        if !self.time_dep {
            return u.to_vec();
        }
        let d = self.state_dim;
        let mut x = vec![0.0f32; self.batch * (d + 1)];
        for r in 0..self.batch {
            x[r * (d + 1)..r * (d + 1) + d].copy_from_slice(&u[r * d..(r + 1) * d]);
            x[r * (d + 1) + d] = t as f32;
        }
        x
    }

    fn strip(&self, gx: &[f32], out: &mut [f32]) {
        if !self.time_dep {
            out.copy_from_slice(gx);
            return;
        }
        let d = self.state_dim;
        for r in 0..self.batch {
            out[r * d..(r + 1) * d].copy_from_slice(&gx[r * (d + 1)..r * (d + 1) + d]);
        }
    }
}

impl OdeRhs for MlpRhs {
    fn state_len(&self) -> usize {
        self.batch * self.state_dim
    }

    fn param_len(&self) -> usize {
        self.mlp.params().len()
    }

    fn params(&self) -> &[f32] {
        self.mlp.params()
    }

    fn set_params(&mut self, theta: &[f32]) {
        self.mlp.set_params(theta);
    }

    fn f(&self, t: f64, u: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        let x = self.augment(t, u);
        let mut y = Vec::new();
        self.mlp.forward(self.batch, &x, &mut y);
        out.copy_from_slice(&y);
    }

    fn vjp_u(&self, t: f64, u: &[f32], v: &[f32], out: &mut [f32]) {
        self.nfe.hit_backward();
        let x = self.augment(t, u);
        let mut gx = Vec::new();
        self.mlp.vjp(self.batch, &x, v, &mut gx, None);
        self.strip(&gx, out);
    }

    fn vjp_both(&self, t: f64, u: &[f32], v: &[f32], out_u: &mut [f32], grad_theta: &mut [f32]) {
        self.nfe.hit_backward();
        let x = self.augment(t, u);
        let mut gx = Vec::new();
        self.mlp.vjp(self.batch, &x, v, &mut gx, Some(grad_theta));
        self.strip(&gx, out_u);
    }

    fn jvp(&self, t: f64, u: &[f32], w: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        let x = self.augment(t, u);
        // tangent of the augmented input: dt column is 0
        let dx = if self.time_dep {
            let d = self.state_dim;
            let mut dx = vec![0.0f32; self.batch * (d + 1)];
            for r in 0..self.batch {
                dx[r * (d + 1)..r * (d + 1) + d].copy_from_slice(&w[r * d..(r + 1) * d]);
            }
            dx
        } else {
            w.to_vec()
        };
        let mut dy = Vec::new();
        self.mlp.jvp(self.batch, &x, &dx, &mut dy);
        out.copy_from_slice(&dy);
    }

    fn nfe(&self) -> Nfe {
        self.nfe.get()
    }

    fn reset_nfe(&self) {
        self.nfe.reset();
    }

    fn activation_bytes_per_eval(&self) -> u64 {
        self.mlp.activation_bytes(self.batch)
    }

    fn batch_rows(&self) -> usize {
        self.batch
    }

    fn make_shard(&self, rows: usize) -> Option<Box<dyn OdeRhs + Send>> {
        if rows == 0 {
            return None;
        }
        // per-row arithmetic is batch-size independent (each GEMM output
        // row reads only its own input row), so a shard reproduces its
        // rows of the full-batch run bitwise
        Some(Box::new(MlpRhs::new(
            self.mlp.dims.clone(),
            self.mlp.act,
            self.time_dep,
            rows,
            self.mlp.params().to_vec(),
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn mk_mlp(seed: u64) -> MlpRhs {
        let dims = vec![5, 8, 4];
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
        MlpRhs::new(dims, Act::Tanh, true, 3, theta)
    }

    #[test]
    fn linear_rhs_exact() {
        let a = vec![0.0, 1.0, -1.0, 0.0]; // rotation generator
        let rhs = LinearRhs::new(2, a);
        let mut out = [0.0f32; 2];
        rhs.f(0.0, &[1.0, 0.0], &mut out);
        assert_eq!(out, [0.0, -1.0]);
        let mut vj = [0.0f32; 2];
        rhs.vjp_u(0.0, &[1.0, 0.0], &[1.0, 0.0], &mut vj);
        assert_eq!(vj, [0.0, 1.0]); // Aᵀ e1
    }

    #[test]
    fn robertson_mass_conservation() {
        // u1' + u2' + u3' = 0
        let rhs = RobertsonRhs::default();
        let u = [0.7f32, 1e-5, 0.3];
        let mut du = [0.0f32; 3];
        rhs.f(0.0, &u, &mut du);
        let s = du[0] as f64 + du[1] as f64 + du[2] as f64;
        assert!(s.abs() < 1e-4, "{s}");
    }

    #[test]
    fn robertson_jacobian_matches_fd() {
        let rhs = RobertsonRhs::default();
        let u = [0.9f32, 2e-5, 0.1];
        let j = rhs.jacobian(&u);
        let h = 1e-6f32;
        for col in 0..3 {
            let mut up = u;
            up[col] += h;
            let mut um = u;
            um[col] -= h;
            let mut fp = [0.0f32; 3];
            let mut fm = [0.0f32; 3];
            rhs.f(0.0, &up, &mut fp);
            rhs.f(0.0, &um, &mut fm);
            for row in 0..3 {
                let fd = (fp[row] as f64 - fm[row] as f64) / (2.0 * h as f64);
                let rel = (fd - j[row][col]).abs() / (1.0 + j[row][col].abs());
                assert!(rel < 2e-2, "J[{row}][{col}] {} vs fd {fd}", j[row][col]);
            }
        }
    }

    #[test]
    fn mlp_rhs_duality_and_nfe() {
        prop::check("mlp-rhs-duality", 11, 10, |rng| {
            let rhs = mk_mlp(rng.next_u64());
            let n = rhs.state_len();
            let u = prop::vec_normal(rng, n);
            let w = prop::vec_normal(rng, n);
            let v = prop::vec_normal(rng, n);
            let mut jw = vec![0.0f32; n];
            rhs.jvp(0.3, &u, &w, &mut jw);
            let mut jtv = vec![0.0f32; n];
            rhs.vjp_u(0.3, &u, &v, &mut jtv);
            let lhs = crate::tensor::dot(&v, &jw);
            let rhsv = crate::tensor::dot(&jtv, &w);
            if (lhs - rhsv).abs() > 1e-4 * (1.0 + lhs.abs()) {
                return Err(format!("duality broken: {lhs} vs {rhsv}"));
            }
            Ok(())
        });
        let rhs = mk_mlp(1);
        rhs.reset_nfe();
        let u = vec![0.1f32; rhs.state_len()];
        let mut out = vec![0.0f32; rhs.state_len()];
        rhs.f(0.0, &u, &mut out);
        rhs.f(0.1, &u, &mut out);
        rhs.vjp_u(0.0, &u, &out.clone(), &mut out);
        assert_eq!(rhs.nfe(), Nfe { forward: 2, backward: 1 });
    }

    #[test]
    fn shards_reproduce_full_batch_rows_bitwise() {
        let rhs = mk_mlp(21); // batch 3, state_dim 4
        let d = rhs.state_dim;
        let b = rhs.batch_rows();
        assert_eq!(b, 3);
        let mut rng = Rng::new(22);
        let u = prop::vec_normal(&mut rng, rhs.state_len());
        let v = prop::vec_normal(&mut rng, rhs.state_len());
        let mut full_f = vec![0.0f32; rhs.state_len()];
        rhs.f(0.4, &u, &mut full_f);
        let mut full_vjp = vec![0.0f32; rhs.state_len()];
        rhs.vjp_u(0.4, &u, &v, &mut full_vjp);

        // single-row shards
        let one = rhs.make_shard(1).expect("MlpRhs is shardable");
        assert_eq!(one.batch_rows(), 1);
        assert_eq!(one.param_len(), rhs.param_len());
        for r in 0..b {
            let mut out = vec![0.0f32; d];
            one.f(0.4, &u[r * d..(r + 1) * d], &mut out);
            assert_eq!(out, &full_f[r * d..(r + 1) * d], "f row {r} bitwise");
            let mut gv = vec![0.0f32; d];
            one.vjp_u(0.4, &u[r * d..(r + 1) * d], &v[r * d..(r + 1) * d], &mut gv);
            assert_eq!(gv, &full_vjp[r * d..(r + 1) * d], "vjp row {r} bitwise");
        }
        // a two-row shard over rows 0..2
        let two = rhs.make_shard(2).expect("shardable");
        let mut out = vec![0.0f32; 2 * d];
        two.f(0.4, &u[..2 * d], &mut out);
        assert_eq!(out, &full_f[..2 * d], "two-row shard bitwise");
        assert!(rhs.make_shard(0).is_none());
        // non-batched RHSs opt out
        assert!(LinearRhs::new(2, vec![0.0; 4]).make_shard(1).is_none());
    }

    #[test]
    fn time_dependence_is_real() {
        let rhs = mk_mlp(5);
        let u = vec![0.3f32; rhs.state_len()];
        let mut a = vec![0.0f32; rhs.state_len()];
        let mut b = vec![0.0f32; rhs.state_len()];
        rhs.f(0.0, &u, &mut a);
        rhs.f(0.9, &u, &mut b);
        assert!(crate::tensor::max_abs_diff(&a, &b) > 1e-6);
    }
}
