//! Implicit theta-method stepping (backward Euler θ=1, Crank–Nicolson θ=½)
//! with Jacobian-free Newton–GMRES — the capability the paper argues only
//! PNODE's high-level adjoint can support (§3.3).
//!
//! Step equation:  u_{n+1} = u_n + h [ (1-θ) f(t_n, u_n) + θ f(t_{n+1}, u_{n+1}) ]
//! Newton residual: R(x) = x - u_n - h (1-θ) f_n - h θ f(t_{n+1}, x)
//! Jacobian action: (∂R/∂x) w = w - h θ (∂f/∂u)(x) w   — via the JVP
//! primitive, so the nonlinear solver never builds a matrix and never
//! enters any AD graph (the paper's key point for memory).

use crate::linalg::newton::{newton_solve, NewtonOptions, NewtonResult};
use crate::ode::rhs::OdeRhs;
use crate::tensor;

/// θ-scheme parameters.
#[derive(Clone, Copy, Debug)]
pub struct ThetaScheme {
    /// implicit weight θ ∈ (0, 1]
    pub theta: f64,
    pub name: &'static str,
    pub order: usize,
}

impl ThetaScheme {
    pub fn backward_euler() -> Self {
        ThetaScheme { theta: 1.0, name: "beuler", order: 1 }
    }

    pub fn crank_nicolson() -> Self {
        ThetaScheme { theta: 0.5, name: "cn", order: 2 }
    }
}

/// Record of one implicit step (what the adjoint needs).
#[derive(Clone, Debug)]
pub struct ImplicitStepRecord {
    pub newton: NewtonResult,
}

/// Implicit stepper with reusable workspace.
pub struct ImplicitStepper {
    pub scheme: ThetaScheme,
    pub newton_opts: NewtonOptions,
    f_n: Vec<f32>,
    f_x: Vec<f32>,
}

impl ImplicitStepper {
    pub fn new(scheme: ThetaScheme, n: usize) -> Self {
        ImplicitStepper {
            scheme,
            newton_opts: NewtonOptions::default(),
            f_n: vec![0.0; n],
            f_x: vec![0.0; n],
        }
    }

    /// One step: fills `u_next` (also the Newton iterate); returns the
    /// Newton statistics.
    pub fn step(
        &mut self,
        rhs: &dyn OdeRhs,
        t: f64,
        h: f64,
        u: &[f32],
        u_next: &mut [f32],
    ) -> ImplicitStepRecord {
        let theta = self.scheme.theta;
        let n = u.len();
        // explicit part: rhs_const = u_n + h(1-θ) f(t_n, u_n)
        let mut rhs_const = u.to_vec();
        if theta < 1.0 {
            rhs.f(t, u, &mut self.f_n);
            tensor::axpy((h * (1.0 - theta)) as f32, &self.f_n, &mut rhs_const);
        }
        // predictor: forward Euler
        if theta >= 1.0 {
            rhs.f(t, u, &mut self.f_n);
        }
        u_next.copy_from_slice(u);
        tensor::axpy(h as f32, &self.f_n, u_next);

        let t1 = t + h;
        let f_x = &mut self.f_x;
        let newton = {
            let residual = |x: &[f32], out: &mut [f32]| {
                rhs.f(t1, x, f_x);
                for i in 0..n {
                    out[i] = x[i] - rhs_const[i] - (h * theta) as f32 * f_x[i];
                }
            };
            let mut jw = vec![0.0f32; n];
            let jacobian = |x: &[f32], w: &[f32], out: &mut [f32]| {
                rhs.jvp(t1, x, w, &mut jw);
                for i in 0..n {
                    out[i] = w[i] - (h * theta) as f32 * jw[i];
                }
            };
            newton_solve(residual, jacobian, u_next, &self.newton_opts)
        };
        ImplicitStepRecord { newton }
    }
}

/// Fixed-step implicit integration; `sink(step, t, h, u_n, u_{n+1})` fires
/// after each step.
pub fn integrate_implicit<F>(
    scheme: ThetaScheme,
    rhs: &dyn OdeRhs,
    t0: f64,
    tf: f64,
    nt: usize,
    u0: &[f32],
    mut sink: F,
) -> Vec<f32>
where
    F: FnMut(usize, f64, f64, &[f32], &[f32]),
{
    let n = u0.len();
    let h = (tf - t0) / nt as f64;
    let mut stepper = ImplicitStepper::new(scheme, n);
    let mut u = u0.to_vec();
    let mut u_next = vec![0.0f32; n];
    for step in 0..nt {
        let t = t0 + step as f64 * h;
        stepper.step(rhs, t, h, &u, &mut u_next);
        sink(step, t, h, &u, &u_next);
        std::mem::swap(&mut u, &mut u_next);
    }
    u
}

/// Implicit integration over a *non-uniform* grid `ts` (used by the stiff
/// task: log-spaced observation times).
pub fn integrate_implicit_grid<F>(
    scheme: ThetaScheme,
    rhs: &dyn OdeRhs,
    ts: &[f64],
    u0: &[f32],
    mut sink: F,
) -> Vec<f32>
where
    F: FnMut(usize, f64, f64, &[f32], &[f32]),
{
    let n = u0.len();
    let mut stepper = ImplicitStepper::new(scheme, n);
    let mut u = u0.to_vec();
    let mut u_next = vec![0.0f32; n];
    for step in 0..ts.len() - 1 {
        let t = ts[step];
        let h = ts[step + 1] - ts[step];
        stepper.step(rhs, t, h, &u, &mut u_next);
        sink(step, t, h, &u, &u_next);
        std::mem::swap(&mut u, &mut u_next);
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::rhs::{LinearRhs, RobertsonRhs};

    #[test]
    fn backward_euler_is_first_order() {
        let rhs = LinearRhs::new(1, vec![-1.0]);
        let exact = (-1.0f64).exp() as f32;
        let run = |nt| {
            let u = integrate_implicit(
                ThetaScheme::backward_euler(),
                &rhs,
                0.0,
                1.0,
                nt,
                &[1.0],
                |_, _, _, _, _| {},
            );
            (u[0] - exact).abs() as f64
        };
        let (e1, e2) = (run(20), run(40));
        let rate = (e1 / e2).log2();
        assert!(rate > 0.8 && rate < 1.3, "rate {rate}");
    }

    #[test]
    fn crank_nicolson_is_second_order() {
        let rhs = LinearRhs::new(2, vec![0.0, 1.0, -1.0, 0.0]);
        let exact = [1.0f64.cos() as f32, -(1.0f64.sin()) as f32];
        let run = |nt| {
            let u = integrate_implicit(
                ThetaScheme::crank_nicolson(),
                &rhs,
                0.0,
                1.0,
                nt,
                &[1.0, 0.0],
                |_, _, _, _, _| {},
            );
            crate::testing::rel_l2(&u, &exact)
        };
        let (e1, e2) = (run(20), run(40));
        let rate = (e1 / e2).log2();
        assert!(rate > 1.8, "rate {rate} (e1 {e1:.2e}, e2 {e2:.2e})");
    }

    #[test]
    fn unconditional_stability_on_stiff_decay() {
        // du/dt = -1000 u with h = 0.1 (λh = -100): explicit Euler explodes,
        // BE stays bounded and positive.
        let rhs = LinearRhs::new(1, vec![-1000.0]);
        let u = integrate_implicit(
            ThetaScheme::backward_euler(),
            &rhs,
            0.0,
            1.0,
            10,
            &[1.0],
            |_, _, _, _, _| {},
        );
        assert!(u[0] >= 0.0 && u[0] < 1e-3, "{}", u[0]);
    }

    #[test]
    fn robertson_short_integration_conserves_mass() {
        let rhs = RobertsonRhs::default();
        let u = integrate_implicit_grid(
            ThetaScheme::crank_nicolson(),
            &rhs,
            &[0.0, 1e-4, 1e-3, 1e-2, 0.1, 1.0],
            &[1.0, 0.0, 0.0],
            |_, _, _, _, _| {},
        );
        let mass = u[0] as f64 + u[1] as f64 + u[2] as f64;
        assert!((mass - 1.0).abs() < 1e-4, "mass {mass}");
        assert!(u[0] < 1.0 && u[2] > 0.0);
    }
}
