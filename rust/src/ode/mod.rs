//! Time integration: Butcher tableaus, explicit RK (fixed + adaptive) and
//! implicit theta-methods (backward Euler, Crank–Nicolson) with
//! matrix-free Newton–GMRES.  The discrete adjoints live in
//! [`crate::adjoint`]; the checkpointing machinery in [`crate::checkpoint`].

pub mod adaptive;
pub mod erk;
pub mod forward;
pub mod grid;
pub mod implicit;
pub mod module_rhs;
pub mod rhs;
pub mod rhs_xla;
pub mod tableau;

pub use adaptive::{AdaptiveController, AdaptiveResult};
pub use erk::{erk_step, ErkWorkspace};
pub use forward::{forward_over_into, ForwardRun, ForwardWorkspace};
pub use grid::{integrate_erk_over, uniform_steps, GridRun, TimeGrid};
pub use implicit::{ImplicitStepper, ThetaScheme};
pub use module_rhs::ModuleRhs;
pub use rhs::{LinearRhs, Nfe, OdeRhs, RobertsonRhs};
pub use rhs_xla::{XlaCnfRhs, XlaRhs};
pub use tableau::{Scheme, Tableau};
