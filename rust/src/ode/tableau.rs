//! Butcher tableaus for the explicit Runge–Kutta schemes used in the paper
//! (Euler, Midpoint, Bosh3, RK4, Dopri5) plus scheme metadata.
//!
//! Layout: `a` is the full s×s matrix flattened row-major (strictly lower
//! triangular for ERK), `b` the quadrature weights, `c` the abscissae.
//! `b_err` (if present) are the *error* weights `b - b̂` of the embedded
//! pair, so the local error estimate is `err = h * Σ_i b_err[i] k_i`.

/// Identifier for every integration scheme the framework supports.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    Euler,
    Midpoint,
    Bosh3,
    Rk4,
    Dopri5,
    /// implicit backward Euler (theta = 1)
    BackwardEuler,
    /// implicit Crank–Nicolson (theta = 1/2)
    CrankNicolson,
}

impl Scheme {
    pub fn parse(s: &str) -> Option<Scheme> {
        Some(match s.to_ascii_lowercase().as_str() {
            "euler" => Scheme::Euler,
            "midpoint" => Scheme::Midpoint,
            "bosh3" => Scheme::Bosh3,
            "rk4" => Scheme::Rk4,
            "dopri5" => Scheme::Dopri5,
            "beuler" | "backward_euler" | "be" => Scheme::BackwardEuler,
            "cn" | "crank_nicolson" | "cranknicolson" => Scheme::CrankNicolson,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Euler => "euler",
            Scheme::Midpoint => "midpoint",
            Scheme::Bosh3 => "bosh3",
            Scheme::Rk4 => "rk4",
            Scheme::Dopri5 => "dopri5",
            Scheme::BackwardEuler => "beuler",
            Scheme::CrankNicolson => "cn",
        }
    }

    pub fn is_implicit(&self) -> bool {
        matches!(self, Scheme::BackwardEuler | Scheme::CrankNicolson)
    }

    /// Explicit tableau (panics for implicit schemes — those go through
    /// [`crate::ode::implicit`]).
    pub fn tableau(&self) -> &'static Tableau {
        match self {
            Scheme::Euler => &EULER,
            Scheme::Midpoint => &MIDPOINT,
            Scheme::Bosh3 => &BOSH3,
            Scheme::Rk4 => &RK4,
            Scheme::Dopri5 => &DOPRI5,
            // lint:allow(panic): tableau() is the explicit-scheme accessor; implicit schemes route through ThetaScheme
            _ => panic!("{} is implicit; no explicit tableau", self.name()),
        }
    }
}

/// An explicit Runge–Kutta Butcher tableau.
#[derive(Debug)]
pub struct Tableau {
    pub name: &'static str,
    pub order: usize,
    /// number of stages
    pub s: usize,
    /// s*s row-major, strictly lower triangular
    pub a: &'static [f64],
    pub b: &'static [f64],
    pub c: &'static [f64],
    /// embedded error weights b - b̂ (None for fixed-step-only schemes)
    pub b_err: Option<&'static [f64]>,
    /// first-same-as-last: k[s-1] of an accepted step equals k[0] of the next
    pub fsal: bool,
}

impl Tableau {
    #[inline]
    pub fn a(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.s + j]
    }

    /// Row-sum consistency check Σ_j a_ij == c_i (tested).
    pub fn is_consistent(&self) -> bool {
        for i in 0..self.s {
            let row: f64 = (0..self.s).map(|j| self.a(i, j)).sum();
            if (row - self.c[i]).abs() > 1e-12 {
                return false;
            }
        }
        (self.b.iter().sum::<f64>() - 1.0).abs() < 1e-12
    }
}

pub static EULER: Tableau = Tableau {
    name: "euler",
    order: 1,
    s: 1,
    a: &[0.0],
    b: &[1.0],
    c: &[0.0],
    b_err: None,
    fsal: false,
};

pub static MIDPOINT: Tableau = Tableau {
    name: "midpoint",
    order: 2,
    s: 2,
    a: &[0.0, 0.0, 0.5, 0.0],
    b: &[0.0, 1.0],
    c: &[0.0, 0.5],
    b_err: None,
    fsal: false,
};

/// Bogacki–Shampine 3(2), FSAL.
pub static BOSH3: Tableau = Tableau {
    name: "bosh3",
    order: 3,
    s: 4,
    a: &[
        0.0, 0.0, 0.0, 0.0, //
        0.5, 0.0, 0.0, 0.0, //
        0.0, 0.75, 0.0, 0.0, //
        2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0, 0.0,
    ],
    b: &[2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0, 0.0],
    c: &[0.0, 0.5, 0.75, 1.0],
    // b - b̂ with b̂ = [7/24, 1/4, 1/3, 1/8]
    b_err: Some(&[
        2.0 / 9.0 - 7.0 / 24.0,
        1.0 / 3.0 - 0.25,
        4.0 / 9.0 - 1.0 / 3.0,
        -0.125,
    ]),
    fsal: true,
};

pub static RK4: Tableau = Tableau {
    name: "rk4",
    order: 4,
    s: 4,
    a: &[
        0.0, 0.0, 0.0, 0.0, //
        0.5, 0.0, 0.0, 0.0, //
        0.0, 0.5, 0.0, 0.0, //
        0.0, 0.0, 1.0, 0.0,
    ],
    b: &[1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0],
    c: &[0.0, 0.5, 0.5, 1.0],
    b_err: None,
    fsal: false,
};

/// Dormand–Prince 5(4), FSAL.
pub static DOPRI5: Tableau = Tableau {
    name: "dopri5",
    order: 5,
    s: 7,
    a: &[
        0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, //
        0.2, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, //
        3.0 / 40.0, 9.0 / 40.0, 0.0, 0.0, 0.0, 0.0, 0.0, //
        44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0, 0.0, 0.0, 0.0, 0.0, //
        19372.0 / 6561.0, -25360.0 / 2187.0, 64448.0 / 6561.0, -212.0 / 729.0, 0.0, 0.0, 0.0, //
        9017.0 / 3168.0, -355.0 / 33.0, 46732.0 / 5247.0, 49.0 / 176.0, -5103.0 / 18656.0, 0.0, 0.0, //
        35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0, 11.0 / 84.0, 0.0,
    ],
    b: &[
        35.0 / 384.0,
        0.0,
        500.0 / 1113.0,
        125.0 / 192.0,
        -2187.0 / 6784.0,
        11.0 / 84.0,
        0.0,
    ],
    c: &[0.0, 0.2, 0.3, 0.8, 8.0 / 9.0, 1.0, 1.0],
    // b - b̂ with b̂ the 4th-order weights
    b_err: Some(&[
        35.0 / 384.0 - 5179.0 / 57600.0,
        0.0,
        500.0 / 1113.0 - 7571.0 / 16695.0,
        125.0 / 192.0 - 393.0 / 640.0,
        -2187.0 / 6784.0 + 92097.0 / 339200.0,
        11.0 / 84.0 - 187.0 / 2100.0,
        -1.0 / 40.0,
    ]),
    fsal: true,
};

/// All explicit schemes (bench sweeps iterate over this).
pub static EXPLICIT_SCHEMES: &[Scheme] = &[
    Scheme::Euler,
    Scheme::Midpoint,
    Scheme::Bosh3,
    Scheme::Rk4,
    Scheme::Dopri5,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tableaus_consistent() {
        for t in [&EULER, &MIDPOINT, &BOSH3, &RK4, &DOPRI5] {
            assert!(t.is_consistent(), "{} inconsistent", t.name);
            assert_eq!(t.a.len(), t.s * t.s);
            assert_eq!(t.b.len(), t.s);
            assert_eq!(t.c.len(), t.s);
            if let Some(be) = t.b_err {
                assert_eq!(be.len(), t.s);
                // error weights of a consistent embedded pair sum to 0
                assert!(be.iter().sum::<f64>().abs() < 1e-12, "{}", t.name);
            }
        }
    }

    #[test]
    fn strictly_lower_triangular() {
        for t in [&EULER, &MIDPOINT, &BOSH3, &RK4, &DOPRI5] {
            for i in 0..t.s {
                for j in i..t.s {
                    assert_eq!(t.a(i, j), 0.0, "{} a[{i}][{j}]", t.name);
                }
            }
        }
    }

    #[test]
    fn fsal_last_row_equals_b() {
        for t in [&BOSH3, &DOPRI5] {
            assert!(t.fsal);
            for j in 0..t.s {
                assert!(
                    (t.a(t.s - 1, j) - t.b[j]).abs() < 1e-15,
                    "{}: FSAL requires a[s-1][:] == b",
                    t.name
                );
            }
            assert_eq!(t.c[t.s - 1], 1.0);
        }
    }

    #[test]
    fn scheme_parse_roundtrip() {
        for s in EXPLICIT_SCHEMES {
            assert_eq!(Scheme::parse(s.name()), Some(*s));
        }
        assert_eq!(Scheme::parse("cn"), Some(Scheme::CrankNicolson));
        assert_eq!(Scheme::parse("nope"), None);
        assert!(Scheme::CrankNicolson.is_implicit());
        assert!(!Scheme::Dopri5.is_implicit());
    }
}
