//! Adaptive step-size control (PI controller) for embedded ERK pairs.
//!
//! The paper's adaptive experiments use Dopri5 with
//! `abstol = reltol = 1e-6` (§5.3.2); rejected steps cost forward NFE but
//! never enter the adjoint (only accepted steps are recorded — see §4:
//! "rejected time steps have no influence ... on the memory cost of PNODE").

use crate::ode::erk::{erk_step, error_estimate, ErkWorkspace};
use crate::ode::rhs::OdeRhs;
use crate::ode::tableau::Tableau;
use crate::tensor;

/// PI step-size controller.  Construct with [`AdaptiveController::for_tableau`]
/// (or [`for_order`](AdaptiveController::for_order)): the PI exponents are
/// derived from the method order at construction, so the controller is
/// always fully specified.
#[derive(Clone, Debug)]
pub struct AdaptiveController {
    pub atol: f64,
    pub rtol: f64,
    pub safety: f64,
    pub min_factor: f64,
    pub max_factor: f64,
    /// PI exponents (derived from the method order at construction)
    pub alpha: f64,
    pub beta: f64,
    /// method order (drives the rejection shrink factor)
    pub order: f64,
    pub max_steps: usize,
}

impl AdaptiveController {
    /// Controller for an embedded pair of the given `order`:
    /// `alpha = 0.7 / p`, `beta = 0.04 / p` (Gustafsson-style PI control).
    pub fn for_order(order: usize, atol: f64, rtol: f64) -> Self {
        assert!(order >= 1, "method order must be at least 1");
        let p = order as f64;
        AdaptiveController {
            atol,
            rtol,
            safety: 0.9,
            min_factor: 0.2,
            max_factor: 10.0,
            alpha: 0.7 / p,
            beta: 0.04 / p,
            order: p,
            max_steps: 100_000,
        }
    }

    /// Controller with PI exponents derived from `tab.order`.
    pub fn for_tableau(tab: &Tableau, atol: f64, rtol: f64) -> Self {
        Self::for_order(tab.order, atol, rtol)
    }
}

/// Outcome of an adaptive integration.
#[derive(Clone, Debug)]
pub struct AdaptiveResult {
    /// accepted (t_n, h_n) pairs, in order
    pub steps: Vec<(f64, f64)>,
    pub rejected: usize,
    pub final_state: Vec<f32>,
}

/// Integrate adaptively from `t0` to `tf`; `sink` fires on *accepted* steps
/// with `(accepted_index, t, h, u_n, ks, u_{n+1})`.
pub fn integrate_adaptive<F>(
    tab: &Tableau,
    rhs: &dyn OdeRhs,
    t0: f64,
    tf: f64,
    h0: f64,
    ctrl: &AdaptiveController,
    u0: &[f32],
    mut sink: F,
) -> AdaptiveResult
where
    F: FnMut(usize, f64, f64, &[f32], &[Vec<f32>], &[f32]),
{
    assert!(tab.b_err.is_some(), "{} has no embedded pair", tab.name);
    debug_assert!(
        ctrl.alpha > 0.0 && ctrl.order >= 1.0,
        "controller must be built via AdaptiveController::for_tableau/for_order"
    );
    let n = u0.len();
    let (alpha, beta) = (ctrl.alpha, ctrl.beta);

    let mut u = u0.to_vec();
    let mut u_next = vec![0.0f32; n];
    let mut err = vec![0.0f32; n];
    let mut scale_ref = vec![0.0f32; n];
    let mut ks: Vec<Vec<f32>> = (0..tab.s).map(|_| vec![0.0f32; n]).collect();
    let mut ws = ErkWorkspace::new(n);
    let mut fsal: Option<Vec<f32>> = None;

    let mut t = t0;
    let mut h = h0.min(tf - t0);
    let mut err_prev: f64 = 1.0;
    let mut steps = Vec::new();
    let mut rejected = 0usize;
    let mut accepted_idx = 0usize;

    for _ in 0..ctrl.max_steps {
        if t >= tf - 1e-14 * (tf - t0).abs() {
            break;
        }
        h = h.min(tf - t);
        erk_step(tab, rhs, t, h, &u, &mut ks, &mut u_next, &mut ws, fsal.as_deref());
        error_estimate(tab, h, &ks, &mut err);
        for i in 0..n {
            scale_ref[i] = u[i].abs().max(u_next[i].abs());
        }
        let err_norm = tensor::wrms_norm(&err, &scale_ref, ctrl.atol, ctrl.rtol);

        if err_norm <= 1.0 || h <= 1e-14 * (tf - t0).abs() {
            // accept
            sink(accepted_idx, t, h, &u, &ks, &u_next);
            steps.push((t, h));
            accepted_idx += 1;
            if tab.fsal {
                match &mut fsal {
                    Some(buf) => buf.copy_from_slice(&ks[tab.s - 1]),
                    None => fsal = Some(ks[tab.s - 1].clone()),
                }
            }
            std::mem::swap(&mut u, &mut u_next);
            t += h;
            // PI controller update
            let e = err_norm.max(1e-10);
            let factor =
                ctrl.safety * e.powf(-alpha) * err_prev.powf(beta);
            h *= factor.clamp(ctrl.min_factor, ctrl.max_factor);
            err_prev = e;
        } else {
            // reject: shrink, invalidate FSAL cache (stage 0 is still valid
            // since u didn't change, but keep it simple and correct)
            rejected += 1;
            fsal = None;
            let factor = ctrl.safety * err_norm.powf(-1.0 / ctrl.order);
            h *= factor.clamp(ctrl.min_factor, 1.0);
        }
    }

    AdaptiveResult { steps, rejected, final_state: u }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::rhs::LinearRhs;
    use crate::ode::tableau;

    #[test]
    fn pi_exponents_derive_from_order() {
        let c = AdaptiveController::for_tableau(&tableau::DOPRI5, 1e-6, 1e-6);
        assert!((c.alpha - 0.7 / 5.0).abs() < 1e-12);
        assert!((c.beta - 0.04 / 5.0).abs() < 1e-12);
        assert_eq!(c.order, 5.0);
        let c3 = AdaptiveController::for_tableau(&tableau::BOSH3, 1e-6, 1e-6);
        assert!(c3.alpha > c.alpha, "lower order => larger exponent");
    }

    #[test]
    fn adaptive_dopri5_hits_tolerance() {
        let rhs = LinearRhs::new(2, vec![0.0, 1.0, -1.0, 0.0]);
        let ctrl = AdaptiveController::for_tableau(&tableau::DOPRI5, 1e-8, 1e-8);
        let res = integrate_adaptive(
            &tableau::DOPRI5,
            &rhs,
            0.0,
            2.0,
            0.1,
            &ctrl,
            &[1.0, 0.0],
            |_, _, _, _, _, _| {},
        );
        let exact = [2.0f64.cos() as f32, -(2.0f64.sin()) as f32];
        let err = crate::testing::rel_l2(&res.final_state, &exact);
        assert!(err < 1e-6, "err {err:.2e}");
        assert!(!res.steps.is_empty());
        // steps must tile [0, 2]
        let total: f64 = res.steps.iter().map(|(_, h)| h).sum();
        assert!((total - 2.0).abs() < 1e-9);
    }

    #[test]
    fn tighter_tolerance_means_more_steps() {
        let rhs = LinearRhs::new(2, vec![0.0, 1.0, -1.0, 0.0]);
        let loose = integrate_adaptive(
            &tableau::DOPRI5,
            &rhs,
            0.0,
            5.0,
            0.5,
            &AdaptiveController::for_tableau(&tableau::DOPRI5, 1e-3, 1e-3),
            &[1.0, 0.0],
            |_, _, _, _, _, _| {},
        );
        let tight = integrate_adaptive(
            &tableau::DOPRI5,
            &rhs,
            0.0,
            5.0,
            0.5,
            &AdaptiveController::for_tableau(&tableau::DOPRI5, 1e-10, 1e-10),
            &[1.0, 0.0],
            |_, _, _, _, _, _| {},
        );
        assert!(tight.steps.len() > loose.steps.len());
    }

    #[test]
    fn stiff_problem_forces_tiny_steps() {
        // du/dt = -50 u: explicit adaptive must take many steps
        let rhs = LinearRhs::new(1, vec![-50.0]);
        let res = integrate_adaptive(
            &tableau::DOPRI5,
            &rhs,
            0.0,
            1.0,
            0.5,
            &AdaptiveController::for_tableau(&tableau::DOPRI5, 1e-6, 1e-6),
            &[1.0],
            |_, _, _, _, _, _| {},
        );
        // exp(-50) underflows f32 relative comparison; absolute check
        assert!(res.final_state[0].abs() < 1e-4, "{}", res.final_state[0]);
        assert!(res.steps.len() > 10);
    }
}
