//! Time grids for the integrators and the adjoint driver.
//!
//! A [`TimeGrid`] says how the forward pass obtains its step sequence:
//! fixed uniform steps, an explicit (possibly nonuniform) list of
//! `(t_n, h_n)` records, or *adaptive* — the PI controller generates the
//! grid at run time and only the **accepted** steps are recorded (the
//! paper's §4 rule: rejected trials cost forward NFE but never enter the
//! adjoint or the checkpoint store).

use crate::ode::adaptive::{integrate_adaptive, AdaptiveController};
use crate::ode::erk::integrate_grid;
use crate::ode::rhs::OdeRhs;
use crate::ode::tableau::Tableau;

/// How the forward pass obtains its `(t_n, h_n)` step sequence.
#[derive(Clone, Debug, PartialEq)]
pub enum TimeGrid {
    /// `nt` equal steps over `[t0, tf]`.
    Uniform { nt: usize },
    /// An explicit list of `(t_n, h_n)` records (e.g. log-spaced
    /// observation grids, or a frozen accepted grid from a previous
    /// adaptive run).
    Explicit(Vec<(f64, f64)>),
    /// PI-controlled step-size adaptation with an embedded ERK pair.
    /// `h0 = None` picks `(tf - t0) / 16` as the trial step.
    Adaptive { atol: f64, rtol: f64, h0: Option<f64> },
}

impl TimeGrid {
    pub fn uniform(nt: usize) -> TimeGrid {
        TimeGrid::Uniform { nt }
    }

    /// Adaptive grid with `atol = rtol = tol` (the paper's §5.3.2 setup).
    pub fn adaptive(tol: f64) -> TimeGrid {
        TimeGrid::Adaptive { atol: tol, rtol: tol, h0: None }
    }

    /// Explicit grid from a list of time points (`ts` must be strictly
    /// monotone and have at least two entries).
    pub fn from_times(ts: &[f64]) -> TimeGrid {
        assert!(ts.len() >= 2, "a time grid needs at least two points");
        TimeGrid::Explicit(ts.windows(2).map(|w| (w[0], w[1] - w[0])).collect())
    }

    /// Parse a grid spec.  Grammar:
    ///
    /// ```text
    /// uniform | uniform:<nt>
    /// adaptive:<atol>[:<rtol>[:<h0>]]
    /// ```
    ///
    /// `default_nt` fills the bare `uniform` form (the CLI's `--nt`).
    pub fn parse(s: &str, default_nt: usize) -> Result<TimeGrid, String> {
        if s == "uniform" {
            if default_nt == 0 {
                return Err("uniform grid needs nt >= 1".into());
            }
            return Ok(TimeGrid::Uniform { nt: default_nt });
        }
        if let Some(rest) = s.strip_prefix("uniform:") {
            let nt: usize = rest
                .parse()
                .map_err(|_| format!("bad step count {rest:?} in grid spec {s:?}"))?;
            if nt == 0 {
                return Err(format!("{s:?}: uniform grid needs nt >= 1"));
            }
            return Ok(TimeGrid::Uniform { nt });
        }
        if let Some(rest) = s.strip_prefix("adaptive:") {
            let parts: Vec<&str> = rest.split(':').collect();
            if parts.len() > 3 || parts[0].is_empty() {
                return Err(format!(
                    "bad adaptive grid spec {s:?} (want adaptive:<atol>[:<rtol>[:<h0>]])"
                ));
            }
            let num = |p: &str| -> Result<f64, String> {
                let v: f64 = p
                    .parse()
                    .map_err(|_| format!("bad number {p:?} in grid spec {s:?}"))?;
                if !(v.is_finite() && v > 0.0) {
                    return Err(format!("{s:?}: tolerances/steps must be positive, got {p:?}"));
                }
                Ok(v)
            };
            let atol = num(parts[0])?;
            let rtol = if parts.len() > 1 { num(parts[1])? } else { atol };
            let h0 = if parts.len() > 2 { Some(num(parts[2])?) } else { None };
            return Ok(TimeGrid::Adaptive { atol, rtol, h0 });
        }
        Err(format!(
            "unknown grid spec {s:?} \
             (want uniform | uniform:<nt> | adaptive:<atol>[:<rtol>[:<h0>]])"
        ))
    }

    pub fn name(&self) -> String {
        match self {
            TimeGrid::Uniform { nt } => format!("uniform:{nt}"),
            TimeGrid::Explicit(steps) => format!("explicit:{}", steps.len()),
            TimeGrid::Adaptive { atol, rtol, h0 } => match h0 {
                Some(h0) => format!("adaptive:{atol}:{rtol}:{h0}"),
                None => format!("adaptive:{atol}:{rtol}"),
            },
        }
    }

    /// Whether the step sequence is known before the forward pass runs.
    pub fn is_static(&self) -> bool {
        !matches!(self, TimeGrid::Adaptive { .. })
    }

    /// Planned step count; `None` for adaptive grids (unknown until the
    /// forward pass has run).
    pub fn planned_nt(&self) -> Option<usize> {
        match self {
            TimeGrid::Uniform { nt } => Some(*nt),
            TimeGrid::Explicit(steps) => Some(steps.len()),
            TimeGrid::Adaptive { .. } => None,
        }
    }
}

/// Default adaptive trial step when a grid spec carries `h0: None`.
/// Single source of truth: the adjoint driver and [`integrate_erk_over`]
/// must agree, or different methods would generate different accepted
/// grids from the same spec.
pub fn default_adaptive_h0(t0: f64, tf: f64) -> f64 {
    (tf - t0) / 16.0
}

/// The `(t_n, h_n)` records of `nt` equal steps over `[t0, tf]`.
pub fn uniform_steps(t0: f64, tf: f64, nt: usize) -> Vec<(f64, f64)> {
    let h = (tf - t0) / nt as f64;
    (0..nt).map(|i| (t0 + i as f64 * h, h)).collect()
}

/// Outcome of [`integrate_erk_over`]: the executed (accepted) grid plus
/// the number of rejected adaptive trials.
#[derive(Clone, Debug)]
pub struct GridRun {
    pub final_state: Vec<f32>,
    /// accepted `(t_n, h_n)` records, in order
    pub steps: Vec<(f64, f64)>,
    pub n_rejected: usize,
}

/// Integrate an explicit RK scheme over `grid`, firing `sink` on every
/// executed (accepted) step with `(step, t, h, u_n, ks, u_{n+1})`.
/// Rejected adaptive trials burn forward NFE but never reach the sink.
pub fn integrate_erk_over<F>(
    tab: &Tableau,
    rhs: &dyn OdeRhs,
    t0: f64,
    tf: f64,
    grid: &TimeGrid,
    u0: &[f32],
    sink: F,
) -> GridRun
where
    F: FnMut(usize, f64, f64, &[f32], &[Vec<f32>], &[f32]),
{
    match grid {
        TimeGrid::Uniform { nt } => {
            let steps = uniform_steps(t0, tf, *nt);
            let final_state = integrate_grid(tab, rhs, &steps, u0, sink);
            GridRun { final_state, steps, n_rejected: 0 }
        }
        TimeGrid::Explicit(steps) => {
            let final_state = integrate_grid(tab, rhs, steps, u0, sink);
            GridRun { final_state, steps: steps.clone(), n_rejected: 0 }
        }
        TimeGrid::Adaptive { atol, rtol, h0 } => {
            let ctrl = AdaptiveController::for_tableau(tab, *atol, *rtol);
            let h0 = h0.unwrap_or_else(|| default_adaptive_h0(t0, tf));
            let res = integrate_adaptive(tab, rhs, t0, tf, h0, &ctrl, u0, sink);
            GridRun {
                final_state: res.final_state,
                steps: res.steps,
                n_rejected: res.rejected,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::rhs::LinearRhs;
    use crate::ode::tableau;

    #[test]
    fn parse_roundtrip_and_errors() {
        assert_eq!(TimeGrid::parse("uniform", 8), Ok(TimeGrid::Uniform { nt: 8 }));
        assert_eq!(TimeGrid::parse("uniform:12", 8), Ok(TimeGrid::Uniform { nt: 12 }));
        assert_eq!(
            TimeGrid::parse("adaptive:1e-6", 8),
            Ok(TimeGrid::Adaptive { atol: 1e-6, rtol: 1e-6, h0: None })
        );
        assert_eq!(
            TimeGrid::parse("adaptive:1e-6:1e-8:0.25", 8),
            Ok(TimeGrid::Adaptive { atol: 1e-6, rtol: 1e-8, h0: Some(0.25) })
        );
        for bad in [
            "uniform:0",
            "uniform:x",
            "adaptive:",
            "adaptive:-1",
            "adaptive:1e-6:1e-6:0.1:9",
            "bogus",
        ] {
            assert!(TimeGrid::parse(bad, 8).is_err(), "{bad}");
        }
        for g in [
            TimeGrid::Uniform { nt: 7 },
            TimeGrid::Adaptive { atol: 1e-6, rtol: 1e-6, h0: None },
            TimeGrid::Adaptive { atol: 1e-5, rtol: 1e-7, h0: Some(0.5) },
        ] {
            assert_eq!(TimeGrid::parse(&g.name(), 1), Ok(g.clone()), "{}", g.name());
        }
    }

    #[test]
    fn uniform_steps_tile_the_interval() {
        let steps = uniform_steps(0.0, 1.0, 4);
        assert_eq!(steps.len(), 4);
        assert_eq!(steps[0], (0.0, 0.25));
        let total: f64 = steps.iter().map(|(_, h)| h).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn from_times_matches_windows() {
        let g = TimeGrid::from_times(&[0.0, 0.1, 0.4, 1.0]);
        match &g {
            TimeGrid::Explicit(steps) => {
                assert_eq!(steps.len(), 3);
                assert!((steps[1].1 - 0.3).abs() < 1e-12);
            }
            _ => panic!("wrong variant"),
        }
        assert_eq!(g.planned_nt(), Some(3));
        assert!(g.is_static());
        assert!(!TimeGrid::adaptive(1e-6).is_static());
    }

    #[test]
    fn integrate_over_all_grid_kinds_agrees_on_smooth_problem() {
        let rhs = LinearRhs::new(2, vec![0.0, 1.0, -1.0, 0.0]);
        let exact = [2.0f64.cos() as f32, -(2.0f64.sin()) as f32];
        let u0 = [1.0f32, 0.0];
        let sink = |_: usize, _: f64, _: f64, _: &[f32], _: &[Vec<f32>], _: &[f32]| {};
        let uni = integrate_erk_over(
            &tableau::DOPRI5, &rhs, 0.0, 2.0, &TimeGrid::Uniform { nt: 40 }, &u0, sink,
        );
        let expl = integrate_erk_over(
            &tableau::DOPRI5,
            &rhs,
            0.0,
            2.0,
            &TimeGrid::Explicit(uniform_steps(0.0, 2.0, 40)),
            &u0,
            sink,
        );
        let ada = integrate_erk_over(
            &tableau::DOPRI5, &rhs, 0.0, 2.0, &TimeGrid::adaptive(1e-8), &u0, sink,
        );
        // explicit copy of the uniform grid is the same computation, bitwise
        assert_eq!(uni.final_state, expl.final_state);
        assert_eq!(uni.steps, expl.steps);
        assert_eq!(uni.n_rejected, 0);
        assert!(crate::testing::rel_l2(&ada.final_state, &exact) < 1e-6);
        assert!(!ada.steps.is_empty());
        let total: f64 = ada.steps.iter().map(|(_, h)| h).sum();
        assert!((total - 2.0).abs() < 1e-9, "accepted steps tile the interval");
    }
}
