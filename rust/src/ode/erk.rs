//! Explicit Runge–Kutta stepping with stage recording.
//!
//! A step records the stage derivatives `k_i = f(t + c_i h, U_i)` so that
//! (a) the discrete adjoint can reconstruct the stage states
//! `U_i = u_n + h Σ_{j<i} a_ij k_j` with pure linear algebra (no extra NFE),
//! and (b) FSAL schemes can reuse `k_{s-1}` as the next step's `k_0`.

use crate::ode::rhs::OdeRhs;
use crate::ode::tableau::Tableau;
use crate::tensor;

/// Reusable scratch so the hot loop allocates nothing.
pub struct ErkWorkspace {
    /// stage state U_i
    stage_state: Vec<f32>,
}

impl ErkWorkspace {
    pub fn new(n: usize) -> Self {
        ErkWorkspace { stage_state: vec![0.0; n] }
    }
}

/// Take one ERK step.
///
/// * `ks` must hold `tab.s` vectors of length `state_len`; they are filled
///   with the stage derivatives.
/// * If `fsal_k0` is `Some` and the tableau is FSAL, stage 0 is copied from
///   it instead of evaluating `f` (saves one NFE per step).
/// * `u_next` may not alias `u`.
pub fn erk_step(
    tab: &Tableau,
    rhs: &dyn OdeRhs,
    t: f64,
    h: f64,
    u: &[f32],
    ks: &mut [Vec<f32>],
    u_next: &mut [f32],
    ws: &mut ErkWorkspace,
    fsal_k0: Option<&[f32]>,
) {
    debug_assert_eq!(ks.len(), tab.s);
    let n = u.len();
    debug_assert!(ks.iter().all(|k| k.len() == n));
    for i in 0..tab.s {
        if i == 0 {
            if let (true, Some(k0)) = (tab.fsal, fsal_k0) {
                ks[0].copy_from_slice(k0);
                continue;
            }
            rhs.f(t, u, &mut ks[0]);
            continue;
        }
        // U_i = u + h Σ_{j<i} a_ij k_j
        let us = &mut ws.stage_state;
        us.copy_from_slice(u);
        for (j, kj) in ks.iter().enumerate().take(i) {
            let a = tab.a(i, j);
            if a != 0.0 {
                tensor::axpy((h * a) as f32, kj, us);
            }
        }
        rhs.f(t + tab.c[i] * h, us, &mut ks[i]);
    }
    // u_next = u + h Σ b_i k_i
    u_next.copy_from_slice(u);
    for i in 0..tab.s {
        if tab.b[i] != 0.0 {
            tensor::axpy((h * tab.b[i]) as f32, &ks[i], u_next);
        }
    }
}

/// Reconstruct stage state `U_i` from the recorded stage derivatives.
pub fn stage_state(
    tab: &Tableau,
    i: usize,
    h: f64,
    u: &[f32],
    ks: &[Vec<f32>],
    out: &mut [f32],
) {
    out.copy_from_slice(u);
    for j in 0..i {
        let a = tab.a(i, j);
        if a != 0.0 {
            tensor::axpy((h * a) as f32, &ks[j], out);
        }
    }
}

/// Local error estimate of an embedded pair: `err = h Σ b_err_i k_i`.
pub fn error_estimate(tab: &Tableau, h: f64, ks: &[Vec<f32>], out: &mut [f32]) {
    // lint:allow(panic): the adaptive driver rejects schemes without an embedded pair before ever calling this
    let b_err = tab.b_err.expect("scheme has no embedded error estimate");
    tensor::zero(out);
    for i in 0..tab.s {
        if b_err[i] != 0.0 {
            tensor::axpy((h * b_err[i]) as f32, &ks[i], out);
        }
    }
}

/// Integrate over an explicit list of contiguous `(t_n, h_n)` steps,
/// calling `sink` after every step with `(step_index, t_n, h_n, u_n, ks,
/// u_{n+1})`.  Returns the final state.  The FSAL cache carries across
/// steps regardless of step size (FSAL validity only needs `t_{n+1} =
/// t_n + h_n`, which contiguous grids guarantee).
pub fn integrate_grid<F>(
    tab: &Tableau,
    rhs: &dyn OdeRhs,
    steps: &[(f64, f64)],
    u0: &[f32],
    mut sink: F,
) -> Vec<f32>
where
    F: FnMut(usize, f64, f64, &[f32], &[Vec<f32>], &[f32]),
{
    let n = u0.len();
    let mut u = u0.to_vec();
    let mut u_next = vec![0.0f32; n];
    let mut ks: Vec<Vec<f32>> = (0..tab.s).map(|_| vec![0.0f32; n]).collect();
    let mut ws = ErkWorkspace::new(n);
    let mut fsal: Option<Vec<f32>> = None;
    for (step, &(t, h)) in steps.iter().enumerate() {
        // contiguity is what makes the FSAL reuse (and the composed map)
        // valid; a gapped "grid" would silently integrate the wrong ODE
        debug_assert!(
            step == 0 || {
                let (tp, hp) = steps[step - 1];
                (t - (tp + hp)).abs() <= 1e-12 * (1.0 + t.abs())
            },
            "integrate_grid needs contiguous steps: step {step} starts at {t}, \
             previous step ends at {}",
            steps[step - 1].0 + steps[step - 1].1
        );
        erk_step(tab, rhs, t, h, &u, &mut ks, &mut u_next, &mut ws, fsal.as_deref());
        sink(step, t, h, &u, &ks, &u_next);
        if tab.fsal {
            // k_{s-1} at (t+h, u_next) is next step's k_0
            match &mut fsal {
                Some(buf) => buf.copy_from_slice(&ks[tab.s - 1]),
                None => fsal = Some(ks[tab.s - 1].clone()),
            }
        }
        std::mem::swap(&mut u, &mut u_next);
    }
    u
}

/// Integrate with fixed steps from `t0` to `tf` in `nt` steps, calling
/// `sink` after every step with `(step_index, t_n, h, u_n, ks, u_{n+1})`.
/// Returns the final state.
#[allow(clippy::too_many_arguments)]
pub fn integrate_fixed<F>(
    tab: &Tableau,
    rhs: &dyn OdeRhs,
    t0: f64,
    tf: f64,
    nt: usize,
    u0: &[f32],
    sink: F,
) -> Vec<f32>
where
    F: FnMut(usize, f64, f64, &[f32], &[Vec<f32>], &[f32]),
{
    let steps = crate::ode::grid::uniform_steps(t0, tf, nt);
    integrate_grid(tab, rhs, &steps, u0, sink)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::rhs::LinearRhs;
    use crate::ode::tableau;

    /// du/dt = A u with A = [[0, 1], [-1, 0]]: solution rotates, |u| const.
    fn rotation() -> LinearRhs {
        LinearRhs::new(2, vec![0.0, 1.0, -1.0, 0.0])
    }

    fn integrate(tab: &Tableau, nt: usize) -> Vec<f32> {
        let rhs = rotation();
        integrate_fixed(tab, &rhs, 0.0, 1.0, nt, &[1.0, 0.0], |_, _, _, _, _, _| {})
    }

    #[test]
    fn convergence_orders() {
        // error at t=1 must shrink like h^order
        let exact = [1.0f64.cos() as f32, -(1.0f64.sin()) as f32];
        for (tab, min_rate) in [
            (&tableau::EULER, 0.9),
            (&tableau::MIDPOINT, 1.9),
            (&tableau::BOSH3, 2.9),
            (&tableau::RK4, 3.9),
            (&tableau::DOPRI5, 4.5),
        ] {
            let e1 = crate::testing::rel_l2(&integrate(tab, 10), &exact);
            let e2 = crate::testing::rel_l2(&integrate(tab, 20), &exact);
            let rate = (e1 / e2).log2();
            // escape when the error already sits at the f32 roundoff floor
            assert!(
                rate > min_rate || e2 < 1e-6,
                "{}: rate {rate:.2} (e1={e1:.2e}, e2={e2:.2e})",
                tab.name
            );
        }
    }

    #[test]
    fn fsal_saves_evaluations() {
        let rhs = rotation();
        let nt = 10;
        integrate_fixed(&tableau::DOPRI5, &rhs, 0.0, 1.0, nt, &[1.0, 0.0], |_, _, _, _, _, _| {});
        // 7 stages, FSAL => 7 + 6*(nt-1) forward evals
        assert_eq!(rhs.nfe().forward, (7 + 6 * (nt - 1)) as u64);
    }

    #[test]
    fn stage_state_reconstruction() {
        let rhs = rotation();
        let tab = &tableau::RK4;
        let n = 2;
        let u = vec![0.3f32, -0.7];
        let mut ks: Vec<Vec<f32>> = (0..tab.s).map(|_| vec![0.0f32; n]).collect();
        let mut u_next = vec![0.0f32; n];
        let mut ws = ErkWorkspace::new(n);
        let (t, h) = (0.2, 0.05);
        erk_step(tab, &rhs, t, h, &u, &mut ks, &mut u_next, &mut ws, None);
        // reconstructed U_i must satisfy k_i = f(t + c_i h, U_i)
        for i in 0..tab.s {
            let mut ui = vec![0.0f32; n];
            stage_state(tab, i, h, &u, &ks, &mut ui);
            let mut fi = vec![0.0f32; n];
            rhs.f(t + tab.c[i] * h, &ui, &mut fi);
            crate::testing::assert_allclose(&fi, &ks[i], 1e-6, 1e-7, "stage recon");
        }
    }

    #[test]
    fn nonuniform_grid_matches_manual_step_composition() {
        let rhs = rotation();
        let tab = &tableau::BOSH3; // FSAL: exercises the cache across sizes
        let steps = [(0.0, 0.1), (0.1, 0.3), (0.4, 0.25), (0.65, 0.35)];
        let u0 = vec![0.8f32, -0.4];
        let via_grid = integrate_grid(tab, &rhs, &steps, &u0, |_, _, _, _, _, _| {});

        let n = 2;
        let mut u = u0.clone();
        let mut un = vec![0.0f32; n];
        let mut ks: Vec<Vec<f32>> = (0..tab.s).map(|_| vec![0.0f32; n]).collect();
        let mut ws = ErkWorkspace::new(n);
        let mut fsal: Option<Vec<f32>> = None;
        for &(t, h) in &steps {
            erk_step(tab, &rhs, t, h, &u, &mut ks, &mut un, &mut ws, fsal.as_deref());
            fsal = Some(ks[tab.s - 1].clone());
            std::mem::swap(&mut u, &mut un);
        }
        assert_eq!(via_grid, u, "grid integration is the literal composition");
    }

    #[test]
    fn error_estimate_is_small_for_smooth_problem() {
        let rhs = rotation();
        let tab = &tableau::DOPRI5;
        let u = vec![1.0f32, 0.0];
        let mut ks: Vec<Vec<f32>> = (0..tab.s).map(|_| vec![0.0f32; 2]).collect();
        let mut u_next = vec![0.0f32; 2];
        let mut ws = ErkWorkspace::new(2);
        erk_step(tab, &rhs, 0.0, 0.01, &u, &mut ks, &mut u_next, &mut ws, None);
        let mut err = vec![0.0f32; 2];
        error_estimate(tab, 0.01, &ks, &mut err);
        assert!(crate::tensor::nrm2(&err) < 1e-10);
    }
}
