//! Allocation-free forward-only integration — the serving fast path.
//!
//! [`crate::ode::grid::integrate_erk_over`] allocates its state, stage,
//! and FSAL buffers per call and hands every accepted step to a sink (the
//! adjoint's recording hook).  Inference needs neither: this module runs
//! the *same arithmetic* on a caller-owned [`ForwardWorkspace`] and
//! writes the final state into a caller slice, so a warm
//! [`crate::api::Session`] serves requests with zero steady-state
//! allocation.
//!
//! Bitwise contract: [`forward_over_into`] reproduces
//! `integrate_erk_over(..).final_state` bit for bit, for every grid kind.
//!
//! * Fixed grids run the identical [`erk_step`] sequence — same axpy
//!   order, same FSAL carry, same `u`/`u_next` swap — with uniform step
//!   records computed by the identical `t0 + i * h` expression.
//! * Adaptive grids run the identical PI-controller loop (same accept /
//!   reject tests, same factor clamps, same FSAL invalidation on
//!   reject), so the generated step sequence — and therefore every
//!   floating-point operation — matches.
//!
//! The tests pin this equality; `tests/serve_determinism.rs` pins it end
//! to end through the facade.

use crate::ode::adaptive::AdaptiveController;
use crate::ode::erk::{erk_step, error_estimate, ErkWorkspace};
use crate::ode::grid::{default_adaptive_h0, TimeGrid};
use crate::ode::rhs::OdeRhs;
use crate::ode::tableau::Tableau;
use crate::tensor;

/// Reusable buffers for [`forward_over_into`]: state ping-pong, stage
/// derivatives, FSAL carry, and the adaptive controller's error scratch.
/// Sized by [`ForwardWorkspace::ensure`]; a stable `(stages, state_len)`
/// shape never re-allocates, which is the serving path's steady-state
/// zero-allocation invariant (observable through the `ensure` return
/// value, surfaced as `Session::forward_allocs`).
pub struct ForwardWorkspace {
    /// stage count the buffers are sized for (0 = empty)
    s: usize,
    /// state length the buffers are sized for
    n: usize,
    u: Vec<f32>,
    u_next: Vec<f32>,
    /// stage derivatives `k_i`
    ks: Vec<Vec<f32>>,
    /// FSAL carry: `k_{s-1}` of the previous step (valid per-call only)
    fsal: Vec<f32>,
    /// embedded error estimate (adaptive grids)
    err: Vec<f32>,
    /// per-component error scale (adaptive grids)
    scale_ref: Vec<f32>,
    stage: ErkWorkspace,
}

impl ForwardWorkspace {
    /// An empty workspace; buffers appear at the first
    /// [`ForwardWorkspace::ensure`].
    pub fn new() -> Self {
        ForwardWorkspace {
            s: 0,
            n: 0,
            u: Vec::new(),
            u_next: Vec::new(),
            ks: Vec::new(),
            fsal: Vec::new(),
            err: Vec::new(),
            scale_ref: Vec::new(),
            stage: ErkWorkspace::new(0),
        }
    }

    /// Size every buffer for a `(stages, state_len)` shape.  Returns
    /// `true` iff this call had to (re)allocate: a stable shape returns
    /// `false` forever after its first call, which is what the serving
    /// tests and the `serve_throughput --smoke` gate pin.
    pub fn ensure(&mut self, s: usize, n: usize) -> bool {
        if self.s == s && self.n == n {
            return false;
        }
        self.s = s;
        self.n = n;
        self.u = vec![0.0; n];
        self.u_next = vec![0.0; n];
        self.ks = (0..s).map(|_| vec![0.0f32; n]).collect();
        self.fsal = vec![0.0; n];
        self.err = vec![0.0; n];
        self.scale_ref = vec![0.0; n];
        self.stage = ErkWorkspace::new(n);
        true
    }

    /// The `(stages, state_len)` shape the buffers are currently sized
    /// for (`(0, 0)` when empty).
    pub fn shape(&self) -> (usize, usize) {
        (self.s, self.n)
    }
}

impl Default for ForwardWorkspace {
    fn default() -> Self {
        ForwardWorkspace::new()
    }
}

/// Step counts of one [`forward_over_into`] call.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ForwardRun {
    /// executed (accepted) steps
    pub n_steps: u64,
    /// rejected adaptive trials (0 on fixed grids)
    pub n_rejected: u64,
}

/// One ERK step on the workspace state: `ws.u <- Φ_h(ws.u)` with the
/// FSAL carry maintained — exactly `integrate_grid`'s per-step body.
fn step_into(
    tab: &Tableau,
    rhs: &dyn OdeRhs,
    t: f64,
    h: f64,
    ws: &mut ForwardWorkspace,
    fsal_valid: &mut bool,
) {
    let fsal_k0 = if *fsal_valid { Some(ws.fsal.as_slice()) } else { None };
    erk_step(tab, rhs, t, h, &ws.u, &mut ws.ks, &mut ws.u_next, &mut ws.stage, fsal_k0);
    if tab.fsal {
        // k_{s-1} at (t+h, u_next) is next step's k_0
        ws.fsal.copy_from_slice(&ws.ks[tab.s - 1]);
        *fsal_valid = true;
    }
    std::mem::swap(&mut ws.u, &mut ws.u_next);
}

/// Integrate an explicit RK scheme over `grid` without allocating: the
/// sink-free, record-free twin of
/// [`integrate_erk_over`](crate::ode::grid::integrate_erk_over), bitwise
/// identical to its `final_state` (see the module docs for why).  The
/// caller must have sized `ws` via `ws.ensure(tab.s, u0.len())`;
/// `out.len()` must equal `u0.len()`.
pub fn forward_over_into(
    tab: &Tableau,
    rhs: &dyn OdeRhs,
    t0: f64,
    tf: f64,
    grid: &TimeGrid,
    u0: &[f32],
    ws: &mut ForwardWorkspace,
    out: &mut [f32],
) -> ForwardRun {
    assert_eq!(
        ws.shape(),
        (tab.s, u0.len()),
        "forward workspace not sized for this (stages, state_len): call ensure() first"
    );
    assert_eq!(out.len(), u0.len(), "out must match the state length");
    match grid {
        TimeGrid::Uniform { nt } => {
            // the identical step records uniform_steps() would produce
            let h = (tf - t0) / *nt as f64;
            ws.u.copy_from_slice(u0);
            let mut fsal_valid = false;
            for i in 0..*nt {
                let t = t0 + i as f64 * h;
                step_into(tab, rhs, t, h, ws, &mut fsal_valid);
            }
            out.copy_from_slice(&ws.u);
            ForwardRun { n_steps: *nt as u64, n_rejected: 0 }
        }
        TimeGrid::Explicit(steps) => {
            ws.u.copy_from_slice(u0);
            let mut fsal_valid = false;
            for &(t, h) in steps {
                step_into(tab, rhs, t, h, ws, &mut fsal_valid);
            }
            out.copy_from_slice(&ws.u);
            ForwardRun { n_steps: steps.len() as u64, n_rejected: 0 }
        }
        TimeGrid::Adaptive { atol, rtol, h0 } => {
            // same controller, same default trial step as integrate_erk_over:
            // the accepted grid (and so the bits) must agree across entry
            // points
            assert!(tab.b_err.is_some(), "{} has no embedded pair", tab.name);
            let ctrl = AdaptiveController::for_tableau(tab, *atol, *rtol);
            let h0 = h0.unwrap_or_else(|| default_adaptive_h0(t0, tf));
            let n = u0.len();
            let (alpha, beta) = (ctrl.alpha, ctrl.beta);
            ws.u.copy_from_slice(u0);
            let mut fsal_valid = false;
            let mut t = t0;
            let mut h = h0.min(tf - t0);
            let mut err_prev: f64 = 1.0;
            let mut accepted = 0u64;
            let mut rejected = 0u64;
            for _ in 0..ctrl.max_steps {
                if t >= tf - 1e-14 * (tf - t0).abs() {
                    break;
                }
                h = h.min(tf - t);
                let fsal_k0 = if fsal_valid { Some(ws.fsal.as_slice()) } else { None };
                erk_step(tab, rhs, t, h, &ws.u, &mut ws.ks, &mut ws.u_next, &mut ws.stage, fsal_k0);
                error_estimate(tab, h, &ws.ks, &mut ws.err);
                for i in 0..n {
                    ws.scale_ref[i] = ws.u[i].abs().max(ws.u_next[i].abs());
                }
                let err_norm = tensor::wrms_norm(&ws.err, &ws.scale_ref, ctrl.atol, ctrl.rtol);
                if err_norm <= 1.0 || h <= 1e-14 * (tf - t0).abs() {
                    // accept
                    accepted += 1;
                    if tab.fsal {
                        ws.fsal.copy_from_slice(&ws.ks[tab.s - 1]);
                        fsal_valid = true;
                    }
                    std::mem::swap(&mut ws.u, &mut ws.u_next);
                    t += h;
                    // PI controller update
                    let e = err_norm.max(1e-10);
                    let factor = ctrl.safety * e.powf(-alpha) * err_prev.powf(beta);
                    h *= factor.clamp(ctrl.min_factor, ctrl.max_factor);
                    err_prev = e;
                } else {
                    // reject: shrink, invalidate FSAL cache (same rule as
                    // integrate_adaptive)
                    rejected += 1;
                    fsal_valid = false;
                    let factor = ctrl.safety * err_norm.powf(-1.0 / ctrl.order);
                    h *= factor.clamp(ctrl.min_factor, 1.0);
                }
            }
            out.copy_from_slice(&ws.u);
            ForwardRun { n_steps: accepted, n_rejected: rejected }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::grid::{integrate_erk_over, uniform_steps};
    use crate::ode::rhs::LinearRhs;
    use crate::ode::tableau;

    fn rotation() -> LinearRhs {
        LinearRhs::new(2, vec![0.0, 1.0, -1.0, 0.0])
    }

    fn run_both(tab: &Tableau, grid: &TimeGrid, u0: &[f32]) -> (Vec<f32>, Vec<f32>, ForwardRun) {
        let rhs = rotation();
        let reference =
            integrate_erk_over(tab, &rhs, 0.0, 2.0, grid, u0, |_, _, _, _, _, _| {});
        let mut ws = ForwardWorkspace::new();
        assert!(ws.ensure(tab.s, u0.len()), "first ensure allocates");
        let mut out = vec![0.0f32; u0.len()];
        let run = forward_over_into(tab, &rhs, 0.0, 2.0, grid, u0, &mut ws, &mut out);
        (reference.final_state, out, run)
    }

    #[test]
    fn matches_integrate_erk_over_bitwise_on_all_grid_kinds() {
        let u0 = [0.8f32, -0.35];
        for tab in [&tableau::EULER, &tableau::RK4, &tableau::BOSH3, &tableau::DOPRI5] {
            for grid in [
                TimeGrid::Uniform { nt: 13 },
                TimeGrid::Explicit(uniform_steps(0.0, 2.0, 13)),
                TimeGrid::Explicit(vec![(0.0, 0.5), (0.5, 0.75), (1.25, 0.75)]),
            ] {
                let (reference, got, run) = run_both(tab, &grid, &u0);
                assert_eq!(reference, got, "{} over {}", tab.name, grid.name());
                assert_eq!(run.n_rejected, 0);
                assert!(run.n_steps > 0);
            }
        }
    }

    #[test]
    fn matches_adaptive_bitwise_including_rejected_steps() {
        // -50u forces rejections, exercising FSAL invalidation parity
        let rhs = LinearRhs::new(1, vec![-50.0]);
        for tol in [1e-4, 1e-7] {
            let grid = TimeGrid::Adaptive { atol: tol, rtol: tol, h0: Some(0.5) };
            let reference =
                integrate_erk_over(&tableau::DOPRI5, &rhs, 0.0, 2.0, &grid, &[1.0], |_, _, _, _, _, _| {});
            let mut ws = ForwardWorkspace::new();
            ws.ensure(tableau::DOPRI5.s, 1);
            let mut out = vec![0.0f32; 1];
            let run = forward_over_into(&tableau::DOPRI5, &rhs, 0.0, 2.0, &grid, &[1.0], &mut ws, &mut out);
            assert_eq!(reference.final_state, out, "tol {tol}");
            assert_eq!(run.n_steps as usize, reference.steps.len());
            assert_eq!(run.n_rejected as usize, reference.n_rejected);
            assert!(run.n_rejected > 0, "the stiff case must exercise rejects (tol {tol})");
        }
        // smooth default-h0 path too
        let (reference, got, _) = run_both(&tableau::DOPRI5, &TimeGrid::adaptive(1e-8), &[1.0, 0.0]);
        assert_eq!(reference, got);
    }

    #[test]
    fn workspace_reuse_never_reallocates_and_keeps_bits() {
        let rhs = rotation();
        let tab = &tableau::DOPRI5;
        let grid = TimeGrid::Uniform { nt: 9 };
        let mut ws = ForwardWorkspace::new();
        assert!(ws.ensure(tab.s, 2));
        let mut first = vec![0.0f32; 2];
        forward_over_into(tab, &rhs, 0.0, 2.0, &grid, &[1.0, 0.0], &mut ws, &mut first);
        for _ in 0..5 {
            assert!(!ws.ensure(tab.s, 2), "stable shape never re-allocates");
            let mut again = vec![0.0f32; 2];
            forward_over_into(tab, &rhs, 0.0, 2.0, &grid, &[1.0, 0.0], &mut ws, &mut again);
            assert_eq!(first, again, "workspace reuse is bitwise repeatable");
        }
        assert!(ws.ensure(tab.s, 4), "shape change re-allocates");
        assert_eq!(ws.shape(), (tab.s, 4));
    }
}
