//! [`XlaRhs`]: the production `OdeRhs` executing AOT-compiled artifacts.
//!
//! All four primitives (`f`, `vjp_u`, `vjp_both`, `jvp`) are separate HLO
//! executables compiled at startup from `artifacts/<config>.<prim>.hlo.txt`;
//! the L2 `vjp_both` fuses the u- and θ-cotangents over one shared forward
//! recompute (the Pallas dense kernel runs inside all of them).

use std::cell::RefCell;

use anyhow::Result;

use crate::ode::rhs::{Nfe, NfeCounter, OdeRhs};
use crate::runtime::ModelArtifacts;

/// Neural RHS backed by PJRT executables.
pub struct XlaRhs {
    arts: ModelArtifacts,
    theta: Vec<f32>,
    batch: usize,
    state_dim: usize,
    nfe: NfeCounter,
    /// reusable t buffer ([1]-shaped artifact input)
    t_buf: RefCell<[f32; 1]>,
}

impl XlaRhs {
    pub fn new(arts: ModelArtifacts, theta: Vec<f32>) -> Result<Self> {
        anyhow::ensure!(
            arts.entry.kind == "mlp",
            "XlaRhs wants an 'mlp' config, got {:?} ({})",
            arts.entry.kind,
            arts.entry.name
        );
        anyhow::ensure!(
            theta.len() == arts.entry.param_count,
            "theta len {} != param_count {}",
            theta.len(),
            arts.entry.param_count
        );
        let batch = arts.entry.batch;
        let state_dim = arts.entry.state_dim;
        Ok(XlaRhs { arts, theta, batch, state_dim, nfe: NfeCounter::default(), t_buf: RefCell::new([0.0]) })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn state_dim(&self) -> usize {
        self.state_dim
    }

    pub fn artifacts(&self) -> &ModelArtifacts {
        &self.arts
    }

    fn run1(&self, prim: &str, t: f64, u: &[f32], extra: Option<&[f32]>, out: &mut [f32]) {
        self.t_buf.borrow_mut()[0] = t as f32;
        let tb = self.t_buf.borrow();
        // lint:allow(panic): load() verified every manifest primitive before constructing the RHS
        let exe = self.arts.get(prim).expect("primitive loaded");
        let res = match extra {
            Some(v) => exe.call(&[u, &self.theta, &tb[..], v]),
            None => exe.call(&[u, &self.theta, &tb[..]]),
        }
        // lint:allow(panic): a failed XLA execution mid-integration is unrecoverable; the message carries the primitive and error chain
        .unwrap_or_else(|e| panic!("XLA {prim} failed: {e:#}"));
        out.copy_from_slice(&res[0]);
    }
}

impl OdeRhs for XlaRhs {
    fn state_len(&self) -> usize {
        self.batch * self.state_dim
    }

    fn param_len(&self) -> usize {
        self.theta.len()
    }

    fn params(&self) -> &[f32] {
        &self.theta
    }

    fn set_params(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }

    fn f(&self, t: f64, u: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        self.run1("f", t, u, None, out);
    }

    fn vjp_u(&self, t: f64, u: &[f32], v: &[f32], out: &mut [f32]) {
        self.nfe.hit_backward();
        self.run1("vjp_u", t, u, Some(v), out);
    }

    fn vjp_both(&self, t: f64, u: &[f32], v: &[f32], out_u: &mut [f32], grad_theta: &mut [f32]) {
        self.nfe.hit_backward();
        self.t_buf.borrow_mut()[0] = t as f32;
        let tb = self.t_buf.borrow();
        // lint:allow(panic): load() verified every manifest primitive before constructing the RHS
        let exe = self.arts.get("vjp_both").expect("vjp_both loaded");
        let res = exe
            .call(&[u, &self.theta, &tb[..], v])
            // lint:allow(panic): a failed XLA execution mid-integration is unrecoverable; the message carries the error chain
            .unwrap_or_else(|e| panic!("XLA vjp_both failed: {e:#}"));
        out_u.copy_from_slice(&res[0]);
        for (g, d) in grad_theta.iter_mut().zip(&res[1]) {
            *g += d;
        }
    }

    fn jvp(&self, t: f64, u: &[f32], w: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        self.run1("jvp", t, u, Some(w), out);
    }

    fn nfe(&self) -> Nfe {
        self.nfe.get()
    }

    fn reset_nfe(&self) {
        self.nfe.reset();
    }

    fn activation_bytes_per_eval(&self) -> u64 {
        // same formula as the Rust mirror: per-layer inputs + preactivations
        let dims = &self.arts.entry.dims;
        let mut elems = 0usize;
        for w in dims.windows(2) {
            elems += self.batch * w[0] + self.batch * w[1];
        }
        (elems * 4) as u64
    }
}

/// Augmented CNF dynamics backed by PJRT executables (`faug`, `vjp_aug`).
///
/// State layout: `[x (B*D) | logp (B)]` flattened; ε is the Hutchinson
/// probe, fixed per training iteration (`set_eps`).
pub struct XlaCnfRhs {
    arts: ModelArtifacts,
    theta: Vec<f32>,
    batch: usize,
    dim: usize,
    eps: Vec<f32>,
    nfe: NfeCounter,
    t_buf: RefCell<[f32; 1]>,
}

impl XlaCnfRhs {
    pub fn new(arts: ModelArtifacts, theta: Vec<f32>) -> Result<Self> {
        anyhow::ensure!(arts.entry.kind == "cnf", "XlaCnfRhs wants a 'cnf' config");
        anyhow::ensure!(theta.len() == arts.entry.param_count);
        let batch = arts.entry.batch;
        let dim = arts.entry.state_dim;
        Ok(XlaCnfRhs {
            arts,
            theta,
            batch,
            dim,
            eps: vec![1.0; batch * dim],
            nfe: NfeCounter::default(),
            t_buf: RefCell::new([0.0]),
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Set the Hutchinson probe for this iteration.
    pub fn set_eps(&mut self, eps: &[f32]) {
        assert_eq!(eps.len(), self.batch * self.dim);
        self.eps.copy_from_slice(eps);
    }

    fn split<'a>(&self, u: &'a [f32]) -> (&'a [f32], &'a [f32]) {
        u.split_at(self.batch * self.dim)
    }
}

impl OdeRhs for XlaCnfRhs {
    fn state_len(&self) -> usize {
        self.batch * self.dim + self.batch
    }

    fn param_len(&self) -> usize {
        self.theta.len()
    }

    fn params(&self) -> &[f32] {
        &self.theta
    }

    fn set_params(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }

    fn f(&self, t: f64, u: &[f32], out: &mut [f32]) {
        self.nfe.hit_forward();
        let (x, _logp) = self.split(u);
        self.t_buf.borrow_mut()[0] = t as f32;
        let tb = self.t_buf.borrow();
        // lint:allow(panic): load() verified every manifest primitive before constructing the RHS
        let exe = self.arts.get("faug").expect("faug loaded");
        let res = exe
            .call(&[x, &self.theta, &tb[..], &self.eps])
            // lint:allow(panic): a failed XLA execution mid-integration is unrecoverable; the message carries the error chain
            .unwrap_or_else(|e| panic!("XLA faug failed: {e:#}"));
        let nd = self.batch * self.dim;
        out[..nd].copy_from_slice(&res[0]);
        out[nd..].copy_from_slice(&res[1]);
    }

    fn vjp_u(&self, t: f64, u: &[f32], v: &[f32], out: &mut [f32]) {
        // CNF adjoint always needs θ grads too; route through vjp_both and
        // drop them (only used by continuous-adjoint baselines).
        let mut scratch = vec![0.0f32; self.theta.len()];
        self.vjp_both(t, u, v, out, &mut scratch);
        // vjp_both already counted backward NFE
    }

    fn vjp_both(&self, t: f64, u: &[f32], v: &[f32], out_u: &mut [f32], grad_theta: &mut [f32]) {
        self.nfe.hit_backward();
        let (x, _) = self.split(u);
        let nd = self.batch * self.dim;
        let (vx, vlogp) = v.split_at(nd);
        self.t_buf.borrow_mut()[0] = t as f32;
        let tb = self.t_buf.borrow();
        // lint:allow(panic): load() verified every manifest primitive before constructing the RHS
        let exe = self.arts.get("vjp_aug").expect("vjp_aug loaded");
        let res = exe
            .call(&[x, &self.theta, &tb[..], &self.eps, vx, vlogp])
            // lint:allow(panic): a failed XLA execution mid-integration is unrecoverable; the message carries the error chain
            .unwrap_or_else(|e| panic!("XLA vjp_aug failed: {e:#}"));
        out_u[..nd].copy_from_slice(&res[0]);
        // d(dynamics)/d(logp) = 0: logp never feeds back into f
        out_u[nd..].fill(0.0);
        for (g, d) in grad_theta.iter_mut().zip(&res[1]) {
            *g += d;
        }
    }

    fn jvp(&self, _t: f64, _u: &[f32], _w: &[f32], _out: &mut [f32]) {
        unimplemented!("CNF tasks use explicit schemes only (no jvp artifact)")
    }

    fn nfe(&self) -> Nfe {
        self.nfe.get()
    }

    fn reset_nfe(&self) {
        self.nfe.reset();
    }

    fn activation_bytes_per_eval(&self) -> u64 {
        let dims = &self.arts.entry.dims;
        let mut elems = 0usize;
        for w in dims.windows(2) {
            elems += self.batch * w[0] + self.batch * w[1];
        }
        // the Hutchinson JVP roughly doubles the forward graph
        (2 * elems * 4) as u64
    }
}
