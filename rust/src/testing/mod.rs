//! Test-support utilities (property-test runner, tolerances).

pub mod prop;

/// Assert two slices are elementwise close.
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f64, atol: f64, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * (*w as f64).abs();
        let diff = (*g as f64 - *w as f64).abs();
        assert!(
            diff <= tol,
            "{ctx}: index {i}: got {g}, want {w}, |diff| {diff:.3e} > tol {tol:.3e}"
        );
    }
}

/// Relative L2 error between two vectors.
pub fn rel_l2(got: &[f32], want: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (g, w) in got.iter().zip(want) {
        num += ((*g - *w) as f64).powi(2);
        den += (*w as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}
