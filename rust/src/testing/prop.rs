//! Miniature property-testing runner (the offline registry has no proptest).
//!
//! `check` runs a property over `n` seeded random cases; on failure it
//! reports the failing case index and the exact seed so the case replays
//! deterministically with `replay`.

use crate::util::rng::Rng;

/// Run `property` over `n` cases derived from `base_seed`.
/// The property returns `Err(message)` to signal a counterexample.
pub fn check<F>(name: &str, base_seed: u64, n: usize, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..n {
        let seed = case_seed(base_seed, case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{n} (seed {seed:#x}):\n  {msg}\n  \
                 replay with testing::prop::replay({seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F>(seed: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    property(&mut rng).expect("replayed property failed");
}

fn case_seed(base: u64, case: usize) -> u64 {
    base.wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(case as u64)
        .rotate_left(17)
        | 1
}

// ---------- common generators ----------

/// Random vector with entries in [-scale, scale].
pub fn vec_uniform(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.uniform(-scale as f64, scale as f64) as f32).collect()
}

/// Random standard-normal vector.
pub fn vec_normal(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut v = vec![0.0; len];
    rng.fill_normal(&mut v);
    v
}

/// Random size in [lo, hi].
pub fn size_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("dot-commutes", 1, 50, |rng| {
            let n = size_in(rng, 1, 32);
            let x = vec_normal(rng, n);
            let y = vec_normal(rng, n);
            let a = crate::tensor::dot(&x, &y);
            let b = crate::tensor::dot(&y, &x);
            if (a - b).abs() < 1e-9 {
                Ok(())
            } else {
                Err(format!("{a} != {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn reports_counterexample() {
        check("always-fails", 2, 3, |_rng| Err("nope".to_string()));
    }

    #[test]
    fn seeds_are_distinct() {
        let s: Vec<u64> = (0..100).map(|i| case_seed(42, i)).collect();
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), s.len());
    }
}
