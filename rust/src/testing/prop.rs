//! Miniature property-testing runner (the offline registry has no proptest).
//!
//! `check` runs a property over `n` seeded random cases; on failure it
//! reports the failing case index and the exact seed so the case replays
//! deterministically with `replay`.

use crate::util::rng::Rng;

/// Run `property` over `n` cases derived from `base_seed`.
/// The property returns `Err(message)` to signal a counterexample.
pub fn check<F>(name: &str, base_seed: u64, n: usize, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..n {
        let seed = case_seed(base_seed, case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{n} (seed {seed:#x}):\n  {msg}\n  \
                 replay with testing::prop::replay({seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F>(seed: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    property(&mut rng).expect("replayed property failed");
}

fn case_seed(base: u64, case: usize) -> u64 {
    base.wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(case as u64)
        .rotate_left(17)
        | 1
}

// ---------- common generators ----------

/// Random vector with entries in [-scale, scale].
pub fn vec_uniform(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.uniform(-scale as f64, scale as f64) as f32).collect()
}

/// Random standard-normal vector.
pub fn vec_normal(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut v = vec![0.0; len];
    rng.fill_normal(&mut v);
    v
}

/// Random size in [lo, hi].
pub fn size_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

// ---------- per-module derivative properties ----------
//
// Shared by the nn::module unit tests and the gradcheck integration suite:
// every module must satisfy vjp/jvp duality, match finite differences of
// its forward map, and (for the second-order pass) match finite
// differences of its *jvp* map.

use crate::nn::module::Module;

/// Evaluate `m` at `(bsz, t, θ, x)` with fresh buffers; returns `y` and
/// leaves the forward cache in the returned arena.
pub fn module_eval(
    m: &dyn Module,
    bsz: usize,
    t: f64,
    theta: &[f32],
    x: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; bsz * m.out_dim()];
    let mut cache = vec![0.0f32; m.cache_len(bsz)];
    m.forward(bsz, t, theta, x, &mut y, &mut cache);
    (y, cache)
}

/// Adjoint consistency `⟨v, J w⟩ == ⟨Jᵀ v, w⟩` at a random point.
pub fn module_duality(
    m: &dyn Module,
    bsz: usize,
    t: f64,
    theta: &[f32],
    rng: &mut Rng,
) -> Result<(), String> {
    let x = vec_normal(rng, bsz * m.in_dim());
    let w = vec_normal(rng, bsz * m.in_dim());
    let v = vec_normal(rng, bsz * m.out_dim());
    let (_y, cache) = module_eval(m, bsz, t, theta, &x);
    let mut jw = vec![0.0f32; bsz * m.out_dim()];
    m.jvp(bsz, t, theta, &w, &mut jw, &cache);
    let mut jtv = vec![0.0f32; bsz * m.in_dim()];
    m.vjp(bsz, t, theta, &v, &mut jtv, None, &cache);
    let lhs = crate::tensor::dot(&v, &jw);
    let rhs = crate::tensor::dot(&jtv, &w);
    if (lhs - rhs).abs() > 1e-4 * (1.0 + lhs.abs()) {
        return Err(format!("duality broken: <v,Jw> {lhs} != <J^T v,w> {rhs}"));
    }
    Ok(())
}

/// Central-difference check of `vjp` — both the input gradient and the
/// parameter gradient of `L = ⟨v, f(x, θ, t)⟩`.
pub fn module_fd(
    m: &dyn Module,
    bsz: usize,
    t: f64,
    theta: &[f32],
    rng: &mut Rng,
) -> Result<(), String> {
    let x = vec_normal(rng, bsz * m.in_dim());
    let v = vec_normal(rng, bsz * m.out_dim());
    let (_y, cache) = module_eval(m, bsz, t, theta, &x);
    let mut gx = vec![0.0f32; bsz * m.in_dim()];
    let mut gt = vec![0.0f32; m.param_len()];
    m.vjp(bsz, t, theta, &v, &mut gx, Some(&mut gt), &cache);

    let loss = |theta: &[f32], x: &[f32]| -> f64 {
        let (y, _) = module_eval(m, bsz, t, theta, x);
        crate::tensor::dot(&v, &y)
    };
    let h = 1e-3f32;
    for idx in 0..x.len() {
        let mut xp = x.clone();
        xp[idx] += h;
        let mut xm = x.clone();
        xm[idx] -= h;
        let fd = (loss(theta, &xp) - loss(theta, &xm)) / (2.0 * h as f64);
        if (fd - gx[idx] as f64).abs() > 2e-2 * (1.0 + fd.abs()) {
            return Err(format!("gx[{idx}] {} vs fd {fd}", gx[idx]));
        }
    }
    for idx in theta_probe_indices(theta.len()) {
        let mut tp = theta.to_vec();
        tp[idx] += h;
        let mut tm = theta.to_vec();
        tm[idx] -= h;
        let fd = (loss(&tp, &x) - loss(&tm, &x)) / (2.0 * h as f64);
        if (fd - gt[idx] as f64).abs() > 2e-2 * (1.0 + fd.abs()) {
            return Err(format!("gθ[{idx}] {} vs fd {fd}", gt[idx]));
        }
    }
    Ok(())
}

/// Central-difference check of the directional second-order adjoint:
/// `sovjp` must match finite differences of `S(x, θ) = ⟨u, J(x, θ)·w⟩`
/// (with `Jw` evaluated through `jvp`).
pub fn module_sovjp_fd(
    m: &dyn Module,
    bsz: usize,
    t: f64,
    theta: &[f32],
    rng: &mut Rng,
) -> Result<(), String> {
    let x = vec_normal(rng, bsz * m.in_dim());
    let w = vec_normal(rng, bsz * m.in_dim());
    let u = vec_normal(rng, bsz * m.out_dim());
    let mut gx = vec![0.0f32; bsz * m.in_dim()];
    let mut gt = vec![0.0f32; m.param_len()];
    let mut cache = vec![0.0f32; m.cache_len(bsz)];
    m.sovjp(bsz, t, theta, &x, &w, &u, &mut gx, Some(&mut gt), &mut cache);

    let pairing = |theta: &[f32], x: &[f32]| -> f64 {
        let (_y, cache) = module_eval(m, bsz, t, theta, x);
        let mut jw = vec![0.0f32; bsz * m.out_dim()];
        m.jvp(bsz, t, theta, &w, &mut jw, &cache);
        crate::tensor::dot(&u, &jw)
    };
    let h = 1e-3f32;
    for idx in 0..x.len() {
        let mut xp = x.clone();
        xp[idx] += h;
        let mut xm = x.clone();
        xm[idx] -= h;
        let fd = (pairing(theta, &xp) - pairing(theta, &xm)) / (2.0 * h as f64);
        if (fd - gx[idx] as f64).abs() > 5e-2 * (1.0 + fd.abs()) {
            return Err(format!("sovjp gx[{idx}] {} vs fd {fd}", gx[idx]));
        }
    }
    for idx in theta_probe_indices(theta.len()) {
        let mut tp = theta.to_vec();
        tp[idx] += h;
        let mut tm = theta.to_vec();
        tm[idx] -= h;
        let fd = (pairing(&tp, &x) - pairing(&tm, &x)) / (2.0 * h as f64);
        if (fd - gt[idx] as f64).abs() > 5e-2 * (1.0 + fd.abs()) {
            return Err(format!("sovjp gθ[{idx}] {} vs fd {fd}", gt[idx]));
        }
    }
    Ok(())
}

/// Up to 8 probe indices spread over a parameter vector (empty when the
/// module has no parameters).
fn theta_probe_indices(p: usize) -> Vec<usize> {
    if p == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..8.min(p)).map(|i| i * p / 8.min(p)).collect();
    idx.push(p - 1);
    idx.sort_unstable();
    idx.dedup();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("dot-commutes", 1, 50, |rng| {
            let n = size_in(rng, 1, 32);
            let x = vec_normal(rng, n);
            let y = vec_normal(rng, n);
            let a = crate::tensor::dot(&x, &y);
            let b = crate::tensor::dot(&y, &x);
            if (a - b).abs() < 1e-9 {
                Ok(())
            } else {
                Err(format!("{a} != {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn reports_counterexample() {
        check("always-fails", 2, 3, |_rng| Err("nope".to_string()));
    }

    #[test]
    fn seeds_are_distinct() {
        let s: Vec<u64> = (0..100).map(|i| case_seed(42, i)).collect();
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), s.len());
    }
}
