//! ANODE zero-channel augmentation (Gholami et al., 2019): lift
//! `[B, d] → [B, d + extra]` by appending zero channels per sample.
//! Used by the tasks layer to lift data into an augmented ODE state; the
//! map is linear and constant, so every derivative pass is a pure
//! copy/truncate.

use crate::nn::module::Module;

#[derive(Clone, Debug)]
pub struct Augment {
    d: usize,
    extra: usize,
}

impl Augment {
    pub fn new(d: usize, extra: usize) -> Self {
        assert!(d > 0, "augment needs a nonzero base dim");
        assert!(extra > 0, "augment with 0 extra channels is the identity — drop it");
        Augment { d, extra }
    }

    pub fn extra(&self) -> usize {
        self.extra
    }
}

#[allow(clippy::too_many_arguments)]
impl Module for Augment {
    fn in_dim(&self) -> usize {
        self.d
    }

    fn out_dim(&self) -> usize {
        self.d + self.extra
    }

    fn param_len(&self) -> usize {
        0
    }

    fn cache_len(&self, _bsz: usize) -> usize {
        0
    }

    fn max_width(&self) -> usize {
        self.d + self.extra
    }

    fn forward(
        &self,
        bsz: usize,
        _t: f64,
        _theta: &[f32],
        x: &[f32],
        y: &mut [f32],
        _cache: &mut [f32],
    ) {
        let (d, dd) = (self.d, self.d + self.extra);
        for r in 0..bsz {
            y[r * dd..r * dd + d].copy_from_slice(&x[r * d..(r + 1) * d]);
            y[r * dd + d..(r + 1) * dd].fill(0.0);
        }
    }

    fn vjp(
        &self,
        bsz: usize,
        _t: f64,
        _theta: &[f32],
        v: &[f32],
        gx: &mut [f32],
        _grad_theta: Option<&mut [f32]>,
        _cache: &[f32],
    ) {
        let (d, dd) = (self.d, self.d + self.extra);
        for r in 0..bsz {
            gx[r * d..(r + 1) * d].copy_from_slice(&v[r * dd..r * dd + d]);
        }
    }

    fn jvp(&self, bsz: usize, t: f64, theta: &[f32], dx: &[f32], dy: &mut [f32], cache: &[f32]) {
        // the pushforward of a constant linear map is the map itself
        let _ = cache;
        let mut dummy: [f32; 0] = [];
        self.forward(bsz, t, theta, dx, dy, &mut dummy);
    }

    fn sovjp(
        &self,
        bsz: usize,
        _t: f64,
        _theta: &[f32],
        _x: &[f32],
        _w: &[f32],
        _u: &[f32],
        gx: &mut [f32],
        _grad_theta: Option<&mut [f32]>,
        _cache: &mut [f32],
    ) {
        // J is constant: zero curvature
        gx[..bsz * self.d].fill(0.0);
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }
}
