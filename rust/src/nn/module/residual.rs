//! Residual wrapper `y = x + f(x)` (requires `f` to map `d → d`).
//! The identity path has zero parameters and zero curvature, so every
//! derivative pass is the inner module's plus the corresponding
//! passthrough term.

use std::cell::RefCell;

use crate::nn::module::Module;

pub struct Residual {
    inner: Box<dyn Module>,
    tmp: RefCell<Vec<f32>>,
}

impl Clone for Residual {
    fn clone(&self) -> Self {
        Residual { inner: self.inner.clone(), tmp: RefCell::default() }
    }
}

impl std::fmt::Debug for Residual {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Residual").field("dim", &self.in_dim()).finish()
    }
}

impl Residual {
    pub fn new(inner: Box<dyn Module>) -> Self {
        assert_eq!(
            inner.in_dim(),
            inner.out_dim(),
            "residual needs a square inner module (in == out)"
        );
        Residual { inner, tmp: RefCell::default() }
    }

    fn ensure_tmp(&self, n: usize) {
        let mut t = self.tmp.borrow_mut();
        if t.len() < n {
            t.resize(n, 0.0);
        }
    }
}

#[allow(clippy::too_many_arguments)]
impl Module for Residual {
    fn in_dim(&self) -> usize {
        self.inner.in_dim()
    }

    fn out_dim(&self) -> usize {
        self.inner.out_dim()
    }

    fn param_len(&self) -> usize {
        self.inner.param_len()
    }

    fn cache_len(&self, bsz: usize) -> usize {
        self.inner.cache_len(bsz)
    }

    fn max_width(&self) -> usize {
        self.inner.max_width()
    }

    fn forward(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        y: &mut [f32],
        cache: &mut [f32],
    ) {
        let n = bsz * self.in_dim();
        self.ensure_tmp(n);
        let mut tmp = self.tmp.borrow_mut();
        self.inner.forward(bsz, t, theta, x, &mut tmp[..n], cache);
        for i in 0..n {
            y[i] = x[i] + tmp[i];
        }
    }

    fn vjp(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        v: &[f32],
        gx: &mut [f32],
        grad_theta: Option<&mut [f32]>,
        cache: &[f32],
    ) {
        let n = bsz * self.in_dim();
        self.ensure_tmp(n);
        let mut tmp = self.tmp.borrow_mut();
        self.inner.vjp(bsz, t, theta, v, &mut tmp[..n], grad_theta, cache);
        for i in 0..n {
            gx[i] = v[i] + tmp[i];
        }
    }

    fn jvp(&self, bsz: usize, t: f64, theta: &[f32], dx: &[f32], dy: &mut [f32], cache: &[f32]) {
        let n = bsz * self.in_dim();
        self.ensure_tmp(n);
        let mut tmp = self.tmp.borrow_mut();
        self.inner.jvp(bsz, t, theta, dx, &mut tmp[..n], cache);
        for i in 0..n {
            dy[i] = dx[i] + tmp[i];
        }
    }

    fn sovjp(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        w: &[f32],
        u: &[f32],
        gx: &mut [f32],
        grad_theta: Option<&mut [f32]>,
        cache: &mut [f32],
    ) {
        // J = I + J_inner; the identity part is constant, so the whole
        // second-order term is the inner module's
        self.inner.sovjp(bsz, t, theta, x, w, u, gx, grad_theta, cache);
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }
}
