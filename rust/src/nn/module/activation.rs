//! Elementwise activation module `y_i = act(x_i)`, caching its input
//! (the pre-activation) for the derivative passes — exactly what the
//! legacy `Mlp` kept in its `pres` buffers.

use crate::nn::Act;
use crate::nn::module::Module;

#[derive(Clone, Debug)]
pub struct Activation {
    act: Act,
    d: usize,
}

impl Activation {
    pub fn new(act: Act, d: usize) -> Self {
        assert!(d > 0, "activation width must be nonzero");
        Activation { act, d }
    }

    pub fn act(&self) -> Act {
        self.act
    }
}

#[allow(clippy::too_many_arguments)]
impl Module for Activation {
    fn in_dim(&self) -> usize {
        self.d
    }

    fn out_dim(&self) -> usize {
        self.d
    }

    fn param_len(&self) -> usize {
        0
    }

    fn cache_len(&self, bsz: usize) -> usize {
        bsz * self.d
    }

    fn max_width(&self) -> usize {
        self.d
    }

    fn forward(
        &self,
        bsz: usize,
        _t: f64,
        _theta: &[f32],
        x: &[f32],
        y: &mut [f32],
        cache: &mut [f32],
    ) {
        let n = bsz * self.d;
        cache[..n].copy_from_slice(x);
        for i in 0..n {
            y[i] = self.act.apply(x[i]);
        }
    }

    fn vjp(
        &self,
        bsz: usize,
        _t: f64,
        _theta: &[f32],
        v: &[f32],
        gx: &mut [f32],
        _grad_theta: Option<&mut [f32]>,
        cache: &[f32],
    ) {
        for i in 0..bsz * self.d {
            gx[i] = v[i] * self.act.grad(cache[i]);
        }
    }

    fn jvp(&self, bsz: usize, _t: f64, _theta: &[f32], dx: &[f32], dy: &mut [f32], cache: &[f32]) {
        for i in 0..bsz * self.d {
            dy[i] = dx[i] * self.act.grad(cache[i]);
        }
    }

    fn sovjp(
        &self,
        bsz: usize,
        _t: f64,
        _theta: &[f32],
        x: &[f32],
        w: &[f32],
        u: &[f32],
        gx: &mut [f32],
        _grad_theta: Option<&mut [f32]>,
        cache: &mut [f32],
    ) {
        // ⟨u, a'(x) ⊙ w⟩  ⇒  gx_i = u_i w_i a''(x_i)
        let n = bsz * self.d;
        cache[..n].copy_from_slice(x);
        for i in 0..n {
            gx[i] = u[i] * w[i] * self.act.grad2(x[i]);
        }
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }

    fn as_activation(&self) -> Option<&Activation> {
        Some(self)
    }
}
