//! Composition `y = f_{K-1}(… f_1(f_0(x)))` with flat-parameter slicing
//! and a single shared cache arena.
//!
//! * θ layout: children's parameter slices concatenated in order (for a
//!   `Linear`/`Activation` chain this is exactly the legacy `Mlp` layout
//!   of `nn::init::layer_offsets`).
//! * cache layout: children's caches concatenated in order; the arena is
//!   carved with running offsets, no per-call allocation.
//! * work buffers: two ping-pong buffers of `bsz · max_width` floats in
//!   interior scratch carry the boundary values / cotangents between
//!   children.
//!
//! The second-order pass ([`Module::sovjp`]) runs the standard
//! Hessian-vector recursion over the chain: with boundaries
//! `b_{k+1} = f_k(b_k)`, tangents `w_{k+1} = J_k w_k` and the cotangent
//! chain `c_k = J_kᵀ c_{k+1}` (seeded `c_K = u`),
//!
//! ```text
//! ∇⟨u, J_{K-1}···J_0 w⟩ = Σ_k  (J_0ᵀ···J_{k-1}ᵀ) ∇_{b_k}⟨c_{k+1}, J_k w_k⟩
//! ```
//!
//! evaluated in one reverse sweep: each child contributes its direct
//! `sovjp` term, and the accumulated cotangent is pulled back through the
//! child's first-order `vjp` — which also collects the θ-gradients of the
//! earlier children the pullback passes through.

use std::cell::RefCell;

use crate::nn::module::Module;

#[derive(Clone, Debug, Default)]
struct SeqScratch {
    /// first-order ping-pong boundary buffers
    buf_a: Vec<f32>,
    buf_b: Vec<f32>,
    /// sovjp: all boundary values b_k, concatenated
    bounds: Vec<f32>,
    /// sovjp: all boundary tangents w_k, concatenated
    tans: Vec<f32>,
    /// sovjp: cotangent-chain ping-pong
    c_a: Vec<f32>,
    c_b: Vec<f32>,
    /// sovjp: accumulated second-order cotangent ping-pong
    acc_a: Vec<f32>,
    acc_b: Vec<f32>,
    /// sovjp: per-child direct term
    g_tmp: Vec<f32>,
    /// float offsets of boundary k inside `bounds`/`tans` (len K+2)
    b_off: Vec<usize>,
}

impl SeqScratch {
    fn ensure_work(&mut self, work: usize) {
        if self.buf_a.len() < work {
            self.buf_a.resize(work, 0.0);
            self.buf_b.resize(work, 0.0);
        }
    }

    fn ensure_sovjp(&mut self, work: usize, bounds_total: usize) {
        if self.c_a.len() < work {
            self.c_a.resize(work, 0.0);
            self.c_b.resize(work, 0.0);
            self.acc_a.resize(work, 0.0);
            self.acc_b.resize(work, 0.0);
            self.g_tmp.resize(work, 0.0);
        }
        if self.bounds.len() < bounds_total {
            self.bounds.resize(bounds_total, 0.0);
            self.tans.resize(bounds_total, 0.0);
        }
    }
}

pub struct Sequential {
    children: Vec<Box<dyn Module>>,
    /// θ offsets: child k owns `theta[theta_off[k]..theta_off[k+1]]`
    theta_off: Vec<usize>,
    max_width: usize,
    scratch: RefCell<SeqScratch>,
}

impl Clone for Sequential {
    fn clone(&self) -> Self {
        Sequential {
            children: self.children.clone(),
            theta_off: self.theta_off.clone(),
            max_width: self.max_width,
            scratch: RefCell::default(),
        }
    }
}

impl std::fmt::Debug for Sequential {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Sequential")
            .field("children", &self.children.len())
            .field("in_dim", &self.in_dim())
            .field("out_dim", &self.out_dim())
            .finish()
    }
}

impl Sequential {
    pub fn new(children: Vec<Box<dyn Module>>) -> Self {
        assert!(!children.is_empty(), "sequential needs at least one module");
        for pair in children.windows(2) {
            assert_eq!(
                pair[0].out_dim(),
                pair[1].in_dim(),
                "sequential dim mismatch between adjacent modules"
            );
        }
        let mut theta_off = Vec::with_capacity(children.len() + 1);
        theta_off.push(0);
        let mut acc = 0;
        let mut max_width = 0;
        for c in &children {
            acc += c.param_len();
            theta_off.push(acc);
            max_width = max_width.max(c.max_width());
        }
        Sequential { children, theta_off, max_width, scratch: RefCell::default() }
    }

    pub fn n_children(&self) -> usize {
        self.children.len()
    }

    fn theta_slice<'a>(&self, theta: &'a [f32], k: usize) -> &'a [f32] {
        &theta[self.theta_off[k]..self.theta_off[k + 1]]
    }

    /// Boundary float offsets at batch `bsz` written into `b_off`
    /// (boundary 0 = the input, boundary k+1 = child k's output).
    fn boundary_offsets(&self, bsz: usize, b_off: &mut Vec<usize>) -> usize {
        b_off.clear();
        b_off.push(0);
        let mut acc = bsz * self.in_dim();
        b_off.push(acc);
        for c in &self.children {
            acc += bsz * c.out_dim();
            b_off.push(acc);
        }
        acc
    }
}

#[allow(clippy::too_many_arguments)]
impl Module for Sequential {
    fn in_dim(&self) -> usize {
        self.children[0].in_dim()
    }

    fn out_dim(&self) -> usize {
        self.children[self.children.len() - 1].out_dim()
    }

    fn param_len(&self) -> usize {
        self.theta_off[self.children.len()]
    }

    fn cache_len(&self, bsz: usize) -> usize {
        self.children.iter().map(|c| c.cache_len(bsz)).sum()
    }

    fn max_width(&self) -> usize {
        self.max_width
    }

    fn forward(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        y: &mut [f32],
        cache: &mut [f32],
    ) {
        let k_n = self.children.len();
        if k_n == 1 {
            self.children[0].forward(bsz, t, self.theta_slice(theta, 0), x, y, cache);
            return;
        }
        let mut s = self.scratch.borrow_mut();
        s.ensure_work(bsz * self.max_width);
        let s = &mut *s;
        let (mut cur, mut nxt) = (&mut s.buf_a[..], &mut s.buf_b[..]);
        let mut c_off = 0;
        for (k, child) in self.children.iter().enumerate() {
            let cl = child.cache_len(bsz);
            let ck = &mut cache[c_off..c_off + cl];
            c_off += cl;
            let th = self.theta_slice(theta, k);
            let din = bsz * child.in_dim();
            let dout = bsz * child.out_dim();
            if k == 0 {
                child.forward(bsz, t, th, x, &mut nxt[..dout], ck);
            } else if k + 1 == k_n {
                child.forward(bsz, t, th, &cur[..din], y, ck);
                return;
            } else {
                child.forward(bsz, t, th, &cur[..din], &mut nxt[..dout], ck);
            }
            std::mem::swap(&mut cur, &mut nxt);
        }
    }

    fn vjp(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        v: &[f32],
        gx: &mut [f32],
        mut grad_theta: Option<&mut [f32]>,
        cache: &[f32],
    ) {
        let k_n = self.children.len();
        if k_n == 1 {
            self.children[0].vjp(bsz, t, self.theta_slice(theta, 0), v, gx, grad_theta, cache);
            return;
        }
        let mut s = self.scratch.borrow_mut();
        s.ensure_work(bsz * self.max_width);
        let s = &mut *s;
        let (mut cur, mut nxt) = (&mut s.buf_a[..], &mut s.buf_b[..]);
        let mut c_end = self.cache_len(bsz);
        for k in (0..k_n).rev() {
            let child = &self.children[k];
            let cl = child.cache_len(bsz);
            let ck = &cache[c_end - cl..c_end];
            c_end -= cl;
            let th = self.theta_slice(theta, k);
            let gt = grad_theta
                .as_deref_mut()
                .map(|g| &mut g[self.theta_off[k]..self.theta_off[k + 1]]);
            let din = bsz * child.in_dim();
            let dout = bsz * child.out_dim();
            let vin: &[f32] = if k + 1 == k_n { v } else { &cur[..dout] };
            if k == 0 {
                child.vjp(bsz, t, th, vin, gx, gt, ck);
            } else {
                child.vjp(bsz, t, th, vin, &mut nxt[..din], gt, ck);
                std::mem::swap(&mut cur, &mut nxt);
            }
        }
    }

    fn jvp(&self, bsz: usize, t: f64, theta: &[f32], dx: &[f32], dy: &mut [f32], cache: &[f32]) {
        let k_n = self.children.len();
        if k_n == 1 {
            self.children[0].jvp(bsz, t, self.theta_slice(theta, 0), dx, dy, cache);
            return;
        }
        let mut s = self.scratch.borrow_mut();
        s.ensure_work(bsz * self.max_width);
        let s = &mut *s;
        let (mut cur, mut nxt) = (&mut s.buf_a[..], &mut s.buf_b[..]);
        let mut c_off = 0;
        for (k, child) in self.children.iter().enumerate() {
            let cl = child.cache_len(bsz);
            let ck = &cache[c_off..c_off + cl];
            c_off += cl;
            let th = self.theta_slice(theta, k);
            let din = bsz * child.in_dim();
            let dout = bsz * child.out_dim();
            if k == 0 {
                child.jvp(bsz, t, th, dx, &mut nxt[..dout], ck);
            } else if k + 1 == k_n {
                child.jvp(bsz, t, th, &cur[..din], dy, ck);
                return;
            } else {
                child.jvp(bsz, t, th, &cur[..din], &mut nxt[..dout], ck);
            }
            std::mem::swap(&mut cur, &mut nxt);
        }
    }

    fn sovjp(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        w: &[f32],
        u: &[f32],
        gx: &mut [f32],
        mut grad_theta: Option<&mut [f32]>,
        cache: &mut [f32],
    ) {
        let k_n = self.children.len();
        if k_n == 1 {
            self.children[0]
                .sovjp(bsz, t, self.theta_slice(theta, 0), x, w, u, gx, grad_theta, cache);
            return;
        }
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        let bounds_total = self.boundary_offsets(bsz, &mut s.b_off);
        s.ensure_sovjp(bsz * self.max_width, bounds_total);
        let SeqScratch { bounds, tans, c_a, c_b, acc_a, acc_b, g_tmp, b_off, .. } = s;

        // 1. forward sweep: boundaries b_k (children write their caches)
        bounds[b_off[0]..b_off[1]].copy_from_slice(x);
        let mut c_off = 0;
        for (k, child) in self.children.iter().enumerate() {
            let cl = child.cache_len(bsz);
            let th = self.theta_slice(theta, k);
            let (head, tail) = bounds.split_at_mut(b_off[k + 1]);
            let out_len = b_off[k + 2] - b_off[k + 1];
            child.forward(
                bsz,
                t,
                th,
                &head[b_off[k]..],
                &mut tail[..out_len],
                &mut cache[c_off..c_off + cl],
            );
            c_off += cl;
        }

        // 2. tangent sweep: w_k = J_{k-1} w_{k-1}
        tans[b_off[0]..b_off[1]].copy_from_slice(w);
        let mut c_off = 0;
        for (k, child) in self.children.iter().enumerate() {
            let cl = child.cache_len(bsz);
            let th = self.theta_slice(theta, k);
            let (head, tail) = tans.split_at_mut(b_off[k + 1]);
            let out_len = b_off[k + 2] - b_off[k + 1];
            let ck = &cache[c_off..c_off + cl];
            child.jvp(bsz, t, th, &head[b_off[k]..], &mut tail[..out_len], ck);
            c_off += cl;
        }

        // 3. reverse sweep: direct sovjp terms + first-order pullbacks
        let u_len = bsz * self.out_dim();
        c_a[..u_len].copy_from_slice(u);
        acc_a[..u_len].fill(0.0);
        let (mut c_cur, mut c_nxt) = (&mut c_a[..], &mut c_b[..]);
        let (mut a_cur, mut a_nxt) = (&mut acc_a[..], &mut acc_b[..]);
        let mut c_end = self.cache_len(bsz);
        for k in (0..k_n).rev() {
            let child = &self.children[k];
            let cl = child.cache_len(bsz);
            let c_lo = c_end - cl;
            c_end = c_lo;
            let th = self.theta_slice(theta, k);
            let din = bsz * child.in_dim();
            let dout = bsz * child.out_dim();
            let bk = &bounds[b_off[k]..b_off[k] + din];
            let wk = &tans[b_off[k]..b_off[k] + din];
            // direct term: ∇_{b_k}⟨c_{k+1}, J_k w_k⟩ (+ its θ grads)
            let gt = grad_theta
                .as_deref_mut()
                .map(|g| &mut g[self.theta_off[k]..self.theta_off[k + 1]]);
            child.sovjp(
                bsz,
                t,
                th,
                bk,
                wk,
                &c_cur[..dout],
                &mut g_tmp[..din],
                gt,
                &mut cache[c_lo..c_lo + cl],
            );
            // pull the accumulated cotangent back through J_kᵀ, collecting
            // this child's θ grads of the pullback
            let gt = grad_theta
                .as_deref_mut()
                .map(|g| &mut g[self.theta_off[k]..self.theta_off[k + 1]]);
            child.vjp(bsz, t, th, &a_cur[..dout], &mut a_nxt[..din], gt, &cache[c_lo..c_lo + cl]);
            for i in 0..din {
                a_nxt[i] += g_tmp[i];
            }
            std::mem::swap(&mut a_cur, &mut a_nxt);
            // cotangent chain for the next (earlier) child
            if k > 0 {
                let ck = &cache[c_lo..c_lo + cl];
                child.vjp(bsz, t, th, &c_cur[..dout], &mut c_nxt[..din], None, ck);
                std::mem::swap(&mut c_cur, &mut c_nxt);
            }
        }
        gx[..bsz * self.in_dim()].copy_from_slice(&a_cur[..bsz * self.in_dim()]);
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }
}
