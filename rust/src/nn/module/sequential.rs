//! Composition `y = f_{K-1}(… f_1(f_0(x)))` with flat-parameter slicing,
//! a single shared cache arena, and a kernel-fusion plan.
//!
//! * θ layout: children's parameter slices concatenated in order (for a
//!   `Linear`/`Activation` chain this is exactly the legacy `Mlp` layout
//!   of `nn::init::layer_offsets`).
//! * cache layout: children's caches concatenated in order; the arena is
//!   carved with running offsets, no per-call allocation.
//! * work buffers: two ping-pong buffers of `bsz · max_width` floats in
//!   interior scratch carry the boundary values / cotangents between
//!   children.
//!
//! **Fusion plan** (DESIGN.md §12): at construction the chain is walked
//! once and every `Linear` immediately followed by an `Activation`
//! (detected via [`Module::as_linear`] / [`Module::as_activation`])
//! collapses into one plan step.  A fused step evaluates the GEMM, the
//! bias add, and the activation in a single pass over each output row
//! while it is still cache-hot ([`sgemm_epi2`]), and its VJP computes
//! `gz = v ⊙ act'(z)` with the bias gradient folded into the same sweep.
//! The per-element arithmetic — one add for the bias, the same
//! elementwise multiply order, the same `sgemm_at`/`sgemm_bt` calls — is
//! identical to the unfused module composition, so fused results are
//! bitwise equal to the legacy child-by-child evaluation on the same
//! kernel path (pinned by `nn::mlp`'s recomposition test).  The cache
//! layout is also unchanged: the Linear slot holds the layer input, the
//! Activation slot the pre-activation.
//!
//! **Time-augmented entry** (`*_time_aug`): for [`super::ConcatTime`]
//! dynamics the first Linear consumes `[x | t]`.  Folding the constant
//! `t` column into an effective bias `b_eff = b + t·W[d,:]` lets the
//! fused first step run the GEMM at `k = d` straight off the un-augmented
//! input — no `[B, d+1]` copy on the jvp path, no cotangent stripping on
//! the vjp path.  The augmented input is still written into the Linear's
//! cache (the weight gradient needs the `t` column).  Note `b_eff`
//! associates `b + t·w` before the row sum, so the fused forward may
//! differ from the unfused augment path in the last ulp — the fused path
//! is used consistently for forward/vjp/jvp, and nothing pins those two
//! evaluations bitwise against each other (`sovjp` stays on the augment
//! path; see the contract note in DESIGN.md §12).
//!
//! The second-order pass ([`Module::sovjp`]) runs the standard
//! Hessian-vector recursion over the chain: with boundaries
//! `b_{k+1} = f_k(b_k)`, tangents `w_{k+1} = J_k w_k` and the cotangent
//! chain `c_k = J_kᵀ c_{k+1}` (seeded `c_K = u`),
//!
//! ```text
//! ∇⟨u, J_{K-1}···J_0 w⟩ = Σ_k  (J_0ᵀ···J_{k-1}ᵀ) ∇_{b_k}⟨c_{k+1}, J_k w_k⟩
//! ```
//!
//! evaluated in one reverse sweep: each child contributes its direct
//! `sovjp` term, and the accumulated cotangent is pulled back through the
//! child's first-order `vjp` — which also collects the θ-gradients of the
//! earlier children the pullback passes through.  The sovjp sweep is
//! per-child (unfused); it benefits from the fast kernels but not from
//! step fusion.

use std::cell::RefCell;

use crate::nn::module::Module;
use crate::tensor::gemm::{sgemm_at, sgemm_bt, sgemm_epi, sgemm_epi2};

/// One step of the fusion plan.
#[derive(Clone, Copy, Debug)]
enum Step {
    /// child `k` evaluated through its own `Module` impl
    Child(usize),
    /// children `(k, k+1)` = Linear + Activation evaluated as one fused
    /// GEMM + epilogue pass
    LinAct(usize),
}

#[derive(Clone, Debug, Default)]
struct SeqScratch {
    /// first-order ping-pong boundary buffers
    buf_a: Vec<f32>,
    buf_b: Vec<f32>,
    /// fused vjp: gz = v ⊙ act'(z) (must not alias the incoming v)
    gz: Vec<f32>,
    /// time-aug fused first layer: b_eff = b + t·W[d,:]
    bias_eff: Vec<f32>,
    /// sovjp: all boundary values b_k, concatenated
    bounds: Vec<f32>,
    /// sovjp: all boundary tangents w_k, concatenated
    tans: Vec<f32>,
    /// sovjp: cotangent-chain ping-pong
    c_a: Vec<f32>,
    c_b: Vec<f32>,
    /// sovjp: accumulated second-order cotangent ping-pong
    acc_a: Vec<f32>,
    acc_b: Vec<f32>,
    /// sovjp: per-child direct term
    g_tmp: Vec<f32>,
    /// float offsets of boundary k inside `bounds`/`tans` (len K+2)
    b_off: Vec<usize>,
}

impl SeqScratch {
    fn ensure_work(&mut self, work: usize) {
        if self.buf_a.len() < work {
            self.buf_a.resize(work, 0.0);
            self.buf_b.resize(work, 0.0);
            self.gz.resize(work, 0.0);
            self.bias_eff.resize(work, 0.0);
        }
    }

    fn ensure_sovjp(&mut self, work: usize, bounds_total: usize) {
        if self.c_a.len() < work {
            self.c_a.resize(work, 0.0);
            self.c_b.resize(work, 0.0);
            self.acc_a.resize(work, 0.0);
            self.acc_b.resize(work, 0.0);
            self.g_tmp.resize(work, 0.0);
        }
        if self.bounds.len() < bounds_total {
            self.bounds.resize(bounds_total, 0.0);
            self.tans.resize(bounds_total, 0.0);
        }
    }
}

pub struct Sequential {
    children: Vec<Box<dyn Module>>,
    /// θ offsets: child k owns `theta[theta_off[k]..theta_off[k+1]]`
    theta_off: Vec<usize>,
    max_width: usize,
    /// fusion plan computed once at construction
    plan: Vec<Step>,
    scratch: RefCell<SeqScratch>,
}

impl Clone for Sequential {
    fn clone(&self) -> Self {
        Sequential {
            children: self.children.clone(),
            theta_off: self.theta_off.clone(),
            max_width: self.max_width,
            plan: self.plan.clone(),
            scratch: RefCell::default(),
        }
    }
}

impl std::fmt::Debug for Sequential {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Sequential")
            .field("children", &self.children.len())
            .field("fused_steps", &self.plan.len())
            .field("in_dim", &self.in_dim())
            .field("out_dim", &self.out_dim())
            .finish()
    }
}

impl Sequential {
    pub fn new(children: Vec<Box<dyn Module>>) -> Self {
        assert!(!children.is_empty(), "sequential needs at least one module");
        for pair in children.windows(2) {
            assert_eq!(
                pair[0].out_dim(),
                pair[1].in_dim(),
                "sequential dim mismatch between adjacent modules"
            );
        }
        let mut theta_off = Vec::with_capacity(children.len() + 1);
        theta_off.push(0);
        let mut acc = 0;
        let mut max_width = 0;
        for c in &children {
            acc += c.param_len();
            theta_off.push(acc);
            max_width = max_width.max(c.max_width());
        }
        let mut plan = Vec::with_capacity(children.len());
        let mut k = 0;
        while k < children.len() {
            let fuse = k + 1 < children.len()
                && children[k].as_linear().is_some()
                && children[k + 1].as_activation().is_some();
            if fuse {
                plan.push(Step::LinAct(k));
                k += 2;
            } else {
                plan.push(Step::Child(k));
                k += 1;
            }
        }
        Sequential { children, theta_off, max_width, plan, scratch: RefCell::default() }
    }

    pub fn n_children(&self) -> usize {
        self.children.len()
    }

    /// How many plan steps run fused Linear+Activation kernels.
    pub fn n_fused_steps(&self) -> usize {
        self.plan.iter().filter(|s| matches!(s, Step::LinAct(_))).count()
    }

    fn theta_slice<'a>(&self, theta: &'a [f32], k: usize) -> &'a [f32] {
        &theta[self.theta_off[k]..self.theta_off[k + 1]]
    }

    /// Boundary float offsets at batch `bsz` written into `b_off`
    /// (boundary 0 = the input, boundary k+1 = child k's output).
    fn boundary_offsets(&self, bsz: usize, b_off: &mut Vec<usize>) -> usize {
        b_off.clear();
        b_off.push(0);
        let mut acc = bsz * self.in_dim();
        b_off.push(acc);
        for c in &self.children {
            acc += bsz * c.out_dim();
            b_off.push(acc);
        }
        acc
    }

    /// Can [`Sequential::forward_time_aug`] & co. drive this stack?  The
    /// time-augmented entry needs the first step to be a fused
    /// Linear(+Activation) whose weight matrix owns the `t` column.
    pub(crate) fn supports_time_aug(&self) -> bool {
        matches!(self.plan.first(), Some(Step::LinAct(0)))
    }

    /// [`Module::forward`] with the first fused layer consuming the
    /// logical input `[x | t]` (x is `[B, in_dim − 1]`): the constant `t`
    /// column folds into an effective bias, the GEMM runs at `k = d`.
    /// Caller must check [`Sequential::supports_time_aug`].
    pub(crate) fn forward_time_aug(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        y: &mut [f32],
        cache: &mut [f32],
    ) {
        self.forward_impl(bsz, t, theta, x, y, cache, true);
    }

    /// [`Module::vjp`] counterpart of [`Sequential::forward_time_aug`]:
    /// `gx` is `[B, in_dim − 1]` (the `t` column's cotangent is dropped,
    /// exactly as the augment path strips it).
    pub(crate) fn vjp_time_aug(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        v: &[f32],
        gx: &mut [f32],
        grad_theta: Option<&mut [f32]>,
        cache: &[f32],
    ) {
        self.vjp_impl(bsz, t, theta, v, gx, grad_theta, cache, true);
    }

    /// [`Module::jvp`] counterpart: the `t` column's tangent is zero, so
    /// the first GEMM simply runs at `k = d` on the raw tangent.
    pub(crate) fn jvp_time_aug(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        dx: &[f32],
        dy: &mut [f32],
        cache: &[f32],
    ) {
        self.jvp_impl(bsz, t, theta, dx, dy, cache, true);
    }

    #[allow(clippy::too_many_arguments)]
    fn forward_impl(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        y: &mut [f32],
        cache: &mut [f32],
        aug: bool,
    ) {
        debug_assert!(!aug || self.supports_time_aug());
        let mut s = self.scratch.borrow_mut();
        s.ensure_work(bsz * self.max_width);
        let SeqScratch { buf_a, buf_b, bias_eff, .. } = &mut *s;
        let (mut cur, mut nxt) = (&mut buf_a[..], &mut buf_b[..]);
        let n_steps = self.plan.len();
        let mut c_off = 0;
        for (si, step) in self.plan.iter().enumerate() {
            let first = si == 0;
            let last = si + 1 == n_steps;
            match *step {
                Step::Child(k) => {
                    let child = &self.children[k];
                    let cl = child.cache_len(bsz);
                    let ck = &mut cache[c_off..c_off + cl];
                    c_off += cl;
                    let th = self.theta_slice(theta, k);
                    let din = bsz * child.in_dim();
                    let dout = bsz * child.out_dim();
                    let xin: &[f32] = if first { x } else { &cur[..din] };
                    if last {
                        child.forward(bsz, t, th, xin, y, ck);
                    } else {
                        child.forward(bsz, t, th, xin, &mut nxt[..dout], ck);
                    }
                }
                Step::LinAct(k) => {
                    let lin = &self.children[k];
                    // lint:allow(panic): the step planner emits LinAct only when child k + 1 is an activation
                    let act = self.children[k + 1].as_activation().unwrap().act();
                    let dfull = lin.in_dim();
                    let dout = lin.out_dim();
                    let cl = bsz * (dfull + dout);
                    let (cx, cz) = cache[c_off..c_off + cl].split_at_mut(bsz * dfull);
                    c_off += cl;
                    let th = self.theta_slice(theta, k);
                    let (w, b) = th.split_at(dfull * dout);
                    let keff: usize;
                    let weff: &[f32];
                    let xin: &[f32];
                    if aug && first {
                        // write [x | t] into the Linear cache (gW needs
                        // the t column), but drive the GEMM off the raw
                        // x with b_eff = b + t·W[d,:]
                        let d = dfull - 1;
                        let tt = t as f32;
                        for (crow, xrow) in
                            cx.chunks_exact_mut(dfull).zip(x.chunks_exact(d))
                        {
                            crow[..d].copy_from_slice(xrow);
                            crow[d] = tt;
                        }
                        let be = &mut bias_eff[..dout];
                        for ((bj, wj), b0) in be.iter_mut().zip(&w[d * dout..]).zip(b) {
                            *bj = *b0 + tt * *wj;
                        }
                        keff = d;
                        weff = &w[..d * dout];
                        xin = x;
                    } else {
                        let src: &[f32] = if first { x } else { &cur[..bsz * dfull] };
                        cx.copy_from_slice(src);
                        keff = dfull;
                        weff = w;
                        xin = src;
                    }
                    let bias: &[f32] =
                        if aug && first { &bias_eff[..dout] } else { b };
                    let yout: &mut [f32] =
                        if last { &mut *y } else { &mut nxt[..bsz * dout] };
                    // z (the Activation cache) and y in one pass per row
                    sgemm_epi2(bsz, keff, dout, xin, weff, cz, yout, &|_, zrow, yrow| {
                        for ((zj, yj), bj) in
                            zrow.iter_mut().zip(yrow.iter_mut()).zip(bias)
                        {
                            let zv = *zj + *bj;
                            *zj = zv;
                            *yj = act.apply(zv);
                        }
                    });
                }
            }
            if !last {
                std::mem::swap(&mut cur, &mut nxt);
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn vjp_impl(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        v: &[f32],
        gx: &mut [f32],
        mut grad_theta: Option<&mut [f32]>,
        cache: &[f32],
        aug: bool,
    ) {
        debug_assert!(!aug || self.supports_time_aug());
        let mut s = self.scratch.borrow_mut();
        s.ensure_work(bsz * self.max_width);
        let SeqScratch { buf_a, buf_b, gz, .. } = &mut *s;
        let (mut cur, mut nxt) = (&mut buf_a[..], &mut buf_b[..]);
        let n_steps = self.plan.len();
        let mut c_end = self.cache_len(bsz);
        for (si, step) in self.plan.iter().enumerate().rev() {
            let first = si == 0;
            let last = si + 1 == n_steps;
            match *step {
                Step::Child(k) => {
                    let child = &self.children[k];
                    let cl = child.cache_len(bsz);
                    let ck = &cache[c_end - cl..c_end];
                    c_end -= cl;
                    let th = self.theta_slice(theta, k);
                    let gt = grad_theta
                        .as_deref_mut()
                        .map(|g| &mut g[self.theta_off[k]..self.theta_off[k + 1]]);
                    let din = bsz * child.in_dim();
                    let dout = bsz * child.out_dim();
                    let vin: &[f32] = if last { v } else { &cur[..dout] };
                    if first {
                        child.vjp(bsz, t, th, vin, gx, gt, ck);
                    } else {
                        child.vjp(bsz, t, th, vin, &mut nxt[..din], gt, ck);
                    }
                }
                Step::LinAct(k) => {
                    let lin = &self.children[k];
                    // lint:allow(panic): the step planner emits LinAct only when child k + 1 is an activation
                    let act = self.children[k + 1].as_activation().unwrap().act();
                    let dfull = lin.in_dim();
                    let dout = lin.out_dim();
                    let cl = bsz * (dfull + dout);
                    let ck = &cache[c_end - cl..c_end];
                    c_end -= cl;
                    let (cx, cz) = ck.split_at(bsz * dfull);
                    let th = self.theta_slice(theta, k);
                    let (w, _b) = th.split_at(dfull * dout);
                    let vin: &[f32] = if last { v } else { &cur[..bsz * dout] };
                    let gzs = &mut gz[..bsz * dout];
                    // gz = v ⊙ act'(z); when θ-grads are on, gb folds
                    // into the same sweep (same row-major accumulation
                    // order as the unfused column-sum loop)
                    let gt = grad_theta
                        .as_deref_mut()
                        .map(|g| &mut g[self.theta_off[k]..self.theta_off[k + 1]]);
                    if let Some(gt) = gt {
                        let (gw, gb) = gt.split_at_mut(dfull * dout);
                        for (gzrow, (vrow, zrow)) in gzs
                            .chunks_exact_mut(dout)
                            .zip(vin.chunks_exact(dout).zip(cz.chunks_exact(dout)))
                        {
                            for ((gj, gbj), (vj, zj)) in gzrow
                                .iter_mut()
                                .zip(gb.iter_mut())
                                .zip(vrow.iter().zip(zrow))
                            {
                                let g = *vj * act.grad(*zj);
                                *gj = g;
                                *gbj += g;
                            }
                        }
                        // gW += xᵀ gz (x = the cached layer input)
                        sgemm_at(dfull, bsz, dout, cx, gzs, gw, 1.0);
                    } else {
                        for (gj, (vj, zj)) in gzs.iter_mut().zip(vin.iter().zip(cz)) {
                            *gj = *vj * act.grad(*zj);
                        }
                    }
                    // gx = gz @ Wᵀ; on the time-aug first step the W rows
                    // 0..d are a contiguous prefix, so dropping the t
                    // cotangent is just a shorter n — no strip pass
                    if first {
                        if aug {
                            let d = dfull - 1;
                            sgemm_bt(bsz, dout, d, gzs, &w[..d * dout], gx, 0.0);
                        } else {
                            sgemm_bt(bsz, dout, dfull, gzs, w, gx, 0.0);
                        }
                    } else {
                        sgemm_bt(bsz, dout, dfull, gzs, w, &mut nxt[..bsz * dfull], 0.0);
                    }
                }
            }
            if !first {
                std::mem::swap(&mut cur, &mut nxt);
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn jvp_impl(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        dx: &[f32],
        dy: &mut [f32],
        cache: &[f32],
        aug: bool,
    ) {
        debug_assert!(!aug || self.supports_time_aug());
        let mut s = self.scratch.borrow_mut();
        s.ensure_work(bsz * self.max_width);
        let SeqScratch { buf_a, buf_b, .. } = &mut *s;
        let (mut cur, mut nxt) = (&mut buf_a[..], &mut buf_b[..]);
        let n_steps = self.plan.len();
        let mut c_off = 0;
        for (si, step) in self.plan.iter().enumerate() {
            let first = si == 0;
            let last = si + 1 == n_steps;
            match *step {
                Step::Child(k) => {
                    let child = &self.children[k];
                    let cl = child.cache_len(bsz);
                    let ck = &cache[c_off..c_off + cl];
                    c_off += cl;
                    let th = self.theta_slice(theta, k);
                    let din = bsz * child.in_dim();
                    let dout = bsz * child.out_dim();
                    let xin: &[f32] = if first { dx } else { &cur[..din] };
                    if last {
                        child.jvp(bsz, t, th, xin, dy, ck);
                    } else {
                        child.jvp(bsz, t, th, xin, &mut nxt[..dout], ck);
                    }
                }
                Step::LinAct(k) => {
                    let lin = &self.children[k];
                    // lint:allow(panic): the step planner emits LinAct only when child k + 1 is an activation
                    let act = self.children[k + 1].as_activation().unwrap().act();
                    let dfull = lin.in_dim();
                    let dout = lin.out_dim();
                    let cl = bsz * (dfull + dout);
                    let ck = &cache[c_off..c_off + cl];
                    c_off += cl;
                    let (_cx, cz) = ck.split_at(bsz * dfull);
                    let th = self.theta_slice(theta, k);
                    let (w, _b) = th.split_at(dfull * dout);
                    // the t column's tangent is zero on the aug path
                    let (keff, weff): (usize, &[f32]) = if aug && first {
                        (dfull - 1, &w[..(dfull - 1) * dout])
                    } else {
                        (dfull, w)
                    };
                    let xin: &[f32] = if first { dx } else { &cur[..bsz * keff] };
                    let dyout: &mut [f32] =
                        if last { &mut *dy } else { &mut nxt[..bsz * dout] };
                    sgemm_epi(bsz, keff, dout, xin, weff, dyout, &|i, yrow| {
                        let zrow = &cz[i * dout..(i + 1) * dout];
                        for (yj, zj) in yrow.iter_mut().zip(zrow) {
                            *yj *= act.grad(*zj);
                        }
                    });
                }
            }
            if !last {
                std::mem::swap(&mut cur, &mut nxt);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
impl Module for Sequential {
    fn in_dim(&self) -> usize {
        self.children[0].in_dim()
    }

    fn out_dim(&self) -> usize {
        self.children[self.children.len() - 1].out_dim()
    }

    fn param_len(&self) -> usize {
        self.theta_off[self.children.len()]
    }

    fn cache_len(&self, bsz: usize) -> usize {
        self.children.iter().map(|c| c.cache_len(bsz)).sum()
    }

    fn max_width(&self) -> usize {
        self.max_width
    }

    fn forward(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        y: &mut [f32],
        cache: &mut [f32],
    ) {
        self.forward_impl(bsz, t, theta, x, y, cache, false);
    }

    fn vjp(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        v: &[f32],
        gx: &mut [f32],
        grad_theta: Option<&mut [f32]>,
        cache: &[f32],
    ) {
        self.vjp_impl(bsz, t, theta, v, gx, grad_theta, cache, false);
    }

    fn jvp(&self, bsz: usize, t: f64, theta: &[f32], dx: &[f32], dy: &mut [f32], cache: &[f32]) {
        self.jvp_impl(bsz, t, theta, dx, dy, cache, false);
    }

    fn sovjp(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        w: &[f32],
        u: &[f32],
        gx: &mut [f32],
        mut grad_theta: Option<&mut [f32]>,
        cache: &mut [f32],
    ) {
        let k_n = self.children.len();
        if k_n == 1 {
            self.children[0]
                .sovjp(bsz, t, self.theta_slice(theta, 0), x, w, u, gx, grad_theta, cache);
            return;
        }
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        let bounds_total = self.boundary_offsets(bsz, &mut s.b_off);
        s.ensure_sovjp(bsz * self.max_width, bounds_total);
        let SeqScratch { bounds, tans, c_a, c_b, acc_a, acc_b, g_tmp, b_off, .. } = s;

        // 1. forward sweep: boundaries b_k (children write their caches)
        bounds[b_off[0]..b_off[1]].copy_from_slice(x);
        let mut c_off = 0;
        for (k, child) in self.children.iter().enumerate() {
            let cl = child.cache_len(bsz);
            let th = self.theta_slice(theta, k);
            let (head, tail) = bounds.split_at_mut(b_off[k + 1]);
            let out_len = b_off[k + 2] - b_off[k + 1];
            child.forward(
                bsz,
                t,
                th,
                &head[b_off[k]..],
                &mut tail[..out_len],
                &mut cache[c_off..c_off + cl],
            );
            c_off += cl;
        }

        // 2. tangent sweep: w_k = J_{k-1} w_{k-1}
        tans[b_off[0]..b_off[1]].copy_from_slice(w);
        let mut c_off = 0;
        for (k, child) in self.children.iter().enumerate() {
            let cl = child.cache_len(bsz);
            let th = self.theta_slice(theta, k);
            let (head, tail) = tans.split_at_mut(b_off[k + 1]);
            let out_len = b_off[k + 2] - b_off[k + 1];
            let ck = &cache[c_off..c_off + cl];
            child.jvp(bsz, t, th, &head[b_off[k]..], &mut tail[..out_len], ck);
            c_off += cl;
        }

        // 3. reverse sweep: direct sovjp terms + first-order pullbacks
        let u_len = bsz * self.out_dim();
        c_a[..u_len].copy_from_slice(u);
        acc_a[..u_len].fill(0.0);
        let (mut c_cur, mut c_nxt) = (&mut c_a[..], &mut c_b[..]);
        let (mut a_cur, mut a_nxt) = (&mut acc_a[..], &mut acc_b[..]);
        let mut c_end = self.cache_len(bsz);
        for k in (0..k_n).rev() {
            let child = &self.children[k];
            let cl = child.cache_len(bsz);
            let c_lo = c_end - cl;
            c_end = c_lo;
            let th = self.theta_slice(theta, k);
            let din = bsz * child.in_dim();
            let dout = bsz * child.out_dim();
            let bk = &bounds[b_off[k]..b_off[k] + din];
            let wk = &tans[b_off[k]..b_off[k] + din];
            // direct term: ∇_{b_k}⟨c_{k+1}, J_k w_k⟩ (+ its θ grads)
            let gt = grad_theta
                .as_deref_mut()
                .map(|g| &mut g[self.theta_off[k]..self.theta_off[k + 1]]);
            child.sovjp(
                bsz,
                t,
                th,
                bk,
                wk,
                &c_cur[..dout],
                &mut g_tmp[..din],
                gt,
                &mut cache[c_lo..c_lo + cl],
            );
            // pull the accumulated cotangent back through J_kᵀ, collecting
            // this child's θ grads of the pullback
            let gt = grad_theta
                .as_deref_mut()
                .map(|g| &mut g[self.theta_off[k]..self.theta_off[k + 1]]);
            child.vjp(bsz, t, th, &a_cur[..dout], &mut a_nxt[..din], gt, &cache[c_lo..c_lo + cl]);
            for i in 0..din {
                a_nxt[i] += g_tmp[i];
            }
            std::mem::swap(&mut a_cur, &mut a_nxt);
            // cotangent chain for the next (earlier) child
            if k > 0 {
                let ck = &cache[c_lo..c_lo + cl];
                child.vjp(bsz, t, th, &c_cur[..dout], &mut c_nxt[..din], None, ck);
                std::mem::swap(&mut c_cur, &mut c_nxt);
            }
        }
        gx[..bsz * self.in_dim()].copy_from_slice(&a_cur[..bsz * self.in_dim()]);
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }

    fn as_sequential(&self) -> Option<&Sequential> {
        Some(self)
    }
}
