//! Composable dynamics modules (DESIGN.md §10).
//!
//! A [`Module`] is a differentiable map `y = f(x, θ, t)` over flat f32
//! buffers: `x` is `[B, in_dim]` row-major, `θ` a flat parameter slice in
//! a layout the module defines, and `t` the scalar time every
//! time-conditioned module may read.  Modules are *stateless with respect
//! to parameters* — θ is always passed in — which makes a module graph
//! cheap to clone for batch sharding ([`Module::boxed_clone`]) and lets
//! one flat θ vector drive an arbitrary composition via parameter
//! slicing ([`Sequential`]).
//!
//! Derivative surface (everything the adjoint stack needs):
//!
//! * [`Module::forward`] — evaluate, writing the *forward cache* (layer
//!   inputs / pre-activations) into a caller-provided arena sized by
//!   [`Module::cache_len`] (the scratch plan — no per-call allocation);
//! * [`Module::vjp`] — cotangent pullback `gx = (∂y/∂x)ᵀ v`, accumulating
//!   `gθ += (∂y/∂θ)ᵀ v`, reading the cache of the latest `forward`;
//! * [`Module::jvp`] — tangent pushforward `dy = (∂y/∂x) dx` (same cache);
//! * [`Module::sovjp`] — the directional second-order adjoint
//!   `∇_{x,θ} ⟨u, J(x)·w⟩` (a Hessian-vector product along tangent `w`
//!   with output cotangent `u`).  This is what makes Hutchinson-trace CNF
//!   dynamics exactly differentiable: the adjoint of the trace estimate
//!   `εᵀ J ε` is `∇⟨·, Jε⟩`, a second-order quantity no first-order
//!   vjp/jvp pair can produce (see `tasks::cnf::HutchinsonCnfRhs`).
//!
//! Memory accounting: [`Module::activation_bytes`] is the summed
//! per-module cache footprint of one forward evaluation — the unit the
//! Table-2 memory model multiplies by AD-graph depth
//! ([`crate::methods::MemModel`]).  For the MLP composition it reproduces
//! the legacy closed form exactly (regression-tested in
//! `nn::mlp` and `methods::memmodel`).
//!
//! Implementations: [`Linear`], [`Activation`], [`Sequential`],
//! [`Residual`], [`ConcatTime`] / [`ConcatSquash`] (time-conditioned),
//! [`Augment`] (ANODE zero-channels).  Architectures are addressed by the
//! serializable [`ArchSpec`] and executed as an ODE right-hand side by
//! [`crate::ode::ModuleRhs`].

pub mod activation;
pub mod arch;
pub mod augment;
pub mod linear;
pub mod residual;
pub mod sequential;
pub mod time;

pub use activation::Activation;
pub use arch::ArchSpec;
pub use augment::Augment;
pub use linear::Linear;
pub use residual::Residual;
pub use sequential::Sequential;
pub use time::{ConcatSquash, ConcatTime};

/// A differentiable flat-buffer map `y = f(x, θ, t)`; see the module docs
/// for the buffer/caching contract shared by all methods.
///
/// `Send` (supertrait) so module graphs can move to the data-parallel
/// execution engine's worker threads inside their owning RHS; interior
/// scratch (RefCell) keeps them intentionally not `Sync` — a graph is
/// owned by exactly one shard.
#[allow(clippy::too_many_arguments)]
pub trait Module: Send {
    /// Input channels per sample.
    fn in_dim(&self) -> usize;

    /// Output channels per sample.
    fn out_dim(&self) -> usize;

    /// Flat parameter count (θ slice length this module consumes).
    fn param_len(&self) -> usize;

    /// Scratch plan: f32 slots of forward cache this module writes at
    /// batch `bsz` (what `vjp`/`jvp` read back).
    fn cache_len(&self, bsz: usize) -> usize;

    /// Widest per-sample boundary this module materialises anywhere in
    /// its graph (≥ `max(in_dim, out_dim)`); composites size their
    /// ping-pong work buffers as `bsz * max_width`.
    fn max_width(&self) -> usize;

    /// `y = f(x, θ, t)`, writing the forward cache.
    /// `x` is `[B, in_dim]`, `y` `[B, out_dim]`, `cache` exactly
    /// `cache_len(bsz)` long.
    fn forward(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        y: &mut [f32],
        cache: &mut [f32],
    );

    /// `gx = (∂y/∂x)ᵀ v` (overwritten); `gθ += (∂y/∂θ)ᵀ v` when `Some`.
    /// Reads the cache written by the latest `forward` at the same
    /// `(bsz, t, θ, x)`.
    fn vjp(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        v: &[f32],
        gx: &mut [f32],
        grad_theta: Option<&mut [f32]>,
        cache: &[f32],
    );

    /// `dy = (∂y/∂x) dx` (overwritten); reads the cache like [`Module::vjp`].
    fn jvp(&self, bsz: usize, t: f64, theta: &[f32], dx: &[f32], dy: &mut [f32], cache: &[f32]);

    /// Directional second-order adjoint:
    /// `gx = ∇_x ⟨u, J(x)·w⟩` (overwritten), `gθ += ∇_θ ⟨u, J(x)·w⟩`,
    /// where `J = ∂f/∂x` at `(x, θ, t)`, `w` is an input tangent
    /// `[B, in_dim]` and `u` an output cotangent `[B, out_dim]`.
    ///
    /// Self-contained: runs its own forward sweep and may clobber
    /// `cache` (with values identical to a plain `forward` at the same
    /// arguments, so first-order pullbacks stay valid afterwards).
    fn sovjp(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        w: &[f32],
        u: &[f32],
        gx: &mut [f32],
        grad_theta: Option<&mut [f32]>,
        cache: &mut [f32],
    );

    /// Fresh clone of the graph (scratch not shared) — the basis of
    /// [`crate::ode::OdeRhs::make_shard`] row sharding.
    fn boxed_clone(&self) -> Box<dyn Module>;

    /// Bytes of activations one forward eval materialises (batch
    /// included): the per-module unit of the Table-2 memory model.
    fn activation_bytes(&self, bsz: usize) -> u64 {
        (self.cache_len(bsz) * 4) as u64
    }

    /// Downcast hook for the kernel-fusion planner: `Some` iff this
    /// module is a [`Linear`].  Composites use it to pair a Linear with
    /// the following Activation into one fused GEMM+epilogue pass
    /// (DESIGN.md §12); the default keeps third-party modules opaque.
    fn as_linear(&self) -> Option<&Linear> {
        None
    }

    /// Downcast hook: `Some` iff this module is an [`Activation`].
    fn as_activation(&self) -> Option<&Activation> {
        None
    }

    /// Downcast hook: `Some` iff this module is a [`Sequential`] —
    /// [`ConcatTime`] uses it to hand the time column to the inner
    /// stack's fused first layer instead of materialising `[x | t]`.
    fn as_sequential(&self) -> Option<&Sequential> {
        None
    }
}

impl Clone for Box<dyn Module> {
    fn clone(&self) -> Self {
        self.boxed_clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Act;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    /// One of every module kind (composites built through `ArchSpec`, the
    /// way tasks address them).
    fn roster() -> Vec<(&'static str, Box<dyn Module>)> {
        vec![
            ("linear", Box::new(Linear::new(4, 3)) as Box<dyn Module>),
            ("act-tanh", Box::new(Activation::new(Act::Tanh, 5))),
            ("act-gelu", Box::new(Activation::new(Act::Gelu, 4))),
            ("act-sigmoid", Box::new(Activation::new(Act::Sigmoid, 3))),
            ("augment", Box::new(Augment::new(3, 2))),
            ("mlp-seq", ArchSpec::Mlp { hidden: vec![7, 5], act: Act::Tanh }.build(4)),
            (
                "concat-time",
                ArchSpec::ConcatMlp { hidden: vec![6], act: Act::Gelu }.build(3),
            ),
            (
                "concatsquash",
                ArchSpec::ConcatSquashMlp { hidden: vec![6, 5], act: Act::Tanh }.build(3),
            ),
            (
                "residual",
                ArchSpec::Residual(Box::new(ArchSpec::Mlp { hidden: vec![6], act: Act::Sigmoid }))
                    .build(4),
            ),
        ]
    }

    fn theta_for(m: &dyn Module, rng: &mut Rng) -> Vec<f32> {
        let mut theta = prop::vec_normal(rng, m.param_len());
        for v in theta.iter_mut() {
            *v *= 0.5;
        }
        theta
    }

    #[test]
    fn every_module_satisfies_vjp_jvp_duality() {
        for (name, m) in roster() {
            prop::check(&format!("module-duality-{name}"), 101, 8, |rng| {
                let theta = theta_for(m.as_ref(), rng);
                let t = rng.uniform(0.0, 1.0);
                prop::module_duality(m.as_ref(), 3, t, &theta, rng)
            });
        }
    }

    #[test]
    fn every_module_matches_finite_differences() {
        for (name, m) in roster() {
            prop::check(&format!("module-fd-{name}"), 103, 4, |rng| {
                let theta = theta_for(m.as_ref(), rng);
                let t = rng.uniform(0.0, 1.0);
                prop::module_fd(m.as_ref(), 2, t, &theta, rng)
            });
        }
    }

    #[test]
    fn every_module_second_order_matches_finite_differences() {
        for (name, m) in roster() {
            prop::check(&format!("module-sovjp-{name}"), 107, 4, |rng| {
                let theta = theta_for(m.as_ref(), rng);
                let t = rng.uniform(0.0, 1.0);
                prop::module_sovjp_fd(m.as_ref(), 2, t, &theta, rng)
            });
        }
    }

    #[test]
    fn boxed_clones_are_independent_but_identical() {
        let m = ArchSpec::ConcatSquashMlp { hidden: vec![5], act: Act::Tanh }.build(3);
        let c = m.clone();
        let mut rng = Rng::new(11);
        let theta = theta_for(m.as_ref(), &mut rng);
        let x = prop::vec_normal(&mut rng, 2 * m.in_dim());
        let (y1, _) = prop::module_eval(m.as_ref(), 2, 0.4, &theta, &x);
        let (y2, _) = prop::module_eval(c.as_ref(), 2, 0.4, &theta, &x);
        assert_eq!(y1, y2, "clone reproduces the graph bitwise");
    }

    #[test]
    fn sequential_cache_is_the_sum_of_children() {
        let spec = ArchSpec::Mlp { hidden: vec![8, 6], act: Act::Tanh };
        let m = spec.build(5);
        // Linear caches its input, Activation its pre-activation:
        // Σ_l B·(d_l + d_{l+1}) — the legacy Mlp closed form
        let dims = [5usize, 8, 6, 5];
        let want: usize = dims.windows(2).map(|w| 3 * (w[0] + w[1])).sum();
        assert_eq!(m.cache_len(3), want);
        assert_eq!(m.activation_bytes(3), (want * 4) as u64);
    }
}
