//! `ArchSpec` — the serializable, spec-addressable description of a
//! dynamics architecture (DESIGN.md §10).
//!
//! An `ArchSpec` is to the module graph what
//! [`crate::api::MethodSpec`] is to the gradient engine: a typed value
//! with a string grammar and a lossless JSON form that `RunSpec`
//! documents embed (`"arch": {...}`), so a reviewable spec file pins the
//! *architecture* of a run end-to-end, not just its solver.
//!
//! `build` instantiates the module graph at a given data dimension;
//! `init` draws a parameter vector in the graph's flat layout (Kaiming
//! for dense layers — identical streams to the legacy
//! `nn::init::kaiming_uniform` on the same dims — and zeros for the
//! concatsquash gate/shift hypernetworks, which start as a constant
//! ½-gate).

use crate::nn::Act;
use crate::nn::module::{
    Activation, Augment, ConcatSquash, ConcatTime, Linear, Module, Residual, Sequential,
};
use crate::util::json::Json;
use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ArchSpec {
    /// Time-independent MLP over the state: dims `[d, hidden…, d]`.
    Mlp { hidden: Vec<usize>, act: Act },
    /// MLP over `[x, t]` (a [`ConcatTime`] wrapper): dims `[d+1, hidden…, d]`.
    ConcatMlp { hidden: Vec<usize>, act: Act },
    /// FFJORD concatsquash stack: [`ConcatSquash`] layers `[d, hidden…, d]`
    /// with `act` between them.
    ConcatSquashMlp { hidden: Vec<usize>, act: Act },
    /// `y = x + inner(x)`.
    Residual(Box<ArchSpec>),
    /// ANODE: run `inner` over `d + extra` channels; the task lifts the
    /// data state with zero channels (the [`Augment`] module).
    Augment { extra: usize, inner: Box<ArchSpec> },
}

/// `[d0, …, dn]` layer widths of an MLP-shaped stack.
fn mlp_dims(d_in: usize, hidden: &[usize], d_out: usize) -> Vec<usize> {
    let mut dims = Vec::with_capacity(hidden.len() + 2);
    dims.push(d_in);
    dims.extend_from_slice(hidden);
    dims.push(d_out);
    dims
}

/// `Linear`/`Activation` chain over `dims` with `act` between layers and
/// an identity epilogue — the exact legacy `Mlp` composition (the
/// trailing identity keeps the per-module activation accounting equal to
/// the closed-form `Mlp::activation_bytes`).  Public because
/// [`crate::nn::Mlp`] is itself this composition over possibly
/// non-square dims.
pub fn dense_stack(dims: &[usize], act: Act) -> Sequential {
    let n_layers = dims.len() - 1;
    let mut children: Vec<Box<dyn Module>> = Vec::with_capacity(2 * n_layers);
    for l in 0..n_layers {
        children.push(Box::new(Linear::new(dims[l], dims[l + 1])));
        let a = if l + 1 < n_layers { act } else { Act::Identity };
        children.push(Box::new(Activation::new(a, dims[l + 1])));
    }
    Sequential::new(children)
}

fn squash_stack(dims: &[usize], act: Act) -> Sequential {
    let n_layers = dims.len() - 1;
    let mut children: Vec<Box<dyn Module>> = Vec::with_capacity(2 * n_layers - 1);
    for l in 0..n_layers {
        children.push(Box::new(ConcatSquash::new(dims[l], dims[l + 1])));
        if l + 1 < n_layers {
            children.push(Box::new(Activation::new(act, dims[l + 1])));
        }
    }
    Sequential::new(children)
}

impl ArchSpec {
    /// ODE state dimension when the data has `data_dim` channels (equal
    /// for all architectures except the augmented ones).
    pub fn state_dim(&self, data_dim: usize) -> usize {
        match self {
            ArchSpec::Mlp { .. }
            | ArchSpec::ConcatMlp { .. }
            | ArchSpec::ConcatSquashMlp { .. } => data_dim,
            ArchSpec::Residual(inner) => inner.state_dim(data_dim),
            ArchSpec::Augment { extra, inner } => inner.state_dim(data_dim + extra),
        }
    }

    /// Zero channels the task must lift the data state by (0 unless the
    /// spec carries `Augment` nodes).
    pub fn augment_extra(&self) -> usize {
        match self {
            ArchSpec::Mlp { .. }
            | ArchSpec::ConcatMlp { .. }
            | ArchSpec::ConcatSquashMlp { .. } => 0,
            ArchSpec::Residual(inner) => inner.augment_extra(),
            ArchSpec::Augment { extra, inner } => extra + inner.augment_extra(),
        }
    }

    /// Flat parameter count at `data_dim`.
    pub fn param_count(&self, data_dim: usize) -> usize {
        match self {
            ArchSpec::Mlp { hidden, .. } => {
                crate::nn::param_count(&mlp_dims(data_dim, hidden, data_dim))
            }
            ArchSpec::ConcatMlp { hidden, .. } => {
                crate::nn::param_count(&mlp_dims(data_dim + 1, hidden, data_dim))
            }
            ArchSpec::ConcatSquashMlp { hidden, .. } => {
                mlp_dims(data_dim, hidden, data_dim)
                    .windows(2)
                    .map(|w| w[0] * w[1] + 4 * w[1])
                    .sum()
            }
            ArchSpec::Residual(inner) => inner.param_count(data_dim),
            ArchSpec::Augment { extra, inner } => inner.param_count(data_dim + extra),
        }
    }

    /// Instantiate the module graph at `data_dim`; the result is square
    /// over [`ArchSpec::state_dim`] (time conditioning stays internal).
    pub fn build(&self, data_dim: usize) -> Box<dyn Module> {
        match self {
            ArchSpec::Mlp { hidden, act } => {
                Box::new(dense_stack(&mlp_dims(data_dim, hidden, data_dim), *act))
            }
            ArchSpec::ConcatMlp { hidden, act } => Box::new(ConcatTime::new(
                data_dim,
                Box::new(dense_stack(&mlp_dims(data_dim + 1, hidden, data_dim), *act)),
            )),
            ArchSpec::ConcatSquashMlp { hidden, act } => {
                Box::new(squash_stack(&mlp_dims(data_dim, hidden, data_dim), *act))
            }
            ArchSpec::Residual(inner) => Box::new(Residual::new(inner.build(data_dim))),
            ArchSpec::Augment { extra, inner } => inner.build(data_dim + extra),
        }
    }

    /// The [`Augment`] lift module for this spec, when it is augmented.
    pub fn lift(&self, data_dim: usize) -> Option<Augment> {
        let extra = self.augment_extra();
        (extra > 0).then(|| Augment::new(data_dim, extra))
    }

    /// Draw an initial flat parameter vector in the graph's layout.
    pub fn init(&self, rng: &mut Rng, data_dim: usize) -> Vec<f32> {
        fn kaiming_layer(rng: &mut Rng, din: usize, dout: usize, out: &mut Vec<f32>) {
            let bound = 1.0 / (din as f32).sqrt();
            for _ in 0..din * dout + dout {
                out.push(rng.uniform(-bound as f64, bound as f64) as f32);
            }
        }
        match self {
            ArchSpec::Mlp { hidden, act: _ } => {
                crate::nn::init::kaiming_uniform(rng, &mlp_dims(data_dim, hidden, data_dim), 1.0)
            }
            ArchSpec::ConcatMlp { hidden, act: _ } => crate::nn::init::kaiming_uniform(
                rng,
                &mlp_dims(data_dim + 1, hidden, data_dim),
                1.0,
            ),
            ArchSpec::ConcatSquashMlp { hidden, act: _ } => {
                let dims = mlp_dims(data_dim, hidden, data_dim);
                let mut theta = Vec::with_capacity(self.param_count(data_dim));
                for w in dims.windows(2) {
                    kaiming_layer(rng, w[0], w[1], &mut theta);
                    // gate/shift hypernets start at zero: σ(0) = ½ gate, 0 shift
                    theta.resize(theta.len() + 3 * w[1], 0.0);
                }
                theta
            }
            ArchSpec::Residual(inner) => inner.init(rng, data_dim),
            ArchSpec::Augment { extra, inner } => inner.init(rng, data_dim + extra),
        }
    }

    /// Reject degenerate specs with a message naming the offending part.
    pub fn validate(&self) -> Result<(), String> {
        match self {
            ArchSpec::Mlp { hidden, .. }
            | ArchSpec::ConcatMlp { hidden, .. }
            | ArchSpec::ConcatSquashMlp { hidden, .. } => {
                if hidden.contains(&0) {
                    return Err(format!("arch hidden widths must be nonzero (got {hidden:?})"));
                }
                Ok(())
            }
            ArchSpec::Residual(inner) => inner.validate(),
            ArchSpec::Augment { extra, inner } => {
                if *extra == 0 {
                    return Err("augment needs extra >= 1 (0 channels is the identity)".into());
                }
                inner.validate()
            }
        }
    }

    // ---------------- string grammar ----------------

    /// Canonical name; `parse(name())` round-trips.  Grammar:
    ///
    /// ```text
    /// mlp:<h1,h2,…>:<act>
    /// concat:<h1,h2,…>:<act>
    /// concatsquash:<h1,h2,…>:<act>
    /// residual:<inner>
    /// augment:<extra>:<inner>
    /// ```
    pub fn name(&self) -> String {
        fn csv(hidden: &[usize]) -> String {
            hidden.iter().map(|h| h.to_string()).collect::<Vec<_>>().join(",")
        }
        match self {
            ArchSpec::Mlp { hidden, act } => format!("mlp:{}:{}", csv(hidden), act.name()),
            ArchSpec::ConcatMlp { hidden, act } => {
                format!("concat:{}:{}", csv(hidden), act.name())
            }
            ArchSpec::ConcatSquashMlp { hidden, act } => {
                format!("concatsquash:{}:{}", csv(hidden), act.name())
            }
            ArchSpec::Residual(inner) => format!("residual:{}", inner.name()),
            ArchSpec::Augment { extra, inner } => format!("augment:{extra}:{}", inner.name()),
        }
    }

    /// Parse the CLI grammar of [`ArchSpec::name`].
    pub fn parse(s: &str) -> Result<ArchSpec, String> {
        fn hidden_csv(s: &str) -> Result<Vec<usize>, String> {
            if s.is_empty() {
                return Ok(Vec::new());
            }
            s.split(',')
                .map(|h| h.parse::<usize>().map_err(|_| format!("bad hidden width {h:?}")))
                .collect()
        }
        fn mlp_like(
            rest: &str,
            mk: impl Fn(Vec<usize>, Act) -> ArchSpec,
            what: &str,
        ) -> Result<ArchSpec, String> {
            let (hs, act_s) = rest
                .rsplit_once(':')
                .ok_or_else(|| format!("{what} wants <hidden,…>:<act> (got {rest:?})"))?;
            let act = Act::parse(act_s).ok_or_else(|| format!("unknown activation {act_s:?}"))?;
            let spec = mk(hidden_csv(hs)?, act);
            spec.validate()?;
            Ok(spec)
        }
        let (head, rest) = s.split_once(':').ok_or_else(|| {
            format!("unknown arch {s:?} (want mlp | concat | concatsquash | residual | augment …)")
        })?;
        match head {
            "mlp" => mlp_like(rest, |hidden, act| ArchSpec::Mlp { hidden, act }, "mlp"),
            "concat" => mlp_like(rest, |hidden, act| ArchSpec::ConcatMlp { hidden, act }, "concat"),
            "concatsquash" => mlp_like(
                rest,
                |hidden, act| ArchSpec::ConcatSquashMlp { hidden, act },
                "concatsquash",
            ),
            "residual" => Ok(ArchSpec::Residual(Box::new(ArchSpec::parse(rest)?))),
            "augment" => {
                let (extra_s, inner_s) = rest
                    .split_once(':')
                    .ok_or_else(|| format!("augment wants <extra>:<inner> (got {rest:?})"))?;
                let extra = extra_s
                    .parse::<usize>()
                    .map_err(|_| format!("bad augment channel count {extra_s:?}"))?;
                let spec =
                    ArchSpec::Augment { extra, inner: Box::new(ArchSpec::parse(inner_s)?) };
                spec.validate()?;
                Ok(spec)
            }
            _ => Err(format!(
                "unknown arch {head:?} (want mlp | concat | concatsquash | residual | augment)"
            )),
        }
    }

    // ---------------- JSON ----------------

    pub fn to_json(&self) -> Json {
        fn mlp_like(kind: &str, hidden: &[usize], act: Act) -> Json {
            Json::obj(vec![
                ("kind", Json::str(kind)),
                ("hidden", Json::arr(hidden.iter().map(|h| Json::num(*h as f64)).collect())),
                ("act", Json::str(act.name())),
            ])
        }
        match self {
            ArchSpec::Mlp { hidden, act } => mlp_like("mlp", hidden, *act),
            ArchSpec::ConcatMlp { hidden, act } => mlp_like("concat_mlp", hidden, *act),
            ArchSpec::ConcatSquashMlp { hidden, act } => {
                mlp_like("concatsquash_mlp", hidden, *act)
            }
            ArchSpec::Residual(inner) => Json::obj(vec![
                ("kind", Json::str("residual")),
                ("inner", inner.to_json()),
            ]),
            ArchSpec::Augment { extra, inner } => Json::obj(vec![
                ("kind", Json::str("augment")),
                ("extra", Json::num(*extra as f64)),
                ("inner", inner.to_json()),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<ArchSpec, String> {
        let kind = v
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or("arch needs a \"kind\" string")?;
        let mlp_like = |mk: &dyn Fn(Vec<usize>, Act) -> ArchSpec| -> Result<ArchSpec, String> {
            let hidden = v
                .get("hidden")
                .and_then(|h| h.as_usize_vec())
                .ok_or_else(|| format!("arch {kind:?} needs a \"hidden\" width array"))?;
            let act_s = v
                .get("act")
                .and_then(|a| a.as_str())
                .ok_or_else(|| format!("arch {kind:?} needs an \"act\" string"))?;
            let act = Act::parse(act_s).ok_or_else(|| format!("unknown activation {act_s:?}"))?;
            Ok(mk(hidden, act))
        };
        let spec = match kind {
            "mlp" => mlp_like(&|hidden, act| ArchSpec::Mlp { hidden, act })?,
            "concat_mlp" => mlp_like(&|hidden, act| ArchSpec::ConcatMlp { hidden, act })?,
            "concatsquash_mlp" => {
                mlp_like(&|hidden, act| ArchSpec::ConcatSquashMlp { hidden, act })?
            }
            "residual" => {
                let inner = v.get("inner").ok_or("residual arch needs an \"inner\" object")?;
                ArchSpec::Residual(Box::new(ArchSpec::from_json(inner)?))
            }
            "augment" => {
                let extra = v
                    .get("extra")
                    .and_then(|e| e.as_usize())
                    .ok_or("augment arch needs an \"extra\" count")?;
                let inner = v.get("inner").ok_or("augment arch needs an \"inner\" object")?;
                ArchSpec::Augment { extra, inner: Box::new(ArchSpec::from_json(inner)?) }
            }
            k => {
                return Err(format!(
                    "unknown arch kind {k:?} (want mlp | concat_mlp | concatsquash_mlp | \
                     residual | augment)"
                ))
            }
        };
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roster() -> Vec<ArchSpec> {
        vec![
            ArchSpec::Mlp { hidden: vec![8, 6], act: Act::Tanh },
            ArchSpec::ConcatMlp { hidden: vec![7], act: Act::Gelu },
            ArchSpec::ConcatSquashMlp { hidden: vec![6, 6], act: Act::Tanh },
            ArchSpec::Residual(Box::new(ArchSpec::Mlp { hidden: vec![5], act: Act::Sigmoid })),
            ArchSpec::Augment {
                extra: 2,
                inner: Box::new(ArchSpec::ConcatMlp { hidden: vec![9], act: Act::Relu }),
            },
        ]
    }

    #[test]
    fn name_and_json_roundtrip() {
        for spec in roster() {
            assert_eq!(ArchSpec::parse(&spec.name()), Ok(spec.clone()), "{}", spec.name());
            let j = spec.to_json();
            assert_eq!(ArchSpec::from_json(&j), Ok(spec.clone()), "{}", spec.name());
            // through text, too
            let parsed = crate::util::json::parse(&j.to_string_pretty()).unwrap();
            assert_eq!(ArchSpec::from_json(&parsed), Ok(spec));
        }
    }

    #[test]
    fn built_graphs_are_square_with_consistent_params() {
        let d = 4;
        for spec in roster() {
            let m = spec.build(d);
            let sd = spec.state_dim(d);
            assert_eq!(m.in_dim(), sd, "{}", spec.name());
            assert_eq!(m.out_dim(), sd, "{}", spec.name());
            assert_eq!(m.param_len(), spec.param_count(d), "{}", spec.name());
            let mut rng = Rng::new(9);
            assert_eq!(spec.init(&mut rng, d).len(), spec.param_count(d), "{}", spec.name());
        }
    }

    #[test]
    fn concat_mlp_matches_legacy_mlp_layout() {
        // ConcatMlp's flat layout is the legacy [d+1, hidden…, d] layout
        let spec = ArchSpec::ConcatMlp { hidden: vec![16], act: Act::Tanh };
        assert_eq!(spec.param_count(8), crate::nn::param_count(&[9, 16, 8]));
        let mut a = Rng::new(4);
        let mut b = Rng::new(4);
        let theta = spec.init(&mut a, 8);
        let legacy = crate::nn::init::kaiming_uniform(&mut b, &[9, 16, 8], 1.0);
        assert_eq!(theta, legacy, "identical init stream on the same dims");
    }

    #[test]
    fn augment_changes_state_dim_and_reports_lift() {
        let spec = ArchSpec::Augment {
            extra: 3,
            inner: Box::new(ArchSpec::Mlp { hidden: vec![6], act: Act::Tanh }),
        };
        assert_eq!(spec.state_dim(4), 7);
        assert_eq!(spec.augment_extra(), 3);
        let lift = spec.lift(4).expect("augmented");
        assert_eq!(lift.in_dim(), 4);
        assert_eq!(lift.out_dim(), 7);
        assert!(ArchSpec::Mlp { hidden: vec![6], act: Act::Tanh }.lift(4).is_none());
    }

    #[test]
    fn degenerate_specs_are_rejected() {
        let e = ArchSpec::Mlp { hidden: vec![8, 0], act: Act::Tanh }.validate().unwrap_err();
        assert!(e.contains("nonzero"), "{e}");
        let e = ArchSpec::Augment {
            extra: 0,
            inner: Box::new(ArchSpec::Mlp { hidden: vec![4], act: Act::Tanh }),
        }
        .validate()
        .unwrap_err();
        assert!(e.contains("extra"), "{e}");
        assert!(ArchSpec::parse("mlp:8,x:tanh").is_err());
        assert!(ArchSpec::parse("mlp:8:swish").is_err());
        assert!(ArchSpec::parse("nope:1:tanh").is_err());
        assert!(ArchSpec::parse("augment:0:mlp:4:tanh").is_err());
    }
}
