//! Time-conditioned modules — what the paper's CNF experiments (§5.2)
//! need that a time-independent MLP cannot express.
//!
//! * [`ConcatTime`]: appends the scalar `t` as one extra input channel
//!   per sample and runs an inner module over `[x, t]`.  When the inner
//!   module is a [`Sequential`](super::Sequential) whose first step is a
//!   fused Linear, the first-order passes skip the `[x | t]`
//!   materialisation entirely and fold the time column into that layer's
//!   effective bias (`b_eff = b + t·W[d, :]`, see `sequential.rs` for
//!   the determinism contract); otherwise the legacy augment/strip path
//!   (`model.py::_augment_time` on the Python side) runs unchanged, and
//!   `sovjp` always uses it.
//! * [`ConcatSquash`]: the FFJORD concatsquash layer
//!   `y = (x W + b) ⊙ σ(t·w_g + b_g) + t·w_s` — a dense layer whose gate
//!   and shift are hypernetworks in `t`.  θ layout:
//!   `[W (din·dout) | b | w_g | b_g | w_s]` (each tail block `dout`).

use std::cell::RefCell;

use crate::nn::Act;
use crate::nn::module::Module;
use crate::tensor::gemm::{sgemm, sgemm_at, sgemm_bt, sgemm_epi, sgemm_epi2};

// ---------------------------------------------------------------------------
// ConcatTime
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, Default)]
struct TimeScratch {
    /// augmented input `[x | t]` rows
    xt: Vec<f32>,
    /// augmented cotangent/tangent rows
    pad: Vec<f32>,
    /// augmented second-order gradient rows
    gpad: Vec<f32>,
}

pub struct ConcatTime {
    d: usize,
    inner: Box<dyn Module>,
    scratch: RefCell<TimeScratch>,
}

impl Clone for ConcatTime {
    fn clone(&self) -> Self {
        ConcatTime { d: self.d, inner: self.inner.clone(), scratch: RefCell::default() }
    }
}

impl std::fmt::Debug for ConcatTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ConcatTime").field("d", &self.d).finish()
    }
}

impl ConcatTime {
    /// Wrap `inner` (which must take `d + 1` input channels).
    pub fn new(d: usize, inner: Box<dyn Module>) -> Self {
        assert_eq!(inner.in_dim(), d + 1, "ConcatTime inner must take d+1 channels");
        ConcatTime { d, inner, scratch: RefCell::default() }
    }

    fn ensure(&self, bsz: usize) {
        let n = bsz * (self.d + 1);
        let mut s = self.scratch.borrow_mut();
        if s.xt.len() < n {
            s.xt.resize(n, 0.0);
            s.pad.resize(n, 0.0);
            s.gpad.resize(n, 0.0);
        }
    }

    /// Build `[x_r, t]` rows into `xt` (the legacy augment loop).
    fn augment(&self, bsz: usize, t: f64, x: &[f32], xt: &mut [f32]) {
        let d = self.d;
        for r in 0..bsz {
            xt[r * (d + 1)..r * (d + 1) + d].copy_from_slice(&x[r * d..(r + 1) * d]);
            xt[r * (d + 1) + d] = t as f32;
        }
    }

    /// Drop the `t` column of an augmented per-row gradient.
    fn strip(&self, bsz: usize, gpad: &[f32], out: &mut [f32]) {
        let d = self.d;
        for r in 0..bsz {
            out[r * d..(r + 1) * d].copy_from_slice(&gpad[r * (d + 1)..r * (d + 1) + d]);
        }
    }

    /// Zero-pad a per-row tangent with a zero `t` column.
    fn pad_tangent(&self, bsz: usize, w: &[f32], pad: &mut [f32]) {
        let d = self.d;
        pad[..bsz * (d + 1)].fill(0.0);
        for r in 0..bsz {
            pad[r * (d + 1)..r * (d + 1) + d].copy_from_slice(&w[r * d..(r + 1) * d]);
        }
    }
}

#[allow(clippy::too_many_arguments)]
impl Module for ConcatTime {
    fn in_dim(&self) -> usize {
        self.d
    }

    fn out_dim(&self) -> usize {
        self.inner.out_dim()
    }

    fn param_len(&self) -> usize {
        self.inner.param_len()
    }

    fn cache_len(&self, bsz: usize) -> usize {
        self.inner.cache_len(bsz)
    }

    fn max_width(&self) -> usize {
        self.inner.max_width().max(self.d)
    }

    fn forward(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        y: &mut [f32],
        cache: &mut [f32],
    ) {
        // fused path: hand the t column to the inner stack's first fused
        // Linear (no [B, d+1] materialisation; see sequential.rs docs)
        if let Some(seq) = self.inner.as_sequential() {
            if seq.supports_time_aug() {
                seq.forward_time_aug(bsz, t, theta, x, y, cache);
                return;
            }
        }
        self.ensure(bsz);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        self.augment(bsz, t, x, &mut s.xt);
        self.inner.forward(bsz, t, theta, &s.xt[..bsz * (self.d + 1)], y, cache);
    }

    fn vjp(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        v: &[f32],
        gx: &mut [f32],
        grad_theta: Option<&mut [f32]>,
        cache: &[f32],
    ) {
        if let Some(seq) = self.inner.as_sequential() {
            if seq.supports_time_aug() {
                // writes the [B, d] cotangent directly — no pad + strip
                seq.vjp_time_aug(bsz, t, theta, v, gx, grad_theta, cache);
                return;
            }
        }
        self.ensure(bsz);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        self.inner.vjp(bsz, t, theta, v, &mut s.pad[..bsz * (self.d + 1)], grad_theta, cache);
        self.strip(bsz, &s.pad, gx);
    }

    fn jvp(&self, bsz: usize, t: f64, theta: &[f32], dx: &[f32], dy: &mut [f32], cache: &[f32]) {
        if let Some(seq) = self.inner.as_sequential() {
            if seq.supports_time_aug() {
                seq.jvp_time_aug(bsz, t, theta, dx, dy, cache);
                return;
            }
        }
        self.ensure(bsz);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        // the tangent of the appended t column is 0: state motion leaves t fixed
        self.pad_tangent(bsz, dx, &mut s.pad);
        self.inner.jvp(bsz, t, theta, &s.pad[..bsz * (self.d + 1)], dy, cache);
    }

    fn sovjp(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        w: &[f32],
        u: &[f32],
        gx: &mut [f32],
        grad_theta: Option<&mut [f32]>,
        cache: &mut [f32],
    ) {
        self.ensure(bsz);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        self.augment(bsz, t, x, &mut s.xt);
        self.pad_tangent(bsz, w, &mut s.pad);
        let n_pad = bsz * (self.d + 1);
        self.inner.sovjp(
            bsz,
            t,
            theta,
            &s.xt[..n_pad],
            &s.pad[..n_pad],
            u,
            &mut s.gpad[..n_pad],
            grad_theta,
            cache,
        );
        self.strip(bsz, &s.gpad, gx);
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// ConcatSquash
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, Default)]
struct SquashScratch {
    /// per-channel gate σ(t·w_g + b_g)
    gate: Vec<f32>,
    /// [B, dout] work buffer (gated cotangent / tangent image)
    buf: Vec<f32>,
    /// second [B, dout] work buffer for the second-order pass
    buf2: Vec<f32>,
}

pub struct ConcatSquash {
    din: usize,
    dout: usize,
    scratch: RefCell<SquashScratch>,
}

impl Clone for ConcatSquash {
    fn clone(&self) -> Self {
        ConcatSquash { din: self.din, dout: self.dout, scratch: RefCell::default() }
    }
}

impl std::fmt::Debug for ConcatSquash {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ConcatSquash").field("din", &self.din).field("dout", &self.dout).finish()
    }
}

impl ConcatSquash {
    pub fn new(din: usize, dout: usize) -> Self {
        assert!(din > 0 && dout > 0, "concatsquash dims must be nonzero ({din}x{dout})");
        ConcatSquash { din, dout, scratch: RefCell::default() }
    }

    /// θ = [W | b | w_g | b_g | w_s].
    #[allow(clippy::type_complexity)]
    fn split<'a>(
        &self,
        theta: &'a [f32],
    ) -> (&'a [f32], &'a [f32], &'a [f32], &'a [f32], &'a [f32]) {
        debug_assert_eq!(theta.len(), self.param_len());
        let (w, rest) = theta.split_at(self.din * self.dout);
        let (b, rest) = rest.split_at(self.dout);
        let (wg, rest) = rest.split_at(self.dout);
        let (bg, ws) = rest.split_at(self.dout);
        (w, b, wg, bg, ws)
    }

    fn ensure(&self, bsz: usize) {
        let mut s = self.scratch.borrow_mut();
        if s.gate.len() < self.dout {
            s.gate.resize(self.dout, 0.0);
        }
        if s.buf.len() < bsz * self.dout {
            s.buf.resize(bsz * self.dout, 0.0);
            s.buf2.resize(bsz * self.dout, 0.0);
        }
    }

    fn gates(&self, t: f64, wg: &[f32], bg: &[f32], gate: &mut [f32]) {
        let tt = t as f32;
        for j in 0..self.dout {
            gate[j] = Act::Sigmoid.apply(tt * wg[j] + bg[j]);
        }
    }
}

#[allow(clippy::too_many_arguments)]
impl Module for ConcatSquash {
    fn in_dim(&self) -> usize {
        self.din
    }

    fn out_dim(&self) -> usize {
        self.dout
    }

    fn param_len(&self) -> usize {
        self.din * self.dout + 4 * self.dout
    }

    fn cache_len(&self, bsz: usize) -> usize {
        // input x (for gW) + the pre-gate linear map (for gate-parameter grads)
        bsz * (self.din + self.dout)
    }

    fn max_width(&self) -> usize {
        self.din.max(self.dout)
    }

    fn forward(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        x: &[f32],
        y: &mut [f32],
        cache: &mut [f32],
    ) {
        let (w, b, wg, bg, ws) = self.split(theta);
        self.ensure(bsz);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        self.gates(t, wg, bg, &mut s.gate);
        let (cx, clin) = cache.split_at_mut(bsz * self.din);
        cx.copy_from_slice(x);
        let lin = &mut clin[..bsz * self.dout];
        let gate: &[f32] = &s.gate[..self.dout];
        let tt = t as f32;
        // bias, gate, and shift applied in the GEMM epilogue while each
        // row is cache-hot; lin keeps the pre-gate map the vjp reads back
        sgemm_epi2(bsz, self.din, self.dout, x, w, lin, y, &|_, zrow, yrow| {
            for j in 0..zrow.len() {
                let zv = zrow[j] + b[j];
                zrow[j] = zv;
                yrow[j] = zv * gate[j] + tt * ws[j];
            }
        });
    }

    fn vjp(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        v: &[f32],
        gx: &mut [f32],
        grad_theta: Option<&mut [f32]>,
        cache: &[f32],
    ) {
        let (w, _b, wg, bg, _ws) = self.split(theta);
        self.ensure(bsz);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        self.gates(t, wg, bg, &mut s.gate);
        let (cx, clin) = cache.split_at(bsz * self.din);
        let lin = &clin[..bsz * self.dout];
        // vg = v ⊙ gate (broadcast over rows)
        let vg = &mut s.buf[..bsz * self.dout];
        if let Some(gt) = grad_theta {
            let tt = t as f32;
            let (gw, rest) = gt.split_at_mut(self.din * self.dout);
            let (gb, rest) = rest.split_at_mut(self.dout);
            let (gwg, rest) = rest.split_at_mut(self.dout);
            let (gbg, gws) = rest.split_at_mut(self.dout);
            // gb folded into the gating sweep: same row-major
            // accumulation order as the separate column-sum loop had,
            // so the sums are bitwise identical
            for row in 0..bsz {
                for j in 0..self.dout {
                    let g = v[row * self.dout + j] * s.gate[j];
                    vg[row * self.dout + j] = g;
                    gb[j] += g;
                }
            }
            sgemm_at(self.din, bsz, self.dout, cx, vg, gw, 1.0);
            for j in 0..self.dout {
                // s_j = Σ_r v[r,j]·lin[r,j] drives the gate-parameter grads
                let mut sj = 0.0f32;
                let mut vsum = 0.0f32;
                for row in 0..bsz {
                    sj += v[row * self.dout + j] * lin[row * self.dout + j];
                    vsum += v[row * self.dout + j];
                }
                let gp = s.gate[j] * (1.0 - s.gate[j]);
                gwg[j] += sj * gp * tt;
                gbg[j] += sj * gp;
                gws[j] += tt * vsum;
            }
        } else {
            for row in 0..bsz {
                for j in 0..self.dout {
                    vg[row * self.dout + j] = v[row * self.dout + j] * s.gate[j];
                }
            }
        }
        sgemm_bt(bsz, self.dout, self.din, vg, w, gx, 0.0);
    }

    fn jvp(&self, bsz: usize, t: f64, theta: &[f32], dx: &[f32], dy: &mut [f32], _cache: &[f32]) {
        let (w, _b, wg, bg, _ws) = self.split(theta);
        self.ensure(bsz);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        self.gates(t, wg, bg, &mut s.gate);
        let gate: &[f32] = &s.gate[..self.dout];
        // gate multiply in the GEMM epilogue: no lin_d staging buffer
        sgemm_epi(bsz, self.din, self.dout, dx, w, dy, &|_, yrow| {
            for (yj, gj) in yrow.iter_mut().zip(gate) {
                *yj *= *gj;
            }
        });
    }

    fn sovjp(
        &self,
        bsz: usize,
        t: f64,
        theta: &[f32],
        _x: &[f32],
        w_tan: &[f32],
        u: &[f32],
        gx: &mut [f32],
        grad_theta: Option<&mut [f32]>,
        _cache: &mut [f32],
    ) {
        // J_x = diag(gate) ∘ W is x-independent: ∇_x ⟨u, Jw⟩ = 0.
        let (w, _b, wg, bg, _ws) = self.split(theta);
        gx[..bsz * self.din].fill(0.0);
        let Some(gt) = grad_theta else { return };
        self.ensure(bsz);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        self.gates(t, wg, bg, &mut s.gate);
        let tt = t as f32;
        // linw = w_tan W (the tangent image before gating)
        let linw = &mut s.buf[..bsz * self.dout];
        sgemm(bsz, self.din, self.dout, w_tan, w, linw, 0.0);
        // ug = u ⊙ gate
        let ug = &mut s.buf2[..bsz * self.dout];
        for row in 0..bsz {
            for j in 0..self.dout {
                ug[row * self.dout + j] = u[row * self.dout + j] * s.gate[j];
            }
        }
        let (gw, rest) = gt.split_at_mut(self.din * self.dout);
        let (_gb, rest) = rest.split_at_mut(self.dout);
        let (gwg, rest) = rest.split_at_mut(self.dout);
        let (gbg, _gws) = rest.split_at_mut(self.dout);
        // ⟨u, (wW)⊙g⟩: ∇W_ij = Σ_r w[r,i]·u[r,j]·g_j
        sgemm_at(self.din, bsz, self.dout, w_tan, ug, gw, 1.0);
        // gate-parameter grads through g'_j = g_j(1−g_j)
        for j in 0..self.dout {
            let mut sj = 0.0f32;
            for row in 0..bsz {
                sj += u[row * self.dout + j] * linw[row * self.dout + j];
            }
            let gp = s.gate[j] * (1.0 - s.gate[j]);
            gwg[j] += sj * gp * tt;
            gbg[j] += sj * gp;
            // b and w_s drop out of J entirely
        }
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }
}
