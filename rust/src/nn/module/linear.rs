//! Dense layer `y = x W + b` with the legacy flat layout
//! (`W ∈ R^{din×dout}` row-major, then `b ∈ R^{dout}` — the contract of
//! `nn::init::layer_offsets`).  Arithmetic is kept call-for-call
//! identical to the pre-module `Mlp` layer loops (same sgemm variants,
//! same bias/column-sum loop order), which is what makes the
//! `Sequential`-of-modules recomposition bitwise equal to the legacy
//! implementation.

use crate::nn::module::Module;
use crate::tensor::gemm::{sgemm, sgemm_at, sgemm_bt, sgemm_epi};

#[derive(Clone, Debug)]
pub struct Linear {
    din: usize,
    dout: usize,
}

impl Linear {
    pub fn new(din: usize, dout: usize) -> Self {
        assert!(din > 0 && dout > 0, "linear dims must be nonzero ({din}x{dout})");
        Linear { din, dout }
    }

    fn split<'a>(&self, theta: &'a [f32]) -> (&'a [f32], &'a [f32]) {
        debug_assert_eq!(theta.len(), self.param_len());
        theta.split_at(self.din * self.dout)
    }
}

#[allow(clippy::too_many_arguments)]
impl Module for Linear {
    fn in_dim(&self) -> usize {
        self.din
    }

    fn out_dim(&self) -> usize {
        self.dout
    }

    fn param_len(&self) -> usize {
        self.din * self.dout + self.dout
    }

    fn cache_len(&self, bsz: usize) -> usize {
        // the layer input, needed for gW = xᵀ gpre
        bsz * self.din
    }

    fn max_width(&self) -> usize {
        self.din.max(self.dout)
    }

    fn forward(
        &self,
        bsz: usize,
        _t: f64,
        theta: &[f32],
        x: &[f32],
        y: &mut [f32],
        cache: &mut [f32],
    ) {
        let (w, b) = self.split(theta);
        cache[..bsz * self.din].copy_from_slice(x);
        // bias add fused into the GEMM epilogue (same single add per
        // element as the legacy separate sweep — bitwise identical)
        sgemm_epi(bsz, self.din, self.dout, x, w, y, &|_, yrow| {
            for (yj, bj) in yrow.iter_mut().zip(b) {
                *yj += *bj;
            }
        });
    }

    fn vjp(
        &self,
        bsz: usize,
        _t: f64,
        theta: &[f32],
        v: &[f32],
        gx: &mut [f32],
        grad_theta: Option<&mut [f32]>,
        cache: &[f32],
    ) {
        let (w, _) = self.split(theta);
        if let Some(gt) = grad_theta {
            let (gw, gb) = gt.split_at_mut(self.din * self.dout);
            // gW += xᵀ v  (x is [B,din] so xᵀ is din×B stored [B,din])
            sgemm_at(self.din, bsz, self.dout, &cache[..bsz * self.din], v, gw, 1.0);
            // gb += column sums of v
            for row in 0..bsz {
                for j in 0..self.dout {
                    gb[j] += v[row * self.dout + j];
                }
            }
        }
        // gx = v @ Wᵀ (W stored [din,dout] row-major)
        sgemm_bt(bsz, self.dout, self.din, v, w, gx, 0.0);
    }

    fn jvp(&self, bsz: usize, _t: f64, theta: &[f32], dx: &[f32], dy: &mut [f32], _cache: &[f32]) {
        let (w, _) = self.split(theta);
        sgemm(bsz, self.din, self.dout, dx, w, dy, 0.0);
    }

    fn sovjp(
        &self,
        bsz: usize,
        _t: f64,
        _theta: &[f32],
        _x: &[f32],
        w: &[f32],
        u: &[f32],
        gx: &mut [f32],
        grad_theta: Option<&mut [f32]>,
        _cache: &mut [f32],
    ) {
        // J = W is x-independent: ∇_x ⟨u, Ww⟩ = 0.
        gx[..bsz * self.din].fill(0.0);
        if let Some(gt) = grad_theta {
            // ⟨u, wW⟩ = Σ_{r,i,j} w[r,i] W_ij u[r,j]  ⇒  gW_ij += Σ_r w[r,i] u[r,j]
            let gw = &mut gt[..self.din * self.dout];
            sgemm_at(self.din, bsz, self.dout, w, u, gw, 1.0);
            // the bias drops out of J: gb contribution is zero
        }
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }

    fn as_linear(&self) -> Option<&Linear> {
        Some(self)
    }
}
