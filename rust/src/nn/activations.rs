//! Activation functions and their derivatives.
//!
//! MUST stay in sync with `python/compile/kernels/dense.py` (the Pallas
//! epilogue) and `model.py::act_grad`; the cross-check test in
//! `rust/tests/xla_runtime.rs` compares this implementation against the
//! compiled HLO numerically.

/// Activation kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    Identity,
    Relu,
    Tanh,
    Gelu,
    Sigmoid,
}

impl Act {
    pub fn parse(s: &str) -> Option<Act> {
        Some(match s {
            "identity" => Act::Identity,
            "relu" => Act::Relu,
            "tanh" => Act::Tanh,
            "gelu" => Act::Gelu,
            "sigmoid" => Act::Sigmoid,
            _ => return None,
        })
    }

    /// Canonical name; `parse(name())` round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            Act::Identity => "identity",
            Act::Relu => "relu",
            Act::Tanh => "tanh",
            Act::Gelu => "gelu",
            Act::Sigmoid => "sigmoid",
        }
    }

    /// y = act(x)
    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Act::Identity => x,
            Act::Relu => x.max(0.0),
            Act::Tanh => x.tanh(),
            Act::Gelu => {
                // tanh-approximation (matches jax kernel)
                const C: f32 = 0.7978845608028654; // sqrt(2/pi)
                0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
            }
            Act::Sigmoid => 1.0 / (1.0 + (-x).exp()),
        }
    }

    /// d act / d x evaluated at the pre-activation x.
    #[inline]
    pub fn grad(&self, x: f32) -> f32 {
        match self {
            Act::Identity => 1.0,
            Act::Relu => {
                if x > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Act::Tanh => {
                let y = x.tanh();
                1.0 - y * y
            }
            Act::Gelu => {
                const C: f32 = 0.7978845608028654;
                let inner = C * (x + 0.044715 * x * x * x);
                let th = inner.tanh();
                let sech2 = 1.0 - th * th;
                let dinner = C * (1.0 + 3.0 * 0.044715 * x * x);
                0.5 * (1.0 + th) + 0.5 * x * sech2 * dinner
            }
            Act::Sigmoid => {
                let y = 1.0 / (1.0 + (-x).exp());
                y * (1.0 - y)
            }
        }
    }

    /// d² act / d x² at the pre-activation x (the curvature term of the
    /// directional second-order adjoint `Module::sovjp`; ReLU's kink
    /// contributes 0 almost everywhere, matching the subgradient choice
    /// in [`Act::grad`]).
    #[inline]
    pub fn grad2(&self, x: f32) -> f32 {
        match self {
            Act::Identity | Act::Relu => 0.0,
            Act::Tanh => {
                let y = x.tanh();
                -2.0 * y * (1.0 - y * y)
            }
            Act::Gelu => {
                const C: f32 = 0.7978845608028654;
                const K: f32 = 0.044715;
                let inner = C * (x + K * x * x * x);
                let th = inner.tanh();
                let sech2 = 1.0 - th * th;
                let di = C * (1.0 + 3.0 * K * x * x);
                let ddi = C * 6.0 * K * x;
                sech2 * di + 0.5 * x * sech2 * (ddi - 2.0 * th * di * di)
            }
            Act::Sigmoid => {
                let y = 1.0 / (1.0 + (-x).exp());
                y * (1.0 - y) * (1.0 - 2.0 * y)
            }
        }
    }

    /// Apply elementwise in place.
    pub fn apply_slice(&self, xs: &mut [f32]) {
        for x in xs {
            *x = self.apply(*x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradients_match_finite_differences() {
        let h = 1e-3f64;
        for act in [Act::Identity, Act::Tanh, Act::Gelu, Act::Sigmoid] {
            for &x in &[-2.0f32, -0.5, 0.1, 0.9, 3.0] {
                let fd = (act.apply(x + h as f32) as f64 - act.apply(x - h as f32) as f64)
                    / (2.0 * h);
                let g = act.grad(x) as f64;
                assert!(
                    (fd - g).abs() < 5e-3,
                    "{act:?} at {x}: fd {fd} vs grad {g}"
                );
            }
        }
        // relu away from the kink
        assert_eq!(Act::Relu.grad(1.0), 1.0);
        assert_eq!(Act::Relu.grad(-1.0), 0.0);
    }

    #[test]
    fn known_values() {
        assert_eq!(Act::Relu.apply(-3.0), 0.0);
        assert_eq!(Act::Relu.apply(2.0), 2.0);
        assert!((Act::Sigmoid.apply(0.0) - 0.5).abs() < 1e-7);
        assert!((Act::Tanh.apply(0.0)).abs() < 1e-7);
        assert!((Act::Gelu.apply(0.0)).abs() < 1e-7);
        // gelu(x) -> x for large x
        assert!((Act::Gelu.apply(6.0) - 6.0).abs() < 1e-3);
    }

    #[test]
    fn second_derivatives_match_finite_differences() {
        let h = 1e-3f64;
        for act in [Act::Identity, Act::Tanh, Act::Gelu, Act::Sigmoid] {
            for &x in &[-2.0f32, -0.5, 0.1, 0.9, 3.0] {
                let fd =
                    (act.grad(x + h as f32) as f64 - act.grad(x - h as f32) as f64) / (2.0 * h);
                let g2 = act.grad2(x) as f64;
                assert!(
                    (fd - g2).abs() < 5e-3 * (1.0 + fd.abs()),
                    "{act:?} at {x}: fd {fd} vs grad2 {g2}"
                );
            }
        }
        // relu is piecewise linear away from the kink
        assert_eq!(Act::Relu.grad2(1.0), 0.0);
        assert_eq!(Act::Relu.grad2(-1.0), 0.0);
    }

    #[test]
    fn name_roundtrips() {
        for a in [Act::Identity, Act::Relu, Act::Tanh, Act::Gelu, Act::Sigmoid] {
            assert_eq!(Act::parse(a.name()), Some(a));
        }
    }

    #[test]
    fn parse_all() {
        for (s, a) in [
            ("identity", Act::Identity),
            ("relu", Act::Relu),
            ("tanh", Act::Tanh),
            ("gelu", Act::Gelu),
            ("sigmoid", Act::Sigmoid),
        ] {
            assert_eq!(Act::parse(s), Some(a));
        }
        assert_eq!(Act::parse("swish"), None);
    }
}
