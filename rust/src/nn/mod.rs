//! Neural-network pieces owned by the Rust side: a pure-Rust MLP that
//! mirrors the JAX model exactly (same parameter layout, same activations)
//! for cross-checking and XLA-free tests, parameter initialisation,
//! optimizers (SGD/Adam/AdamW), and the linear classification readout with
//! closed-form softmax-CE gradients.

pub mod activations;
pub mod init;
pub mod mlp;
pub mod module;
pub mod optimizer;
pub mod readout;

pub use activations::Act;
pub use init::kaiming_uniform;
pub use mlp::Mlp;
pub use module::{ArchSpec, Module};
pub use optimizer::{Adam, AdamW, Optimizer, Sgd};
pub use readout::Readout;

/// Parameter count of an MLP with the given layer widths
/// (matches `python/compile/model.py::param_count`).
pub fn param_count(dims: &[usize]) -> usize {
    dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
}

#[cfg(test)]
mod tests {
    #[test]
    fn param_count_matches_python() {
        // asserted on the python side too (test_aot.py)
        assert_eq!(super::param_count(&[9, 16, 8]), 296);
        assert_eq!(super::param_count(&[65, 168, 168, 64]), 50_296);
        assert_eq!(super::param_count(&[3, 50, 50, 50, 50, 50, 3]), 10_553);
    }
}
