//! First-order optimizers over flat parameter vectors.
//!
//! AdamW follows Loshchilov & Hutter (decoupled weight decay), matching the
//! paper's training setup (AdamW, lr 5e-3 for the stiff task).

/// Common interface: consume the gradient, update the parameters in place.
pub trait Optimizer {
    fn step(&mut self, theta: &mut [f32], grad: &[f32]);
    fn set_lr(&mut self, lr: f64);
    fn lr(&self) -> f64;
}

/// Plain SGD with optional momentum.
pub struct Sgd {
    lr: f64,
    momentum: f64,
    velocity: Vec<f32>,
}

impl Sgd {
    pub fn new(n: usize, lr: f64, momentum: f64) -> Self {
        Sgd { lr, momentum, velocity: vec![0.0; n] }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, theta: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(theta.len(), grad.len());
        if self.momentum == 0.0 {
            for (t, g) in theta.iter_mut().zip(grad) {
                *t -= (self.lr * *g as f64) as f32;
            }
        } else {
            for i in 0..theta.len() {
                self.velocity[i] =
                    (self.momentum * self.velocity[i] as f64 + grad[i] as f64) as f32;
                theta[i] -= (self.lr * self.velocity[i] as f64) as f32;
            }
        }
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn lr(&self) -> f64 {
        self.lr
    }
}

/// Adam (Kingma & Ba).
pub struct Adam {
    lr: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
    /// decoupled weight decay coefficient; 0 => plain Adam
    weight_decay: f64,
}

impl Adam {
    pub fn new(n: usize, lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: vec![0.0; n],
            v: vec![0.0; n],
            weight_decay: 0.0,
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, theta: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(theta.len(), grad.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..theta.len() {
            let g = grad[i] as f64;
            let m = self.beta1 * self.m[i] as f64 + (1.0 - self.beta1) * g;
            let v = self.beta2 * self.v[i] as f64 + (1.0 - self.beta2) * g * g;
            self.m[i] = m as f32;
            self.v[i] = v as f32;
            let mhat = m / bc1;
            let vhat = v / bc2;
            let mut update = self.lr * mhat / (vhat.sqrt() + self.eps);
            if self.weight_decay > 0.0 {
                update += self.lr * self.weight_decay * theta[i] as f64;
            }
            theta[i] -= update as f32;
        }
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn lr(&self) -> f64 {
        self.lr
    }
}

/// AdamW = Adam with decoupled weight decay (paper's optimizer).
pub struct AdamW(Adam);

impl AdamW {
    pub fn new(n: usize, lr: f64, weight_decay: f64) -> Self {
        let mut a = Adam::new(n, lr);
        a.weight_decay = weight_decay;
        AdamW(a)
    }
}

impl Optimizer for AdamW {
    fn step(&mut self, theta: &mut [f32], grad: &[f32]) {
        self.0.step(theta, grad)
    }

    fn set_lr(&mut self, lr: f64) {
        self.0.set_lr(lr)
    }

    fn lr(&self) -> f64 {
        self.0.lr()
    }
}

/// Cosine learning-rate schedule with warmup (used by the trainer).
pub fn cosine_lr(base: f64, step: u64, warmup: u64, total: u64) -> f64 {
    if step < warmup {
        return base * (step + 1) as f64 / warmup as f64;
    }
    let p = (step - warmup) as f64 / (total - warmup).max(1) as f64;
    let p = p.min(1.0);
    0.5 * base * (1.0 + (std::f64::consts::PI * p).cos())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// minimize f(x) = (x-3)^2 with each optimizer
    fn run<O: Optimizer>(mut opt: O, iters: usize) -> f32 {
        let mut x = vec![0.0f32];
        for _ in 0..iters {
            let g = vec![2.0 * (x[0] - 3.0)];
            opt.step(&mut x, &g);
        }
        x[0]
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let x = run(Sgd::new(1, 0.1, 0.0), 200);
        assert!((x - 3.0).abs() < 1e-4, "{x}");
        let xm = run(Sgd::new(1, 0.05, 0.9), 400);
        assert!((xm - 3.0).abs() < 1e-3, "{xm}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let x = run(Adam::new(1, 0.1), 500);
        assert!((x - 3.0).abs() < 1e-3, "{x}");
    }

    #[test]
    fn adamw_decay_shrinks_weights() {
        // zero gradient: AdamW still decays parameters, Adam does not
        let mut aw = AdamW::new(1, 0.1, 0.1);
        let mut x = vec![1.0f32];
        for _ in 0..10 {
            aw.step(&mut x, &[0.0]);
        }
        assert!(x[0] < 1.0);
        let mut a = Adam::new(1, 0.1);
        let mut y = vec![1.0f32];
        for _ in 0..10 {
            a.step(&mut y, &[0.0]);
        }
        assert!((y[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_schedule_shape() {
        let base = 1.0;
        assert!(cosine_lr(base, 0, 10, 100) < base * 0.2); // warmup start
        assert!((cosine_lr(base, 10, 10, 100) - base).abs() < 1e-9); // peak
        assert!(cosine_lr(base, 100, 10, 100) < 1e-9); // decayed
    }
}
