//! Pure-Rust MLP that mirrors the JAX/Pallas model bit-for-bit in layout
//! and architecture (not bit-for-bit in floating point — GEMM orders
//! differ — but to ~1e-5 relative, which the cross-check test asserts).
//!
//! Used (a) as an XLA-free `OdeRhs` so the whole adjoint/checkpoint stack
//! is testable without artifacts, and (b) as the oracle the XLA artifacts
//! are validated against from the Rust side.

use std::cell::RefCell;

use crate::nn::activations::Act;
use crate::nn::init::layer_offsets;
use crate::tensor::gemm::{sgemm, sgemm_at, sgemm_bt};

/// Reusable per-layer buffers: the VJP/JVP paths are called N_t·N_s times
/// per gradient, so the hot loop must not allocate (§Perf: reusing these
/// buffers cut `vjp_both` by ~25% on the benchmark model).
#[derive(Clone, Debug, Default)]
struct Scratch {
    /// layer inputs x_l
    xs: Vec<Vec<f32>>,
    /// pre-activations z_l
    pres: Vec<Vec<f32>>,
    /// cotangent ping-pong buffers
    g_a: Vec<f32>,
    g_b: Vec<f32>,
}

/// MLP with flat parameters and manual forward/VJP/JVP.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub dims: Vec<usize>,
    pub act: Act,
    pub out_act: Act,
    theta: Vec<f32>,
    scratch: RefCell<Scratch>,
}

impl Mlp {
    pub fn new(dims: Vec<usize>, act: Act, theta: Vec<f32>) -> Self {
        assert_eq!(theta.len(), crate::nn::param_count(&dims));
        Mlp { dims, act, out_act: Act::Identity, theta, scratch: RefCell::default() }
    }

    /// Size the scratch buffers for batch `bsz` (no-op when already sized).
    fn ensure_scratch(&self, bsz: usize) {
        let mut s = self.scratch.borrow_mut();
        let nl = self.n_layers();
        if s.xs.len() == nl && s.xs[0].len() == bsz * self.dims[0] {
            return;
        }
        s.xs = (0..nl).map(|l| vec![0.0f32; bsz * self.dims[l]]).collect();
        s.pres = (0..nl).map(|l| vec![0.0f32; bsz * self.dims[l + 1]]).collect();
        let widest = bsz * self.dims.iter().copied().max().unwrap();
        s.g_a = vec![0.0f32; widest];
        s.g_b = vec![0.0f32; widest];
    }

    pub fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    pub fn in_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn out_dim(&self) -> usize {
        *self.dims.last().unwrap()
    }

    pub fn params(&self) -> &[f32] {
        &self.theta
    }

    pub fn set_params(&mut self, theta: &[f32]) {
        assert_eq!(theta.len(), self.theta.len());
        self.theta.copy_from_slice(theta);
    }

    fn layer_act(&self, l: usize) -> Act {
        if l + 1 < self.n_layers() + 1 && l < self.n_layers() - 1 {
            self.act
        } else {
            self.out_act
        }
    }

    fn weights(&self, l: usize) -> (&[f32], &[f32]) {
        let (w_off, b_off, end) = layer_offsets(&self.dims, l);
        (&self.theta[w_off..b_off], &self.theta[b_off..end])
    }

    /// Forward pass: x [B, in] -> y [B, out].
    pub fn forward(&self, b: usize, x: &[f32], y: &mut Vec<f32>) {
        let mut h = x.to_vec();
        for l in 0..self.n_layers() {
            h = self.layer_forward(b, l, &h).0;
        }
        y.clear();
        y.extend_from_slice(&h);
    }

    /// One layer: returns (post-activation, pre-activation).
    fn layer_forward(&self, bsz: usize, l: usize, x: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let (din, dout) = (self.dims[l], self.dims[l + 1]);
        let (w, b) = self.weights(l);
        let mut pre = vec![0.0f32; bsz * dout];
        sgemm(bsz, din, dout, x, w, &mut pre, 0.0);
        for row in 0..bsz {
            for j in 0..dout {
                pre[row * dout + j] += b[j];
            }
        }
        let act = self.layer_act(l);
        let mut post = pre.clone();
        act.apply_slice(&mut post);
        (post, pre)
    }

    /// Forward into the scratch caches (per-layer inputs + pre-activations).
    /// Allocation-free after the first call at a given batch size.
    fn forward_cached(&self, bsz: usize, x: &[f32], s: &mut Scratch) {
        s.xs[0].copy_from_slice(x);
        for l in 0..self.n_layers() {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let (w, b) = self.weights(l);
            // split borrows: input lives in xs[l], pre in pres[l]
            let (xs_head, xs_tail) = s.xs.split_at_mut(l + 1);
            let xin = &xs_head[l];
            let pre = &mut s.pres[l];
            sgemm(bsz, din, dout, xin, w, pre, 0.0);
            for row in 0..bsz {
                for j in 0..dout {
                    pre[row * dout + j] += b[j];
                }
            }
            if l + 1 < self.n_layers() {
                let act = self.layer_act(l);
                let nxt = &mut xs_tail[0];
                for i in 0..pre.len() {
                    nxt[i] = act.apply(pre[i]);
                }
            }
        }
    }

    /// VJP: given cotangent v [B, out], compute
    ///   gx [B, in] = v^T dy/dx   and, if `grad_theta` is Some, accumulate
    ///   v^T dy/dθ into it.
    pub fn vjp(
        &self,
        bsz: usize,
        x: &[f32],
        v: &[f32],
        gx: &mut Vec<f32>,
        mut grad_theta: Option<&mut [f32]>,
    ) {
        self.ensure_scratch(bsz);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        self.forward_cached(bsz, x, s);
        // ping-pong cotangent buffers (g_a holds gpre, g_b the next g)
        let cur_len = bsz * self.dims[self.n_layers()];
        s.g_b[..cur_len].copy_from_slice(v);
        for l in (0..self.n_layers()).rev() {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let act = self.layer_act(l);
            // gpre = g * act'(pre)
            let pre = &s.pres[l];
            let n_out = bsz * dout;
            for i in 0..n_out {
                s.g_a[i] = s.g_b[i] * act.grad(pre[i]);
            }
            let gpre = &s.g_a[..n_out];
            if let Some(gt) = grad_theta.as_deref_mut() {
                let (w_off, b_off, end) = layer_offsets(&self.dims, l);
                // gW += x^T gpre  (x is [B,din] so x^T is din×B stored [B,din])
                sgemm_at(din, bsz, dout, &s.xs[l], gpre, &mut gt[w_off..b_off], 1.0);
                // gb += column sums of gpre
                let gb = &mut gt[b_off..end];
                for row in 0..bsz {
                    for j in 0..dout {
                        gb[j] += gpre[row * dout + j];
                    }
                }
            }
            // g = gpre @ W^T (W stored [din,dout] row-major)
            let (w, _) = self.weights(l);
            sgemm_bt(bsz, dout, din, gpre, w, &mut s.g_b[..bsz * din], 0.0);
        }
        gx.clear();
        gx.extend_from_slice(&s.g_b[..bsz * self.dims[0]]);
    }

    /// JVP wrt the input: dy = (dy/dx) dx.
    pub fn jvp(&self, bsz: usize, x: &[f32], dx: &[f32], dy: &mut Vec<f32>) {
        self.ensure_scratch(bsz);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        self.forward_cached(bsz, x, s);
        s.g_b[..bsz * self.dims[0]].copy_from_slice(dx);
        for l in 0..self.n_layers() {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let (w, _) = self.weights(l);
            sgemm(bsz, din, dout, &s.g_b[..bsz * din], w, &mut s.g_a[..bsz * dout], 0.0);
            let act = self.layer_act(l);
            let pre = &s.pres[l];
            for i in 0..bsz * dout {
                s.g_b[i] = s.g_a[i] * act.grad(pre[i]);
            }
        }
        dy.clear();
        dy.extend_from_slice(&s.g_b[..bsz * self.dims[self.n_layers()]]);
    }

    /// Bytes of activations one forward eval materialises (batch included);
    /// the unit the memory model multiplies by graph depth.
    pub fn activation_bytes(&self, bsz: usize) -> u64 {
        // inputs to each layer + pre-activations kept for backward
        let mut elems = 0usize;
        for l in 0..self.n_layers() {
            elems += bsz * self.dims[l]; // layer input
            elems += bsz * self.dims[l + 1]; // pre-activation
        }
        (elems * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn mk(dims: &[usize], act: Act, seed: u64) -> Mlp {
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, dims, 1.0);
        Mlp::new(dims.to_vec(), act, theta)
    }

    /// forward via explicit loops (oracle)
    fn naive_forward(m: &Mlp, bsz: usize, x: &[f32]) -> Vec<f32> {
        let mut h = x.to_vec();
        for l in 0..m.n_layers() {
            let (din, dout) = (m.dims[l], m.dims[l + 1]);
            let (w, b) = m.weights(l);
            let mut out = vec![0.0f32; bsz * dout];
            for r in 0..bsz {
                for j in 0..dout {
                    let mut acc = b[j];
                    for i in 0..din {
                        acc += h[r * din + i] * w[i * dout + j];
                    }
                    out[r * dout + j] = m.layer_act(l).apply(acc);
                }
            }
            h = out;
        }
        h
    }

    #[test]
    fn forward_matches_naive() {
        let m = mk(&[5, 8, 4], Act::Tanh, 1);
        let mut rng = Rng::new(2);
        let x = prop::vec_normal(&mut rng, 3 * 5);
        let mut y = Vec::new();
        m.forward(3, &x, &mut y);
        let want = naive_forward(&m, 3, &x);
        crate::testing::assert_allclose(&y, &want, 1e-5, 1e-6, "mlp fwd");
    }

    #[test]
    fn vjp_matches_finite_differences() {
        prop::check("mlp-vjp-fd", 7, 10, |rng| {
            let dims = [4, 6, 3];
            let m = mk(&dims, Act::Tanh, rng.next_u64());
            let bsz = 2;
            let x = prop::vec_normal(rng, bsz * dims[0]);
            let v = prop::vec_normal(rng, bsz * dims[2]);

            let mut gx = Vec::new();
            let mut gt = vec![0.0f32; m.params().len()];
            m.vjp(bsz, &x, &v, &mut gx, Some(&mut gt));

            // scalar L(x, θ) = <f(x,θ), v>; check d/dx by central differences
            let h = 1e-3f32;
            for idx in [0usize, 3, 7] {
                let mut xp = x.clone();
                xp[idx] += h;
                let mut xm = x.clone();
                xm[idx] -= h;
                let mut yp = Vec::new();
                let mut ym = Vec::new();
                m.forward(bsz, &xp, &mut yp);
                m.forward(bsz, &xm, &mut ym);
                let fd: f64 = yp
                    .iter()
                    .zip(&ym)
                    .zip(&v)
                    .map(|((p, m_), vi)| ((*p - *m_) as f64 / (2.0 * h as f64)) * *vi as f64)
                    .sum();
                if (fd - gx[idx] as f64).abs() > 2e-2 * (1.0 + fd.abs()) {
                    return Err(format!("gx[{idx}] {} vs fd {fd}", gx[idx]));
                }
            }
            // d/dθ for a few entries
            let theta0 = m.params().to_vec();
            for idx in [0usize, 11, theta0.len() - 1] {
                let mut mp = m.clone();
                let mut tp = theta0.clone();
                tp[idx] += h;
                mp.set_params(&tp);
                let mut mm = m.clone();
                let mut tm = theta0.clone();
                tm[idx] -= h;
                mm.set_params(&tm);
                let mut yp = Vec::new();
                let mut ym = Vec::new();
                mp.forward(bsz, &x, &mut yp);
                mm.forward(bsz, &x, &mut ym);
                let fd: f64 = yp
                    .iter()
                    .zip(&ym)
                    .zip(&v)
                    .map(|((p, m_), vi)| ((*p - *m_) as f64 / (2.0 * h as f64)) * *vi as f64)
                    .sum();
                if (fd - gt[idx] as f64).abs() > 2e-2 * (1.0 + fd.abs()) {
                    return Err(format!("gθ[{idx}] {} vs fd {fd}", gt[idx]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn jvp_vjp_duality() {
        prop::check("mlp-duality", 9, 20, |rng| {
            let dims = [5, 7, 4];
            let m = mk(&dims, Act::Gelu, rng.next_u64());
            let bsz = 3;
            let x = prop::vec_normal(rng, bsz * dims[0]);
            let w = prop::vec_normal(rng, bsz * dims[0]);
            let v = prop::vec_normal(rng, bsz * dims[2]);
            let mut jw = Vec::new();
            m.jvp(bsz, &x, &w, &mut jw);
            let mut jtv = Vec::new();
            m.vjp(bsz, &x, &v, &mut jtv, None);
            let lhs = crate::tensor::dot(&v, &jw);
            let rhs = crate::tensor::dot(&jtv, &w);
            if (lhs - rhs).abs() > 1e-4 * (1.0 + lhs.abs()) {
                return Err(format!("<v,Jw> {lhs} != <J^T v,w> {rhs}"));
            }
            Ok(())
        });
    }

    #[test]
    fn activation_bytes_formula() {
        let m = mk(&[5, 8, 4], Act::Tanh, 1);
        // inputs: 5+8, pres: 8+4 per sample -> 25 floats * B=2 * 4 bytes
        assert_eq!(m.activation_bytes(2), (2 * (5 + 8 + 8 + 4) * 4) as u64);
    }
}
