//! Pure-Rust MLP that mirrors the JAX/Pallas model bit-for-bit in layout
//! and architecture (not bit-for-bit in floating point — GEMM orders
//! differ — but to ~1e-5 relative, which the cross-check test asserts).
//!
//! Since the module refactor this type is a thin facade over the
//! composable module graph: a [`crate::nn::module::Sequential`] of
//! `Linear`/`Activation` pairs (identity epilogue) whose arithmetic is
//! call-for-call identical to the historical hand-rolled implementation —
//! the `legacy` oracle in the tests below pins that equality *bitwise*.
//! Forward/VJP/JVP all route through the scratch plan (one cache arena +
//! reused work buffers), so the hot loop performs no per-call
//! allocations — including the forward path, which historically allocated
//! fresh per-layer buffers on every call.

use std::cell::RefCell;

use crate::nn::activations::Act;
use crate::nn::module::arch::dense_stack;
use crate::nn::module::{Module, Sequential};

/// Reusable buffers sized by the module scratch plan: the VJP/JVP paths
/// are called N_t·N_s times per gradient, so the hot loop must not
/// allocate (§Perf: reusing these buffers cut `vjp_both` by ~25% on the
/// benchmark model).
#[derive(Clone, Debug, Default)]
struct Scratch {
    /// forward-cache arena (layer inputs + pre-activations)
    cache: Vec<f32>,
    /// forward output staging
    y: Vec<f32>,
    /// gradient/tangent staging
    g: Vec<f32>,
    /// batch size the buffers are sized for (0 = unsized)
    bsz: usize,
}

/// MLP with flat parameters and manual forward/VJP/JVP.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub dims: Vec<usize>,
    pub act: Act,
    theta: Vec<f32>,
    seq: Sequential,
    scratch: RefCell<Scratch>,
}

impl Mlp {
    pub fn new(dims: Vec<usize>, act: Act, theta: Vec<f32>) -> Self {
        // guard the degenerate 0-layer case up front: the old scratch
        // sizing indexed its first per-layer buffer unconditionally and
        // panicked obscurely on `dims.len() < 2`
        assert!(
            dims.len() >= 2,
            "an MLP needs at least [in, out] dims (got {dims:?})"
        );
        assert_eq!(theta.len(), crate::nn::param_count(&dims));
        let seq = dense_stack(&dims, act);
        Mlp { dims, act, theta, seq, scratch: RefCell::default() }
    }

    /// Size the scratch buffers for batch `bsz` (no-op when already sized).
    fn ensure_scratch(&self, bsz: usize) {
        let mut s = self.scratch.borrow_mut();
        if s.bsz == bsz {
            return;
        }
        s.cache.resize(self.seq.cache_len(bsz), 0.0);
        let widest = bsz * self.seq.max_width();
        s.y.resize(widest, 0.0);
        s.g.resize(widest, 0.0);
        s.bsz = bsz;
    }

    pub fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    pub fn in_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn out_dim(&self) -> usize {
        // lint:allow(panic): dims is validated non-empty at construction
        *self.dims.last().unwrap()
    }

    pub fn params(&self) -> &[f32] {
        &self.theta
    }

    pub fn set_params(&mut self, theta: &[f32]) {
        assert_eq!(theta.len(), self.theta.len());
        self.theta.copy_from_slice(theta);
    }

    /// The underlying module graph (for composition with other modules).
    pub fn module(&self) -> &Sequential {
        &self.seq
    }

    /// (test oracles only: the live paths run through `seq`)
    #[cfg(test)]
    fn layer_act(&self, l: usize) -> Act {
        if l < self.n_layers() - 1 {
            self.act
        } else {
            Act::Identity
        }
    }

    #[cfg(test)]
    fn weights(&self, l: usize) -> (&[f32], &[f32]) {
        let (w_off, b_off, end) = crate::nn::init::layer_offsets(&self.dims, l);
        (&self.theta[w_off..b_off], &self.theta[b_off..end])
    }

    /// Forward pass: x [B, in] -> y [B, out].
    pub fn forward(&self, b: usize, x: &[f32], y: &mut Vec<f32>) {
        self.ensure_scratch(b);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        let n_out = b * self.out_dim();
        self.seq.forward(b, 0.0, &self.theta, x, &mut s.y[..n_out], &mut s.cache);
        y.clear();
        y.extend_from_slice(&s.y[..n_out]);
    }

    /// VJP: given cotangent v [B, out], compute
    ///   gx [B, in] = v^T dy/dx   and, if `grad_theta` is Some, accumulate
    ///   v^T dy/dθ into it.
    pub fn vjp(
        &self,
        bsz: usize,
        x: &[f32],
        v: &[f32],
        gx: &mut Vec<f32>,
        grad_theta: Option<&mut [f32]>,
    ) {
        self.ensure_scratch(bsz);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        let n_out = bsz * self.out_dim();
        let n_in = bsz * self.in_dim();
        self.seq.forward(bsz, 0.0, &self.theta, x, &mut s.y[..n_out], &mut s.cache);
        self.seq.vjp(bsz, 0.0, &self.theta, v, &mut s.g[..n_in], grad_theta, &s.cache);
        gx.clear();
        gx.extend_from_slice(&s.g[..n_in]);
    }

    /// JVP wrt the input: dy = (dy/dx) dx.
    pub fn jvp(&self, bsz: usize, x: &[f32], dx: &[f32], dy: &mut Vec<f32>) {
        self.ensure_scratch(bsz);
        let mut s = self.scratch.borrow_mut();
        let s = &mut *s;
        let n_out = bsz * self.out_dim();
        self.seq.forward(bsz, 0.0, &self.theta, x, &mut s.y[..n_out], &mut s.cache);
        self.seq.jvp(bsz, 0.0, &self.theta, dx, &mut s.g[..n_out], &s.cache);
        dy.clear();
        dy.extend_from_slice(&s.g[..n_out]);
    }

    /// Bytes of activations one forward eval materialises (batch included);
    /// the unit the memory model multiplies by graph depth.  Closed form —
    /// the per-module accounting of the underlying graph reproduces it
    /// exactly (asserted in the tests and in `methods::memmodel`).
    pub fn activation_bytes(&self, bsz: usize) -> u64 {
        // inputs to each layer + pre-activations kept for backward
        let mut elems = 0usize;
        for l in 0..self.n_layers() {
            elems += bsz * self.dims[l]; // layer input
            elems += bsz * self.dims[l + 1]; // pre-activation
        }
        (elems * 4) as u64
    }

    /// The same quantity, summed from the per-module scratch plans.
    pub fn module_activation_bytes(&self, bsz: usize) -> u64 {
        self.seq.activation_bytes(bsz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn mk(dims: &[usize], act: Act, seed: u64) -> Mlp {
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, dims, 1.0);
        Mlp::new(dims.to_vec(), act, theta)
    }

    /// forward via explicit loops (oracle)
    fn naive_forward(m: &Mlp, bsz: usize, x: &[f32]) -> Vec<f32> {
        let mut h = x.to_vec();
        for l in 0..m.n_layers() {
            let (din, dout) = (m.dims[l], m.dims[l + 1]);
            let (w, b) = m.weights(l);
            let mut out = vec![0.0f32; bsz * dout];
            for r in 0..bsz {
                for j in 0..dout {
                    let mut acc = b[j];
                    for i in 0..din {
                        acc += h[r * din + i] * w[i * dout + j];
                    }
                    out[r * dout + j] = m.layer_act(l).apply(acc);
                }
            }
            h = out;
        }
        h
    }

    #[test]
    fn forward_matches_naive() {
        let m = mk(&[5, 8, 4], Act::Tanh, 1);
        let mut rng = Rng::new(2);
        let x = prop::vec_normal(&mut rng, 3 * 5);
        let mut y = Vec::new();
        m.forward(3, &x, &mut y);
        let want = naive_forward(&m, 3, &x);
        crate::testing::assert_allclose(&y, &want, 1e-5, 1e-6, "mlp fwd");
    }

    #[test]
    fn vjp_matches_finite_differences() {
        prop::check("mlp-vjp-fd", 7, 10, |rng| {
            let dims = [4, 6, 3];
            let m = mk(&dims, Act::Tanh, rng.next_u64());
            let bsz = 2;
            let x = prop::vec_normal(rng, bsz * dims[0]);
            let v = prop::vec_normal(rng, bsz * dims[2]);

            let mut gx = Vec::new();
            let mut gt = vec![0.0f32; m.params().len()];
            m.vjp(bsz, &x, &v, &mut gx, Some(&mut gt));

            // scalar L(x, θ) = <f(x,θ), v>; check d/dx by central differences
            let h = 1e-3f32;
            for idx in [0usize, 3, 7] {
                let mut xp = x.clone();
                xp[idx] += h;
                let mut xm = x.clone();
                xm[idx] -= h;
                let mut yp = Vec::new();
                let mut ym = Vec::new();
                m.forward(bsz, &xp, &mut yp);
                m.forward(bsz, &xm, &mut ym);
                let fd: f64 = yp
                    .iter()
                    .zip(&ym)
                    .zip(&v)
                    .map(|((p, m_), vi)| ((*p - *m_) as f64 / (2.0 * h as f64)) * *vi as f64)
                    .sum();
                if (fd - gx[idx] as f64).abs() > 2e-2 * (1.0 + fd.abs()) {
                    return Err(format!("gx[{idx}] {} vs fd {fd}", gx[idx]));
                }
            }
            // d/dθ for a few entries
            let theta0 = m.params().to_vec();
            for idx in [0usize, 11, theta0.len() - 1] {
                let mut mp = m.clone();
                let mut tp = theta0.clone();
                tp[idx] += h;
                mp.set_params(&tp);
                let mut mm = m.clone();
                let mut tm = theta0.clone();
                tm[idx] -= h;
                mm.set_params(&tm);
                let mut yp = Vec::new();
                let mut ym = Vec::new();
                mp.forward(bsz, &x, &mut yp);
                mm.forward(bsz, &x, &mut ym);
                let fd: f64 = yp
                    .iter()
                    .zip(&ym)
                    .zip(&v)
                    .map(|((p, m_), vi)| ((*p - *m_) as f64 / (2.0 * h as f64)) * *vi as f64)
                    .sum();
                if (fd - gt[idx] as f64).abs() > 2e-2 * (1.0 + fd.abs()) {
                    return Err(format!("gθ[{idx}] {} vs fd {fd}", gt[idx]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn jvp_vjp_duality() {
        prop::check("mlp-duality", 9, 20, |rng| {
            let dims = [5, 7, 4];
            let m = mk(&dims, Act::Gelu, rng.next_u64());
            let bsz = 3;
            let x = prop::vec_normal(rng, bsz * dims[0]);
            let w = prop::vec_normal(rng, bsz * dims[0]);
            let v = prop::vec_normal(rng, bsz * dims[2]);
            let mut jw = Vec::new();
            m.jvp(bsz, &x, &w, &mut jw);
            let mut jtv = Vec::new();
            m.vjp(bsz, &x, &v, &mut jtv, None);
            let lhs = crate::tensor::dot(&v, &jw);
            let rhs = crate::tensor::dot(&jtv, &w);
            if (lhs - rhs).abs() > 1e-4 * (1.0 + lhs.abs()) {
                return Err(format!("<v,Jw> {lhs} != <J^T v,w> {rhs}"));
            }
            Ok(())
        });
    }

    #[test]
    fn activation_bytes_formula() {
        let m = mk(&[5, 8, 4], Act::Tanh, 1);
        // inputs: 5+8, pres: 8+4 per sample -> 25 floats * B=2 * 4 bytes
        assert_eq!(m.activation_bytes(2), (2 * (5 + 8 + 8 + 4) * 4) as u64);
    }

    #[test]
    fn per_module_accounting_reproduces_closed_form() {
        for dims in [vec![5usize, 8, 4], vec![3, 50, 50, 3], vec![9, 16, 8], vec![7, 2]] {
            let m = mk(&dims, Act::Gelu, 5);
            for bsz in [1usize, 2, 16] {
                assert_eq!(
                    m.module_activation_bytes(bsz),
                    m.activation_bytes(bsz),
                    "{dims:?} at B={bsz}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "at least [in, out]")]
    fn degenerate_dims_are_rejected_up_front() {
        let _ = Mlp::new(vec![4], Act::Tanh, Vec::new());
    }

    /// The pre-refactor hand-rolled implementation, kept verbatim as the
    /// bitwise oracle: the module recomposition must reproduce it exactly
    /// (same sgemm calls in the same order on the same buffers).
    mod legacy {
        use crate::nn::init::layer_offsets;
        use crate::nn::Act;
        use crate::tensor::gemm::{sgemm, sgemm_at, sgemm_bt};

        pub struct LegacyMlp {
            pub dims: Vec<usize>,
            pub act: Act,
            pub theta: Vec<f32>,
        }

        impl LegacyMlp {
            fn n_layers(&self) -> usize {
                self.dims.len() - 1
            }

            fn layer_act(&self, l: usize) -> Act {
                if l < self.n_layers() - 1 {
                    self.act
                } else {
                    Act::Identity
                }
            }

            fn weights(&self, l: usize) -> (&[f32], &[f32]) {
                let (w_off, b_off, end) = layer_offsets(&self.dims, l);
                (&self.theta[w_off..b_off], &self.theta[b_off..end])
            }

            fn forward_cached(
                &self,
                bsz: usize,
                x: &[f32],
                xs: &mut Vec<Vec<f32>>,
                pres: &mut Vec<Vec<f32>>,
            ) {
                xs.clear();
                pres.clear();
                xs.push(x.to_vec());
                for l in 0..self.n_layers() {
                    let (din, dout) = (self.dims[l], self.dims[l + 1]);
                    let (w, b) = self.weights(l);
                    let mut pre = vec![0.0f32; bsz * dout];
                    sgemm(bsz, din, dout, &xs[l], w, &mut pre, 0.0);
                    for row in 0..bsz {
                        for j in 0..dout {
                            pre[row * dout + j] += b[j];
                        }
                    }
                    if l + 1 < self.n_layers() {
                        let act = self.layer_act(l);
                        let mut nxt = vec![0.0f32; bsz * dout];
                        for i in 0..pre.len() {
                            nxt[i] = act.apply(pre[i]);
                        }
                        xs.push(nxt);
                    }
                    pres.push(pre);
                }
            }

            pub fn forward(&self, bsz: usize, x: &[f32]) -> Vec<f32> {
                let (mut xs, mut pres) = (Vec::new(), Vec::new());
                self.forward_cached(bsz, x, &mut xs, &mut pres);
                let last = pres.last().unwrap();
                let act = self.layer_act(self.n_layers() - 1);
                last.iter().map(|&p| act.apply(p)).collect()
            }

            pub fn vjp(
                &self,
                bsz: usize,
                x: &[f32],
                v: &[f32],
                grad_theta: Option<&mut [f32]>,
            ) -> Vec<f32> {
                let (mut xs, mut pres) = (Vec::new(), Vec::new());
                self.forward_cached(bsz, x, &mut xs, &mut pres);
                let widest = bsz * self.dims.iter().copied().max().unwrap();
                let mut g_a = vec![0.0f32; widest];
                let mut g_b = vec![0.0f32; widest];
                let cur_len = bsz * self.dims[self.n_layers()];
                g_b[..cur_len].copy_from_slice(v);
                let mut grad_theta = grad_theta;
                for l in (0..self.n_layers()).rev() {
                    let (din, dout) = (self.dims[l], self.dims[l + 1]);
                    let act = self.layer_act(l);
                    let pre = &pres[l];
                    let n_out = bsz * dout;
                    for i in 0..n_out {
                        g_a[i] = g_b[i] * act.grad(pre[i]);
                    }
                    let gpre = &g_a[..n_out];
                    if let Some(gt) = grad_theta.as_deref_mut() {
                        let (w_off, b_off, end) = layer_offsets(&self.dims, l);
                        sgemm_at(din, bsz, dout, &xs[l], gpre, &mut gt[w_off..b_off], 1.0);
                        let gb = &mut gt[b_off..end];
                        for row in 0..bsz {
                            for j in 0..dout {
                                gb[j] += gpre[row * dout + j];
                            }
                        }
                    }
                    let (w, _) = self.weights(l);
                    sgemm_bt(bsz, dout, din, gpre, w, &mut g_b[..bsz * din], 0.0);
                }
                g_b[..bsz * self.dims[0]].to_vec()
            }

            pub fn jvp(&self, bsz: usize, x: &[f32], dx: &[f32]) -> Vec<f32> {
                let (mut xs, mut pres) = (Vec::new(), Vec::new());
                self.forward_cached(bsz, x, &mut xs, &mut pres);
                let widest = bsz * self.dims.iter().copied().max().unwrap();
                let mut g_a = vec![0.0f32; widest];
                let mut g_b = vec![0.0f32; widest];
                g_b[..bsz * self.dims[0]].copy_from_slice(dx);
                for l in 0..self.n_layers() {
                    let (din, dout) = (self.dims[l], self.dims[l + 1]);
                    let (w, _) = self.weights(l);
                    sgemm(bsz, din, dout, &g_b[..bsz * din], w, &mut g_a[..bsz * dout], 0.0);
                    let act = self.layer_act(l);
                    let pre = &pres[l];
                    for i in 0..bsz * dout {
                        g_b[i] = g_a[i] * act.grad(pre[i]);
                    }
                }
                g_b[..bsz * self.dims[self.n_layers()]].to_vec()
            }
        }
    }

    #[test]
    fn module_recomposition_is_bitwise_equal_to_legacy() {
        prop::check("mlp-vs-legacy-bitwise", 13, 10, |rng| {
            let dims = vec![5usize, 9, 7, 4];
            let theta = crate::nn::init::kaiming_uniform(rng, &dims, 1.0);
            let act = match rng.below(3) {
                0 => Act::Tanh,
                1 => Act::Gelu,
                _ => Act::Sigmoid,
            };
            let new = Mlp::new(dims.clone(), act, theta.clone());
            let old = legacy::LegacyMlp { dims, act, theta };
            let bsz = 3;
            let x = prop::vec_normal(rng, bsz * 5);
            let v = prop::vec_normal(rng, bsz * 4);
            let w = prop::vec_normal(rng, bsz * 5);

            let mut y = Vec::new();
            new.forward(bsz, &x, &mut y);
            if y != old.forward(bsz, &x) {
                return Err("forward differs bitwise".into());
            }
            let mut gx = Vec::new();
            let mut gt_new = vec![0.0f32; new.params().len()];
            new.vjp(bsz, &x, &v, &mut gx, Some(&mut gt_new));
            let mut gt_old = vec![0.0f32; old.theta.len()];
            let gx_old = old.vjp(bsz, &x, &v, Some(&mut gt_old));
            if gx != gx_old {
                return Err("vjp gx differs bitwise".into());
            }
            if gt_new != gt_old {
                return Err("vjp gθ differs bitwise".into());
            }
            let mut dy = Vec::new();
            new.jvp(bsz, &x, &w, &mut dy);
            if dy != old.jvp(bsz, &x, &w) {
                return Err("jvp differs bitwise".into());
            }
            Ok(())
        });
    }
}
