//! Linear classification readout with closed-form softmax cross-entropy
//! gradients.
//!
//! The ODE block maps features u0 -> u(T); the readout maps u(T) -> logits.
//! Loss and all three gradients (du, dW, db) have closed forms, so this
//! layer is trained directly in Rust — no artifact needed:
//!
//!   p = softmax(u W + b),  L = -mean_i log p[i, y_i]
//!   dL/dlogits = (p - onehot(y)) / B
//!   dL/du = dL/dlogits W^T,  dL/dW = u^T dL/dlogits,  dL/db = Σ_rows

use crate::tensor::gemm::{sgemm_at, sgemm_bt, sgemm_epi};
use crate::util::rng::Rng;

/// Linear readout (D features -> K classes).
#[derive(Clone, Debug)]
pub struct Readout {
    pub d: usize,
    pub k: usize,
    /// [D, K] row-major
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

/// Result of a loss evaluation.
pub struct ReadoutGrads {
    pub loss: f64,
    pub accuracy: f64,
    /// dL/du [B, D]
    pub du: Vec<f32>,
    pub dw: Vec<f32>,
    pub db: Vec<f32>,
}

impl Readout {
    pub fn new(rng: &mut Rng, d: usize, k: usize) -> Self {
        let bound = 1.0 / (d as f32).sqrt();
        let mut w = vec![0.0f32; d * k];
        rng.fill_uniform(&mut w, -bound, bound);
        Readout { d, k, w, b: vec![0.0; k] }
    }

    pub fn n_params(&self) -> usize {
        self.d * self.k + self.k
    }

    /// logits = u W + b (bias added in the GEMM epilogue)
    pub fn logits(&self, bsz: usize, u: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; bsz * self.k];
        let b = &self.b[..self.k];
        sgemm_epi(bsz, self.d, self.k, u, &self.w, &mut out, &|_, row| {
            for (oj, bj) in row.iter_mut().zip(b) {
                *oj += *bj;
            }
        });
        out
    }

    /// Mean CE loss + accuracy + all gradients.
    pub fn loss_and_grads(&self, bsz: usize, u: &[f32], labels: &[usize]) -> ReadoutGrads {
        debug_assert_eq!(labels.len(), bsz);
        let mut p = self.logits(bsz, u);
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        // softmax rows + CE
        for r in 0..bsz {
            let row = &mut p[r * self.k..(r + 1) * self.k];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut argmax = 0;
            for (j, x) in row.iter().enumerate() {
                if *x == mx {
                    argmax = j;
                    break;
                }
            }
            if argmax == labels[r] {
                correct += 1;
            }
            let mut z = 0.0f64;
            for x in row.iter_mut() {
                *x = (*x - mx).exp();
                z += *x as f64;
            }
            for x in row.iter_mut() {
                *x = (*x as f64 / z) as f32;
            }
            loss -= (row[labels[r]].max(1e-12) as f64).ln();
        }
        loss /= bsz as f64;
        // dlogits = (p - onehot) / B
        let scale = 1.0 / bsz as f32;
        for r in 0..bsz {
            p[r * self.k + labels[r]] -= 1.0;
        }
        for x in p.iter_mut() {
            *x *= scale;
        }
        // du = dlogits @ W^T
        let mut du = vec![0.0f32; bsz * self.d];
        sgemm_bt(bsz, self.k, self.d, &p, &self.w, &mut du, 0.0);
        // dW = u^T @ dlogits
        let mut dw = vec![0.0f32; self.d * self.k];
        sgemm_at(self.d, bsz, self.k, u, &p, &mut dw, 0.0);
        // db = column sums
        let mut db = vec![0.0f32; self.k];
        for r in 0..bsz {
            for j in 0..self.k {
                db[j] += p[r * self.k + j];
            }
        }
        ReadoutGrads { loss, accuracy: correct as f64 / bsz as f64, du, dw, db }
    }

    /// SGD-style in-place update (the trainer uses its own optimizer state
    /// for θ; the readout is small enough for plain steps).
    pub fn apply_grads(&mut self, lr: f32, g: &ReadoutGrads) {
        for (w, d) in self.w.iter_mut().zip(&g.dw) {
            *w -= lr * d;
        }
        for (b, d) in self.b.iter_mut().zip(&g.db) {
            *b -= lr * d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn loss_decreases_under_training() {
        let mut rng = Rng::new(0);
        let (bsz, d, k) = (32, 8, 3);
        let mut ro = Readout::new(&mut rng, d, k);
        // separable data: class = argmax of first k features
        let mut u = vec![0.0f32; bsz * d];
        rng.fill_normal(&mut u);
        let labels: Vec<usize> = (0..bsz)
            .map(|r| {
                let row = &u[r * d..r * d + k];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect();
        let first = ro.loss_and_grads(bsz, &u, &labels).loss;
        for _ in 0..200 {
            let g = ro.loss_and_grads(bsz, &u, &labels);
            ro.apply_grads(0.5, &g);
        }
        let last = ro.loss_and_grads(bsz, &u, &labels);
        assert!(last.loss < first * 0.2, "{} -> {}", first, last.loss);
        assert!(last.accuracy > 0.9);
    }

    #[test]
    fn gradients_match_finite_differences() {
        prop::check("readout-fd", 3, 5, |rng| {
            let (bsz, d, k) = (4, 5, 3);
            let ro = Readout::new(rng, d, k);
            let u = prop::vec_normal(rng, bsz * d);
            let labels: Vec<usize> = (0..bsz).map(|_| rng.below(k)).collect();
            let g = ro.loss_and_grads(bsz, &u, &labels);
            let h = 1e-3f32;
            // check du at a few entries
            for idx in [0usize, 7, bsz * d - 1] {
                let mut up = u.clone();
                up[idx] += h;
                let mut um = u.clone();
                um[idx] -= h;
                let lp = ro.loss_and_grads(bsz, &up, &labels).loss;
                let lm = ro.loss_and_grads(bsz, &um, &labels).loss;
                let fd = (lp - lm) / (2.0 * h as f64);
                if (fd - g.du[idx] as f64).abs() > 1e-3 * (1.0 + fd.abs()) {
                    return Err(format!("du[{idx}]: {} vs fd {fd}", g.du[idx]));
                }
            }
            // check dW at a few entries
            for idx in [0usize, d * k / 2, d * k - 1] {
                let mut rp = ro.clone();
                rp.w[idx] += h;
                let mut rm = ro.clone();
                rm.w[idx] -= h;
                let lp = rp.loss_and_grads(bsz, &u, &labels).loss;
                let lm = rm.loss_and_grads(bsz, &u, &labels).loss;
                let fd = (lp - lm) / (2.0 * h as f64);
                if (fd - g.dw[idx] as f64).abs() > 1e-3 * (1.0 + fd.abs()) {
                    return Err(format!("dw[{idx}]: {} vs fd {fd}", g.dw[idx]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn softmax_probabilities_valid() {
        let mut rng = Rng::new(9);
        let ro = Readout::new(&mut rng, 4, 3);
        let u = prop::vec_normal(&mut rng, 2 * 4);
        let g = ro.loss_and_grads(2, &u, &[0, 2]);
        assert!(g.loss > 0.0);
        assert!(g.accuracy >= 0.0 && g.accuracy <= 1.0);
        // gradient wrt logits sums to ~0 per row => db sums to 0
        let s: f32 = g.db.iter().sum();
        assert!(s.abs() < 1e-6);
    }
}
