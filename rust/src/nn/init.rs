//! Parameter initialisation.
//!
//! Layout contract (shared with `python/compile/model.py`): for each layer,
//! weight `W ∈ R^{din×dout}` row-major, then bias `b ∈ R^{dout}`,
//! concatenated over layers into one flat f32 vector.

use crate::util::rng::Rng;

/// Kaiming-uniform initialisation of a full MLP parameter vector:
/// each layer's entries drawn from U(-1/sqrt(din), 1/sqrt(din)).
pub fn kaiming_uniform(rng: &mut Rng, dims: &[usize], scale: f32) -> Vec<f32> {
    let mut theta = Vec::with_capacity(super::param_count(dims));
    for w in dims.windows(2) {
        let (din, dout) = (w[0], w[1]);
        let bound = scale / (din as f32).sqrt();
        for _ in 0..din * dout + dout {
            theta.push(rng.uniform(-bound as f64, bound as f64) as f32);
        }
    }
    theta
}

/// Offsets of (W, b) for layer `l` inside the flat vector.
pub fn layer_offsets(dims: &[usize], l: usize) -> (usize, usize, usize) {
    let mut off = 0;
    for i in 0..l {
        off += dims[i] * dims[i + 1] + dims[i + 1];
    }
    let w_off = off;
    let b_off = off + dims[l] * dims[l + 1];
    let end = b_off + dims[l + 1];
    (w_off, b_off, end)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_len_and_bounds() {
        let dims = [9, 16, 8];
        let mut rng = Rng::new(0);
        let theta = kaiming_uniform(&mut rng, &dims, 1.0);
        assert_eq!(theta.len(), crate::nn::param_count(&dims));
        let bound0 = 1.0 / 3.0 + 1e-6; // 1/sqrt(9)
        for &x in &theta[..9 * 16 + 16] {
            assert!(x.abs() <= bound0);
        }
    }

    #[test]
    fn offsets_partition_vector() {
        let dims = [5, 8, 4];
        let (w0, b0, e0) = layer_offsets(&dims, 0);
        let (w1, b1, e1) = layer_offsets(&dims, 1);
        assert_eq!((w0, b0, e0), (0, 40, 48));
        assert_eq!((w1, b1, e1), (48, 48 + 32, 48 + 36));
        assert_eq!(e1, crate::nn::param_count(&dims));
    }
}
