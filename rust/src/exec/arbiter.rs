//! The shared checkpoint-memory arbiter: one global hot-tier byte pool
//! leased to concurrent per-worker tiered stores.
//!
//! [`crate::checkpoint::MemoryBudget`] caps one store; a data-parallel
//! fleet needs the *sum* of its hot tiers capped.  [`BudgetArbiter`]
//! lifts the budget to a thread-safe pool: each store holds a [`Lease`]
//! and, before growing its RAM footprint, *asks* for coverage.  Grants
//! are clipped to what the pool has left, so an over-subscribed fleet
//! degrades by spilling to its cold tiers instead of exceeding the
//! budget — the paper's memory/compute trade-off at fleet level.
//!
//! Protocol (all calls non-blocking; no ordering between workers):
//!
//! 1. `lease()` — open a zero-byte account.
//! 2. `ask(want)` — request coverage for `want` bytes total.  Returns the
//!    granted total `min(want, held + pool-remaining, fair share)`.  A
//!    clipped grant bumps the `lease_waits` / `denied_bytes` contention
//!    counters; the caller must evict down to the grant.
//! 3. `settle(bytes)` — unconditionally record actual holdings (shrink
//!    after eviction/consumption, or a *mandatory floor*: a store must
//!    keep its one working record resident even when the pool is empty —
//!    overdraw is counted in `over_grant_bytes`, never refused, so the
//!    fleet cannot deadlock).
//! 4. Dropping the lease releases everything.
//!
//! **Fair share** ([`BudgetArbiter::set_parties`]): grants are capped at
//! `total / parties`.  Without the cap a store that runs first would
//! hoard the whole pool (its checkpoints stay resident between its
//! forward and backward sweeps), and every later store's mandatory floor
//! would overdraw the budget.  With `parties =` the fleet size, floors
//! fit by construction whenever one checkpoint record fits the share, so
//! `peak_leased <= total` holds.  Parties defaults to 1 (cap = whole
//! pool).
//!
//! Determinism: grants influence *where* checkpoints live (hot vs cold),
//! never their payloads, and tiered storage is value-preserving — so
//! worker-count-dependent lease interleavings cannot change gradients.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

#[cfg(feature = "debug-sync")]
use crate::analysis::race;
use crate::obs;

/// State lock that shrugs off poisoning: every critical section below is
/// a handful of saturating counter updates that cannot unwind mid-write,
/// so a poisoned guard still holds consistent counters — and refusing to
/// settle would leak leased bytes on the panicking worker's unwind path.
fn lock_state(m: &Mutex<ArbState>) -> MutexGuard<'_, ArbState> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Aggregate pool counters (see [`BudgetArbiter::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArbiterStats {
    /// pool size in bytes
    pub total: u64,
    /// bytes currently leased out
    pub leased: u64,
    /// peak bytes ever leased out (includes mandatory-floor overdraw)
    pub peak_leased: u64,
    /// asks that could not be granted in full (contention events)
    pub lease_waits: u64,
    /// total bytes of clipped grant across all contended asks
    pub denied_bytes: u64,
    /// peak bytes leased *beyond* the pool via mandatory floors
    pub over_grant_bytes: u64,
}

#[derive(Debug, Default)]
struct ArbState {
    leased: u64,
    peak_leased: u64,
    lease_waits: u64,
    denied_bytes: u64,
    over_grant_bytes: u64,
}

/// Thread-safe global hot-tier byte pool.
#[derive(Debug)]
pub struct BudgetArbiter {
    total: u64,
    /// fleet size for the fair-share grant cap (`total / parties`)
    parties: AtomicUsize,
    state: Mutex<ArbState>,
    /// identity of this pool's byte counters for the vector-clock checker
    #[cfg(feature = "debug-sync")]
    sync_id: u64,
}

impl BudgetArbiter {
    pub fn new(total_bytes: u64) -> Arc<BudgetArbiter> {
        Arc::new(BudgetArbiter {
            total: total_bytes,
            parties: AtomicUsize::new(1),
            state: Mutex::new(ArbState::default()),
            #[cfg(feature = "debug-sync")]
            sync_id: race::new_object_id(),
        })
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Declare how many accounts will share the pool; each account's
    /// grant is capped at `total / parties` (see the module docs).
    pub fn set_parties(&self, n: usize) {
        // Relaxed: parties is a standalone tuning knob set before the
        // fleet spawns — grant math re-reads it per ask and only the byte
        // counters (which ride the state mutex) need a happens-before edge
        self.parties.store(n.max(1), Ordering::Relaxed);
    }

    pub fn stats(&self) -> ArbiterStats {
        let st = lock_state(&self.state);
        #[cfg(feature = "debug-sync")]
        race::stats_read(self.sync_id);
        ArbiterStats {
            total: self.total,
            leased: st.leased,
            peak_leased: st.peak_leased,
            lease_waits: st.lease_waits,
            denied_bytes: st.denied_bytes,
            over_grant_bytes: st.over_grant_bytes,
        }
    }

    /// Open a zero-byte lease account on this pool.
    pub fn lease(self: &Arc<Self>) -> Lease {
        Lease { arb: self.clone(), held: 0 }
    }
}

/// One store's account with the arbiter.  Releases its holdings on drop.
#[derive(Debug)]
pub struct Lease {
    arb: Arc<BudgetArbiter>,
    held: u64,
}

impl Lease {
    /// Bytes currently covered by this lease.
    pub fn held(&self) -> u64 {
        self.held
    }

    /// Ask for coverage of `want` bytes total; returns the granted total
    /// (never below the current holdings — use [`Lease::settle`] to
    /// shrink).  Grants are capped at the pool remainder AND the fair
    /// share (`total / parties`); clipped grants count as contention.
    pub fn ask(&mut self, want: u64) -> u64 {
        if want <= self.held {
            return self.held;
        }
        // the span covers the lock acquisition, so its duration IS the
        // wait this ask spent contending with the rest of the fleet
        let _sp = obs::span("lease.ask");
        // Relaxed pairs with the Relaxed store in set_parties: a stale
        // fair-share cap only re-slices grants, it cannot corrupt the
        // byte counters — those are guarded by the state mutex below
        let parties = self.arb.parties.load(Ordering::Relaxed).max(1) as u64;
        let share = self.arb.total / parties;
        let target = want.min(self.held.max(share));
        let mut st = lock_state(&self.arb.state);
        #[cfg(feature = "debug-sync")]
        race::lease_write(self.arb.sync_id);
        let avail = self.arb.total.saturating_sub(st.leased);
        let grant = self.held + avail.min(target.saturating_sub(self.held));
        if grant < want {
            st.lease_waits += 1;
            st.denied_bytes += want - grant;
            if obs::enabled() {
                obs::instant("lease.wait");
                obs::counter("lease.denied_bytes", (want - grant) as f64);
            }
        }
        st.leased += grant - self.held;
        st.peak_leased = st.peak_leased.max(st.leased);
        self.held = grant;
        grant
    }

    /// Record actual holdings of `bytes` — shrink after eviction or
    /// consumption, or grow unconditionally for a mandatory floor (the
    /// overdraw beyond the pool is counted, never refused).
    pub fn settle(&mut self, bytes: u64) {
        if bytes == self.held {
            return;
        }
        let _sp = obs::span("lease.settle");
        let mut st = lock_state(&self.arb.state);
        #[cfg(feature = "debug-sync")]
        race::lease_write(self.arb.sync_id);
        if bytes >= self.held {
            st.leased += bytes - self.held;
        } else {
            st.leased -= self.held - bytes;
        }
        self.held = bytes;
        if st.leased > self.arb.total {
            st.over_grant_bytes = st.over_grant_bytes.max(st.leased - self.arb.total);
        }
        st.peak_leased = st.peak_leased.max(st.leased);
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        self.settle(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grants_are_clipped_to_the_pool() {
        let arb = BudgetArbiter::new(1000);
        let mut a = arb.lease();
        let mut b = arb.lease();
        assert_eq!(a.ask(600), 600);
        assert_eq!(b.ask(600), 400, "second lease gets the remainder");
        let st = arb.stats();
        assert_eq!(st.leased, 1000);
        assert_eq!(st.lease_waits, 1);
        assert_eq!(st.denied_bytes, 200);
        assert_eq!(st.peak_leased, 1000);
        assert_eq!(st.over_grant_bytes, 0);
    }

    #[test]
    fn settle_shrinks_and_frees_room_for_others() {
        let arb = BudgetArbiter::new(1000);
        let mut a = arb.lease();
        let mut b = arb.lease();
        a.ask(1000);
        assert_eq!(b.ask(100), 0, "pool exhausted");
        a.settle(300);
        assert_eq!(b.ask(100), 100, "released bytes become grantable");
        assert_eq!(arb.stats().leased, 400);
    }

    #[test]
    fn ask_never_shrinks_and_is_idempotent_when_covered() {
        let arb = BudgetArbiter::new(500);
        let mut a = arb.lease();
        assert_eq!(a.ask(400), 400);
        assert_eq!(a.ask(200), 400, "already covered");
        assert_eq!(arb.stats().leased, 400);
    }

    #[test]
    fn mandatory_floor_overdraws_and_is_counted() {
        let arb = BudgetArbiter::new(100);
        let mut a = arb.lease();
        let mut b = arb.lease();
        a.ask(100);
        assert_eq!(b.ask(80), 0);
        // b must keep one 80-byte record resident regardless
        b.settle(80);
        let st = arb.stats();
        assert_eq!(st.leased, 180);
        assert_eq!(st.over_grant_bytes, 80);
        assert_eq!(st.peak_leased, 180);
    }

    #[test]
    fn parties_cap_prevents_sequential_hoarding() {
        // without the fair-share cap, a store that runs first would lease
        // the whole pool; every later store's mandatory floor would then
        // overdraw the budget
        let arb = BudgetArbiter::new(900);
        arb.set_parties(3);
        let mut a = arb.lease();
        assert_eq!(a.ask(900), 300, "capped at total/parties");
        assert_eq!(a.ask(901), 300, "repeat asks stay capped");
        let mut b = arb.lease();
        assert_eq!(b.ask(500), 300);
        let mut c = arb.lease();
        assert_eq!(c.ask(100), 100, "under-share asks granted in full");
        let st = arb.stats();
        assert!(st.peak_leased <= 900, "{st:?}");
        assert_eq!(st.over_grant_bytes, 0);
    }

    #[test]
    fn drop_releases_everything() {
        let arb = BudgetArbiter::new(256);
        {
            let mut a = arb.lease();
            a.ask(256);
            assert_eq!(arb.stats().leased, 256);
        }
        assert_eq!(arb.stats().leased, 0);
        assert_eq!(arb.stats().peak_leased, 256, "peak is a high-water mark");
    }

    #[test]
    fn concurrent_asks_never_exceed_the_pool() {
        let arb = BudgetArbiter::new(10_000);
        std::thread::scope(|s| {
            for _ in 0..8 {
                let arb = arb.clone();
                s.spawn(move || {
                    let mut l = arb.lease();
                    for want in [100u64, 900, 2500, 400] {
                        l.ask(want);
                        assert!(arb.stats().leased <= 10_000);
                        l.settle(want.min(l.held()));
                    }
                });
            }
        });
        assert_eq!(arb.stats().leased, 0);
        let st = arb.stats();
        assert!(st.peak_leased <= 10_000, "{st:?}");
        assert_eq!(st.over_grant_bytes, 0, "no floors used: {st:?}");
    }
}
