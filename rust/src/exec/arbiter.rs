//! The shared checkpoint-memory arbiter: one global hot-tier byte pool
//! leased to concurrent per-worker tiered stores.
//!
//! [`crate::checkpoint::MemoryBudget`] caps one store; a data-parallel
//! fleet needs the *sum* of its hot tiers capped.  [`BudgetArbiter`]
//! lifts the budget to a thread-safe pool: each store holds a [`Lease`]
//! and, before growing its RAM footprint, *asks* for coverage.  Grants
//! are clipped to what the pool has left, so an over-subscribed fleet
//! degrades by spilling to its cold tiers instead of exceeding the
//! budget — the paper's memory/compute trade-off at fleet level.
//!
//! Two admission styles share the pool:
//!
//! * **Checkpoint leases** — the non-blocking `lease()`/`ask` protocol
//!   below; clipped grants degrade stores to their cold tiers.
//! * **Session leases** ([`BudgetArbiter::acquire`]) — whole-session
//!   admission for the serve path: a serving sweep has no degraded mode,
//!   so it *blocks* until its bytes fit in full and an over-subscribed
//!   fleet queues instead of OOM-ing.
//!
//! Protocol (all calls non-blocking; no ordering between workers):
//!
//! 1. `lease()` — open a zero-byte account.
//! 2. `ask(want)` — request coverage for `want` bytes total.  Returns the
//!    granted total `min(want, held + pool-remaining, fair share)`.  A
//!    clipped grant bumps the `lease_waits` / `denied_bytes` contention
//!    counters; the caller must evict down to the grant.
//! 3. `settle(bytes)` — unconditionally record actual holdings (shrink
//!    after eviction/consumption, or a *mandatory floor*: a store must
//!    keep its one working record resident even when the pool is empty —
//!    overdraw is counted in `over_grant_bytes`, never refused, so the
//!    fleet cannot deadlock).
//! 4. Dropping the lease releases everything.
//!
//! **Fair share** ([`BudgetArbiter::set_parties`]): grants are capped at
//! `total / parties`.  Without the cap a store that runs first would
//! hoard the whole pool (its checkpoints stay resident between its
//! forward and backward sweeps), and every later store's mandatory floor
//! would overdraw the budget.  With `parties =` the fleet size, floors
//! fit by construction whenever one checkpoint record fits the share, so
//! `peak_leased <= total` holds.  Parties defaults to 1 (cap = whole
//! pool).
//!
//! Determinism: grants influence *where* checkpoints live (hot vs cold),
//! never their payloads, and tiered storage is value-preserving — so
//! worker-count-dependent lease interleavings cannot change gradients.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(feature = "debug-sync")]
use crate::analysis::race;
use crate::obs;

/// State lock that shrugs off poisoning: every critical section below is
/// a handful of saturating counter updates that cannot unwind mid-write,
/// so a poisoned guard still holds consistent counters — and refusing to
/// settle would leak leased bytes on the panicking worker's unwind path.
fn lock_state(m: &Mutex<ArbState>) -> MutexGuard<'_, ArbState> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Aggregate pool counters (see [`BudgetArbiter::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArbiterStats {
    /// pool size in bytes
    pub total: u64,
    /// bytes currently leased out
    pub leased: u64,
    /// peak bytes ever leased out (includes mandatory-floor overdraw)
    pub peak_leased: u64,
    /// asks that could not be granted in full (contention events)
    pub lease_waits: u64,
    /// total bytes of clipped grant across all contended asks
    pub denied_bytes: u64,
    /// peak bytes leased *beyond* the pool via mandatory floors
    pub over_grant_bytes: u64,
}

#[derive(Debug, Default)]
struct ArbState {
    leased: u64,
    peak_leased: u64,
    lease_waits: u64,
    denied_bytes: u64,
    over_grant_bytes: u64,
}

/// Thread-safe global hot-tier byte pool.
#[derive(Debug)]
pub struct BudgetArbiter {
    total: u64,
    /// fleet size for the fair-share grant cap (`total / parties`)
    parties: AtomicUsize,
    state: Mutex<ArbState>,
    /// wakes blocked [`BudgetArbiter::acquire`] calls whenever a lease
    /// shrinks or drops (bytes return to the pool)
    freed: Condvar,
    /// identity of this pool's byte counters for the vector-clock checker
    #[cfg(feature = "debug-sync")]
    sync_id: u64,
}

impl BudgetArbiter {
    pub fn new(total_bytes: u64) -> Arc<BudgetArbiter> {
        Arc::new(BudgetArbiter {
            total: total_bytes,
            parties: AtomicUsize::new(1),
            state: Mutex::new(ArbState::default()),
            freed: Condvar::new(),
            #[cfg(feature = "debug-sync")]
            sync_id: race::new_object_id(),
        })
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Declare how many accounts will share the pool; each account's
    /// grant is capped at `total / parties` (see the module docs).
    pub fn set_parties(&self, n: usize) {
        // Relaxed: parties is a standalone tuning knob set before the
        // fleet spawns — grant math re-reads it per ask and only the byte
        // counters (which ride the state mutex) need a happens-before edge
        self.parties.store(n.max(1), Ordering::Relaxed);
    }

    pub fn stats(&self) -> ArbiterStats {
        let st = lock_state(&self.state);
        #[cfg(feature = "debug-sync")]
        race::stats_read(self.sync_id);
        ArbiterStats {
            total: self.total,
            leased: st.leased,
            peak_leased: st.peak_leased,
            lease_waits: st.lease_waits,
            denied_bytes: st.denied_bytes,
            over_grant_bytes: st.over_grant_bytes,
        }
    }

    /// Open a zero-byte lease account on this pool.
    pub fn lease(self: &Arc<Self>) -> Lease {
        Lease { arb: self.clone(), held: 0 }
    }

    /// Session-level admission control (the serve path): **block** until
    /// `want` bytes fit in the pool *in full*, then lease them and return
    /// the holding lease.
    ///
    /// [`Lease::ask`]'s clipped grants are right for checkpoint stores —
    /// they degrade to their cold tiers and keep going — but a serving
    /// session has no degraded mode: a partial grant would just overdraw
    /// memory.  So an over-subscribed fleet queues here instead of
    /// OOM-ing.  Deadlock-free by the mandatory-floor rule: a request
    /// larger than the whole pool is admitted once nothing else is
    /// leased, with the overdraw counted in `over_grant_bytes` like any
    /// floor.  Each blocked acquisition bumps `lease_waits` /
    /// `denied_bytes` once and emits the same `lease.wait` instant and
    /// `lease.denied_bytes` counter through the obs sink as a clipped
    /// `ask`.  [`Lease::settle`] shrinks and lease drops wake the queue.
    pub fn acquire(self: &Arc<Self>, want: u64) -> Lease {
        // the span covers the whole blocking wait, so its duration IS the
        // admission delay this session spent queued behind the fleet
        let _sp = obs::span("lease.acquire");
        let mut st = lock_state(&self.state);
        let mut waited = false;
        while st.leased + want > self.total && st.leased > 0 {
            if !waited {
                waited = true;
                st.lease_waits += 1;
                let shortfall = want.saturating_sub(self.total.saturating_sub(st.leased));
                st.denied_bytes += shortfall;
                if obs::enabled() {
                    obs::instant("lease.wait");
                    obs::counter("lease.denied_bytes", shortfall as f64);
                }
            }
            st = match self.freed.wait(st) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
        #[cfg(feature = "debug-sync")]
        race::lease_write(self.sync_id);
        st.leased += want;
        st.peak_leased = st.peak_leased.max(st.leased);
        if st.leased > self.total {
            st.over_grant_bytes = st.over_grant_bytes.max(st.leased - self.total);
        }
        Lease { arb: self.clone(), held: want }
    }
}

/// One store's account with the arbiter.  Releases its holdings on drop.
#[derive(Debug)]
pub struct Lease {
    arb: Arc<BudgetArbiter>,
    held: u64,
}

impl Lease {
    /// Bytes currently covered by this lease.
    pub fn held(&self) -> u64 {
        self.held
    }

    /// Ask for coverage of `want` bytes total; returns the granted total
    /// (never below the current holdings — use [`Lease::settle`] to
    /// shrink).  Grants are capped at the pool remainder AND the fair
    /// share (`total / parties`); clipped grants count as contention.
    pub fn ask(&mut self, want: u64) -> u64 {
        if want <= self.held {
            return self.held;
        }
        // the span covers the lock acquisition, so its duration IS the
        // wait this ask spent contending with the rest of the fleet
        let _sp = obs::span("lease.ask");
        // Relaxed pairs with the Relaxed store in set_parties: a stale
        // fair-share cap only re-slices grants, it cannot corrupt the
        // byte counters — those are guarded by the state mutex below
        let parties = self.arb.parties.load(Ordering::Relaxed).max(1) as u64;
        let share = self.arb.total / parties;
        let target = want.min(self.held.max(share));
        let mut st = lock_state(&self.arb.state);
        #[cfg(feature = "debug-sync")]
        race::lease_write(self.arb.sync_id);
        let avail = self.arb.total.saturating_sub(st.leased);
        let grant = self.held + avail.min(target.saturating_sub(self.held));
        if grant < want {
            st.lease_waits += 1;
            st.denied_bytes += want - grant;
            if obs::enabled() {
                obs::instant("lease.wait");
                obs::counter("lease.denied_bytes", (want - grant) as f64);
            }
        }
        st.leased += grant - self.held;
        st.peak_leased = st.peak_leased.max(st.leased);
        self.held = grant;
        grant
    }

    /// Record actual holdings of `bytes` — shrink after eviction or
    /// consumption, or grow unconditionally for a mandatory floor (the
    /// overdraw beyond the pool is counted, never refused).
    pub fn settle(&mut self, bytes: u64) {
        if bytes == self.held {
            return;
        }
        let _sp = obs::span("lease.settle");
        let shrank = bytes < self.held;
        let mut st = lock_state(&self.arb.state);
        #[cfg(feature = "debug-sync")]
        race::lease_write(self.arb.sync_id);
        if bytes >= self.held {
            st.leased += bytes - self.held;
        } else {
            st.leased -= self.held - bytes;
        }
        self.held = bytes;
        if st.leased > self.arb.total {
            st.over_grant_bytes = st.over_grant_bytes.max(st.leased - self.arb.total);
        }
        st.peak_leased = st.peak_leased.max(st.leased);
        drop(st);
        if shrank {
            // bytes just returned to the pool: wake queued acquire()s
            self.arb.freed.notify_all();
        }
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        self.settle(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grants_are_clipped_to_the_pool() {
        let arb = BudgetArbiter::new(1000);
        let mut a = arb.lease();
        let mut b = arb.lease();
        assert_eq!(a.ask(600), 600);
        assert_eq!(b.ask(600), 400, "second lease gets the remainder");
        let st = arb.stats();
        assert_eq!(st.leased, 1000);
        assert_eq!(st.lease_waits, 1);
        assert_eq!(st.denied_bytes, 200);
        assert_eq!(st.peak_leased, 1000);
        assert_eq!(st.over_grant_bytes, 0);
    }

    #[test]
    fn settle_shrinks_and_frees_room_for_others() {
        let arb = BudgetArbiter::new(1000);
        let mut a = arb.lease();
        let mut b = arb.lease();
        a.ask(1000);
        assert_eq!(b.ask(100), 0, "pool exhausted");
        a.settle(300);
        assert_eq!(b.ask(100), 100, "released bytes become grantable");
        assert_eq!(arb.stats().leased, 400);
    }

    #[test]
    fn ask_never_shrinks_and_is_idempotent_when_covered() {
        let arb = BudgetArbiter::new(500);
        let mut a = arb.lease();
        assert_eq!(a.ask(400), 400);
        assert_eq!(a.ask(200), 400, "already covered");
        assert_eq!(arb.stats().leased, 400);
    }

    #[test]
    fn mandatory_floor_overdraws_and_is_counted() {
        let arb = BudgetArbiter::new(100);
        let mut a = arb.lease();
        let mut b = arb.lease();
        a.ask(100);
        assert_eq!(b.ask(80), 0);
        // b must keep one 80-byte record resident regardless
        b.settle(80);
        let st = arb.stats();
        assert_eq!(st.leased, 180);
        assert_eq!(st.over_grant_bytes, 80);
        assert_eq!(st.peak_leased, 180);
    }

    #[test]
    fn parties_cap_prevents_sequential_hoarding() {
        // without the fair-share cap, a store that runs first would lease
        // the whole pool; every later store's mandatory floor would then
        // overdraw the budget
        let arb = BudgetArbiter::new(900);
        arb.set_parties(3);
        let mut a = arb.lease();
        assert_eq!(a.ask(900), 300, "capped at total/parties");
        assert_eq!(a.ask(901), 300, "repeat asks stay capped");
        let mut b = arb.lease();
        assert_eq!(b.ask(500), 300);
        let mut c = arb.lease();
        assert_eq!(c.ask(100), 100, "under-share asks granted in full");
        let st = arb.stats();
        assert!(st.peak_leased <= 900, "{st:?}");
        assert_eq!(st.over_grant_bytes, 0);
    }

    #[test]
    fn drop_releases_everything() {
        let arb = BudgetArbiter::new(256);
        {
            let mut a = arb.lease();
            a.ask(256);
            assert_eq!(arb.stats().leased, 256);
        }
        assert_eq!(arb.stats().leased, 0);
        assert_eq!(arb.stats().peak_leased, 256, "peak is a high-water mark");
    }

    #[test]
    fn acquire_blocks_until_bytes_return_and_counts_the_wait() {
        let arb = BudgetArbiter::new(1000);
        let first = arb.acquire(800);
        assert_eq!(arb.stats().leased, 800);
        assert_eq!(arb.stats().lease_waits, 0, "uncontended admission is free");
        std::thread::scope(|s| {
            let arb2 = arb.clone();
            let t = s.spawn(move || {
                // needs 400 but only 200 remain: must queue until `first` drops
                let l = arb2.acquire(400);
                let held = l.held();
                drop(l);
                held
            });
            // wait until the waiter has actually queued (its block is
            // counted), then release the bytes it needs
            for _ in 0..2000 {
                if arb.stats().lease_waits == 1 {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            assert_eq!(arb.stats().lease_waits, 1, "waiter must have queued");
            drop(first);
            assert_eq!(t.join().unwrap(), 400);
        });
        let st = arb.stats();
        assert_eq!(st.leased, 0, "both session leases released");
        assert_eq!(st.lease_waits, 1, "the queued admission counted once");
        assert_eq!(st.denied_bytes, 200, "shortfall at block time");
        assert!(st.peak_leased <= 1000, "{st:?}");
        assert_eq!(st.over_grant_bytes, 0);
    }

    #[test]
    fn oversized_acquire_admits_alone_and_counts_overdraw() {
        // a single session bigger than the pool must not deadlock the
        // fleet: it is admitted once the pool is otherwise empty, like a
        // mandatory floor
        let arb = BudgetArbiter::new(100);
        let big = arb.acquire(250);
        assert_eq!(big.held(), 250);
        let st = arb.stats();
        assert_eq!(st.leased, 250);
        assert_eq!(st.over_grant_bytes, 150);
        drop(big);
        assert_eq!(arb.stats().leased, 0);
    }

    #[test]
    fn concurrent_acquires_serialize_within_the_pool() {
        // 4 threads × 10 acquisitions of 600 against a 1000-byte pool:
        // at most one sweep can hold bytes at a time, so leased never
        // exceeds the pool and everything drains
        let arb = BudgetArbiter::new(1000);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let arb = arb.clone();
                s.spawn(move || {
                    for _ in 0..10 {
                        let l = arb.acquire(600);
                        assert!(arb.stats().leased <= 1000);
                        drop(l);
                    }
                });
            }
        });
        let st = arb.stats();
        assert_eq!(st.leased, 0);
        assert!(st.peak_leased <= 1000, "{st:?}");
        assert_eq!(st.over_grant_bytes, 0, "no session exceeded the pool: {st:?}");
    }

    #[test]
    fn concurrent_asks_never_exceed_the_pool() {
        let arb = BudgetArbiter::new(10_000);
        std::thread::scope(|s| {
            for _ in 0..8 {
                let arb = arb.clone();
                s.spawn(move || {
                    let mut l = arb.lease();
                    for want in [100u64, 900, 2500, 400] {
                        l.ask(want);
                        assert!(arb.stats().leased <= 10_000);
                        l.settle(want.min(l.held()));
                    }
                });
            }
        });
        assert_eq!(arb.stats().leased, 0);
        let st = arb.stats();
        assert!(st.peak_leased <= 10_000, "{st:?}");
        assert_eq!(st.over_grant_bytes, 0, "no floors used: {st:?}");
    }
}
