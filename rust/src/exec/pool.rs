//! Scoped worker pool: a fixed job list drained by `workers` threads
//! claiming indices from an atomic counter (dynamic load balancing — a
//! slow shard never serializes the fast ones behind it).
//!
//! Results land in per-index slots, so the returned `Vec` is in job
//! order regardless of which worker ran what — callers downstream (the
//! deterministic tree reduction, row concatenation) see a worker-count-
//! independent ordering by construction.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

#[cfg(feature = "debug-sync")]
use crate::analysis::race;
use crate::obs;

/// Slot lock that shrugs off poisoning: slots hold plain moved-in data
/// (no invariants spanning the lock), and a panicking job propagates
/// through the scope join anyway — recovering here never observes a
/// half-written value.
fn lock_slot<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Run one pool job inside its own obs logical-thread context: events are
/// keyed by job index (`job + 1`; 0 is the main thread), not by OS
/// thread, so the merged trace is identical across runs and worker
/// counts even though index claiming is dynamic.
fn run_job_observed<T>(i: usize, job: impl FnOnce(usize) -> T) -> T {
    let _ctx = obs::job_ctx(i as u32 + 1);
    let _sp = obs::span("pool.job");
    job(i)
}

/// Run `job(0..n_jobs)` on up to `workers` threads; results in job order.
///
/// `workers <= 1` (or a single job) runs inline on the caller's thread.
/// A panicking job propagates the panic to the caller once the scope
/// joins.
pub fn run_indexed<T, F>(workers: usize, n_jobs: usize, job: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n_jobs == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n_jobs);
    if workers == 1 {
        return (0..n_jobs).map(|i| run_job_observed(i, &job)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n_jobs).map(|_| Mutex::new(None)).collect();
    #[cfg(feature = "debug-sync")]
    let run_id = race::pool_run_begin(n_jobs);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                // Relaxed suffices: the RMW only hands out distinct
                // indices; each result is published by the slot mutex
                // (release at unlock → acquire at collection), and the
                // collector runs after the scope join, a full edge
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_jobs {
                    break;
                }
                #[cfg(feature = "debug-sync")]
                race::pool_claim(run_id, i);
                let out = run_job_observed(i, &job);
                *lock_slot(&slots[i]) = Some(out);
                #[cfg(feature = "debug-sync")]
                race::pool_complete(run_id, i);
            });
        }
    });
    #[cfg(feature = "debug-sync")]
    race::pool_scope_join(run_id);
    slots
        .into_iter()
        .enumerate()
        .map(|(_i, m)| {
            #[cfg(feature = "debug-sync")]
            race::pool_collect(run_id, _i);
            let slot = match m.into_inner() {
                Ok(v) => v,
                Err(poisoned) => poisoned.into_inner(),
            };
            slot.expect("scope joined with every job done") // lint:allow(panic): the counter runs past n_jobs before any worker exits, so a joined scope has filled every slot
        })
        .collect()
}

/// [`run_indexed`] over owned one-shot jobs (each consumed exactly once).
pub fn run_once_jobs<T, J>(workers: usize, jobs: Vec<J>) -> Vec<T>
where
    T: Send,
    J: FnOnce() -> T + Send,
{
    let jobs: Vec<Mutex<Option<J>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    run_indexed(workers, jobs.len(), |i| {
        // lint:allow(panic): the atomic counter hands each index to exactly one worker
        let job = lock_slot(&jobs[i]).take().expect("index claimed once");
        job()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_are_in_job_order_for_any_worker_count() {
        for workers in [1usize, 2, 3, 8, 64] {
            let out = run_indexed(workers, 17, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>(), "workers={workers}");
        }
        assert!(run_indexed(4, 0, |i| i).is_empty());
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let hits = AtomicU64::new(0);
        let out = run_indexed(5, 100, |i| {
            hits.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert_eq!(out.len(), 100);
    }

    #[test]
    fn once_jobs_move_their_captures() {
        let jobs: Vec<_> = (0..6)
            .map(|i| {
                let owned = vec![i as f32; 4];
                move || owned.iter().sum::<f32>()
            })
            .collect();
        let out = run_once_jobs(3, jobs);
        assert_eq!(out, vec![0.0, 4.0, 8.0, 12.0, 16.0, 20.0]);
    }
}
