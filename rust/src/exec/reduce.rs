//! Fixed-order tree reduction for shard results.
//!
//! Floating-point addition is not associative, so the *shape* of the
//! reduction is part of the result.  These combiners always pair
//! neighbours `(0,1), (2,3), …` round by round over the shard-ordered
//! input — the shape depends only on the number of shards, never on how
//! many workers computed them or in what order they finished.  That is
//! the second half of the engine's determinism argument (the first half
//! is worker-count-independent sharding).

/// Fold `items` with `combine` over a fixed-shape binary tree.
/// Returns `None` for an empty input.
pub fn tree_fold<T>(mut items: Vec<T>, combine: impl Fn(T, T) -> T) -> Option<T> {
    while items.len() > 1 {
        let mut next = Vec::with_capacity(items.len().div_ceil(2));
        let mut it = items.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(combine(a, b)),
                None => next.push(a), // odd tail passes through unchanged
            }
        }
        items = next;
    }
    items.pop()
}

/// Elementwise tree-sum of equal-length vectors.
pub fn tree_sum(parts: Vec<Vec<f32>>) -> Option<Vec<f32>> {
    tree_fold(parts, |mut a, b| {
        debug_assert_eq!(a.len(), b.len());
        for (x, y) in a.iter_mut().zip(&b) {
            *x += *y;
        }
        a
    })
}

/// `acc += tree_sum(parts)` (no-op for empty `parts`).
pub fn tree_sum_into(acc: &mut [f32], parts: Vec<Vec<f32>>) {
    if let Some(total) = tree_sum(parts) {
        assert_eq!(total.len(), acc.len(), "shard gradient length mismatch");
        for (x, y) in acc.iter_mut().zip(&total) {
            *x += *y;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tree_shape_is_the_documented_pairing() {
        // strings make the reduction shape observable
        let parts: Vec<String> = ["a", "b", "c", "d", "e"].iter().map(|s| s.to_string()).collect();
        let folded = tree_fold(parts, |a, b| format!("({a}+{b})"));
        assert_eq!(folded.as_deref(), Some("(((a+b)+(c+d))+e)"));
        assert_eq!(tree_fold(Vec::<u32>::new(), |a, b| a + b), None);
        assert_eq!(tree_fold(vec![7u32], |a, b| a + b), Some(7));
    }

    #[test]
    fn tree_sum_is_reproducible_and_shape_dependent() {
        let mut rng = Rng::new(9);
        let parts: Vec<Vec<f32>> = (0..7)
            .map(|_| {
                let mut v = vec![0.0f32; 33];
                rng.fill_normal(&mut v);
                // widen the dynamic range so fold order visibly matters
                for (i, x) in v.iter_mut().enumerate() {
                    *x *= 10f32.powi((i % 7) as i32 - 3);
                }
                v
            })
            .collect();
        let a = tree_sum(parts.clone()).unwrap();
        let b = tree_sum(parts.clone()).unwrap();
        assert_eq!(a, b, "same shards, same shape, same bits");
        // a left fold is a different shape; it may (and generally does)
        // differ in the last bits — the point of fixing the tree
        let left = parts
            .clone()
            .into_iter()
            .reduce(|mut x, y| {
                for (p, q) in x.iter_mut().zip(&y) {
                    *p += *q;
                }
                x
            })
            .unwrap();
        let close = a.iter().zip(&left).all(|(x, y)| (x - y).abs() <= 1e-3 * (1.0 + y.abs()));
        assert!(close, "shapes agree to rounding");
    }

    #[test]
    fn tree_sum_into_accumulates() {
        let mut acc = vec![1.0f32, 2.0];
        tree_sum_into(&mut acc, vec![vec![0.5, 0.5], vec![0.25, 0.25], vec![0.25, 0.25]]);
        assert_eq!(acc, vec![2.0, 3.0]);
        tree_sum_into(&mut acc, Vec::new());
        assert_eq!(acc, vec![2.0, 3.0]);
    }
}
