//! Data-parallel adjoint execution engine (DESIGN.md §8).
//!
//! Three independent pieces compose into the fleet-level system:
//!
//! * [`pool`] — a scoped worker pool draining an indexed job list
//!   (results always in job order);
//! * [`reduce`] — fixed-shape tree reduction, so combined shard
//!   gradients are bitwise identical for `workers = 1, 2, N`;
//! * [`arbiter`] — the shared checkpoint-memory arbiter leasing one
//!   global hot-tier byte pool to concurrent tiered stores.
//!
//! The determinism contract: *sharding* is a pure function of the batch
//! size and [`ExecConfig::shard_rows`] (never of the worker count), each
//! shard's computation is self-contained, and the reduction shape is
//! fixed by the shard count — so the worker count only changes wall
//! clock, never bits.  See [`crate::methods::ParallelAdjoint`] for the
//! end-to-end wrapper.

pub mod arbiter;
pub mod pool;
pub mod reduce;

pub use arbiter::{ArbiterStats, BudgetArbiter, Lease};

/// Default rows per shard: small enough that a typical minibatch yields
/// more shards than cores (load balancing), large enough that per-shard
/// GEMMs stay efficient.
pub const DEFAULT_SHARD_ROWS: usize = 16;

/// Worker-pool configuration for data-parallel gradient execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecConfig {
    /// concurrent worker threads (wall-clock knob; never changes bits)
    pub workers: usize,
    /// rows per shard (determinism knob: fixes the shard decomposition
    /// and therefore the reduction shape)
    pub shard_rows: usize,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig { workers: default_workers(), shard_rows: DEFAULT_SHARD_ROWS }
    }
}

impl ExecConfig {
    pub fn with_workers(workers: usize) -> Self {
        ExecConfig { workers, shard_rows: DEFAULT_SHARD_ROWS }
    }
}

/// Default worker count: `PNODE_WORKERS` if set (>= 1), else the
/// machine's available parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("PNODE_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Decompose `rows` batch rows into contiguous shards of (at most)
/// `shard_rows` rows.  Depends only on its arguments — in particular not
/// on the worker count — which is what makes shard-order concatenation
/// and tree reduction worker-count independent.
pub fn shard_ranges(rows: usize, shard_rows: usize) -> Vec<std::ops::Range<usize>> {
    let sr = shard_rows.max(1);
    (0..rows).step_by(sr).map(|lo| lo..(lo + sr).min(rows)).collect()
}

/// Execution counters for one data-parallel gradient, reported through
/// `MethodReport::exec` into `ExperimentRow`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ExecStats {
    /// worker threads used
    pub workers: u64,
    /// shards the batch was decomposed into
    pub shards: u64,
    /// batch rows per second over the forward+backward pair
    pub samples_per_sec: f64,
    /// global hot-tier pool size (0 when no arbiter governs the run)
    pub lease_pool_bytes: u64,
    /// arbiter peak leased bytes (the fleet's concurrent hot footprint)
    pub peak_leased_bytes: u64,
    /// clipped lease asks during this gradient (contention events)
    pub lease_waits: u64,
    /// bytes of clipped grant during this gradient
    pub lease_denied_bytes: u64,
    /// peak mandatory-floor overdraw beyond the pool
    pub over_grant_bytes: u64,
    /// how many per-block stats this aggregate folds together (0 on a
    /// raw, never-merged struct, which represents a single block) —
    /// makes the conservative min-throughput `samples_per_sec`
    /// interpretable downstream
    pub blocks_merged: u64,
}

impl ExecStats {
    /// Fold another block's execution stats into this aggregate
    /// (multi-block tasks run their blocks sequentially): contention
    /// counters accumulate, peaks widen, and the reported throughput is
    /// the slowest block's (conservative).
    pub fn merge(&mut self, other: &ExecStats) {
        // a freshly produced per-block stats struct carries 0 and counts
        // as one block, so the aggregate says how many mins were taken;
        // seed aggregates from a real first block (not a default) or the
        // empty accumulator is itself counted
        self.blocks_merged = self.blocks_merged.max(1) + other.blocks_merged.max(1);
        self.workers = self.workers.max(other.workers);
        self.shards = self.shards.max(other.shards);
        self.samples_per_sec = if self.samples_per_sec == 0.0 {
            other.samples_per_sec
        } else if other.samples_per_sec == 0.0 {
            self.samples_per_sec
        } else {
            self.samples_per_sec.min(other.samples_per_sec)
        };
        self.lease_pool_bytes = self.lease_pool_bytes.max(other.lease_pool_bytes);
        self.peak_leased_bytes = self.peak_leased_bytes.max(other.peak_leased_bytes);
        self.lease_waits += other.lease_waits;
        self.lease_denied_bytes += other.lease_denied_bytes;
        self.over_grant_bytes = self.over_grant_bytes.max(other.over_grant_bytes);
    }

    /// Fold a *concurrent* peer's stats into this aggregate — the serve
    /// fleet's semantics.  [`ExecStats::merge`] takes the `min` of
    /// `samples_per_sec` because sequential blocks bottleneck on the
    /// slowest; sessions in a serve pool run side by side, so the
    /// fleet's aggregate throughput is the **sum** of per-session
    /// throughputs.  Everything else folds exactly like `merge`
    /// (peaks widen, contention counters accumulate), and `0.0` still
    /// reads as "unset" on either side rather than contributing zero.
    pub fn merge_sum(&mut self, other: &ExecStats) {
        let mine = self.samples_per_sec;
        self.merge(other);
        self.samples_per_sec = if mine == 0.0 {
            other.samples_per_sec
        } else if other.samples_per_sec == 0.0 {
            mine
        } else {
            mine + other.samples_per_sec
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_tile_exactly_and_ignore_worker_count() {
        let r = shard_ranges(40, 16);
        assert_eq!(r, vec![0..16, 16..32, 32..40]);
        assert_eq!(shard_ranges(16, 16), vec![0..16]);
        assert_eq!(shard_ranges(5, 2), vec![0..2, 2..4, 4..5]);
        assert_eq!(shard_ranges(0, 8), Vec::<std::ops::Range<usize>>::new());
        assert_eq!(shard_ranges(3, 0), vec![0..1, 1..2, 2..3], "shard_rows clamps to 1");
        // coverage is a partition
        let r = shard_ranges(101, 7);
        let total: usize = r.iter().map(|x| x.len()).sum();
        assert_eq!(total, 101);
        for w in r.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
    }

    #[test]
    fn default_workers_is_at_least_one() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn exec_stats_merge_semantics() {
        let mut a = ExecStats {
            workers: 4,
            shards: 8,
            samples_per_sec: 100.0,
            lease_pool_bytes: 1024,
            peak_leased_bytes: 900,
            lease_waits: 2,
            lease_denied_bytes: 64,
            over_grant_bytes: 0,
            blocks_merged: 0,
        };
        let b = ExecStats {
            workers: 4,
            shards: 8,
            samples_per_sec: 80.0,
            lease_pool_bytes: 1024,
            peak_leased_bytes: 1000,
            lease_waits: 1,
            lease_denied_bytes: 16,
            over_grant_bytes: 8,
            blocks_merged: 0,
        };
        a.merge(&b);
        assert_eq!(a.samples_per_sec, 80.0, "slowest block wins");
        assert_eq!(a.peak_leased_bytes, 1000);
        assert_eq!(a.lease_waits, 3);
        assert_eq!(a.lease_denied_bytes, 80);
        assert_eq!(a.over_grant_bytes, 8);
        assert_eq!(a.blocks_merged, 2, "two raw per-block stats folded");
        let mut c = ExecStats::default();
        c.merge(&a);
        assert_eq!(c.samples_per_sec, 80.0, "zero treated as unset");
        assert_eq!(c.blocks_merged, 3, "a default self still counts as one block");
    }

    #[test]
    fn exec_stats_merge_sum_adds_concurrent_throughput() {
        // pin both folds side by side: sequential blocks take the min,
        // concurrent serve sessions take the sum
        let a0 = ExecStats { samples_per_sec: 100.0, lease_waits: 2, ..Default::default() };
        let b = ExecStats {
            samples_per_sec: 80.0,
            lease_waits: 1,
            peak_leased_bytes: 512,
            ..Default::default()
        };
        let mut seq = a0;
        seq.merge(&b);
        assert_eq!(seq.samples_per_sec, 80.0, "merge: slowest block wins");
        let mut par = a0;
        par.merge_sum(&b);
        assert_eq!(par.samples_per_sec, 180.0, "merge_sum: fleet throughput adds");
        // everything else folds identically to merge
        assert_eq!(par.lease_waits, 3);
        assert_eq!(par.peak_leased_bytes, 512);
        assert_eq!(par.blocks_merged, 2);
        // zero stays "unset" in both directions
        let mut empty = ExecStats::default();
        empty.merge_sum(&b);
        assert_eq!(empty.samples_per_sec, 80.0);
        let mut back = b;
        back.merge_sum(&ExecStats::default());
        assert_eq!(back.samples_per_sec, 80.0);
    }
}
