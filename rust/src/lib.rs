//! # PNODE-RS
//!
//! A memory-efficient neural-ODE training framework based on high-level
//! discrete adjoint differentiation — a Rust + JAX/Pallas reproduction of
//! Zhang & Zhao, *A memory-efficient neural ODE framework based on
//! high-level adjoint differentiation* (2022).
//!
//! Architecture (three layers, Python never on the training path):
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the fused dense
//!   layer at the heart of the RHS MLP, tiled for a TPU-style memory
//!   hierarchy, lowered AOT.
//! * **L2** — JAX compute graph (`python/compile/model.py`): the RHS
//!   `f(u, θ, t)` and its VJP/JVP actions, exported once as HLO text.
//! * **L3** — this crate: the PJRT runtime (behind the `xla` feature),
//!   time integrators and their discrete adjoints, checkpointing (incl.
//!   binomial/Revolve and the tiered RAM-budget/disk-spill storage
//!   backend with reverse-order prefetch), the five gradient methods from
//!   the paper (PNODE, NODE-cont, NODE-naive, ANODE, ACA), Newton–GMRES
//!   implicit solvers, the training loop, datasets, and the benchmark
//!   harness that regenerates every table and figure — all behind the
//!   typed [`api`] facade (`SolverBuilder` → `RunSpec` → `Session`),
//!   which every task, bench, example, and the CLI construct runs
//!   through.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for results.

pub mod adjoint;
pub mod analysis;
pub mod api;
pub mod bench;
pub mod checkpoint;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod linalg;
pub mod methods;
pub mod nn;
pub mod obs;
pub mod ode;
pub mod runtime;
pub mod serve;
pub mod tasks;
pub mod tensor;
pub mod testing;
pub mod train;
pub mod util;
