//! Minimal timing harness + table printer used by every `cargo bench`
//! target (`[[bench]] harness = false`), plus the facade-level gradient
//! timer [`bench_grad`] (one [`crate::api::Session`] reused across
//! iterations — the serving hot path, measured).

use std::time::Instant;

use crate::api::RunSpec;
use crate::ode::rhs::OdeRhs;
use crate::util::stats::Stream;

/// Timing statistics of a benchmarked closure.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

impl BenchResult {
    /// JSON encoding for machine-readable bench artifacts (the micro
    /// bench writes `BENCH_micro.json` at the repo root from these).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_secs", Json::num(self.mean_secs)),
            ("std_secs", Json::num(self.std_secs)),
            ("min_secs", Json::num(self.min_secs)),
            ("max_secs", Json::num(self.max_secs)),
        ])
    }

    pub fn summary(&self) -> String {
        format!(
            "{}: {} ± {} (n={}, min {}, max {})",
            self.name,
            crate::util::human_secs(self.mean_secs),
            crate::util::human_secs(self.std_secs),
            self.iters,
            crate::util::human_secs(self.min_secs),
            crate::util::human_secs(self.max_secs),
        )
    }
}

/// Run `f` `iters` times after `warmup` unmeasured runs.
pub fn bench_fn(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Stream::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        s.push(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_secs: s.mean(),
        std_secs: s.std(),
        min_secs: s.min(),
        max_secs: s.max(),
    }
}

/// Time full forward+backward gradients of `spec` on `rhs`: one session
/// opened up front, its workspaces reused every iteration (λ re-seeded
/// from `lambda_f` by `Session::grad` itself).  Panics on an invalid
/// spec — build it with `SolverBuilder`.
pub fn bench_grad(
    name: &str,
    spec: &RunSpec,
    rhs: &dyn OdeRhs,
    u0: &[f32],
    lambda_f: &[f32],
    warmup: usize,
    iters: usize,
) -> BenchResult {
    let mut session = crate::api::Session::new(spec.clone())
        .unwrap_or_else(|e| panic!("bench_grad: invalid spec: {e}"));
    bench_fn(name, warmup, iters, move || {
        let _ = session.grad(rhs, u0, lambda_f);
    })
}

/// Aligned-column table printer (paper-style output).
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for (c, cell) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", cell, w = widths[c]));
            }
            s.trim_end().to_string() + "\n"
        };
        out.push_str(&line(&self.headers, &widths));
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * ncols));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Export rows as JSON for EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::Obj(
                    self.headers
                        .iter()
                        .zip(r)
                        .map(|(h, c)| (h.clone(), Json::Str(c.clone())))
                        .collect(),
                )
            })
            .collect();
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            ("rows", Json::Arr(rows)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_fn("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
        });
        assert!(r.mean_secs >= 0.0);
        assert!(r.min_secs <= r.mean_secs + 1e-12);
        assert_eq!(r.iters, 5);
        assert!(r.summary().contains("spin"));
        // the JSON encoding round-trips through the in-tree parser
        let j = crate::util::json::parse(&r.to_json().to_string_compact()).unwrap();
        assert_eq!(j.get("name").and_then(|v| v.as_str()), Some("spin"));
        assert_eq!(j.get("iters").and_then(|v| v.as_usize()), Some(5));
        assert!(j.get("mean_secs").and_then(|v| v.as_f64()).is_some());
        assert!(j.get("max_secs").and_then(|v| v.as_f64()).is_some());
    }

    #[test]
    fn bench_grad_drives_a_facade_session() {
        use crate::api::SolverBuilder;
        use crate::nn::Act;
        use crate::ode::ModuleRhs;
        use crate::util::rng::Rng;
        let dims = vec![4, 6, 3];
        let mut rng = Rng::new(5);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
        let rhs = ModuleRhs::mlp(dims, Act::Tanh, true, 2, theta);
        let mut u0 = vec![0.0f32; rhs.state_len()];
        rng.fill_normal(&mut u0);
        let w = vec![1.0f32; rhs.state_len()];
        let spec = SolverBuilder::new().uniform(3).build().unwrap();
        let r = bench_grad("facade grad", &spec, &rhs, &u0, &w, 1, 3);
        assert_eq!(r.iters, 3);
        assert!(r.mean_secs >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Test", &["a", "column_b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["long_cell".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("== Test =="));
        assert!(s.contains("long_cell"));
        let j = t.to_json().to_string_compact();
        assert!(j.contains("column_b"));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
