//! Benchmark harness (criterion is unavailable offline): timed runs with
//! warmup and statistics, aligned table printing matching the paper's
//! table format, and JSON export of rows.

pub mod harness;

pub use harness::{bench_fn, bench_grad, BenchResult, Table};
