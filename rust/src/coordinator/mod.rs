//! Experiment coordinator: a job matrix runner that executes
//! (method × scheme × N_t) sweeps, collects rows, and writes results —
//! the "leader" of the benchmark harness.  Pure-Rust jobs run on the
//! execution engine's worker pool via [`Runner::run_jobs_parallel`]
//! (rows stay in submission order); PJRT-backed jobs run one at a time
//! on the leader thread via [`Runner::run_job`] (the PJRT CPU client is
//! not Sync), which is also the mode for precise per-job wall times.

pub mod runner;

pub use runner::{ExperimentRow, JobBody, JobMeta, Runner};
