//! Experiment coordinator: a job matrix runner that executes
//! (method × scheme × N_t) sweeps, collects rows, and writes results —
//! the "leader" of the benchmark harness.  Pure-Rust jobs can run on a
//! thread pool; PJRT-backed jobs run on the leader thread (the PJRT CPU
//! client is not Sync).

pub mod runner;

pub use runner::{ExperimentRow, Runner};
